//! Shuffle data-plane throughput in GB/s: every available XOR kernel
//! tier (bytewise oracle, portable u64, AVX2/NEON when the CPU has
//! them) × buffer sizes from 4 KiB to 256 MiB × pooled-vs-fresh buffer
//! checkout, plus the streamed huge-payload digest and a pooled
//! vs unpooled end-to-end shuffle.
//!
//! Besides the human-readable BENCH lines, this bench writes
//! `BENCH_shuffle.json` (machine-readable) so later PRs can diff the
//! shuffle data plane's throughput trajectory and catch regressions.
//! `--quick` (or `CAMR_BENCH_QUICK=1`) caps sizes at 16 MiB and drops
//! iteration counts — the cap is printed, never silent.

use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::shuffle::buf::{self, BufferPool, XorKernel};
use camr::util::bench::Bench;
use camr::util::json::Json;
use camr::workload::stream::{StreamedWorkload, SyntheticSource};
use camr::workload::synth::SyntheticWorkload;
use camr::workload::Workload;
use std::sync::Arc;

/// Bytes per nanosecond == GB/s.
fn gbps(bytes: usize, mean_ns: f64) -> f64 {
    if mean_ns > 0.0 {
        bytes as f64 / mean_ns
    } else {
        0.0
    }
}

fn main() {
    let b = Bench::new();
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("CAMR_BENCH_QUICK").is_ok();

    let kernels = buf::available_kernels();
    let active = buf::active_kernel();
    println!(
        "== XOR kernel stack: {} available, dispatch -> {} ==\n",
        kernels.iter().map(|k| k.label()).collect::<Vec<_>>().join(" "),
        active.label()
    );

    let all_sizes: &[(usize, &str)] = &[
        (4 << 10, "4KiB"),
        (64 << 10, "64KiB"),
        (1 << 20, "1MiB"),
        (16 << 20, "16MiB"),
        (256 << 20, "256MiB"),
    ];
    let sizes: &[(usize, &str)] = if quick { &all_sizes[..4] } else { all_sizes };
    if quick {
        println!("(--quick: sizes capped at 16MiB; run without --quick for 256MiB rows)\n");
    }

    // kernel × size XOR rows. Buffers come from the pool (so ≥1MiB rows
    // exercise the large size class) but checkout stays outside the
    // timed closure — these rows are pure XOR throughput.
    let pool = BufferPool::new();
    let mut xor_rows = Vec::new();
    for &(n, label) in sizes {
        let src: Vec<u8> = (0..n).map(|i| (i.wrapping_mul(31) + 7) as u8).collect();
        let mut dst = pool.acquire_unzeroed(n);
        let mut byte_ns = f64::NAN;
        let mut per_kernel = Vec::new();
        for &kernel in &kernels {
            let d = dst.as_mut_slice();
            let mean_ns = b.run(&format!("xor_{}_{label}", kernel.label()), || {
                buf::xor_into_with(kernel, d, &src).unwrap();
                d[0]
            });
            if kernel == XorKernel::Bytewise {
                byte_ns = mean_ns;
            }
            per_kernel.push((kernel, mean_ns));
        }
        println!();
        for (kernel, mean_ns) in per_kernel {
            let speedup = if mean_ns > 0.0 { byte_ns / mean_ns } else { 0.0 };
            println!(
                "  {label} {:>12}: {:7.2} GB/s ({speedup:.1}x per-byte){}",
                kernel.label(),
                gbps(n, mean_ns),
                if kernel == active { "  <- dispatched" } else { "" }
            );
            xor_rows.push(Json::obj(vec![
                ("kernel", Json::Str(kernel.label().to_string())),
                ("label", Json::Str(label.to_string())),
                ("bytes", Json::UInt(n as u128)),
                ("mean_ns", Json::Num(mean_ns)),
                ("gbps", Json::Num(gbps(n, mean_ns))),
                ("speedup_vs_bytewise", Json::Num(speedup)),
                ("dispatched", Json::Bool(kernel == active)),
            ]));
        }
        println!();
    }

    // Pool checkout vs fresh allocation, small class and large class.
    println!("== Buffer checkout: pool vs fresh allocation ==\n");
    let mut pool_rows = Vec::new();
    let large = sizes.last().unwrap().0;
    for &(n, label) in &[(1usize << 20, "1MiB"), (large, sizes.last().unwrap().1)] {
        let pool = BufferPool::new();
        drop(pool.acquire_unzeroed(n)); // warm the free list
        // The engines' hot paths use acquire_unzeroed (encode fill(0)s
        // and decode copy_from_slices before reading), so that is the
        // production number; the zeroing acquire is reported alongside.
        let pool_ns = b.run(&format!("pool_acquire_unzeroed_{label}"), || {
            let mut buf = pool.acquire_unzeroed(n);
            buf.as_mut_slice()[0] = 1;
            buf.len()
        });
        let pool_zeroed_ns = b.run(&format!("pool_acquire_zeroed_{label}"), || {
            let buf = pool.acquire(n);
            buf.len()
        });
        let alloc_ns = b.run(&format!("fresh_vec_alloc_{label}"), || {
            let mut v = vec![0u8; n];
            v[0] = 1;
            v.len()
        });
        println!();
        pool_rows.push(Json::obj(vec![
            ("label", Json::Str(label.to_string())),
            ("bytes", Json::UInt(n as u128)),
            ("acquire_unzeroed_mean_ns", Json::Num(pool_ns)),
            ("acquire_zeroed_mean_ns", Json::Num(pool_zeroed_ns)),
            ("fresh_alloc_mean_ns", Json::Num(alloc_ns)),
        ]));
    }

    // Streamed huge-payload digest: GB/s through one pooled chunk.
    println!("== Streamed map digest (subfile folded chunk-at-a-time) ==\n");
    let sub_bytes: u64 = if quick { 4 << 20 } else { 64 << 20 };
    let chunk_bytes: usize = 1 << 20;
    let cfg = SystemConfig::with_options(3, 2, 1, 1, 64).unwrap();
    let src = Arc::new(SyntheticSource::new(7, sub_bytes * cfg.subfiles() as u64));
    let wl = StreamedWorkload::new(&cfg, src, sub_bytes, chunk_bytes, 7).unwrap();
    let stream_ns = b.run("streamed_map_subfile", || {
        wl.map_subfile(0, 0).unwrap().len()
    });
    println!(
        "  {} MiB subfile, {} MiB chunks: {:.2} GB/s\n",
        sub_bytes >> 20,
        chunk_bytes >> 20,
        gbps(sub_bytes as usize, stream_ns)
    );
    let stream_row = Json::obj(vec![
        ("subfile_bytes", Json::UInt(sub_bytes as u128)),
        ("chunk_bytes", Json::UInt(chunk_bytes as u128)),
        ("mean_ns", Json::Num(stream_ns)),
        ("gbps", Json::Num(gbps(sub_bytes as usize, stream_ns))),
    ]);

    println!("== End-to-end shuffle: pooled vs unpooled data plane ==\n");
    let mut e2e_rows = Vec::new();
    for (k, q, bytes) in [(3usize, 4usize, 4096usize), (4, 3, 4096)] {
        let cfg = SystemConfig::with_options(k, q, 2, 1, bytes).unwrap();
        let mut means = [0f64; 2];
        for (i, pooling) in [true, false].into_iter().enumerate() {
            let cfg2 = cfg.clone();
            let tag = if pooling { "pooled" } else { "unpooled" };
            means[i] = b.run(&format!("shuffle_{tag}_k{k}_q{q}_B{bytes}"), move || {
                let wl = SyntheticWorkload::new(&cfg2, 7);
                let mut e = Engine::new(cfg2.clone(), Box::new(wl)).unwrap();
                e.verify = false;
                e.pooling = pooling;
                e.run().unwrap().stage_bytes
            });
        }
        let speedup = if means[0] > 0.0 { means[1] / means[0] } else { 0.0 };
        println!("  k={k} q={q} B={bytes}: pooled/unpooled e2e speedup {speedup:.2}x\n");
        e2e_rows.push(Json::obj(vec![
            ("k", Json::UInt(k as u128)),
            ("q", Json::UInt(q as u128)),
            ("value_bytes", Json::UInt(bytes as u128)),
            ("pooled_mean_ns", Json::Num(means[0])),
            ("unpooled_mean_ns", Json::Num(means[1])),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("xor_throughput".to_string())),
        ("quick", Json::Bool(quick)),
        ("dispatched_kernel", Json::Str(active.label().to_string())),
        (
            "available_kernels",
            Json::Arr(kernels.iter().map(|k| Json::Str(k.label().to_string())).collect()),
        ),
        ("xor", Json::Arr(xor_rows)),
        ("pool", Json::Arr(pool_rows)),
        ("stream", stream_row),
        ("e2e", Json::Arr(e2e_rows)),
    ]);
    let path = "BENCH_shuffle.json";
    match std::fs::write(path, report.render() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
