//! XOR and buffer-pool throughput: the word-wise hot path vs the naive
//! per-byte reference, pool acquire/release vs fresh allocation, and a
//! pooled-vs-unpooled end-to-end shuffle comparison.
//!
//! Besides the human-readable BENCH lines, this bench writes
//! `BENCH_shuffle.json` (machine-readable) so later PRs can diff the
//! shuffle data plane's throughput trajectory and catch regressions.

use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::shuffle::buf::{self, BufferPool};
use camr::util::bench::Bench;
use camr::util::json::Json;
use camr::workload::synth::SyntheticWorkload;

/// Bytes per nanosecond == GB/s.
fn gbps(bytes: usize, mean_ns: f64) -> f64 {
    if mean_ns > 0.0 {
        bytes as f64 / mean_ns
    } else {
        0.0
    }
}

fn main() {
    let b = Bench::new();
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("CAMR_BENCH_QUICK").is_ok();

    println!("== Word-wise vs per-byte XOR (xor_into vs xor_into_bytewise) ==\n");
    let sizes: &[(usize, &str)] =
        &[(4 << 10, "4KiB"), (64 << 10, "64KiB"), (1 << 20, "1MiB"), (4 << 20, "4MiB")];
    let mut xor_rows = Vec::new();
    for &(n, label) in sizes {
        let src: Vec<u8> = (0..n).map(|i| (i.wrapping_mul(31) + 7) as u8).collect();
        let mut dst = vec![0u8; n];
        let word_ns = b.run(&format!("xor_wordwise_{label}"), || {
            buf::xor_into(&mut dst, &src).unwrap();
            dst[0]
        });
        let byte_ns = b.run(&format!("xor_bytewise_{label}"), || {
            buf::xor_into_bytewise(&mut dst, &src).unwrap();
            dst[0]
        });
        let speedup = if word_ns > 0.0 { byte_ns / word_ns } else { 0.0 };
        println!(
            "  {label}: word-wise {:.2} GB/s, per-byte {:.2} GB/s -> {speedup:.1}x\n",
            gbps(n, word_ns),
            gbps(n, byte_ns)
        );
        xor_rows.push(Json::obj(vec![
            ("label", Json::Str(label.to_string())),
            ("bytes", Json::UInt(n as u128)),
            ("wordwise_mean_ns", Json::Num(word_ns)),
            ("bytewise_mean_ns", Json::Num(byte_ns)),
            ("wordwise_gbps", Json::Num(gbps(n, word_ns))),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    println!("== Buffer pool vs fresh allocation (1 MiB buffers) ==\n");
    let pool = BufferPool::new();
    drop(pool.acquire(1 << 20)); // warm the free list
    // The engines' hot paths use acquire_unzeroed (encode fill(0)s and
    // decode copy_from_slices before reading), so that is the
    // production number; the zeroing acquire is reported alongside.
    let pool_ns = b.run("pool_acquire_unzeroed_1MiB", || {
        let mut buf = pool.acquire_unzeroed(1 << 20);
        // Touch the buffer like the encoder does (first word write).
        buf.as_mut_slice()[0] = 1;
        buf.len()
    });
    let pool_zeroed_ns = b.run("pool_acquire_zeroed_1MiB", || {
        let buf = pool.acquire(1 << 20);
        buf.len()
    });
    let alloc_ns = b.run("fresh_vec_alloc_1MiB", || {
        let mut v = vec![0u8; 1 << 20];
        v[0] = 1;
        v.len()
    });
    println!();

    println!("== End-to-end shuffle: pooled vs unpooled data plane ==\n");
    let mut e2e_rows = Vec::new();
    for (k, q, bytes) in [(3usize, 4usize, 4096usize), (4, 3, 4096)] {
        let cfg = SystemConfig::with_options(k, q, 2, 1, bytes).unwrap();
        let mut means = [0f64; 2];
        for (i, pooling) in [true, false].into_iter().enumerate() {
            let cfg2 = cfg.clone();
            let tag = if pooling { "pooled" } else { "unpooled" };
            means[i] = b.run(&format!("shuffle_{tag}_k{k}_q{q}_B{bytes}"), move || {
                let wl = SyntheticWorkload::new(&cfg2, 7);
                let mut e = Engine::new(cfg2.clone(), Box::new(wl)).unwrap();
                e.verify = false;
                e.pooling = pooling;
                e.run().unwrap().stage_bytes
            });
        }
        let speedup = if means[0] > 0.0 { means[1] / means[0] } else { 0.0 };
        println!("  k={k} q={q} B={bytes}: pooled/unpooled e2e speedup {speedup:.2}x\n");
        e2e_rows.push(Json::obj(vec![
            ("k", Json::UInt(k as u128)),
            ("q", Json::UInt(q as u128)),
            ("value_bytes", Json::UInt(bytes as u128)),
            ("pooled_mean_ns", Json::Num(means[0])),
            ("unpooled_mean_ns", Json::Num(means[1])),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("xor_throughput".to_string())),
        ("quick", Json::Bool(quick)),
        ("xor", Json::Arr(xor_rows)),
        (
            "pool",
            Json::obj(vec![
                ("acquire_unzeroed_1MiB_mean_ns", Json::Num(pool_ns)),
                ("acquire_zeroed_1MiB_mean_ns", Json::Num(pool_zeroed_ns)),
                ("fresh_alloc_1MiB_mean_ns", Json::Num(alloc_ns)),
            ]),
        ),
        ("e2e", Json::Arr(e2e_rows)),
    ]);
    let path = "BENCH_shuffle.json";
    match std::fs::write(path, report.render() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
