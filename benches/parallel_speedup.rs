//! Serial vs thread-per-worker engine: map-phase wall-clock speedup.
//!
//! The acceptance bar for the parallel engine: at `K ≥ 8` workers with a
//! compute-heavy map kernel, the map phase must run > 1.5× faster than
//! the serial reference while charging byte-identical stage ledgers.
//! The map work here is a deterministic spin kernel layered over the
//! synthetic workload — heavy enough that thread fan-out dominates
//! channel/barrier overhead, like a real map kernel would be.

use camr::agg::{Aggregator, Value};
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::coordinator::parallel::ParallelEngine;
use camr::error::Result;
use camr::util::bench::fmt_ns;
use camr::workload::synth::SyntheticWorkload;
use camr::workload::Workload;
use std::time::Duration;

/// Synthetic values plus a deterministic CPU burn per map invocation.
struct HeavyWorkload {
    inner: SyntheticWorkload,
    spins: u64,
}

impl Workload for HeavyWorkload {
    fn name(&self) -> &str {
        "heavy-synthetic"
    }

    fn aggregator(&self) -> &dyn Aggregator {
        self.inner.aggregator()
    }

    fn map_subfile(&self, job: usize, subfile: usize) -> Result<Vec<Value>> {
        // Emulate a real map kernel: ~spins dependent multiplies.
        let mut acc = ((job as u64) << 32) ^ subfile as u64 ^ 0x9E3779B97F4A7C15;
        for i in 0..self.spins {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(acc);
        self.inner.map_subfile(job, subfile)
    }
}

/// Best-of-N map/shuffle times for one engine kind.
fn measure<F: FnMut() -> (Duration, Duration, [usize; 3])>(
    iters: usize,
    mut f: F,
) -> (Duration, Duration, [usize; 3]) {
    let mut best_map = Duration::MAX;
    let mut best_shuffle = Duration::MAX;
    let mut bytes = [0usize; 3];
    for _ in 0..iters {
        let (m, s, b) = f();
        best_map = best_map.min(m);
        best_shuffle = best_shuffle.min(s);
        bytes = b;
    }
    (best_map, best_shuffle, bytes)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("CAMR_BENCH_QUICK").is_ok();
    let iters = if quick { 3 } else { 7 };
    let spins: u64 = if quick { 8_000 } else { 25_000 };
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("== Map-phase speedup: serial engine vs thread-per-worker ==");
    println!("   ({cores} hardware threads available, spin kernel {spins} iters/map)\n");
    println!(
        "{:>3} {:>3} {:>4} {:>6} {:>12} {:>12} {:>9} {:>12} {:>12}",
        "k", "q", "K", "maps", "map_serial", "map_par", "speedup", "shuf_serial", "shuf_par"
    );

    let mut k8_speedup: Option<f64> = None;
    for (k, q, gamma) in [
        (4usize, 2usize, 8usize), // K = 8, 768 map invocations
        (2, 4, 32),               // K = 8, k = 2 corner
        (3, 3, 8),                // K = 9
        (4, 3, 4),                // K = 12
    ] {
        let cfg = SystemConfig::with_options(k, q, gamma, 1, 256).unwrap();
        let (smap, sshuf, sbytes) = measure(iters, || {
            let wl = HeavyWorkload { inner: SyntheticWorkload::new(&cfg, 7), spins };
            let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
            e.verify = false;
            let out = e.run().unwrap();
            (out.map_time, out.shuffle_time, out.stage_bytes)
        });
        let (pmap, pshuf, pbytes) = measure(iters, || {
            let wl = HeavyWorkload { inner: SyntheticWorkload::new(&cfg, 7), spins };
            let mut e = ParallelEngine::new(cfg.clone(), Box::new(wl)).unwrap();
            e.verify = false;
            let out = e.run().unwrap();
            (out.map_time, out.shuffle_time, out.stage_bytes)
        });
        assert_eq!(sbytes, pbytes, "k={k} q={q}: ledgers diverged");
        let speedup = smap.as_secs_f64() / pmap.as_secs_f64().max(1e-12);
        let maps = (k - 1) * cfg.jobs() * cfg.subfiles();
        println!(
            "{:>3} {:>3} {:>4} {:>6} {:>12} {:>12} {:>8.2}x {:>12} {:>12}",
            k,
            q,
            cfg.servers(),
            maps,
            fmt_ns(smap.as_nanos() as f64),
            fmt_ns(pmap.as_nanos() as f64),
            speedup,
            fmt_ns(sshuf.as_nanos() as f64),
            fmt_ns(pshuf.as_nanos() as f64),
        );
        println!(
            "BENCH par_speedup_k{k}_q{q} serial_map_ns={} par_map_ns={} speedup={speedup:.3}",
            smap.as_nanos(),
            pmap.as_nanos()
        );
        if cfg.servers() >= 8 && k8_speedup.is_none() {
            k8_speedup = Some(speedup);
        }
    }

    if let Some(s) = k8_speedup {
        println!(
            "\nmap-phase speedup at K >= 8: {s:.2}x (target > 1.5x; needs >= 2 hardware threads)"
        );
        if cores >= 2 && s <= 1.5 {
            println!("WARNING: speedup below 1.5x despite {cores} hardware threads");
        }
    }
}
