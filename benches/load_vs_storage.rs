//! E6/E7 — §IV/§V load analysis: measured CAMR load vs the closed form
//! and vs CCDC (Eq. (6)) at matched storage fraction, across (k, q).
//!
//! Every row runs the real byte-exact engines (both schemes fully
//! decode + verify) and asserts:
//!   - measured L_CAMR == (k(q-1)+1)/(q(k-1)) exactly (B chosen so
//!     (k-1) | B — no padding slack);
//!   - L_CAMR == L_CCDC under Eq.-(6) accounting;
//!   - J_CCDC == C(K,k) >> J_CAMR = q^{k-1}.
//! Timed sections report end-to-end wall per scheme.

use camr::analysis::load;
use camr::baseline::CcdcEngine;
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::util::bench::Bench;
use camr::workload::synth::SyntheticWorkload;

fn main() {
    let b = Bench::with_iters(5, 1);
    println!("== §IV/§V: measured loads, CAMR vs CCDC at equal μ ==\n");
    println!(
        "{:>3} {:>3} {:>4} {:>7} {:>9} {:>9} {:>9} {:>8} {:>8}",
        "k", "q", "K", "J_camr", "L_meas", "L_form", "L_ccdc", "J_ccdc", "ok"
    );
    for (k, q) in [(2usize, 2usize), (2, 3), (3, 2), (3, 3), (4, 2), (5, 2)] {
        // B = 120 is divisible by k-1 for k ∈ {2,3,4,5} (1,2,3,4 | 120).
        let cfg = SystemConfig::with_options(k, q, 2, 1, 120).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 11);
        let mut engine = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        let out = engine.run().unwrap();
        let formula = load::camr_total(k, q);
        assert!(out.verified);
        assert!(
            (out.total_load() - formula).abs() < 1e-12,
            "k={k} q={q}: {} != {formula}",
            out.total_load()
        );

        let mut ccdc = CcdcEngine::new(cfg.servers(), k, 2, 120, 11).unwrap();
        let cout = ccdc.run().unwrap();
        assert!(cout.verified);
        assert!(
            (cout.paper_load() - formula).abs() < 1e-12,
            "CCDC Eq.(6) load must equal CAMR's at matched μ"
        );
        println!(
            "{:>3} {:>3} {:>4} {:>7} {:>9.4} {:>9.4} {:>9.4} {:>8} {:>8}",
            k,
            q,
            cfg.servers(),
            cfg.jobs(),
            out.total_load(),
            formula,
            cout.paper_load(),
            cout.jobs,
            "yes"
        );
    }

    println!("\n== End-to-end wall time per scheme (K = 6, Example-1 scale) ==\n");
    let cfg = SystemConfig::with_options(3, 2, 2, 1, 120).unwrap();
    b.run("camr_e2e_k3_q2 (4 jobs)", || {
        let wl = SyntheticWorkload::new(&cfg, 3);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.verify = false;
        e.run().unwrap().stage_bytes
    });
    b.run("ccdc_e2e_K6_k3 (20 jobs)", || {
        let mut e = CcdcEngine::new(6, 3, 2, 120, 3).unwrap();
        e.run().unwrap().measured_bytes
    });

    println!("\n== Larger design: K = 12 (k=3, q=4) ==\n");
    let cfg = SystemConfig::with_options(3, 4, 2, 1, 120).unwrap();
    b.run("camr_e2e_k3_q4 (16 jobs)", || {
        let wl = SyntheticWorkload::new(&cfg, 5);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.verify = false;
        e.run().unwrap().stage_bytes
    });
    b.run("ccdc_e2e_K12_k3 (220 jobs)", || {
        let mut e = CcdcEngine::new(12, 3, 2, 120, 5).unwrap();
        e.run().unwrap().measured_bytes
    });
}
