//! E5 — §III-C per-stage loads: measured stage 1/2/3 bytes vs the closed
//! forms k/(K(k-1)), (q-1)k/(K(k-1)), (q-1)/q, and per-stage wall time.
//!
//! The Example-1 row must measure exactly 1/4, 1/4, 1/2.

use camr::analysis::load;
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::coordinator::master::Master;
use camr::coordinator::parallel::ParallelEngine;
use camr::util::bench::Bench;
use camr::workload::synth::SyntheticWorkload;

fn main() {
    println!("== §III-C / §IV: per-stage loads (measured vs closed form) ==\n");
    println!(
        "{:>3} {:>3} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "k", "q", "L1_meas", "L1_form", "L2_meas", "L2_form", "L3_meas", "L3_form"
    );
    for (k, q) in [(3usize, 2usize), (3, 3), (3, 4), (4, 2), (4, 3), (5, 2), (2, 5)] {
        let cfg = SystemConfig::with_options(k, q, 2, 1, 120).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 1);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.verify = false;
        let out = e.run().unwrap();
        let form = load::camr_stages(k, q);
        for (i, expect) in [form.stage1, form.stage2, form.stage3].iter().enumerate() {
            assert!(
                (out.stage_load(i + 1) - expect).abs() < 1e-12,
                "k={k} q={q} stage{}: {} != {expect}",
                i + 1,
                out.stage_load(i + 1)
            );
        }
        println!(
            "{:>3} {:>3} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>9.4}",
            k,
            q,
            out.stage_load(1),
            form.stage1,
            out.stage_load(2),
            form.stage2,
            out.stage_load(3),
            form.stage3
        );
    }
    // Example 1 exact check.
    {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 2);
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!((out.stage_load(1) - 0.25).abs() < 1e-12);
        assert!((out.stage_load(2) - 0.25).abs() < 1e-12);
        assert!((out.stage_load(3) - 0.50).abs() < 1e-12);
        println!("\nExample 1 exact: 1/4 + 1/4 + 1/2 = 1  ✓");
    }

    println!("\n== Per-stage wall time (k=3, q=4, γ=4, B=4096) ==\n");
    let b = Bench::new();
    let cfg = SystemConfig::with_options(3, 4, 4, 1, 4096).unwrap();
    let master = Master::new(cfg.clone()).unwrap();
    let schedule = master.schedule().unwrap();
    println!(
        "schedule: {} stage-1 groups, {} stage-2 groups, {} stage-3 unicasts",
        schedule.stage1.len(),
        schedule.stage2.len(),
        schedule.stage3.len()
    );
    b.run("schedule_build_k3_q4", || master.schedule().unwrap().stage2.len());
    b.run("full_run_k3_q4_B4096", || {
        let wl = SyntheticWorkload::new(&cfg, 9);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.verify = false;
        let out = e.run().unwrap();
        (out.map_time, out.shuffle_time)
    });
    // Report the phase split of one instrumented run per engine.
    let wl = SyntheticWorkload::new(&cfg, 9);
    let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
    e.verify = false;
    let out = e.run().unwrap();
    println!(
        "\nphase split (serial):   map {:?}  shuffle {:?}  reduce {:?}  (stage bytes {:?})",
        out.map_time, out.shuffle_time, out.reduce_time, out.stage_bytes
    );
    let wl = SyntheticWorkload::new(&cfg, 9);
    let mut p = ParallelEngine::new(cfg.clone(), Box::new(wl)).unwrap();
    p.verify = false;
    let pout = p.run().unwrap();
    assert_eq!(pout.stage_bytes, out.stage_bytes, "engines must charge identical bytes");
    println!(
        "phase split (parallel): map {:?}  shuffle {:?}  reduce {:?}  (stage bytes {:?})",
        pout.map_time, pout.shuffle_time, pout.reduce_time, pout.stage_bytes
    );
}
