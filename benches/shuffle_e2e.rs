//! E10 support — end-to-end shuffle throughput: wall time and effective
//! link throughput of the full map/shuffle/reduce pipeline as value size
//! and cluster size grow, plus the message-passing cluster deployment.
//!
//! This is the macro-bench the §Perf iteration log in EXPERIMENTS.md
//! tracks (before/after numbers come from these BENCH lines).

use camr::config::SystemConfig;
use camr::coordinator::cluster::run_cluster;
use camr::coordinator::engine::Engine;
use camr::coordinator::parallel::ParallelEngine;
use camr::util::bench::{fmt_ns, Bench};
use camr::workload::synth::SyntheticWorkload;
use std::sync::Arc;

fn main() {
    let b = Bench::new();
    println!("== End-to-end pipeline wall time (sync engine, verify off) ==\n");
    for (k, q, gamma, bytes) in [
        (3usize, 2usize, 2usize, 64usize), // Example-1 scale
        (3, 2, 2, 4096),                   // fat values
        (3, 4, 2, 1024),                   // K = 12
        (4, 3, 2, 1024),                   // K = 12, deeper design
        (3, 6, 2, 1024),                   // K = 18, 36 jobs
        (2, 12, 2, 1024),                  // K = 24, k = 2 corner
    ] {
        let cfg = SystemConfig::with_options(k, q, gamma, 1, bytes).unwrap();
        let name = format!(
            "e2e_k{k}_q{q}_B{bytes} (K={}, J={})",
            cfg.servers(),
            cfg.jobs()
        );
        let cfg2 = cfg.clone();
        b.run(&name, move || {
            let wl = SyntheticWorkload::new(&cfg2, 7);
            let mut e = Engine::new(cfg2.clone(), Box::new(wl)).unwrap();
            e.verify = false;
            e.run().unwrap().stage_bytes
        });
    }

    println!("\n== Shuffle-only throughput (bytes on link / shuffle wall) ==\n");
    for (k, q, bytes) in [(3usize, 4usize, 4096usize), (4, 3, 4096), (3, 6, 2048)] {
        let cfg = SystemConfig::with_options(k, q, 2, 1, bytes).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 7);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.verify = false;
        let out = e.run().unwrap();
        let total: usize = out.stage_bytes.iter().sum();
        let gbps = total as f64 / out.shuffle_time.as_secs_f64() / 1e9;
        println!(
            "  k={k} q={q} B={bytes}: {total} link bytes in {} → {gbps:.2} GB/s effective",
            fmt_ns(out.shuffle_time.as_nanos() as f64)
        );
    }

    println!("\n== Pooled vs unpooled shuffle data plane (serial engine) ==\n");
    // The pooled plane recycles Δ/scratch buffers through shuffle::buf;
    // the unpooled rows run the legacy allocate-per-packet path. The
    // ledgers are byte-identical (rust/tests/golden_ledger.rs); only
    // allocator traffic and wall time differ. xor_throughput.rs records
    // the same comparison into BENCH_shuffle.json.
    for (k, q, bytes) in [(3usize, 4usize, 4096usize), (4, 3, 4096), (3, 2, 65536)] {
        for pooling in [true, false] {
            let cfg = SystemConfig::with_options(k, q, 2, 1, bytes).unwrap();
            let tag = if pooling { "pooled" } else { "unpooled" };
            let name = format!("e2e_{tag}_k{k}_q{q}_B{bytes} (K={})", cfg.servers());
            b.run(&name, move || {
                let wl = SyntheticWorkload::new(&cfg, 7);
                let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
                e.verify = false;
                e.pooling = pooling;
                e.run().unwrap().stage_bytes
            });
        }
    }

    println!("\n== Thread-per-worker engine (same pipeline, barrier-synchronized) ==\n");
    for (k, q) in [(3usize, 2usize), (3, 4), (4, 3)] {
        let cfg = SystemConfig::with_options(k, q, 2, 1, 1024).unwrap();
        let name = format!("parallel_k{k}_q{q} (K={})", cfg.servers());
        let cfg2 = cfg.clone();
        // Byte-for-byte ledger equality with the serial engine is
        // asserted by rust/tests/parallel_engine.rs; here we only time.
        b.run(&name, move || {
            let wl = SyntheticWorkload::new(&cfg2, 7);
            let mut e = ParallelEngine::new(cfg2.clone(), Box::new(wl)).unwrap();
            e.verify = false;
            e.run().unwrap().stage_bytes
        });
    }

    println!("\n== Message-passing cluster deployment (one thread per server) ==\n");
    for (k, q) in [(3usize, 2usize), (3, 4)] {
        let cfg = SystemConfig::with_options(k, q, 2, 1, 1024).unwrap();
        let name = format!("cluster_k{k}_q{q} (K={})", cfg.servers());
        let cfg2 = cfg.clone();
        b.run(&name, move || {
            let wl = Arc::new(SyntheticWorkload::new(&cfg2, 7));
            run_cluster(cfg2.clone(), wl).unwrap().stage_bytes
        });
    }
}
