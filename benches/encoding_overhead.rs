//! E9 — encoding-overhead ablation (the paper's §I motivation via [7]:
//! "increasing the number of tasks scales the overhead of the encoding
//! complexity and can diminish any gains in the communication load").
//!
//! At equal cluster size and storage fraction, CAMR runs `q^{k-1}` jobs
//! while CCDC must run `C(K,k)`. This bench measures, on the same
//! hardware and the same Lemma-2 XOR machinery, the total encode work
//! (operations and wall time) each scheme pays — the quantity that blows
//! up with the job count.

use camr::baseline::CcdcEngine;
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::coordinator::master::Master;
use camr::util::bench::Bench;
use camr::workload::synth::SyntheticWorkload;

fn main() {
    let b = Bench::new();
    println!("== Encode work at equal (K, μ): CAMR q^(k-1) jobs vs CCDC C(K,k) jobs ==\n");
    println!(
        "{:>4} {:>4} {:>8} {:>8} {:>12} {:>12}",
        "K", "k", "J_camr", "J_ccdc", "enc_camr", "enc_ccdc"
    );
    for (k, q) in [(3usize, 2usize), (3, 3), (3, 4), (4, 2), (2, 6)] {
        let cfg = SystemConfig::with_options(k, q, 1, 1, 120).unwrap();
        let servers = cfg.servers();
        // CAMR encode ops: every member of every stage-1/2 group encodes
        // once per run.
        let master = Master::new(cfg.clone()).unwrap();
        let schedule = master.schedule().unwrap();
        let camr_ops = (schedule.stage1.len() + schedule.stage2.len()) * k;
        let mut ccdc = CcdcEngine::new(servers, k, 1, 120, 3).unwrap();
        let ccdc_out = ccdc.run().unwrap();
        println!(
            "{:>4} {:>4} {:>8} {:>8} {:>12} {:>12}",
            servers,
            k,
            cfg.jobs(),
            ccdc_out.jobs,
            camr_ops,
            ccdc_out.encode_ops
        );
        assert!(ccdc_out.encode_ops >= camr_ops, "CCDC must encode at least as much");
    }

    println!("\n== Wall time: full run including encode, same (K, μ, B) ==\n");
    for (k, q) in [(3usize, 2usize), (3, 4), (4, 2)] {
        let cfg = SystemConfig::with_options(k, q, 1, 1, 1024).unwrap();
        let servers = cfg.servers();
        let cfg2 = cfg.clone();
        b.run(&format!("camr_K{servers}_k{k} ({} jobs)", cfg.jobs()), move || {
            let wl = SyntheticWorkload::new(&cfg2, 3);
            let mut e = Engine::new(cfg2.clone(), Box::new(wl)).unwrap();
            e.verify = false;
            e.run().unwrap().map_invocations
        });
        b.run(
            &format!(
                "ccdc_K{servers}_k{k} ({} jobs)",
                camr::analysis::jobs::binomial(servers as u64, k as u64)
            ),
            move || {
                let mut e = CcdcEngine::new(servers, k, 1, 1024, 3).unwrap();
                e.run().unwrap().encode_ops
            },
        );
    }
    println!(
        "\nCAMR's smaller job count keeps encode overhead bounded as the \
         cluster scales (Table III / [7])."
    );
}
