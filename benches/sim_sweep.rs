//! Simulated completion-time sweep: bandwidth × straggler distribution
//! × scheme (CAMR vs CCDC vs uncoded), plus wall-time throughput of the
//! simulator itself.
//!
//! The schemes' ledgers come from real engine runs (byte-exact); each
//! cell replays them through the discrete-event simulator at one
//! (bandwidth, straggler) point. Besides the human-readable BENCH
//! lines, this writes machine-readable `BENCH_sim.json` so later PRs
//! can diff the completion-time trajectory (created on
//! `cargo bench --bench sim_sweep`; not checked in).

use camr::baseline::{CcdcEngine, UncodedEngine, UncodedMode};
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::net::{Stage, Transmission};
use camr::sim::{self, LinkKind, SimConfig, StragglerModel};
use camr::util::bench::Bench;
use camr::util::json::Json;
use camr::workload::synth::SyntheticWorkload;

fn main() {
    let b = Bench::new();
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("CAMR_BENCH_QUICK").is_ok();

    // ---- Byte-exact ledgers from real runs (paper Example 1 shape).
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let (camr_ledger, camr_maps) = {
        let wl = SyntheticWorkload::new(&cfg, 7);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.verify = false;
        e.run().unwrap();
        (e.bus.ledger().to_vec(), sim::camr_per_worker_maps(&cfg, &e.master.placement))
    };
    let unc_ledger = {
        let wl = SyntheticWorkload::new(&cfg, 7);
        let mut e = UncodedEngine::new(cfg.clone(), Box::new(wl), UncodedMode::Aggregated)
            .unwrap();
        e.run().unwrap();
        e.bus.ledger().to_vec()
    };
    let (ccdc_ledger, ccdc_maps, ccdc_jobs) = {
        let mut e = CcdcEngine::new(cfg.servers(), cfg.k, cfg.gamma, cfg.value_bytes, 7)
            .unwrap();
        let out = e.run().unwrap();
        let maps = sim::ccdc_per_worker_maps(cfg.servers(), cfg.k, cfg.gamma);
        (e.bus.ledger().to_vec(), maps, out.jobs)
    };
    let schemes: [(&str, &[Transmission], &[usize], usize); 3] = [
        ("camr", &camr_ledger, &camr_maps, cfg.jobs()),
        ("ccdc", &ccdc_ledger, &ccdc_maps, ccdc_jobs),
        ("uncoded", &unc_ledger, &camr_maps, cfg.jobs()),
    ];

    // ---- Sweep: bandwidth × straggler × scheme.
    let bandwidths: &[f64] = if quick {
        &[1.25e8, 1.25e6]
    } else {
        &[1.25e9, 1.25e8, 1.25e7, 1.25e6]
    };
    let stragglers: &[(&str, StragglerModel)] = &[
        ("none", StragglerModel::Deterministic),
        ("shifted_exp_r10", StragglerModel::ShiftedExp { rate: 10.0 }),
        ("shifted_exp_r2", StragglerModel::ShiftedExp { rate: 2.0 }),
        ("tail_p05_x10", StragglerModel::Tail { prob: 0.05, factor: 10.0 }),
    ];
    println!("== Simulated completion times: bandwidth x straggler x scheme ==\n");
    let mut rows = Vec::new();
    for &bw in bandwidths {
        for (sname, smodel) in stragglers {
            let mut cell = Vec::new();
            for (label, ledger, maps, jobs) in &schemes {
                let sc = SimConfig {
                    link: LinkKind::Shared,
                    link_bytes_per_sec: bw,
                    latency_secs: 0.0,
                    secs_per_map: 1e-3,
                    speeds: Vec::new(),
                    straggler: *smodel,
                    seed: 42,
                };
                let out = sim::simulate(&sc, maps, ledger).unwrap();
                cell.push((*label, out.total_secs / *jobs as f64, out.total_secs));
                rows.push(Json::obj(vec![
                    ("bandwidth", Json::Num(bw)),
                    ("straggler", Json::Str(sname.to_string())),
                    ("scheme", Json::Str(label.to_string())),
                    ("jobs", Json::UInt(*jobs as u128)),
                    ("map_secs", Json::Num(out.map_secs)),
                    ("shuffle_secs", Json::Num(out.shuffle_secs)),
                    ("total_secs", Json::Num(out.total_secs)),
                    ("secs_per_job", Json::Num(out.total_secs / *jobs as f64)),
                ]));
            }
            let per_job = |l: &str| cell.iter().find(|c| c.0 == l).unwrap().1;
            println!(
                "  bw={bw:>9.3e} straggler={sname:<16} t/job: camr {:.6} ccdc {:.6} \
                 uncoded {:.6}  (camr speedup over uncoded {:.2}x)",
                per_job("camr"),
                per_job("ccdc"),
                per_job("uncoded"),
                per_job("uncoded") / per_job("camr")
            );
            // Same map work, fewer shuffle bytes: CAMR can never lose
            // to the uncoded baseline in this sweep.
            assert!(per_job("camr") <= per_job("uncoded") + 1e-15);
        }
    }
    println!();

    // ---- Wall-time of the simulator itself.
    println!("== Simulator throughput ==\n");
    let sc = SimConfig {
        straggler: StragglerModel::ShiftedExp { rate: 5.0 },
        ..SimConfig::commodity()
    };
    let replay_ns = b.run("sim_replay_example1_camr", || {
        sim::simulate(&sc, &camr_maps, &camr_ledger).unwrap().events
    });
    // A big synthetic ledger: 50k transmissions over 12 senders in 3
    // stage phases, plus 12×2000 map tasks.
    let big_n = if quick { 5_000 } else { 50_000 };
    let big_ledger: Vec<Transmission> = (0..big_n)
        .map(|i| Transmission {
            stage: match i * 3 / big_n {
                0 => Stage::Stage1,
                1 => Stage::Stage2,
                _ => Stage::Stage3,
            },
            sender: i % 12,
            recipients: vec![(i + 1) % 12],
            bytes: 4096,
            job: 0,
        })
        .collect();
    let big_maps = vec![2000usize; 12];
    let mut big_events = 0u64;
    let big_ns = b.run("sim_replay_big_ledger", || {
        let out = sim::simulate(&sc, &big_maps, &big_ledger).unwrap();
        big_events = out.events;
        big_events
    });
    let events_per_sec = if big_ns > 0.0 { big_events as f64 / (big_ns * 1e-9) } else { 0.0 };
    println!("\n  {big_events} events at {events_per_sec:.0} events/s\n");

    let report = Json::obj(vec![
        ("bench", Json::Str("sim_sweep".to_string())),
        ("quick", Json::Bool(quick)),
        ("sweep", Json::Arr(rows)),
        (
            "throughput",
            Json::obj(vec![
                ("replay_example1_mean_ns", Json::Num(replay_ns)),
                ("replay_big_mean_ns", Json::Num(big_ns)),
                ("big_events", Json::UInt(big_events as u128)),
                ("events_per_sec", Json::Num(events_per_sec)),
            ]),
        ),
    ]);
    let path = "BENCH_sim.json";
    match std::fs::write(path, report.render() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
