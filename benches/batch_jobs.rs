//! Batch-runtime sweep: executed wall time and simulated makespan of
//! the *full job set* — CAMR rounds (serial vs thread-per-worker,
//! growing batch sizes) against the capped CCDC family and the uncoded
//! baseline.
//!
//! Every cell really executes its jobs end to end (map, coded shuffle,
//! reduce, oracle verification pipelined behind the next round) through
//! one persistent engine, then replays the aggregate job-tagged ledger
//! through the cluster simulator for barriered-vs-pipelined makespans.
//! Writes machine-readable `BENCH_batch.json` (created on
//! `cargo bench --bench batch_jobs`; not checked in).

use camr::config::SystemConfig;
use camr::coordinator::batch::{run_batch_synthetic, BatchOptions, BatchScheme};
use camr::sim::SimConfig;
use camr::util::bench::Bench;
use camr::util::json::Json;

fn main() {
    let b = Bench::new();
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("CAMR_BENCH_QUICK").is_ok();
    let cfg = SystemConfig::new(3, 2, 2).unwrap(); // paper Example 1 shape
    let per_round = cfg.jobs();
    // Slow enough that shuffles dominate and pipelining has something
    // to hide map work behind.
    let mut sc = SimConfig::commodity();
    sc.link_bytes_per_sec = 1e5;

    let round_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let mut rows: Vec<Json> = Vec::new();

    println!("== Batch runtime: executed wall time + simulated makespan ==\n");
    for &rounds in round_counts {
        for parallel in [false, true] {
            let opts = BatchOptions {
                jobs: Some(rounds * per_round),
                parallel,
                ..BatchOptions::default()
            };
            let label = format!(
                "camr_batch_{}x{}jobs_{}",
                rounds,
                per_round,
                if parallel { "parallel" } else { "serial" }
            );
            let mut last = None;
            let wall_ns = b.run(&label, || {
                let out = run_batch_synthetic(&cfg, BatchScheme::Camr, &opts).unwrap();
                assert!(out.all_verified());
                let bytes = out.total_bytes();
                last = Some(out);
                bytes
            });
            let out = last.expect("at least one timed run");
            let sim = out.simulate(&sc).unwrap();
            println!(
                "    jobs={:<3} units={} bytes={} serial={:.6}s pipelined={:.6}s (saved {:.1}%)\n",
                out.jobs_executed,
                out.units.len(),
                out.total_bytes(),
                sim.serial_secs,
                sim.pipelined_secs,
                100.0 * sim.saved_secs() / sim.serial_secs.max(1e-12)
            );
            rows.push(Json::obj(vec![
                ("scheme", Json::Str("camr".into())),
                ("engine", Json::Str(if parallel { "parallel" } else { "serial" }.into())),
                ("rounds", Json::UInt(rounds as u128)),
                ("jobs", Json::UInt(out.jobs_executed as u128)),
                ("bytes", Json::UInt(out.total_bytes() as u128)),
                ("wall_ns", Json::Num(wall_ns)),
                ("serial_secs", Json::Num(sim.serial_secs)),
                ("pipelined_secs", Json::Num(sim.pipelined_secs)),
                ("saved_secs", Json::Num(sim.saved_secs())),
            ]));
        }
    }

    // Baselines at the same storage fraction: the capped CCDC family
    // and one uncoded round set.
    for (scheme, label, cap) in [
        (BatchScheme::Ccdc, "ccdc_family_capped", Some(if quick { 10 } else { 20 })),
        (BatchScheme::Uncoded, "uncoded_round", None),
    ] {
        let opts = BatchOptions { ccdc_cap: cap, ..BatchOptions::default() };
        let mut last = None;
        let wall_ns = b.run(label, || {
            let out = run_batch_synthetic(&cfg, scheme, &opts).unwrap();
            assert!(out.all_verified());
            let bytes = out.total_bytes();
            last = Some(out);
            bytes
        });
        let out = last.expect("at least one timed run");
        let sim = out.simulate(&sc).unwrap();
        println!(
            "    required={} executed={} bytes={} pipelined={:.6}s ({:.6}s/job)\n",
            out.jobs_required,
            out.jobs_executed,
            out.total_bytes(),
            sim.pipelined_secs,
            sim.pipelined_secs / out.jobs_executed.max(1) as f64
        );
        rows.push(Json::obj(vec![
            ("scheme", Json::Str(scheme.label().into())),
            ("engine", Json::Str("serial".into())),
            ("rounds", Json::UInt(out.units.len() as u128)),
            ("jobs", Json::UInt(out.jobs_executed as u128)),
            ("jobs_required", Json::UInt(out.jobs_required)),
            ("bytes", Json::UInt(out.total_bytes() as u128)),
            ("wall_ns", Json::Num(wall_ns)),
            ("serial_secs", Json::Num(sim.serial_secs)),
            ("pipelined_secs", Json::Num(sim.pipelined_secs)),
            ("saved_secs", Json::Num(sim.saved_secs())),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("batch_jobs".to_string())),
        ("quick", Json::Bool(quick)),
        ("k", Json::UInt(cfg.k as u128)),
        ("q", Json::UInt(cfg.q as u128)),
        ("gamma", Json::UInt(cfg.gamma as u128)),
        ("sim_bandwidth", Json::Num(sc.link_bytes_per_sec)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = "BENCH_batch.json";
    match std::fs::write(path, report.render() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
