//! Ablation bench — per-stage coding contribution (DESIGN.md's called-out
//! design choice: where does the coded multicast actually earn its load
//! reduction?).
//!
//! Runs all four coding variants (stage 1/2 coded or unicast) across
//! several designs, asserting each variant's measured load equals its
//! closed form, and times the full runs so the XOR cost of coding is
//! visible next to the bytes it saves.

use camr::baseline::{run_ablation, CodingChoice};
use camr::config::SystemConfig;
use camr::util::bench::Bench;
use camr::workload::synth::SyntheticWorkload;

fn main() {
    println!("== Stage-coding ablation (all variants oracle-verified) ==\n");
    for (k, q) in [(3usize, 2usize), (3, 4), (4, 2), (4, 3)] {
        let cfg = SystemConfig::with_options(k, q, 2, 1, 120).unwrap();
        println!("k={k} q={q} (K={}, J={}):", cfg.servers(), cfg.jobs());
        println!(
            "  {:<22} {:>8} {:>8} {:>8} {:>8} {:>9}",
            "variant", "L1", "L2", "L3", "total", "expected"
        );
        for choice in CodingChoice::all() {
            let wl = SyntheticWorkload::new(&cfg, 1);
            let out = run_ablation(cfg.clone(), Box::new(wl), choice).unwrap();
            assert!(out.verified);
            let n = out.normalizer;
            let expect = choice.expected_load(k, q);
            assert!(
                (out.total_load() - expect).abs() < 1e-12,
                "k={k} q={q} {}: {} vs {expect}",
                choice.label(),
                out.total_load()
            );
            println!(
                "  {:<22} {:>8.4} {:>8.4} {:>8.4} {:>8.4} {:>9.4}",
                choice.label(),
                out.stage_bytes[0] as f64 / n,
                out.stage_bytes[1] as f64 / n,
                out.stage_bytes[2] as f64 / n,
                out.total_load(),
                expect
            );
        }
        println!();
    }

    println!("== Wall time: coding cost vs bytes saved (k=4, q=3, B=4096) ==\n");
    let b = Bench::new();
    let cfg = SystemConfig::with_options(4, 3, 2, 1, 4096).unwrap();
    for choice in CodingChoice::all() {
        let cfg2 = cfg.clone();
        b.run(&format!("ablation[{}]", choice.label()), move || {
            let wl = SyntheticWorkload::new(&cfg2, 2);
            run_ablation(cfg2.clone(), Box::new(wl), choice).unwrap().stage_bytes
        });
    }
    println!(
        "\nThe XOR encode/decode adds CPU work but removes a factor k-1 \
         from stages 1–2 on the wire."
    );
}
