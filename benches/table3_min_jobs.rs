//! E8 — Table III: minimum job requirements, CAMR vs CCDC, K = 100.
//!
//! Regenerates the table exactly (values are asserted) and benchmarks
//! the cost of *instantiating* each scheme's job structure at its
//! minimum size — q^{k-1} design points vs C(K,k) subsets — which is
//! what a master actually pays at submission time.

use camr::analysis::jobs::{binomial, table3, JobRequirement};
use camr::baseline::ccdc::k_subsets;
use camr::design::ResolvableDesign;
use camr::util::bench::Bench;

fn main() {
    println!("== Table III: minimum #jobs at equal storage fraction, K = 100 ==\n");
    println!("{:>4} {:>12} {:>12} {:>9}", "k", "J_CAMR", "J_CCDC", "ratio");
    for row in table3() {
        println!(
            "{:>4} {:>12} {:>12} {:>8.1}x",
            row.k,
            row.camr,
            row.ccdc,
            row.ratio()
        );
    }
    // Assert the exact paper values.
    let rows = table3();
    assert_eq!(
        rows.iter().map(|r| (r.camr, r.ccdc)).collect::<Vec<_>>(),
        vec![(50, 4950), (15_625, 3_921_225), (160_000, 75_287_520)]
    );
    assert_eq!(binomial(6, 3), 20); // §III-C example

    println!("\n== Master-side instantiation cost at minimum job count ==\n");
    let b = Bench::new();
    // CAMR: build the resolvable design (jobs + ownership) at K=100.
    for (k, q) in [(2usize, 50usize), (4, 25), (5, 20)] {
        b.run(&format!("camr_design_k{k}_q{q} (J={})", q.pow(k as u32 - 1)), || {
            let d = ResolvableDesign::new(k, q).unwrap();
            d.jobs()
        });
    }
    // CCDC: enumerate the job subsets. k=4/5 at K=100 are infeasible
    // (3.9M / 75M jobs) — bench k=2 and smaller K to show the scaling.
    b.run("ccdc_jobs_k2_K100 (J=4950)", || k_subsets(100, 2).len());
    b.run("ccdc_jobs_k3_K30 (J=4060)", || k_subsets(30, 3).len());
    b.run("ccdc_jobs_k4_K25 (J=12650)", || k_subsets(25, 4).len());
    println!(
        "\nCCDC at K=100, k=4 would need {} jobs and k=5 {} jobs — not \
         instantiable in a bench; CAMR needs {} and {}.",
        JobRequirement::for_params(4, 25).ccdc,
        JobRequirement::for_params(5, 20).ccdc,
        JobRequirement::for_params(4, 25).camr,
        JobRequirement::for_params(5, 20).camr
    );
}
