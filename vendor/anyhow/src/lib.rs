//! Minimal in-tree substitute for the `anyhow` crate.
//!
//! This workspace builds fully offline, so the handful of `anyhow` APIs
//! the binaries and examples use are reimplemented here: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!`,
//! `bail!`, `ensure!` macros. Errors are flattened to their display
//! string at conversion time — good enough for CLI diagnostics.

use std::fmt;

/// A type-erased error: the source error's display string.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(&e)
    }
}

/// `Result` defaulting to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (subset of `anyhow::Context`).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string (subset of `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("value was {}", 42)
    }

    #[test]
    fn macros_build_errors() {
        assert_eq!(fails().unwrap_err().to_string(), "value was 42");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn ensure_both_arms() {
        fn check(x: usize) -> Result<()> {
            ensure!(x > 1);
            ensure!(x > 2, "x too small: {x}");
            Ok(())
        }
        assert!(check(3).is_ok());
        assert!(check(2).unwrap_err().to_string().contains("too small"));
        assert!(check(1).unwrap_err().to_string().contains("condition failed"));
    }

    #[test]
    fn context_wraps_messages() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting"));
        let n: Option<u8> = None;
        assert!(n.with_context(|| "missing").is_err());
    }

    #[test]
    fn from_std_error() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
    }
}
