"""Layer-2 correctness: the map-phase model graphs vs oracles, and the
AOT export path (HLO text must be produced and contain the entry module).
"""

import json
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels.ref import batch_agg_ref, matvec_ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=shape).astype(np.float32))


def test_map_shard_matches_ref():
    a, x = rand((96, 8), 1), rand((8,), 2)
    (got,) = model.map_shard(a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(matvec_ref(a, x)), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    gamma=st.integers(min_value=1, max_value=4),
    m=st.integers(min_value=1, max_value=64),
    cols=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_map_batch_matches_ref(gamma, m, cols, seed):
    a = rand((gamma, m, cols), seed)
    x = rand((gamma, cols), seed ^ 0xABCD)
    (got,) = model.map_batch(a, x)
    want = batch_agg_ref(a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_map_batch_equals_sum_of_shards():
    # The fused batch graph must equal γ separate shard maps + combine —
    # i.e. aggregation is associative through the L2 graph (Def. 1).
    gamma, m, cols = 3, 24, 8
    a = rand((gamma, m, cols), 7)
    x = rand((gamma, cols), 8)
    (fused,) = model.map_batch(a, x)
    parts = [model.map_shard(a[g], x[g])[0] for g in range(gamma)]
    manual = jnp.sum(jnp.stack(parts), axis=0)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(manual), rtol=1e-5, atol=1e-6)


def test_export_writes_hlo_text_and_meta():
    with tempfile.TemporaryDirectory() as d:
        path = aot.export(
            model.map_shard,
            (
                jax.ShapeDtypeStruct((24, 8), jnp.float32),
                jax.ShapeDtypeStruct((8,), jnp.float32),
            ),
            d,
            "map_kernel",
            {"m": 24, "cols": 8, "dtype": "f32", "kernel": "pallas_matvec"},
        )
        text = open(path).read()
        # HLO text, not proto bytes: must start with the module header.
        assert text.lstrip().startswith("HloModule")
        # Entry computation consumes the two parameters.
        assert "f32[24,8]" in text
        assert "f32[8]" in text
        meta = json.load(open(os.path.join(d, "map_kernel.meta.json")))
        assert meta["m"] == 24 and meta["cols"] == 8 and meta["dtype"] == "f32"


def test_exported_hlo_is_runnable_by_jax_cpu():
    # Round-trip sanity: compile the exported text back through the local
    # XLA client and compare numerics with the oracle. This is the same
    # path the rust runtime uses (HloModuleProto::from_text).
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(model.map_shard).lower(
        jax.ShapeDtypeStruct((24, 8), jnp.float32),
        jax.ShapeDtypeStruct((8,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # parse back via the XLA HLO text parser if exposed; otherwise assert
    # the text at least names a dot/reduce pipeline.
    assert ("dot(" in text) or ("dot." in text) or ("fusion" in text)


@pytest.mark.parametrize("m,cols", [(96, 8), (32, 16)])
def test_export_shapes_parameterized(m, cols):
    with tempfile.TemporaryDirectory() as d:
        aot.export(
            model.map_shard,
            (
                jax.ShapeDtypeStruct((m, cols), jnp.float32),
                jax.ShapeDtypeStruct((cols,), jnp.float32),
            ),
            d,
            "k",
            {"m": m, "cols": cols, "dtype": "f32", "kernel": "pallas_matvec"},
        )
        text = open(os.path.join(d, "k.hlo.txt")).read()
        assert f"f32[{m},{cols}]" in text
