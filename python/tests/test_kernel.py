"""Layer-1 correctness: the Pallas matvec kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and data; every case asserts allclose against
``ref.matvec_ref``. This is the CORE correctness signal gating the AOT
export (``make artifacts`` is only trusted because these pass).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.matvec import matvec, pick_tile_m, vmem_footprint_bytes
from compile.kernels.ref import matvec_ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.uniform(-1.0, 1.0, size=shape).astype(np.float32)


@pytest.mark.parametrize(
    "m,cols",
    [(1, 1), (4, 8), (8, 3), (96, 8), (128, 16), (130, 7), (24, 24)],
)
def test_matvec_matches_ref_fixed_shapes(m, cols):
    a = rand((m, cols), seed=m * 1000 + cols)
    x = rand((cols,), seed=m + cols)
    got = np.asarray(matvec(jnp.asarray(a), jnp.asarray(x)))
    want = np.asarray(matvec_ref(jnp.asarray(a), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=160),
    cols=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_matvec_matches_ref_hypothesis(m, cols, seed):
    a = rand((m, cols), seed=seed)
    x = rand((cols,), seed=seed ^ 0xFFFF)
    got = np.asarray(matvec(jnp.asarray(a), jnp.asarray(x)))
    want = np.asarray(matvec_ref(jnp.asarray(a), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=2, max_value=128),
    tile_target=st.integers(min_value=1, max_value=128),
)
def test_pick_tile_m_divides(m, tile_target):
    t = pick_tile_m(m, tile_target)
    assert m % t == 0
    assert 1 <= t <= min(m, tile_target)


def test_explicit_tile_must_divide():
    a = jnp.zeros((6, 4), jnp.float32)
    x = jnp.zeros((4,), jnp.float32)
    with pytest.raises(ValueError):
        matvec(a, x, tile_m=4)  # 4 does not divide 6


def test_shape_mismatch_rejected():
    a = jnp.zeros((6, 4), jnp.float32)
    x = jnp.zeros((5,), jnp.float32)
    with pytest.raises(ValueError):
        matvec(a, x)


def test_vmem_footprint_under_budget():
    # The default artifact shape must sit far below a TPU core's ~16 MiB
    # VMEM (DESIGN.md §Perf / §Hardware-Adaptation).
    assert vmem_footprint_bytes(96, 8) < 1 << 20
    # A production-ish layer shard too: 4096 x 512 tiles at 128 rows.
    assert vmem_footprint_bytes(4096, 512) < 4 << 20


def test_deterministic():
    a = rand((32, 8), seed=1)
    x = rand((8,), seed=2)
    r1 = np.asarray(matvec(jnp.asarray(a), jnp.asarray(x)))
    r2 = np.asarray(matvec(jnp.asarray(a), jnp.asarray(x)))
    np.testing.assert_array_equal(r1, r2)


# ---- fused batch kernel -------------------------------------------------

from compile.kernels.matvec import batch_matvec_fused, matvec as _matvec
from compile.kernels.ref import batch_agg_ref


@settings(max_examples=25, deadline=None)
@given(
    gamma=st.integers(min_value=1, max_value=4),
    m=st.integers(min_value=1, max_value=96),
    cols=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_batch_fused_matches_ref(gamma, m, cols, seed):
    a = rand((gamma, m, cols), seed)
    x = rand((gamma, cols), seed ^ 0x5A5A)
    got = np.asarray(batch_matvec_fused(jnp.asarray(a), jnp.asarray(x)))
    want = np.asarray(batch_agg_ref(jnp.asarray(a), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_batch_fused_equals_loop_of_singles():
    # In-kernel accumulation == γ separate kernel calls + sum.
    gamma, m, cols = 3, 32, 8
    a = rand((gamma, m, cols), 11)
    x = rand((gamma, cols), 12)
    fused = np.asarray(batch_matvec_fused(jnp.asarray(a), jnp.asarray(x)))
    singles = sum(
        np.asarray(_matvec(jnp.asarray(a[g]), jnp.asarray(x[g]))) for g in range(gamma)
    )
    np.testing.assert_allclose(fused, singles, rtol=1e-5, atol=1e-5)


def test_batch_fused_rejects_bad_shapes():
    a = jnp.zeros((2, 8, 4), jnp.float32)
    x = jnp.zeros((3, 4), jnp.float32)
    with pytest.raises(ValueError):
        batch_matvec_fused(a, x)
