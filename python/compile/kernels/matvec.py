"""Layer-1 Pallas kernel: tiled matrix-vector product.

The map-phase hot-spot of the CAMR matvec workload (paper §I: "the
matrix-vector multiplications performed during the forward and backward
propagation in neural networks... computing each of these products
constitutes a job"). Each subfile of a job is a column shard ``A_n``
(``m x cols``) with its input slice ``x_n``; the kernel computes the
partial product ``A_n @ x_n`` that the rust coordinator aggregates.

TPU shaping (DESIGN.md §Hardware-Adaptation): the grid walks row tiles of
``A`` so each step streams one ``(tile_m, cols)`` block from HBM into
VMEM (BlockSpec), multiplies against the resident ``x`` and writes a
``(tile_m,)`` slice of the output. ``tile_m`` targets MXU-friendly
128-row tiles and divides ``m`` exactly. fp32 accumulation.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that both pytest and the
rust runtime run. Real-TPU efficiency is estimated from the VMEM/MXU
footprint in DESIGN.md, not measured here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_tile_m(m: int, target: int = 128) -> int:
    """Largest divisor of ``m`` that is <= target (MXU sublane budget)."""
    if m <= 0:
        raise ValueError(f"m must be positive, got {m}")
    best = 1
    for cand in range(1, min(m, target) + 1):
        if m % cand == 0:
            best = cand
    return best


def _matvec_tile_kernel(a_ref, x_ref, o_ref):
    """One grid step: (tile_m, cols) x (cols,) -> (tile_m,).

    ``jnp.dot`` on an fp32 tile maps onto the MXU on real hardware;
    ``preferred_element_type`` pins fp32 accumulation.
    """
    o_ref[...] = jnp.dot(a_ref[...], x_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_m",))
def matvec(a: jax.Array, x: jax.Array, tile_m: int | None = None) -> jax.Array:
    """Tiled Pallas matvec: ``a (m, cols) @ x (cols,) -> (m,)``.

    ``tile_m`` must divide ``m``; defaults to the largest divisor <= 128.
    """
    m, cols = a.shape
    if x.shape != (cols,):
        raise ValueError(f"x shape {x.shape} does not match a {a.shape}")
    if tile_m is None:
        tile_m = pick_tile_m(m)
    if m % tile_m != 0:
        raise ValueError(f"tile_m={tile_m} does not divide m={m}")
    grid = (m // tile_m,)
    return pl.pallas_call(
        _matvec_tile_kernel,
        grid=grid,
        in_specs=[
            # Row tile i of A: HBM -> VMEM, one (tile_m, cols) block/step.
            pl.BlockSpec((tile_m, cols), lambda i: (i, 0)),
            # x stays resident across the whole grid.
            pl.BlockSpec((cols,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, x)


def _batch_matvec_kernel(a_ref, x_ref, o_ref):
    """Fused batch kernel: one grid step handles (shard g, row-tile i).

    The output tile accumulates across the γ grid steps of its row tile —
    the paper's end-of-map aggregation (§III-B) done *inside* the kernel,
    so partial products never round-trip through HBM.
    """
    g = pl.program_id(0)
    partial = jnp.dot(a_ref[0], x_ref[0], preferred_element_type=jnp.float32)

    @pl.when(g == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(g > 0)
    def _accum():
        o_ref[...] = o_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("tile_m",))
def batch_matvec_fused(a_batch: jax.Array, x_batch: jax.Array, tile_m: int | None = None) -> jax.Array:
    """Fused map+combine over a batch: ``(γ, m, cols), (γ, cols) -> (m,)``.

    Equivalent to ``sum_g a_batch[g] @ x_batch[g]`` with the sum
    accumulated in VMEM across grid steps (revisiting output blocks),
    instead of materializing γ partial vectors and reducing afterwards.
    """
    gamma, m, cols = a_batch.shape
    if x_batch.shape != (gamma, cols):
        raise ValueError(f"x_batch shape {x_batch.shape} does not match a {a_batch.shape}")
    if tile_m is None:
        tile_m = pick_tile_m(m)
    if m % tile_m != 0:
        raise ValueError(f"tile_m={tile_m} does not divide m={m}")
    grid = (gamma, m // tile_m)
    return pl.pallas_call(
        _batch_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_m, cols), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, cols), lambda g, i: (g, 0)),
        ],
        # Output tile depends only on i: revisited across g (accumulate).
        out_specs=pl.BlockSpec((tile_m,), lambda g, i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(a_batch, x_batch)


def vmem_footprint_bytes(m: int, cols: int, tile_m: int | None = None) -> int:
    """Estimated VMEM residency per grid step (A tile + x + out tile).

    Used by DESIGN.md's roofline estimate; must stay well under the
    ~16 MiB VMEM of a TPU core.
    """
    if tile_m is None:
        tile_m = pick_tile_m(m)
    return 4 * (tile_m * cols + cols + tile_m)
