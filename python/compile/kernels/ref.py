"""Pure-jnp oracles for the Pallas kernels.

The CORE correctness signal for Layer 1: pytest asserts the Pallas
kernel's output matches these references across shape/dtype sweeps
(hypothesis) before anything is AOT-exported for the rust runtime.
"""

import jax.numpy as jnp


def matvec_ref(a, x):
    """Reference partial product: plain ``a @ x`` in fp32."""
    return jnp.dot(a.astype(jnp.float32), x.astype(jnp.float32))


def batch_agg_ref(a_batch, x_batch):
    """Reference batch aggregate: sum of the γ per-subfile partials.

    ``a_batch`` is ``(gamma, m, cols)``, ``x_batch`` is ``(gamma, cols)``;
    the result is the batch-level aggregate value the CAMR map phase
    combines (paper §III-B).
    """
    partials = jnp.einsum(
        "gmc,gc->gm", a_batch.astype(jnp.float32), x_batch.astype(jnp.float32)
    )
    return jnp.sum(partials, axis=0)
