"""Layer-2 JAX model: the CAMR map-phase compute graph.

Two entry points, both lowered AOT by :mod:`compile.aot` and executed
from rust via PJRT (python never runs on the request path):

- :func:`map_shard` — one subfile's partial product ``A_n @ x_n``
  (calls the Layer-1 Pallas kernel). This is what the rust engine's
  ``PjrtShardCompute`` invokes per (job, subfile).
- :func:`map_batch` — a whole batch of ``γ`` subfiles mapped and
  combined in one fused graph (the paper's end-of-map aggregation,
  §III-B): ``sum_n A_n @ x_n``. Demonstrates that the combine fuses into
  the same XLA module, costing no extra materialization.

Outputs are 1-tuples because ``aot.py`` lowers with ``return_tuple=True``
(the rust side unwraps with ``to_tuple1``).
"""

import jax
import jax.numpy as jnp

from .kernels.matvec import batch_matvec_fused, matvec


def map_shard(a, x):
    """Partial product of one subfile: ``(m, cols) x (cols,) -> (m,)``."""
    return (matvec(a, x),)


def map_batch(a_batch, x_batch):
    """Map + combine one batch of γ subfiles in a single fused graph.

    vmap runs the Pallas kernel per subfile; the sum is the batch-level
    aggregate ``α`` of §III-B. Shapes: ``(γ, m, cols), (γ, cols) -> (m,)``.
    """
    partials = jax.vmap(lambda a, x: matvec(a, x))(a_batch, x_batch)
    return (jnp.sum(partials, axis=0),)


def map_batch_fused(a_batch, x_batch):
    """Same contract as :func:`map_batch`, but the γ-way combine happens
    *inside* the Pallas kernel (accumulating output tiles across grid
    steps) — zero materialized partials. Exported as the `batch_fused`
    artifact for the ablation comparison.
    """
    return (batch_matvec_fused(a_batch, x_batch),)
