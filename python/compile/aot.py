"""AOT export: lower the Layer-2 JAX model to HLO **text** artifacts.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust
side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/load_hlo and its README for the full gotcha list).

Each artifact is a pair:
  ``<name>.hlo.txt``   — the HLO module (compiled by rust via PJRT)
  ``<name>.meta.json`` — shapes + provenance read by ``rust/src/runtime``

Run once via ``make artifacts``; rust is self-contained afterwards.

Usage:
  python -m compile.aot --out ../artifacts [--m 96] [--cols 8] [--gamma 2]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(fn, example_args, out_dir: str, name: str, meta: dict) -> str:
    """Lower ``fn`` at the example shapes and write the artifact pair."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    os.makedirs(out_dir, exist_ok=True)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return hlo_path


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact directory")
    p.add_argument("--m", type=int, default=96, help="rows per shard (M = Q * rows_per_func)")
    p.add_argument("--cols", type=int, default=8, help="columns per subfile shard")
    p.add_argument("--gamma", type=int, default=2, help="subfiles per batch (batch artifact)")
    args = p.parse_args()

    f32 = jnp.float32
    shard_args = (
        jax.ShapeDtypeStruct((args.m, args.cols), f32),
        jax.ShapeDtypeStruct((args.cols,), f32),
    )
    path = export(
        model.map_shard,
        shard_args,
        args.out,
        "map_kernel",
        {"m": args.m, "cols": args.cols, "dtype": "f32", "kernel": "pallas_matvec"},
    )
    print(f"wrote {path}", file=sys.stderr)

    batch_args = (
        jax.ShapeDtypeStruct((args.gamma, args.m, args.cols), f32),
        jax.ShapeDtypeStruct((args.gamma, args.cols), f32),
    )
    path = export(
        model.map_batch,
        batch_args,
        args.out,
        "batch_agg",
        {
            "m": args.m,
            "cols": args.cols,
            "gamma": args.gamma,
            "dtype": "f32",
            "kernel": "pallas_matvec+sum",
        },
    )
    print(f"wrote {path}", file=sys.stderr)

    path = export(
        model.map_batch_fused,
        batch_args,
        args.out,
        "batch_fused",
        {
            "m": args.m,
            "cols": args.cols,
            "gamma": args.gamma,
            "dtype": "f32",
            "kernel": "pallas_batch_fused",
        },
    )
    print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
