//! Shuffle plan descriptors shared by all three stages.

use crate::{BatchId, FuncId, JobId, ServerId};

/// Identifies one *chunk* of Lemma 2: the aggregate of the intermediate
/// values of `func` over batch `batch` of job `job`, destined to
/// `receiver` (who cannot compute it locally).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ChunkSpec {
    /// The server that must decode this chunk.
    pub receiver: ServerId,
    /// Job the aggregate belongs to.
    pub job: JobId,
    /// Output function of the aggregate (the receiver's function).
    pub func: FuncId,
    /// Batch whose `γ` per-subfile values are aggregated.
    pub batch: BatchId,
}

/// A stage-3 unicast: `sender` fuses the aggregates of `batches` (all the
/// batches of `job` it stores) for the receiver's `func` and sends one
/// value of `B` bytes (paper Eq. (5)).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnicastSpec {
    /// The unique owner of `job` in the receiver's parallel class.
    pub sender: ServerId,
    /// The non-owner server that still misses these values.
    pub receiver: ServerId,
    /// Job the fused aggregate belongs to.
    pub job: JobId,
    /// Output function (the receiver's function).
    pub func: FuncId,
    /// The `k-1` batches fused into the single transmitted value.
    pub batches: Vec<BatchId>,
}
