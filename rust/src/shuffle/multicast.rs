//! Algorithm 2 — the coded multicast of Lemma 2.
//!
//! Setting: a group `G = {m_0, …, m_{g-1}}` of `g` machines such that for
//! every `p`, the machines `G \ {m_p}` all store a chunk `D_p` (of `B`
//! bytes) that `m_p` does not. Each chunk is split into `g-1` packets;
//! packet `i` of `D_p` is associated with the `i`-th machine of
//! `G \ {m_p}` (in group order). Machine `m_t` broadcasts the XOR of the
//! packets associated with it (one per other member's chunk); every
//! machine cancels what it knows and recovers its missing packet. After
//! `g` broadcasts of `⌈B/(g-1)⌉` bytes, every machine has its chunk —
//! `g/(g-1) · B` bytes total (Lemma 2).
//!
//! The implementation is *byte-exact*: encoding really XORs payload
//! packets, decoding really cancels them, and the engine verifies every
//! decoded chunk. Nothing is accounted that is not actually transmitted.

use super::packet;
use super::plan::ChunkSpec;
use crate::error::{CamrError, Result};
use crate::ServerId;

/// One Lemma-2 group: `members[p]` must decode the chunk described by
/// `chunks[p]`, which every *other* member can compute locally.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupPlan {
    /// Group members in canonical order (`G` of Lemma 2).
    pub members: Vec<ServerId>,
    /// `chunks[p]` is the chunk missing at `members[p]`.
    pub chunks: Vec<ChunkSpec>,
}

impl GroupPlan {
    /// Group size `g`.
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// Packets per chunk (`g - 1`).
    pub fn parts(&self) -> usize {
        self.members.len() - 1
    }

    /// The members of `G \ {members[p]}` in group order.
    pub fn others(&self, p: usize) -> Vec<ServerId> {
        let mut o = self.members.clone();
        o.remove(p);
        o
    }

    /// The packet index of chunk `p` associated with member position `t`
    /// (`t ≠ p`): position of `members[t]` within `others(p)`.
    pub fn packet_index(&self, p: usize, t: usize) -> usize {
        debug_assert_ne!(p, t);
        if t < p {
            t
        } else {
            t - 1
        }
    }

    /// XOR packet `idx` of `chunk` into `delta` without materializing the
    /// packet: the zero padding of the last packet is a XOR no-op, so only
    /// the real bytes are touched. This is the shuffle hot path (§Perf).
    fn xor_packet_into(delta: &mut [u8], chunk: &[u8], idx: usize, plen: usize) -> Result<()> {
        let start = (idx * plen).min(chunk.len());
        let end = ((idx + 1) * plen).min(chunk.len());
        packet::xor_into(&mut delta[..end - start], &chunk[start..end])
    }

    /// The broadcast `Δ_t` of member position `t` (paper Eq. (3)):
    /// XOR over all chunks `p ≠ t` of the packet associated with `t`,
    /// written into the caller-provided `delta` buffer (typically a
    /// zeroed [`super::buf::PooledBuf`] — no allocation on this path).
    ///
    /// `chunk_bytes(p)` supplies a borrowed view of chunk `p`'s payload
    /// (the engine reads it from the **sender's** local store — every
    /// chunk `p ≠ t` is stored by `members[t]` by construction). No
    /// copies of the chunks are made.
    pub fn encode_ref_into<'a, F>(
        &self,
        t: usize,
        chunk_len: usize,
        mut chunk_bytes: F,
        delta: &mut [u8],
    ) -> Result<()>
    where
        F: FnMut(usize) -> Result<&'a [u8]>,
    {
        let g = self.size();
        if g < 2 {
            return Err(CamrError::ShuffleDecode("group size must be >= 2".into()));
        }
        let plen = packet::packet_len(chunk_len, self.parts());
        if delta.len() != plen {
            return Err(CamrError::ShuffleDecode(format!(
                "delta buffer has {} bytes, expected {plen}",
                delta.len()
            )));
        }
        delta.fill(0);
        for p in 0..g {
            if p == t {
                continue;
            }
            let chunk = chunk_bytes(p)?;
            if chunk.len() != chunk_len {
                return Err(CamrError::ShuffleDecode(format!(
                    "chunk {p} has {} bytes, expected {chunk_len}",
                    chunk.len()
                )));
            }
            Self::xor_packet_into(delta, chunk, self.packet_index(p, t), plen)?;
        }
        Ok(())
    }

    /// Allocating wrapper over [`GroupPlan::encode_ref_into`].
    pub fn encode_ref<'a, F>(&self, t: usize, chunk_len: usize, chunk_bytes: F) -> Result<Vec<u8>>
    where
        F: FnMut(usize) -> Result<&'a [u8]>,
    {
        if self.size() < 2 {
            return Err(CamrError::ShuffleDecode("group size must be >= 2".into()));
        }
        let mut delta = vec![0u8; packet::packet_len(chunk_len, self.parts())];
        self.encode_ref_into(t, chunk_len, chunk_bytes, &mut delta)?;
        Ok(delta)
    }

    /// Owned-payload convenience wrapper over [`GroupPlan::encode_ref`]
    /// (used by tests and the CCDC baseline).
    pub fn encode<F>(&self, t: usize, chunk_len: usize, mut chunk_bytes: F) -> Result<Vec<u8>>
    where
        F: FnMut(usize) -> Result<Vec<u8>>,
    {
        let g = self.size();
        let chunks: Vec<Option<Vec<u8>>> = (0..g)
            .map(|p| if p == t { Ok(None) } else { chunk_bytes(p).map(Some) })
            .collect::<Result<_>>()?;
        self.encode_ref(t, chunk_len, |p| {
            chunks[p]
                .as_deref()
                .ok_or_else(|| CamrError::ShuffleDecode(format!("chunk {p} unavailable")))
        })
    }

    /// Decode at member position `r` using a caller-provided scratch
    /// packet buffer (typically a [`super::buf::PooledBuf`]): given the
    /// broadcasts `deltas[t]` for every `t ≠ r` (entry `r` is ignored),
    /// reconstruct chunk `r`. `chunk_bytes(p)` supplies borrowed views
    /// of the chunks `p ≠ r` from the decoder's local store (used to
    /// cancel known packets); nothing is copied or split. `deltas` may
    /// be any borrowable byte containers — owned `Vec<u8>`s or shared
    /// [`super::buf::SharedBuf`] handles alike.
    pub fn decode_ref_scratch<'a, D, F>(
        &self,
        r: usize,
        chunk_len: usize,
        deltas: &[D],
        mut chunk_bytes: F,
        scratch: &mut [u8],
    ) -> Result<Vec<u8>>
    where
        D: AsRef<[u8]>,
        F: FnMut(usize) -> Result<&'a [u8]>,
    {
        let g = self.size();
        if g < 2 {
            return Err(CamrError::ShuffleDecode("group size must be >= 2".into()));
        }
        if deltas.len() != g {
            return Err(CamrError::ShuffleDecode(format!(
                "need {g} delta slots, got {}",
                deltas.len()
            )));
        }
        let parts = self.parts();
        let plen = packet::packet_len(chunk_len, parts);
        if scratch.len() != plen {
            return Err(CamrError::ShuffleDecode(format!(
                "scratch buffer has {} bytes, expected {plen}",
                scratch.len()
            )));
        }
        // Borrow the decoder's known chunks once.
        let mut known: Vec<Option<&[u8]>> = vec![None; g];
        for p in 0..g {
            if p == r {
                continue;
            }
            known[p] = Some(chunk_bytes(p)?);
        }
        // Recover packet i of chunk r from the broadcast of others(r)[i],
        // writing straight into the output buffer. Iterating t ascending
        // yields packet_index(r, t) = 0, 1, …, g-2 in order.
        let mut out = vec![0u8; chunk_len];
        for t in (0..g).filter(|&t| t != r) {
            let delta = deltas[t].as_ref();
            if delta.len() != plen {
                return Err(CamrError::ShuffleDecode(format!(
                    "delta from position {t} has {} bytes, expected {plen}",
                    delta.len()
                )));
            }
            scratch.copy_from_slice(delta);
            for p in (0..g).filter(|&p| p != t && p != r) {
                let chunk = known[p].expect("known chunk");
                Self::xor_packet_into(scratch, chunk, self.packet_index(p, t), plen)?;
            }
            let idx = self.packet_index(r, t);
            let start = (idx * plen).min(chunk_len);
            let end = ((idx + 1) * plen).min(chunk_len);
            out[start..end].copy_from_slice(&scratch[..end - start]);
        }
        Ok(out)
    }

    /// Decode with a scratch packet acquired from `pool` — the engines'
    /// allocation-free path (only the returned chunk itself is allocated,
    /// because it outlives the exchange inside the worker's store).
    pub fn decode_ref_pooled<'a, D, F>(
        &self,
        r: usize,
        chunk_len: usize,
        deltas: &[D],
        chunk_bytes: F,
        pool: &super::buf::BufferPool,
    ) -> Result<Vec<u8>>
    where
        D: AsRef<[u8]>,
        F: FnMut(usize) -> Result<&'a [u8]>,
    {
        if self.size() < 2 {
            return Err(CamrError::ShuffleDecode("group size must be >= 2".into()));
        }
        // Unzeroed: decode_ref_scratch overwrites the scratch packet
        // (copy_from_slice) before ever reading it.
        let mut scratch = pool.acquire_unzeroed(packet::packet_len(chunk_len, self.parts()));
        self.decode_ref_scratch(r, chunk_len, deltas, chunk_bytes, scratch.as_mut_slice())
    }

    /// Allocating wrapper over [`GroupPlan::decode_ref_scratch`].
    pub fn decode_ref<'a, D, F>(
        &self,
        r: usize,
        chunk_len: usize,
        deltas: &[D],
        chunk_bytes: F,
    ) -> Result<Vec<u8>>
    where
        D: AsRef<[u8]>,
        F: FnMut(usize) -> Result<&'a [u8]>,
    {
        if self.size() < 2 {
            return Err(CamrError::ShuffleDecode("group size must be >= 2".into()));
        }
        let mut scratch = vec![0u8; packet::packet_len(chunk_len, self.parts())];
        self.decode_ref_scratch(r, chunk_len, deltas, chunk_bytes, &mut scratch)
    }

    /// Owned-payload convenience wrapper over [`GroupPlan::decode_ref`].
    pub fn decode<F>(
        &self,
        r: usize,
        chunk_len: usize,
        deltas: &[Vec<u8>],
        mut chunk_bytes: F,
    ) -> Result<Vec<u8>>
    where
        F: FnMut(usize) -> Result<Vec<u8>>,
    {
        let g = self.size();
        let chunks: Vec<Option<Vec<u8>>> = (0..g)
            .map(|p| if p == r { Ok(None) } else { chunk_bytes(p).map(Some) })
            .collect::<Result<_>>()?;
        self.decode_ref(r, chunk_len, deltas, |p| {
            chunks[p]
                .as_deref()
                .ok_or_else(|| CamrError::ShuffleDecode(format!("chunk {p} unavailable")))
        })
    }

    /// Bytes put on the link by this group's exchange:
    /// `g · ⌈B/(g-1)⌉` (Lemma 2's `B·g/(g-1)` plus padding).
    pub fn link_bytes(&self, chunk_len: usize) -> usize {
        self.size() * packet::packet_len(chunk_len, self.parts())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a synthetic group where chunk p's payload is a deterministic
    /// pattern, run the full encode/decode exchange, and check every
    /// member recovers its chunk byte-exactly.
    fn run_exchange(g: usize, chunk_len: usize) {
        let members: Vec<ServerId> = (0..g).collect();
        let chunks: Vec<ChunkSpec> = (0..g)
            .map(|p| ChunkSpec { receiver: p, job: p, func: p, batch: p })
            .collect();
        let plan = GroupPlan { members, chunks };
        let payload = |p: usize| -> Vec<u8> {
            (0..chunk_len).map(|i| (p as u8).wrapping_mul(31).wrapping_add(i as u8)).collect()
        };
        // Every member broadcasts.
        let deltas: Vec<Vec<u8>> = (0..g)
            .map(|t| plan.encode(t, chunk_len, |p| Ok(payload(p))).unwrap())
            .collect();
        // Every member decodes its missing chunk.
        for r in 0..g {
            let got = plan.decode(r, chunk_len, &deltas, |p| Ok(payload(p))).unwrap();
            assert_eq!(got, payload(r), "member {r} failed to decode (g={g}, B={chunk_len})");
        }
        // Lemma 2's cost: g packets of ⌈B/(g-1)⌉ bytes.
        let total: usize = deltas.iter().map(|d| d.len()).sum();
        assert_eq!(total, plan.link_bytes(chunk_len));
        assert_eq!(total, g * chunk_len.div_ceil(g - 1));
    }

    #[test]
    fn lemma2_exchange_small_groups() {
        for g in 2..=6 {
            for chunk_len in [1usize, 2, 7, 8, 64, 65] {
                run_exchange(g, chunk_len);
            }
        }
    }

    #[test]
    fn lemma2_cost_matches_closed_form_when_divisible() {
        // When (g-1) | B the measured cost is exactly B·g/(g-1).
        let g = 4;
        let b = 99; // 3 | 99
        let members: Vec<ServerId> = (0..g).collect();
        let chunks: Vec<ChunkSpec> =
            (0..g).map(|p| ChunkSpec { receiver: p, job: 0, func: p, batch: p }).collect();
        let plan = GroupPlan { members, chunks };
        assert_eq!(plan.link_bytes(b), b * g / (g - 1));
    }

    #[test]
    fn packet_index_is_position_in_others() {
        let plan = GroupPlan {
            members: vec![10, 20, 30, 40],
            chunks: (0..4).map(|p| ChunkSpec { receiver: p, job: 0, func: p, batch: 0 }).collect(),
        };
        assert_eq!(plan.others(1), vec![10, 30, 40]);
        assert_eq!(plan.packet_index(1, 0), 0);
        assert_eq!(plan.packet_index(1, 2), 1);
        assert_eq!(plan.packet_index(1, 3), 2);
    }

    #[test]
    fn encode_rejects_wrong_chunk_length() {
        let plan = GroupPlan {
            members: vec![0, 1, 2],
            chunks: (0..3).map(|p| ChunkSpec { receiver: p, job: 0, func: p, batch: 0 }).collect(),
        };
        let err = plan.encode(0, 8, |_| Ok(vec![0u8; 4]));
        assert!(err.is_err());
    }

    #[test]
    fn decode_rejects_wrong_delta_count() {
        let plan = GroupPlan {
            members: vec![0, 1, 2],
            chunks: (0..3).map(|p| ChunkSpec { receiver: p, job: 0, func: p, batch: 0 }).collect(),
        };
        let err = plan.decode(0, 8, &[vec![0u8; 4]], |_| Ok(vec![0u8; 8]));
        assert!(err.is_err());
    }

    #[test]
    fn group_of_two_degenerates_to_swap() {
        // g = 2: each Δ is the full opposite chunk (k-1 = 1 packet).
        let plan = GroupPlan {
            members: vec![7, 9],
            chunks: (0..2).map(|p| ChunkSpec { receiver: p, job: 0, func: p, batch: 0 }).collect(),
        };
        let c0 = vec![1u8, 2, 3];
        let c1 = vec![9u8, 8, 7];
        let chunk = |p: usize| if p == 0 { Ok(c0.clone()) } else { Ok(c1.clone()) };
        let d0 = plan.encode(0, 3, chunk).unwrap(); // member 0 sends chunk 1
        let d1 = plan.encode(1, 3, chunk).unwrap(); // member 1 sends chunk 0
        assert_eq!(d0, c1);
        assert_eq!(d1, c0);
        let deltas = vec![d0, d1];
        assert_eq!(plan.decode(0, 3, &deltas, chunk).unwrap(), c0);
        assert_eq!(plan.decode(1, 3, &deltas, chunk).unwrap(), c1);
    }
}
