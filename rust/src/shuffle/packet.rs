//! Packetization and XOR primitives for Algorithm 2.
//!
//! A chunk of `B` bytes is split into `p` packets of `⌈B/p⌉` bytes each
//! (zero-padded). The padding overhead is measured, not hidden: the
//! engine's byte accounting charges the padded packet size, and the
//! integration tests assert the measured load matches the closed form
//! exactly whenever `p | B` (and is within the padding bound otherwise).

use crate::error::{CamrError, Result};

/// Packet length for a chunk of `chunk_len` bytes split `parts` ways.
pub fn packet_len(chunk_len: usize, parts: usize) -> usize {
    debug_assert!(parts >= 1);
    chunk_len.div_ceil(parts)
}

/// Split `chunk` into exactly `parts` packets of equal (padded) length.
pub fn split(chunk: &[u8], parts: usize) -> Vec<Vec<u8>> {
    let plen = packet_len(chunk.len(), parts);
    (0..parts)
        .map(|i| {
            let start = (i * plen).min(chunk.len());
            let end = ((i + 1) * plen).min(chunk.len());
            let mut p = chunk[start..end].to_vec();
            p.resize(plen, 0u8);
            p
        })
        .collect()
}

/// Reassemble packets into a chunk of `chunk_len` bytes (drop padding).
pub fn join(packets: &[Vec<u8>], chunk_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(chunk_len);
    for p in packets {
        out.extend_from_slice(p);
    }
    if out.len() < chunk_len {
        return Err(CamrError::ShuffleDecode(format!(
            "joined packets give {} bytes, need {chunk_len}",
            out.len()
        )));
    }
    out.truncate(chunk_len);
    Ok(out)
}

/// XOR `src` into `dst` in place. Lengths must match.
///
/// Re-exported from [`super::buf`] (u64 lanes + byte tail) so existing
/// callers keep one canonical hot-path implementation.
pub use super::buf::xor_into;

/// XOR a set of equal-length slices together (returns zeroes when empty
/// and `len` is provided via the first slice — callers pass ≥1 slice).
pub fn xor_all(slices: &[&[u8]]) -> Result<Vec<u8>> {
    let first = slices
        .first()
        .ok_or_else(|| CamrError::ShuffleDecode("xor_all needs >= 1 slice".into()))?;
    let mut acc = first.to_vec();
    super::buf::xor_fold(&mut acc, &slices[1..])?;
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_join_roundtrip_exact() {
        let chunk: Vec<u8> = (0..12u8).collect();
        let packets = split(&chunk, 3);
        assert_eq!(packets.len(), 3);
        assert!(packets.iter().all(|p| p.len() == 4));
        assert_eq!(join(&packets, 12).unwrap(), chunk);
    }

    #[test]
    fn split_join_roundtrip_padded() {
        let chunk: Vec<u8> = (0..10u8).collect();
        let packets = split(&chunk, 3); // ⌈10/3⌉ = 4 bytes each
        assert!(packets.iter().all(|p| p.len() == 4));
        assert_eq!(join(&packets, 10).unwrap(), chunk);
    }

    #[test]
    fn split_single_part_is_whole_chunk() {
        let chunk = vec![9u8, 8, 7];
        let packets = split(&chunk, 1);
        assert_eq!(packets, vec![chunk.clone()]);
        assert_eq!(join(&packets, 3).unwrap(), chunk);
    }

    #[test]
    fn split_more_parts_than_bytes() {
        let chunk = vec![1u8, 2];
        let packets = split(&chunk, 4); // plen = 1, trailing packets all padding
        assert_eq!(packets.len(), 4);
        assert!(packets.iter().all(|p| p.len() == 1));
        assert_eq!(join(&packets, 2).unwrap(), chunk);
    }

    #[test]
    fn xor_roundtrip() {
        let a: Vec<u8> = (0..33u8).collect(); // odd length exercises tail loop
        let b: Vec<u8> = (100..133u8).collect();
        let mut x = a.clone();
        xor_into(&mut x, &b).unwrap();
        xor_into(&mut x, &b).unwrap();
        assert_eq!(x, a);
    }

    #[test]
    fn xor_all_matches_manual() {
        let a = vec![0b1010u8];
        let b = vec![0b0110u8];
        let c = vec![0b0001u8];
        let x = xor_all(&[&a, &b, &c]).unwrap();
        assert_eq!(x, vec![0b1101u8]);
    }

    #[test]
    fn xor_length_mismatch_errors() {
        let mut a = vec![0u8; 4];
        assert!(xor_into(&mut a, &[0u8; 5]).is_err());
        assert!(xor_all(&[]).is_err());
    }

    #[test]
    fn packet_len_divides_and_rounds() {
        assert_eq!(packet_len(12, 3), 4);
        assert_eq!(packet_len(10, 3), 4);
        assert_eq!(packet_len(1, 4), 1);
    }
}
