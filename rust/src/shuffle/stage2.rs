//! Stage 2 (paper §III-C.2): transversal groups — one block per parallel
//! class with empty intersection — exchange batch aggregates of jobs the
//! excluded member does *not* own.
//!
//! For group `G` and member `U_{k'}` at class `i`, the subset
//! `P = G \ {U_{k'}}` jointly owns a unique job `j` (SPC parity pins it
//! down); the remaining owner `U_l` of `j` lies in class `i` too, and `P`
//! shares the batch labeled `U_l`. Every server of `P` can therefore
//! compute `β^{(j)}_{[k']}` — the receiver's-function aggregate over that
//! batch (Eq. (4)) — and Algorithm 2 delivers it.
//!
//! There are `q^{k-1}(q-1)` groups; load `(q-1)·k/(K(k-1))` (§IV).

use super::multicast::GroupPlan;
use super::plan::ChunkSpec;
use crate::config::SystemConfig;
use crate::design::ResolvableDesign;
use crate::error::Result;
use crate::placement::Placement;

/// Build all stage-2 group plans (one per transversal group per round).
pub fn plan(
    cfg: &SystemConfig,
    design: &ResolvableDesign,
    placement: &Placement,
) -> Result<Vec<GroupPlan>> {
    let transversals = design.transversal_groups();
    let mut groups = Vec::with_capacity(transversals.len() * cfg.rounds);
    for round in 0..cfg.rounds {
        for members in &transversals {
            let chunks: Vec<ChunkSpec> = (0..cfg.k)
                .map(|i| {
                    let (job, remaining_owner) = design.stage2_target(members, i);
                    let batch = placement
                        .missing_batch(job, remaining_owner)
                        .expect("remaining owner misses exactly one batch");
                    ChunkSpec {
                        receiver: members[i],
                        job,
                        func: round * cfg.servers() + members[i],
                        batch,
                    }
                })
                .collect();
            groups.push(GroupPlan { members: members.clone(), chunks });
        }
    }
    Ok(groups)
}

/// Expected bytes on the link for stage 2 (with padding).
pub fn expected_bytes(cfg: &SystemConfig) -> usize {
    let parts = cfg.k - 1;
    let num_groups = cfg.jobs() * (cfg.q - 1); // q^{k-1}(q-1)
    cfg.rounds * num_groups * cfg.k * cfg.value_bytes.div_ceil(parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::ResolvableDesign;

    fn setup(k: usize, q: usize, g: usize) -> (SystemConfig, ResolvableDesign, Placement) {
        let cfg = SystemConfig::new(k, q, g).unwrap();
        let d = ResolvableDesign::new(k, q).unwrap();
        let p = Placement::new(&d, &cfg).unwrap();
        (cfg, d, p)
    }

    #[test]
    fn group_count_is_qk1_qm1() {
        for (k, q) in [(3, 2), (3, 3), (4, 2), (2, 4)] {
            let (cfg, d, p) = setup(k, q, 1);
            let groups = plan(&cfg, &d, &p).unwrap();
            assert_eq!(groups.len(), q.pow(k as u32 - 1) * (q - 1));
        }
    }

    #[test]
    fn table1_group_u1_u3_u6() {
        // Paper Table I: group {U1, U3, U6} = servers {0, 2, 5}.
        //  U1 recovers α(ν^{(3)}_{1,5}, ν^{(3)}_{1,6}) → job 2 (0-based),
        //    batch {5,6} = batch 2, func 0.
        //  U3 recovers α(ν^{(2)}_{3,1}, ν^{(2)}_{3,2}) → job 1, batch 0,
        //    func 2.
        //  U6 recovers α(ν^{(1)}_{6,3}, ν^{(1)}_{6,4}) → job 0, batch 1,
        //    func 5.
        let (cfg, d, p) = setup(3, 2, 2);
        let groups = plan(&cfg, &d, &p).unwrap();
        let g = groups
            .iter()
            .find(|g| g.members == vec![0, 2, 5])
            .expect("group {U1,U3,U6} must exist");
        assert_eq!(g.chunks[0], ChunkSpec { receiver: 0, job: 2, func: 0, batch: 2 });
        assert_eq!(g.chunks[1], ChunkSpec { receiver: 2, job: 1, func: 2, batch: 0 });
        assert_eq!(g.chunks[2], ChunkSpec { receiver: 5, job: 0, func: 5, batch: 1 });
    }

    #[test]
    fn receivers_do_not_own_their_chunk_jobs() {
        for (k, q) in [(3, 2), (3, 3), (4, 2)] {
            let (cfg, d, p) = setup(k, q, 1);
            for g in plan(&cfg, &d, &p).unwrap() {
                for c in &g.chunks {
                    assert!(!p.owns(c.receiver, c.job), "receiver owns its stage-2 job");
                    let _ = d;
                }
            }
        }
    }

    #[test]
    fn senders_store_every_chunk_they_encode() {
        for (k, q) in [(3, 2), (3, 3), (4, 2)] {
            let (cfg, d, p) = setup(k, q, 2);
            for g in plan(&cfg, &d, &p).unwrap() {
                for (pos, &m) in g.members.iter().enumerate() {
                    for (cpos, c) in g.chunks.iter().enumerate() {
                        if cpos != pos {
                            assert!(
                                p.stores_batch(m, c.job, c.batch),
                                "k={k} q={q}: member {m} cannot encode chunk {c:?}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn covers_every_nonowner_job_batch_once() {
        // Across all groups, each (server, non-owned job) appears exactly
        // once as a receiver — and the delivered batch is the one whose
        // label (the remaining owner) lies in the receiver's class.
        let (cfg, d, p) = setup(3, 3, 1);
        let mut seen = std::collections::HashSet::new();
        for g in plan(&cfg, &d, &p).unwrap() {
            for c in &g.chunks {
                assert!(seen.insert((c.receiver, c.job)), "duplicate {c:?}");
                let label = p.batch_label(c.job, c.batch);
                assert_eq!(d.class_of(label), d.class_of(c.receiver));
            }
        }
        let expect = cfg.servers() * (cfg.jobs() - cfg.jobs() / cfg.q);
        assert_eq!(seen.len(), expect);
    }

    #[test]
    fn example_load_is_one_quarter() {
        // Paper: L_stage2 = 4 groups × 3 × B/2 = 6B → 6B/24B = 1/4.
        let (cfg, _, _) = setup(3, 2, 2);
        assert_eq!(expected_bytes(&cfg), 6 * cfg.value_bytes);
    }
}
