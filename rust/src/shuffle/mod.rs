//! The CAMR shuffle (paper §III-C): Algorithm 2 coded multicast plus the
//! three stage planners, running on a pooled, zero-copy data plane.
//!
//! - [`buf`] — the reusable buffer arena ([`buf::BufferPool`], with a
//!   large size class for streamed chunks) and the runtime-dispatched
//!   XOR kernel stack ([`buf::xor_into`], [`buf::xor_fold`]; AVX2/NEON
//!   when the CPU has them, portable u64 lanes everywhere) that make
//!   encode/decode allocation-free and SIMD-wide.
//! - [`packet`] — chunk ↔ packet splitting and XOR primitives.
//! - [`multicast`] — Algorithm 2: within a group of `g` machines where
//!   each misses exactly one chunk jointly stored by the others, `g`
//!   coded broadcasts of `B/(g-1)` bytes deliver every missing chunk
//!   (Lemma 2).
//! - [`plan`] — chunk / unicast descriptors shared by the stages.
//! - [`stage1`] — owners of each job exchange their missing batch
//!   aggregates.
//! - [`stage2`] — transversal groups deliver one batch aggregate of a
//!   non-owned job to each member.
//! - [`stage3`] — parallel-class unicasts deliver the remaining fused
//!   aggregate of every non-owned job.
//!
//! ## Pool lifecycle of one coded exchange
//!
//! Every `Δ` broadcast follows the same arc through the data plane:
//!
//! 1. **acquire** — the encoder checks a zeroed, word-aligned packet
//!    buffer out of the engine's [`buf::BufferPool`];
//! 2. **encode** — [`multicast::GroupPlan::encode_ref_into`] XORs the
//!    sender's locally stored chunks into it in place through the
//!    dispatched kernel ([`buf::active_kernel`]);
//! 3. **bus** — the shared link is charged with `Δ.len()` bytes exactly
//!    as before: pooling changes *where bytes live*, never how many are
//!    accounted, so the ledger stays byte-identical to the unpooled
//!    data plane (the golden-ledger test pins this down);
//! 4. **decode** — receivers borrow the same payload through cheap
//!    [`buf::SharedBuf`] clones (one buffer, `g-1` readers) and cancel
//!    known packets against a pooled scratch buffer;
//! 5. **release** — when the last reference drops, the backing store
//!    returns to the pool, ready for the next group. Release rides on
//!    `Drop`, so a buffer can never be returned twice — even on worker
//!    failure (asserted by the failure-injection tests).

pub mod buf;
pub mod multicast;
pub mod packet;
pub mod plan;
pub mod stage1;
pub mod stage2;
pub mod stage3;

pub use buf::{BufferPool, SharedBuf, XorKernel};
pub use multicast::GroupPlan;
pub use plan::{ChunkSpec, UnicastSpec};
