//! The CAMR shuffle (paper §III-C): Algorithm 2 coded multicast plus the
//! three stage planners.
//!
//! - [`packet`] — chunk ↔ packet splitting and XOR primitives.
//! - [`multicast`] — Algorithm 2: within a group of `g` machines where
//!   each misses exactly one chunk jointly stored by the others, `g`
//!   coded broadcasts of `B/(g-1)` bytes deliver every missing chunk
//!   (Lemma 2).
//! - [`plan`] — chunk / unicast descriptors shared by the stages.
//! - [`stage1`] — owners of each job exchange their missing batch
//!   aggregates.
//! - [`stage2`] — transversal groups deliver one batch aggregate of a
//!   non-owned job to each member.
//! - [`stage3`] — parallel-class unicasts deliver the remaining fused
//!   aggregate of every non-owned job.

pub mod multicast;
pub mod packet;
pub mod plan;
pub mod stage1;
pub mod stage2;
pub mod stage3;

pub use multicast::GroupPlan;
pub use plan::{ChunkSpec, UnicastSpec};
