//! Stage 1 (paper §III-C.1): for each job, its `k` owners exchange their
//! missing batch aggregates via Algorithm 2.
//!
//! For job `j` with owners `X^{(j)}`, owner `U_{k'}` misses exactly the
//! batch labeled with itself; the other `k-1` owners all store that batch
//! and can compute the aggregate `α^{(j)}_{[k']}` of the receiver's own
//! function over it. One Lemma-2 group per (job, round).
//!
//! Load: `J·k·⌈B/(k-1)⌉` bytes per round → `k / (K(k-1))` (paper §IV).

use super::multicast::GroupPlan;
use super::plan::ChunkSpec;
use crate::config::SystemConfig;
use crate::error::Result;
use crate::placement::Placement;

/// Build all stage-1 group plans (one per job per round).
pub fn plan(cfg: &SystemConfig, placement: &Placement) -> Result<Vec<GroupPlan>> {
    let mut groups = Vec::with_capacity(cfg.jobs() * cfg.rounds);
    for round in 0..cfg.rounds {
        for j in 0..cfg.jobs() {
            let members = placement.owners(j).to_vec();
            let chunks: Vec<ChunkSpec> = members
                .iter()
                .map(|&owner| {
                    let batch = placement
                        .missing_batch(j, owner)
                        .expect("owner always has a missing batch");
                    ChunkSpec {
                        receiver: owner,
                        job: j,
                        func: round * cfg.servers() + owner,
                        batch,
                    }
                })
                .collect();
            groups.push(GroupPlan { members, chunks });
        }
    }
    Ok(groups)
}

/// Expected bytes on the link for stage 1 (with padding).
pub fn expected_bytes(cfg: &SystemConfig) -> usize {
    let parts = cfg.k - 1;
    cfg.rounds * cfg.jobs() * cfg.k * cfg.value_bytes.div_ceil(parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::ResolvableDesign;

    fn setup(k: usize, q: usize, g: usize) -> (SystemConfig, Placement) {
        let cfg = SystemConfig::new(k, q, g).unwrap();
        let d = ResolvableDesign::new(k, q).unwrap();
        let p = Placement::new(&d, &cfg).unwrap();
        (cfg, p)
    }

    #[test]
    fn one_group_per_job() {
        let (cfg, p) = setup(3, 2, 2);
        let groups = plan(&cfg, &p).unwrap();
        assert_eq!(groups.len(), 4);
        for (j, g) in groups.iter().enumerate() {
            assert_eq!(g.members, p.owners(j));
            assert_eq!(g.chunks.len(), 3);
        }
    }

    #[test]
    fn example3_chunks_for_job1() {
        // Paper Example 3: owners of J1 = {U1, U3, U5}; U1 needs the
        // φ_1 aggregate of batch {5,6} (batch 2), U3 of batch {1,2}
        // (batch 0), U5 of batch {3,4} (batch 1).
        let (cfg, p) = setup(3, 2, 2);
        let groups = plan(&cfg, &p).unwrap();
        let g0 = &groups[0];
        assert_eq!(g0.members, vec![0, 2, 4]);
        assert_eq!(g0.chunks[0], ChunkSpec { receiver: 0, job: 0, func: 0, batch: 2 });
        assert_eq!(g0.chunks[1], ChunkSpec { receiver: 2, job: 0, func: 2, batch: 0 });
        assert_eq!(g0.chunks[2], ChunkSpec { receiver: 4, job: 0, func: 4, batch: 1 });
    }

    #[test]
    fn senders_store_every_chunk_they_encode() {
        // Feasibility: each member must store every other member's chunk.
        for (k, q) in [(2, 3), (3, 2), (3, 3), (4, 2)] {
            let (cfg, p) = setup(k, q, 2);
            for g in plan(&cfg, &p).unwrap() {
                for (pos, &m) in g.members.iter().enumerate() {
                    for (cpos, c) in g.chunks.iter().enumerate() {
                        if cpos == pos {
                            assert!(!p.stores_batch(m, c.job, c.batch));
                        } else {
                            assert!(p.stores_batch(m, c.job, c.batch));
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn expected_bytes_matches_formula() {
        // Example 1: J·k·B/(k-1) = 4·3·B/2 = 6B (paper: 6B → L = 1/4).
        let (cfg, _) = setup(3, 2, 2);
        assert_eq!(expected_bytes(&cfg), 6 * cfg.value_bytes);
    }

    #[test]
    fn multi_round_duplicates_with_shifted_funcs() {
        let cfg = SystemConfig::with_options(3, 2, 2, 2, 64).unwrap();
        let d = ResolvableDesign::new(3, 2).unwrap();
        let p = Placement::new(&d, &cfg).unwrap();
        let groups = plan(&cfg, &p).unwrap();
        assert_eq!(groups.len(), 8);
        // Round 2 chunk funcs are shifted by K = 6.
        assert_eq!(groups[4].chunks[0].func, 6);
    }
}
