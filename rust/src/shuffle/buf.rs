//! Reusable buffer arena and word-wise XOR — the shuffle data plane's
//! allocation-free hot path (§Perf).
//!
//! ## Why a pool
//!
//! Algorithm 2 moves a lot of short-lived byte buffers: one coded `Δ`
//! per group member per group, one scratch packet per decode, every
//! round, every stage. Allocating a fresh `Vec<u8>` for each turns the
//! shuffle into an allocator benchmark; the measured CAMR-vs-baseline
//! wall-clock gap then reflects `malloc` behaviour instead of bytes on
//! the wire. [`BufferPool`] recycles the backing stores instead: a
//! buffer is acquired (zeroed), filled by the encoder, shared with every
//! decoder, and returned to the pool automatically when the last
//! reference drops.
//!
//! ## Pool lifecycle
//!
//! ```text
//! acquire (zeroed, word-aligned)
//!    → encode Δ in place (xor_into on u64 lanes)
//!    → charge bus with Δ.len()          (ledger bytes are unchanged)
//!    → share with decoders (SharedBuf: one payload, N readers)
//!    → decode cancels known packets (pooled scratch)
//!    → release on last drop (back to the free list, never twice)
//! ```
//!
//! Release is tied to `Drop`, so a buffer can never be returned twice —
//! [`BufferPool::stats`] exposes the acquire/release counters the
//! failure-injection tests use to prove it (released never exceeds
//! acquired, and everything outstanding returns even on error paths).
//!
//! ## Alignment
//!
//! Backing stores are `Vec<u64>`, so every buffer starts on an 8-byte
//! boundary and [`xor_into`] streams whole `u64` lanes with a byte tail.
//! The byte-wise reference implementation ([`xor_into_bytewise`]) is
//! kept for the property tests and the `xor_throughput` bench.

use crate::error::{CamrError, Result};
use std::sync::{Arc, Mutex};

/// XOR `src` into `dst` in place on `u64` lanes with a byte tail.
/// Lengths must match. This is the shuffle hot path.
pub fn xor_into(dst: &mut [u8], src: &[u8]) -> Result<()> {
    if dst.len() != src.len() {
        return Err(CamrError::ShuffleDecode(format!(
            "xor length mismatch: {} vs {}",
            dst.len(),
            src.len()
        )));
    }
    let n = dst.len();
    let words = n / 8;
    for i in 0..words {
        let o = i * 8;
        let a = u64::from_ne_bytes(dst[o..o + 8].try_into().unwrap());
        let b = u64::from_ne_bytes(src[o..o + 8].try_into().unwrap());
        dst[o..o + 8].copy_from_slice(&(a ^ b).to_ne_bytes());
    }
    for i in words * 8..n {
        dst[i] ^= src[i];
    }
    Ok(())
}

/// XOR every slice of `srcs` into `acc` in place (word-wise). All
/// lengths must equal `acc.len()`.
pub fn xor_fold(acc: &mut [u8], srcs: &[&[u8]]) -> Result<()> {
    for s in srcs {
        xor_into(acc, s)?;
    }
    Ok(())
}

/// Naive per-byte XOR — the reference the property tests check
/// [`xor_into`] against bit-for-bit, and the baseline the
/// `xor_throughput` bench beats.
pub fn xor_into_bytewise(dst: &mut [u8], src: &[u8]) -> Result<()> {
    if dst.len() != src.len() {
        return Err(CamrError::ShuffleDecode(format!(
            "xor length mismatch: {} vs {}",
            dst.len(),
            src.len()
        )));
    }
    for (d, s) in dst.iter_mut().zip(src) {
        *d ^= s;
    }
    Ok(())
}

/// Counters describing a pool's traffic (see [`BufferPool::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers handed out by [`BufferPool::acquire`].
    pub acquired: u64,
    /// Buffers returned to the free list (on drop — at most once each).
    pub released: u64,
    /// Acquisitions that had to allocate a fresh backing store.
    pub allocated: u64,
    /// Acquisitions served from the free list (allocation avoided).
    pub recycled: u64,
}

impl PoolStats {
    /// Buffers currently in flight (`acquired - released`).
    pub fn outstanding(&self) -> u64 {
        self.acquired - self.released
    }
}

#[derive(Debug, Default)]
struct PoolInner {
    free: Vec<Vec<u64>>,
    stats: PoolStats,
}

/// A thread-safe arena of recycled, 8-byte-aligned chunk buffers.
///
/// Clones share the same free list (cheap `Arc` clone), so the serial
/// engine, the parallel engine's worker threads, and tests can all
/// return buffers to one place. Buffers come back zeroed on acquire.
#[derive(Debug, Clone, Default)]
pub struct BufferPool {
    inner: Arc<Mutex<PoolInner>>,
}

impl BufferPool {
    /// New empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Acquire a zeroed buffer of `len` bytes (word-aligned backing).
    pub fn acquire(&self, len: usize) -> PooledBuf {
        self.acquire_inner(len, true)
    }

    /// Acquire a buffer of `len` bytes whose contents are *unspecified*
    /// (recycled bytes from an earlier checkout). For paths that fully
    /// overwrite the buffer before reading it — encode starts with
    /// `fill(0)`, decode scratch starts with `copy_from_slice` — this
    /// skips the redundant zeroing memset on the hot path.
    pub fn acquire_unzeroed(&self, len: usize) -> PooledBuf {
        self.acquire_inner(len, false)
    }

    fn acquire_inner(&self, len: usize, zero: bool) -> PooledBuf {
        let nwords = len.div_ceil(8);
        let mut words = {
            let mut inner = self.inner.lock().expect("buffer pool poisoned");
            inner.stats.acquired += 1;
            match inner.free.pop() {
                Some(w) => {
                    inner.stats.recycled += 1;
                    w
                }
                None => {
                    inner.stats.allocated += 1;
                    Vec::new()
                }
            }
        };
        // Resize outside the lock.
        if zero {
            // clear + resize rewrites every live word with zeros.
            words.clear();
            words.resize(nwords, 0u64);
        } else if words.len() < nwords {
            words.resize(nwords, 0u64);
        } else {
            // truncate never touches the retained (stale) words.
            words.truncate(nwords);
        }
        PooledBuf { words, len, pool: Arc::clone(&self.inner) }
    }

    /// Snapshot of the pool's counters.
    pub fn stats(&self) -> PoolStats {
        self.inner.lock().expect("buffer pool poisoned").stats
    }

    /// Buffers currently sitting on the free list.
    pub fn free_buffers(&self) -> usize {
        self.inner.lock().expect("buffer pool poisoned").free.len()
    }
}

/// A buffer checked out of a [`BufferPool`]. Returns its backing store
/// to the pool exactly once, on drop.
#[derive(Debug)]
pub struct PooledBuf {
    words: Vec<u64>,
    len: usize,
    pool: Arc<Mutex<PoolInner>>,
}

impl PooledBuf {
    /// Length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the buffer holds zero bytes.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Borrow the bytes.
    pub fn as_slice(&self) -> &[u8] {
        // SAFETY: `words` owns at least `len.div_ceil(8)` u64s, so bytes
        // `[0, len)` lie inside the allocation; u8 has no alignment or
        // validity requirements, and the borrow is tied to `&self`.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr().cast::<u8>(), self.len) }
    }

    /// Borrow the bytes mutably.
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        // SAFETY: as in `as_slice`, plus `&mut self` guarantees
        // exclusive access to the backing store.
        unsafe { std::slice::from_raw_parts_mut(self.words.as_mut_ptr().cast::<u8>(), self.len) }
    }
}

impl AsRef<[u8]> for PooledBuf {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        let words = std::mem::take(&mut self.words);
        let mut inner = self.pool.lock().expect("buffer pool poisoned");
        inner.stats.released += 1;
        inner.free.push(words);
    }
}

#[derive(Debug)]
enum Backing {
    Pooled(PooledBuf),
    Heap(Vec<u8>),
}

/// An immutable, cheaply clonable view of an encoded payload: one
/// buffer, any number of readers. The parallel engine ships one
/// `SharedBuf` to every group member instead of cloning the `Δ` bytes
/// per recipient; the pooled backing returns to its pool when the last
/// clone drops.
#[derive(Debug, Clone)]
pub struct SharedBuf {
    inner: Arc<Backing>,
}

impl SharedBuf {
    /// Length in bytes.
    pub fn len(&self) -> usize {
        self.as_ref().len()
    }

    /// True when the payload holds zero bytes.
    pub fn is_empty(&self) -> bool {
        self.as_ref().is_empty()
    }

    /// Stream the payload into `w` straight from the (pooled) backing
    /// store — the socket transport's zero-copy serialize path: an
    /// encoded `Δ` goes from the pool buffer onto the wire without an
    /// intermediate `Vec`.
    pub fn write_to<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        w.write_all(self.as_ref())
    }
}

impl AsRef<[u8]> for SharedBuf {
    fn as_ref(&self) -> &[u8] {
        match &*self.inner {
            Backing::Pooled(b) => b.as_slice(),
            Backing::Heap(v) => v.as_slice(),
        }
    }
}

impl From<PooledBuf> for SharedBuf {
    fn from(b: PooledBuf) -> Self {
        SharedBuf { inner: Arc::new(Backing::Pooled(b)) }
    }
}

impl From<Vec<u8>> for SharedBuf {
    fn from(v: Vec<u8>) -> Self {
        SharedBuf { inner: Arc::new(Backing::Heap(v)) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xor_wordwise_matches_bytewise() {
        for len in [0usize, 1, 7, 8, 9, 15, 16, 17, 63, 64, 65, 255] {
            let a: Vec<u8> = (0..len).map(|i| (i * 37 + 11) as u8).collect();
            let b: Vec<u8> = (0..len).map(|i| (i * 101 + 5) as u8).collect();
            let mut word = a.clone();
            let mut byte = a.clone();
            xor_into(&mut word, &b).unwrap();
            xor_into_bytewise(&mut byte, &b).unwrap();
            assert_eq!(word, byte, "len={len}");
        }
    }

    #[test]
    fn xor_fold_matches_sequential() {
        let a: Vec<u8> = (0..33).map(|i| i as u8).collect();
        let b: Vec<u8> = (0..33).map(|i| (i * 3) as u8).collect();
        let c: Vec<u8> = (0..33).map(|i| (i * 7 + 1) as u8).collect();
        let mut folded = vec![0u8; 33];
        xor_fold(&mut folded, &[&a, &b, &c]).unwrap();
        let mut seq = vec![0u8; 33];
        xor_into(&mut seq, &a).unwrap();
        xor_into(&mut seq, &b).unwrap();
        xor_into(&mut seq, &c).unwrap();
        assert_eq!(folded, seq);
    }

    #[test]
    fn xor_length_mismatch_errors() {
        let mut d = vec![0u8; 4];
        assert!(xor_into(&mut d, &[0u8; 5]).is_err());
        assert!(xor_into_bytewise(&mut d, &[0u8; 5]).is_err());
        assert!(xor_fold(&mut d, &[&[0u8; 4], &[0u8; 3]]).is_err());
    }

    #[test]
    fn acquire_is_zeroed_and_recycles() {
        let pool = BufferPool::new();
        {
            let mut b = pool.acquire(24);
            b.as_mut_slice().fill(0xAB);
        }
        // Same backing store comes back, zeroed.
        let b = pool.acquire(24);
        assert_eq!(b.len(), 24);
        assert!(b.as_slice().iter().all(|&x| x == 0));
        let stats = pool.stats();
        assert_eq!(stats.acquired, 2);
        assert_eq!(stats.allocated, 1);
        assert_eq!(stats.recycled, 1);
        assert_eq!(stats.outstanding(), 1);
        drop(b);
        assert_eq!(pool.stats().outstanding(), 0);
        assert_eq!(pool.free_buffers(), 1);
    }

    #[test]
    fn acquire_unzeroed_recycles_without_rezeroing_guarantee() {
        let pool = BufferPool::new();
        {
            let mut b = pool.acquire(16);
            b.as_mut_slice().fill(0xCD);
        }
        // Unzeroed acquire: correct length, contents unspecified — but
        // fully writable, and the pool accounting is identical.
        let mut b = pool.acquire_unzeroed(16);
        assert_eq!(b.len(), 16);
        b.as_mut_slice().copy_from_slice(&[1u8; 16]);
        assert_eq!(b.as_slice(), &[1u8; 16]);
        drop(b);
        // Growth beyond the recycled capacity still yields valid bytes.
        let b = pool.acquire_unzeroed(64);
        assert_eq!(b.len(), 64);
        let stats = pool.stats();
        assert_eq!(stats.acquired, 3);
        assert_eq!(stats.recycled, 2);
    }

    #[test]
    fn zero_length_buffers_work() {
        let pool = BufferPool::new();
        let b = pool.acquire(0);
        assert!(b.is_empty());
        assert_eq!(b.as_slice(), &[] as &[u8]);
    }

    #[test]
    fn odd_lengths_get_word_padding() {
        let pool = BufferPool::new();
        for len in [1usize, 7, 9, 13] {
            let mut b = pool.acquire(len);
            assert_eq!(b.len(), len);
            assert_eq!(b.as_mut_slice().len(), len);
            b.as_mut_slice().fill(0xFF);
        }
        assert_eq!(pool.stats().released, 4);
    }

    #[test]
    fn shared_buf_single_payload_many_readers() {
        let pool = BufferPool::new();
        let mut b = pool.acquire(16);
        b.as_mut_slice().copy_from_slice(&[7u8; 16]);
        let shared: SharedBuf = b.into();
        let clones: Vec<SharedBuf> = (0..5).map(|_| shared.clone()).collect();
        for c in &clones {
            assert_eq!(c.as_ref(), &[7u8; 16]);
            assert_eq!(c.len(), 16);
        }
        // Backing stays checked out until the last clone drops.
        assert_eq!(pool.stats().outstanding(), 1);
        drop(shared);
        drop(clones);
        assert_eq!(pool.stats().outstanding(), 0);
    }

    #[test]
    fn heap_backed_shared_buf() {
        let s: SharedBuf = vec![1u8, 2, 3].into();
        assert_eq!(s.as_ref(), &[1, 2, 3]);
        assert!(!s.is_empty());
    }

    #[test]
    fn write_to_streams_exact_bytes_from_any_backing() {
        let pool = BufferPool::new();
        let mut b = pool.acquire(13);
        for (i, x) in b.as_mut_slice().iter_mut().enumerate() {
            *x = i as u8;
        }
        let pooled: SharedBuf = b.into();
        let mut sink = Vec::new();
        pooled.write_to(&mut sink).unwrap();
        assert_eq!(sink, (0..13u8).collect::<Vec<_>>());
        let heap: SharedBuf = vec![9u8, 8, 7].into();
        heap.write_to(&mut sink).unwrap();
        assert_eq!(&sink[13..], &[9, 8, 7]);
    }

    #[test]
    fn pool_is_thread_safe() {
        let pool = BufferPool::new();
        std::thread::scope(|s| {
            for t in 0..8u8 {
                let pool = pool.clone();
                s.spawn(move || {
                    for i in 0..50usize {
                        let mut b = pool.acquire(i % 67 + 1);
                        assert!(b.as_slice().iter().all(|&x| x == 0));
                        b.as_mut_slice().fill(t);
                    }
                });
            }
        });
        let stats = pool.stats();
        assert_eq!(stats.acquired, 400);
        assert_eq!(stats.released, 400);
        assert_eq!(stats.outstanding(), 0);
    }
}
