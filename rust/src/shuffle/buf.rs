//! Reusable buffer arena and the runtime-dispatched XOR kernel stack —
//! the shuffle data plane's allocation-free hot path (§Perf).
//!
//! ## The kernel stack
//!
//! Every coded `Δ` in Algorithm 2 is built and cancelled with XOR, so
//! the per-byte XOR cost is the constant factor that decides whether
//! the paper's load gains survive at scale. [`xor_into`] therefore
//! dispatches, once per process, to the widest kernel the hardware
//! offers:
//!
//! | tier | kernel | where |
//! |------|--------|-------|
//! | [`XorKernel::Avx2`] | `_mm256_xor_si256`, 4×32 B unrolled | x86/x86_64, runtime-detected |
//! | [`XorKernel::Neon`] | `veorq_u8`, 4×16 B unrolled | aarch64, runtime-detected |
//! | [`XorKernel::PortableU64`] | safe `u64` words + byte tail | everywhere (the forced tier) |
//! | [`XorKernel::Bytewise`] | one byte at a time | correctness oracle only |
//!
//! Detection runs exactly once (cached in an atomic); every
//! [`xor_into`]/[`xor_fold`] call after that is a load + indirect
//! branch. The SIMD tiers use unaligned loads, because the encode path
//! XORs packets sliced at arbitrary `idx·plen` offsets out of chunk
//! buffers — alignment is never assumed, only [`BufferPool`]'s 8-byte
//! backing guarantee. [`xor_into_bytewise`] is kept verbatim as the
//! oracle the differential tests check every tier against bit-for-bit,
//! and [`xor_into_with`] lets tests and benches target one tier
//! explicitly. Setting `CAMR_FORCE_PORTABLE=1` before the first XOR
//! pins the dispatch to the portable tier (CI runs the whole suite that
//! way so runners without AVX2 stay covered).
//!
//! ## Why a pool
//!
//! Algorithm 2 moves a lot of short-lived byte buffers: one coded `Δ`
//! per group member per group, one scratch packet per decode, every
//! round, every stage. Allocating a fresh `Vec<u8>` for each turns the
//! shuffle into an allocator benchmark; the measured CAMR-vs-baseline
//! wall-clock gap then reflects `malloc` behaviour instead of bytes on
//! the wire. [`BufferPool`] recycles the backing stores instead: a
//! buffer is acquired (zeroed), filled by the encoder, shared with every
//! decoder, and returned to the pool automatically when the last
//! reference drops.
//!
//! ## Pool lifecycle
//!
//! ```text
//! acquire (zeroed, word-aligned)
//!    → encode Δ in place (dispatched XOR kernel)
//!    → charge bus with Δ.len()          (ledger bytes are unchanged)
//!    → share with decoders (SharedBuf: one payload, N readers)
//!    → decode cancels known packets (pooled scratch)
//!    → release on last drop (back to the free list, never twice)
//! ```
//!
//! Release is tied to `Drop`, so a buffer can never be returned twice —
//! [`BufferPool::stats`] exposes the acquire/release counters the
//! failure-injection tests use to prove it (released never exceeds
//! acquired, and everything outstanding returns even on error paths).
//!
//! ## Size classes: small Δs vs streamed chunks
//!
//! The streaming workloads (`workload::stream`) checkout chunks in the
//! hundreds-of-MB regime through the same pool that recycles 64-byte Δ
//! packets. One undifferentiated free list would let a 256 MiB backing
//! get pinned under a 64 B checkout forever (or shrink-grow-thrash).
//! Buffers at or above [`LARGE_CLASS_BYTES`] therefore recycle through
//! a separate large-class list: acquired first-fit by capacity, and
//! retained at most [`LARGE_RETAIN`] deep — releases beyond that free
//! their memory immediately (counted in [`PoolStats::dropped`]), so a
//! streaming run's high-water mark is bounded by its concurrency, not
//! its history.
//!
//! ## Alignment
//!
//! Backing stores are `Vec<u64>`, so every pooled buffer starts on an
//! 8-byte boundary. The kernels do not require it (unaligned loads),
//! but word-aligned starts keep the portable tier on its fast path.

use crate::error::{CamrError, Result};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

// ---------------------------------------------------------------------------
// XOR kernel stack
// ---------------------------------------------------------------------------

/// One tier of the XOR kernel stack (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum XorKernel {
    /// Per-byte reference — the correctness oracle.
    Bytewise,
    /// Safe portable `u64`-lane path with a byte tail.
    PortableU64,
    /// 256-bit AVX2 path (x86/x86_64, runtime-detected).
    Avx2,
    /// 128-bit NEON path (aarch64, runtime-detected).
    Neon,
}

impl XorKernel {
    /// Stable label used in bench reports and logs.
    pub fn label(self) -> &'static str {
        match self {
            XorKernel::Bytewise => "bytewise",
            XorKernel::PortableU64 => "portable_u64",
            XorKernel::Avx2 => "avx2",
            XorKernel::Neon => "neon",
        }
    }
}

/// Cached dispatch decision: 0 = undecided, else `kernel_code`.
static ACTIVE_KERNEL: AtomicU8 = AtomicU8::new(0);

fn kernel_code(k: XorKernel) -> u8 {
    match k {
        XorKernel::Bytewise => 1,
        XorKernel::PortableU64 => 2,
        XorKernel::Avx2 => 3,
        XorKernel::Neon => 4,
    }
}

/// Pick the widest kernel the hardware offers (or the portable tier
/// when forced). Pure function of the CPU + the flag, so tests can
/// exercise the override without touching process environment.
fn choose_kernel(force_portable: bool) -> XorKernel {
    if force_portable {
        return XorKernel::PortableU64;
    }
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    if is_x86_feature_detected!("avx2") {
        return XorKernel::Avx2;
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        return XorKernel::Neon;
    }
    XorKernel::PortableU64
}

/// The kernel [`xor_into`] dispatches to, deciding (and caching) it on
/// first use. Honors `CAMR_FORCE_PORTABLE=1` (any value other than
/// empty or `0`) read at decision time.
pub fn active_kernel() -> XorKernel {
    match ACTIVE_KERNEL.load(Ordering::Relaxed) {
        1 => XorKernel::Bytewise,
        2 => XorKernel::PortableU64,
        3 => XorKernel::Avx2,
        4 => XorKernel::Neon,
        _ => {
            let force = match std::env::var_os("CAMR_FORCE_PORTABLE") {
                Some(v) => !v.is_empty() && v != "0",
                None => false,
            };
            let k = choose_kernel(force);
            ACTIVE_KERNEL.store(kernel_code(k), Ordering::Relaxed);
            k
        }
    }
}

/// Every kernel the current CPU can execute, oracle first. Benches
/// iterate this to produce one throughput row per tier.
pub fn available_kernels() -> Vec<XorKernel> {
    let mut ks = Vec::with_capacity(4);
    ks.push(XorKernel::Bytewise);
    ks.push(XorKernel::PortableU64);
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    if is_x86_feature_detected!("avx2") {
        ks.push(XorKernel::Avx2);
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        ks.push(XorKernel::Neon);
    }
    ks
}

fn check_len(dst: &[u8], src: &[u8]) -> Result<()> {
    if dst.len() != src.len() {
        return Err(CamrError::ShuffleDecode(format!(
            "xor length mismatch: {} vs {}",
            dst.len(),
            src.len()
        )));
    }
    Ok(())
}

/// XOR `src` into `dst` in place through the dispatched kernel.
/// Lengths must match. This is the shuffle hot path: every Δ encode and
/// decode in the serial, channel, and socket planes lands here.
pub fn xor_into(dst: &mut [u8], src: &[u8]) -> Result<()> {
    check_len(dst, src)?;
    let kernel = active_kernel();
    if crate::obs::metrics_enabled() {
        let m = crate::obs::metrics();
        m.xor_bytes.add(dst.len() as u64);
        m.xor_calls_for(kernel.label()).inc();
    }
    debug_assert_eq!(dst.len(), src.len(), "check_len let a length mismatch through");
    match kernel {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        // SAFETY: active_kernel returns Avx2 only after runtime detection
        // (re-asserted here: calling an AVX2 target_feature fn without
        // hardware support is UB, not a slow path).
        XorKernel::Avx2 => unsafe {
            debug_assert!(is_x86_feature_detected!("avx2"), "Avx2 dispatched without support");
            avx2::xor_into(dst, src)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: active_kernel returns Neon only after runtime detection
        // (re-asserted here for the same reason as Avx2).
        XorKernel::Neon => unsafe {
            debug_assert!(
                std::arch::is_aarch64_feature_detected!("neon"),
                "Neon dispatched without support"
            );
            neon::xor_into(dst, src)
        },
        XorKernel::Bytewise => xor_bytes(dst, src),
        _ => xor_u64_lanes(dst, src),
    }
    Ok(())
}

/// XOR `src` into `dst` through one explicit kernel tier — the handle
/// the differential tests and the throughput bench use to pin a tier.
/// Errors if the tier is not available on this CPU.
pub fn xor_into_with(kernel: XorKernel, dst: &mut [u8], src: &[u8]) -> Result<()> {
    check_len(dst, src)?;
    match kernel {
        XorKernel::Bytewise => xor_bytes(dst, src),
        XorKernel::PortableU64 => xor_u64_lanes(dst, src),
        XorKernel::Avx2 => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            if is_x86_feature_detected!("avx2") {
                // SAFETY: detection just confirmed AVX2 support.
                unsafe { avx2::xor_into(dst, src) };
                return Ok(());
            }
            return Err(CamrError::InvalidConfig(
                "avx2 XOR kernel is not available on this CPU".into(),
            ));
        }
        XorKernel::Neon => {
            #[cfg(target_arch = "aarch64")]
            if std::arch::is_aarch64_feature_detected!("neon") {
                // SAFETY: detection just confirmed NEON support.
                unsafe { neon::xor_into(dst, src) };
                return Ok(());
            }
            return Err(CamrError::InvalidConfig(
                "neon XOR kernel is not available on this CPU".into(),
            ));
        }
    }
    Ok(())
}

/// XOR every slice of `srcs` into `acc` in place (dispatched kernel).
/// All lengths must equal `acc.len()`.
pub fn xor_fold(acc: &mut [u8], srcs: &[&[u8]]) -> Result<()> {
    for s in srcs {
        xor_into(acc, s)?;
    }
    Ok(())
}

/// Naive per-byte XOR — the reference the property tests check every
/// dispatched tier against bit-for-bit, and the baseline the
/// `xor_throughput` bench beats.
pub fn xor_into_bytewise(dst: &mut [u8], src: &[u8]) -> Result<()> {
    check_len(dst, src)?;
    xor_bytes(dst, src);
    Ok(())
}

#[inline]
fn xor_bytes(dst: &mut [u8], src: &[u8]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d ^= s;
    }
}

/// Portable tier: whole `u64` lanes with a byte tail. Also the
/// sub-vector tail of both SIMD tiers.
#[inline]
fn xor_u64_lanes(dst: &mut [u8], src: &[u8]) {
    debug_assert_eq!(dst.len(), src.len());
    let split = dst.len() / 8 * 8;
    let (d_words, d_tail) = dst.split_at_mut(split);
    let (s_words, s_tail) = src.split_at(split);
    for (d, s) in d_words.chunks_exact_mut(8).zip(s_words.chunks_exact(8)) {
        let a = u64::from_ne_bytes((&*d).try_into().unwrap());
        let b = u64::from_ne_bytes(s.try_into().unwrap());
        d.copy_from_slice(&(a ^ b).to_ne_bytes());
    }
    for (d, s) in d_tail.iter_mut().zip(s_tail) {
        *d ^= s;
    }
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod avx2 {
    #[cfg(target_arch = "x86")]
    use core::arch::x86::{__m256i, _mm256_loadu_si256, _mm256_storeu_si256, _mm256_xor_si256};
    #[cfg(target_arch = "x86_64")]
    use core::arch::x86_64::{__m256i, _mm256_loadu_si256, _mm256_storeu_si256, _mm256_xor_si256};

    /// XOR `src` into `dst` on 32-byte AVX2 lanes, 4× unrolled (128 B
    /// per main-loop iteration), unaligned loads/stores throughout; the
    /// sub-vector tail goes through the portable `u64` path.
    ///
    /// # Safety
    ///
    /// The CPU must support AVX2 (runtime-detect before calling) and
    /// `dst.len() == src.len()` (checked by every public caller).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn xor_into(dst: &mut [u8], src: &[u8]) {
        debug_assert_eq!(dst.len(), src.len());
        let n = dst.len();
        let d = dst.as_mut_ptr();
        let s = src.as_ptr();
        let mut o = 0usize;
        while o + 128 <= n {
            for k in 0..4usize {
                let p = o + 32 * k;
                let a = _mm256_loadu_si256(d.add(p).cast::<__m256i>());
                let b = _mm256_loadu_si256(s.add(p).cast::<__m256i>());
                _mm256_storeu_si256(d.add(p).cast::<__m256i>(), _mm256_xor_si256(a, b));
            }
            o += 128;
        }
        while o + 32 <= n {
            let a = _mm256_loadu_si256(d.add(o).cast::<__m256i>());
            let b = _mm256_loadu_si256(s.add(o).cast::<__m256i>());
            _mm256_storeu_si256(d.add(o).cast::<__m256i>(), _mm256_xor_si256(a, b));
            o += 32;
        }
        super::xor_u64_lanes(&mut dst[o..], &src[o..]);
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::{veorq_u8, vld1q_u8, vst1q_u8};

    /// XOR `src` into `dst` on 16-byte NEON lanes, 4× unrolled (64 B per
    /// main-loop iteration); the sub-vector tail goes through the
    /// portable `u64` path.
    ///
    /// # Safety
    ///
    /// NEON must be available (runtime-detect before calling; it is
    /// baseline on aarch64) and `dst.len() == src.len()`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn xor_into(dst: &mut [u8], src: &[u8]) {
        debug_assert_eq!(dst.len(), src.len());
        let n = dst.len();
        let d = dst.as_mut_ptr();
        let s = src.as_ptr();
        let mut o = 0usize;
        while o + 64 <= n {
            for k in 0..4usize {
                let p = o + 16 * k;
                vst1q_u8(d.add(p), veorq_u8(vld1q_u8(d.add(p)), vld1q_u8(s.add(p))));
            }
            o += 64;
        }
        while o + 16 <= n {
            vst1q_u8(d.add(o), veorq_u8(vld1q_u8(d.add(o)), vld1q_u8(s.add(o))));
            o += 16;
        }
        super::xor_u64_lanes(&mut dst[o..], &src[o..]);
    }
}

// ---------------------------------------------------------------------------
// Buffer pool
// ---------------------------------------------------------------------------

/// Buffers of at least this many bytes recycle through the large-class
/// free list (capacity first-fit, bounded retention) instead of the
/// small free list. 1 MiB: comfortably above every Δ/scratch size the
/// coded shuffle produces, comfortably below streamed chunk sizes.
pub const LARGE_CLASS_BYTES: usize = 1 << 20;

const LARGE_CLASS_WORDS: usize = LARGE_CLASS_BYTES / 8;

/// At most this many large backings are kept on the free list; releases
/// beyond it free their memory immediately (see [`PoolStats::dropped`])
/// so streaming runs cannot pin unbounded hundreds-of-MB chunks.
pub const LARGE_RETAIN: usize = 4;

/// Counters describing a pool's traffic (see [`BufferPool::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers handed out by [`BufferPool::acquire`].
    pub acquired: u64,
    /// Buffers returned on drop — at most once each.
    pub released: u64,
    /// Acquisitions that had to allocate a fresh backing store.
    pub allocated: u64,
    /// Acquisitions served from a free list (allocation avoided).
    pub recycled: u64,
    /// Large-class releases whose backing was freed instead of retained
    /// (the free list already held [`LARGE_RETAIN`] large buffers).
    pub dropped: u64,
}

impl PoolStats {
    /// Buffers currently in flight (`acquired - released`).
    pub fn outstanding(&self) -> u64 {
        self.acquired - self.released
    }
}

#[derive(Debug, Default)]
struct PoolInner {
    /// Small-class free list (below [`LARGE_CLASS_BYTES`]): LIFO, any
    /// backing serves any small request (resize-on-checkout).
    free: Vec<Vec<u64>>,
    /// Large-class free list: first-fit by capacity, at most
    /// [`LARGE_RETAIN`] entries.
    large: Vec<Vec<u64>>,
    stats: PoolStats,
}

/// A thread-safe arena of recycled, 8-byte-aligned chunk buffers.
///
/// Clones share the same free lists (cheap `Arc` clone), so the serial
/// engine, the parallel engine's worker threads, and tests can all
/// return buffers to one place. Buffers come back zeroed on acquire.
#[derive(Debug, Clone, Default)]
pub struct BufferPool {
    inner: Arc<Mutex<PoolInner>>,
}

impl BufferPool {
    /// New empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Acquire a zeroed buffer of `len` bytes (word-aligned backing).
    pub fn acquire(&self, len: usize) -> PooledBuf {
        self.acquire_inner(len, true)
    }

    /// Acquire a buffer of `len` bytes whose contents are *unspecified*
    /// (recycled bytes from an earlier checkout). For paths that fully
    /// overwrite the buffer before reading it — encode starts with
    /// `fill(0)`, decode scratch starts with `copy_from_slice`, chunk
    /// readers fill from the source — this skips the redundant zeroing
    /// memset on the hot path (a full writeback pass at 256 MiB).
    pub fn acquire_unzeroed(&self, len: usize) -> PooledBuf {
        self.acquire_inner(len, false)
    }

    fn acquire_inner(&self, len: usize, zero: bool) -> PooledBuf {
        if crate::obs::metrics_enabled() {
            crate::obs::metrics().pool_acquired.inc();
        }
        let nwords = len.div_ceil(8);
        let mut words = {
            let mut inner = self.inner.lock().expect("buffer pool poisoned");
            inner.stats.acquired += 1;
            let hit = if nwords >= LARGE_CLASS_WORDS {
                // First fit: a retained large backing that already has
                // the capacity. A miss allocates fresh rather than
                // growing a smaller backing (realloc of a huge buffer).
                let pos = inner.large.iter().position(|w| w.capacity() >= nwords);
                pos.map(|i| inner.large.swap_remove(i))
            } else {
                inner.free.pop()
            };
            match hit {
                Some(w) => {
                    inner.stats.recycled += 1;
                    w
                }
                None => {
                    inner.stats.allocated += 1;
                    Vec::new()
                }
            }
        };
        // Resize outside the lock.
        if zero {
            // clear + resize rewrites every live word with zeros.
            words.clear();
            words.resize(nwords, 0u64);
        } else if words.len() < nwords {
            words.resize(nwords, 0u64);
        } else {
            // truncate never touches the retained (stale) words.
            words.truncate(nwords);
        }
        PooledBuf { words, len, pool: Arc::clone(&self.inner) }
    }

    /// Snapshot of the pool's counters.
    pub fn stats(&self) -> PoolStats {
        self.inner.lock().expect("buffer pool poisoned").stats
    }

    /// Buffers currently sitting on the free lists (both classes).
    pub fn free_buffers(&self) -> usize {
        let inner = self.inner.lock().expect("buffer pool poisoned");
        inner.free.len() + inner.large.len()
    }
}

/// A buffer checked out of a [`BufferPool`]. Returns its backing store
/// to the pool exactly once, on drop.
#[derive(Debug)]
pub struct PooledBuf {
    words: Vec<u64>,
    len: usize,
    pool: Arc<Mutex<PoolInner>>,
}

impl PooledBuf {
    /// Length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the buffer holds zero bytes.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Borrow the bytes.
    pub fn as_slice(&self) -> &[u8] {
        // SAFETY: `words` owns at least `len.div_ceil(8)` u64s
        // (asserted below — the one precondition `from_raw_parts`
        // cannot check), so bytes `[0, len)` lie inside the
        // allocation; u8 has no alignment or validity requirements,
        // and the borrow is tied to `&self`.
        debug_assert!(self.len <= self.words.len() * 8, "PooledBuf len outruns its backing");
        unsafe { std::slice::from_raw_parts(self.words.as_ptr().cast::<u8>(), self.len) }
    }

    /// Borrow the bytes mutably.
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        // SAFETY: as in `as_slice`, plus `&mut self` guarantees
        // exclusive access to the backing store.
        debug_assert!(self.len <= self.words.len() * 8, "PooledBuf len outruns its backing");
        unsafe { std::slice::from_raw_parts_mut(self.words.as_mut_ptr().cast::<u8>(), self.len) }
    }
}

impl AsRef<[u8]> for PooledBuf {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        let words = std::mem::take(&mut self.words);
        let large = words.capacity() >= LARGE_CLASS_WORDS;
        if crate::obs::metrics_enabled() {
            crate::obs::metrics().pool_released.inc();
        }
        let mut inner = self.pool.lock().expect("buffer pool poisoned");
        inner.stats.released += 1;
        if large && inner.large.len() >= LARGE_RETAIN {
            inner.stats.dropped += 1;
            if crate::obs::metrics_enabled() {
                crate::obs::metrics().pool_dropped.inc();
            }
            drop(inner);
            // Free the huge backing outside the lock.
            drop(words);
        } else if large {
            inner.large.push(words);
        } else {
            inner.free.push(words);
        }
    }
}

#[derive(Debug)]
enum Backing {
    Pooled(PooledBuf),
    Heap(Vec<u8>),
}

/// An immutable, cheaply clonable view of an encoded payload: one
/// buffer, any number of readers. The parallel engine ships one
/// `SharedBuf` to every group member instead of cloning the `Δ` bytes
/// per recipient; the pooled backing returns to its pool when the last
/// clone drops.
#[derive(Debug, Clone)]
pub struct SharedBuf {
    inner: Arc<Backing>,
}

impl SharedBuf {
    /// Length in bytes.
    pub fn len(&self) -> usize {
        self.as_ref().len()
    }

    /// True when the payload holds zero bytes.
    pub fn is_empty(&self) -> bool {
        self.as_ref().is_empty()
    }

    /// Stream the payload into `w` straight from the (pooled) backing
    /// store — the socket transport's zero-copy serialize path: an
    /// encoded `Δ` goes from the pool buffer onto the wire without an
    /// intermediate `Vec`.
    pub fn write_to<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        w.write_all(self.as_ref())
    }
}

impl AsRef<[u8]> for SharedBuf {
    fn as_ref(&self) -> &[u8] {
        match &*self.inner {
            Backing::Pooled(b) => b.as_slice(),
            Backing::Heap(v) => v.as_slice(),
        }
    }
}

impl From<PooledBuf> for SharedBuf {
    fn from(b: PooledBuf) -> Self {
        SharedBuf { inner: Arc::new(Backing::Pooled(b)) }
    }
}

impl From<Vec<u8>> for SharedBuf {
    fn from(v: Vec<u8>) -> Self {
        SharedBuf { inner: Arc::new(Backing::Heap(v)) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Lengths straddling every kernel's lane width, unroll stride, and
    /// page-ish boundaries — the differential-fuzz grid.
    const FUZZ_LENS: &[usize] = &[
        0, 1, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 129, 255, 256, 257, 1023,
        4095, 4096, 4097, 65537,
    ];

    fn pattern(len: usize, mul: usize, add: usize) -> Vec<u8> {
        (0..len).map(|i| (i.wrapping_mul(mul).wrapping_add(add)) as u8).collect()
    }

    #[test]
    fn every_available_kernel_matches_the_bytewise_oracle() {
        for kernel in available_kernels() {
            for &len in FUZZ_LENS {
                let src = pattern(len, 101, 5);
                let mut got = pattern(len, 37, 11);
                let mut want = got.clone();
                xor_into_with(kernel, &mut got, &src).unwrap();
                xor_into_bytewise(&mut want, &src).unwrap();
                assert_eq!(got, want, "kernel={} len={len}", kernel.label());
            }
        }
    }

    #[test]
    fn kernels_handle_misaligned_slices() {
        // Slice both operands at every sub-word offset out of larger
        // buffers: the encode path XORs packets at arbitrary idx·plen
        // offsets, so no kernel may assume alignment.
        for kernel in available_kernels() {
            for off in 0..9usize {
                for &len in &[1usize, 31, 64, 257, 4096] {
                    let src_back = pattern(len + 16, 211, 3);
                    let dst_back = pattern(len + 16, 53, 9);
                    let mut got = dst_back.clone();
                    let mut want = dst_back.clone();
                    xor_into_with(kernel, &mut got[off..off + len], &src_back[off..off + len])
                        .unwrap();
                    xor_into_bytewise(&mut want[off..off + len], &src_back[off..off + len])
                        .unwrap();
                    assert_eq!(got, want, "kernel={} off={off} len={len}", kernel.label());
                    // Bytes outside the slice are untouched.
                    assert_eq!(&got[..off], &dst_back[..off]);
                    assert_eq!(&got[off + len..], &dst_back[off + len..]);
                }
            }
        }
    }

    #[test]
    fn dispatched_xor_matches_oracle_and_is_stable() {
        let first = active_kernel();
        assert!(available_kernels().contains(&first), "dispatch picked an unavailable kernel");
        assert_eq!(active_kernel(), first, "dispatch must be cached");
        for &len in FUZZ_LENS {
            let src = pattern(len, 31, 7);
            let mut word = pattern(len, 37, 11);
            let mut byte = word.clone();
            xor_into(&mut word, &src).unwrap();
            xor_into_bytewise(&mut byte, &src).unwrap();
            assert_eq!(word, byte, "len={len}");
        }
    }

    #[test]
    fn forced_portable_override_selects_the_portable_tier() {
        // The decision function itself (the env flag feeds it once, at
        // first dispatch — process-global, so tested directly here).
        assert_eq!(choose_kernel(true), XorKernel::PortableU64);
        let free = choose_kernel(false);
        assert!(available_kernels().contains(&free));
        // XOR is an involution under every tier: applying a forced
        // portable pass after a free-choice pass restores the input.
        let src = pattern(1000, 19, 2);
        let orig = pattern(1000, 7, 1);
        let mut buf = orig.clone();
        xor_into_with(free, &mut buf, &src).unwrap();
        xor_into_with(XorKernel::PortableU64, &mut buf, &src).unwrap();
        assert_eq!(buf, orig);
    }

    #[test]
    fn unavailable_kernels_error_instead_of_faulting() {
        let mut d = vec![0u8; 64];
        let s = vec![1u8; 64];
        for kernel in [XorKernel::Avx2, XorKernel::Neon] {
            let available = available_kernels().contains(&kernel);
            let res = xor_into_with(kernel, &mut d, &s);
            assert_eq!(res.is_ok(), available, "kernel={}", kernel.label());
        }
    }

    #[test]
    fn kernel_labels_are_distinct() {
        let ks = [XorKernel::Bytewise, XorKernel::PortableU64, XorKernel::Avx2, XorKernel::Neon];
        for a in ks {
            for b in ks {
                assert_eq!(a == b, a.label() == b.label());
            }
        }
    }

    #[test]
    fn xor_fold_matches_sequential() {
        let a: Vec<u8> = (0..33).map(|i| i as u8).collect();
        let b: Vec<u8> = (0..33).map(|i| (i * 3) as u8).collect();
        let c: Vec<u8> = (0..33).map(|i| (i * 7 + 1) as u8).collect();
        let mut folded = vec![0u8; 33];
        xor_fold(&mut folded, &[&a, &b, &c]).unwrap();
        let mut seq = vec![0u8; 33];
        xor_into(&mut seq, &a).unwrap();
        xor_into(&mut seq, &b).unwrap();
        xor_into(&mut seq, &c).unwrap();
        assert_eq!(folded, seq);
    }

    #[test]
    fn xor_length_mismatch_errors() {
        let mut d = vec![0u8; 4];
        assert!(xor_into(&mut d, &[0u8; 5]).is_err());
        assert!(xor_into_bytewise(&mut d, &[0u8; 5]).is_err());
        assert!(xor_fold(&mut d, &[&[0u8; 4], &[0u8; 3]]).is_err());
        for kernel in available_kernels() {
            assert!(xor_into_with(kernel, &mut d, &[0u8; 5]).is_err());
        }
    }

    #[test]
    fn acquire_is_zeroed_and_recycles() {
        let pool = BufferPool::new();
        {
            let mut b = pool.acquire(24);
            b.as_mut_slice().fill(0xAB);
        }
        // Same backing store comes back, zeroed.
        let b = pool.acquire(24);
        assert_eq!(b.len(), 24);
        assert!(b.as_slice().iter().all(|&x| x == 0));
        let stats = pool.stats();
        assert_eq!(stats.acquired, 2);
        assert_eq!(stats.allocated, 1);
        assert_eq!(stats.recycled, 1);
        assert_eq!(stats.outstanding(), 1);
        drop(b);
        assert_eq!(pool.stats().outstanding(), 0);
        assert_eq!(pool.free_buffers(), 1);
    }

    #[test]
    fn acquire_unzeroed_recycles_without_rezeroing_guarantee() {
        let pool = BufferPool::new();
        {
            let mut b = pool.acquire(16);
            b.as_mut_slice().fill(0xCD);
        }
        // Unzeroed acquire: correct length, contents unspecified — but
        // fully writable, and the pool accounting is identical.
        let mut b = pool.acquire_unzeroed(16);
        assert_eq!(b.len(), 16);
        b.as_mut_slice().copy_from_slice(&[1u8; 16]);
        assert_eq!(b.as_slice(), &[1u8; 16]);
        drop(b);
        // Growth beyond the recycled capacity still yields valid bytes.
        let b = pool.acquire_unzeroed(64);
        assert_eq!(b.len(), 64);
        let stats = pool.stats();
        assert_eq!(stats.acquired, 3);
        assert_eq!(stats.recycled, 2);
    }

    #[test]
    fn zero_length_buffers_work() {
        let pool = BufferPool::new();
        let b = pool.acquire(0);
        assert!(b.is_empty());
        assert_eq!(b.as_slice(), &[] as &[u8]);
    }

    #[test]
    fn odd_lengths_get_word_padding() {
        let pool = BufferPool::new();
        for len in [1usize, 7, 9, 13] {
            let mut b = pool.acquire(len);
            assert_eq!(b.len(), len);
            assert_eq!(b.as_mut_slice().len(), len);
            b.as_mut_slice().fill(0xFF);
        }
        assert_eq!(pool.stats().released, 4);
    }

    #[test]
    fn large_buffers_recycle_through_their_own_class() {
        let pool = BufferPool::new();
        drop(pool.acquire_unzeroed(LARGE_CLASS_BYTES));
        // A small request must NOT be served by the retained large
        // backing — it allocates fresh.
        drop(pool.acquire(64));
        assert_eq!(pool.stats().allocated, 2);
        // A large request first-fits the retained large backing.
        drop(pool.acquire_unzeroed(LARGE_CLASS_BYTES));
        let stats = pool.stats();
        assert_eq!(stats.recycled, 1);
        assert_eq!(stats.allocated, 2);
        assert_eq!(pool.free_buffers(), 2);
    }

    #[test]
    fn large_class_retention_is_bounded() {
        let pool = BufferPool::new();
        // Check out LARGE_RETAIN + 2 large buffers simultaneously, then
        // release them all: only LARGE_RETAIN backings are retained.
        let held: Vec<PooledBuf> =
            (0..LARGE_RETAIN + 2).map(|_| pool.acquire_unzeroed(LARGE_CLASS_BYTES)).collect();
        drop(held);
        let stats = pool.stats();
        assert_eq!(stats.released, (LARGE_RETAIN + 2) as u64);
        assert_eq!(stats.dropped, 2);
        assert_eq!(pool.free_buffers(), LARGE_RETAIN);
        // Small-class releases are never dropped.
        for _ in 0..3 * LARGE_RETAIN {
            drop(pool.acquire(64));
        }
        assert_eq!(pool.stats().dropped, 2);
    }

    #[test]
    fn large_class_first_fit_skips_too_small_backings() {
        let pool = BufferPool::new();
        drop(pool.acquire_unzeroed(LARGE_CLASS_BYTES));
        // 4× larger than the retained backing: first-fit misses, a
        // fresh backing is allocated, and both are retained afterwards.
        drop(pool.acquire_unzeroed(4 * LARGE_CLASS_BYTES));
        let stats = pool.stats();
        assert_eq!(stats.allocated, 2);
        assert_eq!(stats.recycled, 0);
        assert_eq!(pool.free_buffers(), 2);
        // The big request now recycles the big backing; the small large
        // request fits either.
        drop(pool.acquire_unzeroed(4 * LARGE_CLASS_BYTES));
        assert_eq!(pool.stats().recycled, 1);
    }

    #[test]
    fn shared_buf_single_payload_many_readers() {
        let pool = BufferPool::new();
        let mut b = pool.acquire(16);
        b.as_mut_slice().copy_from_slice(&[7u8; 16]);
        let shared: SharedBuf = b.into();
        let clones: Vec<SharedBuf> = (0..5).map(|_| shared.clone()).collect();
        for c in &clones {
            assert_eq!(c.as_ref(), &[7u8; 16]);
            assert_eq!(c.len(), 16);
        }
        // Backing stays checked out until the last clone drops.
        assert_eq!(pool.stats().outstanding(), 1);
        drop(shared);
        drop(clones);
        assert_eq!(pool.stats().outstanding(), 0);
    }

    #[test]
    fn heap_backed_shared_buf() {
        let s: SharedBuf = vec![1u8, 2, 3].into();
        assert_eq!(s.as_ref(), &[1, 2, 3]);
        assert!(!s.is_empty());
    }

    #[test]
    fn write_to_streams_exact_bytes_from_any_backing() {
        let pool = BufferPool::new();
        let mut b = pool.acquire(13);
        for (i, x) in b.as_mut_slice().iter_mut().enumerate() {
            *x = i as u8;
        }
        let pooled: SharedBuf = b.into();
        let mut sink = Vec::new();
        pooled.write_to(&mut sink).unwrap();
        assert_eq!(sink, (0..13u8).collect::<Vec<_>>());
        let heap: SharedBuf = vec![9u8, 8, 7].into();
        heap.write_to(&mut sink).unwrap();
        assert_eq!(&sink[13..], &[9, 8, 7]);
    }

    #[test]
    fn pool_is_thread_safe() {
        let pool = BufferPool::new();
        std::thread::scope(|s| {
            for t in 0..8u8 {
                let pool = pool.clone();
                s.spawn(move || {
                    for i in 0..50usize {
                        let mut b = pool.acquire(i % 67 + 1);
                        assert!(b.as_slice().iter().all(|&x| x == 0));
                        b.as_mut_slice().fill(t);
                    }
                });
            }
        });
        let stats = pool.stats();
        assert_eq!(stats.acquired, 400);
        assert_eq!(stats.released, 400);
        assert_eq!(stats.outstanding(), 0);
    }
}
