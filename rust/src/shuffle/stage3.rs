//! Stage 3 (paper §III-C.3): parallel-class unicasts.
//!
//! After stage 2, server `U_m` still misses, for every job `j` it does
//! not own, the aggregates of the `k-1` batches other than the one stage
//! 2 delivered. All those batches live at a *single* server: the unique
//! owner `U_l` of `j` in `U_m`'s own parallel class (resolvability makes
//! it unique — blocks of a class are disjoint). `U_l` fuses them into one
//! value (Eq. (5)) and unicasts `B` bytes to `U_m`.
//!
//! Per server: `J - q^{k-2}` missing jobs → load `(q-1)/q` (§IV).

use super::plan::UnicastSpec;
use crate::config::SystemConfig;
use crate::design::ResolvableDesign;
use crate::error::Result;
use crate::placement::Placement;

/// Build all stage-3 unicasts (one per (receiver, non-owned job, round)).
pub fn plan(
    cfg: &SystemConfig,
    design: &ResolvableDesign,
    placement: &Placement,
) -> Result<Vec<UnicastSpec>> {
    let mut unicasts = Vec::new();
    for round in 0..cfg.rounds {
        for m in 0..cfg.servers() {
            let class = design.class_of(m);
            for j in design.non_owned_jobs(m) {
                let sender = design.owner_in_class(j, class);
                debug_assert_ne!(sender, m);
                let batches = placement.stored_batches(sender, j);
                debug_assert_eq!(batches.len(), cfg.k - 1);
                unicasts.push(UnicastSpec {
                    sender,
                    receiver: m,
                    job: j,
                    func: round * cfg.servers() + m,
                    batches,
                });
            }
        }
    }
    Ok(unicasts)
}

/// Expected bytes on the link for stage 3 (no packetization — whole
/// values are unicast, so no padding either).
pub fn expected_bytes(cfg: &SystemConfig) -> usize {
    let missing_jobs = cfg.jobs() - cfg.jobs() / cfg.q; // J - q^{k-2}
    cfg.rounds * cfg.servers() * missing_jobs * cfg.value_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::ResolvableDesign;

    fn setup(k: usize, q: usize, g: usize) -> (SystemConfig, ResolvableDesign, Placement) {
        let cfg = SystemConfig::new(k, q, g).unwrap();
        let d = ResolvableDesign::new(k, q).unwrap();
        let p = Placement::new(&d, &cfg).unwrap();
        (cfg, d, p)
    }

    #[test]
    fn table2_needs_for_example1() {
        // Paper Table II (appendix), translated to 0-based ids: each
        // server's stage-3 needs. E.g. U1 needs the fused aggregates of
        // jobs 3 and 4 (0-based 2, 3): subfiles {1..4} = batches {0,1}.
        let (cfg, d, p) = setup(3, 2, 2);
        let unicasts = plan(&cfg, &d, &p).unwrap();
        // U1 (server 0): non-owned jobs are 2 and 3.
        let u1: Vec<&UnicastSpec> =
            unicasts.iter().filter(|u| u.receiver == 0).collect();
        assert_eq!(u1.len(), 2);
        let j2 = u1.iter().find(|u| u.job == 2).unwrap();
        // Table II: α(ν^{(3)}_{1,1..4}) → batches {0, 1}; sender must be
        // U2 (server 1), the owner of J3 in U1's class (Example 5).
        assert_eq!(j2.sender, 1);
        assert_eq!(j2.batches, vec![0, 1]);
        assert_eq!(j2.func, 0);
        let j3 = u1.iter().find(|u| u.job == 3).unwrap();
        assert_eq!(j3.sender, 1); // U2 also owns J4
        assert_eq!(j3.batches, vec![0, 1]);
    }

    #[test]
    fn table2_all_rows() {
        // Full Table II: (server, job, subfile-set) for all six servers,
        // 0-based. Subfiles given as sorted batch-subfile unions.
        let (cfg, d, p) = setup(3, 2, 2);
        let unicasts = plan(&cfg, &d, &p).unwrap();
        let expect: Vec<(usize, usize, Vec<usize>)> = vec![
            (0, 2, vec![0, 1, 2, 3]),
            (0, 3, vec![0, 1, 2, 3]),
            (1, 0, vec![0, 1, 2, 3]),
            (1, 1, vec![0, 1, 2, 3]),
            (2, 1, vec![2, 3, 4, 5]),
            (2, 3, vec![2, 3, 4, 5]),
            (3, 0, vec![2, 3, 4, 5]),
            (3, 2, vec![2, 3, 4, 5]),
            (4, 1, vec![0, 1, 4, 5]),
            (4, 2, vec![0, 1, 4, 5]),
            (5, 0, vec![0, 1, 4, 5]),
            (5, 3, vec![0, 1, 4, 5]),
        ];
        assert_eq!(unicasts.len(), expect.len());
        for (recv, job, subfiles) in expect {
            let u = unicasts
                .iter()
                .find(|u| u.receiver == recv && u.job == job)
                .unwrap_or_else(|| panic!("missing unicast recv={recv} job={job}"));
            let got: Vec<usize> =
                u.batches.iter().flat_map(|&b| p.batch_subfiles(b)).collect();
            assert_eq!(got, subfiles, "recv={recv} job={job}");
        }
    }

    #[test]
    fn sender_is_unique_class_owner_and_stores_batches() {
        for (k, q) in [(2, 3), (3, 2), (3, 3), (4, 2)] {
            let (cfg, d, p) = setup(k, q, 1);
            for u in plan(&cfg, &d, &p).unwrap() {
                assert_eq!(d.class_of(u.sender), d.class_of(u.receiver));
                assert!(d.owns(u.sender, u.job));
                assert!(!d.owns(u.receiver, u.job));
                for &b in &u.batches {
                    assert!(p.stores_batch(u.sender, u.job, b));
                }
                // Fused batches + the stage-2 batch = all k batches.
                let missing = p.missing_batch(u.job, u.sender).unwrap();
                let mut all = u.batches.clone();
                all.push(missing);
                all.sort_unstable();
                assert_eq!(all, (0..cfg.k).collect::<Vec<_>>());
            }
        }
    }

    #[test]
    fn unicast_count_matches_formula() {
        for (k, q) in [(3, 2), (3, 3), (4, 2), (2, 5)] {
            let (cfg, d, p) = setup(k, q, 1);
            let unicasts = plan(&cfg, &d, &p).unwrap();
            let j = cfg.jobs();
            assert_eq!(unicasts.len(), cfg.servers() * (j - j / q));
        }
    }

    #[test]
    fn example_load_is_one_half() {
        // Paper: L_stage3 = 6 servers × 2 jobs × B / 24B = 1/2.
        let (cfg, _, _) = setup(3, 2, 2);
        assert_eq!(expected_bytes(&cfg), 12 * cfg.value_bytes);
    }
}
