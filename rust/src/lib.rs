//! # CAMR — Coded Aggregated MapReduce
//!
//! A production-grade reproduction of *"CAMR: Coded Aggregated MapReduce"*
//! (Konstantinidis & Ramamoorthy, ISIT 2019). CAMR is a coded-shuffle
//! scheduling scheme for MapReduce-like clusters running `J` jobs whose
//! intermediate values are *aggregatable* (associative + commutative
//! combiner). It trades map-phase storage redundancy `μ = (k-1)/K` for
//! shuffle communication, achieving the same communication load as CCDC
//! (Li–Maddah-Ali–Avestimehr, ISIT'18)
//!
//! ```text
//! L_CAMR = (k(q-1) + 1) / (q(k-1)),     K = k·q
//! ```
//!
//! while requiring only `J = q^(k-1)` jobs instead of CCDC's
//! `C(K, μK+1)` — exponentially fewer.
//!
//! ## Crate layout
//!
//! - [`design`] — resolvable designs from single-parity-check codes
//!   (paper §III, Lemma 1).
//! - [`placement`] — job ownership and Algorithm 1 batch placement.
//! - [`agg`] — aggregation (combiner) functions: associative + commutative
//!   byte-level reducers.
//! - [`shuffle`] — Algorithm 2 coded multicast and the three shuffle
//!   stages (paper §III-C), on a pooled zero-copy data plane
//!   ([`shuffle::buf`]: recycled word-aligned buffers + u64-lane XOR).
//! - [`net`] — shared-link network simulator with byte-exact accounting,
//!   the channel-backed recorder the parallel engine uses, the
//!   [`net::transport::Transport`] trait abstracting the packet plane,
//!   and the socket data plane: a length-prefixed wire format
//!   ([`net::frame`]) spoken over loopback TCP or Unix-domain sockets
//!   ([`net::socket`]).
//! - [`coordinator`] — workers, master, and the end-to-end engines:
//!   the serial reference [`coordinator::engine::Engine`], the
//!   thread-per-worker [`coordinator::parallel::ParallelEngine`], and
//!   the multi-job [`coordinator::batch`] runtime that executes a
//!   scheme's *entire* job set through one persistent engine.
//! - [`check`] — static verification: the plan-level decodability
//!   prover (`camr check`, engine pre-flight on every plane, and
//!   [`service`] admission) and the repo-invariant linter
//!   (`camr lint`), sharing one typed [`check::Diagnostic`]
//!   vocabulary with machine-readable codes and JSON export. The
//!   module docs carry the diagnostic-code catalog and the guide for
//!   adding a lint.
//! - [`baseline`] — CCDC and uncoded baselines for comparison.
//! - [`analysis`] — closed-form load formulas (§IV, §V) and job-count
//!   minimums (Table III).
//! - [`sim`] — discrete-event cluster simulator: replays byte-exact
//!   ledgers into end-to-end completion times under link models,
//!   stragglers, and heterogeneous worker speeds.
//! - [`workload`] — word counting, distributed matvec (NN layers),
//!   gradient aggregation.
//! - [`runtime`] — PJRT client wrapper executing AOT-compiled JAX/Pallas
//!   artifacts on the map path.
//! - [`service`] — continuous job service on the batch runtime:
//!   bounded per-tenant admission (deficit round-robin fairness, typed
//!   backpressure), a dispatcher pool of persistent engines running
//!   coded rounds in flight, and queue-wait/execution latency
//!   decomposition; `camr serve --bench` drives it with mixed
//!   million-job traffic, and [`sim::arrival`] replays the same seeded
//!   Poisson arrival trace for sim-vs-real comparison.
//! - [`metrics`] — load ledger and reports.
//! - [`obs`] — structured tracing + metrics: typed spans on every
//!   plane (serial, channel, TCP, Unix-domain), a Chrome `trace_event`
//!   exporter for Perfetto, per-worker phase statistics, and a
//!   sim-vs-measured comparison. Off by default, no-op when disabled.
//!
//! ## Quickstart
//!
//! ```
//! use camr::config::SystemConfig;
//! use camr::coordinator::engine::Engine;
//! use camr::workload::wordcount::WordCountWorkload;
//!
//! // Example 1 from the paper: K = 6 servers, q = 2, k = 3, J = 4 jobs.
//! let cfg = SystemConfig::new(3, 2, 2).unwrap();
//! let wl = WordCountWorkload::example1(&cfg);
//! let mut engine = Engine::new(cfg, Box::new(wl)).unwrap();
//! let outcome = engine.run().unwrap();
//! assert!(outcome.verified);
//! // Measured communication load equals the paper's closed form: L = 1.
//! assert!((outcome.total_load() - 1.0).abs() < 1e-9);
//! ```
//!
//! ## Execution engines and the threading model
//!
//! Two engines run the same protocol from the same master schedule:
//!
//! - [`coordinator::engine::Engine`] — the serial reference: one thread,
//!   schedule order, canonical [`net::Bus`] ledger.
//! - [`coordinator::parallel::ParallelEngine`] — thread-per-worker
//!   (pool sized to `K`): the map phase fans out across all servers
//!   concurrently, the three shuffle stages exchange coded packets
//!   through per-worker channels, and [`std::sync::Barrier`]s separate
//!   the phases (map ‖ stage 1 ‖ stage 2 ‖ stage 3 ‖ reduce).
//!
//! The parallel engine's packet plane is pluggable
//! ([`coordinator::parallel::TransportKind`]): in-process mpsc channels
//! (default), or sockets — loopback TCP / Unix-domain, with workers as
//! in-process threads or real `camr worker --connect` subprocesses
//! orchestrated by the [`coordinator::remote`] hub.
//!
//! Load accounting stays *exact* under concurrency: every transmission
//! is charged to the shared link through a channel-backed recorder
//! tagged with its schedule sequence number, so the collected ledger is
//! byte-for-byte the serial one no matter how the threads interleave —
//! multicasts are still charged once, and `RunOutcome::total_load()`
//! is identical between the engines (asserted by the property tests).
//! On the socket plane the recorder lives in the hub, which charges
//! each multicast once while fanning the frame out — the golden-ledger
//! fixture cannot tell the four planes apart
//! (`rust/tests/socket_transport.rs`).
//!
//! ## Performance
//!
//! Both engines run the shuffle on a pooled, zero-copy data plane
//! ([`shuffle::buf`]): coded `Δ` packets are encoded in place into
//! recycled word-aligned buffers, shared with every decoder without
//! cloning, and XORed on `u64` lanes. The ledger is byte-identical
//! with pooling on or off (`Engine::pooling`; pinned by the golden
//! fixture in `rust/tests/golden_ledger.rs`) — only allocator traffic
//! and throughput change. Measure the speedup with
//! `cargo bench --bench xor_throughput` (word-wise vs per-byte XOR,
//! pool vs fresh allocation, pooled vs unpooled end-to-end; results
//! also land in the machine-readable `BENCH_shuffle.json`) and
//! `cargo bench --bench shuffle_e2e` (pooled vs unpooled pipeline
//! rows, plus the thread-per-worker map-phase speedup).
//!
//! ```
//! use camr::config::SystemConfig;
//! use camr::coordinator::parallel::ParallelEngine;
//! use camr::workload::synth::SyntheticWorkload;
//!
//! let cfg = SystemConfig::new(3, 2, 1).unwrap();
//! let wl = SyntheticWorkload::new(&cfg, 7);
//! let mut engine = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
//! let outcome = engine.run().unwrap();
//! assert!(outcome.verified);
//! assert!((outcome.total_load() - 1.0).abs() < 1e-9);
//! ```
//!
//! ## Simulating a cluster
//!
//! The ledgers above are exact in *bytes*; the [`sim`] subsystem turns
//! them into *time*. A deterministic discrete-event simulator (binary
//! heap + virtual clock, seeded by [`util::rng`]) replays any recorded
//! ledger through a configurable cluster: shared-link or
//! full-bisection bandwidth, per-message latency, per-worker speed
//! multipliers, and pluggable straggler distributions — with multicast
//! charged once, exactly like [`net::Bus`]. With zero latency,
//! homogeneous workers, and no stragglers it reproduces the closed-form
//! [`sim::TimeModel`] bit-exactly, so the analytic model and the
//! simulator can never drift apart. Run `camr simulate
//! configs/example1.toml` to compare CAMR / CCDC / uncoded completion
//! times, or `cargo run --release --example straggler_sweep` to find
//! the bandwidth crossover where CAMR's extra map work pays for itself.
//!
//! ```
//! use camr::config::SystemConfig;
//! use camr::coordinator::engine::Engine;
//! use camr::sim::{self, SimConfig, StragglerModel};
//! use camr::workload::synth::SyntheticWorkload;
//!
//! let cfg = SystemConfig::new(3, 2, 2).unwrap();
//! let wl = SyntheticWorkload::new(&cfg, 7);
//! let mut engine = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
//! engine.run().unwrap();
//!
//! let mut sc = SimConfig::commodity(); // 1 Gb/s shared link, 1 ms map
//! sc.straggler = StragglerModel::ShiftedExp { rate: 5.0 };
//! let maps = sim::camr_per_worker_maps(&cfg, &engine.master.placement);
//! let out = sim::simulate(&sc, &maps, engine.bus.ledger()).unwrap();
//! assert!(out.total_secs > out.map_secs && out.map_secs > 0.0);
//! ```
//!
//! ## Executing the full job set
//!
//! The paper's headline claim is a *job-count* claim: CAMR matches
//! CCDC's load with `q^(k-1)` jobs instead of `C(K, μK+1)` (Table III).
//! The [`coordinator::batch`] runtime makes that claim executable: it
//! runs a scheme's entire job set end to end through one persistent
//! engine — workers, schedule and the pooled data plane are reused and
//! only the workload is swapped per unit — with oracle verification of
//! unit `i` pipelined behind unit `i+1`'s execution. Every unit's
//! byte-exact ledger folds into one job-tagged aggregate transcript
//! that [`sim::simulate_batch`] replays for a batch makespan, both
//! barriered and pipelined (unit `i+1` maps while unit `i` shuffles).
//! `camr batch configs/example1.toml` compares all three schemes; the
//! CCDC family is capped (`--ccdc-cap`) because its size is exponential
//! — which is the point.
//!
//! ```
//! use camr::config::SystemConfig;
//! use camr::coordinator::batch::{run_batch_synthetic, BatchOptions, BatchScheme};
//!
//! let cfg = SystemConfig::new(3, 2, 2).unwrap(); // Example 1: K = 6
//! let camr = run_batch_synthetic(&cfg, BatchScheme::Camr, &BatchOptions::default()).unwrap();
//! let ccdc = run_batch_synthetic(&cfg, BatchScheme::Ccdc, &BatchOptions::default()).unwrap();
//! assert_eq!(camr.jobs_executed, 4);   // the whole CAMR job set
//! assert_eq!(ccdc.jobs_required, 20);  // C(6, 3): five times the floor
//! assert!(camr.all_verified() && ccdc.all_verified());
//! ```

pub mod agg;
pub mod analysis;
pub mod baseline;
pub mod check;
pub mod config;
pub mod coordinator;
pub mod design;
pub mod error;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod placement;
pub mod report;
pub mod runtime;
pub mod service;
pub mod shuffle;
pub mod sim;
pub mod util;
pub mod workload;

pub use config::SystemConfig;
pub use error::{CamrError, Result};

/// Identifier of a server (0-based; the paper's `U_{i+1}`).
pub type ServerId = usize;
/// Identifier of a job (0-based; the paper's `J_{j+1}`); also the point id
/// of the resolvable design.
pub type JobId = usize;
/// Identifier of an output function (0-based; the paper's `φ_{q+1}`).
pub type FuncId = usize;
/// Identifier of a subfile within a job (0-based; the paper's `n^{(j)}`).
pub type SubfileId = usize;
/// Identifier of a batch within a job (0-based); each batch holds γ
/// consecutive subfiles.
pub type BatchId = usize;
