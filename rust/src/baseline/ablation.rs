//! Stage-coding ablation: run the CAMR placement and schedule but
//! replace the coded multicast of stage 1 and/or stage 2 with plain
//! unicasts of the same chunks.
//!
//! This isolates *where* the coding gain comes from: each coded stage
//! multicasts `g` packets of `B/(g-1)` instead of unicasting `g` chunks
//! of `B` — a per-stage factor of `g-1 = k-1`. Stage 3 is inherently
//! unicast (Eq. (5)), so it has no coded/uncoded split.
//!
//! Used by `benches/encoding_overhead.rs` §ablation and the
//! `camr ablation` CLI subcommand; all variants verify against the
//! oracle, so the ablation never trades correctness for load.

use crate::config::SystemConfig;
use crate::coordinator::master::Master;
use crate::coordinator::values::ValueKey;
use crate::coordinator::worker::Worker;
use crate::error::{CamrError, Result};
use crate::net::{Bus, Stage};
use crate::util::par;
use crate::workload::{check_output, Workload};
use crate::{FuncId, JobId};
use std::collections::HashMap;

/// Which stages keep their coded multicast.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CodingChoice {
    /// Stage 1 coded (owners' exchange).
    pub stage1_coded: bool,
    /// Stage 2 coded (transversal groups).
    pub stage2_coded: bool,
}

impl CodingChoice {
    /// The full CAMR scheme.
    pub fn full() -> Self {
        CodingChoice { stage1_coded: true, stage2_coded: true }
    }

    /// All four variants for the ablation sweep.
    pub fn all() -> [CodingChoice; 4] {
        [
            CodingChoice { stage1_coded: true, stage2_coded: true },
            CodingChoice { stage1_coded: false, stage2_coded: true },
            CodingChoice { stage1_coded: true, stage2_coded: false },
            CodingChoice { stage1_coded: false, stage2_coded: false },
        ]
    }

    /// Human-readable label.
    pub fn label(&self) -> String {
        format!(
            "s1={} s2={}",
            if self.stage1_coded { "coded" } else { "unicast" },
            if self.stage2_coded { "coded" } else { "unicast" }
        )
    }

    /// Closed-form load for this variant: an uncoded stage multiplies
    /// its coded load by `k-1` (each chunk crosses whole instead of as
    /// one coded packet per member).
    pub fn expected_load(&self, k: usize, q: usize) -> f64 {
        let forms = crate::analysis::load::camr_stages(k, q);
        let s1 = if self.stage1_coded { forms.stage1 } else { forms.stage1 * (k as f64 - 1.0) };
        let s2 = if self.stage2_coded { forms.stage2 } else { forms.stage2 * (k as f64 - 1.0) };
        s1 + s2 + forms.stage3
    }
}

/// Outcome of an ablation run.
#[derive(Debug, Clone)]
pub struct AblationOutcome {
    /// The variant.
    pub choice: CodingChoice,
    /// Bytes per stage.
    pub stage_bytes: [usize; 3],
    /// `J·Q·B`.
    pub normalizer: f64,
    /// Verified against the oracle.
    pub verified: bool,
}

impl AblationOutcome {
    /// Total measured load.
    pub fn total_load(&self) -> f64 {
        self.stage_bytes.iter().sum::<usize>() as f64 / self.normalizer
    }
}

/// Run one ablation variant end to end (always oracle-verified).
pub fn run_ablation(
    cfg: SystemConfig,
    workload: Box<dyn Workload>,
    choice: CodingChoice,
) -> Result<AblationOutcome> {
    let master = Master::new(cfg.clone())?;
    let schedule = master.schedule()?;
    let mut workers: Vec<Worker> =
        (0..cfg.servers()).map(|s| Worker::new(s, &cfg)).collect();
    let mut bus = Bus::new();

    // Map phase (same as the engine).
    {
        let placement = &master.placement;
        let wl = &*workload;
        let cfg_ref = &cfg;
        let mut slots: Vec<(&mut Worker, Result<usize>)> =
            workers.iter_mut().map(|w| (w, Ok(0))).collect();
        par::for_each_mut(&mut slots, |(w, slot)| {
            *slot = w.run_map_phase(cfg_ref, placement, wl);
        });
        for (_, r) in slots {
            r?;
        }
    }

    // Stages 1 and 2: coded or unicast per the choice.
    for (groups, stage, coded) in [
        (&schedule.stage1, Stage::Stage1, choice.stage1_coded),
        (&schedule.stage2, Stage::Stage2, choice.stage2_coded),
    ] {
        for plan in groups {
            if coded {
                let mut deltas = Vec::with_capacity(plan.members.len());
                for &m in &plan.members {
                    let delta = workers[m].encode_for_group(plan)?;
                    bus.multicast(
                        stage,
                        m,
                        plan.members.iter().copied().filter(|&x| x != m).collect(),
                        delta.len(),
                    );
                    deltas.push(delta);
                }
                for &m in &plan.members {
                    workers[m].decode_from_group(plan, &deltas)?;
                }
            } else {
                // Uncoded: any holder unicasts each receiver's chunk
                // whole (B bytes instead of one B/(k-1) packet each).
                for (p, c) in plan.chunks.iter().enumerate() {
                    let holder = plan
                        .members
                        .iter()
                        .enumerate()
                        .find(|&(t, _)| t != p)
                        .map(|(_, &m)| m)
                        .ok_or_else(|| CamrError::ShuffleDecode("no holder".into()))?;
                    let v = workers[holder]
                        .store
                        .get(ValueKey { job: c.job, func: c.func, batch: c.batch })?
                        .clone();
                    bus.unicast(stage, holder, c.receiver, v.len());
                    workers[c.receiver]
                        .store
                        .put(ValueKey { job: c.job, func: c.func, batch: c.batch }, v);
                }
            }
        }
    }

    // Stage 3 (always unicast) + reduce + verify — same as the engine.
    let agg = workload.aggregator();
    for u in &schedule.stage3 {
        let v = workers[u.sender].fuse_for_unicast(agg, u)?;
        bus.unicast(Stage::Stage3, u.sender, u.receiver, v.len());
        workers[u.receiver].receive_fused(u, v)?;
    }

    let mut outputs: HashMap<(JobId, FuncId), Vec<u8>> = HashMap::new();
    for f in 0..cfg.functions() {
        let reducer = cfg.reducer_of(f);
        for j in 0..cfg.jobs() {
            let out = workers[reducer].reduce(&cfg, &master.placement, agg, j, f)?;
            outputs.insert((j, f), out);
        }
    }
    for ((j, f), got) in &outputs {
        let want = workload.oracle(&cfg, *j, *f)?;
        check_output(&*workload, *j, *f, got, &want)?;
    }

    Ok(AblationOutcome {
        choice,
        stage_bytes: [
            bus.stage_bytes(Stage::Stage1),
            bus.stage_bytes(Stage::Stage2),
            bus.stage_bytes(Stage::Stage3),
        ],
        normalizer: cfg.load_normalizer(),
        verified: true,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::synth::SyntheticWorkload;

    #[test]
    fn full_coding_matches_engine() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 8);
        let out = run_ablation(cfg, Box::new(wl), CodingChoice::full()).unwrap();
        assert!(out.verified);
        assert!((out.total_load() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_variants_verify_and_match_expected_loads() {
        for (k, q) in [(3usize, 2usize), (3, 3), (4, 2)] {
            let cfg = SystemConfig::with_options(k, q, 2, 1, 120).unwrap();
            for choice in CodingChoice::all() {
                let wl = SyntheticWorkload::new(&cfg, 4);
                let out = run_ablation(cfg.clone(), Box::new(wl), choice).unwrap();
                assert!(out.verified, "k={k} q={q} {}", choice.label());
                let expect = choice.expected_load(k, q);
                assert!(
                    (out.total_load() - expect).abs() < 1e-12,
                    "k={k} q={q} {}: {} vs {expect}",
                    choice.label(),
                    out.total_load()
                );
            }
        }
    }

    #[test]
    fn uncoded_stages_cost_k_minus_1_times_more() {
        let cfg = SystemConfig::with_options(4, 2, 1, 1, 120).unwrap();
        let coded = run_ablation(
            cfg.clone(),
            Box::new(SyntheticWorkload::new(&cfg, 1)),
            CodingChoice::full(),
        )
        .unwrap();
        let uncoded = run_ablation(
            cfg.clone(),
            Box::new(SyntheticWorkload::new(&cfg, 1)),
            CodingChoice { stage1_coded: false, stage2_coded: false },
        )
        .unwrap();
        // Stages 1+2 exactly (k-1)× heavier without coding.
        let c12 = (coded.stage_bytes[0] + coded.stage_bytes[1]) as f64;
        let u12 = (uncoded.stage_bytes[0] + uncoded.stage_bytes[1]) as f64;
        assert!((u12 / c12 - 3.0).abs() < 1e-12); // k-1 = 3
        assert_eq!(coded.stage_bytes[2], uncoded.stage_bytes[2]);
    }
}
