//! Baseline schemes CAMR is compared against (paper §V).
//!
//! - [`uncoded`] — plain unicast shuffles over the *same* Algorithm-1
//!   placement: with aggregation (`L = 2 - k/K`) and without
//!   (`L ≈ γk(K-k+1)/K`, showing the compression gain of Definition 1).
//! - [`ccdc`] — Compressed Coded Distributed Computing (Li et al.,
//!   ISIT'18): jobs ↔ `C(K, μK+1)` subsets, coded owner exchange, and
//!   non-owner delivery accounted at the paper's Eq.-(6) rate.

pub mod ablation;
pub mod ccdc;
pub mod uncoded;

pub use ablation::{run_ablation, CodingChoice};
pub use ccdc::CcdcEngine;
pub use uncoded::{UncodedEngine, UncodedMode};
