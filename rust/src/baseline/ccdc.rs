//! CCDC baseline — Compressed Coded Distributed Computing
//! (Li, Maddah-Ali, Avestimehr, ISIT 2018; the paper's Eq. (6)).
//!
//! At storage fraction `μ = (k-1)/K` (matching CAMR), CCDC requires one
//! job per `(μK+1) = k`-subset of servers: `J_CCDC = C(K, k)` jobs. Each
//! job's dataset splits into `k` batches labeled by its owners; an owner
//! stores all batches but its own — structurally the same per-group
//! placement as CAMR's stage 1, but over *all* `C(K,k)` groups instead
//! of the `q^{k-1}` design-selected ones. That combinatorial explosion
//! is exactly the limitation CAMR removes (Table III).
//!
//! ## Shuffle
//! - **Owner exchange** — byte-exact Lemma-2 coded multicast inside each
//!   job's owner group (identical machinery to CAMR stage 1).
//! - **Non-owner delivery** — each non-owner needs its function's total
//!   aggregate. No single owner stores a whole job, so our executable
//!   implementation ships two complementary partial aggregates (`2B`
//!   uncoded). [4]'s index-coded delivery achieves `k·B/(k-1)` per
//!   (job, non-owner); we report **both** numbers: `measured_bytes`
//!   (what this implementation actually put on the link) and
//!   `paper_bytes` (Eq. (6) accounting, used in the comparison benches
//!   so the baseline is never disadvantaged). With both accountings the
//!   *job-count* comparison — CAMR's headline — is unaffected.

use crate::agg::Value;
use crate::analysis::jobs::binomial;
use crate::error::{CamrError, Result};
use crate::net::{Bus, Stage};
use crate::shuffle::multicast::GroupPlan;
use crate::shuffle::plan::ChunkSpec;
use crate::{FuncId, JobId, ServerId};
use std::collections::HashMap;

/// A synthetic aggregatable workload over CCDC's job set (u64-lane sums,
/// deterministic from the seed — same construction as
/// `workload::synth`, but CCDC's `J = C(K,k)` differs from CAMR's).
pub struct CcdcWorkload {
    seed: u64,
    value_bytes: usize,
}

impl CcdcWorkload {
    fn mix(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = x;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn value(&self, job: JobId, subfile: usize, func: FuncId) -> Value {
        let lanes = self.value_bytes / 8;
        let mut v = Vec::with_capacity(self.value_bytes);
        for lane in 0..lanes {
            let x = Self::mix(
                self.seed
                    ^ (job as u64) << 40
                    ^ (subfile as u64) << 24
                    ^ (func as u64) << 8
                    ^ lane as u64,
            );
            v.extend_from_slice(&x.to_le_bytes());
        }
        v
    }
}

/// Outcome of a CCDC run.
#[derive(Debug, Clone)]
pub struct CcdcOutcome {
    /// Jobs actually executed (`C(K, k)`, or the cap passed to
    /// [`CcdcEngine::run_capped`]).
    pub jobs: usize,
    /// Size of the full job family `C(K, k)` — what the scheme *requires*
    /// at this storage fraction, independent of any execution cap.
    pub family: usize,
    /// Bytes actually transmitted by this implementation.
    pub measured_bytes: usize,
    /// Bytes under [4]'s Eq.-(6) accounting (coded non-owner delivery,
    /// exact rational — no packet padding).
    pub paper_bytes: f64,
    /// Normalizer `J·Q·B`.
    pub normalizer: f64,
    /// Oracle verification result.
    pub verified: bool,
    /// Number of Lemma-2 encode operations (for the encoding-overhead
    /// bench, E9).
    pub encode_ops: usize,
}

impl CcdcOutcome {
    /// Load under Eq.-(6) accounting — equals `(1-μ)(μK+1)/(μK)`.
    pub fn paper_load(&self) -> f64 {
        self.paper_bytes / self.normalizer
    }

    /// Load actually measured for this implementation.
    pub fn measured_load(&self) -> f64 {
        self.measured_bytes as f64 / self.normalizer
    }
}

/// The CCDC engine: `K` servers, group size `k`, `γ` subfiles per batch.
pub struct CcdcEngine {
    servers: usize,
    k: usize,
    gamma: usize,
    value_bytes: usize,
    jobs: Vec<Vec<ServerId>>, // job id → sorted owner k-subset
    workload: CcdcWorkload,
    /// Link ledger (Baseline stage tag).
    pub bus: Bus,
}

impl CcdcEngine {
    /// Build for `K` servers with group size `k` (μK = k-1), matching a
    /// CAMR config's storage fraction when `K = k·q`.
    pub fn new(
        servers: usize,
        k: usize,
        gamma: usize,
        value_bytes: usize,
        seed: u64,
    ) -> Result<Self> {
        if k < 2 || servers <= k {
            return Err(CamrError::InvalidConfig(format!(
                "CCDC needs 2 <= k < K (got k={k}, K={servers})"
            )));
        }
        if value_bytes % 8 != 0 {
            return Err(CamrError::InvalidConfig("value_bytes must be a multiple of 8".into()));
        }
        let count = binomial(servers as u64, k as u64);
        if count > 2_000_000 {
            return Err(CamrError::InvalidConfig(format!(
                "C({servers},{k}) = {count} CCDC jobs is too large to simulate"
            )));
        }
        let jobs = k_subsets(servers, k);
        debug_assert_eq!(jobs.len() as u128, count);
        Ok(CcdcEngine {
            servers,
            k,
            gamma,
            value_bytes,
            jobs,
            workload: CcdcWorkload { seed, value_bytes },
            bus: Bus::new(),
        })
    }

    /// Number of CCDC jobs `C(K, k)`.
    pub fn job_count(&self) -> usize {
        self.jobs.len()
    }

    /// Oracle: total aggregate of (job, func) over all `k·γ` subfiles.
    fn oracle(&self, job: JobId, func: FuncId) -> Value {
        let mut acc = vec![0u8; self.value_bytes];
        for n in 0..self.k * self.gamma {
            let v = self.workload.value(job, n, func);
            acc = sum_u64(&acc, &v);
        }
        acc
    }

    /// The sorted owner `k`-subset of one job of the family.
    pub fn job_owners(&self, job: JobId) -> &[ServerId] {
        &self.jobs[job]
    }

    /// Per-worker map-invocation counts of one job: each of its `k`
    /// owners maps its `k-1` stored batches of `γ` subfiles; everyone
    /// else maps nothing. Summed over the full family this reproduces
    /// [`crate::sim::ccdc_per_worker_maps`].
    pub fn per_worker_maps_per_job(&self, job: JobId) -> Vec<usize> {
        let mut maps = vec![0usize; self.servers];
        for &o in &self.jobs[job] {
            maps[o] = (self.k - 1) * self.gamma;
        }
        maps
    }

    /// Run the full CCDC protocol; verifies every output bit-exactly.
    pub fn run(&mut self) -> Result<CcdcOutcome> {
        self.run_capped(None)
    }

    /// Run the first `min(cap, C(K, k))` jobs of the family, one job at
    /// a time — map, owner exchange, non-owner delivery, verify — with
    /// the bus tagged per job ([`crate::net::Bus::set_job`]), so the
    /// ledger is a contiguous per-job sequence the batch simulator can
    /// pipeline. `None` executes the whole family. Per-job loads are
    /// identical either way; the cap exists because `C(K, k)` grows
    /// exponentially (the very limitation CAMR removes).
    pub fn run_capped(&mut self, cap: Option<usize>) -> Result<CcdcOutcome> {
        self.bus.reset();
        let b = self.value_bytes;
        let funcs = self.servers;
        let executed = cap.map_or(self.jobs.len(), |c| c.min(self.jobs.len()));
        if executed == 0 {
            return Err(CamrError::InvalidConfig("CCDC cap must execute >= 1 job".into()));
        }

        let mut outputs: HashMap<(JobId, FuncId), Value> = HashMap::new();
        let mut encode_ops = 0usize;
        let mut nonowner_pairs = 0usize;
        for j in 0..executed {
            self.bus.set_job(j);
            let owners = self.jobs[j].clone();

            // ---- Map: per-owner batch aggregates for this job only.
            // store[s] : (func, batch) → aggregate. Owner at position p
            // stores batches {0..k} \ {p}.
            let mut store: Vec<HashMap<(FuncId, usize), Value>> =
                vec![HashMap::new(); self.servers];
            for (p, &s) in owners.iter().enumerate() {
                for batch in (0..self.k).filter(|&x| x != p) {
                    for f in 0..funcs {
                        let mut acc = vec![0u8; b];
                        for i in 0..self.gamma {
                            let n = batch * self.gamma + i;
                            acc = sum_u64(&acc, &self.workload.value(j, n, f));
                        }
                        store[s].insert((f, batch), acc);
                    }
                }
            }

            // ---- Owner exchange: Lemma-2 coded multicast in the group.
            let chunks: Vec<ChunkSpec> = owners
                .iter()
                .enumerate()
                .map(|(p, &o)| ChunkSpec { receiver: o, job: j, func: o, batch: p })
                .collect();
            let plan = GroupPlan { members: owners.clone(), chunks };
            let mut deltas = Vec::with_capacity(self.k);
            for (t, &m) in owners.iter().enumerate() {
                let delta = plan.encode(t, b, |p| {
                    let c = plan.chunks[p];
                    store[m]
                        .get(&(c.func, c.batch))
                        .cloned()
                        .ok_or_else(|| CamrError::MissingValue(format!("{c:?} at {m}")))
                })?;
                encode_ops += 1;
                self.bus.multicast(
                    Stage::Baseline,
                    m,
                    owners.iter().copied().filter(|&x| x != m).collect(),
                    delta.len(),
                );
                deltas.push(delta);
            }
            for (r, &m) in owners.iter().enumerate() {
                let chunk = plan.decode(r, b, &deltas, |p| {
                    let c = plan.chunks[p];
                    store[m]
                        .get(&(c.func, c.batch))
                        .cloned()
                        .ok_or_else(|| CamrError::MissingValue(format!("{c:?} at {m}")))
                })?;
                store[m].insert((m, r), chunk);
            }
            // Owners reduce now: fold all k batch aggregates of their own
            // function.
            for &m in &owners {
                let mut acc = vec![0u8; b];
                for batch in 0..self.k {
                    let v = store[m].get(&(m, batch)).ok_or_else(|| {
                        CamrError::MissingValue(format!("job {j} batch {batch} at {m}"))
                    })?;
                    acc = sum_u64(&acc, v);
                }
                outputs.insert((j, m), acc);
            }

            // ---- Non-owner delivery: two complementary partial
            // aggregates (measured), accounted at k·B/(k-1) under Eq. (6).
            let owner_set: std::collections::HashSet<ServerId> =
                owners.iter().copied().collect();
            for m in (0..self.servers).filter(|s| !owner_set.contains(s)) {
                nonowner_pairs += 1;
                let u0 = owners[0]; // misses batch 0, stores 1..k-1
                let u1 = owners[1]; // stores batch 0
                let mut fused = vec![0u8; b];
                for batch in 1..self.k {
                    let v = store[u0]
                        .get(&(m, batch))
                        .ok_or_else(|| CamrError::MissingValue(format!("fused {j}/{m}/{batch}")))?;
                    fused = sum_u64(&fused, v);
                }
                self.bus.unicast(Stage::Baseline, u0, m, fused.len());
                let v0 = store[u1]
                    .get(&(m, 0))
                    .ok_or_else(|| CamrError::MissingValue(format!("batch0 {j}/{m}")))?
                    .clone();
                self.bus.unicast(Stage::Baseline, u1, m, v0.len());
                outputs.insert((j, m), sum_u64(&fused, &v0));
            }
        }
        self.bus.set_job(0);

        // ---- Verify every output against the oracle (bit-exact).
        for ((j, f), got) in &outputs {
            let want = self.oracle(*j, *f);
            if got != &want {
                return Err(CamrError::Verification(format!(
                    "CCDC output mismatch at job {j} func {f}"
                )));
            }
        }

        let measured = self.bus.total_bytes();
        // Eq.-(6) accounting (exact rational): both the owner exchange
        // and each non-owner delivery cost k·B/(k-1).
        let coded_pair = self.k as f64 * b as f64 / (self.k as f64 - 1.0);
        let paper_bytes = (executed + nonowner_pairs) as f64 * coded_pair;
        Ok(CcdcOutcome {
            jobs: executed,
            family: self.jobs.len(),
            measured_bytes: measured,
            paper_bytes,
            normalizer: (executed * funcs * b) as f64,
            verified: true,
            encode_ops,
        })
    }
}

/// Enumerate all k-subsets of `[0, n)` in lexicographic order.
pub fn k_subsets(n: usize, k: usize) -> Vec<Vec<usize>> {
    if k == 0 || k > n {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut cur: Vec<usize> = (0..k).collect();
    loop {
        out.push(cur.clone());
        // Rightmost position that can still be incremented.
        let mut i = k as isize - 1;
        while i >= 0 && cur[i as usize] == n - k + i as usize {
            i -= 1;
        }
        if i < 0 {
            return out;
        }
        let i = i as usize;
        cur[i] += 1;
        for t in i + 1..k {
            cur[t] = cur[t - 1] + 1;
        }
    }
}

fn sum_u64(a: &[u8], b: &[u8]) -> Vec<u8> {
    debug_assert_eq!(a.len(), b.len());
    let mut out = vec![0u8; a.len()];
    for i in (0..a.len()).step_by(8) {
        let x = u64::from_le_bytes(a[i..i + 8].try_into().unwrap());
        let y = u64::from_le_bytes(b[i..i + 8].try_into().unwrap());
        out[i..i + 8].copy_from_slice(&x.wrapping_add(y).to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::load;

    #[test]
    fn k_subsets_enumeration() {
        let s = k_subsets(4, 2);
        assert_eq!(s, vec![vec![0, 1], vec![0, 2], vec![0, 3], vec![1, 2], vec![1, 3], vec![2, 3]]);
        assert_eq!(k_subsets(6, 3).len(), 20);
        assert_eq!(k_subsets(10, 4).len(), 210);
    }

    #[test]
    fn example_needs_20_jobs() {
        // Paper §III-C: CCDC at K=6, μ=1/3 needs C(6,3) = 20 jobs.
        let e = CcdcEngine::new(6, 3, 2, 64, 1).unwrap();
        assert_eq!(e.job_count(), 20);
    }

    #[test]
    fn run_verifies_and_matches_eq6() {
        let mut e = CcdcEngine::new(6, 3, 2, 64, 7).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified);
        // Eq. (6): L = (1-1/3)(3)/(2) = 1 at K=6, μK=2.
        assert!((out.paper_load() - load::ccdc_total(2, 6)).abs() < 1e-12);
        // Our executable delivery is the uncoded 2B variant — strictly
        // more traffic than Eq. (6) accounting.
        assert!(out.measured_load() >= out.paper_load());
    }

    #[test]
    fn eq6_accounting_across_parameters() {
        for (servers, k) in [(4, 2), (6, 2), (6, 3), (8, 4), (9, 3)] {
            let mut e = CcdcEngine::new(servers, k, 1, 64, 3).unwrap();
            let out = e.run().unwrap();
            let expect = load::ccdc_total(k - 1, servers);
            assert!(
                (out.paper_load() - expect).abs() < 1e-12,
                "K={servers} k={k}: {} vs {expect}",
                out.paper_load()
            );
        }
    }

    #[test]
    fn rejects_oversized_job_counts() {
        assert!(CcdcEngine::new(100, 5, 1, 64, 0).is_err()); // 75M jobs
    }

    #[test]
    fn capped_run_executes_a_verified_prefix_with_per_job_tags() {
        let mut full = CcdcEngine::new(6, 3, 2, 64, 7).unwrap();
        let fout = full.run().unwrap();
        assert_eq!(fout.jobs, 20);
        assert_eq!(fout.family, 20);
        // Every job's ledger slice is contiguous and tagged 0..20, and
        // per-job bytes are uniform (the family is symmetric).
        assert_eq!(full.bus.job_count(), 20);
        let j0 = full.bus.job_bytes(0);
        assert!(j0 > 0);
        assert!((0..20).all(|j| full.bus.job_bytes(j) == j0));
        let mut capped = CcdcEngine::new(6, 3, 2, 64, 7).unwrap();
        let cout = capped.run_capped(Some(5)).unwrap();
        assert_eq!(cout.jobs, 5);
        assert_eq!(cout.family, 20);
        assert!(cout.verified);
        // Per-job load is cap-invariant: the capped prefix measures the
        // same Eq.-(6) load as the full family.
        assert!((cout.paper_load() - fout.paper_load()).abs() < 1e-12);
        assert!((cout.measured_load() - fout.measured_load()).abs() < 1e-12);
        // The capped ledger is exactly the first 5 jobs of the full one.
        assert_eq!(capped.bus.job_count(), 5);
        assert_eq!(capped.bus.total_bytes(), 5 * j0);
        // A cap beyond the family is clamped; zero is rejected.
        let mut over = CcdcEngine::new(6, 3, 2, 64, 7).unwrap();
        assert_eq!(over.run_capped(Some(999)).unwrap().jobs, 20);
        assert!(over.run_capped(Some(0)).is_err());
    }

    #[test]
    fn per_job_maps_sum_to_family_total() {
        let e = CcdcEngine::new(6, 3, 2, 64, 1).unwrap();
        let mut total = vec![0usize; 6];
        for j in 0..e.job_count() {
            let per = e.per_worker_maps_per_job(j);
            assert_eq!(per.iter().filter(|&&m| m > 0).count(), 3, "k owners map");
            assert!(e.job_owners(j).iter().all(|&o| per[o] == 4), "(k-1)·γ each");
            for (t, p) in total.iter_mut().zip(per) {
                *t += p;
            }
        }
        assert_eq!(total, crate::sim::ccdc_per_worker_maps(6, 3, 2));
    }
}
