//! Uncoded shuffle baselines over the Algorithm-1 placement.
//!
//! Both baselines run the identical Map phase and placement as CAMR and
//! differ only in the Shuffle: every needed value crosses the link as a
//! plain unicast.
//!
//! **Aggregated** (`UncodedMode::Aggregated`): senders still combine
//! before transmitting (Definition 1 is exploited, coding is not).
//! - owner `U_{k'}` of job `j` receives its missing batch aggregate from
//!   any holder: `B` bytes;
//! - non-owner `m` receives two complementary partial aggregates (no
//!   single server stores a whole job): the fused aggregate of one
//!   owner's `k-1` stored batches plus that owner's missing batch
//!   aggregate from a second owner: `2B` bytes.
//!
//! Total `L = (k + 2(K-k))/K = 2 - k/K`.
//!
//! **Raw** (`UncodedMode::Raw`): no aggregation at all — every
//! per-subfile intermediate value crosses the wire individually, as in a
//! vanilla MapReduce shuffle. `L = γ(k + (K-k)k)/K`, i.e. ~`γk×` more
//! traffic — the compression gain the paper's Definition 1 unlocks.

use crate::agg::Value;
use crate::config::SystemConfig;
use crate::coordinator::master::Master;
use crate::coordinator::values::ValueKey;
use crate::coordinator::worker::Worker;
use crate::error::{CamrError, Result};
use crate::net::{Bus, Stage};
use crate::workload::{check_output, Workload};
use crate::{FuncId, JobId};
use std::collections::HashMap;

/// Shuffle mode for the uncoded baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UncodedMode {
    /// Combine before transmitting (aggregation without coding).
    Aggregated,
    /// Ship every per-subfile value (no aggregation, no coding).
    Raw,
}

/// Outcome of an uncoded baseline run.
#[derive(Debug, Clone)]
pub struct UncodedOutcome {
    /// Bytes on the link.
    pub shuffle_bytes: usize,
    /// Load normalizer `J·Q·B`.
    pub normalizer: f64,
    /// Oracle verification result.
    pub verified: bool,
}

impl UncodedOutcome {
    /// Measured communication load.
    pub fn load(&self) -> f64 {
        self.shuffle_bytes as f64 / self.normalizer
    }
}

/// The uncoded baseline engine.
pub struct UncodedEngine {
    master: Master,
    workers: Vec<Worker>,
    workload: Box<dyn Workload>,
    mode: UncodedMode,
    /// The shared link ledger.
    pub bus: Bus,
}

impl UncodedEngine {
    /// Build for a config/workload/mode.
    pub fn new(cfg: SystemConfig, workload: Box<dyn Workload>, mode: UncodedMode) -> Result<Self> {
        let master = Master::new(cfg)?;
        let workers = (0..master.cfg.servers()).map(|s| Worker::new(s, &master.cfg)).collect();
        Ok(UncodedEngine { master, workers, workload, mode, bus: Bus::new() })
    }

    /// Swap in the next job's workload, returning the previous one — the
    /// batch runtime reuses this engine (workers + placement) across the
    /// jobs of an uncoded batch.
    pub fn replace_workload(&mut self, workload: Box<dyn Workload>) -> Box<dyn Workload> {
        std::mem::replace(&mut self.workload, workload)
    }

    /// Access the placement (for per-worker map counts in simulation).
    pub fn placement(&self) -> &crate::placement::Placement {
        &self.master.placement
    }

    /// Run map → unicast shuffle → reduce, verifying against the oracle.
    pub fn run(&mut self) -> Result<UncodedOutcome> {
        self.bus.reset();
        for w in &mut self.workers {
            w.store.clear();
        }
        // Identical map phase to CAMR.
        let cfg = self.master.cfg.clone();
        {
            let placement = &self.master.placement;
            let workload = &*self.workload;
            let cfg_ref = &cfg;
            let mut results: Vec<Result<usize>> =
                (0..self.workers.len()).map(|_| Ok(0)).collect();
            let mut slots: Vec<(&mut Worker, &mut Result<usize>)> =
                self.workers.iter_mut().zip(results.iter_mut()).collect();
            crate::util::par::for_each_mut(&mut slots, |(w, slot)| {
                **slot = w.run_map_phase(cfg_ref, placement, workload);
            });
            for r in results {
                r?;
            }
        }

        let mut outputs: HashMap<(JobId, FuncId), Value> = HashMap::new();
        match self.mode {
            UncodedMode::Aggregated => self.run_aggregated(&cfg, &mut outputs)?,
            UncodedMode::Raw => self.run_raw(&cfg, &mut outputs)?,
        }

        // Verify against the oracle.
        let workload = &*self.workload;
        let pairs: Vec<(JobId, FuncId)> = outputs.keys().copied().collect();
        let outputs_ref = &outputs;
        let failures: Vec<String> = crate::util::par::map_indexed(pairs.len(), |i| {
            let (j, f) = pairs[i];
            let want = match workload.oracle(&cfg, j, f) {
                Ok(w) => w,
                Err(e) => return Some(e.to_string()),
            };
            check_output(workload, j, f, &outputs_ref[&(j, f)], &want)
                .err()
                .map(|e| e.to_string())
        })
        .into_iter()
        .flatten()
        .collect();
        if let Some(first) = failures.first() {
            return Err(CamrError::Verification(format!(
                "uncoded baseline: {} mismatches; first: {first}",
                failures.len()
            )));
        }
        Ok(UncodedOutcome {
            shuffle_bytes: self.bus.total_bytes(),
            normalizer: cfg.load_normalizer(),
            verified: true,
        })
    }

    /// Aggregated unicast shuffle.
    fn run_aggregated(
        &mut self,
        cfg: &SystemConfig,
        outputs: &mut HashMap<(JobId, FuncId), Value>,
    ) -> Result<()> {
        let agg = self.workload.aggregator();
        let placement = &self.master.placement;
        for f in 0..cfg.functions() {
            let m = cfg.reducer_of(f);
            for j in 0..cfg.jobs() {
                let owners = placement.owners(j).to_vec();
                if placement.owns(m, j) {
                    // Missing batch aggregate from any holder.
                    let b = placement.missing_batch(j, m)?;
                    let holder = *owners
                        .iter()
                        .find(|&&o| placement.stores_batch(o, j, b))
                        .expect("k-1 holders exist");
                    let v = self.workers[holder]
                        .store
                        .get(ValueKey { job: j, func: f, batch: b })?
                        .clone();
                    self.bus.unicast(Stage::Baseline, holder, m, v.len());
                    // Reduce: local k-1 aggregates + received.
                    let mut acc = v;
                    for b2 in placement.stored_batches(m, j) {
                        let local = self.workers[m]
                            .store
                            .get(ValueKey { job: j, func: f, batch: b2 })?;
                        acc = agg.combine(&acc, local)?;
                    }
                    outputs.insert((j, f), acc);
                } else {
                    // Two complementary senders: u0's fused stored batches
                    // plus u0's missing batch from u1.
                    let u0 = owners[0];
                    let b_miss = placement.missing_batch(j, u0)?;
                    let u1 = *owners[1..]
                        .iter()
                        .find(|&&o| placement.stores_batch(o, j, b_miss))
                        .expect("another owner stores u0's missing batch");
                    let mut fused = agg.identity(cfg.value_bytes);
                    for b in placement.stored_batches(u0, j) {
                        let v =
                            self.workers[u0].store.get(ValueKey { job: j, func: f, batch: b })?;
                        fused = agg.combine(&fused, v)?;
                    }
                    self.bus.unicast(Stage::Baseline, u0, m, fused.len());
                    let v_miss = self.workers[u1]
                        .store
                        .get(ValueKey { job: j, func: f, batch: b_miss })?
                        .clone();
                    self.bus.unicast(Stage::Baseline, u1, m, v_miss.len());
                    outputs.insert((j, f), agg.combine(&fused, &v_miss)?);
                }
            }
        }
        Ok(())
    }

    /// Raw unicast shuffle: per-subfile values, no aggregation.
    fn run_raw(
        &mut self,
        cfg: &SystemConfig,
        outputs: &mut HashMap<(JobId, FuncId), Value>,
    ) -> Result<()> {
        let agg = self.workload.aggregator();
        let placement = &self.master.placement;
        for f in 0..cfg.functions() {
            let m = cfg.reducer_of(f);
            for j in 0..cfg.jobs() {
                let mut acc = agg.identity(cfg.value_bytes);
                for b in 0..cfg.batches() {
                    if placement.stores_batch(m, j, b) {
                        // Local batch aggregate (computed in map phase).
                        let v = self.workers[m].store.get(ValueKey { job: j, func: f, batch: b })?;
                        acc = agg.combine(&acc, v)?;
                    } else {
                        // Fetch each subfile's value individually from a
                        // holder — γ unicasts of B bytes each.
                        let holder = *placement
                            .owners(j)
                            .iter()
                            .find(|&&o| placement.stores_batch(o, j, b))
                            .expect("every batch has k-1 holders");
                        for n in placement.batch_subfiles(b) {
                            let vals = self.workload.map_subfile(j, n)?;
                            let v = &vals[f];
                            self.bus.unicast(Stage::Baseline, holder, m, v.len());
                            acc = agg.combine(&acc, v)?;
                        }
                    }
                }
                outputs.insert((j, f), acc);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::load;
    use crate::workload::synth::SyntheticWorkload;

    fn run(k: usize, q: usize, gamma: usize, mode: UncodedMode) -> UncodedOutcome {
        let cfg = SystemConfig::new(k, q, gamma).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 123);
        let mut e = UncodedEngine::new(cfg, Box::new(wl), mode).unwrap();
        e.run().unwrap()
    }

    #[test]
    fn aggregated_load_matches_closed_form() {
        for (k, q) in [(2, 2), (3, 2), (3, 3), (4, 2)] {
            let out = run(k, q, 2, UncodedMode::Aggregated);
            let expect = load::uncoded_aggregated_total(k, q);
            assert!(
                (out.load() - expect).abs() < 1e-12,
                "k={k} q={q}: {} vs {expect}",
                out.load()
            );
            assert!(out.verified);
        }
    }

    #[test]
    fn raw_load_matches_closed_form() {
        for (k, q, g) in [(3, 2, 1), (3, 2, 3), (3, 3, 2)] {
            let out = run(k, q, g, UncodedMode::Raw);
            let expect = load::uncoded_raw_total(k, q, g);
            assert!(
                (out.load() - expect).abs() < 1e-12,
                "k={k} q={q} γ={g}: {} vs {expect}",
                out.load()
            );
        }
    }

    #[test]
    fn camr_beats_uncoded_aggregated_for_k3() {
        let coded = {
            let cfg = SystemConfig::new(3, 2, 2).unwrap();
            let wl = SyntheticWorkload::new(&cfg, 5);
            let mut e = crate::coordinator::engine::Engine::new(cfg, Box::new(wl)).unwrap();
            e.run().unwrap().total_load()
        };
        let uncoded = run(3, 2, 2, UncodedMode::Aggregated).load();
        assert!(coded < uncoded, "{coded} !< {uncoded}");
    }
}
