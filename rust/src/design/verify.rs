//! Structural verification of resolvable designs.
//!
//! [`verify_design`] re-checks every invariant promised by Lemma 1
//! directly from the block structure (no reliance on how the design was
//! constructed). The engine runs it once at startup; tests and proptest
//! harnesses use it to validate randomized parameter sweeps.

use super::resolvable::ResolvableDesign;
use crate::error::{CamrError, Result};

/// A full structural report of a design verification.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DesignReport {
    /// Number of points (jobs).
    pub points: usize,
    /// Number of blocks (servers).
    pub blocks: usize,
    /// Number of parallel classes.
    pub classes: usize,
    /// Common block cardinality `q^{k-2}`.
    pub block_size: usize,
    /// Replication of each point (must equal `k` — one block per class).
    pub replication: usize,
}

/// Verify every Lemma-1 invariant of the design; returns a report on
/// success, or the first violated invariant as an error.
pub fn verify_design(d: &ResolvableDesign) -> Result<DesignReport> {
    let k = d.code.k;
    let q = d.code.q;
    let expect_block = q.pow(k as u32 - 2);

    // 1. Every block has cardinality q^{k-2} and sorted distinct points.
    for s in 0..d.servers() {
        let b = d.block(s);
        if b.points.len() != expect_block {
            return Err(CamrError::DesignInvariant(format!(
                "block {s} has {} points, expected {expect_block}",
                b.points.len()
            )));
        }
        if b.points.windows(2).any(|w| w[0] >= w[1]) {
            return Err(CamrError::DesignInvariant(format!(
                "block {s} points not strictly increasing"
            )));
        }
        if b.points.iter().any(|&p| p >= d.jobs()) {
            return Err(CamrError::DesignInvariant(format!("block {s} point out of range")));
        }
    }

    // 2. Each parallel class partitions the point set (resolution).
    for i in 0..d.classes() {
        let mut seen = vec![false; d.jobs()];
        for s in d.class_members(i) {
            if d.class_of(s) != i {
                return Err(CamrError::DesignInvariant(format!(
                    "server {s} reported in class {i} but class_of = {}",
                    d.class_of(s)
                )));
            }
            for &p in &d.block(s).points {
                if seen[p] {
                    return Err(CamrError::DesignInvariant(format!(
                        "class {i}: point {p} appears in two blocks — not a parallel class"
                    )));
                }
                seen[p] = true;
            }
        }
        if let Some(p) = seen.iter().position(|&b| !b) {
            return Err(CamrError::DesignInvariant(format!(
                "class {i}: point {p} not covered — classes must partition the points"
            )));
        }
    }

    // 3. Every point lies in exactly k blocks (one per class) and the
    //    owner bookkeeping agrees with raw block membership.
    for j in 0..d.jobs() {
        let own = d.owners(j);
        if own.len() != k {
            return Err(CamrError::DesignInvariant(format!(
                "job {j} has {} owners, expected {k}",
                own.len()
            )));
        }
        for (i, &s) in own.iter().enumerate() {
            if d.class_of(s) != i || !d.block(s).points.contains(&j) {
                return Err(CamrError::DesignInvariant(format!(
                    "job {j}: owner list inconsistent at class {i}"
                )));
            }
        }
    }

    // 4. Any two blocks from *different* classes intersect in exactly
    //    q^{k-3} points when k >= 3 (and at most 1 point when k = 2);
    //    blocks within a class are disjoint. This is the structure that
    //    makes stage-2 groups pin down unique jobs.
    for a in 0..d.servers() {
        for b in (a + 1)..d.servers() {
            let ba = d.block(a);
            let bb = d.block(b);
            let inter = ba.points.iter().filter(|p| bb.points.contains(p)).count();
            if d.class_of(a) == d.class_of(b) {
                if inter != 0 {
                    return Err(CamrError::DesignInvariant(format!(
                        "blocks {a},{b} in the same class intersect ({inter} points)"
                    )));
                }
            } else if k >= 3 {
                // Fixing two coordinates of an SPC codeword leaves
                // q^{k-3} free message digits.
                let expect = q.pow(k as u32 - 3);
                if inter != expect {
                    return Err(CamrError::DesignInvariant(format!(
                        "cross-class blocks {a},{b} intersect in {inter}, expected {expect}"
                    )));
                }
            } else {
                // k = 2: a codeword is (u, u) — blocks from the two
                // classes intersect in exactly one point when their
                // levels agree and are disjoint otherwise.
                let expect = usize::from(d.block(a).level == d.block(b).level);
                if inter != expect {
                    return Err(CamrError::DesignInvariant(format!(
                        "k=2 blocks {a},{b} intersect in {inter}, expected {expect}"
                    )));
                }
            }
        }
    }

    Ok(DesignReport {
        points: d.jobs(),
        blocks: d.servers(),
        classes: d.classes(),
        block_size: expect_block,
        replication: k,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::resolvable::ResolvableDesign;

    #[test]
    fn verifies_small_designs() {
        for (k, q) in [(2, 2), (2, 5), (3, 2), (3, 3), (3, 4), (4, 2), (4, 3), (5, 2)] {
            let d = ResolvableDesign::new(k, q).unwrap();
            let r = verify_design(&d).unwrap_or_else(|e| panic!("k={k} q={q}: {e}"));
            assert_eq!(r.points, q.pow(k as u32 - 1));
            assert_eq!(r.blocks, k * q);
            assert_eq!(r.classes, k);
            assert_eq!(r.block_size, q.pow(k as u32 - 2));
            assert_eq!(r.replication, k);
        }
    }

    #[test]
    fn verifies_non_prime_q() {
        // Footnote 1: Z_q need not be a field.
        for q in [4usize, 6, 8, 9] {
            let d = ResolvableDesign::new(3, q).unwrap();
            verify_design(&d).unwrap();
        }
    }
}
