//! Resolvable designs from single-parity-check codes (paper §III).
//!
//! The combinatorial heart of CAMR: a `(k, k-1)` SPC code over `Z_q`
//! yields a resolvable design whose points are the `J = q^(k-1)` jobs
//! and whose `k·q` blocks are the servers, partitioned into `k` parallel
//! classes of `q` blocks each (Lemma 1).

pub mod resolvable;
pub mod spc;
pub mod verify;

pub use resolvable::{Block, ResolvableDesign};
pub use spc::SpcCode;
