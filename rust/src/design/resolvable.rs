//! Resolvable design construction from SPC codes (paper Lemma 1, Eq. (1)).
//!
//! Points are the `J = q^{k-1}` codeword indices (= jobs). Block
//! `B_{i,l} = { j : T[i][j] = l }` collects the codewords whose `i`-th
//! coordinate equals `l`. The `k·q` blocks are the servers; blocks with
//! the same row `i` form parallel class `P_i` (each class partitions the
//! point set — the defining property of resolvability).
//!
//! Server indexing convention (paper §III-A): server `U_m` (1-based in
//! the paper, 0-based here) corresponds to block `B_{⌈m/q⌉, (m-1) mod q}`,
//! i.e. with 0-based `s`: row `i = s / q`, level `l = s mod q`.

use super::spc::SpcCode;
use crate::error::Result;
use crate::{JobId, ServerId};

/// A block of the design: the set of points (jobs) whose codeword has
/// value `level` at coordinate `row`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Block {
    /// Parallel-class index `i` (0-based row of `T`).
    pub row: usize,
    /// Coordinate value `l ∈ Z_q`.
    pub level: u32,
    /// Sorted point (job) ids in this block; always `q^{k-2}` of them.
    pub points: Vec<JobId>,
}

/// The resolvable design `(X_SPC, A_SPC)` of Lemma 1, with the
/// block ↔ server correspondence baked in.
#[derive(Debug, Clone)]
pub struct ResolvableDesign {
    /// The underlying SPC code.
    pub code: SpcCode,
    /// All `k·q` blocks, indexed by server id (`s = row·q + level`).
    blocks: Vec<Block>,
    /// `owners[j]` = the `k` servers whose blocks contain point `j`,
    /// one per parallel class, sorted ascending (equivalently by row).
    owners: Vec<Vec<ServerId>>,
}

impl ResolvableDesign {
    /// Build the design for parameters `(k, q)`.
    pub fn new(k: usize, q: usize) -> Result<Self> {
        let code = SpcCode::new(k, q)?;
        let j_total = code.num_codewords();
        let mut blocks: Vec<Block> = (0..k * q)
            .map(|s| Block { row: s / q, level: (s % q) as u32, points: Vec::new() })
            .collect();
        let mut owners: Vec<Vec<ServerId>> = vec![Vec::with_capacity(k); j_total];
        for j in 0..j_total {
            for i in 0..k {
                let l = code.t(i, j);
                let s = i * q + l as usize;
                blocks[s].points.push(j);
                owners[j].push(s);
            }
        }
        Ok(ResolvableDesign { code, blocks, owners })
    }

    /// Cluster size `K = k·q` (= number of blocks).
    pub fn servers(&self) -> usize {
        self.code.k * self.code.q
    }

    /// Number of points / jobs `J = q^{k-1}`.
    pub fn jobs(&self) -> usize {
        self.code.num_codewords()
    }

    /// Number of parallel classes (= `k`).
    pub fn classes(&self) -> usize {
        self.code.k
    }

    /// The block associated with server `s`.
    pub fn block(&self, s: ServerId) -> &Block {
        &self.blocks[s]
    }

    /// The server id of block `B_{row, level}` (0-based row).
    pub fn server_of_block(&self, row: usize, level: u32) -> ServerId {
        debug_assert!(row < self.code.k);
        debug_assert!((level as usize) < self.code.q);
        row * self.code.q + level as usize
    }

    /// The parallel class (0-based row) that server `s` belongs to.
    pub fn class_of(&self, s: ServerId) -> usize {
        s / self.code.q
    }

    /// All servers in parallel class `i`, ascending.
    pub fn class_members(&self, i: usize) -> Vec<ServerId> {
        (0..self.code.q).map(|l| i * self.code.q + l).collect()
    }

    /// The `k` owner servers of job `j` (paper's `X^{(j)}`), sorted
    /// ascending — one per parallel class.
    pub fn owners(&self, j: JobId) -> &[ServerId] {
        &self.owners[j]
    }

    /// Whether server `s` owns (is assigned) job `j`.
    pub fn owns(&self, s: ServerId, j: JobId) -> bool {
        let i = self.class_of(s);
        self.owners[j][i] == s
    }

    /// The unique owner of job `j` inside parallel class `i`.
    pub fn owner_in_class(&self, j: JobId, i: usize) -> ServerId {
        self.owners[j][i]
    }

    /// Jobs **not** owned by server `s` — `J - q^{k-2}` of them.
    pub fn non_owned_jobs(&self, s: ServerId) -> Vec<JobId> {
        (0..self.jobs()).filter(|&j| !self.owns(s, j)).collect()
    }

    /// Enumerate stage-2 transversal groups: one server per parallel
    /// class with empty common intersection — equivalently, the coordinate
    /// vectors over `Z_q` that are *not* codewords (§III-C.2). Each group
    /// is returned sorted by row, i.e. `[B_{1,v_1}, …, B_{k,v_k}]`.
    ///
    /// There are exactly `q^{k-1}(q-1)` such groups.
    pub fn transversal_groups(&self) -> Vec<Vec<ServerId>> {
        self.code
            .all_non_codewords()
            .into_iter()
            .map(|v| {
                v.iter().enumerate().map(|(i, &l)| self.server_of_block(i, l)).collect()
            })
            .collect()
    }

    /// For a transversal group `g` (sorted by row) and the member at row
    /// `i`, return `(job, remaining_owner)`: the unique job jointly owned
    /// by `g \ {g[i]}`, and its owner in class `i` (which is *not* `g[i]`).
    ///
    /// This is the stage-2 chunk identification (paper §III-C.2).
    pub fn stage2_target(&self, group: &[ServerId], i: usize) -> (JobId, ServerId) {
        debug_assert_eq!(group.len(), self.code.k);
        let v: Vec<u32> = group.iter().map(|&s| (s % self.code.q) as u32).collect();
        let j = self.code.complete_except(&v, i);
        let rem = self.owner_in_class(j, i);
        debug_assert_ne!(rem, group[i], "remaining owner must differ from excluded server");
        (j, rem)
    }

    /// Check that a candidate group (one server per class) has empty
    /// intersection, i.e. is a valid stage-2 group.
    pub fn is_transversal_group(&self, group: &[ServerId]) -> bool {
        if group.len() != self.code.k {
            return false;
        }
        for (i, &s) in group.iter().enumerate() {
            if self.class_of(s) != i {
                return false;
            }
        }
        let v: Vec<u32> = group.iter().map(|&s| (s % self.code.q) as u32).collect();
        !self.code.is_codeword(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example_design() -> ResolvableDesign {
        ResolvableDesign::new(3, 2).unwrap()
    }

    #[test]
    fn example2_ownership() {
        // Paper Eq. (2): X^(1)={U1,U3,U5}, X^(2)={U1,U4,U6},
        //                X^(3)={U2,U3,U6}, X^(4)={U2,U4,U5}. (1-based)
        let d = example_design();
        assert_eq!(d.owners(0), &[0, 2, 4]);
        assert_eq!(d.owners(1), &[0, 3, 5]);
        assert_eq!(d.owners(2), &[1, 2, 5]);
        assert_eq!(d.owners(3), &[1, 3, 4]);
    }

    #[test]
    fn block_sizes_are_q_pow_k2() {
        for (k, q) in [(3, 2), (3, 3), (4, 2), (2, 4), (4, 3)] {
            let d = ResolvableDesign::new(k, q).unwrap();
            for s in 0..d.servers() {
                assert_eq!(
                    d.block(s).points.len(),
                    q.pow(k as u32 - 2),
                    "k={k} q={q} s={s}"
                );
            }
        }
    }

    #[test]
    fn parallel_classes_partition_points() {
        for (k, q) in [(3, 2), (3, 3), (4, 2), (2, 5)] {
            let d = ResolvableDesign::new(k, q).unwrap();
            for i in 0..d.classes() {
                let mut seen = vec![false; d.jobs()];
                for s in d.class_members(i) {
                    for &p in &d.block(s).points {
                        assert!(!seen[p], "point {p} twice in class {i}");
                        seen[p] = true;
                    }
                }
                assert!(seen.iter().all(|&b| b), "class {i} misses points");
            }
        }
    }

    #[test]
    fn owners_one_per_class_and_consistent() {
        let d = ResolvableDesign::new(4, 3).unwrap();
        for j in 0..d.jobs() {
            let own = d.owners(j);
            assert_eq!(own.len(), 4);
            for (i, &s) in own.iter().enumerate() {
                assert_eq!(d.class_of(s), i);
                assert!(d.block(s).points.contains(&j));
                assert!(d.owns(s, j));
            }
            // Sorted ascending because class i servers are i*q..(i+1)*q.
            let mut sorted = own.to_vec();
            sorted.sort_unstable();
            assert_eq!(own, &sorted[..]);
        }
    }

    #[test]
    fn transversal_group_count() {
        for (k, q) in [(3, 2), (3, 3), (4, 2), (2, 4)] {
            let d = ResolvableDesign::new(k, q).unwrap();
            let groups = d.transversal_groups();
            assert_eq!(groups.len(), q.pow(k as u32 - 1) * (q - 1), "k={k} q={q}");
            for g in &groups {
                assert!(d.is_transversal_group(g));
                // Empty intersection: no job owned by all members.
                for j in 0..d.jobs() {
                    assert!(
                        !g.iter().all(|&s| d.owns(s, j)),
                        "group {g:?} jointly owns job {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn stage2_target_properties() {
        // For every group and excluded row i: the k-1 remaining members
        // all own the target job, the excluded member does not, and the
        // remaining owner is in the excluded member's class.
        for (k, q) in [(3, 2), (3, 3), (4, 2)] {
            let d = ResolvableDesign::new(k, q).unwrap();
            for g in d.transversal_groups() {
                for i in 0..k {
                    let (j, rem) = d.stage2_target(&g, i);
                    for (t, &s) in g.iter().enumerate() {
                        if t == i {
                            assert!(!d.owns(s, j));
                        } else {
                            assert!(d.owns(s, j));
                        }
                    }
                    assert!(d.owns(rem, j));
                    assert_eq!(d.class_of(rem), d.class_of(g[i]));
                    assert_ne!(rem, g[i]);
                }
            }
        }
    }

    #[test]
    fn example4_group_u1_u3_u6() {
        // Paper Example 4: G = {U1, U3, U6} (1-based) = {0, 2, 5}.
        // No job is common to all three, but each pair owns one.
        let d = example_design();
        let g = vec![0usize, 2, 5];
        assert!(d.is_transversal_group(&g));
        // Removing U1 → {U3,U6} jointly own J3 (0-based job 2).
        assert_eq!(d.stage2_target(&g, 0).0, 2);
        // Removing U3 → {U1,U6} jointly own J2 (0-based job 1).
        assert_eq!(d.stage2_target(&g, 1).0, 1);
        // Removing U6 → {U1,U3} jointly own J1 (0-based job 0).
        assert_eq!(d.stage2_target(&g, 2).0, 0);
    }

    #[test]
    fn stage2_pair_coverage_is_exact() {
        // Every (server, non-owned job) pair is covered exactly once
        // across all (group, excluded-row) combinations — the counting
        // identity k·q^{k-1}(q-1) = K(J - q^{k-2}).
        for (k, q) in [(3, 2), (3, 3), (4, 2)] {
            let d = ResolvableDesign::new(k, q).unwrap();
            let mut cover = std::collections::HashMap::new();
            for g in d.transversal_groups() {
                for i in 0..k {
                    let (j, _) = d.stage2_target(&g, i);
                    *cover.entry((g[i], j)).or_insert(0usize) += 1;
                }
            }
            for s in 0..d.servers() {
                for j in d.non_owned_jobs(s) {
                    assert_eq!(cover.get(&(s, j)), Some(&1), "k={k} q={q} s={s} j={j}");
                }
            }
            let total: usize = cover.values().sum();
            assert_eq!(total, d.servers() * (d.jobs() - q.pow(k as u32 - 2)));
        }
    }
}
