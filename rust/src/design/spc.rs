//! `(k, k-1)` single-parity-check codes over `Z_q` (paper §III, Eq. before (1)).
//!
//! The generator matrix is `G = [ I_{k-1} | 1 ]`, so a message
//! `u ∈ Z_q^{k-1}` encodes to `c = (u_1, …, u_{k-1}, Σ u_i mod q)`.
//! The `q^{k-1}` codewords are stacked as the columns of the `k × q^{k-1}`
//! matrix `T`; column `j` is the codeword of job `J_{j+1}`.
//!
//! The construction works for any `q ≥ 2` — `Z_q` need not be a field
//! (paper footnote 1).

use crate::error::{CamrError, Result};

/// A `(k, k-1)` single-parity-check code over `Z_q`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpcCode {
    /// Code length (= number of parallel classes).
    pub k: usize,
    /// Alphabet size (= blocks per parallel class).
    pub q: usize,
}

impl SpcCode {
    /// Construct a `(k, k-1)` SPC code over `Z_q`.
    pub fn new(k: usize, q: usize) -> Result<Self> {
        if k < 2 {
            return Err(CamrError::InvalidConfig(format!("SPC code needs k >= 2, got {k}")));
        }
        if q < 2 {
            return Err(CamrError::InvalidConfig(format!("SPC code needs q >= 2, got {q}")));
        }
        Ok(SpcCode { k, q })
    }

    /// Number of codewords `q^{k-1}` (= number of jobs / design points).
    pub fn num_codewords(&self) -> usize {
        self.q.pow(self.k as u32 - 1)
    }

    /// The message vector of codeword index `j`, i.e. the base-`q` digits
    /// of `j`, **most-significant digit first**. This makes codeword index
    /// order equal lexicographic order — the order the paper lists
    /// codewords in (Example 2: {000, 011, 101, 110} are jobs 1–4).
    pub fn message(&self, j: usize) -> Vec<u32> {
        let mut digits = vec![0u32; self.k - 1];
        let mut x = j;
        for slot in digits.iter_mut().rev() {
            *slot = (x % self.q) as u32;
            x /= self.q;
        }
        digits
    }

    /// The index of the codeword whose message digits are `u`
    /// (MSD-first, inverse of [`SpcCode::message`]).
    pub fn index_of_message(&self, u: &[u32]) -> usize {
        debug_assert_eq!(u.len(), self.k - 1);
        let mut j = 0usize;
        for &d in u.iter() {
            j = j * self.q + d as usize;
        }
        j
    }

    /// Encode message index `j` into a length-`k` codeword
    /// `c = u · G = (u, Σu mod q)`.
    pub fn codeword(&self, j: usize) -> Vec<u32> {
        let mut c = self.message(j);
        let parity: u32 = c.iter().fold(0u32, |acc, &d| (acc + d) % self.q as u32);
        c.push(parity);
        c
    }

    /// Entry `T[i][j]`: coordinate `i` (0-based row) of codeword `j`
    /// (0-based column). `i` indexes the parallel class, `j` the job.
    pub fn t(&self, i: usize, j: usize) -> u32 {
        debug_assert!(i < self.k);
        debug_assert!(j < self.num_codewords());
        if i < self.k - 1 {
            // MSD-first digit i of j in base q.
            ((j / self.q.pow((self.k - 2 - i) as u32)) % self.q) as u32
        } else {
            // Parity coordinate: sum of message digits mod q.
            self.message(j)
                .iter()
                .fold(0u32, |acc, &d| (acc + d) % self.q as u32)
        }
    }

    /// Whether a length-`k` vector over `Z_q` is a codeword
    /// (parity coordinate equals the sum of the message coordinates).
    pub fn is_codeword(&self, v: &[u32]) -> bool {
        debug_assert_eq!(v.len(), self.k);
        let parity: u32 = v[..self.k - 1].iter().fold(0u32, |acc, &d| (acc + d) % self.q as u32);
        v[self.k - 1] == parity
    }

    /// The unique codeword that agrees with `v` on every coordinate
    /// *except* row `i` (any `k-1` coordinates of an SPC codeword
    /// determine the remaining one). Returns the codeword index.
    ///
    /// This is the stage-2 "joint job" computation: a transversal group
    /// minus one server pins down exactly one job (paper §III-C.2).
    pub fn complete_except(&self, v: &[u32], i: usize) -> usize {
        debug_assert_eq!(v.len(), self.k);
        debug_assert!(i < self.k);
        let q = self.q as u32;
        if i == self.k - 1 {
            // Message fully known; parity is ignored.
            let u: Vec<u32> = v[..self.k - 1].to_vec();
            self.index_of_message(&u)
        } else {
            // Missing message digit = parity - (sum of other message digits).
            let others: u32 = v[..self.k - 1]
                .iter()
                .enumerate()
                .filter(|&(t, _)| t != i)
                .fold(0u32, |acc, (_, &d)| (acc + d) % q);
            let digit = (v[self.k - 1] + q - others) % q;
            let mut u: Vec<u32> = v[..self.k - 1].to_vec();
            u[i] = digit;
            self.index_of_message(&u)
        }
    }

    /// Enumerate all codewords as rows (index order).
    pub fn all_codewords(&self) -> Vec<Vec<u32>> {
        (0..self.num_codewords()).map(|j| self.codeword(j)).collect()
    }

    /// Enumerate all length-`k` vectors over `Z_q` that are **not**
    /// codewords — exactly the stage-2 transversal groups of §III-C.2.
    /// There are `q^k - q^{k-1} = q^{k-1}(q-1)` of them.
    pub fn all_non_codewords(&self) -> Vec<Vec<u32>> {
        let mut out = Vec::with_capacity(self.num_codewords() * (self.q - 1));
        let total = self.q.pow(self.k as u32);
        for x in 0..total {
            let mut v = Vec::with_capacity(self.k);
            let mut y = x;
            for _ in 0..self.k {
                v.push((y % self.q) as u32);
                y /= self.q;
            }
            if !self.is_codeword(&v) {
                out.push(v);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example2_codewords() {
        // Paper Example 2: q = 2, k = 3 → codewords {000, 011, 101, 110}.
        let code = SpcCode::new(3, 2).unwrap();
        let cws: Vec<Vec<u32>> = code.all_codewords();
        assert_eq!(cws.len(), 4);
        // MSD-first indexing makes our job order exactly the paper's
        // lexicographic listing: jobs 1..4 ↔ {000, 011, 101, 110}.
        let expected: Vec<Vec<u32>> =
            vec![vec![0, 0, 0], vec![0, 1, 1], vec![1, 0, 1], vec![1, 1, 0]];
        assert_eq!(cws, expected);
        for cw in cws {
            assert!(code.is_codeword(&cw));
        }
    }

    #[test]
    fn t_matrix_matches_codeword() {
        let code = SpcCode::new(4, 3).unwrap();
        for j in 0..code.num_codewords() {
            let cw = code.codeword(j);
            for i in 0..code.k {
                assert_eq!(code.t(i, j), cw[i], "T[{i}][{j}]");
            }
        }
    }

    #[test]
    fn message_index_roundtrip() {
        let code = SpcCode::new(5, 3).unwrap();
        for j in 0..code.num_codewords() {
            let u = code.message(j);
            assert_eq!(code.index_of_message(&u), j);
        }
    }

    #[test]
    fn non_codeword_count_is_qk1_qm1() {
        for (k, q) in [(2, 2), (3, 2), (3, 3), (4, 2), (2, 5)] {
            let code = SpcCode::new(k, q).unwrap();
            let ncw = code.all_non_codewords();
            assert_eq!(ncw.len(), q.pow(k as u32 - 1) * (q - 1), "k={k} q={q}");
            for v in &ncw {
                assert!(!code.is_codeword(v));
            }
        }
    }

    #[test]
    fn complete_except_recovers_codewords() {
        // For every codeword and every erased coordinate, completion must
        // return that codeword.
        for (k, q) in [(3, 2), (3, 3), (4, 2), (2, 4)] {
            let code = SpcCode::new(k, q).unwrap();
            for j in 0..code.num_codewords() {
                let cw = code.codeword(j);
                for i in 0..k {
                    // Corrupt coordinate i arbitrarily: completion ignores it.
                    let mut v = cw.clone();
                    v[i] = (v[i] + 1) % q as u32;
                    assert_eq!(code.complete_except(&v, i), j, "k={k} q={q} j={j} i={i}");
                }
            }
        }
    }

    #[test]
    fn complete_except_on_non_codeword_differs_at_i() {
        // For a non-codeword v, the completed codeword must differ from v
        // exactly at coordinate i (this underpins stage 2: the remaining
        // owner is in the same parallel class as the excluded server).
        let code = SpcCode::new(3, 2).unwrap();
        for v in code.all_non_codewords() {
            for i in 0..3 {
                let j = code.complete_except(&v, i);
                let cw = code.codeword(j);
                for t in 0..3 {
                    if t == i {
                        assert_ne!(cw[t], v[t], "v={v:?} i={i}");
                    } else {
                        assert_eq!(cw[t], v[t], "v={v:?} i={i}");
                    }
                }
            }
        }
    }

    #[test]
    fn works_for_non_prime_q() {
        // Footnote 1: Z_q need not be a field. q = 6 composite.
        let code = SpcCode::new(3, 6).unwrap();
        assert_eq!(code.num_codewords(), 36);
        for j in 0..36 {
            assert!(code.is_codeword(&code.codeword(j)));
        }
        assert_eq!(code.all_non_codewords().len(), 36 * 5);
    }
}
