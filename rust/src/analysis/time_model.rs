//! Closed-form job-completion-time model — **moved to
//! [`crate::sim::model`]**, where it is the zero-latency / homogeneous /
//! no-straggler degenerate case of the discrete-event cluster
//! simulator (asserted bit-equal in `rust/tests/sim_times.rs`). This
//! module remains as a re-export so existing `analysis::TimeModel`
//! callers keep working.

pub use crate::sim::model::TimeModel;
