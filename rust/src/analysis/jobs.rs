//! Minimum job requirements — the paper's Table III and §V comparison.
//!
//! CAMR needs `J = q^{k-1}` jobs; CCDC needs `C(K, μK+1)`. At the same
//! storage fraction `μ = (k-1)/K` (so `μK+1 = k`), CCDC's requirement is
//! `C(kq, k) ≥ q^k > q^{k-1}` — exponentially larger as `q` grows.

/// Exact binomial coefficient `C(n, r)` as u128 (Table III values fit
/// comfortably: C(100,5) = 75,287,520).
pub fn binomial(n: u64, r: u64) -> u128 {
    if r > n {
        return 0;
    }
    let r = r.min(n - r);
    let mut acc: u128 = 1;
    for i in 0..r {
        acc = acc * (n - i) as u128 / (i + 1) as u128;
    }
    acc
}

/// Job requirements of both schemes at equal storage fraction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobRequirement {
    /// Design parameter `k` (μK = k-1).
    pub k: usize,
    /// Design parameter `q` (K = kq).
    pub q: usize,
    /// Cluster size.
    pub servers: usize,
    /// `J_CAMR = q^{k-1}`.
    pub camr: u128,
    /// `J_CCDC,min = C(K, μK+1) = C(kq, k)`.
    pub ccdc: u128,
}

impl JobRequirement {
    /// Compute both requirements for `(k, q)`.
    pub fn for_params(k: usize, q: usize) -> Self {
        let servers = k * q;
        JobRequirement {
            k,
            q,
            servers,
            camr: (q as u128).pow(k as u32 - 1),
            ccdc: binomial(servers as u64, k as u64),
        }
    }

    /// The ratio CCDC / CAMR (how many times more jobs CCDC needs).
    pub fn ratio(&self) -> f64 {
        self.ccdc as f64 / self.camr as f64
    }
}

/// The rows of Table III: `K = 100`, `k ∈ {2, 4, 5}`.
pub fn table3() -> Vec<JobRequirement> {
    [(2usize, 50usize), (4, 25), (5, 20)]
        .into_iter()
        .map(|(k, q)| JobRequirement::for_params(k, q))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomial_basics() {
        assert_eq!(binomial(6, 3), 20);
        assert_eq!(binomial(100, 2), 4950);
        assert_eq!(binomial(100, 4), 3_921_225);
        assert_eq!(binomial(100, 5), 75_287_520);
        assert_eq!(binomial(5, 0), 1);
        assert_eq!(binomial(5, 5), 1);
        assert_eq!(binomial(3, 4), 0);
    }

    #[test]
    fn table3_matches_paper() {
        // Table III: K = 100;
        //   k=2 → CAMR 50,     CCDC 4,950
        //   k=4 → CAMR 15,625, CCDC 3,921,225
        //   k=5 → CAMR 160,000 CCDC 75,287,520
        let rows = table3();
        assert_eq!(rows[0].camr, 50);
        assert_eq!(rows[0].ccdc, 4950);
        assert_eq!(rows[1].camr, 15_625);
        assert_eq!(rows[1].ccdc, 3_921_225);
        assert_eq!(rows[2].camr, 160_000);
        assert_eq!(rows[2].ccdc, 75_287_520);
        for r in &rows {
            assert_eq!(r.servers, 100);
        }
    }

    #[test]
    fn paper_example_ccdc_needs_20_jobs() {
        // §III-C: "their approach would require a minimum of J = C(6,3)
        // = 20 distributed jobs" vs CAMR's 4.
        let r = JobRequirement::for_params(3, 2);
        assert_eq!(r.ccdc, 20);
        assert_eq!(r.camr, 4);
    }

    #[test]
    fn ccdc_requirement_dominates() {
        // §V bound: C(kq, k) ≥ q^k > q^{k-1} for all valid (k, q).
        for k in 2..8 {
            for q in 2..12 {
                let r = JobRequirement::for_params(k, q);
                assert!(
                    r.ccdc >= (q as u128).pow(k as u32),
                    "k={k} q={q}: C = {} < q^k",
                    r.ccdc
                );
                assert!(r.ccdc > r.camr);
            }
        }
    }

    #[test]
    fn ratio_grows_with_q() {
        let a = JobRequirement::for_params(4, 5).ratio();
        let b = JobRequirement::for_params(4, 25).ratio();
        assert!(b > a);
    }
}
