//! Closed-form analysis: communication loads (§IV, §V) and minimum job
//! requirements (Table III).

pub mod jobs;
pub mod load;
pub mod time_model;

pub use jobs::{binomial, JobRequirement};
pub use load::{LoadBreakdown, Scheme};
pub use time_model::TimeModel;
