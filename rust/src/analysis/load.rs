//! Communication-load closed forms (paper §IV and §V).
//!
//! All loads are normalized by `J·Q·B` (Definition 3).

/// Which scheme a load belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// The paper's scheme.
    Camr,
    /// Compressed Coded Distributed Computing (Li et al., Eq. (6)).
    Ccdc,
    /// Uncoded shuffle that still aggregates before sending.
    UncodedAggregated,
    /// Uncoded shuffle without aggregation (per-subfile values).
    UncodedRaw,
}

/// CAMR per-stage and total loads for parameters `(k, q)` (§IV).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadBreakdown {
    /// `k / (K(k-1))`.
    pub stage1: f64,
    /// `(q-1)·k / (K(k-1))`.
    pub stage2: f64,
    /// `(q-1)/q`.
    pub stage3: f64,
}

impl LoadBreakdown {
    /// Total `L_CAMR = (k(q-1)+1)/(q(k-1))`.
    pub fn total(&self) -> f64 {
        self.stage1 + self.stage2 + self.stage3
    }
}

/// CAMR per-stage loads (§IV).
pub fn camr_stages(k: usize, q: usize) -> LoadBreakdown {
    let (kf, qf) = (k as f64, q as f64);
    let cap_k = kf * qf;
    LoadBreakdown {
        stage1: kf / (cap_k * (kf - 1.0)),
        stage2: (qf - 1.0) * kf / (cap_k * (kf - 1.0)),
        stage3: (qf - 1.0) / qf,
    }
}

/// `L_CAMR = (k(q-1)+1)/(q(k-1))` (§IV).
pub fn camr_total(k: usize, q: usize) -> f64 {
    let (kf, qf) = (k as f64, q as f64);
    (kf * (qf - 1.0) + 1.0) / (qf * (kf - 1.0))
}

/// CCDC load at storage fraction `μ` with `μK ∈ {1, …, K-1}` (Eq. (6)):
/// `L_CCDC = (1-μ)(μK+1)/(μK)`.
pub fn ccdc_total(mu_k: usize, servers: usize) -> f64 {
    let r = mu_k as f64;
    let kf = servers as f64;
    let mu = r / kf;
    (1.0 - mu) * (r + 1.0) / r
}

/// Uncoded-but-aggregated shuffle under the Algorithm-1 placement: each
/// owner receives its missing batch aggregate (1 value), each non-owner
/// needs two complementary partial aggregates (no single server stores a
/// whole job): `L = (k + 2(K-k))/K = 2 - k/K`.
pub fn uncoded_aggregated_total(k: usize, q: usize) -> f64 {
    let cap_k = (k * q) as f64;
    2.0 - k as f64 / cap_k
}

/// Uncoded, *unaggregated* shuffle (per-subfile values cross the wire):
/// owners need `γ` values, non-owners `N = kγ`:
/// `L = γ·(k + (K-k)·k)/K` — larger by roughly a factor `γk`, which is
/// the compression gain the paper's Definition 1 unlocks.
pub fn uncoded_raw_total(k: usize, q: usize, gamma: usize) -> f64 {
    let cap_k = (k * q) as f64;
    let (kf, gf) = (k as f64, gamma as f64);
    (kf * gf + (cap_k - kf) * kf * gf) / cap_k
}

/// Expected *measured* CAMR bytes including packet padding: stages 1 and
/// 2 send packets of `⌈B/(k-1)⌉` bytes. Equals the closed form whenever
/// `(k-1) | B`.
pub fn camr_expected_bytes(k: usize, q: usize, value_bytes: usize, rounds: usize) -> usize {
    let j = q.pow(k as u32 - 1);
    let packet = value_bytes.div_ceil(k - 1);
    let s1 = j * k * packet;
    let s2 = j * (q - 1) * k * packet;
    let s3 = (k * q) * (j - j / q) * value_bytes;
    rounds * (s1 + s2 + s3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example1_stage_loads() {
        // §III-C: 1/4, 1/4, 1/2 → total 1.
        let b = camr_stages(3, 2);
        assert!((b.stage1 - 0.25).abs() < 1e-12);
        assert!((b.stage2 - 0.25).abs() < 1e-12);
        assert!((b.stage3 - 0.5).abs() < 1e-12);
        assert!((b.total() - 1.0).abs() < 1e-12);
        assert!((camr_total(3, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stages_sum_to_total_formula() {
        for k in 2..8 {
            for q in 2..8 {
                let b = camr_stages(k, q);
                assert!(
                    (b.total() - camr_total(k, q)).abs() < 1e-12,
                    "k={k} q={q}: {} vs {}",
                    b.total(),
                    camr_total(k, q)
                );
            }
        }
    }

    #[test]
    fn camr_equals_ccdc_at_same_mu() {
        // §V: with μK = k-1, Eq. (6) reduces to (k(q-1)+1)/(q(k-1)).
        for k in 2..10 {
            for q in 2..10 {
                let camr = camr_total(k, q);
                let ccdc = ccdc_total(k - 1, k * q);
                assert!(
                    (camr - ccdc).abs() < 1e-12,
                    "k={k} q={q}: CAMR {camr} vs CCDC {ccdc}"
                );
            }
        }
    }

    #[test]
    fn example1_ccdc_is_one() {
        // Paper: "the load achieved by the CCDC scheme … is L_CCDC = 1".
        assert!((ccdc_total(2, 6) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn coded_beats_uncoded_aggregated_for_k_ge_3() {
        for k in 3..8 {
            for q in 2..8 {
                assert!(
                    camr_total(k, q) < uncoded_aggregated_total(k, q),
                    "k={k} q={q}"
                );
            }
        }
        // k = 2 has no coding gain (chunks split into k-1 = 1 packet).
        for q in 2..8 {
            assert!((camr_total(2, q) - uncoded_aggregated_total(2, q)).abs() < 1e-12);
        }
    }

    #[test]
    fn aggregation_gain_scales_with_gamma() {
        // Raw shuffle is ~γk× worse than anything aggregated.
        let raw = uncoded_raw_total(3, 2, 4);
        let agg = uncoded_aggregated_total(3, 2);
        assert!(raw / agg > 4.0);
    }

    #[test]
    fn expected_bytes_match_formula_when_divisible() {
        // (k-1) | B → measured bytes = closed-form load × JQB exactly.
        for (k, q, b) in [(3usize, 2usize, 64usize), (5, 2, 64), (3, 3, 128), (4, 3, 66)] {
            let j = q.pow(k as u32 - 1);
            let jqb = (j * k * q * b) as f64;
            let expect = camr_total(k, q) * jqb;
            let measured = camr_expected_bytes(k, q, b, 1) as f64;
            assert!(
                (measured - expect).abs() < 1e-6,
                "k={k} q={q} B={b}: {measured} vs {expect}"
            );
        }
    }
}
