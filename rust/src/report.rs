//! Plain-text table rendering for CLI output and bench reports.

/// A simple fixed-width table builder (no external dependencies; output
/// is stable for snapshotting in tests).
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header arity).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:>width$}  ", cell, width = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new(vec!["k", "CAMR", "CCDC"]);
        t.row(vec!["2", "50", "4950"]);
        t.row(vec!["4", "15625", "3921225"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].contains("CAMR"));
        assert!(lines[2].contains("50"));
        assert!(lines[3].contains("3921225"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn rejects_wrong_arity() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
