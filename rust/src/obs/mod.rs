//! **Observability substrate**: structured tracing + a metrics registry
//! for every execution plane, with zero external dependencies.
//!
//! The repo runs CAMR rounds on four planes (serial [`Engine`],
//! thread-per-worker [`ParallelEngine`], loopback TCP, Unix-domain
//! sockets) and predicts their timing with [`crate::sim`]; this module
//! is how you see where the microseconds and bytes actually land.
//!
//! ## Span taxonomy
//!
//! A [`Span`] is one timed slice of protocol work, tagged with worker
//! id, job id, [`Stage`], schedule sequence number, and a byte count:
//!
//! | kind                 | covers                                             |
//! |----------------------|----------------------------------------------------|
//! | [`SpanKind::Map`]    | one worker's whole map phase                       |
//! | [`SpanKind::Encode`] | XOR-encoding one coded Δ for a delivery group      |
//! | [`SpanKind::Exchange`] | stage 1/2 recv window, or a stage-3 fuse/unicast |
//! | [`SpanKind::Decode`] | XOR-decoding the Δs of one delivery group          |
//! | [`SpanKind::Reduce`] | reducing one `(job, function)` output              |
//! | [`SpanKind::Verify`] | the coordinator's oracle verification pass         |
//! | [`SpanKind::FrameIo`]| writing one wire frame on the socket plane         |
//! | [`SpanKind::Queue`]  | a job's admission-queue wait in [`crate::service`] |
//!
//! Spans of one worker never overlap (the protocol is phase-sequential
//! per worker), so the Chrome export below is a flat, well-nested
//! timeline per thread.
//!
//! ## Overhead model
//!
//! Tracing is **off by default** and the disabled path is a no-op enum
//! branch: [`Tracer::Off`] hands out a [`SpanSink`] whose `begin` never
//! reads the clock and whose `record` returns before touching any
//! state, so an untraced run pays one `Option` check per would-be span.
//! When tracing is on, each worker thread appends to its own private
//! buffer ([`SpanSink`] — no shared state on the hot path) and the
//! buffers drain under a single mutex at flush (end of round / sink
//! drop). The ledger, schedule sequence numbers, and buffer-pool
//! traffic are byte-identical with tracing on or off — pinned by
//! `rust/tests/obs_trace.rs` against the golden fixture.
//!
//! Metrics counters (pool traffic, XOR kernel dispatch, frame codec,
//! dial retries…) are process-global atomics behind one relaxed
//! [`metrics_enabled`] load, so the default-off cost is a single
//! predictable branch per hook.
//!
//! ## Viewing a trace
//!
//! `camr run CONFIG --trace trace.json` (or `CAMR_TRACE=1`, or an
//! `[obs]` section with `trace = "out.json"`) writes Chrome
//! `trace_event` JSON. Open <https://ui.perfetto.dev> and drag the file
//! in (the legacy `chrome://tracing` viewer also loads it): one row per
//! worker (`tid` = worker id + 1; `tid 0` is the coordinator), one
//! slice per span, byte counts and schedule seqs in the slice args.
//! Subprocess socket workers ship their span batches back to the hub in
//! a [`crate::net::frame::FrameKind::Spans`] frame at round end, so
//! they appear on the same timeline (timebases are aligned at handshake
//! time, good to well under a millisecond on loopback).
//!
//! `camr trace CONFIG` runs a traced round and prints the per-worker ×
//! per-phase p50/p99/max table instead; `camr simulate` aligns the
//! measured phase roll-up against [`crate::sim::simulate`] predictions
//! ([`compare_with_sim`]).
//!
//! [`Engine`]: crate::coordinator::engine::Engine
//! [`ParallelEngine`]: crate::coordinator::parallel::ParallelEngine
//! [`Stage`]: crate::net::Stage

use crate::error::{CamrError, Result};
use crate::net::Stage;
use crate::sim::SimOutcome;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Pseudo worker id for coordinator-side spans (verification, hub
/// work). Exported to the trace as `tid 0`; real workers are `id + 1`.
pub const COORD: usize = usize::MAX;

// ---------------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------------

/// The type of protocol work a [`Span`] timed. See the module docs for
/// the taxonomy table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// One worker's map phase.
    Map,
    /// Encoding one coded Δ (XOR of the group's chunks).
    Encode,
    /// A shuffle exchange slice: the stage-1/2 receive window, or one
    /// stage-3 fuse + unicast. The [`Span::stage`] tag says which.
    Exchange,
    /// Decoding the received Δs of one delivery group.
    Decode,
    /// Reducing one `(job, function)` output.
    Reduce,
    /// Oracle verification of the round's outputs (coordinator side).
    Verify,
    /// Writing one frame on the socket wire.
    FrameIo,
    /// A job's admission-queue wait (submit → dequeue) in the
    /// continuous job service.
    Queue,
}

/// Every kind, in taxonomy order (stable codes = indices).
pub const SPAN_KINDS: [SpanKind; 8] = [
    SpanKind::Map,
    SpanKind::Encode,
    SpanKind::Exchange,
    SpanKind::Decode,
    SpanKind::Reduce,
    SpanKind::Verify,
    SpanKind::FrameIo,
    SpanKind::Queue,
];

impl SpanKind {
    /// Stable wire/bucket code (index into [`SPAN_KINDS`]).
    pub fn code(self) -> u8 {
        match self {
            SpanKind::Map => 0,
            SpanKind::Encode => 1,
            SpanKind::Exchange => 2,
            SpanKind::Decode => 3,
            SpanKind::Reduce => 4,
            SpanKind::Verify => 5,
            SpanKind::FrameIo => 6,
            SpanKind::Queue => 7,
        }
    }

    /// Inverse of [`Self::code`].
    pub fn from_code(code: u8) -> Result<Self> {
        SPAN_KINDS
            .get(code as usize)
            .copied()
            .ok_or_else(|| CamrError::Wire(format!("unknown span kind {code}")))
    }

    /// Event name in trace exports and tables.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Map => "map",
            SpanKind::Encode => "encode",
            SpanKind::Exchange => "exchange",
            SpanKind::Decode => "decode",
            SpanKind::Reduce => "reduce",
            SpanKind::Verify => "verify",
            SpanKind::FrameIo => "frame_io",
            SpanKind::Queue => "queue",
        }
    }
}

/// One timed slice of protocol work. Timestamps are nanoseconds since
/// the owning [`Tracer`]'s epoch (a monotonic [`Instant`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    /// What was timed.
    pub kind: SpanKind,
    /// Executing worker ([`COORD`] for coordinator-side spans).
    pub worker: usize,
    /// Paper job the work belonged to (0 when the slice spans jobs).
    pub job: usize,
    /// Shuffle stage, when the work is stage-scoped.
    pub stage: Option<Stage>,
    /// Schedule sequence number (0 when not schedule-driven).
    pub seq: u64,
    /// Bytes the slice moved/produced (0 when not byte-denominated).
    pub bytes: u64,
    /// Start, ns since the tracer epoch.
    pub start_ns: u64,
    /// Duration in ns.
    pub dur_ns: u64,
}

impl Span {
    /// The phase bucket this span rolls up into: `map`, `stage1..3`,
    /// `reduce`, `verify`, or `io` (stage-scoped kinds bucket by their
    /// stage tag).
    pub fn phase(&self) -> &'static str {
        match self.kind {
            SpanKind::Map => "map",
            SpanKind::Reduce => "reduce",
            SpanKind::Verify => "verify",
            SpanKind::FrameIo => "io",
            SpanKind::Queue => "queue",
            SpanKind::Encode | SpanKind::Exchange | SpanKind::Decode => match self.stage {
                Some(Stage::Stage1) => "stage1",
                Some(Stage::Stage2) => "stage2",
                Some(Stage::Stage3) => "stage3",
                Some(Stage::Baseline) => "baseline",
                None => "shuffle",
            },
        }
    }

    /// End timestamp, ns since the tracer epoch.
    pub fn end_ns(&self) -> u64 {
        self.start_ns + self.dur_ns
    }
}

/// Phase buckets in report order.
pub const PHASE_ORDER: [&str; 10] = [
    "queue", "map", "stage1", "stage2", "stage3", "baseline", "shuffle", "reduce", "verify", "io",
];

fn phase_rank(phase: &str) -> usize {
    PHASE_ORDER.iter().position(|p| *p == phase).unwrap_or(PHASE_ORDER.len())
}

fn stage_code(stage: Option<Stage>) -> u8 {
    match stage {
        Some(Stage::Stage1) => 0,
        Some(Stage::Stage2) => 1,
        Some(Stage::Stage3) => 2,
        Some(Stage::Baseline) => 3,
        None => u8::MAX,
    }
}

fn stage_from_code(code: u8) -> Result<Option<Stage>> {
    Ok(match code {
        0 => Some(Stage::Stage1),
        1 => Some(Stage::Stage2),
        2 => Some(Stage::Stage3),
        3 => Some(Stage::Baseline),
        u8::MAX => None,
        other => return Err(CamrError::Wire(format!("unknown span stage code {other}"))),
    })
}

// ---------------------------------------------------------------------------
// Tracer + sinks
// ---------------------------------------------------------------------------

/// Shared state of an enabled tracer: the epoch every span timestamp is
/// relative to, and the drained span buffers.
#[derive(Debug)]
pub struct TraceInner {
    epoch: Instant,
    spans: Mutex<Vec<Span>>,
}

/// Span collector for one run. [`Tracer::Off`] (the default) is the
/// no-op branch: sinks it hands out never read the clock or take a
/// lock. [`Tracer::On`] collects spans from every [`SpanSink`] clone —
/// worker threads buffer privately and drain under the one mutex at
/// flush. Cloning shares the collector.
#[derive(Debug, Clone, Default)]
pub enum Tracer {
    /// Tracing disabled — every operation is a no-op.
    #[default]
    Off,
    /// Tracing enabled; spans accumulate in the shared inner state.
    On(Arc<TraceInner>),
}

impl Tracer {
    /// A fresh enabled tracer whose epoch is now.
    pub fn on() -> Self {
        Tracer::On(Arc::new(TraceInner { epoch: Instant::now(), spans: Mutex::new(Vec::new()) }))
    }

    /// True on the [`Tracer::On`] branch.
    pub fn enabled(&self) -> bool {
        matches!(self, Tracer::On(_))
    }

    /// A per-thread span buffer feeding this tracer (no-op when off).
    pub fn sink(&self) -> SpanSink {
        SpanSink {
            inner: match self {
                Tracer::Off => None,
                Tracer::On(inner) => Some(Arc::clone(inner)),
            },
            buf: Vec::new(),
        }
    }

    /// Absorb already-timestamped spans (a remote worker's batch).
    /// Dropped when tracing is off.
    pub fn ingest(&self, mut spans: Vec<Span>) {
        if let Tracer::On(inner) = self {
            inner.spans.lock().expect("tracer poisoned").append(&mut spans);
        }
    }

    /// Drain every collected span, sorted by start time. Empty when off.
    pub fn take_spans(&self) -> Vec<Span> {
        match self {
            Tracer::Off => Vec::new(),
            Tracer::On(inner) => {
                let mut spans =
                    std::mem::take(&mut *inner.spans.lock().expect("tracer poisoned"));
                spans.sort_by_key(|s| (s.start_ns, s.worker, s.kind.code()));
                spans
            }
        }
    }
}

/// Capture of a span's start instant. Produced by [`SpanSink::begin`];
/// holds nothing on the disabled branch.
#[derive(Debug, Clone, Copy)]
pub struct SpanStart(Option<Instant>);

/// A thread-private span buffer. `begin`/`record` touch no shared
/// state; the buffer drains into the tracer under its mutex on
/// [`SpanSink::flush`] (called automatically on drop).
#[derive(Debug)]
pub struct SpanSink {
    inner: Option<Arc<TraceInner>>,
    buf: Vec<Span>,
}

impl SpanSink {
    /// A sink wired to nothing — every call is the no-op branch.
    /// Equivalent to `Tracer::Off.sink()`; handy as a field default.
    pub fn disabled() -> SpanSink {
        SpanSink { inner: None, buf: Vec::new() }
    }

    /// True when spans recorded here reach a live tracer.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Mark a span's start (reads the clock only when enabled).
    pub fn begin(&self) -> SpanStart {
        SpanStart(self.inner.as_ref().map(|_| Instant::now()))
    }

    /// Close a span opened by [`Self::begin`] and buffer it. The
    /// disabled branch returns immediately.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &mut self,
        start: SpanStart,
        kind: SpanKind,
        worker: usize,
        job: usize,
        stage: Option<Stage>,
        seq: u64,
        bytes: u64,
    ) {
        let (Some(inner), Some(t0)) = (self.inner.as_ref(), start.0) else {
            return; // Tracer::Off — the no-op branch.
        };
        let start_ns = t0.duration_since(inner.epoch).as_nanos() as u64;
        let dur_ns = t0.elapsed().as_nanos() as u64;
        if metrics_enabled() {
            metrics().span_duration_ns[kind.code() as usize].observe(dur_ns);
        }
        self.buf.push(Span { kind, worker, job, stage, seq, bytes, start_ns, dur_ns });
    }

    /// Drain the private buffer into the tracer (one mutex acquisition).
    pub fn flush(&mut self) {
        match &self.inner {
            Some(inner) if !self.buf.is_empty() => {
                inner.spans.lock().expect("tracer poisoned").append(&mut self.buf);
            }
            _ => self.buf.clear(),
        }
    }
}

impl Drop for SpanSink {
    fn drop(&mut self) {
        self.flush();
    }
}

// ---------------------------------------------------------------------------
// Metrics registry
// ---------------------------------------------------------------------------

/// A monotonically increasing named count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A signed instantaneous level (e.g. connected workers).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Move the level by `delta` (may be negative).
    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    /// Set the level outright.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current level.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Buckets in a [`Histogram`]: bucket `i` counts values in
/// `[2^i, 2^(i+1))` (bucket 0 also takes 0), enough for any u64.
pub const HIST_BUCKETS: usize = 64;

/// A fixed-bucket log2 histogram over `u64` observations — span
/// durations in ns, multicast payload bytes. Lock-free.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

/// The log2 bucket index a value lands in.
pub fn log2_bucket(v: u64) -> usize {
    match v {
        0 => 0,
        v => 63 - v.leading_zeros() as usize,
    }
}

impl Histogram {
    /// Record one observation.
    pub fn observe(&self, v: u64) {
        self.buckets[log2_bucket(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Observations so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Upper bound (`2^(i+1) - 1`) of the bucket holding quantile `q`
    /// of the recorded observations; 0 when empty. Bucket-granular by
    /// construction — exact percentiles come from raw span lists
    /// ([`summarize`]), this is the cheap always-on estimate.
    pub fn quantile_upper_bound(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return if i >= 63 { u64::MAX } else { (1u64 << (i + 1)) - 1 };
            }
        }
        u64::MAX
    }

    /// Non-empty buckets as `(bucket_index, count)` pairs.
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                (c > 0).then_some((i, c))
            })
            .collect()
    }
}

/// The process-global registry of named counters/gauges/histograms.
/// Hooks on hot paths (pool, XOR kernels, frame codec) consult
/// [`metrics_enabled`] first, so the default-off cost is one relaxed
/// atomic load.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Buffer-pool checkouts ([`crate::shuffle::buf::BufferPool`]).
    pub pool_acquired: Counter,
    /// Buffer-pool returns.
    pub pool_released: Counter,
    /// Large-class returns whose backing was freed, not retained.
    pub pool_dropped: Counter,
    /// `xor_into` dispatches per kernel tier, indexed like
    /// [`crate::shuffle::buf::XorKernel`] labels: bytewise,
    /// portable_u64, avx2, neon.
    pub xor_calls: [Counter; 4],
    /// Bytes XORed through the dispatched kernel.
    pub xor_bytes: Counter,
    /// Frames serialized by the wire codec.
    pub frames_encoded: Counter,
    /// Frames successfully parsed by the wire codec.
    pub frames_decoded: Counter,
    /// Payload bytes per coded multicast (log2 buckets).
    pub multicast_bytes: Histogram,
    /// Socket dial attempts that had to retry.
    pub dial_retries: Counter,
    /// Hub waits that hit the disconnect timeout.
    pub disconnect_timeouts: Counter,
    /// Workers currently connected to a hub.
    pub workers_connected: Gauge,
    /// Jobs admitted by the continuous job service.
    pub jobs_submitted: Counter,
    /// Typed `QueueFull` rejections returned by the service.
    pub jobs_rejected: Counter,
    /// Jobs the service ran to completion (including failed rounds).
    pub jobs_completed: Counter,
    /// Span durations in ns, one histogram per [`SpanKind`] code.
    pub span_duration_ns: [Histogram; 8],
}

impl Metrics {
    /// The XOR dispatch counter for a kernel label (see
    /// [`crate::shuffle::buf::XorKernel::label`]).
    pub fn xor_calls_for(&self, label: &str) -> &Counter {
        match label {
            "bytewise" => &self.xor_calls[0],
            "portable_u64" => &self.xor_calls[1],
            "avx2" => &self.xor_calls[2],
            _ => &self.xor_calls[3],
        }
    }

    /// Every scalar metric as stable `(name, value)` pairs (histograms
    /// export count/sum/p50/p99 upper bounds).
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        let mut out: Vec<(String, u64)> = vec![
            ("pool.acquired".into(), self.pool_acquired.get()),
            ("pool.released".into(), self.pool_released.get()),
            ("pool.dropped".into(), self.pool_dropped.get()),
            ("xor.calls.bytewise".into(), self.xor_calls[0].get()),
            ("xor.calls.portable_u64".into(), self.xor_calls[1].get()),
            ("xor.calls.avx2".into(), self.xor_calls[2].get()),
            ("xor.calls.neon".into(), self.xor_calls[3].get()),
            ("xor.bytes".into(), self.xor_bytes.get()),
            ("frame.encoded".into(), self.frames_encoded.get()),
            ("frame.decoded".into(), self.frames_decoded.get()),
            ("multicast.bytes.count".into(), self.multicast_bytes.count()),
            ("multicast.bytes.sum".into(), self.multicast_bytes.sum()),
            ("net.dial_retries".into(), self.dial_retries.get()),
            ("net.disconnect_timeouts".into(), self.disconnect_timeouts.get()),
            ("net.workers_connected".into(), self.workers_connected.get().max(0) as u64),
            ("service.jobs_submitted".into(), self.jobs_submitted.get()),
            ("service.jobs_rejected".into(), self.jobs_rejected.get()),
            ("service.jobs_completed".into(), self.jobs_completed.get()),
        ];
        for (kind, h) in SPAN_KINDS.iter().zip(&self.span_duration_ns) {
            let base = format!("span.{}.ns", kind.name());
            out.push((format!("{base}.count"), h.count()));
            out.push((format!("{base}.sum"), h.sum()));
            out.push((format!("{base}.p50_le"), h.quantile_upper_bound(0.50)));
            out.push((format!("{base}.p99_le"), h.quantile_upper_bound(0.99)));
        }
        out
    }
}

static METRICS: OnceLock<Metrics> = OnceLock::new();
static METRICS_ENABLED: AtomicBool = AtomicBool::new(false);

/// The process-global metrics registry.
pub fn metrics() -> &'static Metrics {
    METRICS.get_or_init(Metrics::default)
}

/// Whether hot-path hooks should record into the registry. Off by
/// default; one relaxed load per hook when off.
pub fn metrics_enabled() -> bool {
    METRICS_ENABLED.load(Ordering::Relaxed)
}

/// Turn hot-path metric hooks on or off (process-wide).
pub fn set_metrics_enabled(on: bool) {
    METRICS_ENABLED.store(on, Ordering::Relaxed);
}

/// The trace destination requested via `CAMR_TRACE`: unset/`0`/empty →
/// none, `1`/`true` → `trace.json`, anything else → that path.
pub fn env_trace_destination() -> Option<String> {
    match std::env::var("CAMR_TRACE") {
        Ok(v) if v.is_empty() || v == "0" => None,
        Ok(v) if v == "1" || v == "true" => Some("trace.json".into()),
        Ok(v) => Some(v),
        Err(_) => None,
    }
}

// ---------------------------------------------------------------------------
// Span batch wire format (FrameKind::Spans payloads)
// ---------------------------------------------------------------------------

/// Bytes per encoded span record.
const SPAN_RECORD_BYTES: usize = 48;

/// Hard cap on spans per batch (matches the frame payload cap at any
/// plausible record size and bounds hub-side allocation).
const MAX_SPANS_PER_BATCH: usize = 1 << 22;

/// Serialize a span batch for a [`crate::net::frame::FrameKind::Spans`]
/// payload: a LE `u32` count, then 48-byte records.
pub fn encode_spans(spans: &[Span]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + spans.len() * SPAN_RECORD_BYTES);
    out.extend_from_slice(&(spans.len() as u32).to_le_bytes());
    for s in spans {
        let worker = if s.worker == COORD { u32::MAX } else { s.worker as u32 };
        out.push(s.kind.code());
        out.push(stage_code(s.stage));
        out.extend_from_slice(&[0u8; 2]);
        out.extend_from_slice(&worker.to_le_bytes());
        out.extend_from_slice(&(s.job as u64).to_le_bytes());
        out.extend_from_slice(&s.seq.to_le_bytes());
        out.extend_from_slice(&s.bytes.to_le_bytes());
        out.extend_from_slice(&s.start_ns.to_le_bytes());
        out.extend_from_slice(&s.dur_ns.to_le_bytes());
    }
    out
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes(b[..4].try_into().expect("4 bytes"))
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().expect("8 bytes"))
}

/// Parse a span batch produced by [`encode_spans`]. Typed wire errors
/// on truncation, trailing bytes, or unknown codes.
pub fn decode_spans(payload: &[u8]) -> Result<Vec<Span>> {
    if payload.len() < 4 {
        return Err(CamrError::Wire(format!("span batch truncated: {} bytes", payload.len())));
    }
    let count = le_u32(payload) as usize;
    if count > MAX_SPANS_PER_BATCH {
        return Err(CamrError::Wire(format!("span batch of {count} spans exceeds the cap")));
    }
    let body = &payload[4..];
    if body.len() != count * SPAN_RECORD_BYTES {
        return Err(CamrError::Wire(format!(
            "span batch length {} != {count} records of {SPAN_RECORD_BYTES} bytes",
            body.len()
        )));
    }
    let mut spans = Vec::with_capacity(count);
    for rec in body.chunks_exact(SPAN_RECORD_BYTES) {
        let worker = le_u32(&rec[4..]);
        spans.push(Span {
            kind: SpanKind::from_code(rec[0])?,
            stage: stage_from_code(rec[1])?,
            worker: if worker == u32::MAX { COORD } else { worker as usize },
            job: le_u64(&rec[8..]) as usize,
            seq: le_u64(&rec[16..]),
            bytes: le_u64(&rec[24..]),
            start_ns: le_u64(&rec[32..]),
            dur_ns: le_u64(&rec[40..]),
        });
    }
    Ok(spans)
}

// ---------------------------------------------------------------------------
// Exporters
// ---------------------------------------------------------------------------

fn tid_of(worker: usize) -> u128 {
    if worker == COORD {
        0
    } else {
        worker as u128 + 1
    }
}

/// Render spans as a Chrome `trace_event` document (the "JSON Object
/// Format": `{"traceEvents": [...]}`), viewable in Perfetto. Every
/// event carries the required `ph`/`ts`/`pid`/`tid`/`name` keys; spans
/// become `B`/`E` pairs emitted per-thread in start order, so the
/// per-tid begin/end nesting is well-formed by construction.
pub fn chrome_trace(spans: &[Span]) -> Json {
    let mut sorted: Vec<&Span> = spans.iter().collect();
    sorted.sort_by_key(|s| (tid_of(s.worker), s.start_ns, s.dur_ns, s.kind.code()));
    let mut events = Vec::with_capacity(sorted.len() * 2);
    for s in sorted {
        let tid = Json::UInt(tid_of(s.worker));
        events.push(Json::obj(vec![
            ("ph", Json::Str("B".into())),
            ("ts", Json::Num(s.start_ns as f64 / 1000.0)),
            ("pid", Json::UInt(1)),
            ("tid", tid.clone()),
            ("name", Json::Str(s.kind.name().into())),
            ("cat", Json::Str(s.phase().into())),
            (
                "args",
                Json::obj(vec![
                    ("job", Json::UInt(s.job as u128)),
                    ("seq", Json::UInt(s.seq as u128)),
                    ("bytes", Json::UInt(s.bytes as u128)),
                ]),
            ),
        ]));
        events.push(Json::obj(vec![
            ("ph", Json::Str("E".into())),
            ("ts", Json::Num(s.end_ns() as f64 / 1000.0)),
            ("pid", Json::UInt(1)),
            ("tid", tid),
            ("name", Json::Str(s.kind.name().into())),
        ]));
    }
    Json::obj(vec![
        ("displayTimeUnit", Json::Str("ms".into())),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Write [`chrome_trace`] to `path`.
pub fn write_chrome_trace(path: &Path, spans: &[Span]) -> Result<()> {
    std::fs::write(path, chrome_trace(spans).render())?;
    Ok(())
}

/// One row of the per-worker × per-phase summary: exact percentiles
/// over that bucket's span durations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseStat {
    /// Worker id ([`COORD`] for coordinator rows).
    pub worker: usize,
    /// Phase bucket ([`Span::phase`]).
    pub phase: &'static str,
    /// Spans in the bucket.
    pub count: usize,
    /// Summed span duration, ns.
    pub total_ns: u64,
    /// Median span duration, ns.
    pub p50_ns: u64,
    /// 99th-percentile span duration, ns.
    pub p99_ns: u64,
    /// Longest span, ns.
    pub max_ns: u64,
    /// Summed byte tags.
    pub bytes: u64,
}

/// Nearest-rank percentile of an already-sorted sample; 0 when empty.
/// Shared by the trace tables and the service's sojourn reports.
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Roll spans up into per-worker × per-phase duration statistics,
/// ordered by worker then [`PHASE_ORDER`] (coordinator rows last).
pub fn summarize(spans: &[Span]) -> Vec<PhaseStat> {
    let mut groups: BTreeMap<(usize, usize), (Vec<u64>, u64)> = BTreeMap::new();
    for s in spans {
        let g = groups.entry((s.worker, phase_rank(s.phase()))).or_default();
        g.0.push(s.dur_ns);
        g.1 += s.bytes;
    }
    groups
        .into_iter()
        .map(|((worker, rank), (mut durs, bytes))| {
            durs.sort_unstable();
            PhaseStat {
                worker,
                phase: PHASE_ORDER.get(rank).copied().unwrap_or("other"),
                count: durs.len(),
                total_ns: durs.iter().sum(),
                p50_ns: percentile(&durs, 0.50),
                p99_ns: percentile(&durs, 0.99),
                max_ns: *durs.last().unwrap_or(&0),
                bytes,
            }
        })
        .collect()
}

/// Wall-clock window of one phase across all workers: earliest span
/// start to latest span end. These are the measured counterparts of the
/// simulator's barrier-separated phases (both derive their boundaries
/// from the same schedule structure — [`crate::net::stage_runs`]).
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseRollup {
    /// Phase bucket ([`Span::phase`]).
    pub phase: &'static str,
    /// Window length in seconds.
    pub secs: f64,
    /// Spans inside the window.
    pub spans: usize,
    /// Summed byte tags.
    pub bytes: u64,
}

/// Per-phase wall windows over a span set, in [`PHASE_ORDER`]. The
/// `io`, `verify`, and `queue` buckets are excluded (they overlap
/// protocol phases — queue waits span whole rounds of other jobs).
pub fn phase_rollup(spans: &[Span]) -> Vec<PhaseRollup> {
    let mut windows: BTreeMap<usize, (u64, u64, usize, u64)> = BTreeMap::new();
    for s in spans {
        let phase = s.phase();
        if phase == "io" || phase == "verify" || phase == "queue" {
            continue;
        }
        let w = windows
            .entry(phase_rank(phase))
            .or_insert((u64::MAX, 0, 0, 0));
        w.0 = w.0.min(s.start_ns);
        w.1 = w.1.max(s.end_ns());
        w.2 += 1;
        w.3 += s.bytes;
    }
    windows
        .into_iter()
        .map(|(rank, (start, end, spans, bytes))| PhaseRollup {
            phase: PHASE_ORDER.get(rank).copied().unwrap_or("other"),
            secs: end.saturating_sub(start) as f64 / 1e9,
            spans,
            bytes,
        })
        .collect()
}

/// One phase of the sim-vs-measured comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct SimComparison {
    /// Phase bucket (`map`, `stage1..3`).
    pub phase: &'static str,
    /// The simulator's predicted phase time, seconds.
    pub sim_secs: f64,
    /// The measured phase window, seconds.
    pub measured_secs: f64,
    /// `(measured - sim) / sim`; 0 when the prediction is 0.
    pub rel_err: f64,
}

/// Align a measured [`phase_rollup`] against a [`SimOutcome`]'s
/// predicted phase times. Both sides bucket by the same barriers
/// (`map`, then one bucket per [`crate::net::stage_runs`] stage), so
/// the relative error is phase-for-phase meaningful.
pub fn compare_with_sim(rollup: &[PhaseRollup], sim: &SimOutcome) -> Vec<SimComparison> {
    let measured = |phase: &str| -> f64 {
        rollup.iter().find(|r| r.phase == phase).map_or(0.0, |r| r.secs)
    };
    let pairs = [
        ("map", sim.map_secs),
        ("stage1", sim.stage_secs(Stage::Stage1)),
        ("stage2", sim.stage_secs(Stage::Stage2)),
        ("stage3", sim.stage_secs(Stage::Stage3)),
    ];
    pairs
        .into_iter()
        .map(|(phase, sim_secs)| {
            let m = measured(phase);
            SimComparison {
                phase,
                sim_secs,
                measured_secs: m,
                rel_err: if sim_secs > 0.0 { (m - sim_secs) / sim_secs } else { 0.0 },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(
        kind: SpanKind,
        worker: usize,
        stage: Option<Stage>,
        start_ns: u64,
        dur_ns: u64,
    ) -> Span {
        Span { kind, worker, job: 0, stage, seq: 0, bytes: 64, start_ns, dur_ns }
    }

    #[test]
    fn off_tracer_is_a_noop_branch() {
        let t = Tracer::Off;
        assert!(!t.enabled());
        let mut sink = t.sink();
        assert!(!sink.enabled());
        let s = sink.begin();
        assert!(s.0.is_none(), "Off tracer must not read the clock");
        sink.record(s, SpanKind::Map, 0, 0, None, 0, 0);
        sink.flush();
        assert!(t.take_spans().is_empty());
        // Default is the Off branch.
        assert!(!Tracer::default().enabled());
    }

    #[test]
    fn enabled_tracer_collects_across_sinks_and_threads() {
        let t = Tracer::on();
        assert!(t.enabled());
        std::thread::scope(|scope| {
            for w in 0..3usize {
                let t = t.clone();
                scope.spawn(move || {
                    let mut sink = t.sink();
                    let s = sink.begin();
                    sink.record(s, SpanKind::Map, w, 0, None, 0, 10);
                    // flush happens on sink drop
                });
            }
        });
        let spans = t.take_spans();
        assert_eq!(spans.len(), 3);
        let mut workers: Vec<usize> = spans.iter().map(|s| s.worker).collect();
        workers.sort_unstable();
        assert_eq!(workers, vec![0, 1, 2]);
        assert!(t.take_spans().is_empty(), "take_spans drains");
    }

    #[test]
    fn span_batch_roundtrips_on_the_wire() {
        let spans = vec![
            Span {
                kind: SpanKind::Encode,
                worker: 3,
                job: 2,
                stage: Some(Stage::Stage2),
                seq: 17,
                bytes: 4096,
                start_ns: 1_000,
                dur_ns: 250,
            },
            Span {
                kind: SpanKind::Verify,
                worker: COORD,
                job: 0,
                stage: None,
                seq: 0,
                bytes: 0,
                start_ns: 9_999,
                dur_ns: 1,
            },
        ];
        let enc = encode_spans(&spans);
        assert_eq!(enc.len(), 4 + 2 * 48);
        assert_eq!(decode_spans(&enc).unwrap(), spans);
        // Ingest path.
        let t = Tracer::on();
        t.ingest(decode_spans(&enc).unwrap());
        assert_eq!(t.take_spans().len(), 2);
    }

    #[test]
    fn span_batch_decode_rejects_malformed_payloads() {
        assert!(decode_spans(&[1, 2]).is_err(), "short header");
        let mut enc = encode_spans(&[span(SpanKind::Map, 0, None, 0, 1)]);
        enc.push(0);
        assert!(decode_spans(&enc).is_err(), "trailing byte");
        let mut bad_kind = encode_spans(&[span(SpanKind::Map, 0, None, 0, 1)]);
        bad_kind[4] = 99;
        assert!(decode_spans(&bad_kind).is_err(), "unknown kind code");
        let mut bad_stage = encode_spans(&[span(SpanKind::Map, 0, None, 0, 1)]);
        bad_stage[5] = 7;
        assert!(decode_spans(&bad_stage).is_err(), "unknown stage code");
        let mut huge = Vec::new();
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_spans(&huge).is_err(), "count over cap");
    }

    #[test]
    fn chrome_trace_events_carry_required_keys_and_pair_up() {
        let spans = vec![
            span(SpanKind::Map, 1, None, 0, 100),
            span(SpanKind::Encode, 1, Some(Stage::Stage1), 100, 50),
            span(SpanKind::Verify, COORD, None, 200, 10),
        ];
        let doc = chrome_trace(&spans);
        let Some(Json::Arr(events)) = doc.get("traceEvents") else {
            panic!("traceEvents array missing");
        };
        assert_eq!(events.len(), 6);
        let mut open: BTreeMap<String, u64> = BTreeMap::new();
        for ev in events {
            for key in ["ph", "ts", "pid", "tid", "name"] {
                assert!(ev.get(key).is_some(), "event missing {key}: {}", ev.render());
            }
            let tid = ev.get("tid").unwrap().render();
            match ev.get("ph") {
                Some(Json::Str(ph)) if ph == "B" => *open.entry(tid).or_default() += 1,
                Some(Json::Str(ph)) if ph == "E" => {
                    let depth = open.entry(tid).or_default();
                    assert!(*depth > 0, "E without open B");
                    *depth -= 1;
                }
                other => panic!("unexpected ph {other:?}"),
            }
        }
        assert!(open.values().all(|d| *d == 0), "unclosed spans: {open:?}");
        // Coordinator spans ride tid 0.
        assert!(events.iter().any(|e| e.get("tid") == Some(&Json::UInt(0))));
    }

    #[test]
    fn summarize_buckets_by_worker_and_phase() {
        let spans = vec![
            span(SpanKind::Encode, 0, Some(Stage::Stage1), 0, 10),
            span(SpanKind::Decode, 0, Some(Stage::Stage1), 10, 30),
            span(SpanKind::Encode, 1, Some(Stage::Stage2), 0, 7),
            span(SpanKind::Map, 0, None, 0, 5),
        ];
        let stats = summarize(&spans);
        assert_eq!(stats.len(), 3);
        // Worker 0 rows first, map before stage1 (PHASE_ORDER).
        assert_eq!((stats[0].worker, stats[0].phase), (0, "map"));
        assert_eq!((stats[1].worker, stats[1].phase), (0, "stage1"));
        assert_eq!((stats[2].worker, stats[2].phase), (1, "stage2"));
        assert_eq!(stats[1].count, 2);
        assert_eq!(stats[1].total_ns, 40);
        assert_eq!(stats[1].max_ns, 30);
        assert_eq!(stats[1].bytes, 128);
    }

    #[test]
    fn percentiles_are_exact_over_the_bucket() {
        let durs: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&durs, 0.50), 51, "round half up over 100 samples");
        assert_eq!(percentile(&durs, 0.99), 99);
        assert_eq!(percentile(&durs, 1.0), 100);
        assert_eq!(percentile(&[], 0.5), 0);
    }

    #[test]
    fn phase_rollup_windows_span_workers() {
        let spans = vec![
            span(SpanKind::Encode, 0, Some(Stage::Stage1), 100, 50),
            span(SpanKind::Decode, 1, Some(Stage::Stage1), 200, 300),
            span(SpanKind::Map, 0, None, 0, 80),
            span(SpanKind::Verify, COORD, None, 0, 1_000_000), // excluded
        ];
        let roll = phase_rollup(&spans);
        assert_eq!(roll.len(), 2);
        assert_eq!(roll[0].phase, "map");
        assert!((roll[0].secs - 80e-9).abs() < 1e-15);
        assert_eq!(roll[1].phase, "stage1");
        // Window = min start 100 → max end 500.
        assert!((roll[1].secs - 400e-9).abs() < 1e-15);
        assert_eq!(roll[1].spans, 2);
    }

    #[test]
    fn log2_histogram_buckets_and_quantiles() {
        assert_eq!(log2_bucket(0), 0);
        assert_eq!(log2_bucket(1), 0);
        assert_eq!(log2_bucket(2), 1);
        assert_eq!(log2_bucket(3), 1);
        assert_eq!(log2_bucket(4), 2);
        assert_eq!(log2_bucket(u64::MAX), 63);
        let h = Histogram::default();
        assert_eq!(h.quantile_upper_bound(0.5), 0);
        for v in [1u64, 1, 1, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 1003);
        assert_eq!(h.quantile_upper_bound(0.5), 1, "bucket 0 upper bound");
        assert_eq!(h.quantile_upper_bound(0.99), 1023, "bucket [512,1024)");
        assert_eq!(h.nonzero_buckets(), vec![(0, 3), (9, 1)]);
    }

    #[test]
    fn metrics_registry_counts_and_snapshots() {
        let m = Metrics::default();
        m.pool_acquired.add(3);
        m.pool_released.inc();
        m.xor_calls_for("avx2").inc();
        m.xor_calls_for("portable_u64").add(2);
        m.workers_connected.add(2);
        m.workers_connected.add(-1);
        m.multicast_bytes.observe(64);
        let snap: BTreeMap<String, u64> = m.snapshot().into_iter().collect();
        assert_eq!(snap["pool.acquired"], 3);
        assert_eq!(snap["pool.released"], 1);
        assert_eq!(snap["xor.calls.avx2"], 1);
        assert_eq!(snap["xor.calls.portable_u64"], 2);
        assert_eq!(snap["net.workers_connected"], 1);
        assert_eq!(snap["multicast.bytes.count"], 1);
        assert_eq!(snap["multicast.bytes.sum"], 64);
    }

    #[test]
    fn global_toggle_defaults_off() {
        // Other tests may flip it; assert the API works, then restore.
        let was = metrics_enabled();
        set_metrics_enabled(true);
        assert!(metrics_enabled());
        metrics().frames_encoded.inc();
        set_metrics_enabled(was);
    }

    #[test]
    fn env_trace_destination_parses_the_convention() {
        // Can't mutate process env safely under the parallel test
        // runner; exercise the mapping through a run with the var unset.
        if std::env::var_os("CAMR_TRACE").is_none() {
            assert_eq!(env_trace_destination(), None);
        }
    }

    #[test]
    fn sim_comparison_reports_relative_error() {
        let roll = vec![
            PhaseRollup { phase: "map", secs: 2.0, spans: 1, bytes: 0 },
            PhaseRollup { phase: "stage1", secs: 1.5, spans: 2, bytes: 128 },
        ];
        // A hand-built SimOutcome: map 1 s, stage1 1 s, stage2 absent.
        let sim = SimOutcome {
            map_secs: 1.0,
            phases: vec![crate::sim::PhaseTime {
                stage: Stage::Stage1,
                transmissions: 2,
                bytes: 128,
                secs: 1.0,
            }],
            shuffle_secs: 1.0,
            total_secs: 2.0,
            map_tasks: 4,
            transmissions: 2,
            shuffle_bytes: 128,
            events: 4,
        };
        let cmp = compare_with_sim(&roll, &sim);
        assert_eq!(cmp.len(), 4);
        assert_eq!(cmp[0].phase, "map");
        assert!((cmp[0].rel_err - 1.0).abs() < 1e-12, "measured 2s vs sim 1s");
        assert!((cmp[1].rel_err - 0.5).abs() < 1e-12);
        assert_eq!(cmp[2].measured_secs, 0.0);
        assert_eq!(cmp[2].rel_err, 0.0, "zero prediction pins rel_err to 0");
    }
}
