//! System configuration and validation.
//!
//! CAMR's parameters (paper §III-A):
//! - `k`, `q` factor the cluster size `K = k·q`;
//! - the job count is forced to `J = q^(k-1)` by the SPC-code design;
//! - each job's data set is split into `N = k·γ` subfiles grouped into
//!   `k` batches of `γ` subfiles;
//! - each server stores `μ = (k-1)/K` of the union of all data sets;
//! - `Q` output functions per job with `K | Q`; the paper presents
//!   `Q = K` and repeats the shuffle `Q/K` times for larger `Q`
//!   (we expose that as `rounds = Q/K`).

use crate::error::{CamrError, Result};
use crate::util::cfgtext::CfgText;

/// Core system parameters for a CAMR deployment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SystemConfig {
    /// Block-design parameter `k`: batches per job, owners per job,
    /// and the SPC code length. Must be ≥ 2.
    pub k: usize,
    /// Design parameter `q`: the SPC alphabet size and the number of
    /// blocks per parallel class. Must be ≥ 2.
    pub q: usize,
    /// Subfiles per batch (`γ` in the paper). Must be ≥ 1.
    pub gamma: usize,
    /// Number of shuffle rounds: `Q = rounds · K` output functions per
    /// job. Defaults to 1 (the paper's `Q = K` presentation).
    pub rounds: usize,
    /// Size in bytes of every intermediate value `ν` (the paper's `B`,
    /// expressed in bytes). Aggregates of any number of values are also
    /// `value_bytes` long — that is the point of aggregation.
    pub value_bytes: usize,
}

impl SystemConfig {
    /// Create a config with `Q = K` and a default 64-byte value size.
    ///
    /// Errors if `k < 2`, `q < 2` or `gamma < 1`.
    pub fn new(k: usize, q: usize, gamma: usize) -> Result<Self> {
        Self::with_options(k, q, gamma, 1, 64)
    }

    /// Create a fully-specified config.
    pub fn with_options(
        k: usize,
        q: usize,
        gamma: usize,
        rounds: usize,
        value_bytes: usize,
    ) -> Result<Self> {
        let cfg = SystemConfig { k, q, gamma, rounds, value_bytes };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Validate all parameter constraints from §II–§III.
    pub fn validate(&self) -> Result<()> {
        if self.k < 2 {
            return Err(CamrError::InvalidConfig(format!(
                "k must be >= 2 (got {}): Algorithm 2 splits chunks into k-1 packets",
                self.k
            )));
        }
        if self.q < 2 {
            return Err(CamrError::InvalidConfig(format!(
                "q must be >= 2 (got {}): each parallel class needs >= 2 blocks",
                self.q
            )));
        }
        if self.gamma < 1 {
            return Err(CamrError::InvalidConfig("gamma must be >= 1".into()));
        }
        if self.rounds < 1 {
            return Err(CamrError::InvalidConfig("rounds must be >= 1".into()));
        }
        if self.value_bytes == 0 {
            return Err(CamrError::InvalidConfig("value_bytes must be > 0".into()));
        }
        // Guard against absurd design sizes (q^(k-1) jobs).
        let j = (self.q as f64).powi(self.k as i32 - 1);
        if j > 1e9 {
            return Err(CamrError::InvalidConfig(format!(
                "q^(k-1) = {j:.3e} jobs is too large to simulate"
            )));
        }
        Ok(())
    }

    /// Cluster size `K = k·q`.
    pub fn servers(&self) -> usize {
        self.k * self.q
    }

    /// Number of jobs `J = q^(k-1)` dictated by the SPC design.
    pub fn jobs(&self) -> usize {
        self.q.pow(self.k as u32 - 1)
    }

    /// Output functions per job, `Q = rounds · K`.
    pub fn functions(&self) -> usize {
        self.rounds * self.servers()
    }

    /// Subfiles per job, `N = k·γ`.
    pub fn subfiles(&self) -> usize {
        self.k * self.gamma
    }

    /// Batches per job (= `k`).
    pub fn batches(&self) -> usize {
        self.k
    }

    /// The storage fraction `μ = (k-1)/K` (Definition 2 / §III-A).
    pub fn storage_fraction(&self) -> f64 {
        (self.k as f64 - 1.0) / self.servers() as f64
    }

    /// The normalizer `J·Q·B` (Definition 3), in bytes.
    pub fn load_normalizer(&self) -> f64 {
        self.jobs() as f64 * self.functions() as f64 * self.value_bytes as f64
    }

    /// The reducer server of function `f` (round-robin; with `Q = K`
    /// this is the identity `φ_k → U_k`).
    pub fn reducer_of(&self, f: crate::FuncId) -> crate::ServerId {
        f % self.servers()
    }

    /// All functions reduced by server `s`: `{s, s+K, …}`.
    pub fn functions_of(&self, s: crate::ServerId) -> Vec<crate::FuncId> {
        (0..self.rounds).map(|r| r * self.servers() + s).collect()
    }
}

/// Workload selector for the CLI / config file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Word counting over synthetic "books" (paper Example 1).
    WordCount,
    /// Distributed matrix–vector products (NN forward pass shards).
    MatVec,
    /// Distributed gradient aggregation (SGD motivation, §I).
    Gradient,
    /// Random opaque byte values (load/stress testing).
    Synthetic,
    /// Chunk-streamed huge subfiles: maps fold over pooled chunks
    /// instead of materializing whole subfiles (hundreds-of-MB regime).
    Streamed,
}

impl WorkloadKind {
    /// Canonical name, re-parseable by [`WorkloadKind::parse`] (used to
    /// ship the workload selection to socket-transport worker
    /// processes).
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::WordCount => "word_count",
            WorkloadKind::MatVec => "mat_vec",
            WorkloadKind::Gradient => "gradient",
            WorkloadKind::Synthetic => "synthetic",
            WorkloadKind::Streamed => "streamed",
        }
    }

    /// Parse a workload name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "word_count" | "wordcount" => WorkloadKind::WordCount,
            "mat_vec" | "matvec" => WorkloadKind::MatVec,
            "gradient" => WorkloadKind::Gradient,
            "synthetic" => WorkloadKind::Synthetic,
            "streamed" => WorkloadKind::Streamed,
            other => {
                return Err(CamrError::InvalidConfig(format!(
                    "unknown workload {other} \
                     (word_count | mat_vec | gradient | synthetic | streamed)"
                )))
            }
        })
    }
}

/// Which data plane `camr run` moves packets over.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportChoice {
    /// Single-threaded serial engine (no packet plane at all).
    #[default]
    Serial,
    /// Thread-per-worker engine over in-process channels.
    Chan,
    /// Worker subprocesses over loopback TCP.
    Tcp,
    /// Worker subprocesses over a Unix-domain socket.
    Unix,
}

impl TransportChoice {
    /// Parse a transport name (CLI `--transport` / `[transport] kind`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "serial" => TransportChoice::Serial,
            "chan" | "channel" => TransportChoice::Chan,
            "tcp" => TransportChoice::Tcp,
            "unix" => TransportChoice::Unix,
            other => {
                return Err(CamrError::InvalidConfig(format!(
                    "unknown transport {other} (serial | chan | tcp | unix)"
                )))
            }
        })
    }
}

/// How socket-transport workers are hosted.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WorkerModeChoice {
    /// One `camr worker --connect` subprocess per server (default).
    #[default]
    Process,
    /// One thread per server dialing the same socket (tests / CI).
    Thread,
}

impl WorkerModeChoice {
    /// Parse a worker-mode name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "process" => WorkerModeChoice::Process,
            "thread" => WorkerModeChoice::Thread,
            other => {
                return Err(CamrError::InvalidConfig(format!(
                    "unknown worker mode {other} (process | thread)"
                )))
            }
        })
    }
}

/// The `[transport]` config section: which plane to run on and how the
/// socket planes behave.
#[derive(Debug, Clone)]
pub struct TransportConfig {
    /// Which data plane (`serial | chan | tcp | unix`).
    pub kind: TransportChoice,
    /// Listen address override: `host:port` for TCP, a filesystem path
    /// for Unix sockets. Defaults to an ephemeral loopback port / a
    /// fresh temp-dir path.
    pub listen: Option<String>,
    /// Seconds of hub inactivity after which a socket run fails with a
    /// typed disconnect error instead of hanging.
    pub disconnect_timeout_secs: f64,
    /// Worker hosting (`process | thread`).
    pub workers: WorkerModeChoice,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            kind: TransportChoice::Serial,
            listen: None,
            disconnect_timeout_secs: 30.0,
            workers: WorkerModeChoice::Process,
        }
    }
}

impl TransportConfig {
    fn from_cfg(c: &CfgText) -> Result<Option<Self>> {
        if !c.section_names().iter().any(|s| s == "transport") {
            return Ok(None);
        }
        for key in c.keys("transport") {
            if !matches!(key.as_str(), "kind" | "listen" | "disconnect_timeout_secs" | "workers")
            {
                return Err(CamrError::InvalidConfig(format!("unknown [transport] key {key}")));
            }
        }
        let kind = match c.get("transport", "kind") {
            Some(s) => TransportChoice::parse(s)?,
            None => TransportChoice::Serial,
        };
        let listen = c.get("transport", "listen").map(|s| s.to_string());
        let disconnect_timeout_secs = c
            .get_f64("transport", "disconnect_timeout_secs")
            .map_err(CamrError::InvalidConfig)?
            .unwrap_or(30.0);
        if disconnect_timeout_secs.is_nan() || disconnect_timeout_secs <= 0.0 {
            return Err(CamrError::InvalidConfig(
                "disconnect_timeout_secs must be > 0".into(),
            ));
        }
        let workers = match c.get("transport", "workers") {
            Some(s) => WorkerModeChoice::parse(s)?,
            None => WorkerModeChoice::Process,
        };
        Ok(Some(TransportConfig { kind, listen, disconnect_timeout_secs, workers }))
    }
}

/// The `[obs]` config section: observability defaults for `camr run`
/// (CLI `--trace` and the `CAMR_TRACE` env var override it).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ObsConfig {
    /// Enable tracing even without a `trace` path (the trace then goes
    /// to `trace.json` in the working directory).
    pub enabled: bool,
    /// Where to write the Chrome `trace_event` JSON.
    pub trace: Option<String>,
}

impl ObsConfig {
    fn from_cfg(c: &CfgText) -> Result<Option<Self>> {
        if !c.section_names().iter().any(|s| s == "obs") {
            return Ok(None);
        }
        for key in c.keys("obs") {
            if !matches!(key.as_str(), "enabled" | "trace") {
                return Err(CamrError::InvalidConfig(format!("unknown [obs] key {key}")));
            }
        }
        let enabled =
            c.get_bool("obs", "enabled").map_err(CamrError::InvalidConfig)?.unwrap_or(false);
        let trace = c.get("obs", "trace").map(|s| s.to_string());
        Ok(Some(ObsConfig { enabled, trace }))
    }

    /// The trace output path this section asks for, if it asks for one.
    pub fn destination(&self) -> Option<String> {
        match (&self.trace, self.enabled) {
            (Some(path), _) => Some(path.clone()),
            (None, true) => Some("trace.json".into()),
            (None, false) => None,
        }
    }
}

/// The `[service]` config section: continuous job-service knobs for
/// `camr serve` (CLI flags override it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Dispatcher pool size (engines / coded rounds in flight).
    pub engines: usize,
    /// Per-tenant admission-queue bound.
    pub queue_capacity: usize,
    /// Number of tenant lanes.
    pub tenants: usize,
    /// Deficit round-robin quantum.
    pub quantum: u64,
    /// Per-tenant weights (`weights = "1,2,4"`); `None` means all 1.
    /// When present, must list exactly `tenants` entries.
    pub weights: Option<Vec<u64>>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            engines: 2,
            queue_capacity: 64,
            tenants: 4,
            quantum: 1,
            weights: None,
        }
    }
}

impl ServiceConfig {
    fn from_cfg(c: &CfgText) -> Result<Option<Self>> {
        if !c.section_names().iter().any(|s| s == "service") {
            return Ok(None);
        }
        for key in c.keys("service") {
            if !matches!(
                key.as_str(),
                "engines" | "queue_capacity" | "tenants" | "quantum" | "weights"
            ) {
                return Err(CamrError::InvalidConfig(format!("unknown [service] key {key}")));
            }
        }
        let gu = |k: &str| c.get_usize("service", k).map_err(CamrError::InvalidConfig);
        let d = ServiceConfig::default();
        let sc = ServiceConfig {
            engines: gu("engines")?.unwrap_or(d.engines),
            queue_capacity: gu("queue_capacity")?.unwrap_or(d.queue_capacity),
            tenants: gu("tenants")?.unwrap_or(d.tenants),
            quantum: c
                .get_u64("service", "quantum")
                .map_err(CamrError::InvalidConfig)?
                .unwrap_or(d.quantum),
            weights: match c.get("service", "weights") {
                None => None,
                Some(s) => Some(
                    s.split(',')
                        .map(|w| {
                            w.trim().parse::<u64>().map_err(|_| {
                                CamrError::InvalidConfig(format!(
                                    "bad [service] weight entry {w:?}"
                                ))
                            })
                        })
                        .collect::<Result<Vec<u64>>>()?,
                ),
            },
        };
        sc.validate()?;
        Ok(Some(sc))
    }

    /// Reject degenerate or inconsistent knobs.
    pub fn validate(&self) -> Result<()> {
        if self.engines == 0 {
            return Err(CamrError::InvalidConfig("[service] engines must be >= 1".into()));
        }
        if self.queue_capacity == 0 {
            return Err(CamrError::InvalidConfig("[service] queue_capacity must be >= 1".into()));
        }
        if self.tenants == 0 {
            return Err(CamrError::InvalidConfig("[service] tenants must be >= 1".into()));
        }
        if self.quantum == 0 {
            return Err(CamrError::InvalidConfig("[service] quantum must be >= 1".into()));
        }
        if let Some(w) = &self.weights {
            if w.len() != self.tenants {
                return Err(CamrError::InvalidConfig(format!(
                    "[service] weights lists {} entries for {} tenants",
                    w.len(),
                    self.tenants
                )));
            }
            if w.contains(&0) {
                return Err(CamrError::InvalidConfig("[service] weights must be >= 1".into()));
            }
        }
        Ok(())
    }

    /// The effective weight vector: the explicit list, or all-ones.
    pub fn weight_vector(&self) -> Vec<u64> {
        self.weights.clone().unwrap_or_else(|| vec![1; self.tenants])
    }
}

/// Top-level run configuration, loadable from a TOML-subset file.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// System parameters.
    pub system: SystemConfig,
    /// Which workload to run.
    pub workload: WorkloadKind,
    /// RNG seed for synthetic data.
    pub seed: u64,
    /// Optional path to an AOT HLO artifact for the PJRT-backed mapper.
    pub artifact: Option<String>,
    /// Emit JSON metrics instead of a human table.
    pub json: bool,
    /// Optional `[sim]` cluster model (`camr simulate`, and `camr run`
    /// attaches simulated phase times to its report when present).
    pub sim: Option<crate::sim::SimConfig>,
    /// Optional `[transport]` section selecting the data plane for
    /// `camr run` (overridable by `--transport`).
    pub transport: Option<TransportConfig>,
    /// Optional `[obs]` section enabling tracing by default
    /// (overridable by `--trace` / `CAMR_TRACE`).
    pub obs: Option<ObsConfig>,
    /// Optional `[service]` section configuring the continuous job
    /// service for `camr serve` (overridable by CLI flags).
    pub service: Option<ServiceConfig>,
}

impl RunConfig {
    /// Parse a TOML-subset run configuration:
    ///
    /// ```toml
    /// workload = "word_count"
    /// seed = 7
    /// json = false
    /// # artifact = "artifacts/map_kernel.hlo.txt"
    ///
    /// [system]
    /// k = 3
    /// q = 2
    /// gamma = 2
    /// rounds = 1
    /// value_bytes = 64
    ///
    /// # Optional cluster model for `camr simulate` / simulated times.
    /// [sim]
    /// link = "shared"              # shared | bisection
    /// link_bytes_per_sec = 1.25e8
    /// secs_per_map = 0.001
    /// straggler = "none"           # none | shifted_exp | tail
    ///
    /// # Optional data-plane selection for `camr run`.
    /// [transport]
    /// kind = "serial"              # serial | chan | tcp | unix
    /// disconnect_timeout_secs = 30.0
    /// workers = "process"          # process | thread
    ///
    /// # Optional tracing defaults for `camr run`.
    /// [obs]
    /// enabled = false              # true -> trace even without --trace
    /// trace = "trace.json"         # Chrome trace_event output path
    ///
    /// # Optional job-service knobs for `camr serve`.
    /// [service]
    /// engines = 2                  # dispatcher pool size
    /// queue_capacity = 64          # per-tenant admission bound
    /// tenants = 4
    /// quantum = 1                  # deficit round-robin quantum
    /// weights = "1,1,2,4"          # per-tenant weights (len = tenants)
    /// ```
    pub fn from_text(text: &str) -> Result<Self> {
        let c = CfgText::parse(text).map_err(CamrError::InvalidConfig)?;
        // Unknown-key validation.
        for key in c.keys("") {
            if !matches!(key.as_str(), "workload" | "seed" | "artifact" | "json") {
                return Err(CamrError::InvalidConfig(format!("unknown top-level key {key}")));
            }
        }
        for key in c.keys("system") {
            if !matches!(key.as_str(), "k" | "q" | "gamma" | "rounds" | "value_bytes") {
                return Err(CamrError::InvalidConfig(format!("unknown [system] key {key}")));
            }
        }
        for s in c.section_names() {
            if !matches!(s.as_str(), "" | "system" | "sim" | "transport" | "obs" | "service") {
                return Err(CamrError::InvalidConfig(format!("unknown section [{s}]")));
            }
        }
        let g = |k: &str| c.get_usize("system", k).map_err(CamrError::InvalidConfig);
        let system = SystemConfig::with_options(
            g("k")?.ok_or_else(|| CamrError::InvalidConfig("[system] k required".into()))?,
            g("q")?.ok_or_else(|| CamrError::InvalidConfig("[system] q required".into()))?,
            g("gamma")?.unwrap_or(1),
            g("rounds")?.unwrap_or(1),
            g("value_bytes")?.unwrap_or(64),
        )?;
        let workload = WorkloadKind::parse(c.get("", "workload").unwrap_or("word_count"))?;
        let seed = c.get_u64("", "seed").map_err(CamrError::InvalidConfig)?.unwrap_or(0xCA3A);
        let artifact = c.get("", "artifact").map(|s| s.to_string());
        let json = c.get_bool("", "json").map_err(CamrError::InvalidConfig)?.unwrap_or(false);
        let sim = crate::sim::SimConfig::from_cfg(&c)?;
        let transport = TransportConfig::from_cfg(&c)?;
        let obs = ObsConfig::from_cfg(&c)?;
        let service = ServiceConfig::from_cfg(&c)?;
        Ok(RunConfig { system, workload, seed, artifact, json, sim, transport, obs, service })
    }

    /// Load from a file path.
    pub fn from_path(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_text(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example1_parameters() {
        // Paper Example 1/2: q = 2, k = 3 → K = 6, J = 4, μ = 1/3.
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        assert_eq!(cfg.servers(), 6);
        assert_eq!(cfg.jobs(), 4);
        assert_eq!(cfg.subfiles(), 6);
        assert_eq!(cfg.functions(), 6);
        assert!((cfg.storage_fraction() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_degenerate_parameters() {
        assert!(SystemConfig::new(1, 2, 1).is_err());
        assert!(SystemConfig::new(2, 1, 1).is_err());
        assert!(SystemConfig::new(2, 2, 0).is_err());
        assert!(SystemConfig::with_options(2, 2, 1, 0, 64).is_err());
        assert!(SystemConfig::with_options(2, 2, 1, 1, 0).is_err());
    }

    #[test]
    fn rejects_oversized_designs() {
        // q = 10, k = 11 → 10^10 jobs: refuse to simulate.
        assert!(SystemConfig::new(11, 10, 1).is_err());
    }

    #[test]
    fn multi_round_functions() {
        let cfg = SystemConfig::with_options(3, 2, 1, 2, 64).unwrap();
        assert_eq!(cfg.functions(), 12);
        assert_eq!(cfg.functions_of(0), vec![0, 6]);
        assert_eq!(cfg.reducer_of(7), 1);
    }

    #[test]
    fn table3_row_parameters() {
        // Table III uses K = 100 with k ∈ {2, 4, 5}.
        for (k, q, j) in [(2, 50, 50), (4, 25, 15625), (5, 20, 160_000)] {
            let cfg = SystemConfig::new(k, q, 1).unwrap();
            assert_eq!(cfg.servers(), 100);
            assert_eq!(cfg.jobs(), j);
        }
    }

    #[test]
    fn config_file_roundtrip() {
        let text = r#"
            workload = "word_count"
            seed = 7
            [system]
            k = 3
            q = 2
            gamma = 2
            rounds = 1
            value_bytes = 64
        "#;
        let rc = RunConfig::from_text(text).unwrap();
        assert_eq!(rc.system.jobs(), 4);
        assert_eq!(rc.workload, WorkloadKind::WordCount);
        assert_eq!(rc.seed, 7);
        assert!(!rc.json);
        assert!(rc.artifact.is_none());
        assert!(rc.sim.is_none(), "no [sim] section means no sim config");
    }

    #[test]
    fn config_file_parses_sim_section() {
        let text = r#"
            [system]
            k = 3
            q = 2
            [sim]
            link = "shared"
            link_bytes_per_sec = 1.25e6
            straggler = "shifted_exp"
            straggler_rate = 5.0
            seed = 42
        "#;
        let rc = RunConfig::from_text(text).unwrap();
        let sc = rc.sim.expect("[sim] section parsed");
        assert_eq!(sc.link_bytes_per_sec, 1.25e6);
        assert_eq!(sc.seed, 42);
        assert!(RunConfig::from_text("[system]\nk = 3\nq = 2\n[sim]\nwat = 1").is_err());
    }

    #[test]
    fn config_file_rejects_unknown_keys() {
        assert!(RunConfig::from_text("typo = 1\n[system]\nk = 3\nq = 2").is_err());
        assert!(RunConfig::from_text("[system]\nk = 3\nq = 2\nbogus = 1").is_err());
        assert!(RunConfig::from_text("[bogus]\nx = 1").is_err());
    }

    #[test]
    fn config_file_requires_k_and_q() {
        assert!(RunConfig::from_text("[system]\nk = 3").is_err());
        assert!(RunConfig::from_text("[system]\nq = 2").is_err());
    }

    #[test]
    fn workload_kind_parse() {
        assert_eq!(WorkloadKind::parse("matvec").unwrap(), WorkloadKind::MatVec);
        assert!(WorkloadKind::parse("nope").is_err());
    }

    #[test]
    fn workload_kind_name_reparses() {
        for kind in [
            WorkloadKind::WordCount,
            WorkloadKind::MatVec,
            WorkloadKind::Gradient,
            WorkloadKind::Synthetic,
            WorkloadKind::Streamed,
        ] {
            assert_eq!(WorkloadKind::parse(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn config_file_parses_transport_section() {
        let text = r#"
            [system]
            k = 3
            q = 2
            [transport]
            kind = "unix"
            disconnect_timeout_secs = 2.5
            workers = "thread"
        "#;
        let rc = RunConfig::from_text(text).unwrap();
        let t = rc.transport.expect("[transport] section parsed");
        assert_eq!(t.kind, TransportChoice::Unix);
        assert_eq!(t.disconnect_timeout_secs, 2.5);
        assert_eq!(t.workers, WorkerModeChoice::Thread);
        assert!(t.listen.is_none());
        // Absent section → no transport config.
        assert!(RunConfig::from_text("[system]\nk = 3\nq = 2").unwrap().transport.is_none());
        // Unknown keys / values rejected.
        assert!(RunConfig::from_text("[system]\nk = 3\nq = 2\n[transport]\nwat = 1").is_err());
        assert!(
            RunConfig::from_text("[system]\nk = 3\nq = 2\n[transport]\nkind = \"warp\"").is_err()
        );
        assert!(RunConfig::from_text(
            "[system]\nk = 3\nq = 2\n[transport]\ndisconnect_timeout_secs = 0"
        )
        .is_err());
    }

    #[test]
    fn config_file_parses_obs_section() {
        let text = r#"
            [system]
            k = 3
            q = 2
            [obs]
            enabled = true
            trace = "out/run.trace.json"
        "#;
        let rc = RunConfig::from_text(text).unwrap();
        let o = rc.obs.expect("[obs] section parsed");
        assert!(o.enabled);
        assert_eq!(o.destination().as_deref(), Some("out/run.trace.json"));
        // enabled without a path falls back to trace.json; disabled
        // without a path asks for nothing.
        let on = RunConfig::from_text("[system]\nk = 3\nq = 2\n[obs]\nenabled = true").unwrap();
        assert_eq!(on.obs.unwrap().destination().as_deref(), Some("trace.json"));
        let off = RunConfig::from_text("[system]\nk = 3\nq = 2\n[obs]\nenabled = false").unwrap();
        assert_eq!(off.obs.unwrap().destination(), None);
        // A bare path implies tracing on.
        let path =
            RunConfig::from_text("[system]\nk = 3\nq = 2\n[obs]\ntrace = \"t.json\"").unwrap();
        assert_eq!(path.obs.unwrap().destination().as_deref(), Some("t.json"));
        // Absent section → no obs config; unknown keys rejected.
        assert!(RunConfig::from_text("[system]\nk = 3\nq = 2").unwrap().obs.is_none());
        assert!(RunConfig::from_text("[system]\nk = 3\nq = 2\n[obs]\nwat = 1").is_err());
    }

    #[test]
    fn config_file_parses_service_section() {
        let text = r#"
            [system]
            k = 3
            q = 2
            [service]
            engines = 3
            queue_capacity = 16
            tenants = 3
            quantum = 2
            weights = "1, 2, 4"
        "#;
        let rc = RunConfig::from_text(text).unwrap();
        let s = rc.service.expect("[service] section parsed");
        assert_eq!(s.engines, 3);
        assert_eq!(s.queue_capacity, 16);
        assert_eq!(s.quantum, 2);
        assert_eq!(s.weight_vector(), vec![1, 2, 4]);
        // Absent section → no service config; defaults are all-ones.
        assert!(RunConfig::from_text("[system]\nk = 3\nq = 2").unwrap().service.is_none());
        assert_eq!(ServiceConfig::default().weight_vector(), vec![1; 4]);
        // Unknown keys and inconsistent knobs rejected.
        assert!(RunConfig::from_text("[system]\nk = 3\nq = 2\n[service]\nwat = 1").is_err());
        assert!(
            RunConfig::from_text("[system]\nk = 3\nq = 2\n[service]\nengines = 0").is_err()
        );
        assert!(RunConfig::from_text(
            "[system]\nk = 3\nq = 2\n[service]\ntenants = 2\nweights = \"1\""
        )
        .is_err());
        assert!(RunConfig::from_text(
            "[system]\nk = 3\nq = 2\n[service]\ntenants = 2\nweights = \"1,zero\""
        )
        .is_err());
        assert!(RunConfig::from_text(
            "[system]\nk = 3\nq = 2\n[service]\ntenants = 2\nweights = \"1,0\""
        )
        .is_err());
    }

    #[test]
    fn transport_choice_parse() {
        assert_eq!(TransportChoice::parse("serial").unwrap(), TransportChoice::Serial);
        assert_eq!(TransportChoice::parse("chan").unwrap(), TransportChoice::Chan);
        assert_eq!(TransportChoice::parse("tcp").unwrap(), TransportChoice::Tcp);
        assert_eq!(TransportChoice::parse("unix").unwrap(), TransportChoice::Unix);
        assert!(TransportChoice::parse("smoke-signal").is_err());
    }
}
