//! Per-server value store: batch-level aggregates of intermediate values.
//!
//! The paper's Map phase ends with each mapper combining the values of
//! each (function, job, batch) triple it stores (§III-B) — the store
//! holds exactly those aggregates, plus everything decoded during the
//! shuffle. Keys are dense-packed into a flat `u64` for hashing speed
//! (this map sits on the shuffle hot path).

use crate::agg::Value;
use crate::error::{CamrError, Result};
use crate::{BatchId, FuncId, JobId};
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Multiplicative hasher for the already-dense-packed `u64` keys —
/// (~2× faster than SipHash on this map, which sits on the shuffle hot
/// path; see EXPERIMENTS.md §Perf). Keys are not attacker-controlled.
#[derive(Default)]
pub struct PackedKeyHasher(u64);

impl Hasher for PackedKeyHasher {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, _bytes: &[u8]) {
        unreachable!("PackedKeyHasher only hashes u64 keys");
    }

    fn write_u64(&mut self, x: u64) {
        // Fibonacci multiply + xor-fold: full avalanche for dense keys.
        let h = x.wrapping_mul(0x9E3779B97F4A7C15);
        self.0 = h ^ (h >> 29);
    }
}

type FastMap = HashMap<u64, Value, BuildHasherDefault<PackedKeyHasher>>;

/// Key of a batch aggregate: (job, func, batch).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ValueKey {
    pub job: JobId,
    pub func: FuncId,
    pub batch: BatchId,
}

/// A server-local store of batch aggregates.
///
/// `fused` holds stage-3 style multi-batch aggregates keyed by
/// (job, func) — the receiver never needs them at batch granularity.
#[derive(Debug, Default, Clone)]
pub struct ValueStore {
    batch_aggs: FastMap,
    fused: FastMap,
    dims: (usize, usize, usize), // (jobs, funcs, batches) for packing
}

impl ValueStore {
    /// Create a store for the given dimensions.
    pub fn new(jobs: usize, funcs: usize, batches: usize) -> Self {
        ValueStore {
            batch_aggs: FastMap::default(),
            fused: FastMap::default(),
            dims: (jobs, funcs, batches),
        }
    }

    fn pack(&self, k: ValueKey) -> u64 {
        debug_assert!(k.job < self.dims.0 && k.func < self.dims.1 && k.batch < self.dims.2);
        ((k.job as u64 * self.dims.1 as u64) + k.func as u64) * self.dims.2 as u64
            + k.batch as u64
    }

    fn pack_jf(&self, job: JobId, func: FuncId) -> u64 {
        job as u64 * self.dims.1 as u64 + func as u64
    }

    /// Insert (or overwrite) a batch aggregate.
    pub fn put(&mut self, key: ValueKey, v: Value) {
        let k = self.pack(key);
        self.batch_aggs.insert(k, v);
    }

    /// Fetch a batch aggregate.
    pub fn get(&self, key: ValueKey) -> Result<&Value> {
        let k = self.pack(key);
        self.batch_aggs.get(&k).ok_or_else(|| {
            CamrError::MissingValue(format!(
                "batch aggregate job={} func={} batch={}",
                key.job, key.func, key.batch
            ))
        })
    }

    /// Whether a batch aggregate is present.
    pub fn contains(&self, key: ValueKey) -> bool {
        self.batch_aggs.contains_key(&self.pack(key))
    }

    /// Insert a fused (multi-batch) aggregate for (job, func).
    pub fn put_fused(&mut self, job: JobId, func: FuncId, v: Value) {
        let k = self.pack_jf(job, func);
        self.fused.insert(k, v);
    }

    /// Fetch a fused aggregate.
    pub fn get_fused(&self, job: JobId, func: FuncId) -> Result<&Value> {
        self.fused.get(&self.pack_jf(job, func)).ok_or_else(|| {
            CamrError::MissingValue(format!("fused aggregate job={job} func={func}"))
        })
    }

    /// Number of stored batch aggregates (storage accounting / tests).
    pub fn len(&self) -> usize {
        self.batch_aggs.len()
    }

    /// True when no batch aggregates are stored.
    pub fn is_empty(&self) -> bool {
        self.batch_aggs.is_empty()
    }

    /// Clear everything (between runs).
    pub fn clear(&mut self) {
        self.batch_aggs.clear();
        self.fused.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut s = ValueStore::new(4, 6, 3);
        let key = ValueKey { job: 2, func: 5, batch: 1 };
        s.put(key, vec![1, 2, 3]);
        assert_eq!(s.get(key).unwrap(), &vec![1, 2, 3]);
        assert!(s.contains(key));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn missing_value_is_error() {
        let s = ValueStore::new(4, 6, 3);
        let e = s.get(ValueKey { job: 0, func: 0, batch: 0 });
        assert!(matches!(e, Err(CamrError::MissingValue(_))));
    }

    #[test]
    fn fused_separate_namespace() {
        let mut s = ValueStore::new(4, 6, 3);
        s.put_fused(1, 2, vec![9]);
        assert_eq!(s.get_fused(1, 2).unwrap(), &vec![9]);
        assert!(s.get(ValueKey { job: 1, func: 2, batch: 0 }).is_err());
    }

    #[test]
    fn keys_do_not_collide() {
        // Dense packing must be injective across the whole key space.
        let mut s = ValueStore::new(5, 7, 4);
        let mut count = 0;
        for j in 0..5 {
            for f in 0..7 {
                for b in 0..4 {
                    s.put(ValueKey { job: j, func: f, batch: b }, vec![j as u8, f as u8, b as u8]);
                    count += 1;
                }
            }
        }
        assert_eq!(s.len(), count);
        for j in 0..5 {
            for f in 0..7 {
                for b in 0..4 {
                    let v = s.get(ValueKey { job: j, func: f, batch: b }).unwrap();
                    assert_eq!(v, &vec![j as u8, f as u8, b as u8]);
                }
            }
        }
    }

    #[test]
    fn clear_empties_both_maps() {
        let mut s = ValueStore::new(2, 2, 2);
        s.put(ValueKey { job: 0, func: 0, batch: 0 }, vec![1]);
        s.put_fused(0, 0, vec![2]);
        s.clear();
        assert!(s.is_empty());
        assert!(s.get_fused(0, 0).is_err());
    }
}
