//! Message-passing cluster deployment: the same CAMR protocol with one
//! OS thread per server and all coordination over channels.
//!
//! The synchronous [`super::engine::Engine`] is the reference
//! implementation (and what the benches measure); this module deploys
//! the protocol the way a real cluster runs it — a leader thread driving
//! phase barriers, worker threads that own their state exclusively and
//! exchange coded packets through channels, no shared memory between
//! servers. The leader is where stragglers, retries, and backpressure
//! would live; the command protocol below keeps those extension points
//! explicit.

use super::master::Master;
use super::worker::Worker;
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::net::{Bus, Stage};
use crate::shuffle::multicast::GroupPlan;
use crate::shuffle::plan::UnicastSpec;
use crate::workload::Workload;
use crate::ServerId;
use std::sync::mpsc;
use std::sync::Arc;

/// Commands the leader sends to worker threads.
enum Command {
    /// Run the map phase; reply with the invocation count.
    Map { reply: mpsc::Sender<Result<usize>> },
    /// Encode Δ for a group this worker belongs to.
    Encode { plan: Arc<GroupPlan>, reply: mpsc::Sender<Result<Vec<u8>>> },
    /// Decode the worker's chunk from the group's broadcasts.
    Decode { plan: Arc<GroupPlan>, deltas: Arc<Vec<Vec<u8>>>, reply: mpsc::Sender<Result<()>> },
    /// Fuse and return a stage-3 unicast payload.
    Fuse { spec: Arc<UnicastSpec>, reply: mpsc::Sender<Result<Vec<u8>>> },
    /// Accept a stage-3 unicast payload.
    Deliver { spec: Arc<UnicastSpec>, value: Vec<u8>, reply: mpsc::Sender<Result<()>> },
    /// Reduce one (job, func) output.
    Reduce { job: usize, func: usize, reply: mpsc::Sender<Result<Vec<u8>>> },
    /// Shut down.
    Stop,
}

/// Cluster outcome (mirrors the sync engine's accounting).
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    /// Bytes per stage on the simulated shared link.
    pub stage_bytes: [usize; 3],
    /// `J·Q·B`.
    pub normalizer: f64,
    /// Total map invocations.
    pub map_invocations: usize,
    /// Outputs produced.
    pub outputs: usize,
}

impl ClusterOutcome {
    /// Total measured load.
    pub fn total_load(&self) -> f64 {
        self.stage_bytes.iter().sum::<usize>() as f64 / self.normalizer
    }
}

/// Run the full protocol with one thread per server.
pub fn run_cluster(cfg: SystemConfig, workload: Arc<dyn Workload>) -> Result<ClusterOutcome> {
    let master = Master::new(cfg.clone())?;
    let schedule = master.schedule()?;
    let placement = Arc::new(master.placement.clone());
    let mut bus = Bus::new();

    // Spawn worker threads.
    let mut txs: Vec<mpsc::Sender<Command>> = Vec::with_capacity(cfg.servers());
    let mut joins = Vec::with_capacity(cfg.servers());
    for s in 0..cfg.servers() {
        let (tx, rx) = mpsc::channel::<Command>();
        let cfg_c = cfg.clone();
        let placement_c = Arc::clone(&placement);
        let workload_c = Arc::clone(&workload);
        let join = std::thread::Builder::new()
            .name(format!("camr-worker-{s}"))
            .spawn(move || {
                let mut worker = Worker::new(s, &cfg_c);
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Command::Map { reply } => {
                            let r = worker.run_map_phase(&cfg_c, &placement_c, &*workload_c);
                            let _ = reply.send(r);
                        }
                        Command::Encode { plan, reply } => {
                            let _ = reply.send(worker.encode_for_group(&plan));
                        }
                        Command::Decode { plan, deltas, reply } => {
                            let _ =
                                reply.send(worker.decode_from_group(&plan, deltas.as_slice()));
                        }
                        Command::Fuse { spec, reply } => {
                            let _ = reply
                                .send(worker.fuse_for_unicast(workload_c.aggregator(), &spec));
                        }
                        Command::Deliver { spec, value, reply } => {
                            let _ = reply.send(worker.receive_fused(&spec, value));
                        }
                        Command::Reduce { job, func, reply } => {
                            let _ = reply.send(worker.reduce(
                                &cfg_c,
                                &placement_c,
                                workload_c.aggregator(),
                                job,
                                func,
                            ));
                        }
                        Command::Stop => break,
                    }
                }
            })
            .map_err(|e| CamrError::Runtime(format!("spawn worker {s}: {e}")))?;
        txs.push(tx);
        joins.push(join);
    }

    let send = |s: ServerId, cmd: Command| -> Result<()> {
        txs[s].send(cmd).map_err(|_| CamrError::Runtime(format!("worker {s} died")))
    };

    // ---- Map phase (parallel across workers, barrier at the end).
    let (rtx, rrx) = mpsc::channel();
    for s in 0..cfg.servers() {
        send(s, Command::Map { reply: rtx.clone() })?;
    }
    let mut map_invocations = 0usize;
    for _ in 0..cfg.servers() {
        map_invocations +=
            rrx.recv().map_err(|_| CamrError::Runtime("map reply lost".into()))??;
    }

    // ---- Coded stages 1 and 2.
    for (groups, stage) in
        [(&schedule.stage1, Stage::Stage1), (&schedule.stage2, Stage::Stage2)]
    {
        for plan in groups.iter() {
            let plan = Arc::new(plan.clone());
            // Gather broadcasts from all members (in member order).
            let mut rxs = Vec::with_capacity(plan.members.len());
            for &m in &plan.members {
                let (rtx, rrx) = mpsc::channel();
                send(m, Command::Encode { plan: Arc::clone(&plan), reply: rtx })?;
                rxs.push((m, rrx));
            }
            let mut deltas = Vec::with_capacity(plan.members.len());
            for (m, rrx) in rxs {
                let delta =
                    rrx.recv().map_err(|_| CamrError::Runtime("encode reply lost".into()))??;
                bus.multicast(
                    stage,
                    m,
                    plan.members.iter().copied().filter(|&x| x != m).collect(),
                    delta.len(),
                );
                deltas.push(delta);
            }
            // Deliver the broadcast set; every member decodes.
            let deltas = Arc::new(deltas);
            let (atx, arx) = mpsc::channel();
            for &m in &plan.members {
                send(
                    m,
                    Command::Decode {
                        plan: Arc::clone(&plan),
                        deltas: Arc::clone(&deltas),
                        reply: atx.clone(),
                    },
                )?;
            }
            for _ in 0..plan.members.len() {
                arx.recv().map_err(|_| CamrError::Runtime("decode reply lost".into()))??;
            }
        }
    }

    // ---- Stage 3 unicasts.
    for spec in &schedule.stage3 {
        let spec = Arc::new(spec.clone());
        let (rtx, rrx) = mpsc::channel();
        send(spec.sender, Command::Fuse { spec: Arc::clone(&spec), reply: rtx })?;
        let value = rrx.recv().map_err(|_| CamrError::Runtime("fuse reply lost".into()))??;
        bus.unicast(Stage::Stage3, spec.sender, spec.receiver, value.len());
        let (rtx, rrx) = mpsc::channel();
        send(spec.receiver, Command::Deliver { spec: Arc::clone(&spec), value, reply: rtx })?;
        rrx.recv().map_err(|_| CamrError::Runtime("deliver reply lost".into()))??;
    }

    // ---- Reduce.
    let mut outputs = 0usize;
    for f in 0..cfg.functions() {
        let reducer = cfg.reducer_of(f);
        for j in 0..cfg.jobs() {
            let (rtx, rrx) = mpsc::channel();
            send(reducer, Command::Reduce { job: j, func: f, reply: rtx })?;
            let _v =
                rrx.recv().map_err(|_| CamrError::Runtime("reduce reply lost".into()))??;
            outputs += 1;
        }
    }

    // Shut down workers.
    for tx in &txs {
        let _ = tx.send(Command::Stop);
    }
    for j in joins {
        let _ = j.join();
    }

    Ok(ClusterOutcome {
        stage_bytes: [
            bus.stage_bytes(Stage::Stage1),
            bus.stage_bytes(Stage::Stage2),
            bus.stage_bytes(Stage::Stage3),
        ],
        normalizer: cfg.load_normalizer(),
        map_invocations,
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::synth::SyntheticWorkload;

    #[test]
    fn cluster_matches_sync_engine() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = Arc::new(SyntheticWorkload::new(&cfg, 0xBEEF));
        let out = run_cluster(cfg, wl).unwrap();
        assert!((out.total_load() - 1.0).abs() < 1e-12);
        assert_eq!(out.map_invocations, 2 * 4 * 6);
        assert_eq!(out.outputs, 24);
    }

    #[test]
    fn cluster_larger_parameters() {
        let cfg = SystemConfig::new(3, 3, 1).unwrap();
        let wl = Arc::new(SyntheticWorkload::new(&cfg, 1));
        let out = run_cluster(cfg, wl).unwrap();
        let expect = crate::analysis::load::camr_total(3, 3);
        assert!((out.total_load() - expect).abs() < 1e-12);
    }

    #[test]
    fn cluster_multi_round() {
        let cfg = SystemConfig::with_options(3, 2, 1, 2, 64).unwrap();
        let wl = Arc::new(SyntheticWorkload::new(&cfg, 2));
        let out = run_cluster(cfg, wl).unwrap();
        assert!((out.total_load() - 1.0).abs() < 1e-12);
        assert_eq!(out.outputs, 4 * 12);
    }
}
