//! The CAMR coordinator: per-server workers, the master, and the
//! end-to-end engine (the paper's system contribution, L3).
//!
//! - [`values`] — per-server store of batch-level aggregates.
//! - [`worker`] — a server: maps, combines, encodes/decodes coded
//!   packets, reduces.
//! - [`master`] — phase orchestration and schedule distribution.
//! - [`engine`] — drives map → shuffle (3 stages) → reduce, verifies
//!   against the oracle, and reports measured loads.
//! - [`cluster`] — async (tokio) deployment of the same protocol over
//!   message channels, one task per server.

pub mod cluster;
pub mod engine;
pub mod master;
pub mod values;
pub mod worker;

pub use engine::{Engine, RunOutcome};
