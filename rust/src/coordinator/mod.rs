//! The CAMR coordinator: per-server workers, the master, and the
//! end-to-end engines (the paper's system contribution, L3).
//!
//! - [`values`] — per-server store of batch-level aggregates.
//! - [`worker`] — a server: maps, combines, encodes/decodes coded
//!   packets, reduces.
//! - [`master`] — phase orchestration and schedule distribution.
//! - [`engine`] — the **serial reference engine**: drives map →
//!   shuffle (3 stages) → reduce on one thread in schedule order,
//!   verifies against the oracle, and reports measured loads. Its bus
//!   ledger is the canonical transcript.
//! - [`parallel`] — the **thread-per-worker engine**: one OS thread per
//!   server (pool sized to `K`), barrier-synchronized phases, coded
//!   packets exchanged through per-worker channels, and a channel-backed
//!   shared-link recorder whose sequence-numbered ledger collapses to
//!   exactly the serial transcript. Same protocol, same bytes, real
//!   concurrency. Generic over [`crate::net::transport::Transport`]:
//!   the same worker loop ([`proto`]) runs over in-process channels or
//!   over sockets.
//! - [`proto`] — the transport-agnostic worker protocol: the per-worker
//!   round (map → coded stages → stage 3 → reduce) expressed against
//!   the `Transport` trait, plus the deterministic flattening of the
//!   schedule into ledger sequence numbers.
//! - [`remote`] — the socket data plane: the coordinator **hub**
//!   (listener, handshake, frame routing, barrier release, ledger
//!   recording) and the `camr worker --connect` subprocess entrypoint.
//!   Workers run as separate processes; the checked-in golden ledger
//!   is byte-identical to the serial engine's.
//! - [`cluster`] — message-passing deployment of the same protocol (one
//!   std thread per server driven lockstep by a leader thread over
//!   command channels) — the extension point where stragglers, retries
//!   and backpressure would live.
//! - [`batch`] — the **multi-job batch runtime**: executes a scheme's
//!   *entire* job set (all `q^(k-1)` CAMR jobs vs the capped
//!   `C(K, μK+1)` CCDC family vs uncoded) through one persistent
//!   engine, swapping only the workload between units so workers,
//!   schedule and buffer pool are reused; verification of unit `i`
//!   runs behind unit `i+1`'s execution, and the aggregate job-tagged
//!   ledger replays through [`crate::sim::simulate_batch`] for a
//!   barriered-vs-pipelined batch makespan.
//!
//! ## Threading model
//!
//! The protocol is bulk-synchronous: map ‖ → stage 1 ‖ → stage 2 ‖ →
//! stage 3 ‖ → reduce ‖, where ‖ marks a barrier. Workers never share
//! memory — each owns its [`values::ValueStore`] exclusively on its own
//! thread, and everything crossing server boundaries is an explicit
//! packet on a channel, charged to the shared link at its schedule
//! sequence number. That is why the measured loads are identical between
//! the serial and parallel engines: the bytes on the link are a pure
//! function of the schedule, and the schedule is fixed by the master
//! before any thread starts.

pub mod batch;
pub mod cluster;
pub mod engine;
pub mod master;
pub mod parallel;
pub mod proto;
pub mod remote;
pub mod values;
pub mod worker;

pub use batch::{run_batch, run_batch_synthetic, BatchOptions, BatchOutcome, BatchScheme};
pub use engine::{Engine, RunOutcome};
pub use parallel::{ParallelEngine, TransportKind};
pub use remote::{SocketOptions, WorkerMode, WorkerSpec};
