//! The master (leader) node: builds the design, placement and the full
//! shuffle schedule before the run starts (the paper's "master node
//! judiciously places each subfile…", §II).
//!
//! The master performs *no* data-plane work — it only produces plans;
//! workers execute them against local state. This mirrors the separation
//! in real deployments (driver vs executors).

use crate::config::SystemConfig;
use crate::design::{verify::verify_design, ResolvableDesign};
use crate::error::Result;
use crate::placement::{storage::audit_storage, Placement};
use crate::shuffle::multicast::GroupPlan;
use crate::shuffle::plan::UnicastSpec;
use crate::shuffle::{stage1, stage2, stage3};

/// The full static schedule of one CAMR run.
pub struct Schedule {
    /// Stage-1 groups (one per job per round).
    pub stage1: Vec<GroupPlan>,
    /// Stage-2 groups (one per transversal group per round).
    pub stage2: Vec<GroupPlan>,
    /// Stage-3 unicasts.
    pub stage3: Vec<UnicastSpec>,
}

/// The master: owns the design, placement and schedule.
pub struct Master {
    /// System parameters.
    pub cfg: SystemConfig,
    /// The resolvable design (verified at construction).
    pub design: ResolvableDesign,
    /// Algorithm-1 placement (validated and storage-audited).
    pub placement: Placement,
}

impl Master {
    /// Build and verify design + placement for a config.
    pub fn new(cfg: SystemConfig) -> Result<Self> {
        cfg.validate()?;
        let design = ResolvableDesign::new(cfg.k, cfg.q)?;
        verify_design(&design)?;
        let placement = Placement::new(&design, &cfg)?;
        placement.validate()?;
        audit_storage(&placement, &cfg)?;
        Ok(Master { cfg, design, placement })
    }

    /// Produce the complete three-stage shuffle schedule.
    pub fn schedule(&self) -> Result<Schedule> {
        Ok(Schedule {
            stage1: stage1::plan(&self.cfg, &self.placement)?,
            stage2: stage2::plan(&self.cfg, &self.design, &self.placement)?,
            stage3: stage3::plan(&self.cfg, &self.design, &self.placement)?,
        })
    }

    /// Expected total shuffle bytes (closed forms of §IV, incl. padding).
    pub fn expected_shuffle_bytes(&self) -> usize {
        stage1::expected_bytes(&self.cfg)
            + stage2::expected_bytes(&self.cfg)
            + stage3::expected_bytes(&self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn master_builds_verified_example() {
        let m = Master::new(SystemConfig::new(3, 2, 2).unwrap()).unwrap();
        let s = m.schedule().unwrap();
        assert_eq!(s.stage1.len(), 4);
        assert_eq!(s.stage2.len(), 4);
        assert_eq!(s.stage3.len(), 12);
    }

    #[test]
    fn expected_bytes_equals_paper_total() {
        // Example 1: 6B + 6B + 12B = 24B = J·Q·B → L = 1.
        let m = Master::new(SystemConfig::new(3, 2, 2).unwrap()).unwrap();
        assert_eq!(m.expected_shuffle_bytes(), 24 * m.cfg.value_bytes);
    }

    #[test]
    fn schedule_counts_scale_with_rounds() {
        let cfg = SystemConfig::with_options(3, 2, 2, 3, 64).unwrap();
        let m = Master::new(cfg).unwrap();
        let s = m.schedule().unwrap();
        assert_eq!(s.stage1.len(), 12);
        assert_eq!(s.stage2.len(), 12);
        assert_eq!(s.stage3.len(), 36);
    }
}
