//! Socket-transport orchestration: the coordinator **hub** and the
//! `camr worker --connect` entrypoint.
//!
//! The hub binds a TCP or Unix-domain listener, spawns one worker per
//! server (subprocess or thread), assigns worker ids in accept order,
//! and ships each worker the *recipe* for the run — the config TOML
//! text of a [`WorkerSpec`] — in the `Welcome` frame. Every process
//! then reconstructs the identical [`super::master::Master`], schedule
//! and workload from that text (all deterministic functions of
//! `(config, seed)`), so the flattened ledger sequence numbers agree
//! across processes without ever being negotiated.
//!
//! During the run the hub is a frame router with the ledger recorder
//! attached: a worker's multicast arrives as **one** `Delta` frame, is
//! charged once through the same [`crate::net::BusRecorder`] path the
//! channel plane uses, and is fanned out to the recipient list. Barrier
//! frames implement the protocol's four phase barriers; `BarrierGo`
//! releases a phase only after every worker arrived *and* every data
//! frame of that phase has already been forwarded (per-connection FIFO
//! makes that ordering free — see `net::socket`).
//!
//! ## Failure containment
//!
//! - A worker that hits a typed error sends a `Failed` frame; the hub
//!   reconstructs the error via [`CamrError::from_wire`], broadcasts
//!   `Abort`, and tears the fleet down.
//! - A worker that *vanishes* (killed process, dropped connection)
//!   surfaces as reader-thread EOF; the hub fails the run with a typed
//!   [`CamrError::Disconnected`].
//! - A worker that silently wedges trips the hub's inactivity timeout
//!   ([`SocketOptions::disconnect_timeout`]) — also `Disconnected`.
//!
//! No path hangs: every abort broadcasts `Abort`, shuts the sockets
//! down, kills subprocess workers and joins every thread before the
//! error is returned.

use super::engine::{verify_outputs, RunOutcome};
use super::master::Master;
use super::proto::{self, RoundCtx};
use super::worker::Worker;
use crate::agg::Value;
use crate::config::{RunConfig, SystemConfig, WorkloadKind};
use crate::error::{CamrError, Result};
use crate::net::frame::{write_frame, Frame, FrameDecoder, FrameKind, WIRE_VERSION};
use crate::net::socket::{
    decode_outputs, dial, read_frame_blocking, SockListener, SockStream, SocketKind,
    SocketTransport,
};
use crate::net::{Bus, BusRecorder, SharedBus, Stage};
use crate::obs::{self, SpanKind, Tracer, COORD};
use crate::shuffle::buf::BufferPool;
use crate::workload;
use crate::{FuncId, JobId, ServerId};
use std::collections::HashMap;
use std::io::Read;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// How socket workers are hosted.
#[derive(Debug, Clone)]
pub enum WorkerMode {
    /// Dial from threads inside the coordinator process (tests / CI:
    /// exercises the full wire protocol without process management).
    Thread,
    /// Spawn `exe worker --connect <url>` subprocesses — the real
    /// multi-process data plane.
    Process {
        /// Path to the `camr` binary to spawn.
        exe: PathBuf,
    },
}

/// Options for a socket-transport run.
#[derive(Debug, Clone)]
pub struct SocketOptions {
    /// TCP or Unix-domain.
    pub kind: SocketKind,
    /// Listen address override (`host:port` / socket path); `None`
    /// picks an ephemeral loopback port or a fresh temp-dir path.
    pub listen: Option<String>,
    /// Worker hosting.
    pub mode: WorkerMode,
    /// Hub inactivity budget: if no frame (or connection event) arrives
    /// for this long mid-run, the run fails with a typed
    /// [`CamrError::Disconnected`] instead of hanging.
    pub disconnect_timeout: Duration,
    /// Fault-injection hook: make the worker with assigned id 0 crash
    /// right after crossing barrier `n` (0 = after map, 1 = after
    /// stage 1, …). Subprocess workers `exit(101)`; thread workers drop
    /// the connection.
    pub die_after_barrier: Option<usize>,
}

impl SocketOptions {
    /// Options with defaults (30 s disconnect timeout, no fault hook).
    pub fn new(kind: SocketKind, mode: WorkerMode) -> Self {
        SocketOptions {
            kind,
            listen: None,
            mode,
            disconnect_timeout: Duration::from_secs(30),
            die_after_barrier: None,
        }
    }

    /// TCP with subprocess workers spawned from `exe`.
    pub fn tcp_processes(exe: PathBuf) -> Self {
        Self::new(SocketKind::Tcp, WorkerMode::Process { exe })
    }

    /// Unix-domain with subprocess workers spawned from `exe`.
    pub fn unix_processes(exe: PathBuf) -> Self {
        Self::new(SocketKind::Unix, WorkerMode::Process { exe })
    }

    /// TCP with in-process worker threads.
    pub fn tcp_threads() -> Self {
        Self::new(SocketKind::Tcp, WorkerMode::Thread)
    }

    /// Unix-domain with in-process worker threads.
    pub fn unix_threads() -> Self {
        Self::new(SocketKind::Unix, WorkerMode::Thread)
    }
}

/// The deterministic workload recipe shipped to every worker process.
/// Together with the system config this reconstructs bit-identical data
/// in each process ([`workload::build_native`]).
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    /// Which native workload to build.
    pub kind: WorkloadKind,
    /// RNG seed for the synthetic data.
    pub seed: u64,
}

/// Render the run recipe as config TOML text (the `Welcome` payload) —
/// parsed back by [`RunConfig::from_text`] in the worker.
fn spec_text(cfg: &SystemConfig, spec: &WorkerSpec) -> String {
    format!(
        "workload = \"{}\"\nseed = {}\n\n[system]\nk = {}\nq = {}\n\
         gamma = {}\nrounds = {}\nvalue_bytes = {}\n",
        spec.kind.name(),
        spec.seed,
        cfg.k,
        cfg.q,
        cfg.gamma,
        cfg.rounds,
        cfg.value_bytes
    )
}

/// What the hub hands back to the engine after a socket run.
pub struct SocketRun {
    /// The canonical ledger (sorted by schedule sequence numbers).
    pub bus: Bus,
    /// Reduced `(job, func) → value` outputs from every worker.
    pub outputs: HashMap<(JobId, FuncId), Value>,
    /// Measured loads and phase times.
    pub outcome: RunOutcome,
}

/// Subprocess fleet with kill-on-drop semantics: no abort path leaves
/// orphaned workers behind.
#[derive(Default)]
struct Fleet {
    children: Vec<Child>,
}

impl Fleet {
    fn shutdown(&mut self, graceful: bool) {
        for c in &mut self.children {
            if !graceful {
                let _ = c.kill();
            }
            let _ = c.wait();
        }
        self.children.clear();
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.shutdown(false);
    }
}

/// One connection's event as seen by the hub loop.
enum HubEvent {
    /// A decoded frame from worker `.0`.
    Frame(usize, Frame),
    /// Worker `.0`'s connection ended (reason in `.1`).
    Closed(usize, String),
}

/// What the hub loop accumulates on success.
struct HubResult {
    outputs: HashMap<(JobId, FuncId), Value>,
    map_invocations: usize,
    /// Elapsed time from run start to each barrier release (map,
    /// stage 1, stage 2, stage 3).
    phase_marks: [Duration; 4],
    reduce_time: Duration,
}

/// Read one frame with a deadline (handshake use; read timeouts on the
/// stream keep the poll loop live).
fn read_frame_deadline(
    stream: &mut SockStream,
    decoder: &mut FrameDecoder,
    deadline: Instant,
) -> Result<Frame> {
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(f) = decoder.next_frame()? {
            return Ok(f);
        }
        if Instant::now() >= deadline {
            return Err(CamrError::Disconnected("handshake timed out".into()));
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                return Err(CamrError::Disconnected(
                    "connection closed during handshake".into(),
                ))
            }
            Ok(n) => decoder.feed(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Run one full round over sockets: bind, spawn, handshake, route,
/// collect. Returns the canonical bus, the reduced outputs and the
/// measured outcome; any failure is a typed error after full teardown.
#[allow(clippy::too_many_arguments)]
pub fn run_socket(
    master: &Master,
    spec: &WorkerSpec,
    workload: &dyn crate::workload::Workload,
    pool: &BufferPool,
    pooling: bool,
    verify: bool,
    tracer: &Tracer,
    opts: &SocketOptions,
) -> Result<SocketRun> {
    let cfg = &master.cfg;
    let servers = cfg.servers();
    let listener = SockListener::bind(opts.kind, opts.listen.as_deref())?;
    let url = listener.url().to_string();

    // ---- Spawn the fleet.
    let mut fleet = Fleet::default();
    let mut wthreads = Vec::new();
    match &opts.mode {
        WorkerMode::Process { exe } => {
            for _ in 0..servers {
                fleet.children.push(
                    Command::new(exe)
                        .arg("worker")
                        .arg("--connect")
                        .arg(&url)
                        .stdin(Stdio::null())
                        .stdout(Stdio::null())
                        .spawn()?,
                );
            }
        }
        WorkerMode::Thread => {
            for i in 0..servers {
                let url = url.clone();
                let pool = pool.clone();
                wthreads.push(
                    std::thread::Builder::new()
                        .name(format!("camr-sock-worker-{i}"))
                        .spawn(move || {
                            // Errors surface hub-side (Failed frame or
                            // disconnect); nothing to do with them here.
                            let _ = worker_at(&url, false, Some(pool));
                        })?,
                );
            }
        }
    }

    // ---- Accept + handshake, assigning ids in accept order.
    let handshake_deadline =
        Instant::now() + opts.disconnect_timeout.max(Duration::from_secs(10));
    let text = spec_text(cfg, spec);
    let mut conns: Vec<(SockStream, FrameDecoder)> = Vec::with_capacity(servers);
    for id in 0..servers {
        let accept = || -> Result<(SockStream, FrameDecoder)> {
            let mut s = listener.accept_within(handshake_deadline)?;
            s.set_read_timeout(Some(Duration::from_millis(25)))?;
            s.set_write_timeout(Some(opts.disconnect_timeout))?;
            let mut dec = FrameDecoder::new();
            let hello = read_frame_deadline(&mut s, &mut dec, handshake_deadline)?;
            if hello.kind != FrameKind::Hello {
                return Err(CamrError::Wire(format!(
                    "expected Hello, got {:?}",
                    hello.kind
                )));
            }
            if hello.tag != WIRE_VERSION {
                return Err(CamrError::Wire(format!(
                    "wire version mismatch: worker speaks {}, hub speaks {WIRE_VERSION}",
                    hello.tag
                )));
            }
            let mut w = Frame::new(FrameKind::Welcome);
            w.tag = id as u32;
            // Flags: bit 0 = pooling, bit 1 = tracing.
            w.job = u32::from(pooling) | (u32::from(tracer.enabled()) << 1);
            w.extra = match opts.die_after_barrier {
                // The hook targets *assigned* id 0 (spawn order and
                // accept order need not agree).
                Some(n) if id == 0 => n as u32 + 1,
                _ => 0,
            };
            write_frame(&mut s, &w, text.as_bytes())?;
            Ok((s, dec))
        };
        conns.push(accept()?);
        if obs::metrics_enabled() {
            obs::metrics().workers_connected.add(1);
        }
        // On error: return propagates, Fleet::drop kills subprocesses,
        // thread workers die on their handshake deadline / socket error.
    }

    // ---- Reader threads: frames from every connection into one queue.
    let (ev_tx, ev_rx) = mpsc::channel::<HubEvent>();
    let mut writers: Vec<SockStream> = Vec::with_capacity(servers);
    let mut readers = Vec::with_capacity(servers);
    for (w, (s, dec)) in conns.into_iter().enumerate() {
        writers.push(s.try_clone()?);
        let tx = ev_tx.clone();
        readers.push(std::thread::Builder::new().name(format!("camr-hub-read-{w}")).spawn(
            move || {
                let mut s = s;
                let mut dec = dec;
                loop {
                    match read_frame_blocking(&mut s, &mut dec) {
                        Ok(Some(f)) => {
                            if tx.send(HubEvent::Frame(w, f)).is_err() {
                                break;
                            }
                        }
                        Ok(None) => {
                            let _ = tx.send(HubEvent::Closed(w, "connection closed".into()));
                            break;
                        }
                        Err(e) => {
                            let _ = tx.send(HubEvent::Closed(w, e.to_string()));
                            break;
                        }
                    }
                }
            },
        )?);
    }
    drop(ev_tx);

    // ---- Route frames + run barriers, recording the ledger once per
    // forwarded frame.
    let shared = SharedBus::new();
    let rec = shared.recorder();
    let hub_res =
        hub_loop(servers, &rec, &mut writers, &ev_rx, opts.disconnect_timeout, tracer);
    drop(rec);

    // ---- Teardown (both paths): abort broadcast if needed, close every
    // socket, reap subprocesses, join every thread.
    let ok = hub_res.is_ok();
    if !ok {
        let abort = Frame::new(FrameKind::Abort);
        for w in writers.iter_mut() {
            let _ = write_frame(w, &abort, &[]);
        }
    }
    for w in &writers {
        w.shutdown();
    }
    fleet.shutdown(ok);
    for t in wthreads {
        let _ = t.join();
    }
    for r in readers {
        let _ = r.join();
    }
    drop(writers);
    drop(listener);
    if obs::metrics_enabled() {
        obs::metrics().workers_connected.add(-(servers as i64));
    }

    let bus = shared.collect();
    let hub = hub_res?;

    let verified = if verify {
        let mut sink = tracer.sink();
        let t = sink.begin();
        verify_outputs(cfg, workload, &hub.outputs)?;
        sink.record(t, SpanKind::Verify, COORD, 0, None, 0, hub.outputs.len() as u64);
        true
    } else {
        true
    };
    let outcome = RunOutcome {
        stage_bytes: [
            bus.stage_bytes(Stage::Stage1),
            bus.stage_bytes(Stage::Stage2),
            bus.stage_bytes(Stage::Stage3),
        ],
        normalizer: cfg.load_normalizer(),
        map_invocations: hub.map_invocations,
        verified,
        outputs: hub.outputs.len(),
        map_time: hub.phase_marks[0],
        shuffle_time: hub.phase_marks[3] - hub.phase_marks[0],
        stage_times: [
            hub.phase_marks[1] - hub.phase_marks[0],
            hub.phase_marks[2] - hub.phase_marks[1],
            hub.phase_marks[3] - hub.phase_marks[2],
        ],
        reduce_time: hub.reduce_time,
    };
    Ok(SocketRun { bus, outputs: hub.outputs, outcome })
}

/// The hub's event loop: four barrier phases of routing, then output
/// collection. Any protocol violation, worker failure, disconnect or
/// inactivity timeout returns a typed error (the caller tears down).
fn hub_loop(
    servers: usize,
    rec: &BusRecorder,
    writers: &mut [SockStream],
    events: &mpsc::Receiver<HubEvent>,
    timeout: Duration,
    tracer: &Tracer,
) -> Result<HubResult> {
    let t0 = Instant::now();
    let mut phase_marks = [Duration::ZERO; 4];

    for b in 0..4u32 {
        let mut arrived = vec![false; servers];
        let mut count = 0usize;
        while count < servers {
            match events.recv_timeout(timeout) {
                Ok(HubEvent::Frame(w, f)) => match f.kind {
                    FrameKind::Barrier => {
                        if f.tag != b {
                            return Err(CamrError::Wire(format!(
                                "worker {w} at barrier {} while hub runs barrier {b}",
                                f.tag
                            )));
                        }
                        if arrived[w] {
                            return Err(CamrError::Wire(format!(
                                "worker {w} hit barrier {b} twice"
                            )));
                        }
                        arrived[w] = true;
                        count += 1;
                    }
                    FrameKind::Delta => {
                        if let Some(&bad) = f.recipients.iter().find(|&&m| m >= servers) {
                            return Err(CamrError::Wire(format!(
                                "delta frame addressed to worker {bad} of {servers}"
                            )));
                        }
                        // Charge the shared link ONCE at the schedule
                        // sequence number, then fan out to recipients.
                        rec.multicast(
                            f.seq,
                            f.stage,
                            f.sender as ServerId,
                            f.recipients.clone(),
                            f.payload.len(),
                        );
                        for &m in &f.recipients {
                            write_frame(&mut writers[m], &f, &f.payload).map_err(|e| {
                                CamrError::Disconnected(format!(
                                    "forwarding to worker {m}: {e}"
                                ))
                            })?;
                        }
                    }
                    FrameKind::Fused => {
                        let m = f.extra as usize;
                        if m >= servers {
                            return Err(CamrError::Wire(format!(
                                "fused frame addressed to worker {m} of {servers}"
                            )));
                        }
                        rec.unicast(f.seq, Stage::Stage3, f.sender as ServerId, m, f.payload.len());
                        write_frame(&mut writers[m], &f, &f.payload).map_err(|e| {
                            CamrError::Disconnected(format!("forwarding to worker {m}: {e}"))
                        })?;
                    }
                    FrameKind::Failed => {
                        return Err(CamrError::from_wire(
                            f.tag,
                            String::from_utf8_lossy(&f.payload).into_owned(),
                        ));
                    }
                    other => {
                        return Err(CamrError::Wire(format!(
                            "unexpected {other:?} frame from worker {w} during phase {b}"
                        )))
                    }
                },
                Ok(HubEvent::Closed(w, why)) => {
                    return Err(CamrError::Disconnected(format!(
                        "worker {w} vanished during phase {b}: {why}"
                    )));
                }
                Err(_) => {
                    if obs::metrics_enabled() {
                        obs::metrics().disconnect_timeouts.inc();
                    }
                    return Err(CamrError::Disconnected(format!(
                        "no progress for {timeout:?} waiting at barrier {b} \
                         ({count}/{servers} workers arrived)"
                    )));
                }
            }
        }
        // Release the phase. Per-connection FIFO guarantees every data
        // frame forwarded above lands before this go signal.
        let mut go = Frame::new(FrameKind::BarrierGo);
        go.tag = b;
        for (m, w) in writers.iter_mut().enumerate() {
            write_frame(w, &go, &[]).map_err(|e| {
                CamrError::Disconnected(format!("releasing barrier {b} to worker {m}: {e}"))
            })?;
        }
        phase_marks[b as usize] = t0.elapsed();
    }

    // ---- Collect reduced outputs.
    let mut done = vec![false; servers];
    let mut ndone = 0usize;
    let mut map_invocations = 0usize;
    let mut outputs: HashMap<(JobId, FuncId), Value> = HashMap::new();
    while ndone < servers {
        match events.recv_timeout(timeout) {
            Ok(HubEvent::Frame(w, f)) => match f.kind {
                FrameKind::Outputs => {
                    for (key, v) in decode_outputs(&f.payload)? {
                        outputs.insert(key, v);
                    }
                }
                FrameKind::Done => {
                    if !done[w] {
                        done[w] = true;
                        ndone += 1;
                        map_invocations += f.seq as usize;
                    }
                }
                // The worker's span batch for the round (sent between
                // Outputs and Done when the Welcome enabled tracing).
                FrameKind::Spans => {
                    tracer.ingest(obs::decode_spans(&f.payload)?);
                }
                FrameKind::Failed => {
                    return Err(CamrError::from_wire(
                        f.tag,
                        String::from_utf8_lossy(&f.payload).into_owned(),
                    ));
                }
                other => {
                    return Err(CamrError::Wire(format!(
                        "unexpected {other:?} frame from worker {w} during collection"
                    )))
                }
            },
            // A finished worker closing its socket is the normal exit.
            Ok(HubEvent::Closed(w, _)) if done[w] => {}
            Ok(HubEvent::Closed(w, why)) => {
                return Err(CamrError::Disconnected(format!(
                    "worker {w} vanished before finishing: {why}"
                )));
            }
            Err(_) => {
                if obs::metrics_enabled() {
                    obs::metrics().disconnect_timeouts.inc();
                }
                return Err(CamrError::Disconnected(format!(
                    "no progress for {timeout:?} collecting outputs \
                     ({ndone}/{servers} workers done)"
                )));
            }
        }
    }
    let reduce_time = t0.elapsed() - phase_marks[3];
    Ok(HubResult { outputs, map_invocations, phase_marks, reduce_time })
}

/// `camr worker --connect <url>`: dial the hub and run one round as a
/// subprocess worker. The process exits nonzero on error; failures are
/// also reported to the hub as `Failed` frames where possible.
pub fn run_worker(url: &str) -> Result<()> {
    worker_at(url, true, None)
}

/// Dial + execute one round. `hard_exit` selects the die-after hook's
/// behavior (process exit vs dropped connection); `pool` lets
/// thread-mode workers share the engine's buffer pool (hygiene tests).
fn worker_at(url: &str, hard_exit: bool, pool: Option<BufferPool>) -> Result<()> {
    let stream = dial(url)?;
    worker_over_stream(stream, hard_exit, pool)
}

/// The worker side of the protocol, given a connected stream: handshake,
/// rebuild the run from the shipped recipe, execute
/// [`proto::run_round`] over a [`SocketTransport`], ship outputs.
fn worker_over_stream(
    mut stream: SockStream,
    hard_exit: bool,
    pool: Option<BufferPool>,
) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(25)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    let mut dec = FrameDecoder::new();

    // Handshake: announce the wire version, receive id + recipe.
    let mut hello = Frame::new(FrameKind::Hello);
    hello.tag = WIRE_VERSION;
    write_frame(&mut stream, &hello, &[])?;
    let welcome =
        read_frame_deadline(&mut stream, &mut dec, Instant::now() + Duration::from_secs(30))?;
    if welcome.kind != FrameKind::Welcome {
        return Err(CamrError::Wire(format!("expected Welcome, got {:?}", welcome.kind)));
    }
    let id = welcome.tag as ServerId;
    let pooling = welcome.job & 1 == 1;
    let tracing = welcome.job & 2 == 2;
    let die_after = match welcome.extra {
        0 => None,
        n => Some((n - 1) as usize),
    };

    // Rebuild the run deterministically from the shipped config text.
    let text = String::from_utf8_lossy(&welcome.payload).into_owned();
    let rc = RunConfig::from_text(&text)?;
    let master = Master::new(rc.system.clone())?;
    // Workers re-derive the plan from the shipped config; pre-flight
    // it independently so a worker never executes a schedule the hub
    // could not have proven (defense in depth across the trust
    // boundary of the wire).
    crate::check::preflight(&master)?;
    let wl = workload::build_native(rc.workload, &master.cfg, rc.seed)?;
    let schedule = master.schedule()?;
    let pool = pool.unwrap_or_default();
    // Worker-local tracer: spans use this process's own epoch (per-tid
    // timelines stay coherent; cross-process skew is handshake-level and
    // documented in `obs`). The batch ships to the hub before `Done`.
    let tracer = if tracing { Tracer::on() } else { Tracer::Off };
    let mut ctx = RoundCtx::new(&master.cfg, &master.placement, &*wl, &schedule, &pool, pooling);
    ctx.tracer = tracer.clone();
    let mut worker = Worker::new(id, &master.cfg);

    let mut link = SocketTransport::new(stream, dec, id, die_after, hard_exit);
    link.set_span_sink(tracer.sink());
    let run = proto::run_round(id, &mut worker, &ctx, &mut link);

    if link.crashed() {
        // Thread-mode die-after hook: vanish without reporting.
        return Ok(());
    }
    if let Some(e) = run.error {
        // The Failed frame already went to the hub via Transport::fail.
        return Err(e);
    }
    if link.aborted() {
        return Err(CamrError::Runtime(format!("worker {id}: run aborted")));
    }
    link.send_outputs(&run.outputs)?;
    if tracer.enabled() {
        link.flush_spans();
        let spans = tracer.take_spans();
        if !spans.is_empty() {
            link.send_spans(&spans)?;
        }
    }
    link.send_done(run.map_invocations)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_text_roundtrips_through_runconfig() {
        let cfg = SystemConfig::with_options(3, 2, 2, 2, 96).unwrap();
        let spec = WorkerSpec { kind: WorkloadKind::Gradient, seed: 0xFEED };
        let rc = RunConfig::from_text(&spec_text(&cfg, &spec)).unwrap();
        assert_eq!(rc.system, cfg);
        assert_eq!(rc.workload, WorkloadKind::Gradient);
        assert_eq!(rc.seed, 0xFEED);
    }
}
