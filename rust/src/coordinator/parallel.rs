//! Thread-per-worker execution engine: real concurrency, byte-exact
//! accounting, pluggable transport.
//!
//! [`ParallelEngine`] runs the identical CAMR protocol as the serial
//! [`super::engine::Engine`], but with one worker per server executing
//! [`super::proto::run_round`] over a [`crate::net::transport::Transport`].
//! The phases are separated by barriers, matching the bulk-synchronous
//! structure of the paper's protocol:
//!
//! ```text
//! map ─barrier─ stage 1 ─barrier─ stage 2 ─barrier─ stage 3 ─barrier─ reduce
//! ```
//!
//! Two data planes implement that contract:
//!
//! - [`TransportKind::Chan`] (default): one OS thread per server, mpsc
//!   channels, [`std::sync::Barrier`] synchronization — the engine this
//!   module always was.
//! - [`TransportKind::Socket`]: workers in separate processes (or
//!   threads) speaking the length-prefixed wire format of
//!   [`crate::net::frame`] over loopback TCP or a Unix-domain socket,
//!   orchestrated by the [`super::remote`] hub.
//!
//! ## Why load accounting stays exact under concurrency
//!
//! Workers charge the shared link through a channel-backed
//! [`crate::net::BusRecorder`], tagging every transmission with its
//! *schedule sequence number* — the position it would occupy in a serial
//! execution. [`crate::net::SharedBus::collect`] sorts by that tag, so
//! the ledger (order, senders, recipients, byte counts) is identical to
//! the serial engine's regardless of thread interleaving; multicasts are
//! still charged exactly once. On the socket plane the recorder lives in
//! the coordinator hub and charges each forwarded frame once — same
//! sequence numbers, same ledger. The property tests assert ledger
//! equality byte for byte across all planes.
//!
//! ## Pooled data plane
//!
//! Coded `Δ` payloads live in [`crate::shuffle::buf::BufferPool`]
//! buffers shared across all worker threads: a sender encodes once into
//! a pooled buffer and ships the *same* payload to every group member
//! as a cheap [`crate::shuffle::buf::SharedBuf`] clone (an `Arc` bump,
//! not a byte copy) — or, over sockets, streams it onto the wire
//! straight from the pooled backing store. When the last reference
//! drops the backing store returns to the free list exactly once. None
//! of this changes what the bus records.
//!
//! ## Failure handling
//!
//! A worker that hits an error publishes it through the transport
//! ([`crate::net::transport::Transport::fail`]) and keeps meeting every
//! barrier without doing work; peers waiting on its packets observe the
//! abort and bail out the same way. The run then surfaces the root
//! cause instead of deadlocking. Over sockets a *vanished* worker
//! process additionally surfaces as a typed
//! [`CamrError::Disconnected`] within the configured timeout.

use super::engine::{verify_outputs, RunOutcome};
use super::master::Master;
use super::proto::{self, RoundCtx};
use super::remote::{self, SocketOptions, WorkerSpec};
use super::worker::Worker;
use crate::agg::Value;
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::net::transport::{InProcTransport, Packet};
use crate::net::{Bus, SharedBus, Stage};
use crate::obs::{SpanKind, Tracer, COORD};
use crate::shuffle::buf::{BufferPool, PoolStats};
use crate::workload::Workload;
use crate::{FuncId, JobId, ServerId};
use std::collections::HashMap;
use std::sync::atomic::AtomicBool;
use std::sync::{mpsc, Barrier};
use std::time::{Duration, Instant};

/// Which data plane the engine moves packets over.
#[derive(Debug, Clone, Default)]
pub enum TransportKind {
    /// In-process mpsc channels, one thread per server (default).
    #[default]
    Chan,
    /// Socket transport (TCP or Unix-domain) via the [`remote`] hub;
    /// requires [`ParallelEngine::remote_spec`] so worker processes can
    /// reconstruct the workload deterministically.
    Socket(SocketOptions),
}

/// What a worker thread hands back when it finishes.
struct WorkerDone {
    worker: Worker,
    map_invocations: usize,
    outputs: Vec<((JobId, FuncId), Value)>,
    error: Option<CamrError>,
}

/// The thread-per-worker engine. Produces the same [`RunOutcome`] (and
/// the same [`Bus`] ledger) as the serial engine for the same config and
/// workload — on every transport.
pub struct ParallelEngine {
    /// The master (design, placement, schedule factory).
    pub master: Master,
    workers: Vec<Worker>,
    workload: Box<dyn Workload>,
    /// Ledger of the last run, in canonical (serial-equivalent) order.
    pub bus: Bus,
    /// Skip oracle verification (for large load-sweep runs).
    pub verify: bool,
    /// Route shuffle buffers through the shared [`BufferPool`]
    /// (default). `false` restores the legacy allocate-per-packet data
    /// plane; the ledger is byte-identical either way.
    pub pooling: bool,
    /// Which packet plane [`ParallelEngine::run`] uses.
    pub transport: TransportKind,
    /// Deterministic workload recipe shipped to socket-transport worker
    /// processes (required for [`TransportKind::Socket`]; ignored on the
    /// channel plane, where the in-process `workload` is used directly).
    pub remote_spec: Option<WorkerSpec>,
    /// Span collector ([`Tracer::Off`] by default — the no-op branch).
    /// On the channel plane every worker thread buffers spans locally and
    /// drains them here at round end; on the socket plane workers ship
    /// their spans to the hub in a [`crate::net::frame::FrameKind::Spans`]
    /// frame and the hub ingests them into this same tracer.
    pub tracer: Tracer,
    pool: BufferPool,
    outputs: HashMap<(JobId, FuncId), Value>,
}

impl ParallelEngine {
    /// Build an engine for a config and workload.
    pub fn new(cfg: SystemConfig, workload: Box<dyn Workload>) -> Result<Self> {
        let master = Master::new(cfg)?;
        // Pre-flight on every transport this engine fronts (chan,
        // tcp, unix): prove the plan before spawning threads or
        // worker processes (see `crate::check::prover`).
        crate::check::preflight(&master)?;
        let workers =
            (0..master.cfg.servers()).map(|s| Worker::new(s, &master.cfg)).collect();
        Ok(ParallelEngine {
            master,
            workers,
            workload,
            bus: Bus::new(),
            verify: true,
            pooling: true,
            transport: TransportKind::Chan,
            remote_spec: None,
            tracer: Tracer::Off,
            pool: BufferPool::new(),
            outputs: HashMap::new(),
        })
    }

    /// Access the system config.
    pub fn cfg(&self) -> &SystemConfig {
        &self.master.cfg
    }

    /// Counters of the shared shuffle buffer pool.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// A reduced output (after `run`).
    pub fn output(&self, job: JobId, func: FuncId) -> Option<&Value> {
        self.outputs.get(&(job, func))
    }

    /// Swap in the next job's workload, returning the previous one (see
    /// [`crate::coordinator::engine::Engine::replace_workload`]; the
    /// batch runtime reuses the engine's threads-per-run setup, schedule
    /// and shared buffer pool across jobs).
    pub fn replace_workload(&mut self, workload: Box<dyn Workload>) -> Box<dyn Workload> {
        std::mem::replace(&mut self.workload, workload)
    }

    /// Move the reduced outputs out of the engine (cleared at the start
    /// of the next `run` anyway); lets the batch runtime verify job `i`
    /// off-thread while job `i+1` executes.
    pub fn take_outputs(&mut self) -> HashMap<(JobId, FuncId), Value> {
        std::mem::take(&mut self.outputs)
    }

    /// Run the full protocol over the selected transport and return
    /// measured loads.
    pub fn run(&mut self) -> Result<RunOutcome> {
        match self.transport.clone() {
            TransportKind::Chan => self.run_chan(),
            TransportKind::Socket(opts) => self.run_socket(&opts),
        }
    }

    /// Socket plane: hand the run to the [`remote`] hub, which spawns
    /// worker processes (or threads), records the ledger once per
    /// forwarded frame, and hands back bus + outputs.
    fn run_socket(&mut self, opts: &SocketOptions) -> Result<RunOutcome> {
        let spec = self.remote_spec.clone().ok_or_else(|| {
            CamrError::InvalidConfig(
                "socket transport requires remote_spec (the workload recipe shipped to \
                 worker processes)"
                    .into(),
            )
        })?;
        self.outputs.clear();
        let run = remote::run_socket(
            &self.master,
            &spec,
            &*self.workload,
            &self.pool,
            self.pooling,
            self.verify,
            &self.tracer,
            opts,
        )?;
        self.bus = run.bus;
        self.outputs = run.outputs;
        Ok(run.outcome)
    }

    /// Channel plane: one scoped OS thread per server, all executing
    /// [`proto::run_round`] over [`InProcTransport`].
    fn run_chan(&mut self) -> Result<RunOutcome> {
        self.outputs.clear();
        let schedule = self.master.schedule()?;
        let servers = self.master.cfg.servers();

        let mut workers: Vec<Worker> = self.workers.drain(..).collect();
        for w in &mut workers {
            w.store.clear();
        }

        let cfg = &self.master.cfg;
        let mut ctx = RoundCtx::new(
            cfg,
            &self.master.placement,
            &*self.workload,
            &schedule,
            &self.pool,
            self.pooling,
        );
        ctx.tracer = self.tracer.clone();
        let ctx = ctx;
        let barrier = Barrier::new(servers + 1);
        let failed = AtomicBool::new(false);

        let shared_bus = SharedBus::new();
        let (done_tx, done_rx) = mpsc::channel::<WorkerDone>();
        let mut inboxes: Vec<mpsc::Sender<Packet>> = Vec::with_capacity(servers);
        let mut receivers: Vec<mpsc::Receiver<Packet>> = Vec::with_capacity(servers);
        for _ in 0..servers {
            let (tx, rx) = mpsc::channel();
            inboxes.push(tx);
            receivers.push(rx);
        }

        let t0 = Instant::now();
        let (map_time, shuffle_time, stage_times, t_reduce) = std::thread::scope(|s| {
            for (id, (mut worker, inbox)) in workers.drain(..).zip(receivers).enumerate() {
                let peers = inboxes.clone();
                let bus = shared_bus.recorder();
                let done = done_tx.clone();
                let ctx = &ctx;
                let barrier = &barrier;
                let failed = &failed;
                std::thread::Builder::new()
                    .name(format!("camr-worker-{id}"))
                    .spawn_scoped(s, move || {
                        let mut link =
                            InProcTransport::new(id, inbox, peers, bus, barrier, failed);
                        let run = proto::run_round(id, &mut worker, ctx, &mut link);
                        let _ = done.send(WorkerDone {
                            worker,
                            map_invocations: run.map_invocations,
                            outputs: run.outputs,
                            error: run.error,
                        });
                    })
                    .expect("spawn worker thread");
            }
            // The main thread participates in the four phase barriers
            // only to timestamp them.
            barrier.wait(); // map done
            let map_time = t0.elapsed();
            let t1 = Instant::now();
            barrier.wait(); // stage 1 done
            let m1 = t1.elapsed();
            barrier.wait(); // stage 2 done
            let m2 = t1.elapsed();
            barrier.wait(); // stage 3 done
            let shuffle_time = t1.elapsed();
            let stage_times = [m1, m2 - m1, shuffle_time - m2];
            (map_time, shuffle_time, stage_times, Instant::now())
        });
        drop(done_tx);
        drop(inboxes);

        // All threads have exited: gather workers, outputs and errors.
        let mut map_invocations = 0usize;
        let mut outputs: HashMap<(JobId, FuncId), Value> = HashMap::new();
        let mut returned: Vec<Worker> = Vec::with_capacity(servers);
        let mut errors: Vec<(ServerId, CamrError)> = Vec::new();
        for done in done_rx.iter() {
            map_invocations += done.map_invocations;
            if let Some(e) = done.error {
                errors.push((done.worker.id, e));
            }
            for (key, v) in done.outputs {
                outputs.insert(key, v);
            }
            returned.push(done.worker);
        }
        returned.sort_by_key(|w| w.id);
        self.workers = returned;
        self.bus = shared_bus.collect();

        if !errors.is_empty() {
            // Surface the root cause: workers that merely timed out
            // waiting on a failed peer report a secondary "aborted after
            // peer failure" — prefer any primary error over those.
            errors.sort_by_key(|(id, _)| *id);
            let root = errors
                .iter()
                .position(|(_, e)| !e.to_string().contains("aborted after peer failure"))
                .unwrap_or(0);
            return Err(errors.remove(root).1);
        }

        let verified = if self.verify {
            let mut sink = self.tracer.sink();
            let t = sink.begin();
            verify_outputs(cfg, &*self.workload, &outputs)?;
            sink.record(t, SpanKind::Verify, COORD, 0, None, 0, outputs.len() as u64);
            true
        } else {
            true
        };
        let reduce_time = t_reduce.elapsed();
        self.outputs = outputs;

        Ok(RunOutcome {
            stage_bytes: [
                self.bus.stage_bytes(Stage::Stage1),
                self.bus.stage_bytes(Stage::Stage2),
                self.bus.stage_bytes(Stage::Stage3),
            ],
            normalizer: cfg.load_normalizer(),
            map_invocations,
            verified,
            outputs: self.outputs.len(),
            map_time,
            shuffle_time,
            stage_times,
            reduce_time,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;
    use crate::workload::synth::SyntheticWorkload;

    fn run_parallel(k: usize, q: usize, gamma: usize, seed: u64) -> (ParallelEngine, RunOutcome) {
        let cfg = SystemConfig::new(k, q, gamma).unwrap();
        let wl = SyntheticWorkload::new(&cfg, seed);
        let mut e = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        (e, out)
    }

    #[test]
    fn example1_loads_match_paper() {
        let (_, out) = run_parallel(3, 2, 2, 0xC0FFEE);
        assert!(out.verified);
        assert!((out.stage_load(1) - 0.25).abs() < 1e-12);
        assert!((out.stage_load(2) - 0.25).abs() < 1e-12);
        assert!((out.stage_load(3) - 0.50).abs() < 1e-12);
        assert!((out.total_load() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn matches_serial_engine_bytes_and_outputs() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let mut serial =
            Engine::new(cfg.clone(), Box::new(SyntheticWorkload::new(&cfg, 9))).unwrap();
        let sout = serial.run().unwrap();
        let (par, pout) = run_parallel(3, 2, 2, 9);
        assert_eq!(sout.stage_bytes, pout.stage_bytes);
        assert_eq!(sout.outputs, pout.outputs);
        for j in 0..cfg.jobs() {
            for f in 0..cfg.functions() {
                assert_eq!(serial.output(j, f), par.output(j, f), "job {j} func {f}");
            }
        }
    }

    #[test]
    fn pooled_and_unpooled_ledgers_identical() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let mut pooled =
            ParallelEngine::new(cfg.clone(), Box::new(SyntheticWorkload::new(&cfg, 21)))
                .unwrap();
        let pout = pooled.run().unwrap();
        let mut legacy =
            ParallelEngine::new(cfg.clone(), Box::new(SyntheticWorkload::new(&cfg, 21)))
                .unwrap();
        legacy.pooling = false;
        let lout = legacy.run().unwrap();
        assert!(pout.verified && lout.verified);
        assert_eq!(pout.stage_bytes, lout.stage_bytes);
        for j in 0..cfg.jobs() {
            for f in 0..cfg.functions() {
                assert_eq!(pooled.output(j, f), legacy.output(j, f), "job {j} func {f}");
            }
        }
        // Every pooled buffer returned exactly once across all threads.
        let stats = pooled.pool_stats();
        assert!(stats.acquired > 0);
        assert_eq!(stats.outstanding(), 0);
        assert_eq!(stats.acquired, stats.released);
        assert_eq!(legacy.pool_stats().acquired, 0);
    }

    #[test]
    fn rerun_is_idempotent() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 4);
        let mut e = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
        let a = e.run().unwrap();
        let b = e.run().unwrap();
        assert_eq!(a.stage_bytes, b.stage_bytes);
        assert!(b.verified);
    }

    #[test]
    fn multi_round_verified() {
        let cfg = SystemConfig::with_options(3, 2, 2, 2, 64).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 1);
        let mut e = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified);
        assert!((out.total_load() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stage_times_cover_the_shuffle() {
        let (_, out) = run_parallel(3, 2, 2, 5);
        let sum: Duration = out.stage_times.iter().sum();
        assert_eq!(sum, out.shuffle_time);
    }

    #[test]
    fn socket_transport_without_spec_is_typed_error() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 1);
        let mut e = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
        e.transport = TransportKind::Socket(SocketOptions::unix_threads());
        match e.run() {
            Err(CamrError::InvalidConfig(m)) => assert!(m.contains("remote_spec")),
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }
}
