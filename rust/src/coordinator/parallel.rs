//! Thread-per-worker execution engine: real concurrency, byte-exact
//! accounting.
//!
//! [`ParallelEngine`] runs the identical CAMR protocol as the serial
//! [`super::engine::Engine`], but with one OS thread per server (pool
//! sized to `K`). The phases are separated by [`std::sync::Barrier`]
//! synchronization, matching the bulk-synchronous structure of the
//! paper's protocol:
//!
//! ```text
//! map ─barrier─ stage 1 ─barrier─ stage 2 ─barrier─ stage 3 ─barrier─ reduce
//! ```
//!
//! - **Map**: every worker maps its stored batches concurrently — this
//!   is where the wall-clock speedup over the serial engine comes from.
//! - **Stages 1–2** (coded multicasts): each worker encodes the `Δ`
//!   broadcasts for every Lemma-2 group it belongs to and sends them to
//!   the other group members through per-worker mpsc channels; it then
//!   decodes each group once all of that group's broadcasts arrived.
//!   Groups of a stage proceed concurrently — correct because every
//!   encode reads only map-phase aggregates while every decode writes a
//!   fresh `(job, func, batch)` key, and each worker's store is touched
//!   only by its own thread.
//! - **Stage 3** (unicasts): senders fuse and ship, receivers store.
//! - **Reduce**: each worker reduces the functions it is responsible
//!   for; the main thread collects outputs and runs oracle verification.
//!
//! ## Why load accounting stays exact under concurrency
//!
//! Workers charge the shared link through a channel-backed
//! [`crate::net::BusRecorder`], tagging every transmission with its
//! *schedule sequence number* — the position it would occupy in a serial
//! execution. [`crate::net::SharedBus::collect`] sorts by that tag, so
//! the ledger (order, senders, recipients, byte counts) is identical to
//! the serial engine's regardless of thread interleaving; multicasts are
//! still charged exactly once. The property tests assert ledger equality
//! byte for byte.
//!
//! ## Pooled data plane
//!
//! Coded `Δ` payloads live in [`crate::shuffle::buf::BufferPool`]
//! buffers shared across all worker threads: a sender encodes once into
//! a pooled buffer and ships the *same* payload to every group member
//! as a cheap [`crate::shuffle::buf::SharedBuf`] clone (an `Arc` bump,
//! not a byte copy). Decode scratch packets come from the same pool.
//! When the last reference drops — normally after decode, or during
//! unwinding on a failure — the backing store returns to the free list
//! exactly once. None of this changes what the bus records: the ledger
//! stays byte-identical to the serial engine's, pooling on or off.
//!
//! ## Failure handling
//!
//! A worker that hits an error (e.g. a failing map kernel) raises a
//! shared poison flag and keeps meeting every barrier without doing
//! work; peers waiting on its packets time out, observe the flag, and
//! abort their phase the same way. The run then surfaces the
//! lowest-numbered worker's error instead of deadlocking.

use super::engine::{verify_outputs, RunOutcome};
use super::master::{Master, Schedule};
use super::worker::Worker;
use crate::agg::Value;
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::net::{Bus, BusRecorder, SharedBus, Stage};
use crate::placement::Placement;
use crate::shuffle::buf::{BufferPool, PoolStats, SharedBuf};
use crate::shuffle::multicast::GroupPlan;
use crate::workload::Workload;
use crate::{FuncId, JobId, ServerId};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Barrier};
use std::time::{Duration, Instant};

/// A packet exchanged worker-to-worker through channels.
enum Packet {
    /// Coded broadcast `Δ` from member position `from` of the flattened
    /// stage-1/2 group with global index `group`. The payload is a
    /// [`SharedBuf`]: one encoded buffer shared by every recipient
    /// (no per-recipient clone of the bytes).
    Delta { group: usize, from: usize, delta: SharedBuf },
    /// Stage-3 fused unicast payload for `schedule.stage3[spec]`.
    Fused { spec: usize, value: Vec<u8> },
}

/// One stage-1/2 group, flattened with its ledger sequence base.
struct StageGroup<'a> {
    /// Which coded stage the group belongs to.
    stage: Stage,
    /// Barrier phase: 0 for stage 1, 1 for stage 2.
    phase: usize,
    /// The Lemma-2 plan.
    plan: &'a GroupPlan,
    /// Sequence number of this group's first broadcast in a serial run.
    seq_base: u64,
}

/// Read-only state shared by every worker thread for one run.
struct Shared<'a> {
    cfg: &'a SystemConfig,
    placement: &'a Placement,
    workload: &'a dyn Workload,
    schedule: &'a Schedule,
    groups: Vec<StageGroup<'a>>,
    /// Sequence number of the first stage-3 unicast.
    stage3_base: u64,
    barrier: &'a Barrier,
    failed: &'a AtomicBool,
    /// Shared buffer arena for Δ and scratch packets (all threads
    /// acquire from and release to the same free list).
    pool: &'a BufferPool,
    /// Whether to route buffers through the pool (engine's `pooling`).
    pooling: bool,
}

/// What a worker thread hands back when it finishes.
struct WorkerDone {
    worker: Worker,
    map_invocations: usize,
    outputs: Vec<((JobId, FuncId), Value)>,
    error: Option<CamrError>,
}

/// Per-group receive state during a coded phase.
struct GroupState {
    /// This worker's member position in the group.
    pos: usize,
    /// Broadcast slots, one per member position (shared payloads).
    deltas: Vec<Option<SharedBuf>>,
}

/// The thread-per-worker engine. Produces the same [`RunOutcome`] (and
/// the same [`Bus`] ledger) as the serial engine for the same config and
/// workload.
pub struct ParallelEngine {
    /// The master (design, placement, schedule factory).
    pub master: Master,
    workers: Vec<Worker>,
    workload: Box<dyn Workload>,
    /// Ledger of the last run, in canonical (serial-equivalent) order.
    pub bus: Bus,
    /// Skip oracle verification (for large load-sweep runs).
    pub verify: bool,
    /// Route shuffle buffers through the shared [`BufferPool`]
    /// (default). `false` restores the legacy allocate-per-packet data
    /// plane; the ledger is byte-identical either way.
    pub pooling: bool,
    pool: BufferPool,
    outputs: HashMap<(JobId, FuncId), Value>,
}

impl ParallelEngine {
    /// Build an engine for a config and workload.
    pub fn new(cfg: SystemConfig, workload: Box<dyn Workload>) -> Result<Self> {
        let master = Master::new(cfg)?;
        let workers =
            (0..master.cfg.servers()).map(|s| Worker::new(s, &master.cfg)).collect();
        Ok(ParallelEngine {
            master,
            workers,
            workload,
            bus: Bus::new(),
            verify: true,
            pooling: true,
            pool: BufferPool::new(),
            outputs: HashMap::new(),
        })
    }

    /// Access the system config.
    pub fn cfg(&self) -> &SystemConfig {
        &self.master.cfg
    }

    /// Counters of the shared shuffle buffer pool.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// A reduced output (after `run`).
    pub fn output(&self, job: JobId, func: FuncId) -> Option<&Value> {
        self.outputs.get(&(job, func))
    }

    /// Swap in the next job's workload, returning the previous one (see
    /// [`crate::coordinator::engine::Engine::replace_workload`]; the
    /// batch runtime reuses the engine's threads-per-run setup, schedule
    /// and shared buffer pool across jobs).
    pub fn replace_workload(&mut self, workload: Box<dyn Workload>) -> Box<dyn Workload> {
        std::mem::replace(&mut self.workload, workload)
    }

    /// Move the reduced outputs out of the engine (cleared at the start
    /// of the next `run` anyway); lets the batch runtime verify job `i`
    /// off-thread while job `i+1` executes.
    pub fn take_outputs(&mut self) -> HashMap<(JobId, FuncId), Value> {
        std::mem::take(&mut self.outputs)
    }

    /// Run the full protocol with one thread per server and return
    /// measured loads.
    pub fn run(&mut self) -> Result<RunOutcome> {
        self.outputs.clear();
        let schedule = self.master.schedule()?;
        let servers = self.master.cfg.servers();

        // Flatten the coded groups with ledger sequence numbers matching
        // the serial engine's emission order: all stage-1 groups in
        // schedule order (one broadcast per member, in member order),
        // then all stage-2 groups, then the stage-3 unicasts.
        let mut groups: Vec<StageGroup<'_>> =
            Vec::with_capacity(schedule.stage1.len() + schedule.stage2.len());
        let mut seq = 0u64;
        for (stage, phase, plans) in [
            (Stage::Stage1, 0usize, &schedule.stage1),
            (Stage::Stage2, 1usize, &schedule.stage2),
        ] {
            for plan in plans.iter() {
                groups.push(StageGroup { stage, phase, plan, seq_base: seq });
                seq += plan.members.len() as u64;
            }
        }
        let stage3_base = seq;

        let mut workers: Vec<Worker> = self.workers.drain(..).collect();
        for w in &mut workers {
            w.store.clear();
        }

        let cfg = &self.master.cfg;
        let placement = &self.master.placement;
        let workload: &dyn Workload = &*self.workload;
        let barrier = Barrier::new(servers + 1);
        let failed = AtomicBool::new(false);
        let shared = Shared {
            cfg,
            placement,
            workload,
            schedule: &schedule,
            groups,
            stage3_base,
            barrier: &barrier,
            failed: &failed,
            pool: &self.pool,
            pooling: self.pooling,
        };

        let shared_bus = SharedBus::new();
        let (done_tx, done_rx) = mpsc::channel::<WorkerDone>();
        let mut inboxes: Vec<mpsc::Sender<Packet>> = Vec::with_capacity(servers);
        let mut receivers: Vec<mpsc::Receiver<Packet>> = Vec::with_capacity(servers);
        for _ in 0..servers {
            let (tx, rx) = mpsc::channel();
            inboxes.push(tx);
            receivers.push(rx);
        }

        let t0 = Instant::now();
        let (map_time, shuffle_time, t_reduce) = std::thread::scope(|s| {
            for (id, (worker, inbox)) in workers.drain(..).zip(receivers).enumerate() {
                let peers = inboxes.clone();
                let bus = shared_bus.recorder();
                let done = done_tx.clone();
                let shared = &shared;
                std::thread::Builder::new()
                    .name(format!("camr-worker-{id}"))
                    .spawn_scoped(s, move || {
                        worker_main(id, worker, shared, &inbox, &peers, &bus, &done)
                    })
                    .expect("spawn worker thread");
            }
            // The main thread participates in the four phase barriers
            // only to timestamp them.
            barrier.wait(); // map done
            let map_time = t0.elapsed();
            let t1 = Instant::now();
            barrier.wait(); // stage 1 done
            barrier.wait(); // stage 2 done
            barrier.wait(); // stage 3 done
            let shuffle_time = t1.elapsed();
            (map_time, shuffle_time, Instant::now())
        });
        drop(done_tx);
        drop(inboxes);

        // All threads have exited: gather workers, outputs and errors.
        let mut map_invocations = 0usize;
        let mut outputs: HashMap<(JobId, FuncId), Value> = HashMap::new();
        let mut returned: Vec<Worker> = Vec::with_capacity(servers);
        let mut errors: Vec<(ServerId, CamrError)> = Vec::new();
        for done in done_rx.iter() {
            map_invocations += done.map_invocations;
            if let Some(e) = done.error {
                errors.push((done.worker.id, e));
            }
            for (key, v) in done.outputs {
                outputs.insert(key, v);
            }
            returned.push(done.worker);
        }
        returned.sort_by_key(|w| w.id);
        self.workers = returned;
        self.bus = shared_bus.collect();

        if !errors.is_empty() {
            // Surface the root cause: workers that merely timed out
            // waiting on a failed peer report a secondary "aborted after
            // peer failure" — prefer any primary error over those.
            errors.sort_by_key(|(id, _)| *id);
            let root = errors
                .iter()
                .position(|(_, e)| !e.to_string().contains("aborted after peer failure"))
                .unwrap_or(0);
            return Err(errors.remove(root).1);
        }

        let verified = if self.verify {
            verify_outputs(cfg, workload, &outputs)?;
            true
        } else {
            true
        };
        let reduce_time = t_reduce.elapsed();
        self.outputs = outputs;

        Ok(RunOutcome {
            stage_bytes: [
                self.bus.stage_bytes(Stage::Stage1),
                self.bus.stage_bytes(Stage::Stage2),
                self.bus.stage_bytes(Stage::Stage3),
            ],
            normalizer: cfg.load_normalizer(),
            map_invocations,
            verified,
            outputs: self.outputs.len(),
            map_time,
            shuffle_time,
            reduce_time,
        })
    }
}

/// Body of one worker thread: all five phases, with a barrier after the
/// map phase and after each shuffle stage. On error the worker poisons
/// the shared flag but keeps meeting every barrier so nobody deadlocks.
fn worker_main(
    id: ServerId,
    mut worker: Worker,
    sh: &Shared<'_>,
    inbox: &mpsc::Receiver<Packet>,
    peers: &[mpsc::Sender<Packet>],
    bus: &BusRecorder,
    done: &mpsc::Sender<WorkerDone>,
) {
    let mut error: Option<CamrError> = None;
    let fail = |e: CamrError, slot: &mut Option<CamrError>, flag: &AtomicBool| {
        flag.store(true, Ordering::SeqCst);
        if slot.is_none() {
            *slot = Some(e);
        }
    };

    // ---- Map.
    let mut map_invocations = 0usize;
    match worker.run_map_phase(sh.cfg, sh.placement, sh.workload) {
        Ok(n) => map_invocations = n,
        Err(e) => fail(e, &mut error, sh.failed),
    }
    sh.barrier.wait();

    // ---- Coded stages 1 and 2.
    for phase in 0..2 {
        if error.is_none() && !sh.failed.load(Ordering::SeqCst) {
            if let Err(e) = run_coded_phase(id, &mut worker, sh, phase, inbox, peers, bus) {
                fail(e, &mut error, sh.failed);
            }
        }
        sh.barrier.wait();
    }

    // ---- Stage 3.
    if error.is_none() && !sh.failed.load(Ordering::SeqCst) {
        if let Err(e) = run_stage3(id, &mut worker, sh, inbox, peers, bus) {
            fail(e, &mut error, sh.failed);
        }
    }
    sh.barrier.wait();

    // ---- Reduce.
    let mut outputs = Vec::new();
    if error.is_none() && !sh.failed.load(Ordering::SeqCst) {
        match run_reduce(id, &worker, sh) {
            Ok(o) => outputs = o,
            Err(e) => fail(e, &mut error, sh.failed),
        }
    }

    let _ = done.send(WorkerDone { worker, map_invocations, outputs, error });
}

/// Receive one packet, bailing out (instead of blocking forever) once the
/// shared failure flag is raised and the inbox has drained.
fn recv_packet(inbox: &mpsc::Receiver<Packet>, failed: &AtomicBool) -> Option<Packet> {
    loop {
        match inbox.recv_timeout(Duration::from_millis(10)) {
            Ok(p) => return Some(p),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if failed.load(Ordering::SeqCst) {
                    // Final non-blocking sweep: packets already in flight
                    // must not be mistaken for missing ones.
                    return inbox.try_recv().ok();
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return None,
        }
    }
}

/// One coded phase (stage 1 or 2) for one worker: encode and broadcast
/// `Δ` for every owned group, then receive peers' broadcasts, then decode
/// every group's missing chunk into the local store.
fn run_coded_phase(
    id: ServerId,
    worker: &mut Worker,
    sh: &Shared<'_>,
    phase: usize,
    inbox: &mpsc::Receiver<Packet>,
    peers: &[mpsc::Sender<Packet>],
    bus: &BusRecorder,
) -> Result<()> {
    // The groups of this phase that this worker belongs to.
    let mut mine: HashMap<usize, GroupState> = HashMap::new();
    let mut order: Vec<usize> = Vec::new();
    let mut expected = 0usize;
    for (gi, g) in sh.groups.iter().enumerate() {
        if g.phase != phase {
            continue;
        }
        if let Some(pos) = g.plan.members.iter().position(|&m| m == id) {
            expected += g.plan.members.len() - 1;
            mine.insert(gi, GroupState { pos, deltas: vec![None; g.plan.members.len()] });
            order.push(gi);
        }
    }

    // Encode + broadcast in schedule order. Each Δ is encoded once —
    // into a pooled buffer when pooling is on — and shared with every
    // recipient through cheap `SharedBuf` clones.
    for &gi in &order {
        let g = &sh.groups[gi];
        let delta = worker.encode_for_group_shared(g.plan, sh.pool, sh.pooling)?;
        let st = mine.get_mut(&gi).expect("own group");
        let recipients: Vec<ServerId> =
            g.plan.members.iter().copied().filter(|&m| m != id).collect();
        bus.multicast(g.seq_base + st.pos as u64, g.stage, id, recipients, delta.len());
        for &m in g.plan.members.iter().filter(|&&m| m != id) {
            let _ = peers[m].send(Packet::Delta {
                group: gi,
                from: st.pos,
                delta: delta.clone(),
            });
        }
        st.deltas[st.pos] = Some(delta);
    }

    // Receive the other members' broadcasts.
    let mut received = 0usize;
    while received < expected {
        let Some(pkt) = recv_packet(inbox, sh.failed) else {
            return Err(CamrError::Runtime(format!(
                "worker {id}: coded stage aborted after peer failure"
            )));
        };
        match pkt {
            Packet::Delta { group, from, delta } => {
                let st = mine.get_mut(&group).ok_or_else(|| {
                    CamrError::Runtime(format!(
                        "worker {id}: delta for group {group} it is not a member of"
                    ))
                })?;
                if st.deltas[from].replace(delta).is_some() {
                    return Err(CamrError::Runtime(format!(
                        "worker {id}: duplicate delta from position {from} of group {group}"
                    )));
                }
                received += 1;
            }
            Packet::Fused { .. } => {
                return Err(CamrError::Runtime(format!(
                    "worker {id}: stage-3 packet during a coded stage"
                )))
            }
        }
    }

    // Decode every group (schedule order for determinism of any error).
    // Deltas are *taken* out of the receive state, so each group's
    // buffers return to the pool as soon as its decode finishes —
    // per-group recycling, same as the serial engine.
    for &gi in &order {
        let g = &sh.groups[gi];
        let st = mine.get_mut(&gi).expect("own group");
        let deltas: Vec<SharedBuf> = st
            .deltas
            .iter_mut()
            .map(|d| d.take().expect("all broadcasts received"))
            .collect();
        if sh.pooling {
            worker.decode_from_group_pooled(g.plan, &deltas, sh.pool)?;
        } else {
            worker.decode_from_group(g.plan, &deltas)?;
        }
    }
    Ok(())
}

/// Stage 3 for one worker: fuse + send every unicast it owns, then
/// receive and store every fused aggregate addressed to it.
fn run_stage3(
    id: ServerId,
    worker: &mut Worker,
    sh: &Shared<'_>,
    inbox: &mpsc::Receiver<Packet>,
    peers: &[mpsc::Sender<Packet>],
    bus: &BusRecorder,
) -> Result<()> {
    let agg = sh.workload.aggregator();
    let mut expected = 0usize;
    for (si, u) in sh.schedule.stage3.iter().enumerate() {
        if u.receiver == id {
            expected += 1;
        }
        if u.sender == id {
            let v = worker.fuse_for_unicast(agg, u)?;
            bus.unicast(sh.stage3_base + si as u64, Stage::Stage3, id, u.receiver, v.len());
            let _ = peers[u.receiver].send(Packet::Fused { spec: si, value: v });
        }
    }
    let mut received = 0usize;
    while received < expected {
        let Some(pkt) = recv_packet(inbox, sh.failed) else {
            return Err(CamrError::Runtime(format!(
                "worker {id}: stage 3 aborted after peer failure"
            )));
        };
        match pkt {
            Packet::Fused { spec, value } => {
                worker.receive_fused(&sh.schedule.stage3[spec], value)?;
                received += 1;
            }
            Packet::Delta { .. } => {
                return Err(CamrError::Runtime(format!(
                    "worker {id}: coded-stage packet during stage 3"
                )))
            }
        }
    }
    Ok(())
}

/// Reduce every (job, func) pair this worker is the reducer of.
fn run_reduce(
    id: ServerId,
    worker: &Worker,
    sh: &Shared<'_>,
) -> Result<Vec<((JobId, FuncId), Value)>> {
    let agg = sh.workload.aggregator();
    let mut out = Vec::new();
    for f in 0..sh.cfg.functions() {
        if sh.cfg.reducer_of(f) != id {
            continue;
        }
        for j in 0..sh.cfg.jobs() {
            out.push(((j, f), worker.reduce(sh.cfg, sh.placement, agg, j, f)?));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;
    use crate::workload::synth::SyntheticWorkload;

    fn run_parallel(k: usize, q: usize, gamma: usize, seed: u64) -> (ParallelEngine, RunOutcome) {
        let cfg = SystemConfig::new(k, q, gamma).unwrap();
        let wl = SyntheticWorkload::new(&cfg, seed);
        let mut e = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        (e, out)
    }

    #[test]
    fn example1_loads_match_paper() {
        let (_, out) = run_parallel(3, 2, 2, 0xC0FFEE);
        assert!(out.verified);
        assert!((out.stage_load(1) - 0.25).abs() < 1e-12);
        assert!((out.stage_load(2) - 0.25).abs() < 1e-12);
        assert!((out.stage_load(3) - 0.50).abs() < 1e-12);
        assert!((out.total_load() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn matches_serial_engine_bytes_and_outputs() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let mut serial =
            Engine::new(cfg.clone(), Box::new(SyntheticWorkload::new(&cfg, 9))).unwrap();
        let sout = serial.run().unwrap();
        let (par, pout) = run_parallel(3, 2, 2, 9);
        assert_eq!(sout.stage_bytes, pout.stage_bytes);
        assert_eq!(sout.outputs, pout.outputs);
        for j in 0..cfg.jobs() {
            for f in 0..cfg.functions() {
                assert_eq!(serial.output(j, f), par.output(j, f), "job {j} func {f}");
            }
        }
    }

    #[test]
    fn pooled_and_unpooled_ledgers_identical() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let mut pooled =
            ParallelEngine::new(cfg.clone(), Box::new(SyntheticWorkload::new(&cfg, 21)))
                .unwrap();
        let pout = pooled.run().unwrap();
        let mut legacy =
            ParallelEngine::new(cfg.clone(), Box::new(SyntheticWorkload::new(&cfg, 21)))
                .unwrap();
        legacy.pooling = false;
        let lout = legacy.run().unwrap();
        assert!(pout.verified && lout.verified);
        assert_eq!(pout.stage_bytes, lout.stage_bytes);
        for j in 0..cfg.jobs() {
            for f in 0..cfg.functions() {
                assert_eq!(pooled.output(j, f), legacy.output(j, f), "job {j} func {f}");
            }
        }
        // Every pooled buffer returned exactly once across all threads.
        let stats = pooled.pool_stats();
        assert!(stats.acquired > 0);
        assert_eq!(stats.outstanding(), 0);
        assert_eq!(stats.acquired, stats.released);
        assert_eq!(legacy.pool_stats().acquired, 0);
    }

    #[test]
    fn rerun_is_idempotent() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 4);
        let mut e = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
        let a = e.run().unwrap();
        let b = e.run().unwrap();
        assert_eq!(a.stage_bytes, b.stage_bytes);
        assert!(b.verified);
    }

    #[test]
    fn multi_round_verified() {
        let cfg = SystemConfig::with_options(3, 2, 2, 2, 64).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 1);
        let mut e = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified);
        assert!((out.total_load() - 1.0).abs() < 1e-12);
    }
}
