//! Multi-job **batch runtime**: execute the *entire* job set of a
//! scheme — all `q^(k-1)` CAMR jobs, all `C(K, μK+1)` CCDC jobs (under
//! a cap), or the uncoded baseline — end to end, through one persistent
//! engine.
//!
//! The paper's headline claim (§V, Table III) is about *job counts*,
//! not single-job loads: CAMR achieves CCDC's communication load while
//! requiring exponentially fewer concurrent jobs. That claim only
//! matters if the whole job set actually runs, so this module promotes
//! the engines from "one run, exact bytes" to "full workload, exact
//! bytes *and* end-to-end time":
//!
//! - **Persistent worker pool** — one [`Engine`] / [`ParallelEngine`]
//!   (workers, placement, schedule, [`crate::shuffle::buf::BufferPool`])
//!   is reused across every execution unit of the batch; only the workload
//!   is swapped per unit ([`Engine::replace_workload`]), so buffers
//!   recycled by job `i` serve job `i+1` without reallocation.
//! - **Pipelined verification** — oracle verification of unit `i`
//!   (a pure check, not part of the protocol) runs on a background
//!   thread while unit `i+1` executes, hiding its cost behind real work.
//! - **Aggregate ledger** — each unit's byte-exact ledger is folded
//!   into one job-tagged transcript ([`crate::net::Bus::append_ledger`]);
//!   a job-tag change is a phase barrier, so
//!   [`crate::sim::simulate_batch`] can replay the whole batch and
//!   report both the barriered makespan and the pipelined makespan
//!   where unit `i+1` maps (compute) while unit `i` shuffles (link).
//! - **Per-job failure tolerance** — with [`BatchOptions::strict`] off,
//!   a CAMR/uncoded unit that fails is recorded while the rest of the
//!   batch keeps running: a unit that failed to *execute* contributes
//!   no traffic, while one that executed but failed *verification*
//!   keeps its (genuine) traffic in the aggregate ledger and is only
//!   excluded from `jobs_executed`. The shared buffer pool must come
//!   back clean either way (`outstanding == 0`, asserted by the batch
//!   tests).
//!
//! ## Execution units
//!
//! CAMR couples its `J = q^(k-1)` jobs into **one coded execution
//! round** — that is the whole point of the design — so the CAMR (and
//! uncoded-baseline) batch executes rounds of `J` jobs each:
//! `jobs = all` is the scheme's required set (one round), `jobs = N`
//! executes `⌈N/J⌉` rounds. CCDC's jobs are independent, so its unit is
//! a single job and `jobs = all` is the full `C(K, k)` family — capped
//! by [`BatchOptions::ccdc_cap`], because that count is exponential
//! (which is exactly the limitation CAMR removes).

use super::engine::{verify_outputs, Engine, RunOutcome};
use super::parallel::ParallelEngine;
use crate::agg::Value;
use crate::analysis::jobs::binomial;
use crate::baseline::ccdc::CcdcEngine;
use crate::baseline::uncoded::{UncodedEngine, UncodedMode};
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::net::Bus;
use crate::obs::{self, PhaseRollup, Tracer};
use crate::shuffle::buf::PoolStats;
use crate::sim::{self, BatchSimOutcome, SimConfig};
use crate::util::rng::mix_key;
use crate::workload::Workload;
use crate::{FuncId, JobId};
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Which scheme a batch executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchScheme {
    /// CAMR coded rounds of `q^(k-1)` jobs each.
    Camr,
    /// CCDC baseline: independent jobs, `C(K, k)` required.
    Ccdc,
    /// Uncoded-aggregated baseline over the Algorithm-1 placement.
    Uncoded,
}

impl BatchScheme {
    /// Parse a scheme name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "camr" => BatchScheme::Camr,
            "ccdc" => BatchScheme::Ccdc,
            "uncoded" => BatchScheme::Uncoded,
            other => {
                return Err(CamrError::InvalidConfig(format!(
                    "unknown batch scheme {other} (camr | ccdc | uncoded)"
                )))
            }
        })
    }

    /// Short label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            BatchScheme::Camr => "camr",
            BatchScheme::Ccdc => "ccdc",
            BatchScheme::Uncoded => "uncoded",
        }
    }
}

/// Default cap on executed CCDC jobs (`C(K, k)` is exponential).
pub const DEFAULT_CCDC_CAP: usize = 1000;

/// Knobs of one batch execution.
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// Paper-job budget: `None` = the scheme's full required set;
    /// `Some(n)` = at least `n` jobs (CAMR/uncoded round up to whole
    /// rounds of `J`).
    pub jobs: Option<usize>,
    /// Use the thread-per-worker [`ParallelEngine`] for CAMR rounds.
    pub parallel: bool,
    /// Route shuffle buffers through the shared pool (CAMR engines).
    pub pooling: bool,
    /// Oracle-verify every unit's outputs (CAMR rounds; the uncoded and
    /// CCDC engines verify inside their own runs unconditionally).
    pub verify: bool,
    /// Verify unit `i` on a background thread while unit `i+1` runs
    /// (only meaningful with `verify`; CAMR rounds only).
    pub pipeline_verify: bool,
    /// Fail the whole batch on the first unit error. With `false`,
    /// failed CAMR/uncoded units are recorded and skipped; the CCDC
    /// family executes atomically, so any of its failures always aborts
    /// the batch.
    pub strict: bool,
    /// Cap on executed CCDC jobs (`None` = run the full family — think
    /// twice). Ignored by the other schemes.
    pub ccdc_cap: Option<usize>,
    /// Base seed; unit `u` draws its workload from
    /// `mix_key(seed, [u])`, so every unit maps fresh data.
    pub seed: u64,
    /// Span collector threaded into the CAMR engines ([`Tracer::Off`]
    /// by default). When enabled, every executed unit's spans are rolled
    /// up into its [`UnitRecord::phases`] and the full span set stays in
    /// the tracer for export after the batch.
    pub tracer: Tracer,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions {
            jobs: None,
            parallel: false,
            pooling: true,
            verify: true,
            pipeline_verify: true,
            strict: true,
            ccdc_cap: Some(DEFAULT_CCDC_CAP),
            seed: 0xCA3A,
            tracer: Tracer::Off,
        }
    }
}

/// What happened to one execution unit (a CAMR/uncoded round, or one
/// CCDC job).
#[derive(Debug, Clone)]
pub struct UnitRecord {
    /// Unit index in attempt order.
    pub unit: usize,
    /// Paper jobs covered by this unit.
    pub jobs: usize,
    /// Bytes the unit put on the link (0 if it failed).
    pub bytes: usize,
    /// Map invocations the unit executed.
    pub map_invocations: usize,
    /// Whether the unit's outputs passed oracle verification.
    pub verified: bool,
    /// The unit's failure, if any (execution or verification).
    pub error: Option<String>,
    /// Per-phase wall windows of this unit's spans (empty unless the
    /// batch ran with [`BatchOptions::tracer`] enabled; CAMR units only).
    pub phases: Vec<PhaseRollup>,
}

/// Result of one batch execution.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// The scheme executed.
    pub scheme: BatchScheme,
    /// Jobs the scheme *requires* at this storage fraction (Table III
    /// closed form: `q^(k-1)` for CAMR/uncoded, `C(K, k)` for CCDC).
    pub jobs_required: u128,
    /// Paper jobs successfully executed end to end.
    pub jobs_executed: usize,
    /// Paper jobs attempted (== executed unless units failed).
    pub jobs_attempted: usize,
    /// Per-unit records, in attempt order.
    pub units: Vec<UnitRecord>,
    /// Aggregate job-tagged ledger of every unit that *executed*
    /// (including units later vetoed by verification — their traffic
    /// really crossed the link), tagged `0..n` in execution order.
    pub bus: Bus,
    /// Per-executed-unit per-worker map counts, aligned with the
    /// ledger's job tags (input to [`crate::sim::simulate_batch`]).
    pub maps: Vec<Vec<usize>>,
    /// Sum of the executed units' load normalizers (`J·Q·B` each).
    pub normalizer: f64,
    /// Buffer-pool counters after the batch (CAMR engines; `None` for
    /// schemes without a pooled data plane).
    pub pool: Option<PoolStats>,
    /// Wall-clock time of the whole batch.
    pub wall: Duration,
}

impl BatchOutcome {
    /// Total bytes across the successful units.
    pub fn total_bytes(&self) -> usize {
        self.bus.total_bytes()
    }

    /// Aggregate communication load (per-unit loads are identical, so
    /// this equals the single-run load of the scheme).
    pub fn load(&self) -> f64 {
        self.total_bytes() as f64 / self.normalizer
    }

    /// True when every attempted unit executed and verified.
    pub fn all_verified(&self) -> bool {
        self.units.iter().all(|u| u.verified && u.error.is_none())
    }

    /// Paper jobs whose traffic is in the aggregate ledger: every unit
    /// that executed, including verification-vetoed ones. This is the
    /// denominator for per-job *time* statistics ([`Self::simulate`]
    /// replays exactly these jobs), whereas [`Self::jobs_executed`]
    /// counts only fully successful jobs.
    ///
    /// [`Self::jobs_executed`]: BatchOutcome::jobs_executed
    pub fn jobs_simulated(&self) -> usize {
        self.units.iter().filter(|u| u.bytes > 0).map(|u| u.jobs).sum()
    }

    /// Replay the aggregate ledger through the cluster simulator:
    /// barriered vs pipelined makespan of the whole batch.
    pub fn simulate(&self, sc: &SimConfig) -> Result<BatchSimOutcome> {
        sim::simulate_batch(sc, &self.maps, self.bus.ledger())
    }
}

/// A workload source for the batch runtime: unit index + derived seed →
/// that unit's workload. Ignored by the CCDC scheme (its workload is
/// defined over its own job family).
pub type WorkloadFactory<'a> = dyn Fn(usize, u64) -> Result<Box<dyn Workload>> + 'a;

/// The engine face the batch loop drives — implemented by both CAMR
/// engines so the loop is written once. Also the persistent-engine face
/// each [`crate::service`] dispatcher owns: one boxed `RoundEngine` per
/// dispatcher thread, workload swapped per job, buffers reused across
/// the whole job stream.
pub(crate) trait RoundEngine {
    fn run_once(&mut self) -> Result<RunOutcome>;
    fn swap_workload(&mut self, wl: Box<dyn Workload>) -> Box<dyn Workload>;
    fn grab_outputs(&mut self) -> HashMap<(JobId, FuncId), Value>;
    fn ledger_bus(&self) -> &Bus;
    fn worker_maps(&self) -> Vec<usize>;
    fn pool_counters(&self) -> PoolStats;
}

impl RoundEngine for Engine {
    fn run_once(&mut self) -> Result<RunOutcome> {
        self.run()
    }
    fn swap_workload(&mut self, wl: Box<dyn Workload>) -> Box<dyn Workload> {
        self.replace_workload(wl)
    }
    fn grab_outputs(&mut self) -> HashMap<(JobId, FuncId), Value> {
        self.take_outputs()
    }
    fn ledger_bus(&self) -> &Bus {
        &self.bus
    }
    fn worker_maps(&self) -> Vec<usize> {
        sim::camr_per_worker_maps(self.cfg(), &self.master.placement)
    }
    fn pool_counters(&self) -> PoolStats {
        self.pool_stats()
    }
}

impl RoundEngine for ParallelEngine {
    fn run_once(&mut self) -> Result<RunOutcome> {
        self.run()
    }
    fn swap_workload(&mut self, wl: Box<dyn Workload>) -> Box<dyn Workload> {
        self.replace_workload(wl)
    }
    fn grab_outputs(&mut self) -> HashMap<(JobId, FuncId), Value> {
        self.take_outputs()
    }
    fn ledger_bus(&self) -> &Bus {
        &self.bus
    }
    fn worker_maps(&self) -> Vec<usize> {
        sim::camr_per_worker_maps(self.cfg(), &self.master.placement)
    }
    fn pool_counters(&self) -> PoolStats {
        self.pool_stats()
    }
}

/// Number of CAMR rounds covering a paper-job budget.
fn rounds_for(cfg: &SystemConfig, jobs: Option<usize>) -> Result<usize> {
    let per_round = cfg.jobs();
    let rounds = match jobs {
        None => 1,
        Some(0) => return Err(CamrError::InvalidConfig("batch needs >= 1 job".into())),
        Some(n) => n.div_ceil(per_round),
    };
    if rounds > 100_000 {
        return Err(CamrError::InvalidConfig(format!(
            "{rounds} rounds is too large a batch to execute"
        )));
    }
    Ok(rounds)
}

/// Execute a batch of `scheme` over `cfg`. See the module docs for the
/// execution-unit semantics; `factory` supplies each CAMR/uncoded
/// unit's workload (use [`run_batch_synthetic`] when any deterministic
/// aggregatable data will do).
pub fn run_batch(
    cfg: &SystemConfig,
    scheme: BatchScheme,
    opts: &BatchOptions,
    factory: &WorkloadFactory<'_>,
) -> Result<BatchOutcome> {
    match scheme {
        BatchScheme::Camr => run_camr_batch(cfg, opts, factory),
        BatchScheme::Uncoded => run_uncoded_batch(cfg, opts, factory),
        BatchScheme::Ccdc => run_ccdc_batch(cfg, opts),
    }
}

/// [`run_batch`] with a [`crate::workload::synth::SyntheticWorkload`]
/// per unit (seeded from the unit's derived seed).
pub fn run_batch_synthetic(
    cfg: &SystemConfig,
    scheme: BatchScheme,
    opts: &BatchOptions,
) -> Result<BatchOutcome> {
    let cfg2 = cfg.clone();
    run_batch(cfg, scheme, opts, &move |_, seed| {
        Ok(Box::new(crate::workload::synth::SyntheticWorkload::new(&cfg2, seed))
            as Box<dyn Workload>)
    })
}

/// The CAMR batch: rounds of `J` coupled jobs through one persistent
/// engine (serial or thread-per-worker), verification pipelined behind
/// the next round's execution.
fn run_camr_batch(
    cfg: &SystemConfig,
    opts: &BatchOptions,
    factory: &WorkloadFactory<'_>,
) -> Result<BatchOutcome> {
    let rounds = rounds_for(cfg, opts.jobs)?;
    let per_round = cfg.jobs();
    let t0 = Instant::now();

    let mut engine: Box<dyn RoundEngine> = if opts.parallel {
        let mut e = ParallelEngine::new(cfg.clone(), factory(0, mix_key(opts.seed, &[0]))?)?;
        e.pooling = opts.pooling;
        e.verify = false; // the batch loop owns verification
        e.tracer = opts.tracer.clone();
        Box::new(e)
    } else {
        let mut e = Engine::new(cfg.clone(), factory(0, mix_key(opts.seed, &[0]))?)?;
        e.pooling = opts.pooling;
        e.verify = false;
        e.tracer = opts.tracer.clone();
        Box::new(e)
    };

    let mut units: Vec<UnitRecord> = Vec::with_capacity(rounds);
    let mut bus = Bus::new();
    let mut maps: Vec<Vec<usize>> = Vec::new();
    let mut normalizer = 0.0f64;
    // Traced batches: each unit's spans are drained for its roll-up and
    // re-ingested afterwards, so the tracer still holds the whole batch.
    let mut all_spans: Vec<obs::Span> = Vec::new();

    // Verification results flow back over a channel: (unit, error?).
    let (vtx, vrx) = mpsc::channel::<(usize, Option<String>)>();
    std::thread::scope(|scope| -> Result<()> {
        // The outputs of the last *successful* round, awaiting
        // verification against its workload (still inside the engine
        // until the next round's swap hands it back).
        let mut pending: Option<(usize, HashMap<(JobId, FuncId), Value>)> = None;
        let verify_now = |unit: usize,
                          wl: &dyn Workload,
                          outputs: &HashMap<(JobId, FuncId), Value>| {
            let res = verify_outputs(cfg, wl, outputs);
            let _ = vtx.send((unit, res.err().map(|e| e.to_string())));
        };
        for r in 0..rounds {
            if r > 0 {
                let prev = engine.swap_workload(factory(r, mix_key(opts.seed, &[r as u64]))?);
                // Launch (or run inline) the previous round's check while
                // this round executes.
                if let Some((unit, outputs)) = pending.take() {
                    if opts.pipeline_verify {
                        let tx = vtx.clone();
                        scope.spawn(move || {
                            let res = verify_outputs(cfg, &*prev, &outputs);
                            let _ = tx.send((unit, res.err().map(|e| e.to_string())));
                        });
                    } else {
                        verify_now(unit, &*prev, &outputs);
                    }
                }
            }
            match engine.run_once() {
                Ok(out) => {
                    let tag = maps.len();
                    bus.append_ledger(engine.ledger_bus().ledger(), tag);
                    maps.push(engine.worker_maps());
                    normalizer += cfg.load_normalizer();
                    let phases = if opts.tracer.enabled() {
                        let spans = opts.tracer.take_spans();
                        let rollup = obs::phase_rollup(&spans);
                        all_spans.extend(spans);
                        rollup
                    } else {
                        Vec::new()
                    };
                    units.push(UnitRecord {
                        unit: r,
                        jobs: per_round,
                        bytes: out.stage_bytes.iter().sum(),
                        map_invocations: out.map_invocations,
                        verified: true, // provisional; vrx may veto below
                        error: None,
                        phases,
                    });
                    if opts.verify {
                        pending = Some((r, engine.grab_outputs()));
                    }
                }
                Err(e) => {
                    if opts.strict {
                        return Err(e);
                    }
                    engine.grab_outputs(); // discard partial state
                    if opts.tracer.enabled() {
                        all_spans.extend(opts.tracer.take_spans());
                    }
                    units.push(UnitRecord {
                        unit: r,
                        jobs: per_round,
                        bytes: 0,
                        map_invocations: 0,
                        verified: false,
                        error: Some(e.to_string()),
                        phases: Vec::new(),
                    });
                }
            }
        }
        // Verify the final successful round inline (there is no next
        // round to hide it behind).
        if let Some((unit, outputs)) = pending.take() {
            let wl = engine.swap_workload(Box::new(NoWorkload));
            verify_now(unit, &*wl, &outputs);
        }
        Ok(())
    })?;
    drop(vtx);
    let mut failures: Vec<(usize, String)> = Vec::new();
    for (unit, err) in vrx.iter() {
        if let Some(msg) = err {
            let rec = units.iter_mut().find(|u| u.unit == unit).expect("verified unit");
            rec.verified = false;
            rec.error = Some(msg.clone());
            failures.push((unit, msg));
        }
    }
    if opts.strict {
        if let Some((unit, msg)) = failures.first() {
            return Err(CamrError::Verification(format!("batch unit {unit}: {msg}")));
        }
    }

    // Hand the whole batch's spans back so callers can still export one
    // continuous trace (unit roll-ups above consumed them per unit).
    if !all_spans.is_empty() {
        opts.tracer.ingest(all_spans);
    }

    let jobs_executed: usize =
        units.iter().filter(|u| u.error.is_none()).map(|u| u.jobs).sum();
    Ok(BatchOutcome {
        scheme: BatchScheme::Camr,
        jobs_required: per_round as u128,
        jobs_executed,
        jobs_attempted: rounds * per_round,
        units,
        bus,
        maps,
        normalizer,
        pool: Some(engine.pool_counters()),
        wall: t0.elapsed(),
    })
}

/// Placeholder workload installed while a round's real workload is out
/// being verified; running the engine against it is a bug by
/// construction, and it reports as such.
struct NoWorkload;

impl Workload for NoWorkload {
    fn name(&self) -> &str {
        "batch-placeholder"
    }
    fn aggregator(&self) -> &dyn crate::agg::Aggregator {
        &crate::agg::SumU64
    }
    fn map_subfile(&self, job: JobId, subfile: usize) -> Result<Vec<Value>> {
        Err(CamrError::Runtime(format!(
            "batch placeholder workload mapped (job {job}, subfile {subfile}) — \
             a unit ran before its workload was installed"
        )))
    }
}

/// The uncoded-baseline batch: rounds of the same `J`-job workload over
/// the identical Algorithm-1 placement, verification inline (the
/// uncoded engine verifies inside `run`).
fn run_uncoded_batch(
    cfg: &SystemConfig,
    opts: &BatchOptions,
    factory: &WorkloadFactory<'_>,
) -> Result<BatchOutcome> {
    let rounds = rounds_for(cfg, opts.jobs)?;
    let per_round = cfg.jobs();
    let t0 = Instant::now();
    let mut engine = UncodedEngine::new(
        cfg.clone(),
        factory(0, mix_key(opts.seed, &[0]))?,
        UncodedMode::Aggregated,
    )?;
    let worker_maps = sim::camr_per_worker_maps(cfg, engine.placement());
    let mut units: Vec<UnitRecord> = Vec::with_capacity(rounds);
    let mut bus = Bus::new();
    let mut maps: Vec<Vec<usize>> = Vec::new();
    let mut normalizer = 0.0f64;
    for r in 0..rounds {
        if r > 0 {
            engine.replace_workload(factory(r, mix_key(opts.seed, &[r as u64]))?);
        }
        match engine.run() {
            Ok(out) => {
                let tag = maps.len();
                bus.append_ledger(engine.bus.ledger(), tag);
                maps.push(worker_maps.clone());
                normalizer += cfg.load_normalizer();
                units.push(UnitRecord {
                    unit: r,
                    jobs: per_round,
                    bytes: out.shuffle_bytes,
                    map_invocations: (cfg.k - 1) * per_round * cfg.subfiles(),
                    verified: out.verified,
                    error: None,
                    phases: Vec::new(),
                });
            }
            Err(e) => {
                if opts.strict {
                    return Err(e);
                }
                units.push(UnitRecord {
                    unit: r,
                    jobs: per_round,
                    bytes: 0,
                    map_invocations: 0,
                    verified: false,
                    error: Some(e.to_string()),
                    phases: Vec::new(),
                });
            }
        }
    }
    let jobs_executed: usize =
        units.iter().filter(|u| u.error.is_none()).map(|u| u.jobs).sum();
    Ok(BatchOutcome {
        scheme: BatchScheme::Uncoded,
        jobs_required: per_round as u128,
        jobs_executed,
        jobs_attempted: rounds * per_round,
        units,
        bus,
        maps,
        normalizer,
        pool: None,
        wall: t0.elapsed(),
    })
}

/// The CCDC batch: the (capped) job family through [`CcdcEngine`], one
/// unit per independent job, already per-job tagged by the engine.
///
/// The CCDC engine executes and bit-verifies its family atomically, so
/// [`BatchOptions::verify`], `pipeline_verify` and `strict` do not
/// apply here: every executed job is always verified, and any failure
/// aborts the whole CCDC batch (see the `BatchOptions` field docs).
fn run_ccdc_batch(cfg: &SystemConfig, opts: &BatchOptions) -> Result<BatchOutcome> {
    let family = binomial(cfg.servers() as u64, cfg.k as u64);
    let budget = match opts.jobs {
        None => usize::MAX,
        Some(0) => return Err(CamrError::InvalidConfig("batch needs >= 1 job".into())),
        Some(n) => n,
    };
    let cap = opts.ccdc_cap.unwrap_or(usize::MAX).min(budget);
    let t0 = Instant::now();
    let mut engine =
        CcdcEngine::new(cfg.servers(), cfg.k, cfg.gamma, cfg.value_bytes, opts.seed)?;
    let out = engine.run_capped(Some(cap))?;
    // One ledger pass for the per-job byte split (Bus::job_bytes would
    // rescan the whole ledger per job).
    let mut per_job_bytes = vec![0usize; out.jobs];
    for t in engine.bus.ledger() {
        per_job_bytes[t.job] += t.bytes;
    }
    let units: Vec<UnitRecord> = per_job_bytes
        .iter()
        .enumerate()
        .map(|(j, &bytes)| UnitRecord {
            unit: j,
            jobs: 1,
            bytes,
            map_invocations: (cfg.k - 1) * cfg.k * cfg.gamma,
            verified: out.verified,
            error: None,
            phases: Vec::new(),
        })
        .collect();
    let maps: Vec<Vec<usize>> =
        (0..out.jobs).map(|j| engine.per_worker_maps_per_job(j)).collect();
    Ok(BatchOutcome {
        scheme: BatchScheme::Ccdc,
        jobs_required: family,
        jobs_executed: out.jobs,
        jobs_attempted: out.jobs,
        units,
        bus: engine.bus.clone(),
        maps,
        normalizer: out.normalizer,
        pool: None,
        wall: t0.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::jobs::JobRequirement;

    fn opts() -> BatchOptions {
        BatchOptions::default()
    }

    #[test]
    fn camr_batch_all_executes_the_required_set_once() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let out = run_batch_synthetic(&cfg, BatchScheme::Camr, &opts()).unwrap();
        assert_eq!(out.jobs_required, 4);
        assert_eq!(out.jobs_executed, 4);
        assert_eq!(out.units.len(), 1);
        assert!(out.all_verified());
        assert!((out.load() - 1.0).abs() < 1e-12, "Example 1 load is 1");
        let pool = out.pool.expect("CAMR batches pool");
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    fn multi_round_batch_reuses_the_pool_and_tags_rounds() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let mut o = opts();
        o.jobs = Some(12); // 3 rounds of 4
        let out = run_batch_synthetic(&cfg, BatchScheme::Camr, &o).unwrap();
        assert_eq!(out.units.len(), 3);
        assert_eq!(out.jobs_executed, 12);
        assert_eq!(out.bus.job_count(), 3);
        assert_eq!(out.maps.len(), 3);
        // Every round's bytes are identical (the schedule is fixed).
        assert!(out.units.iter().all(|u| u.bytes == out.units[0].bytes));
        assert!((out.load() - 1.0).abs() < 1e-12);
        let pool = out.pool.unwrap();
        assert_eq!(pool.outstanding(), 0);
        assert!(pool.recycled > 0, "rounds must reuse each other's buffers: {pool:?}");
        // Rounds map *different* data (distinct derived seeds) yet the
        // ledger stays schedule-determined: uniform per-round bytes.
        assert_eq!(out.bus.job_bytes(0), out.bus.job_bytes(2));
    }

    #[test]
    fn traced_batch_rolls_up_phases_per_unit() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let mut o = opts();
        o.jobs = Some(8); // 2 rounds
        o.tracer = Tracer::on();
        let out = run_batch_synthetic(&cfg, BatchScheme::Camr, &o).unwrap();
        assert_eq!(out.units.len(), 2);
        for u in &out.units {
            assert!(!u.phases.is_empty(), "traced unit has a roll-up");
            assert!(u.phases.iter().any(|p| p.phase == "map"));
            assert!(u.phases.iter().any(|p| p.phase == "stage1" && p.bytes > 0));
        }
        // The tracer still holds the whole batch's spans for export,
        // and the byte-exact ledger is invariant under tracing.
        assert!(!o.tracer.take_spans().is_empty());
        assert!((out.load() - 1.0).abs() < 1e-12);
        let untraced = run_batch_synthetic(&cfg, BatchScheme::Camr, &{
            let mut u = opts();
            u.jobs = Some(8);
            u
        })
        .unwrap();
        assert_eq!(out.total_bytes(), untraced.total_bytes());
    }

    #[test]
    fn serial_and_parallel_batches_agree_byte_for_byte() {
        let cfg = SystemConfig::new(3, 2, 1).unwrap();
        let mut o = opts();
        o.jobs = Some(8); // 2 rounds
        let serial = run_batch_synthetic(&cfg, BatchScheme::Camr, &o).unwrap();
        o.parallel = true;
        let par = run_batch_synthetic(&cfg, BatchScheme::Camr, &o).unwrap();
        assert_eq!(serial.total_bytes(), par.total_bytes());
        assert_eq!(serial.bus.ledger().len(), par.bus.ledger().len());
        for (a, b) in serial.bus.ledger().iter().zip(par.bus.ledger()) {
            assert_eq!(a.stage, b.stage);
            assert_eq!(a.sender, b.sender);
            assert_eq!(a.recipients, b.recipients);
            assert_eq!(a.bytes, b.bytes);
            assert_eq!(a.job, b.job);
        }
    }

    #[test]
    fn ccdc_batch_executes_the_capped_family() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let all = run_batch_synthetic(&cfg, BatchScheme::Ccdc, &opts()).unwrap();
        assert_eq!(all.jobs_required, 20);
        assert_eq!(all.jobs_executed, 20);
        assert_eq!(all.units.len(), 20);
        assert_eq!(all.bus.job_count(), 20);
        let mut o = opts();
        o.ccdc_cap = Some(6);
        let capped = run_batch_synthetic(&cfg, BatchScheme::Ccdc, &o).unwrap();
        assert_eq!(capped.jobs_executed, 6);
        assert_eq!(capped.jobs_required, 20, "the requirement is cap-independent");
        // Requirement comparison matches Table III's closed forms.
        let req = JobRequirement::for_params(3, 2);
        let camr = run_batch_synthetic(&cfg, BatchScheme::Camr, &opts()).unwrap();
        assert_eq!(camr.jobs_required, req.camr);
        assert_eq!(all.jobs_required, req.ccdc);
        assert!(camr.jobs_required < all.jobs_required);
    }

    #[test]
    fn uncoded_batch_moves_more_bytes_than_camr() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let camr = run_batch_synthetic(&cfg, BatchScheme::Camr, &opts()).unwrap();
        let unc = run_batch_synthetic(&cfg, BatchScheme::Uncoded, &opts()).unwrap();
        assert_eq!(unc.jobs_executed, 4);
        assert!(unc.all_verified());
        assert!(unc.total_bytes() > camr.total_bytes());
        // Same map work per round, so the simulated gap is pure shuffle.
        assert_eq!(unc.maps, camr.maps);
    }

    #[test]
    fn batch_simulation_pipelined_beats_barriered() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let mut o = opts();
        o.jobs = Some(16); // 4 rounds
        let out = run_batch_synthetic(&cfg, BatchScheme::Camr, &o).unwrap();
        let mut sc = SimConfig::commodity();
        sc.link_bytes_per_sec = 1e5; // slow link: shuffle long enough to hide maps
        let sim = out.simulate(&sc).unwrap();
        assert_eq!(sim.jobs.len(), 4);
        assert!(sim.pipelined_secs < sim.serial_secs, "pipelining must help here");
        assert!(sim.pipelined_secs >= sim.shuffle_secs_total);
    }

    #[test]
    fn rejects_zero_job_budget() {
        let cfg = SystemConfig::new(3, 2, 1).unwrap();
        let mut o = opts();
        o.jobs = Some(0);
        assert!(run_batch_synthetic(&cfg, BatchScheme::Camr, &o).is_err());
        assert!(run_batch_synthetic(&cfg, BatchScheme::Ccdc, &o).is_err());
    }

    #[test]
    fn scheme_parsing() {
        assert_eq!(BatchScheme::parse("camr").unwrap(), BatchScheme::Camr);
        assert_eq!(BatchScheme::parse("ccdc").unwrap(), BatchScheme::Ccdc);
        assert_eq!(BatchScheme::parse("uncoded").unwrap(), BatchScheme::Uncoded);
        assert!(BatchScheme::parse("mapreduce").is_err());
        assert_eq!(BatchScheme::Ccdc.label(), "ccdc");
    }
}
