//! The CAMR round protocol, generic over [`Transport`].
//!
//! This is the body every worker executes — map, the two coded
//! multicast stages, the fused-unicast stage 3, reduce — factored out
//! of the thread engine so the *identical* code drives in-process
//! channels ([`crate::net::transport::InProcTransport`]) and sockets
//! ([`crate::net::socket::SocketTransport`]). The ledger sequence
//! numbers come from [`flatten`], which reproduces the serial engine's
//! emission order exactly; transports only carry them.
//!
//! Failure semantics are the engine's long-standing ones: a worker that
//! hits an error publishes it via [`Transport::fail`] and keeps meeting
//! every barrier without doing work, so nobody deadlocks. On the
//! channel plane barriers never fail; on the socket plane a failed
//! barrier means the coordinator is gone and the worker stops early.

use super::master::Schedule;
use super::worker::Worker;
use crate::agg::Value;
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::net::transport::{Packet, Transport};
use crate::net::Stage;
use crate::obs::{SpanKind, SpanSink, Tracer};
use crate::placement::Placement;
use crate::shuffle::buf::{BufferPool, SharedBuf};
use crate::shuffle::multicast::GroupPlan;
use crate::workload::Workload;
use crate::{FuncId, JobId, ServerId};
use std::collections::HashMap;

/// One stage-1/2 group, flattened with its ledger sequence base.
pub struct FlatGroup<'a> {
    /// Which coded stage the group belongs to.
    pub stage: Stage,
    /// Barrier phase: 0 for stage 1, 1 for stage 2.
    pub phase: usize,
    /// The Lemma-2 plan.
    pub plan: &'a GroupPlan,
    /// Sequence number of this group's first broadcast in a serial run.
    pub seq_base: u64,
}

/// Flatten the coded groups with ledger sequence numbers matching the
/// serial engine's emission order: all stage-1 groups in schedule order
/// (one broadcast per member, in member order), then all stage-2
/// groups. Returns the groups and the sequence number of the first
/// stage-3 unicast.
pub fn flatten(schedule: &Schedule) -> (Vec<FlatGroup<'_>>, u64) {
    let mut groups: Vec<FlatGroup<'_>> =
        Vec::with_capacity(schedule.stage1.len() + schedule.stage2.len());
    let mut seq = 0u64;
    for (stage, phase, plans) in [
        (Stage::Stage1, 0usize, &schedule.stage1),
        (Stage::Stage2, 1usize, &schedule.stage2),
    ] {
        for plan in plans.iter() {
            groups.push(FlatGroup { stage, phase, plan, seq_base: seq });
            seq += plan.members.len() as u64;
        }
    }
    (groups, seq)
}

/// Read-only state one worker needs for one round, shared across all
/// workers on the channel plane and rebuilt per process on the socket
/// plane (everything here is a pure function of config + seed).
pub struct RoundCtx<'a> {
    /// System parameters.
    pub cfg: &'a SystemConfig,
    /// File placement.
    pub placement: &'a Placement,
    /// The workload being executed.
    pub workload: &'a dyn Workload,
    /// The master's shuffle schedule.
    pub schedule: &'a Schedule,
    /// Flattened stage-1/2 groups with sequence bases.
    pub groups: Vec<FlatGroup<'a>>,
    /// Sequence number of the first stage-3 unicast.
    pub stage3_base: u64,
    /// Shared buffer arena for Δ and scratch packets.
    pub pool: &'a BufferPool,
    /// Whether to route buffers through the pool.
    pub pooling: bool,
    /// Span collector ([`Tracer::Off`] by default — the no-op branch).
    /// Every worker thread draws its own [`SpanSink`] from this.
    pub tracer: Tracer,
}

impl<'a> RoundCtx<'a> {
    /// Assemble the context (flattens the schedule).
    pub fn new(
        cfg: &'a SystemConfig,
        placement: &'a Placement,
        workload: &'a dyn Workload,
        schedule: &'a Schedule,
        pool: &'a BufferPool,
        pooling: bool,
    ) -> Self {
        let (groups, stage3_base) = flatten(schedule);
        RoundCtx {
            cfg,
            placement,
            workload,
            schedule,
            groups,
            stage3_base,
            pool,
            pooling,
            tracer: Tracer::Off,
        }
    }
}

/// What one worker hands back after a round.
pub struct WorkerRun {
    /// Map-function invocations this worker performed.
    pub map_invocations: usize,
    /// Reduced `(job, func) → value` outputs this worker owns.
    pub outputs: Vec<((JobId, FuncId), Value)>,
    /// First error this worker hit, if any (already published via
    /// [`Transport::fail`]).
    pub error: Option<CamrError>,
}

/// Per-group receive state during a coded phase.
struct GroupState {
    /// This worker's member position in the group.
    pos: usize,
    /// Broadcast slots, one per member position (shared payloads).
    deltas: Vec<Option<SharedBuf>>,
}

/// Execute one full round for worker `id` over transport `link`: all
/// five phases, with a barrier after the map phase and after each
/// shuffle stage. On error the worker publishes the failure but keeps
/// meeting every barrier so nobody deadlocks; a barrier that itself
/// fails (socket plane: coordinator gone or run aborted) stops the
/// round early.
pub fn run_round<T: Transport>(
    id: ServerId,
    worker: &mut Worker,
    ctx: &RoundCtx<'_>,
    link: &mut T,
) -> WorkerRun {
    let mut error: Option<CamrError> = None;
    // Thread-private span buffer; drains into the tracer when this
    // function returns (sink drop). No-op when tracing is off.
    let mut sink = ctx.tracer.sink();

    // ---- Map.
    let mut map_invocations = 0usize;
    let t = sink.begin();
    match worker.run_map_phase(ctx.cfg, ctx.placement, ctx.workload) {
        Ok(n) => map_invocations = n,
        Err(e) => {
            link.fail(&e);
            error = Some(e);
        }
    }
    sink.record(t, SpanKind::Map, id, 0, None, map_invocations as u64, 0);
    let mut stopped = link.barrier().is_err();

    // ---- Coded stages 1 and 2.
    for phase in 0..2 {
        if stopped {
            break;
        }
        if error.is_none() && !link.aborted() {
            if let Err(e) = run_coded_phase(id, worker, ctx, phase, link, &mut sink) {
                link.fail(&e);
                error.get_or_insert(e);
            }
        }
        stopped = link.barrier().is_err();
    }

    // ---- Stage 3.
    if !stopped {
        if error.is_none() && !link.aborted() {
            if let Err(e) = run_stage3(id, worker, ctx, link, &mut sink) {
                link.fail(&e);
                error.get_or_insert(e);
            }
        }
        stopped = link.barrier().is_err();
    }

    // ---- Reduce.
    let mut outputs = Vec::new();
    if !stopped && error.is_none() && !link.aborted() {
        match run_reduce(id, worker, ctx, &mut sink) {
            Ok(o) => outputs = o,
            Err(e) => {
                link.fail(&e);
                error = Some(e);
            }
        }
    }

    WorkerRun { map_invocations, outputs, error }
}

/// One coded phase (stage 1 or 2) for one worker: encode and broadcast
/// `Δ` for every owned group, then receive peers' broadcasts, then decode
/// every group's missing chunk into the local store.
fn run_coded_phase<T: Transport>(
    id: ServerId,
    worker: &mut Worker,
    ctx: &RoundCtx<'_>,
    phase: usize,
    link: &mut T,
    sink: &mut SpanSink,
) -> Result<()> {
    let stage = if phase == 0 { Stage::Stage1 } else { Stage::Stage2 };
    // The groups of this phase that this worker belongs to.
    let mut mine: HashMap<usize, GroupState> = HashMap::new();
    let mut order: Vec<usize> = Vec::new();
    let mut expected = 0usize;
    for (gi, g) in ctx.groups.iter().enumerate() {
        if g.phase != phase {
            continue;
        }
        if let Some(pos) = g.plan.members.iter().position(|&m| m == id) {
            expected += g.plan.members.len() - 1;
            mine.insert(gi, GroupState { pos, deltas: vec![None; g.plan.members.len()] });
            order.push(gi);
        }
    }

    // Encode + broadcast in schedule order. Each Δ is encoded once —
    // into a pooled buffer when pooling is on — and shared with every
    // recipient (SharedBuf clones in-process, one frame over sockets).
    for &gi in &order {
        let g = &ctx.groups[gi];
        let t = sink.begin();
        let delta = worker.encode_for_group_shared(g.plan, ctx.pool, ctx.pooling)?;
        let st = mine.get_mut(&gi).expect("own group");
        let seq = g.seq_base + st.pos as u64;
        sink.record(t, SpanKind::Encode, id, 0, Some(g.stage), seq, delta.len() as u64);
        let recipients: Vec<ServerId> =
            g.plan.members.iter().copied().filter(|&m| m != id).collect();
        link.send_delta(seq, g.stage, gi, st.pos, &recipients, &delta)?;
        st.deltas[st.pos] = Some(delta);
    }

    // Receive the other members' broadcasts.
    let t_recv = sink.begin();
    let mut recv_bytes = 0u64;
    let mut received = 0usize;
    while received < expected {
        let Some(pkt) = link.recv() else {
            return Err(CamrError::Runtime(format!(
                "worker {id}: coded stage aborted after peer failure"
            )));
        };
        match pkt {
            Packet::Delta { group, from, delta } => {
                let st = mine.get_mut(&group).ok_or_else(|| {
                    CamrError::Runtime(format!(
                        "worker {id}: delta for group {group} it is not a member of"
                    ))
                })?;
                recv_bytes += delta.len() as u64;
                if st.deltas[from].replace(delta).is_some() {
                    return Err(CamrError::Runtime(format!(
                        "worker {id}: duplicate delta from position {from} of group {group}"
                    )));
                }
                received += 1;
            }
            Packet::Fused { .. } => {
                return Err(CamrError::Runtime(format!(
                    "worker {id}: stage-3 packet during a coded stage"
                )))
            }
        }
    }

    // The receive window: send loop end → last peer broadcast in hand.
    sink.record(t_recv, SpanKind::Exchange, id, 0, Some(stage), 0, recv_bytes);

    // Decode every group (schedule order for determinism of any error).
    // Deltas are *taken* out of the receive state, so each group's
    // buffers return to the pool as soon as its decode finishes —
    // per-group recycling, same as the serial engine.
    for &gi in &order {
        let g = &ctx.groups[gi];
        let st = mine.get_mut(&gi).expect("own group");
        let deltas: Vec<SharedBuf> = st
            .deltas
            .iter_mut()
            .map(|d| d.take().expect("all broadcasts received"))
            .collect();
        let bytes: u64 = deltas.iter().map(|d| d.len() as u64).sum();
        let t = sink.begin();
        if ctx.pooling {
            worker.decode_from_group_pooled(g.plan, &deltas, ctx.pool)?;
        } else {
            worker.decode_from_group(g.plan, &deltas)?;
        }
        sink.record(t, SpanKind::Decode, id, 0, Some(g.stage), g.seq_base, bytes);
    }
    Ok(())
}

/// Stage 3 for one worker: fuse + send every unicast it owns, then
/// receive and store every fused aggregate addressed to it.
fn run_stage3<T: Transport>(
    id: ServerId,
    worker: &mut Worker,
    ctx: &RoundCtx<'_>,
    link: &mut T,
    sink: &mut SpanSink,
) -> Result<()> {
    let agg = ctx.workload.aggregator();
    let mut expected = 0usize;
    for (si, u) in ctx.schedule.stage3.iter().enumerate() {
        if u.receiver == id {
            expected += 1;
        }
        if u.sender == id {
            let t = sink.begin();
            let v = worker.fuse_for_unicast(agg, u)?;
            let bytes = v.len() as u64;
            let seq = ctx.stage3_base + si as u64;
            link.send_fused(seq, si, u.receiver, v)?;
            sink.record(t, SpanKind::Exchange, id, u.job, Some(Stage::Stage3), seq, bytes);
        }
    }
    let t_recv = sink.begin();
    let mut recv_bytes = 0u64;
    let mut received = 0usize;
    while received < expected {
        let Some(pkt) = link.recv() else {
            return Err(CamrError::Runtime(format!(
                "worker {id}: stage 3 aborted after peer failure"
            )));
        };
        match pkt {
            Packet::Fused { spec, value } => {
                recv_bytes += value.len() as u64;
                worker.receive_fused(&ctx.schedule.stage3[spec], value)?;
                received += 1;
            }
            Packet::Delta { .. } => {
                return Err(CamrError::Runtime(format!(
                    "worker {id}: coded-stage packet during stage 3"
                )))
            }
        }
    }
    // The stage-3 receive window.
    sink.record(t_recv, SpanKind::Exchange, id, 0, Some(Stage::Stage3), 0, recv_bytes);
    Ok(())
}

/// Reduce every (job, func) pair this worker is the reducer of.
fn run_reduce(
    id: ServerId,
    worker: &Worker,
    ctx: &RoundCtx<'_>,
    sink: &mut SpanSink,
) -> Result<Vec<((JobId, FuncId), Value)>> {
    let agg = ctx.workload.aggregator();
    let mut out = Vec::new();
    for f in 0..ctx.cfg.functions() {
        if ctx.cfg.reducer_of(f) != id {
            continue;
        }
        for j in 0..ctx.cfg.jobs() {
            let t = sink.begin();
            let value = worker.reduce(ctx.cfg, ctx.placement, agg, j, f)?;
            sink.record(t, SpanKind::Reduce, id, j, None, f as u64, value.len() as u64);
            out.push(((j, f), value));
        }
    }
    Ok(out)
}
