//! A CAMR worker (one of the `K` servers).
//!
//! Workers hold only *local* state: the batch aggregates they computed in
//! the Map phase plus whatever they decoded during the shuffle. All
//! encode/decode operations read exclusively from this local store — the
//! engine never "cheats" by reaching across servers, so a successful run
//! is a proof that the schedule is information-theoretically valid.

use super::values::{ValueKey, ValueStore};
use crate::agg::{Aggregator, Value};
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::placement::Placement;
use crate::shuffle::buf::{BufferPool, SharedBuf};
use crate::shuffle::multicast::GroupPlan;
use crate::shuffle::packet;
use crate::shuffle::plan::UnicastSpec;
use crate::workload::Workload;
use crate::{FuncId, JobId, ServerId};

/// One server of the cluster.
pub struct Worker {
    /// This worker's id (`U_{id+1}` in the paper).
    pub id: ServerId,
    /// Local batch aggregates + decoded shuffle values.
    pub store: ValueStore,
    value_bytes: usize,
}

impl Worker {
    /// Create an empty worker.
    pub fn new(id: ServerId, cfg: &SystemConfig) -> Self {
        Worker {
            id,
            store: ValueStore::new(cfg.jobs(), cfg.functions(), cfg.batches()),
            value_bytes: cfg.value_bytes,
        }
    }

    /// Map phase (§III-B): map every subfile of every stored batch for
    /// every output function, then aggregate per (job, func, batch).
    ///
    /// Returns the number of map invocations (for compute accounting —
    /// the paper's computation load is `r = K·μ` times the dataset).
    pub fn run_map_phase(
        &mut self,
        cfg: &SystemConfig,
        placement: &Placement,
        workload: &dyn Workload,
    ) -> Result<usize> {
        let agg = workload.aggregator();
        let mut invocations = 0usize;
        for (job, batch) in placement.inventory(self.id) {
            // Aggregate each function's values across the batch.
            let mut accs: Vec<Value> =
                (0..cfg.functions()).map(|_| agg.identity(self.value_bytes)).collect();
            for n in placement.batch_subfiles(batch) {
                let vals = workload.map_subfile(job, n)?;
                if vals.len() != cfg.functions() {
                    return Err(CamrError::Aggregation(format!(
                        "workload returned {} values, expected Q = {}",
                        vals.len(),
                        cfg.functions()
                    )));
                }
                invocations += 1;
                for (f, v) in vals.iter().enumerate() {
                    if v.len() != self.value_bytes {
                        return Err(CamrError::Aggregation(format!(
                            "value size {} != configured B = {}",
                            v.len(),
                            self.value_bytes
                        )));
                    }
                    agg.combine_into(&mut accs[f], v)?;
                }
            }
            for (f, acc) in accs.into_iter().enumerate() {
                self.store.put(ValueKey { job, func: f, batch }, acc);
            }
        }
        Ok(invocations)
    }

    /// Borrow the chunk payload for position `p` of a group plan from the
    /// local store (zero-copy encode/decode path, §Perf).
    fn chunk_ref(&self, plan: &GroupPlan, p: usize) -> Result<&[u8]> {
        let c = plan.chunks[p];
        Ok(self.store.get(ValueKey { job: c.job, func: c.func, batch: c.batch })?.as_slice())
    }

    /// Produce this worker's coded broadcast `Δ` for a group it belongs
    /// to (Algorithm 2, Eq. (3)).
    pub fn encode_for_group(&self, plan: &GroupPlan) -> Result<Vec<u8>> {
        let t = self.position_in(plan)?;
        plan.encode_ref(t, self.value_bytes, |p| self.chunk_ref(plan, p))
    }

    /// Encode this worker's coded broadcast `Δ` straight into a
    /// caller-provided buffer — the allocation-free encode path of the
    /// pooled data plane (the buffer is zero-filled before encoding, so
    /// it may come from [`BufferPool::acquire_unzeroed`]).
    pub fn encode_for_group_into(&self, plan: &GroupPlan, delta: &mut [u8]) -> Result<()> {
        let t = self.position_in(plan)?;
        plan.encode_ref_into(t, self.value_bytes, |p| self.chunk_ref(plan, p), delta)
    }

    /// Encode this worker's `Δ` as a [`SharedBuf`] ready to broadcast:
    /// through a recycled pool buffer when `pooling` is on, through a
    /// fresh allocation otherwise. One buffer serves every recipient.
    /// Shared by both engines so packet sizing stays in one place.
    pub fn encode_for_group_shared(
        &self,
        plan: &GroupPlan,
        pool: &BufferPool,
        pooling: bool,
    ) -> Result<SharedBuf> {
        if !pooling {
            return Ok(self.encode_for_group(plan)?.into());
        }
        if plan.size() < 2 {
            return Err(CamrError::ShuffleDecode("group size must be >= 2".into()));
        }
        let plen = packet::packet_len(self.value_bytes, plan.parts());
        let mut buf = pool.acquire_unzeroed(plen);
        self.encode_for_group_into(plan, buf.as_mut_slice())?;
        Ok(buf.into())
    }

    /// Decode this worker's missing chunk from the group's broadcasts and
    /// store it. `deltas[t]` is the broadcast of `plan.members[t]` — any
    /// borrowable byte container (`Vec<u8>`,
    /// [`crate::shuffle::buf::SharedBuf`], …).
    pub fn decode_from_group<D: AsRef<[u8]>>(
        &mut self,
        plan: &GroupPlan,
        deltas: &[D],
    ) -> Result<()> {
        let r = self.position_in(plan)?;
        let chunk =
            plan.decode_ref(r, self.value_bytes, deltas, |p| self.chunk_ref(plan, p))?;
        let c = plan.chunks[r];
        self.store.put(ValueKey { job: c.job, func: c.func, batch: c.batch }, chunk);
        Ok(())
    }

    /// Like [`Worker::decode_from_group`], but the scratch packet comes
    /// from `pool` instead of a fresh allocation.
    pub fn decode_from_group_pooled<D: AsRef<[u8]>>(
        &mut self,
        plan: &GroupPlan,
        deltas: &[D],
        pool: &BufferPool,
    ) -> Result<()> {
        let r = self.position_in(plan)?;
        let chunk = plan.decode_ref_pooled(
            r,
            self.value_bytes,
            deltas,
            |p| self.chunk_ref(plan, p),
            pool,
        )?;
        let c = plan.chunks[r];
        self.store.put(ValueKey { job: c.job, func: c.func, batch: c.batch }, chunk);
        Ok(())
    }

    /// Build the stage-3 fused aggregate (Eq. (5)) for a unicast this
    /// worker must send.
    pub fn fuse_for_unicast(&self, agg: &dyn Aggregator, u: &UnicastSpec) -> Result<Value> {
        if u.sender != self.id {
            return Err(CamrError::Placement(format!(
                "worker {} asked to send unicast owned by {}",
                self.id, u.sender
            )));
        }
        let mut acc = agg.identity(self.value_bytes);
        for &b in &u.batches {
            let v = self.store.get(ValueKey { job: u.job, func: u.func, batch: b })?;
            agg.combine_into(&mut acc, v)?;
        }
        Ok(acc)
    }

    /// Receive a stage-3 fused aggregate.
    pub fn receive_fused(&mut self, u: &UnicastSpec, v: Value) -> Result<()> {
        if u.receiver != self.id {
            return Err(CamrError::Placement(format!(
                "worker {} received unicast for {}",
                self.id, u.receiver
            )));
        }
        self.store.put_fused(u.job, u.func, v);
        Ok(())
    }

    /// Reduce `φ_f^{(j)}` (§III-D) from local + received values.
    ///
    /// - Owned job: fold the k-1 locally mapped batch aggregates with the
    ///   stage-1 decoded aggregate of the missing batch.
    /// - Non-owned job: fold the stage-2 batch aggregate with the stage-3
    ///   fused aggregate.
    pub fn reduce(
        &self,
        cfg: &SystemConfig,
        placement: &Placement,
        agg: &dyn Aggregator,
        job: JobId,
        func: FuncId,
    ) -> Result<Value> {
        if cfg.reducer_of(func) != self.id {
            return Err(CamrError::Placement(format!(
                "worker {} reducing function {func} assigned to {}",
                self.id,
                cfg.reducer_of(func)
            )));
        }
        if placement.owns(self.id, job) {
            // All k batch aggregates are in the store: k-1 mapped locally,
            // 1 decoded in stage 1.
            let mut acc = agg.identity(self.value_bytes);
            for b in 0..cfg.batches() {
                let v = self.store.get(ValueKey { job, func, batch: b })?;
                agg.combine_into(&mut acc, v)?;
            }
            Ok(acc)
        } else {
            // Stage 2 delivered one batch aggregate; stage 3 the fused
            // remainder. Find the stage-2 batch: the one present locally.
            let mut acc: Option<Value> = None;
            for b in 0..cfg.batches() {
                if let Ok(v) = self.store.get(ValueKey { job, func, batch: b }) {
                    if acc.is_some() {
                        return Err(CamrError::Verification(format!(
                            "non-owner {} has >1 batch aggregate for job {job}",
                            self.id
                        )));
                    }
                    acc = Some(v.clone());
                }
            }
            let beta = acc.ok_or_else(|| {
                CamrError::MissingValue(format!(
                    "worker {}: stage-2 aggregate for job {job} func {func}",
                    self.id
                ))
            })?;
            let fused = self.store.get_fused(job, func)?;
            agg.combine(&beta, fused)
        }
    }

    /// This worker's position inside a group plan.
    fn position_in(&self, plan: &GroupPlan) -> Result<usize> {
        plan.members.iter().position(|&m| m == self.id).ok_or_else(|| {
            CamrError::Placement(format!("worker {} not in group {:?}", self.id, plan.members))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::ResolvableDesign;
    use crate::workload::synth::SyntheticWorkload;

    fn setup() -> (SystemConfig, Placement, SyntheticWorkload) {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let d = ResolvableDesign::new(3, 2).unwrap();
        let p = Placement::new(&d, &cfg).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 42);
        (cfg, p, wl)
    }

    #[test]
    fn map_phase_fills_inventory() {
        let (cfg, p, wl) = setup();
        let mut w = Worker::new(0, &cfg);
        let invocations = w.run_map_phase(&cfg, &p, &wl).unwrap();
        // U1 stores 4 batches × γ=2 subfiles.
        assert_eq!(invocations, 8);
        // 4 (job, batch) pairs × Q=6 functions.
        assert_eq!(w.store.len(), 24);
    }

    #[test]
    fn map_phase_respects_placement() {
        let (cfg, p, wl) = setup();
        let mut w = Worker::new(1, &cfg); // U2 owns jobs 3, 4 (1-based)
        w.run_map_phase(&cfg, &p, &wl).unwrap();
        // Stores nothing for job 0 (not an owner).
        for f in 0..cfg.functions() {
            for b in 0..cfg.batches() {
                assert!(!w.store.contains(ValueKey { job: 0, func: f, batch: b }));
            }
        }
    }

    #[test]
    fn reduce_rejects_wrong_function() {
        let (cfg, p, wl) = setup();
        let mut w = Worker::new(0, &cfg);
        w.run_map_phase(&cfg, &p, &wl).unwrap();
        let agg = wl.aggregator();
        assert!(w.reduce(&cfg, &p, agg, 0, 1).is_err()); // func 1 belongs to U2
    }

    #[test]
    fn fuse_rejects_foreign_unicast() {
        let (cfg, _, wl) = setup();
        let w = Worker::new(0, &cfg);
        let u = UnicastSpec { sender: 1, receiver: 0, job: 2, func: 0, batches: vec![0, 1] };
        assert!(w.fuse_for_unicast(wl.aggregator(), &u).is_err());
    }
}
