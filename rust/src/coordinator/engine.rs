//! The end-to-end CAMR engine: map → 3-stage coded shuffle → reduce,
//! with byte-exact load accounting and oracle verification.
//!
//! The engine is deliberately strict: every coded packet is really
//! XOR-encoded from the sender's local store and really decoded at each
//! receiver from its local store; a bug anywhere in the combinatorics
//! surfaces as a reduce-phase mismatch against the single-node oracle.
//!
//! This is the **serial reference implementation**: all workers execute
//! on the calling thread, one protocol step at a time, in schedule
//! order. Its [`Bus`] ledger is the canonical transcript that the
//! thread-per-worker [`super::parallel::ParallelEngine`] must reproduce
//! byte-for-byte — the property tests diff the two ledgers directly.
//! (Only the oracle *verification* fans out across threads; it is a
//! check, not part of the protocol.)

use super::master::{Master, Schedule};
use super::worker::Worker;
use crate::agg::Value;
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::net::{Bus, Stage};
use crate::obs::{SpanKind, SpanSink, Tracer, COORD};
use crate::shuffle::buf::{BufferPool, PoolStats, SharedBuf};
use crate::workload::{check_output, Workload};
use crate::{FuncId, JobId};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Measured outcome of a full run.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    /// Bytes on the shared link per stage: [stage1, stage2, stage3].
    pub stage_bytes: [usize; 3],
    /// Load normalizer `J·Q·B` (Definition 3).
    pub normalizer: f64,
    /// Total map invocations across the cluster (computation load).
    pub map_invocations: usize,
    /// Whether every reduce output matched the oracle.
    pub verified: bool,
    /// Number of (job, function) outputs produced.
    pub outputs: usize,
    /// Wall time per phase.
    pub map_time: Duration,
    /// Shuffle wall time (all three stages).
    pub shuffle_time: Duration,
    /// Measured wall time of each shuffle stage: [stage1, stage2,
    /// stage3]. Sums to `shuffle_time` (up to clock granularity); lets
    /// `camr simulate` print sim-vs-real per-stage columns.
    pub stage_times: [Duration; 3],
    /// Reduce + verify wall time.
    pub reduce_time: Duration,
}

impl RunOutcome {
    /// Measured communication load `L` (Definition 3).
    pub fn total_load(&self) -> f64 {
        self.stage_bytes.iter().sum::<usize>() as f64 / self.normalizer
    }

    /// Measured per-stage load (`stage` is 1-based like the paper).
    pub fn stage_load(&self, stage: usize) -> f64 {
        self.stage_bytes[stage - 1] as f64 / self.normalizer
    }
}

/// The engine: master + workers + workload + shared link.
pub struct Engine {
    /// The master (design, placement, schedule factory).
    pub master: Master,
    workers: Vec<Worker>,
    workload: Box<dyn Workload>,
    /// The shared link; public so callers can inspect the ledger
    /// (e.g. to print the paper's Tables I/II).
    pub bus: Bus,
    /// Skip oracle verification (for large load-sweep runs).
    pub verify: bool,
    /// Route shuffle buffers through the [`BufferPool`] (default). Set
    /// to `false` to run the legacy allocate-per-packet data plane —
    /// the ledger must be byte-identical either way (golden test).
    pub pooling: bool,
    /// Span collector ([`Tracer::Off`] by default — the no-op branch).
    /// Enable with [`Tracer::on`] before `run` to record typed spans for
    /// every protocol step; drain with [`Tracer::take_spans`] after.
    pub tracer: Tracer,
    pool: BufferPool,
    outputs: HashMap<(JobId, FuncId), Value>,
}

impl Engine {
    /// Build an engine for a config and workload.
    pub fn new(cfg: SystemConfig, workload: Box<dyn Workload>) -> Result<Self> {
        let master = Master::new(cfg)?;
        // Pre-flight: statically prove decodability, replication, and
        // schedule invariants before any worker starts; a malformed
        // plan is the typed `CamrError::Invalid`, not a mid-round
        // failure.
        crate::check::preflight(&master)?;
        let workers =
            (0..master.cfg.servers()).map(|s| Worker::new(s, &master.cfg)).collect();
        Ok(Engine {
            master,
            workers,
            workload,
            bus: Bus::new(),
            verify: true,
            pooling: true,
            tracer: Tracer::Off,
            pool: BufferPool::new(),
            outputs: HashMap::new(),
        })
    }

    /// Access the system config.
    pub fn cfg(&self) -> &SystemConfig {
        &self.master.cfg
    }

    /// Counters of the shuffle buffer pool (allocation/recycle traffic).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// A reduced output (after `run`).
    pub fn output(&self, job: JobId, func: FuncId) -> Option<&Value> {
        self.outputs.get(&(job, func))
    }

    /// Swap in the next job's workload, returning the previous one. The
    /// batch runtime ([`crate::coordinator::batch`]) reuses one engine —
    /// workers, placement, schedule, buffer pool — across every job of a
    /// batch and only re-seeds the data through this hook; the returned
    /// workload lets a pipelined verifier keep checking the finished job
    /// while the engine starts the next.
    pub fn replace_workload(&mut self, workload: Box<dyn Workload>) -> Box<dyn Workload> {
        std::mem::replace(&mut self.workload, workload)
    }

    /// Move the reduced outputs out of the engine (they are cleared at
    /// the start of the next `run` anyway). Used by the batch runtime to
    /// verify job `i` off-thread while job `i+1` executes.
    pub fn take_outputs(&mut self) -> HashMap<(JobId, FuncId), Value> {
        std::mem::take(&mut self.outputs)
    }

    /// Run the full protocol and return measured loads.
    pub fn run(&mut self) -> Result<RunOutcome> {
        self.bus.reset();
        self.outputs.clear();
        for w in &mut self.workers {
            w.store.clear();
        }
        let schedule = self.master.schedule()?;
        // All workers share the calling thread, so one span buffer covers
        // the whole round; it drains into the tracer when `run` returns.
        let mut sink = self.tracer.sink();

        let t0 = Instant::now();
        let map_invocations = self.map_phase(&mut sink)?;
        let map_time = t0.elapsed();

        let t1 = Instant::now();
        self.shuffle_stage_coded(&schedule.stage1, Stage::Stage1, &mut sink)?;
        let m1 = t1.elapsed();
        self.shuffle_stage_coded(&schedule.stage2, Stage::Stage2, &mut sink)?;
        let m2 = t1.elapsed();
        self.shuffle_stage3(&schedule, &mut sink)?;
        let shuffle_time = t1.elapsed();
        let stage_times = [m1, m2 - m1, shuffle_time - m2];

        let t2 = Instant::now();
        let verified = self.reduce_phase(&mut sink)?;
        let reduce_time = t2.elapsed();

        Ok(RunOutcome {
            stage_bytes: [
                self.bus.stage_bytes(Stage::Stage1),
                self.bus.stage_bytes(Stage::Stage2),
                self.bus.stage_bytes(Stage::Stage3),
            ],
            normalizer: self.master.cfg.load_normalizer(),
            map_invocations,
            verified,
            outputs: self.outputs.len(),
            map_time,
            shuffle_time,
            stage_times,
            reduce_time,
        })
    }

    /// Map phase: every worker maps its stored subfiles for all functions
    /// and aggregates per batch (§III-B). Workers run strictly one after
    /// another on this thread — the serial baseline the parallel engine's
    /// map-phase speedup is measured against.
    fn map_phase(&mut self, sink: &mut SpanSink) -> Result<usize> {
        let cfg = &self.master.cfg;
        let placement = &self.master.placement;
        let workload = &*self.workload;
        let mut total = 0usize;
        for (id, w) in self.workers.iter_mut().enumerate() {
            let t = sink.begin();
            let n = w.run_map_phase(cfg, placement, workload)?;
            sink.record(t, SpanKind::Map, id, 0, None, n as u64, 0);
            total += n;
        }
        Ok(total)
    }

    /// Run one coded stage: every member of every group broadcasts its Δ,
    /// then every member decodes its missing chunk.
    ///
    /// With `pooling` on (the default), the Δ buffers are checked out of
    /// the engine's [`BufferPool`], encoded in place, shared with every
    /// decoder, and recycled when the group finishes — the bus is still
    /// charged the exact same byte counts as the allocate-per-packet
    /// path, so the ledger is invariant under the data-plane choice.
    fn shuffle_stage_coded(
        &mut self,
        groups: &[crate::shuffle::multicast::GroupPlan],
        stage: Stage,
        sink: &mut SpanSink,
    ) -> Result<()> {
        let pool = self.pool.clone();
        let mut seq = 0u64;
        for plan in groups {
            // Encode: one broadcast per member, from local state only.
            let mut deltas: Vec<SharedBuf> = Vec::with_capacity(plan.members.len());
            for &m in plan.members.iter() {
                let t = sink.begin();
                let delta =
                    self.workers[m].encode_for_group_shared(plan, &pool, self.pooling)?;
                sink.record(t, SpanKind::Encode, m, 0, Some(stage), seq, delta.len() as u64);
                seq += 1;
                let recipients: Vec<usize> =
                    plan.members.iter().copied().filter(|&x| x != m).collect();
                self.bus.multicast(stage, m, recipients, delta.len());
                deltas.push(delta);
            }
            // Decode: each member reconstructs its chunk and stores it.
            let bytes: u64 = deltas.iter().map(|d| d.len() as u64).sum();
            for &m in &plan.members {
                let t = sink.begin();
                if self.pooling {
                    self.workers[m].decode_from_group_pooled(plan, &deltas, &pool)?;
                } else {
                    self.workers[m].decode_from_group(plan, &deltas)?;
                }
                sink.record(t, SpanKind::Decode, m, 0, Some(stage), 0, bytes);
            }
        }
        Ok(())
    }

    /// Stage 3: fused unicasts within parallel classes (Eq. (5)).
    fn shuffle_stage3(&mut self, schedule: &Schedule, sink: &mut SpanSink) -> Result<()> {
        let agg = self.workload.aggregator();
        for (si, u) in schedule.stage3.iter().enumerate() {
            let t = sink.begin();
            let v = self.workers[u.sender].fuse_for_unicast(agg, u)?;
            let bytes = v.len() as u64;
            self.bus.unicast(Stage::Stage3, u.sender, u.receiver, v.len());
            self.workers[u.receiver].receive_fused(u, v)?;
            sink.record(
                t,
                SpanKind::Exchange,
                u.sender,
                u.job,
                Some(Stage::Stage3),
                si as u64,
                bytes,
            );
        }
        Ok(())
    }

    /// Reduce phase (§III-D) + oracle verification.
    fn reduce_phase(&mut self, sink: &mut SpanSink) -> Result<bool> {
        let cfg = self.master.cfg.clone();
        let agg = self.workload.aggregator();
        for f in 0..cfg.functions() {
            let reducer = cfg.reducer_of(f);
            for j in 0..cfg.jobs() {
                let t = sink.begin();
                let out =
                    self.workers[reducer].reduce(&cfg, &self.master.placement, agg, j, f)?;
                sink.record(t, SpanKind::Reduce, reducer, j, None, f as u64, out.len() as u64);
                self.outputs.insert((j, f), out);
            }
        }
        if !self.verify {
            return Ok(true);
        }
        let t = sink.begin();
        verify_outputs(&cfg, &*self.workload, &self.outputs)?;
        sink.record(t, SpanKind::Verify, COORD, 0, None, 0, self.outputs.len() as u64);
        Ok(true)
    }
}

/// Check every reduced output against the workload's single-node oracle
/// (parallel over (job, func) pairs — a verification-only fan-out, not
/// part of the protocol). Shared by the serial and parallel engines.
pub(crate) fn verify_outputs(
    cfg: &SystemConfig,
    workload: &dyn Workload,
    outputs: &HashMap<(JobId, FuncId), Value>,
) -> Result<()> {
    let pairs: Vec<(JobId, FuncId)> = outputs.keys().copied().collect();
    let failures: Vec<String> = crate::util::par::map_indexed(pairs.len(), |i| {
        let (j, f) = pairs[i];
        let want = match workload.oracle(cfg, j, f) {
            Ok(w) => w,
            Err(e) => return Some(format!("oracle job {j} func {f}: {e}")),
        };
        let got = &outputs[&(j, f)];
        check_output(workload, j, f, got, &want).err().map(|e| e.to_string())
    })
    .into_iter()
    .flatten()
    .collect();
    if let Some(first) = failures.first() {
        return Err(CamrError::Verification(format!(
            "{} of {} outputs mismatched; first: {first}",
            failures.len(),
            pairs.len()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::synth::SyntheticWorkload;

    fn run(k: usize, q: usize, gamma: usize) -> RunOutcome {
        let cfg = SystemConfig::new(k, q, gamma).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 0xC0FFEE);
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        e.run().unwrap()
    }

    #[test]
    fn example1_measured_loads_match_paper() {
        // Paper §III-C: L1 = 1/4, L2 = 1/4, L3 = 1/2, total 1.
        let out = run(3, 2, 2);
        assert!(out.verified);
        assert!((out.stage_load(1) - 0.25).abs() < 1e-12, "L1 = {}", out.stage_load(1));
        assert!((out.stage_load(2) - 0.25).abs() < 1e-12, "L2 = {}", out.stage_load(2));
        assert!((out.stage_load(3) - 0.50).abs() < 1e-12, "L3 = {}", out.stage_load(3));
        assert!((out.total_load() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn loads_match_closed_form_across_parameters() {
        // L_CAMR = (k(q-1)+1)/(q(k-1)) for every (k, q); value_bytes = 64
        // is divisible by k-1 for these picks, so no padding slack.
        for (k, q) in [(2, 2), (2, 3), (3, 2), (3, 3), (5, 2)] {
            let out = run(k, q, 1);
            let expect = (k as f64 * (q as f64 - 1.0) + 1.0) / (q as f64 * (k as f64 - 1.0));
            assert!(
                (out.total_load() - expect).abs() < 1e-12,
                "k={k} q={q}: measured {} expected {expect}",
                out.total_load()
            );
            assert!(out.verified);
        }
    }

    #[test]
    fn computation_load_is_k_minus_one_times_dataset() {
        // Each subfile is mapped by exactly k-1 servers (the owners that
        // store its batch): total invocations = (k-1)·J·N.
        let out = run(3, 2, 2);
        assert_eq!(out.map_invocations, 2 * 4 * 6);
    }

    #[test]
    fn multi_round_load_unchanged() {
        // Q = 2K repeats the shuffle; the load (normalized by JQB) is
        // identical (§II: "repeat the Shuffle phase Q/K times").
        let cfg = SystemConfig::with_options(3, 2, 2, 2, 64).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 1);
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!((out.total_load() - 1.0).abs() < 1e-12);
        assert!(out.verified);
    }

    #[test]
    fn pooled_and_unpooled_data_planes_agree() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let mut pooled =
            Engine::new(cfg.clone(), Box::new(SyntheticWorkload::new(&cfg, 11))).unwrap();
        assert!(pooled.pooling);
        let pout = pooled.run().unwrap();
        let mut legacy =
            Engine::new(cfg.clone(), Box::new(SyntheticWorkload::new(&cfg, 11))).unwrap();
        legacy.pooling = false;
        let lout = legacy.run().unwrap();
        assert!(pout.verified && lout.verified);
        assert_eq!(pout.stage_bytes, lout.stage_bytes);
        for j in 0..cfg.jobs() {
            for f in 0..cfg.functions() {
                assert_eq!(pooled.output(j, f), legacy.output(j, f), "job {j} func {f}");
            }
        }
        // The pooled plane actually pooled: buffers were acquired,
        // recycled, and every one returned exactly once.
        let stats = pooled.pool_stats();
        assert!(stats.acquired > 0);
        assert!(stats.recycled > 0, "pool never recycled: {stats:?}");
        assert_eq!(stats.outstanding(), 0);
        assert_eq!(stats.acquired, stats.released);
        // The legacy plane never touched the pool.
        assert_eq!(legacy.pool_stats().acquired, 0);
    }

    #[test]
    fn outputs_are_complete() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 3);
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert_eq!(out.outputs, 4 * 6);
        assert!(e.output(0, 0).is_some());
        assert!(e.output(3, 5).is_some());
    }
}
