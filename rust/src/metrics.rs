//! Measured-vs-analytic load reporting.
//!
//! Bundles a run's measured byte counts with the §IV closed forms so
//! every report doubles as a reproduction check of the paper's analysis.

use crate::analysis::load;
use crate::config::SystemConfig;
use crate::coordinator::batch::BatchOutcome;
use crate::coordinator::engine::RunOutcome;
use crate::net::Stage;
use crate::sim::{BatchSimOutcome, SimOutcome};
use crate::util::json::Json;

/// One stage's measured vs expected load.
#[derive(Debug, Clone, Copy)]
pub struct StageMetric {
    /// 1-based stage index.
    pub stage: usize,
    /// Bytes measured on the shared link.
    pub bytes: usize,
    /// Measured load (bytes / JQB).
    pub measured: f64,
    /// Closed-form load from §IV.
    pub expected: f64,
}

/// Simulated per-phase times from the discrete-event cluster simulator
/// ([`crate::sim`]), attached to a report when the run config carries a
/// `[sim]` section.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimTimes {
    /// Simulated map-phase duration (slowest worker), seconds.
    pub map_secs: f64,
    /// Simulated per-stage shuffle durations `[stage1, stage2, stage3]`.
    pub stage_secs: [f64; 3],
    /// Simulated total shuffle duration.
    pub shuffle_secs: f64,
    /// Simulated end-to-end completion time.
    pub total_secs: f64,
}

impl SimTimes {
    /// Extract report times from a simulation outcome.
    pub fn from_outcome(out: &SimOutcome) -> Self {
        SimTimes {
            map_secs: out.map_secs,
            stage_secs: [
                out.stage_secs(Stage::Stage1),
                out.stage_secs(Stage::Stage2),
                out.stage_secs(Stage::Stage3),
            ],
            shuffle_secs: out.shuffle_secs,
            total_secs: out.total_secs,
        }
    }
}

/// Full report of a CAMR run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Parameters `(k, q, γ, rounds, B)`.
    pub k: usize,
    /// `q`.
    pub q: usize,
    /// `γ`.
    pub gamma: usize,
    /// Shuffle rounds (Q/K).
    pub rounds: usize,
    /// Value size in bytes.
    pub value_bytes: usize,
    /// Cluster size.
    pub servers: usize,
    /// Job count.
    pub jobs: usize,
    /// Storage fraction μ.
    pub mu: f64,
    /// Per-stage metrics.
    pub stages: Vec<StageMetric>,
    /// Measured total load.
    pub total_measured: f64,
    /// Closed-form total load.
    pub total_expected: f64,
    /// CCDC load at the same μ (must equal CAMR's, §V).
    pub ccdc_load: f64,
    /// Map invocations (computation load).
    pub map_invocations: usize,
    /// Oracle verification status.
    pub verified: bool,
    /// Phase wall times in microseconds (map, shuffle, reduce).
    pub phase_us: [u128; 3],
    /// Measured wall time of each shuffle stage in microseconds
    /// `[stage1, stage2, stage3]` — the *real* counterpart of
    /// [`SimTimes::stage_secs`], so sim-vs-real columns can be printed
    /// from one report.
    pub stage_us: [u128; 3],
    /// Simulated phase times (when the config has a `[sim]` section).
    pub sim: Option<SimTimes>,
}

impl LoadReport {
    /// Build a report from a run outcome.
    pub fn from_outcome(cfg: &SystemConfig, out: &RunOutcome) -> Self {
        let breakdown = load::camr_stages(cfg.k, cfg.q);
        let expected = [breakdown.stage1, breakdown.stage2, breakdown.stage3];
        let stages: Vec<StageMetric> = (0..3)
            .map(|i| StageMetric {
                stage: i + 1,
                bytes: out.stage_bytes[i],
                measured: out.stage_load(i + 1),
                expected: expected[i],
            })
            .collect();
        LoadReport {
            k: cfg.k,
            q: cfg.q,
            gamma: cfg.gamma,
            rounds: cfg.rounds,
            value_bytes: cfg.value_bytes,
            servers: cfg.servers(),
            jobs: cfg.jobs(),
            mu: cfg.storage_fraction(),
            stages,
            total_measured: out.total_load(),
            total_expected: breakdown.total(),
            ccdc_load: load::ccdc_total(cfg.k - 1, cfg.servers()),
            map_invocations: out.map_invocations,
            verified: out.verified,
            phase_us: [
                out.map_time.as_micros(),
                out.shuffle_time.as_micros(),
                out.reduce_time.as_micros(),
            ],
            stage_us: [
                out.stage_times[0].as_micros(),
                out.stage_times[1].as_micros(),
                out.stage_times[2].as_micros(),
            ],
            sim: None,
        }
    }

    /// Attach simulated phase times from the cluster simulator.
    pub fn attach_sim(&mut self, sim: SimTimes) {
        self.sim = Some(sim);
    }

    /// Measured load is within padding slack of the closed form.
    pub fn matches_analysis(&self) -> bool {
        // Padding inflates stages 1–2 by at most (k-2)/B relatively.
        let slack = (self.k as f64) / (self.value_bytes as f64) + 1e-9;
        (self.total_measured - self.total_expected).abs()
            <= self.total_expected * slack + 1e-12
    }

    /// Serialize to JSON (stable key order).
    pub fn to_json(&self) -> String {
        let sim = match &self.sim {
            None => Json::Null,
            Some(s) => Json::obj(vec![
                ("map_secs", Json::Num(s.map_secs)),
                (
                    "stage_secs",
                    Json::Arr(s.stage_secs.iter().map(|&x| Json::Num(x)).collect()),
                ),
                ("shuffle_secs", Json::Num(s.shuffle_secs)),
                ("total_secs", Json::Num(s.total_secs)),
            ]),
        };
        let stages: Vec<Json> = self
            .stages
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("stage", Json::UInt(s.stage as u128)),
                    ("bytes", Json::UInt(s.bytes as u128)),
                    ("measured", Json::Num(s.measured)),
                    ("expected", Json::Num(s.expected)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("k", Json::UInt(self.k as u128)),
            ("q", Json::UInt(self.q as u128)),
            ("gamma", Json::UInt(self.gamma as u128)),
            ("rounds", Json::UInt(self.rounds as u128)),
            ("value_bytes", Json::UInt(self.value_bytes as u128)),
            ("servers", Json::UInt(self.servers as u128)),
            ("jobs", Json::UInt(self.jobs as u128)),
            ("mu", Json::Num(self.mu)),
            ("stages", Json::Arr(stages)),
            ("total_measured", Json::Num(self.total_measured)),
            ("total_expected", Json::Num(self.total_expected)),
            ("ccdc_load", Json::Num(self.ccdc_load)),
            ("map_invocations", Json::UInt(self.map_invocations as u128)),
            ("verified", Json::Bool(self.verified)),
            (
                "phase_us",
                Json::Arr(self.phase_us.iter().map(|&x| Json::UInt(x)).collect()),
            ),
            (
                "stage_us",
                Json::Arr(self.stage_us.iter().map(|&x| Json::UInt(x)).collect()),
            ),
            ("sim", sim),
        ])
        .render()
    }
}

impl std::fmt::Display for LoadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "CAMR run  k={} q={} γ={} rounds={} B={}  (K={} J={} μ={:.4})",
            self.k, self.q, self.gamma, self.rounds, self.value_bytes, self.servers,
            self.jobs, self.mu
        )?;
        writeln!(f, "  {:<8} {:>12} {:>12} {:>12}", "stage", "bytes", "measured", "expected")?;
        for s in &self.stages {
            writeln!(
                f,
                "  {:<8} {:>12} {:>12.6} {:>12.6}",
                format!("stage{}", s.stage),
                s.bytes,
                s.measured,
                s.expected
            )?;
        }
        writeln!(
            f,
            "  {:<8} {:>12} {:>12.6} {:>12.6}   (CCDC at same μ: {:.6})",
            "total",
            self.stages.iter().map(|s| s.bytes).sum::<usize>(),
            self.total_measured,
            self.total_expected,
            self.ccdc_load
        )?;
        writeln!(
            f,
            "  map invocations: {}   phases: map {}µs shuffle {}µs reduce {}µs   verified: {}",
            self.map_invocations, self.phase_us[0], self.phase_us[1], self.phase_us[2],
            self.verified
        )?;
        writeln!(
            f,
            "  measured stages: stage1 {}µs stage2 {}µs stage3 {}µs",
            self.stage_us[0], self.stage_us[1], self.stage_us[2]
        )?;
        if let Some(s) = &self.sim {
            writeln!(
                f,
                "  simulated: map {:.6}s + shuffle {:.6}s = {:.6}s  \
                 (stage1 {:.6}s, stage2 {:.6}s, stage3 {:.6}s)",
                s.map_secs,
                s.shuffle_secs,
                s.total_secs,
                s.stage_secs[0],
                s.stage_secs[1],
                s.stage_secs[2]
            )?;
        }
        Ok(())
    }
}

/// One scheme's row of a [`BatchReport`]: what the batch runtime
/// actually executed, plus its simulated batch makespans.
#[derive(Debug, Clone)]
pub struct SchemeBatch {
    /// Scheme label (`camr` | `ccdc` | `uncoded`).
    pub scheme: String,
    /// Jobs the scheme requires (Table III closed form).
    pub jobs_required: u128,
    /// Paper jobs executed end to end.
    pub jobs_executed: usize,
    /// Paper jobs whose traffic the simulated makespans replay (adds
    /// verification-vetoed units, whose traffic was real).
    pub jobs_simulated: usize,
    /// Execution units attempted (CAMR rounds / CCDC jobs).
    pub units: usize,
    /// Units that failed (execution or verification).
    pub failed_units: usize,
    /// Bytes on the link across all successful units.
    pub total_bytes: usize,
    /// Aggregate communication load.
    pub load: f64,
    /// Every attempted unit executed and verified.
    pub verified: bool,
    /// Simulated barriered makespan (units fully serialized), seconds.
    pub serial_secs: f64,
    /// Simulated pipelined makespan (unit `i+1` maps while unit `i`
    /// shuffles), seconds.
    pub pipelined_secs: f64,
    /// Simulated total map time across units.
    pub map_secs: f64,
    /// Simulated total shuffle time across units.
    pub shuffle_secs: f64,
    /// Real wall-clock of the executed batch, microseconds.
    pub wall_us: u128,
}

impl SchemeBatch {
    /// Package a batch outcome and its simulation into a report row.
    pub fn from_outcome(out: &BatchOutcome, sim: &BatchSimOutcome) -> Self {
        SchemeBatch {
            scheme: out.scheme.label().to_string(),
            jobs_required: out.jobs_required,
            jobs_executed: out.jobs_executed,
            jobs_simulated: out.jobs_simulated(),
            units: out.units.len(),
            failed_units: out.units.iter().filter(|u| u.error.is_some()).count(),
            total_bytes: out.total_bytes(),
            load: out.load(),
            verified: out.all_verified(),
            serial_secs: sim.serial_secs,
            pipelined_secs: sim.pipelined_secs,
            map_secs: sim.map_secs_total,
            shuffle_secs: sim.shuffle_secs_total,
            wall_us: out.wall.as_micros(),
        }
    }

    /// Simulated completion time per paper job (pipelined makespan over
    /// the jobs the simulation actually replayed).
    pub fn secs_per_job(&self) -> f64 {
        self.pipelined_secs / self.jobs_simulated.max(1) as f64
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scheme", Json::Str(self.scheme.clone())),
            ("jobs_required", Json::UInt(self.jobs_required)),
            ("jobs_executed", Json::UInt(self.jobs_executed as u128)),
            ("jobs_simulated", Json::UInt(self.jobs_simulated as u128)),
            ("units", Json::UInt(self.units as u128)),
            ("failed_units", Json::UInt(self.failed_units as u128)),
            ("total_bytes", Json::UInt(self.total_bytes as u128)),
            ("load", Json::Num(self.load)),
            ("verified", Json::Bool(self.verified)),
            ("serial_secs", Json::Num(self.serial_secs)),
            ("pipelined_secs", Json::Num(self.pipelined_secs)),
            ("map_secs", Json::Num(self.map_secs)),
            ("shuffle_secs", Json::Num(self.shuffle_secs)),
            ("secs_per_job", Json::Num(self.secs_per_job())),
            ("wall_us", Json::UInt(self.wall_us)),
        ])
    }
}

/// Full report of a `camr batch` execution: the compared schemes' batch
/// rows over one system configuration.
#[derive(Debug, Clone)]
pub struct BatchReport {
    /// Design parameter `k`.
    pub k: usize,
    /// Design parameter `q`.
    pub q: usize,
    /// Subfiles per batch `γ`.
    pub gamma: usize,
    /// Value size `B` in bytes.
    pub value_bytes: usize,
    /// Cluster size `K`.
    pub servers: usize,
    /// One-line description of the simulated cluster model.
    pub sim_config: String,
    /// Per-scheme batch rows.
    pub schemes: Vec<SchemeBatch>,
}

impl BatchReport {
    /// The row of one scheme, if it ran.
    pub fn scheme(&self, label: &str) -> Option<&SchemeBatch> {
        self.schemes.iter().find(|s| s.scheme == label)
    }

    /// Serialize to JSON (stable key order).
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("k", Json::UInt(self.k as u128)),
            ("q", Json::UInt(self.q as u128)),
            ("gamma", Json::UInt(self.gamma as u128)),
            ("value_bytes", Json::UInt(self.value_bytes as u128)),
            ("servers", Json::UInt(self.servers as u128)),
            ("sim_config", Json::Str(self.sim_config.clone())),
            ("schemes", Json::Arr(self.schemes.iter().map(|s| s.to_json()).collect())),
        ])
        .render()
    }
}

impl std::fmt::Display for BatchReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "batch run  k={} q={} γ={} B={}  (K={} servers)   sim: {}",
            self.k, self.q, self.gamma, self.value_bytes, self.servers, self.sim_config
        )?;
        writeln!(
            f,
            "  {:<8} {:>10} {:>9} {:>6} {:>12} {:>8} {:>12} {:>12} {:>12}",
            "scheme",
            "required",
            "executed",
            "units",
            "bytes",
            "load",
            "serial_s",
            "pipeline_s",
            "s/job"
        )?;
        for s in &self.schemes {
            writeln!(
                f,
                "  {:<8} {:>10} {:>9} {:>6} {:>12} {:>8.4} {:>12.6} {:>12.6} {:>12.6}{}",
                s.scheme,
                s.jobs_required,
                s.jobs_executed,
                s.units,
                s.total_bytes,
                s.load,
                s.serial_secs,
                s.pipelined_secs,
                s.secs_per_job(),
                if s.verified { "" } else { "  [FAILED UNITS]" }
            )?;
        }
        Ok(())
    }
}

/// One tenant's row of a [`ServeReport`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantServe {
    /// Tenant index.
    pub tenant: usize,
    /// Deficit round-robin weight.
    pub weight: u64,
    /// Jobs admitted for this tenant.
    pub submitted: u64,
    /// Jobs completed for this tenant.
    pub completed: u64,
    /// Typed `QueueFull` rejections returned to this tenant.
    pub rejected: u64,
}

/// Full report of a `camr serve --bench` traffic run: what the
/// continuous job service sustained, with sojourn latency decomposed
/// into queue-wait and execution. Serialized into `BENCH_serve.json`.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Design parameter `k`.
    pub k: usize,
    /// Design parameter `q`.
    pub q: usize,
    /// Subfiles per batch `γ`.
    pub gamma: usize,
    /// Value size `B` in bytes.
    pub value_bytes: usize,
    /// Cluster size `K`.
    pub servers: usize,
    /// Dispatcher pool size (coded rounds in flight).
    pub engines: usize,
    /// Thread-per-worker engines (vs serial).
    pub parallel: bool,
    /// Quick configuration (CI smoke) vs the full traffic run.
    pub quick: bool,
    /// Per-tenant admission-queue bound.
    pub queue_capacity: usize,
    /// Jobs admitted across all tenants.
    pub jobs_submitted: u64,
    /// Jobs run to completion.
    pub jobs_completed: u64,
    /// Typed `QueueFull` rejections (blocking submits count once).
    pub jobs_rejected: u64,
    /// Paper jobs covered (`completed × J`, `J = q^(k-1)` per round).
    pub paper_jobs: u128,
    /// Every completed job's outputs passed oracle verification.
    pub verified: bool,
    /// Wall clock of the whole run, seconds.
    pub wall_secs: f64,
    /// Completed jobs per second.
    pub jobs_per_sec: f64,
    /// Sojourn (submit → complete) `[p50, p99]`, microseconds.
    pub sojourn_us: [u64; 2],
    /// Mean sojourn, microseconds.
    pub sojourn_mean_us: f64,
    /// Queue-wait `[p50, p99]`, microseconds.
    pub queue_us: [u64; 2],
    /// Execution `[p50, p99]`, microseconds.
    pub exec_us: [u64; 2],
    /// Per-tenant throughput rows.
    pub tenants: Vec<TenantServe>,
}

impl ServeReport {
    /// Serialize to JSON (stable key order), identified as the `serve`
    /// bench for `BENCH_serve.json`.
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("bench", Json::Str("serve".into())),
            ("quick", Json::Bool(self.quick)),
            ("k", Json::UInt(self.k as u128)),
            ("q", Json::UInt(self.q as u128)),
            ("gamma", Json::UInt(self.gamma as u128)),
            ("value_bytes", Json::UInt(self.value_bytes as u128)),
            ("servers", Json::UInt(self.servers as u128)),
            ("engines", Json::UInt(self.engines as u128)),
            ("parallel", Json::Bool(self.parallel)),
            ("queue_capacity", Json::UInt(self.queue_capacity as u128)),
            ("jobs_submitted", Json::UInt(self.jobs_submitted as u128)),
            ("jobs_completed", Json::UInt(self.jobs_completed as u128)),
            ("jobs_rejected", Json::UInt(self.jobs_rejected as u128)),
            ("paper_jobs", Json::UInt(self.paper_jobs)),
            ("verified", Json::Bool(self.verified)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("jobs_per_sec", Json::Num(self.jobs_per_sec)),
            ("sojourn_p50_us", Json::UInt(self.sojourn_us[0] as u128)),
            ("sojourn_p99_us", Json::UInt(self.sojourn_us[1] as u128)),
            ("sojourn_mean_us", Json::Num(self.sojourn_mean_us)),
            ("queue_p50_us", Json::UInt(self.queue_us[0] as u128)),
            ("queue_p99_us", Json::UInt(self.queue_us[1] as u128)),
            ("exec_p50_us", Json::UInt(self.exec_us[0] as u128)),
            ("exec_p99_us", Json::UInt(self.exec_us[1] as u128)),
            (
                "tenants",
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("tenant", Json::UInt(t.tenant as u128)),
                                ("weight", Json::UInt(t.weight as u128)),
                                ("submitted", Json::UInt(t.submitted as u128)),
                                ("completed", Json::UInt(t.completed as u128)),
                                ("rejected", Json::UInt(t.rejected as u128)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .render()
    }
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "serve  k={} q={} γ={} B={}  (K={} servers, {} engine{}{})",
            self.k,
            self.q,
            self.gamma,
            self.value_bytes,
            self.servers,
            self.engines,
            if self.engines == 1 { "" } else { "s" },
            if self.parallel { ", parallel" } else { "" }
        )?;
        writeln!(
            f,
            "  jobs: {} submitted, {} completed ({} paper jobs), {} rejected{}",
            self.jobs_submitted,
            self.jobs_completed,
            self.paper_jobs,
            self.jobs_rejected,
            if self.verified { ", all verified" } else { "  [UNVERIFIED]" }
        )?;
        writeln!(
            f,
            "  throughput: {:.1} jobs/s over {:.3}s",
            self.jobs_per_sec, self.wall_secs
        )?;
        writeln!(
            f,
            "  sojourn p50/p99: {}/{} µs  (queue {}/{} µs + exec {}/{} µs)",
            self.sojourn_us[0],
            self.sojourn_us[1],
            self.queue_us[0],
            self.queue_us[1],
            self.exec_us[0],
            self.exec_us[1]
        )?;
        writeln!(
            f,
            "  {:<8} {:>7} {:>10} {:>10} {:>9}",
            "tenant", "weight", "submitted", "completed", "rejected"
        )?;
        for t in &self.tenants {
            writeln!(
                f,
                "  {:<8} {:>7} {:>10} {:>10} {:>9}",
                t.tenant, t.weight, t.submitted, t.completed, t.rejected
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;
    use crate::workload::synth::SyntheticWorkload;

    #[test]
    fn report_matches_analysis_for_example1() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 9);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        let rep = LoadReport::from_outcome(&cfg, &out);
        assert!(rep.matches_analysis());
        assert!((rep.total_measured - 1.0).abs() < 1e-12);
        assert!((rep.ccdc_load - 1.0).abs() < 1e-12);
        // JSON rendering contains the key fields.
        let js = rep.to_json();
        assert!(js.contains("\"jobs\":4"));
        assert!(js.contains("\"verified\":true"));
        assert!(crate::util::json::get_field(&js, "k").unwrap() == "3");
        // Display renders all stages.
        let text = rep.to_string();
        assert!(text.contains("stage1") && text.contains("stage3"));
        // Real per-stage times are carried and sum to the shuffle phase
        // (clock granularity: each readout truncates to whole µs).
        assert!(js.contains("\"stage_us\""));
        let sum: u128 = rep.stage_us.iter().sum();
        assert!(sum <= rep.phase_us[1] + 3, "stage_us {sum} vs shuffle {}", rep.phase_us[1]);
        assert!(text.contains("measured stages:"));
        // Without a [sim] section the report carries no simulated times.
        assert!(rep.sim.is_none());
        assert!(js.contains("\"sim\":null"));
    }

    #[test]
    fn attached_sim_times_render_in_json_and_display() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 9);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        let mut rep = LoadReport::from_outcome(&cfg, &out);
        let sc = crate::sim::SimConfig::commodity();
        let maps = crate::sim::camr_per_worker_maps(&cfg, &e.master.placement);
        let sim = crate::sim::simulate(&sc, &maps, e.bus.ledger()).unwrap();
        rep.attach_sim(SimTimes::from_outcome(&sim));
        let s = rep.sim.unwrap();
        assert!(s.map_secs > 0.0 && s.total_secs > s.map_secs);
        // Stage times sum to the shuffle total (up to one rounding per
        // per-stage readout — the global total uses a single rounding).
        let sum: f64 = s.stage_secs.iter().sum();
        assert!((s.shuffle_secs - sum).abs() <= 1e-15 * s.shuffle_secs.max(1.0));
        assert!(rep.to_json().contains("\"total_secs\""));
        assert!(rep.to_string().contains("simulated:"));
    }

    #[test]
    fn batch_report_renders_scheme_rows() {
        use crate::coordinator::batch::{run_batch_synthetic, BatchOptions, BatchScheme};
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let sc = crate::sim::SimConfig::commodity();
        let mut schemes = Vec::new();
        for scheme in [BatchScheme::Camr, BatchScheme::Ccdc] {
            let out = run_batch_synthetic(&cfg, scheme, &BatchOptions::default()).unwrap();
            let sim = out.simulate(&sc).unwrap();
            schemes.push(SchemeBatch::from_outcome(&out, &sim));
        }
        let rep = BatchReport {
            k: cfg.k,
            q: cfg.q,
            gamma: cfg.gamma,
            value_bytes: cfg.value_bytes,
            servers: cfg.servers(),
            sim_config: sc.describe(),
            schemes,
        };
        let camr = rep.scheme("camr").unwrap();
        let ccdc = rep.scheme("ccdc").unwrap();
        assert_eq!(camr.jobs_required, 4);
        assert_eq!(ccdc.jobs_required, 20);
        assert!(camr.verified && ccdc.verified);
        assert!(camr.pipelined_secs > 0.0);
        assert!(camr.pipelined_secs <= camr.serial_secs + 1e-12);
        let js = rep.to_json();
        assert!(js.contains("\"scheme\":\"camr\""));
        assert!(js.contains("\"jobs_required\":20"));
        let text = rep.to_string();
        assert!(text.contains("pipeline_s") && text.contains("ccdc"));
        assert!(rep.scheme("uncoded").is_none());
    }

    #[test]
    fn serve_report_renders_json_and_table() {
        let rep = ServeReport {
            k: 2,
            q: 2,
            gamma: 1,
            value_bytes: 64,
            servers: 4,
            engines: 2,
            parallel: false,
            quick: true,
            queue_capacity: 64,
            jobs_submitted: 1000,
            jobs_completed: 1000,
            jobs_rejected: 3,
            paper_jobs: 2000,
            verified: true,
            wall_secs: 1.25,
            jobs_per_sec: 800.0,
            sojourn_us: [120, 900],
            sojourn_mean_us: 150.5,
            queue_us: [40, 700],
            exec_us: [80, 200],
            tenants: vec![
                TenantServe { tenant: 0, weight: 1, submitted: 400, completed: 400, rejected: 3 },
                TenantServe { tenant: 1, weight: 2, submitted: 600, completed: 600, rejected: 0 },
            ],
        };
        let js = rep.to_json();
        assert!(js.contains("\"bench\":\"serve\""));
        assert!(js.contains("\"jobs_completed\":1000"));
        assert!(js.contains("\"sojourn_p99_us\":900"));
        assert!(js.contains("\"paper_jobs\":2000"));
        // Render → parse round trip through the same Json codec the
        // bench writer uses.
        let parsed = Json::parse(&js).unwrap();
        assert_eq!(parsed.render(), js);
        let text = rep.to_string();
        assert!(text.contains("all verified") && text.contains("tenant"));
        assert!(text.contains("800.0 jobs/s"));
    }
}
