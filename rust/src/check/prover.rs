//! Layer 1: the plan prover. Given a config's placement + schedule,
//! statically verify — before any worker starts — that every coded
//! packet is decodable by each intended recipient, map replication is
//! exactly `(k-1)×`, job counts match the paper's closed forms, every
//! needed intermediate value is delivered exactly once per round, and
//! the schedule's sequence numbers and stage barriers are well-formed.
//!
//! The prover re-derives the invariants from first principles against
//! an *explicit* fact base ([`PlanFacts`]) rather than trusting the
//! constructors that built the plan: `Placement::validate` proving
//! itself correct would be circular, and an explicit fact base is what
//! lets the mutation tests (`rust/tests/static_check.rs`) seed
//! specific defects — a dropped group member, skewed replication, a
//! duplicated sequence number — and assert each is caught by its
//! diagnostic code.
//!
//! Decodability (Lemma 2): in a delivery group of `g` members, member
//! `t` broadcasts the XOR of one packet from every chunk `p ≠ t`.
//! Recipient `p` recovers its chunk from member `t`'s broadcast iff it
//! can cancel every other term — i.e. it locally maps chunk `p'` for
//! all `p' ≠ p, t`. Both encodability (the sender maps what it
//! encodes) and cancellability therefore reduce to one condition:
//! **chunk `p` is mapped by every member except its recipient**, and
//! not by the recipient (otherwise the delivery is vacuous and the
//! coding wrong). That single condition is checked per XOR term.

use super::{CheckReport, Diagnostic};
use crate::analysis::jobs::JobRequirement;
use crate::config::SystemConfig;
use crate::coordinator::master::{Master, Schedule};
use crate::error::Result;
use crate::shuffle::multicast::GroupPlan;
use crate::shuffle::plan::UnicastSpec;
use crate::{BatchId, JobId, ServerId};
use std::collections::{BTreeMap, BTreeSet};

/// A coded delivery group with its schedule sequence number (the
/// engine numbers member broadcasts per stage, in schedule order).
#[derive(Debug, Clone)]
pub struct SeqGroup {
    /// Position in the stage's schedule (gap-free, unique per stage).
    pub seq: usize,
    /// The group plan: members and the chunk each member recovers.
    pub group: GroupPlan,
}

/// A stage-3 unicast with its schedule sequence number.
#[derive(Debug, Clone)]
pub struct SeqUnicast {
    /// Position in the stage-3 schedule.
    pub seq: usize,
    /// The unicast: sender, receiver, job, function, batches.
    pub spec: UnicastSpec,
}

/// The explicit fact base the prover checks: system parameters, the
/// map placement as a plain set of (server, job, batch) triples, and
/// the sequence-stamped three-stage schedule. All fields are public
/// and plain data so tests can seed targeted defects.
#[derive(Debug, Clone)]
pub struct PlanFacts {
    /// Servers per parallel class.
    pub q: usize,
    /// Parallel classes (= batches per job = owners per job).
    pub k: usize,
    /// Coded rounds in the schedule.
    pub rounds: usize,
    /// Cluster size `K = k·q`.
    pub servers: usize,
    /// Job count the plan claims (`J`, checked against `q^(k-1)`).
    pub jobs: usize,
    /// `owners[j]` — the servers assigned job `j`.
    pub owners: Vec<Vec<ServerId>>,
    /// The placement: server `s` maps batch `b` of job `j`.
    pub stored: BTreeSet<(ServerId, JobId, BatchId)>,
    /// Stage-1 coded groups (one per job per round).
    pub stage1: Vec<SeqGroup>,
    /// Stage-2 coded groups (one per transversal group per round).
    pub stage2: Vec<SeqGroup>,
    /// Stage-3 unicasts.
    pub stage3: Vec<SeqUnicast>,
}

impl PlanFacts {
    /// Extract the fact base from a built master + schedule. Sequence
    /// numbers are stamped exactly as the engines assign them: per
    /// stage, in schedule order, from zero.
    pub fn from_master(master: &Master, schedule: &Schedule) -> PlanFacts {
        let cfg = &master.cfg;
        let mut stored = BTreeSet::new();
        let mut owners = Vec::with_capacity(cfg.jobs());
        for j in 0..cfg.jobs() {
            owners.push(master.placement.owners(j).to_vec());
            for s in master.placement.owners(j) {
                for b in 0..cfg.k {
                    if master.placement.stores_batch(*s, j, b) {
                        stored.insert((*s, j, b));
                    }
                }
            }
        }
        let stamp = |groups: &[GroupPlan]| {
            groups
                .iter()
                .enumerate()
                .map(|(seq, g)| SeqGroup { seq, group: g.clone() })
                .collect()
        };
        PlanFacts {
            q: cfg.q,
            k: cfg.k,
            rounds: cfg.rounds,
            servers: cfg.servers(),
            jobs: cfg.jobs(),
            owners,
            stored,
            stage1: stamp(&schedule.stage1),
            stage2: stamp(&schedule.stage2),
            stage3: schedule
                .stage3
                .iter()
                .enumerate()
                .map(|(seq, s)| SeqUnicast { seq, spec: s.clone() })
                .collect(),
        }
    }

    /// Build master + schedule for a config and extract the facts.
    pub fn from_config(cfg: &SystemConfig) -> Result<PlanFacts> {
        let master = Master::new(cfg.clone())?;
        let schedule = master.schedule()?;
        Ok(PlanFacts::from_master(&master, &schedule))
    }

    fn maps(&self, s: ServerId, j: JobId, b: BatchId) -> bool {
        self.stored.contains(&(s, j, b))
    }
}

/// Prove the plan invariants, returning every violation as a typed
/// diagnostic (see the catalog in [`crate::check`]). An empty report
/// is a proof: the placement and schedule satisfy the paper's
/// decodability, replication, counting, and sequencing invariants.
pub fn prove(f: &PlanFacts) -> CheckReport {
    let mut r = CheckReport::new();
    check_job_count(f, &mut r);
    check_placement_shape(f, &mut r);
    check_replication(f, &mut r);
    for (stage, groups) in [("stage1", &f.stage1), ("stage2", &f.stage2)] {
        for sg in groups.iter() {
            check_group(f, stage, sg, &mut r);
        }
    }
    for su in &f.stage3 {
        check_unicast(f, su, &mut r);
    }
    check_coverage(f, &mut r);
    check_sequences(f, &mut r);
    check_stage_partition(f, &mut r);
    r
}

/// Engine pre-flight: prove a master's schedule before running it.
/// Clean ⇒ `Ok(())`; any violation ⇒ the typed
/// [`crate::error::CamrError::Invalid`] rejection.
pub fn preflight(master: &Master) -> Result<()> {
    let schedule = master.schedule()?;
    prove(&PlanFacts::from_master(master, &schedule)).into_result()
}

/// P101 — `J = q^(k-1)`, agreeing with `analysis::jobs`.
fn check_job_count(f: &PlanFacts, r: &mut CheckReport) {
    let closed = (f.q as u128).pow(f.k.saturating_sub(1) as u32);
    if f.jobs as u128 != closed {
        r.push(Diagnostic::error(
            "P101",
            "plan",
            format!("plan has {} jobs; closed form q^(k-1) = {closed}", f.jobs),
        ));
    }
    let req = JobRequirement::for_params(f.k, f.q);
    if req.camr != closed {
        r.push(Diagnostic::error(
            "P101",
            "plan",
            format!("analysis::jobs says {} CAMR jobs, closed form says {closed}", req.camr),
        ));
    }
    if f.servers != f.k * f.q {
        r.push(Diagnostic::error(
            "P101",
            "plan",
            format!("plan has {} servers; K = k·q = {}", f.servers, f.k * f.q),
        ));
    }
}

/// P102 — every job has `k` distinct owners, one per parallel class.
fn check_placement_shape(f: &PlanFacts, r: &mut CheckReport) {
    if f.owners.len() != f.jobs {
        r.push(Diagnostic::error(
            "P102",
            "placement",
            format!("owner table covers {} jobs, plan has {}", f.owners.len(), f.jobs),
        ));
    }
    for (j, own) in f.owners.iter().enumerate() {
        let loc = format!("job {j}");
        if own.len() != f.k {
            r.push(Diagnostic::error(
                "P102",
                &loc,
                format!("{} owners, want k = {}", own.len(), f.k),
            ));
            continue;
        }
        let classes: BTreeSet<usize> = own.iter().map(|s| s / f.q).collect();
        if classes.len() != f.k || own.iter().any(|&s| s >= f.servers) {
            r.push(Diagnostic::error(
                "P102",
                &loc,
                format!("owners {own:?} are not one valid server per parallel class"),
            ));
        }
    }
}

/// P103 — each (job, batch) is mapped by exactly `k-1` servers, all of
/// them owners of the job.
fn check_replication(f: &PlanFacts, r: &mut CheckReport) {
    let mut holders: BTreeMap<(JobId, BatchId), usize> = BTreeMap::new();
    for &(s, j, b) in &f.stored {
        *holders.entry((j, b)).or_insert(0) += 1;
        if j >= f.jobs || b >= f.k {
            r.push(Diagnostic::error(
                "P103",
                format!("server {s}"),
                format!("stores out-of-range (job {j}, batch {b})"),
            ));
        } else if !f.owners[j].contains(&s) {
            r.push(Diagnostic::error(
                "P103",
                format!("server {s}"),
                format!("stores (job {j}, batch {b}) without owning job {j}"),
            ));
        }
    }
    for j in 0..f.jobs {
        for b in 0..f.k {
            let n = holders.get(&(j, b)).copied().unwrap_or(0);
            if n != f.k.saturating_sub(1) {
                r.push(Diagnostic::error(
                    "P103",
                    format!("job {j} batch {b}"),
                    format!("mapped by {n} servers, want k-1 = {}", f.k - 1),
                ));
            }
        }
    }
}

/// P104/P105/P106 for one coded delivery group.
fn check_group(f: &PlanFacts, stage: &str, sg: &SeqGroup, r: &mut CheckReport) {
    let g = &sg.group;
    let loc = format!("{stage} group {}", sg.seq);
    // P104 — shape: ≥2 distinct valid members, one chunk per member,
    // chunk p addressed to member p.
    let distinct: BTreeSet<ServerId> = g.members.iter().copied().collect();
    if g.members.len() < 2
        || distinct.len() != g.members.len()
        || g.members.iter().any(|&m| m >= f.servers)
    {
        r.push(Diagnostic::error(
            "P104",
            &loc,
            format!("members {:?} are not >= 2 distinct valid servers", g.members),
        ));
        return; // the per-position checks below assume a sane shape
    }
    if g.chunks.len() != g.members.len() {
        r.push(Diagnostic::error(
            "P104",
            &loc,
            format!("{} chunks for {} members (want one each)", g.chunks.len(), g.members.len()),
        ));
        return;
    }
    for (p, c) in g.chunks.iter().enumerate() {
        if c.receiver != g.members[p] {
            r.push(Diagnostic::error(
                "P104",
                format!("{loc} chunk {p}"),
                format!("addressed to {} but member {p} is {}", c.receiver, g.members[p]),
            ));
        }
    }
    // P105 — decodability: chunk p mapped by every member except its
    // recipient (sender-side encodability + recipient-side
    // cancellation of every foreign XOR term), and needed by the
    // recipient (not locally mapped).
    for (p, c) in g.chunks.iter().enumerate() {
        let cloc = format!("{loc} chunk {p}");
        if c.job >= f.jobs || c.batch >= f.k {
            r.push(Diagnostic::error(
                "P105",
                &cloc,
                format!("refers to out-of-range (job {}, batch {})", c.job, c.batch),
            ));
            continue;
        }
        if f.maps(c.receiver, c.job, c.batch) {
            r.push(Diagnostic::error(
                "P105",
                &cloc,
                format!(
                    "receiver {} already maps (job {}, batch {}) — vacuous delivery",
                    c.receiver, c.job, c.batch
                ),
            ));
        }
        for (t, &m) in g.members.iter().enumerate() {
            if t != p && !f.maps(m, c.job, c.batch) {
                r.push(Diagnostic::error(
                    "P105",
                    &cloc,
                    format!(
                        "member {m} does not map (job {}, batch {}): cannot encode it \
                         or cancel it from member broadcasts",
                        c.job, c.batch
                    ),
                ));
            }
        }
    }
    check_funcs(f, &loc, g.chunks.iter().map(|c| (c.func, c.receiver)), r);
}

/// P106 — every delivered function belongs to its receiver's reduce
/// slice (`func mod K == receiver`) and to a scheduled round, and a
/// group serves exactly one round.
fn check_funcs(
    f: &PlanFacts,
    loc: &str,
    funcs: impl Iterator<Item = (usize, ServerId)>,
    r: &mut CheckReport,
) {
    let mut rounds_seen = BTreeSet::new();
    for (func, receiver) in funcs {
        if func % f.servers != receiver {
            r.push(Diagnostic::error(
                "P106",
                loc,
                format!(
                    "func {func} reduces at server {}, not receiver {receiver}",
                    func % f.servers
                ),
            ));
        }
        if func / f.servers >= f.rounds {
            r.push(Diagnostic::error(
                "P106",
                loc,
                format!("func {func} is round {}, schedule has {}", func / f.servers, f.rounds),
            ));
        }
        rounds_seen.insert(func / f.servers);
    }
    if rounds_seen.len() > 1 {
        r.push(Diagnostic::error(
            "P106",
            loc,
            format!("one delivery group spans rounds {rounds_seen:?}"),
        ));
    }
}

/// P104/P105/P106 for one stage-3 unicast: the sender maps every batch
/// it fuses, the receiver maps none of them.
fn check_unicast(f: &PlanFacts, su: &SeqUnicast, r: &mut CheckReport) {
    let s = &su.spec;
    let loc = format!("stage3 unicast {}", su.seq);
    let distinct: BTreeSet<BatchId> = s.batches.iter().copied().collect();
    if s.batches.is_empty()
        || distinct.len() != s.batches.len()
        || s.sender == s.receiver
        || s.sender >= f.servers
        || s.receiver >= f.servers
    {
        r.push(Diagnostic::error(
            "P104",
            &loc,
            format!(
                "malformed unicast: sender {} receiver {} batches {:?}",
                s.sender, s.receiver, s.batches
            ),
        ));
        return;
    }
    for &b in &s.batches {
        if s.job >= f.jobs || b >= f.k {
            r.push(Diagnostic::error(
                "P105",
                &loc,
                format!("refers to out-of-range (job {}, batch {b})", s.job),
            ));
            continue;
        }
        if !f.maps(s.sender, s.job, b) {
            r.push(Diagnostic::error(
                "P105",
                &loc,
                format!("sender {} does not map (job {}, batch {b})", s.sender, s.job),
            ));
        }
        if f.maps(s.receiver, s.job, b) {
            r.push(Diagnostic::error(
                "P105",
                &loc,
                format!(
                    "receiver {} already maps (job {}, batch {b}) — vacuous delivery",
                    s.receiver, s.job
                ),
            ));
        }
    }
    check_funcs(f, &loc, std::iter::once((s.func, s.receiver)), r);
}

/// P107 — exactly-once coverage: per round, each (server, job, batch)
/// the server does *not* map locally is delivered exactly once across
/// the three stages; nothing already mapped is ever delivered.
fn check_coverage(f: &PlanFacts, r: &mut CheckReport) {
    let mut delivered: BTreeMap<(usize, ServerId, JobId, BatchId), usize> = BTreeMap::new();
    let mut charge = |round: usize, recv: ServerId, job: JobId, batch: BatchId| {
        *delivered.entry((round, recv, job, batch)).or_insert(0) += 1;
    };
    for sg in f.stage1.iter().chain(&f.stage2) {
        for c in &sg.group.chunks {
            charge(c.func / f.servers.max(1), c.receiver, c.job, c.batch);
        }
    }
    for su in &f.stage3 {
        for &b in &su.spec.batches {
            charge(su.spec.func / f.servers.max(1), su.spec.receiver, su.spec.job, b);
        }
    }
    for round in 0..f.rounds {
        for s in 0..f.servers {
            for j in 0..f.jobs {
                for b in 0..f.k {
                    let n = delivered.get(&(round, s, j, b)).copied().unwrap_or(0);
                    let needed = !f.maps(s, j, b);
                    if needed && n != 1 {
                        r.push(Diagnostic::error(
                            "P107",
                            format!("round {round} server {s} job {j} batch {b}"),
                            format!("needed value delivered {n} times, want exactly 1"),
                        ));
                    } else if !needed && n != 0 {
                        r.push(Diagnostic::error(
                            "P107",
                            format!("round {round} server {s} job {j} batch {b}"),
                            format!("locally-mapped value delivered {n} times over the wire"),
                        ));
                    }
                }
            }
        }
    }
}

/// P108 — per stage, sequence numbers are exactly `0..len`: unique
/// and gap-free (the engines key ledger order and barrier progress on
/// them).
fn check_sequences(f: &PlanFacts, r: &mut CheckReport) {
    let stages: [(&str, Vec<usize>); 3] = [
        ("stage1", f.stage1.iter().map(|g| g.seq).collect()),
        ("stage2", f.stage2.iter().map(|g| g.seq).collect()),
        ("stage3", f.stage3.iter().map(|u| u.seq).collect()),
    ];
    for (stage, seqs) in stages {
        let mut seen = BTreeSet::new();
        for &q in &seqs {
            if !seen.insert(q) {
                r.push(Diagnostic::error("P108", stage, format!("duplicate sequence number {q}")));
            }
            if q >= seqs.len() {
                r.push(Diagnostic::error(
                    "P108",
                    stage,
                    format!("sequence {q} out of range 0..{} — gap in the schedule", seqs.len()),
                ));
            }
        }
    }
}

/// P109 — the stage barriers partition the schedule into the §IV
/// closed-form op counts: `rounds·J` stage-1 groups,
/// `rounds·J·(q-1)` stage-2 groups, `rounds·K·(J - J/q)` unicasts.
fn check_stage_partition(f: &PlanFacts, r: &mut CheckReport) {
    let per = [
        ("stage1", f.stage1.len(), f.rounds * f.jobs),
        ("stage2", f.stage2.len(), f.rounds * f.jobs * f.q.saturating_sub(1)),
        ("stage3", f.stage3.len(), f.rounds * f.servers * (f.jobs - f.jobs / f.q.max(1))),
    ];
    for (stage, got, want) in per {
        if got != want {
            r.push(Diagnostic::error(
                "P109",
                stage,
                format!("{got} scheduled ops, closed form wants {want}"),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example1_plan_proves_clean() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let f = PlanFacts::from_config(&cfg).unwrap();
        let report = prove(&f);
        assert!(report.is_clean(), "{:?}", report.diagnostics);
        assert!(report.diagnostics.is_empty());
    }

    #[test]
    fn preflight_accepts_valid_master() {
        let master = Master::new(SystemConfig::new(3, 2, 1).unwrap()).unwrap();
        preflight(&master).unwrap();
    }

    #[test]
    fn dropped_group_member_is_caught() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let mut f = PlanFacts::from_config(&cfg).unwrap();
        f.stage1[0].group.members.pop();
        let report = prove(&f);
        assert!(report.has_code("P104"), "{:?}", report.diagnostics);
    }

    #[test]
    fn skewed_replication_is_caught() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let mut f = PlanFacts::from_config(&cfg).unwrap();
        let victim = *f.stored.iter().next().unwrap();
        f.stored.remove(&victim);
        let report = prove(&f);
        assert!(report.has_code("P103"), "{:?}", report.diagnostics);
        // The placement hole also breaks decodability somewhere.
        assert!(report.has_code("P105"), "{:?}", report.diagnostics);
    }

    #[test]
    fn duplicated_sequence_is_caught() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let mut f = PlanFacts::from_config(&cfg).unwrap();
        f.stage2[1].seq = f.stage2[0].seq;
        let report = prove(&f);
        assert!(report.has_code("P108"), "{:?}", report.diagnostics);
    }
}
