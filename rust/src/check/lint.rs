//! Layer 2: the repo-invariant linter (`camr lint`). Walks a source
//! tree and enforces the defect classes this repo has actually
//! shipped — each rule is anchored to a real past regression:
//!
//! - **L201** an unregistered `rust/tests/*.rs` (PR 9: `obs_trace.rs`
//!   silently excluded from `cargo test` because `autotests = false`
//!   makes registration manual).
//! - **L202** a bench emitting a `"bench":` name the `bench_json`
//!   suite never asserts (PR 7: `xor_throughput` writing
//!   `"shuffle_data_plane"` — a guaranteed CI failure on any executed
//!   bench run).
//! - **L203** an over-width line `cargo fmt --check` rejects (PR 7:
//!   `net::socket` tests).
//! - **L204/L205** colliding `FrameKind` discriminants / `CamrError`
//!   wire codes: the wire protocol silently misroutes if two variants
//!   share a code. The declared truth lives in the const tables
//!   (`net::frame::FRAME_KIND_CODES`, `error::WIRE_CODES`); the
//!   linter independently re-parses the `match` arms from source so a
//!   table/code drift is also caught.
//! - **L206** wall-clock or ambient-RNG calls inside `sim/` — the
//!   simulator is deterministic by contract (seeded
//!   [`crate::util::rng`] only; the virtual clock never reads time).
//!
//! Rules are path-relative to the given root so the fixture tests in
//! `rust/tests/lint_rules.rs` can run the identical linter over
//! known-bad miniature trees under `rust/tests/lint_fixtures/`.

use super::{CheckReport, Diagnostic};
use crate::error::Result;
use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

/// Maximum allowed line width (characters), matching the rustfmt
/// configuration the tree is formatted to.
pub const MAX_WIDTH: usize = 100;

/// Directories the source walk never descends into: build output,
/// vendored deps, VCS state, and the intentionally-defective lint
/// fixtures themselves.
const SKIP_DIRS: &[&str] = &["target", "vendor", ".git", "lint_fixtures", "golden"];

/// Run every lint over the repo rooted at `root`, returning all
/// findings. Missing optional inputs (no benches, no `sim/`, …) skip
/// their rules; a missing `Cargo.toml` is an error finding, not an
/// `Err` (the tree is lintable, just wrong).
pub fn lint_repo(root: &Path) -> Result<CheckReport> {
    let mut r = CheckReport::new();
    let manifest = read_manifest(root, &mut r);
    lint_test_registration(root, &manifest, &mut r);
    lint_bench_names(root, &manifest, &mut r);
    lint_line_width(root, &mut r)?;
    lint_code_collisions(
        root,
        "rust/src/net/frame.rs",
        "FrameKind::",
        "L204",
        "FrameKind discriminant",
        &mut r,
    );
    lint_code_collisions(
        root,
        "rust/src/error.rs",
        "CamrError::",
        "L205",
        "CamrError wire code",
        &mut r,
    );
    lint_sim_determinism(root, &mut r);
    Ok(r)
}

/// The registered cargo targets we lint against, parsed from
/// `Cargo.toml` text (section headers + `name`/`path` keys — the
/// manifest is plain enough that a TOML parser is not needed).
#[derive(Debug, Default)]
struct Manifest {
    /// `path` values of every `[[test]]` target.
    test_paths: Vec<String>,
    /// `(name, path)` of every `[[bench]]` target.
    benches: Vec<(String, String)>,
}

fn read_manifest(root: &Path, r: &mut CheckReport) -> Manifest {
    let mut m = Manifest::default();
    let path = root.join("Cargo.toml");
    let Ok(text) = fs::read_to_string(&path) else {
        r.push(Diagnostic::error("L201", "Cargo.toml", "manifest missing or unreadable"));
        return m;
    };
    let mut section = String::new();
    let mut cur_name = String::new();
    for line in text.lines() {
        let line = line.trim();
        if line.starts_with('[') {
            section = line.to_string();
            cur_name.clear();
            continue;
        }
        let Some((key, val)) = line.split_once('=') else { continue };
        let (key, val) = (key.trim(), val.trim().trim_matches('"'));
        match (section.as_str(), key) {
            ("[[test]]", "path") => m.test_paths.push(val.to_string()),
            ("[[bench]]", "name") => cur_name = val.to_string(),
            ("[[bench]]", "path") => m.benches.push((cur_name.clone(), val.to_string())),
            _ => {}
        }
    }
    m
}

/// L201 — with `autotests = false`, a test file cargo is never told
/// about silently drops out of `cargo test`. Every direct `*.rs`
/// child of `rust/tests/` must appear as a `[[test]]` path.
fn lint_test_registration(root: &Path, manifest: &Manifest, r: &mut CheckReport) {
    let dir = root.join("rust/tests");
    let Ok(entries) = fs::read_dir(&dir) else { return };
    let mut files: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.is_file() && p.extension().is_some_and(|x| x == "rs"))
        .collect();
    files.sort();
    for f in files {
        let rel = format!("rust/tests/{}", f.file_name().unwrap().to_string_lossy());
        if !manifest.test_paths.iter().any(|p| p == &rel) {
            r.push(Diagnostic::error(
                "L201",
                &rel,
                "test file not registered as a [[test]] target in Cargo.toml \
                 (autotests = false: cargo test silently skips it)",
            ));
        }
    }
}

/// L202 — every `("bench", Json::Str("NAME"))` a bench emits must be
/// a name `rust/tests/bench_json.rs` asserts, or the executed-bench
/// CI step fails while `cargo test` alone stays green.
fn lint_bench_names(root: &Path, manifest: &Manifest, r: &mut CheckReport) {
    let asserts = fs::read_to_string(root.join("rust/tests/bench_json.rs")).unwrap_or_default();
    if asserts.is_empty() {
        return; // no assertion suite in this tree — nothing to match
    }
    for (name, rel) in &manifest.benches {
        let Ok(text) = fs::read_to_string(root.join(rel)) else { continue };
        for (i, line) in text.lines().enumerate() {
            let Some(at) = line.find("(\"bench\"") else { continue };
            let rest = line.get(at + 8..).unwrap_or("");
            let Some(emitted) = next_string_literal(rest) else { continue };
            if !asserts.contains(&format!("\"{emitted}\"")) {
                r.push(Diagnostic::error(
                    "L202",
                    format!("{rel}:{}", i + 1),
                    format!(
                        "bench target `{name}` emits \"bench\": \"{emitted}\", which \
                         rust/tests/bench_json.rs never asserts"
                    ),
                ));
            }
        }
    }
}

/// The next `"…"` literal in `rest`, if any (no escape handling — the
/// emitted names are plain identifiers).
fn next_string_literal(rest: &str) -> Option<&str> {
    let start = rest.find('"')? + 1;
    let len = rest[start..].find('"')?;
    Some(&rest[start..start + len])
}

/// L203 — over-width lines (PR 7's fmt-breaking defect class: rustfmt
/// cannot shrink a long string literal, so `cargo fmt --check` fails
/// until a human rewraps it).
fn lint_line_width(root: &Path, r: &mut CheckReport) -> Result<()> {
    for dir in ["rust/src", "rust/tests", "benches", "examples"] {
        walk_rs(&root.join(dir), &mut |path| {
            let Ok(text) = fs::read_to_string(path) else { return };
            let rel = path.strip_prefix(root).unwrap_or(path).display();
            for (i, line) in text.lines().enumerate() {
                let width = line.chars().count();
                if width > MAX_WIDTH {
                    r.push(Diagnostic::error(
                        "L203",
                        format!("{rel}:{}", i + 1),
                        format!("line is {width} characters wide (max {MAX_WIDTH})"),
                    ));
                }
            }
        })?;
    }
    Ok(())
}

/// Recursively visit every `.rs` file under `dir`, skipping
/// [`SKIP_DIRS`]. Missing directories are fine (fixtures are partial
/// trees).
fn walk_rs(dir: &Path, visit: &mut dyn FnMut(&Path)) -> Result<()> {
    let Ok(entries) = fs::read_dir(dir) else { return Ok(()) };
    let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            let name = p.file_name().unwrap_or_default().to_string_lossy().into_owned();
            if !SKIP_DIRS.contains(&name.as_str()) {
                walk_rs(&p, visit)?;
            }
        } else if p.extension().is_some_and(|x| x == "rs") {
            visit(&p);
        }
    }
    Ok(())
}

/// L204/L205 — re-parse the `match` arms mapping enum variants to
/// numeric wire codes and flag any code claimed by two variants or
/// any variant claimed by two codes (per direction a collision is a
/// silent misroute on the wire).
fn lint_code_collisions(
    root: &Path,
    rel: &str,
    variant_prefix: &str,
    code: &'static str,
    what: &str,
    r: &mut CheckReport,
) {
    let Ok(text) = fs::read_to_string(root.join(rel)) else { return };
    // (number, variant) pairs from `Variant… => N` and `N => Variant…`.
    let mut by_num: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    let mut lines_of: BTreeMap<(u64, String), usize> = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let Some((lhs, rhs)) = line.split_once("=>") else { continue };
        let (num_side, var_side) = if lhs.contains(variant_prefix) {
            (rhs, lhs)
        } else if rhs.contains(variant_prefix) {
            (lhs, rhs)
        } else {
            continue;
        };
        let Some(n) = parse_leading_int(num_side) else { continue };
        let Some(v) = parse_variant(var_side, variant_prefix) else { continue };
        by_num.entry(n).or_default().push(v.clone());
        lines_of.entry((n, v)).or_insert(i + 1);
    }
    for (n, variants) in &by_num {
        let mut distinct = variants.clone();
        distinct.sort();
        distinct.dedup();
        if distinct.len() > 1 {
            let line = lines_of.get(&(*n, distinct[1].clone())).copied().unwrap_or(0);
            r.push(Diagnostic::error(
                code,
                format!("{rel}:{line}"),
                format!("{what} {n} claimed by multiple variants: {distinct:?}"),
            ));
        }
    }
}

/// Leading integer of a match-arm side like ` 12, ` or `12 => …`.
fn parse_leading_int(side: &str) -> Option<u64> {
    let t = side.trim().trim_end_matches(',');
    let digits: String = t.chars().take_while(|c| c.is_ascii_digit()).collect();
    if digits.is_empty() || digits.len() != t.len() {
        return None;
    }
    digits.parse().ok()
}

/// Variant name after `prefix` in a match-arm side, e.g.
/// `CamrError::QueueFull(m)` → `QueueFull`.
fn parse_variant(side: &str, prefix: &str) -> Option<String> {
    let at = side.find(prefix)? + prefix.len();
    let name: String =
        side[at..].chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// Forbidden tokens inside `sim/`: anything that reads the wall clock
/// or ambient randomness would break replay determinism.
const SIM_FORBIDDEN: &[&str] =
    &["Instant::now", "SystemTime", "thread_rng", "rand::", "from_entropy", "getrandom"];

/// L206 — the simulator must stay deterministic: virtual clock only,
/// seeded `util::rng` only.
fn lint_sim_determinism(root: &Path, r: &mut CheckReport) {
    let _ = walk_rs(&root.join("rust/src/sim"), &mut |path| {
        let Ok(text) = fs::read_to_string(path) else { return };
        let rel = path.strip_prefix(root).unwrap_or(path).display();
        for (i, line) in text.lines().enumerate() {
            for tok in SIM_FORBIDDEN {
                if line.contains(tok) {
                    r.push(Diagnostic::error(
                        "L206",
                        format!("{rel}:{}", i + 1),
                        format!("determinism-critical sim/ path calls `{tok}`"),
                    ));
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn string_literal_extraction() {
        let line = ", Json::Str(\"xor_throughput\".into())";
        assert_eq!(next_string_literal(line), Some("xor_throughput"));
        assert_eq!(next_string_literal("no quotes here"), None);
    }

    #[test]
    fn match_arm_parsing() {
        assert_eq!(parse_leading_int(" 12,"), Some(12));
        assert_eq!(parse_leading_int(" other "), None);
        assert_eq!(parse_leading_int(" return Err(x) "), None);
        assert_eq!(
            parse_variant(" CamrError::QueueFull(m),", "CamrError::"),
            Some("QueueFull".into())
        );
        assert_eq!(parse_variant(" _ ", "CamrError::"), None);
    }
}
