//! Static verification: a plan-level decodability prover and a
//! repo-invariant linter sharing one typed [`Diagnostic`] vocabulary.
//!
//! CAMR's correctness rests on combinatorial invariants — `q^(k-1)`
//! jobs, `(k-1)×` map replication, delivery groups whose XOR-coded
//! packets every recipient can cancel from its local map outputs —
//! that until now were only checked *by executing* a round and
//! oracle-verifying the reduced outputs. This module checks them
//! statically, before any worker starts:
//!
//! - [`prover`] proves a full placement + schedule correct from the
//!   plan alone (`camr check`, engine pre-flight on all four planes,
//!   and [`crate::service::JobService`] admission).
//! - [`lint`] walks the source tree and mechanizes the repo audits
//!   that used to be manual (`camr lint`): test registration,
//!   bench-name/schema agreement, line width, wire-code uniqueness,
//!   and simulator determinism purity.
//!
//! ## Diagnostic-code catalog
//!
//! Prover (`P1xx`, from [`prover::prove`]):
//!
//! | code | invariant |
//! |------|-----------|
//! | P101 | job count equals the closed form `q^(k-1)` (`analysis::jobs`) |
//! | P102 | placement shape: `k` owners per job, one per parallel class |
//! | P103 | map replication exactly `(k-1)×` per (job, batch) |
//! | P104 | delivery-group shape: distinct members, chunk `p` ↔ member `p` |
//! | P105 | decodability: every XOR term is the recipient's needed value |
//! |      | or cancellable from its locally-mapped subfiles |
//! | P106 | reducer consistency: `func mod K` is the chunk's receiver |
//! | P107 | coverage: every needed (receiver, job, batch) delivered |
//! |      | exactly once per round |
//! | P108 | schedule sequence numbers gap-free and unique per stage |
//! | P109 | stage barriers partition the schedule (per-stage op counts |
//! |      | match the §IV closed forms) |
//!
//! Linter (`L2xx`, from [`lint::lint_repo`]):
//!
//! | code | invariant |
//! |------|-----------|
//! | L201 | every `rust/tests/*.rs` registered in `Cargo.toml` |
//! | L202 | every emitted `"bench":` name asserted by `bench_json.rs` |
//! | L203 | source lines at most 100 characters wide |
//! | L204 | `FrameKind` wire discriminants collision-free |
//! | L205 | `CamrError` wire codes collision-free |
//! | L206 | no wall-clock / ambient-RNG calls inside `sim/` |
//!
//! The prover guarantees *plan* correctness: whatever the workers
//! compute, every coded packet is decodable and every needed value
//! arrives exactly once. Only execution can show *data* correctness —
//! that map functions, aggregation, and the XOR data plane produce
//! the right bytes — which stays with the oracle verification
//! (`RunOutcome::verified`). The two agree on every shipped config
//! (`rust/tests/static_check.rs`).
//!
//! ## Adding a new lint
//!
//! Add a rule function in [`lint`] taking the repo root and a
//! `&mut CheckReport`, pick the next free `L2xx` code, document it in
//! the table above, call it from [`lint::lint_repo`], and seed a
//! known-bad fixture under `rust/tests/lint_fixtures/` asserting the
//! code fires (and that the real tree stays clean).

pub mod lint;
pub mod prover;

use crate::error::{CamrError, Result};
use crate::util::json::Json;
use std::fmt;

pub use prover::{preflight, prove, PlanFacts};

/// How bad a finding is. `Error`s fail `camr check` / `camr lint` and
/// engine pre-flight; `Warning`s are reported but do not fail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Invariant violation: the plan or tree is wrong.
    Error,
    /// Suspicious but not provably wrong.
    Warning,
}

impl Severity {
    /// Lower-case label used in reports and JSON.
    pub fn label(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One machine-readable finding: a stable code, a severity, the
/// location it anchors to (a schedule coordinate or `file:line`), and
/// a human message.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable machine-readable code (`P1xx` prover, `L2xx` linter).
    pub code: &'static str,
    /// Error or warning.
    pub severity: Severity,
    /// Where: `stage2 group 3 chunk 1`, `rust/tests/foo.rs:12`, …
    pub location: String,
    /// What went wrong, in terms of the violated invariant.
    pub message: String,
}

impl Diagnostic {
    /// An error-severity diagnostic.
    pub fn error(
        code: &'static str,
        location: impl Into<String>,
        message: impl Into<String>,
    ) -> Self {
        Diagnostic {
            code,
            severity: Severity::Error,
            location: location.into(),
            message: message.into(),
        }
    }

    /// A warning-severity diagnostic.
    pub fn warning(
        code: &'static str,
        location: impl Into<String>,
        message: impl Into<String>,
    ) -> Self {
        Diagnostic {
            code,
            severity: Severity::Warning,
            location: location.into(),
            message: message.into(),
        }
    }

    /// JSON object form (`{"code","severity","location","message"}`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::Str(self.code.to_string())),
            ("severity", Json::Str(self.severity.label().to_string())),
            ("location", Json::Str(self.location.clone())),
            ("message", Json::Str(self.message.clone())),
        ])
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} [{}] {}: {}", self.severity.label(), self.code, self.location, self.message)
    }
}

/// The result of one analysis pass: every diagnostic it produced.
#[derive(Debug, Clone, Default)]
pub struct CheckReport {
    /// All findings, in discovery order.
    pub diagnostics: Vec<Diagnostic>,
}

/// Cap on findings reported *per code* — a systematically broken plan
/// yields thousands of identical violations; the first few plus a
/// count carry the same information.
pub const MAX_PER_CODE: usize = 8;

impl CheckReport {
    /// A report with no findings.
    pub fn new() -> Self {
        CheckReport::default()
    }

    /// Add a finding, truncating after [`MAX_PER_CODE`] per code (a
    /// summary line is appended by the truncation itself).
    pub fn push(&mut self, d: Diagnostic) {
        let same = self.diagnostics.iter().filter(|x| x.code == d.code).count();
        match same.cmp(&MAX_PER_CODE) {
            std::cmp::Ordering::Less => self.diagnostics.push(d),
            std::cmp::Ordering::Equal => self.diagnostics.push(Diagnostic {
                message: format!("… further {} findings suppressed", d.code),
                location: "(truncated)".into(),
                ..d
            }),
            std::cmp::Ordering::Greater => {}
        }
    }

    /// True when no *error*-severity finding is present.
    pub fn is_clean(&self) -> bool {
        !self.diagnostics.iter().any(|d| d.severity == Severity::Error)
    }

    /// The error-severity findings.
    pub fn errors(&self) -> Vec<&Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).collect()
    }

    /// Does any finding carry this code?
    pub fn has_code(&self, code: &str) -> bool {
        self.diagnostics.iter().any(|d| d.code == code)
    }

    /// JSON export: `{"clean": bool, "diagnostics": [...]}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("clean", Json::Bool(self.is_clean())),
            ("diagnostics", Json::Arr(self.diagnostics.iter().map(Diagnostic::to_json).collect())),
        ])
    }

    /// Collapse into a typed result: clean ⇒ `Ok(())`, otherwise the
    /// [`CamrError::Invalid`] rejection engines and the job service
    /// surface instead of failing mid-round. The message leads with
    /// the first error; the rest are summarized by code.
    pub fn into_result(self) -> Result<()> {
        if self.is_clean() {
            return Ok(());
        }
        let errs = self.errors();
        let mut msg = format!("{}", errs[0]);
        if errs.len() > 1 {
            let codes: Vec<&str> = errs.iter().map(|d| d.code).collect();
            msg.push_str(&format!(" (+{} more: {})", errs.len() - 1, codes[1..].join(", ")));
        }
        Err(CamrError::Invalid(msg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_report_is_ok() {
        let r = CheckReport::new();
        assert!(r.is_clean());
        assert!(r.into_result().is_ok());
    }

    #[test]
    fn error_report_becomes_typed_invalid() {
        let mut r = CheckReport::new();
        r.push(Diagnostic::error("P105", "stage1 group 0 chunk 1", "term not cancellable"));
        r.push(Diagnostic::error("P103", "job 2 batch 0", "stored by 1 servers, want 2"));
        assert!(!r.is_clean());
        let err = r.into_result().unwrap_err();
        assert_eq!(err.wire_code(), 13);
        let text = err.to_string();
        assert!(text.contains("P105") && text.contains("P103"), "{text}");
    }

    #[test]
    fn warnings_do_not_fail() {
        let mut r = CheckReport::new();
        r.push(Diagnostic::warning("L203", "x.rs:1", "wide line"));
        assert!(r.is_clean());
        assert!(r.into_result().is_ok());
    }

    #[test]
    fn per_code_truncation_keeps_reports_bounded() {
        let mut r = CheckReport::new();
        for i in 0..100 {
            r.push(Diagnostic::error("P107", format!("receiver {i}"), "missed delivery"));
        }
        let p107 = r.diagnostics.iter().filter(|d| d.code == "P107").count();
        assert_eq!(p107, MAX_PER_CODE + 1);
        assert!(r.diagnostics.last().unwrap().message.contains("suppressed"));
        assert!(!r.is_clean());
    }

    #[test]
    fn json_export_shape() {
        let mut r = CheckReport::new();
        r.push(Diagnostic::error("P108", "stage2", "duplicate seq 3"));
        let j = r.to_json();
        assert_eq!(j.get("clean"), Some(&Json::Bool(false)));
        let rendered = j.render();
        let back = Json::parse(&rendered).unwrap();
        assert_eq!(back, j);
        match back.get("diagnostics") {
            Some(Json::Arr(a)) => {
                assert_eq!(a[0].get("code"), Some(&Json::Str("P108".into())));
                assert_eq!(a[0].get("severity"), Some(&Json::Str("error".into())));
            }
            other => panic!("diagnostics not an array: {other:?}"),
        }
    }
}
