//! PJRT runtime: loads AOT-compiled JAX/Pallas artifacts (HLO **text**,
//! see `python/compile/aot.py`) and executes them on the map path.
//!
//! ## Feature gating
//!
//! The real runtime depends on the external `xla` crate and is compiled
//! only with the `pjrt` cargo feature (which requires adding that
//! dependency — this workspace builds offline by default). Without the
//! feature, [`PjrtShardCompute`] is a stub whose constructor returns a
//! typed [`CamrError::Runtime`] error, so every call site (CLI
//! `--artifact`, the matvec example) degrades gracefully to the native
//! mapper. [`ArtifactMeta`] and [`meta_path_for`] are always available —
//! artifact metadata is plain JSON and needs no accelerator.
//!
//! ## Threading model (with `pjrt`)
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (neither `Send` nor
//! `Sync`), while the engine's map phase fans out across worker threads.
//! We therefore run PJRT on a dedicated **service thread** that owns the
//! client and all compiled executables; map workers submit shard-product
//! requests over a channel and block on the reply. This keeps all PJRT
//! state on one thread (no `unsafe impl Send`) and mirrors how a real
//! deployment pins an accelerator context to a driver thread.
//!
//! Python never runs here: artifacts are produced once by
//! `make artifacts` and loaded from disk.

use crate::error::{CamrError, Result};
use crate::util::json::get_field;
use crate::workload::matvec::ShardCompute;
use std::path::{Path, PathBuf};

/// Metadata emitted by `python/compile/aot.py` alongside each artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Row count `M` of the shard matmul.
    pub m: usize,
    /// Column count of each shard.
    pub cols: usize,
    /// Element type (only "f32" is supported).
    pub dtype: String,
    /// Which kernel produced this HLO ("pallas_matvec" / "jnp_ref").
    pub kernel: String,
}

impl ArtifactMeta {
    /// Parse the flat JSON meta file written by `aot.py`.
    pub fn parse(text: &str) -> Result<Self> {
        let field = |k: &str| {
            get_field(text, k)
                .ok_or_else(|| CamrError::Runtime(format!("artifact meta missing `{k}`")))
        };
        let m = field("m")?
            .parse::<usize>()
            .map_err(|e| CamrError::Runtime(format!("meta m: {e}")))?;
        let cols = field("cols")?
            .parse::<usize>()
            .map_err(|e| CamrError::Runtime(format!("meta cols: {e}")))?;
        Ok(ArtifactMeta { m, cols, dtype: field("dtype")?, kernel: field("kernel")? })
    }
}

/// The meta file path for an artifact: `model.hlo.txt → model.meta.json`.
pub fn meta_path_for(artifact: &Path) -> PathBuf {
    let stem = artifact
        .file_name()
        .and_then(|s| s.to_str())
        .map(|s| s.trim_end_matches(".hlo.txt").to_string())
        .unwrap_or_else(|| "model".into());
    artifact.with_file_name(format!("{stem}.meta.json"))
}

#[cfg(feature = "pjrt")]
mod service {
    use super::ArtifactMeta;
    use crate::error::{CamrError, Result};
    use std::path::{Path, PathBuf};
    use std::sync::mpsc as smpsc;
    use std::sync::Mutex;

    /// A request to the service thread.
    enum Request {
        /// Compute `A_shard (m×cols) · x_shard` and reply with the m-vector.
        MatVec { a: Vec<f32>, x: Vec<f32>, reply: smpsc::Sender<Result<Vec<f32>>> },
        /// Shut down.
        Stop,
    }

    /// Handle to the PJRT service thread.
    ///
    /// Cloneable-ish via `Arc`; `Send + Sync` because it only holds a
    /// mutex-guarded channel sender.
    pub struct PjrtService {
        tx: Mutex<smpsc::Sender<Request>>,
        meta: ArtifactMeta,
        join: Option<std::thread::JoinHandle<()>>,
    }

    impl PjrtService {
        /// Load `<artifact>.hlo.txt` + `<artifact>.meta.json`, compile on the
        /// PJRT CPU client, and start the service thread.
        ///
        /// `artifact` is the path to the `.hlo.txt` file; the meta file is
        /// derived by replacing the extension.
        pub fn start(artifact: &Path) -> Result<Self> {
            let meta_path = super::meta_path_for(artifact);
            let meta_text = std::fs::read_to_string(&meta_path).map_err(|e| {
                CamrError::Runtime(format!("read {}: {e}", meta_path.display()))
            })?;
            let meta = ArtifactMeta::parse(&meta_text)?;
            if meta.dtype != "f32" {
                return Err(CamrError::Runtime(format!(
                    "unsupported artifact dtype {}",
                    meta.dtype
                )));
            }
            let (tx, rx) = smpsc::channel::<Request>();
            let artifact = artifact.to_path_buf();
            let (ready_tx, ready_rx) = smpsc::channel::<Result<()>>();
            let meta_thread = meta.clone();
            let join = std::thread::Builder::new()
                .name("pjrt-service".into())
                .spawn(move || service_main(artifact, meta_thread, rx, ready_tx))
                .map_err(|e| CamrError::Runtime(format!("spawn pjrt thread: {e}")))?;
            // Wait for compile to finish (or fail) before returning.
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => return Err(e),
                Err(_) => {
                    return Err(CamrError::Runtime("pjrt service died during init".into()))
                }
            }
            Ok(PjrtService { tx: Mutex::new(tx), meta, join: Some(join) })
        }

        /// Artifact metadata (shapes).
        pub fn meta(&self) -> &ArtifactMeta {
            &self.meta
        }

        /// Execute one shard product on the service thread.
        pub fn matvec(&self, a: &[f32], x: &[f32]) -> Result<Vec<f32>> {
            if x.len() != self.meta.cols || a.len() != self.meta.m * self.meta.cols {
                return Err(CamrError::Runtime(format!(
                    "shard shape {}×{} does not match artifact {}×{}",
                    a.len() / x.len().max(1),
                    x.len(),
                    self.meta.m,
                    self.meta.cols
                )));
            }
            let (rtx, rrx) = smpsc::channel();
            {
                let tx = self
                    .tx
                    .lock()
                    .map_err(|_| CamrError::Runtime("pjrt tx poisoned".into()))?;
                tx.send(Request::MatVec { a: a.to_vec(), x: x.to_vec(), reply: rtx })
                    .map_err(|_| CamrError::Runtime("pjrt service stopped".into()))?;
            }
            rrx.recv().map_err(|_| CamrError::Runtime("pjrt service dropped reply".into()))?
        }
    }

    impl Drop for PjrtService {
        fn drop(&mut self) {
            if let Ok(tx) = self.tx.lock() {
                let _ = tx.send(Request::Stop);
            }
            if let Some(j) = self.join.take() {
                let _ = j.join();
            }
        }
    }

    /// Service thread main: owns the client + executable, serves requests.
    fn service_main(
        artifact: PathBuf,
        meta: ArtifactMeta,
        rx: smpsc::Receiver<Request>,
        ready: smpsc::Sender<Result<()>>,
    ) {
        let setup = (|| -> Result<(xla::PjRtClient, xla::PjRtLoadedExecutable)> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| CamrError::Runtime(format!("pjrt cpu client: {e}")))?;
            let proto = xla::HloModuleProto::from_text_file(&artifact)
                .map_err(|e| CamrError::Runtime(format!("load {}: {e}", artifact.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| CamrError::Runtime(format!("compile artifact: {e}")))?;
            Ok((client, exe))
        })();
        let (_client, exe) = match setup {
            Ok(pair) => {
                let _ = ready.send(Ok(()));
                pair
            }
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        };
        while let Ok(req) = rx.recv() {
            match req {
                Request::Stop => break,
                Request::MatVec { a, x, reply } => {
                    let result = (|| -> Result<Vec<f32>> {
                        let a_lit = xla::Literal::vec1(&a)
                            .reshape(&[meta.m as i64, meta.cols as i64])
                            .map_err(|e| CamrError::Runtime(format!("reshape A: {e}")))?;
                        let x_lit = xla::Literal::vec1(&x)
                            .reshape(&[meta.cols as i64])
                            .map_err(|e| CamrError::Runtime(format!("reshape x: {e}")))?;
                        let bufs = exe
                            .execute::<xla::Literal>(&[a_lit, x_lit])
                            .map_err(|e| CamrError::Runtime(format!("execute: {e}")))?;
                        let lit = bufs[0][0]
                            .to_literal_sync()
                            .map_err(|e| CamrError::Runtime(format!("fetch result: {e}")))?;
                        // aot.py lowers with return_tuple=True → 1-tuple.
                        let out = lit
                            .to_tuple1()
                            .map_err(|e| CamrError::Runtime(format!("untuple: {e}")))?;
                        out.to_vec::<f32>()
                            .map_err(|e| CamrError::Runtime(format!("to_vec: {e}")))
                    })();
                    let _ = reply.send(result);
                }
            }
        }
    }
}

#[cfg(feature = "pjrt")]
pub use service::PjrtService;

/// [`ShardCompute`] backend that runs the AOT Pallas/JAX kernel via PJRT.
#[cfg(feature = "pjrt")]
pub struct PjrtShardCompute {
    service: PjrtService,
}

#[cfg(feature = "pjrt")]
impl PjrtShardCompute {
    /// Start a service for the artifact and wrap it.
    pub fn new(artifact: &Path) -> Result<Self> {
        Ok(PjrtShardCompute { service: PjrtService::start(artifact)? })
    }

    /// The artifact's shard shape `(m, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.service.meta().m, self.service.meta().cols)
    }
}

#[cfg(feature = "pjrt")]
impl ShardCompute for PjrtShardCompute {
    fn partial_product(&self, a_shard: &[f32], x_shard: &[f32], m: usize) -> Result<Vec<f32>> {
        if m != self.service.meta().m {
            return Err(CamrError::Runtime(format!(
                "m = {m} does not match artifact m = {}",
                self.service.meta().m
            )));
        }
        self.service.matvec(a_shard, x_shard)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Stub [`ShardCompute`] backend used when the crate is built without the
/// `pjrt` feature: construction fails with a typed error so callers fall
/// back to [`crate::workload::matvec::NativeShardCompute`].
#[cfg(not(feature = "pjrt"))]
pub struct PjrtShardCompute {
    _unconstructable: (),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtShardCompute {
    /// Always errors: the crate was built without PJRT support.
    pub fn new(artifact: &Path) -> Result<Self> {
        Err(CamrError::Runtime(format!(
            "cannot load {}: camr was built without the `pjrt` feature (add the `xla` \
             dependency and enable it, or drop --artifact to use the native mapper)",
            artifact.display()
        )))
    }

    /// The artifact's shard shape — unreachable on the stub, which cannot
    /// be constructed.
    pub fn shape(&self) -> (usize, usize) {
        (0, 0)
    }
}

#[cfg(not(feature = "pjrt"))]
impl ShardCompute for PjrtShardCompute {
    fn partial_product(&self, _a: &[f32], _x: &[f32], _m: usize) -> Result<Vec<f32>> {
        Err(CamrError::Runtime("pjrt backend unavailable (built without `pjrt`)".into()))
    }

    fn name(&self) -> &'static str {
        "pjrt-unavailable"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_roundtrip() {
        let meta = ArtifactMeta::parse(
            r#"{"m": 24, "cols": 8, "dtype": "f32", "kernel": "pallas_matvec"}"#,
        )
        .unwrap();
        assert_eq!(meta.m, 24);
        assert_eq!(meta.cols, 8);
        assert_eq!(meta.dtype, "f32");
        assert_eq!(meta.kernel, "pallas_matvec");
        assert!(ArtifactMeta::parse(r#"{"m": 24}"#).is_err());
    }

    #[test]
    fn meta_path_derivation() {
        assert_eq!(
            meta_path_for(Path::new("artifacts/model.hlo.txt")),
            PathBuf::from("artifacts/model.meta.json")
        );
        assert_eq!(
            meta_path_for(Path::new("/x/y/map_kernel.hlo.txt")),
            PathBuf::from("/x/y/map_kernel.meta.json")
        );
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_backend_errors_cleanly() {
        let err = PjrtShardCompute::new(Path::new("artifacts/missing.hlo.txt")).unwrap_err();
        assert!(err.to_string().contains("pjrt"));
    }

    // PJRT-backed execution tests live in rust/tests/pjrt_runtime.rs —
    // they need `make artifacts` to have run first and the `pjrt` feature.
}
