//! Discrete-event cluster simulator: turns byte-exact shuffle ledgers
//! into end-to-end completion times under stragglers, heterogeneity,
//! and real link models.
//!
//! The ledgers produced by [`crate::net::Bus`] (PR 1/2) are exact in
//! *bytes*, but the bus itself is an instantaneous accounting device —
//! it cannot answer the paper's headline question, which is about
//! *time* ("on average, 33% of the overall job execution time is spent
//! on data shuffling", §I). This module closes that gap: it replays a
//! recorded ledger — or one freshly produced by a live engine run —
//! through a configurable cluster model and reports per-phase simulated
//! times.
//!
//! ## Architecture
//!
//! - [`event::EventQueue`] — a binary-heap event queue with a virtual
//!   clock; ties break by schedule order so runs are bit-deterministic.
//! - **Map phase** — every worker runs its map tasks sequentially while
//!   workers proceed in parallel; each task's duration is
//!   `secs_per_map × straggler_factor / speed`. The phase ends at a
//!   barrier (the slowest worker), which is exactly how stragglers
//!   hurt real MapReduce clusters.
//! - **Shuffle** — the ledger is split into barrier-separated phases
//!   (contiguous same-stage runs, via [`crate::net::stage_runs`]) and
//!   each phase's transmissions contend per the link model
//!   ([`link::LinkKind`]): one serializing shared multicast link (the
//!   paper's model) or a full-bisection fabric that serializes per
//!   sender NIC. **A multicast is charged once** regardless of
//!   recipient count, matching `Bus` semantics — this is the property
//!   that makes coded shuffling win.
//! - **Stragglers** — pluggable distributions
//!   ([`straggler::StragglerModel`]): deterministic, shifted
//!   exponential, percentile tail. Draws are addressable by
//!   `(seed, worker, task)`, so schemes with identical map layouts see
//!   identical map randomness and differ only by their shuffles.
//! - **Heterogeneity** — per-worker compute-speed multipliers.
//!
//! ## The closed form is the degenerate case
//!
//! With zero latency, homogeneous workers, no stragglers, and the
//! shared link, the simulator reproduces [`model::TimeModel`] — the
//! closed-form model this module absorbed from `analysis::time_model` —
//! **bit-exactly** (`rust/tests/sim_times.rs`). That identity is not an
//! accident: task completion times are computed from straggler-weighted
//! work *units* (sums of exact `1.0`s in the degenerate case) and link
//! times from integer byte accumulators (`link::Acc`), so each readout
//! performs the same single rounding as the closed form. The two
//! models cannot silently diverge.

pub mod arrival;
pub mod event;
pub mod link;
pub mod model;
pub mod straggler;

pub use arrival::{poisson_trace, simulate_open_arrivals, Arrival, ArrivalConfig};
pub use event::{Event, EventQueue};
pub use link::LinkKind;
pub use model::TimeModel;
pub use straggler::StragglerModel;

use crate::analysis::jobs::binomial;
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::net::{stage_runs, Stage, Transmission};
use crate::placement::Placement;
use crate::util::cfgtext::CfgText;
use crate::util::json::Json;
use link::{Acc, PhaseChains};

/// Full cluster model for one simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    /// Network contention model.
    pub link: LinkKind,
    /// Per-link bandwidth in bytes/second.
    pub link_bytes_per_sec: f64,
    /// Fixed per-message overhead (seconds) occupying the link.
    pub latency_secs: f64,
    /// Nominal compute cost of one map invocation (one subfile, all Q
    /// functions), seconds.
    pub secs_per_map: f64,
    /// Per-worker compute-speed multipliers (task time is divided by
    /// the worker's speed). Empty = homogeneous cluster (all `1.0`).
    pub speeds: Vec<f64>,
    /// Straggler distribution over map-task slowdown factors.
    pub straggler: StragglerModel,
    /// Seed for the straggler draws (perturbs *times* only — the ledger
    /// bytes are an input and are never touched).
    pub seed: u64,
}

impl SimConfig {
    /// The commodity-cluster preset: 1 Gb/s shared link, 1 ms map task,
    /// zero latency, homogeneous, no stragglers — the parameters of
    /// [`TimeModel::commodity`], of which this is the event-driven
    /// generalization.
    pub fn commodity() -> Self {
        let tm = TimeModel::commodity();
        SimConfig {
            link: LinkKind::Shared,
            link_bytes_per_sec: tm.link_bytes_per_sec,
            latency_secs: 0.0,
            secs_per_map: tm.secs_per_map,
            speeds: Vec::new(),
            straggler: StragglerModel::Deterministic,
            seed: 1,
        }
    }

    /// The closed-form model with this config's bandwidth and map cost
    /// (what the simulator degenerates to at zero latency, homogeneous
    /// speeds, and no stragglers).
    pub fn time_model(&self) -> TimeModel {
        TimeModel { link_bytes_per_sec: self.link_bytes_per_sec, secs_per_map: self.secs_per_map }
    }

    /// Validate all parameters.
    pub fn validate(&self) -> Result<()> {
        if !(self.link_bytes_per_sec.is_finite() && self.link_bytes_per_sec > 0.0) {
            return Err(CamrError::InvalidConfig(format!(
                "link_bytes_per_sec must be finite and > 0 (got {})",
                self.link_bytes_per_sec
            )));
        }
        if !(self.latency_secs.is_finite() && self.latency_secs >= 0.0) {
            return Err(CamrError::InvalidConfig(format!(
                "latency_secs must be finite and >= 0 (got {})",
                self.latency_secs
            )));
        }
        if !(self.secs_per_map.is_finite() && self.secs_per_map >= 0.0) {
            return Err(CamrError::InvalidConfig(format!(
                "secs_per_map must be finite and >= 0 (got {})",
                self.secs_per_map
            )));
        }
        for (w, &s) in self.speeds.iter().enumerate() {
            if !(s.is_finite() && s > 0.0) {
                return Err(CamrError::InvalidConfig(format!(
                    "speeds[{w}] must be finite and > 0 (got {s})"
                )));
            }
        }
        self.straggler.validate()
    }

    /// Parse the optional `[sim]` section of a run config. Returns
    /// `Ok(None)` when the section is absent; unknown keys error.
    pub fn from_cfg(c: &CfgText) -> Result<Option<SimConfig>> {
        if !c.section_names().iter().any(|s| s == "sim") {
            return Ok(None);
        }
        for key in c.keys("sim") {
            if !matches!(
                key.as_str(),
                "link"
                    | "link_bytes_per_sec"
                    | "latency_secs"
                    | "secs_per_map"
                    | "straggler"
                    | "straggler_rate"
                    | "tail_prob"
                    | "tail_factor"
                    | "seed"
                    | "speeds"
            ) {
                return Err(CamrError::InvalidConfig(format!("unknown [sim] key {key}")));
            }
        }
        let f = |k: &str| c.get_f64("sim", k).map_err(CamrError::InvalidConfig);
        let mut sc = SimConfig::commodity();
        if let Some(l) = c.get("sim", "link") {
            sc.link = LinkKind::parse(l)?;
        }
        if let Some(v) = f("link_bytes_per_sec")? {
            sc.link_bytes_per_sec = v;
        }
        if let Some(v) = f("latency_secs")? {
            sc.latency_secs = v;
        }
        if let Some(v) = f("secs_per_map")? {
            sc.secs_per_map = v;
        }
        if let Some(v) = c.get_u64("sim", "seed").map_err(CamrError::InvalidConfig)? {
            sc.seed = v;
        }
        let name = c.get("sim", "straggler").unwrap_or("none");
        // A straggler parameter for a model that does not use it is a
        // config mistake, not a default to fall back from — reject it
        // like the unknown-key validation above would.
        let has = |k: &str| c.get("sim", k).is_some();
        let stray = match name {
            "none" | "deterministic" => {
                has("straggler_rate") || has("tail_prob") || has("tail_factor")
            }
            "shifted_exp" => has("tail_prob") || has("tail_factor"),
            "tail" => has("straggler_rate"),
            _ => false, // unknown names error in parse() below
        };
        if stray {
            return Err(CamrError::InvalidConfig(format!(
                "[sim] straggler parameter does not apply to straggler = \"{name}\" \
                 (straggler_rate needs shifted_exp; tail_prob/tail_factor need tail)"
            )));
        }
        sc.straggler = StragglerModel::parse(
            name,
            f("straggler_rate")?.unwrap_or(5.0),
            f("tail_prob")?.unwrap_or(0.05),
            f("tail_factor")?.unwrap_or(10.0),
        )?;
        if let Some(s) = c.get("sim", "speeds") {
            sc.speeds = s
                .split(',')
                .map(|x| {
                    x.trim().parse::<f64>().map_err(|e| {
                        CamrError::InvalidConfig(format!("[sim] speeds entry {x}: {e}"))
                    })
                })
                .collect::<Result<_>>()?;
        }
        sc.validate()?;
        Ok(Some(sc))
    }

    /// One-line description for CLI output.
    pub fn describe(&self) -> String {
        format!(
            "link={} bw={} B/s latency={}s map={}s straggler={} seed={}",
            self.link.label(),
            self.link_bytes_per_sec,
            self.latency_secs,
            self.secs_per_map,
            self.straggler.label(),
            self.seed
        )
    }

    fn speed(&self, w: usize) -> f64 {
        if self.speeds.is_empty() {
            1.0
        } else {
            self.speeds[w]
        }
    }
}

/// Simulated time of one barrier-separated shuffle phase.
#[derive(Debug, Clone)]
pub struct PhaseTime {
    /// Which protocol stage this phase replays.
    pub stage: Stage,
    /// Transmissions in the phase (multicasts count once).
    pub transmissions: usize,
    /// Bytes on the link(s) in the phase.
    pub bytes: usize,
    /// Simulated phase duration, seconds.
    pub secs: f64,
}

/// Result of one simulation.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// Map-phase duration (barrier: the slowest worker), seconds.
    pub map_secs: f64,
    /// Per-phase shuffle times, in ledger order.
    pub phases: Vec<PhaseTime>,
    /// Total shuffle duration, seconds.
    pub shuffle_secs: f64,
    /// End-to-end completion time: map + shuffle.
    pub total_secs: f64,
    /// Total map tasks executed.
    pub map_tasks: usize,
    /// Total transmissions replayed.
    pub transmissions: usize,
    /// Total bytes replayed across all phases.
    pub shuffle_bytes: usize,
    /// Discrete events processed (map tasks + transmissions).
    pub events: u64,
}

impl SimOutcome {
    /// Summed simulated time of every phase with the given stage tag.
    pub fn stage_secs(&self, stage: Stage) -> f64 {
        self.phases.iter().filter(|p| p.stage == stage).map(|p| p.secs).sum()
    }

    /// Stable JSON rendering (keys sorted; bit-deterministic for a
    /// given config + seed — the determinism tests diff these strings).
    pub fn to_json(&self) -> Json {
        let phases: Vec<Json> = self
            .phases
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("stage", Json::Str(p.stage.to_string())),
                    ("transmissions", Json::UInt(p.transmissions as u128)),
                    ("bytes", Json::UInt(p.bytes as u128)),
                    ("secs", Json::Num(p.secs)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("map_secs", Json::Num(self.map_secs)),
            ("shuffle_secs", Json::Num(self.shuffle_secs)),
            ("total_secs", Json::Num(self.total_secs)),
            ("map_tasks", Json::UInt(self.map_tasks as u128)),
            ("transmissions", Json::UInt(self.transmissions as u128)),
            ("shuffle_bytes", Json::UInt(self.shuffle_bytes as u128)),
            ("events", Json::UInt(self.events as u128)),
            ("phases", Json::Arr(phases)),
        ])
    }
}

/// Per-worker map-invocation counts for a CAMR (or uncoded-baseline)
/// run under the Algorithm-1 placement: every stored batch is `γ`
/// subfile maps. The SPC design is symmetric, so the counts are equal
/// across workers — which is what lets the homogeneous degenerate case
/// match the closed form's `map_invocations / K` exactly.
pub fn camr_per_worker_maps(cfg: &SystemConfig, placement: &Placement) -> Vec<usize> {
    (0..cfg.servers()).map(|s| placement.inventory(s).len() * cfg.gamma).collect()
}

/// Per-worker map-invocation counts for the CCDC baseline at matched
/// `μ`: each server owns `C(K-1, k-1)` jobs and stores `k-1` batches of
/// `γ` subfiles per owned job.
pub fn ccdc_per_worker_maps(servers: usize, k: usize, gamma: usize) -> Vec<usize> {
    let per = binomial((servers - 1) as u64, (k - 1) as u64) as usize * (k - 1) * gamma;
    vec![per; servers]
}

/// Run the simulator: replay `ledger` on the cluster described by `sc`,
/// with `maps[w]` map tasks on worker `w` before the shuffle barrier.
///
/// The ledger is any [`crate::net::Bus::ledger`] — a live engine run,
/// the checked-in golden fixture, or a synthetic scenario. Its bytes
/// are never modified; the simulator only assigns times.
pub fn simulate(sc: &SimConfig, maps: &[usize], ledger: &[Transmission]) -> Result<SimOutcome> {
    sc.validate()?;
    let workers = maps.len();
    if workers == 0 {
        return Err(CamrError::InvalidConfig("simulate needs at least one worker".into()));
    }
    if !sc.speeds.is_empty() && sc.speeds.len() != workers {
        return Err(CamrError::InvalidConfig(format!(
            "speeds has {} entries for a {workers}-worker cluster",
            sc.speeds.len()
        )));
    }
    let (bw, lat) = (sc.link_bytes_per_sec, sc.latency_secs);
    let mut q = EventQueue::new();

    // ---- Map phase: workers in parallel, each its tasks in sequence.
    // Work is accumulated in straggler-weighted units (exact integers
    // in the no-straggler case) and multiplied out per readout, so the
    // degenerate case stays bit-exact against the closed form.
    let mut done = vec![0usize; workers];
    let mut work = vec![0.0f64; workers];
    let map_tasks: usize = maps.iter().sum();
    let mut remaining = map_tasks;
    for w in 0..workers {
        if maps[w] > 0 {
            work[w] += sc.straggler.factor(sc.seed, w, 0);
            q.schedule(work[w] * sc.secs_per_map / sc.speed(w), Event::MapTaskDone { worker: w });
        }
    }
    let mut map_secs = 0.0f64;
    while remaining > 0 {
        let (at, ev) = q.pop().expect("map events pending");
        let w = match ev {
            Event::MapTaskDone { worker } => worker,
            Event::TxDone { .. } => unreachable!("no transmissions before the map barrier"),
        };
        done[w] += 1;
        remaining -= 1;
        map_secs = at;
        if done[w] < maps[w] {
            work[w] += sc.straggler.factor(sc.seed, w, done[w]);
            q.schedule(work[w] * sc.secs_per_map / sc.speed(w), Event::MapTaskDone { worker: w });
        }
    }
    debug_assert!(q.is_empty(), "map events left after barrier");

    // ---- Shuffle: barrier-separated phases (contiguous same-stage
    // runs of the ledger), transmissions contending per link model.
    let shuffle_start = map_secs;
    let runs = stage_runs(ledger);
    let mut phases: Vec<PhaseTime> = Vec::with_capacity(runs.len());
    let mut shuffle_secs = 0.0f64;
    match sc.link {
        LinkKind::Shared => {
            // The link serializes everything, so phase barriers are
            // no-ops; one global chain, one global accumulator (single
            // rounding at each readout — and at the total).
            for (stage, range) in &runs {
                let mut acc = Acc::default();
                for t in &ledger[range.clone()] {
                    acc.add(t.bytes);
                }
                phases.push(PhaseTime {
                    stage: *stage,
                    transmissions: range.len(),
                    bytes: acc.bytes as usize,
                    secs: acc.secs(bw, lat),
                });
            }
            if !ledger.is_empty() {
                // Validate senders (bisection does this per phase).
                let _ = PhaseChains::build(LinkKind::Shared, ledger, workers)?;
            }
            let mut global = Acc::default();
            if !ledger.is_empty() {
                global.add(ledger[0].bytes);
                q.schedule(shuffle_start + global.secs(bw, lat), Event::TxDone { index: 0 });
            }
            let mut popped = 0usize;
            while let Some((_, ev)) = q.pop() {
                let index = match ev {
                    Event::TxDone { index } => index,
                    Event::MapTaskDone { .. } => unreachable!("map drained before shuffle"),
                };
                popped += 1;
                let next = index + 1;
                if next < ledger.len() {
                    global.add(ledger[next].bytes);
                    q.schedule(shuffle_start + global.secs(bw, lat), Event::TxDone { index: next });
                }
            }
            debug_assert_eq!(popped, ledger.len());
            shuffle_secs = global.secs(bw, lat);
        }
        LinkKind::Bisection => {
            let mut phase_start = shuffle_start;
            for (stage, range) in &runs {
                let slice = &ledger[range.clone()];
                let chains = PhaseChains::build(LinkKind::Bisection, slice, workers)?;
                let mut chain_of = vec![usize::MAX; slice.len()];
                for (c, ch) in chains.chains.iter().enumerate() {
                    for &p in ch {
                        chain_of[p] = c;
                    }
                }
                let mut accs = vec![Acc::default(); chains.chains.len()];
                let mut cursor = vec![0usize; chains.chains.len()];
                let mut dur = 0.0f64;
                for (c, ch) in chains.chains.iter().enumerate() {
                    accs[c].add(slice[ch[0]].bytes);
                    let t = accs[c].secs(bw, lat);
                    dur = dur.max(t);
                    q.schedule(phase_start + t, Event::TxDone { index: range.start + ch[0] });
                    cursor[c] = 1;
                }
                let mut popped = 0usize;
                while popped < slice.len() {
                    let (_, ev) = q.pop().expect("phase events pending");
                    let index = match ev {
                        Event::TxDone { index } => index,
                        Event::MapTaskDone { .. } => unreachable!(),
                    };
                    popped += 1;
                    let c = chain_of[index - range.start];
                    if cursor[c] < chains.chains[c].len() {
                        let p = chains.chains[c][cursor[c]];
                        cursor[c] += 1;
                        accs[c].add(slice[p].bytes);
                        let t = accs[c].secs(bw, lat);
                        dur = dur.max(t);
                        q.schedule(phase_start + t, Event::TxDone { index: range.start + p });
                    }
                }
                let bytes: usize = slice.iter().map(|t| t.bytes).sum();
                phases.push(PhaseTime {
                    stage: *stage,
                    transmissions: slice.len(),
                    bytes,
                    secs: dur,
                });
                phase_start += dur;
                shuffle_secs += dur;
            }
        }
    }

    let shuffle_bytes: usize = ledger.iter().map(|t| t.bytes).sum();
    Ok(SimOutcome {
        map_secs,
        phases,
        shuffle_secs,
        total_secs: map_secs + shuffle_secs,
        map_tasks,
        transmissions: ledger.len(),
        shuffle_bytes,
        events: q.processed(),
    })
}

/// Simulated times of one job of a batch (see [`simulate_batch`]).
#[derive(Debug, Clone)]
pub struct BatchJobTime {
    /// The job's tag in the aggregate ledger.
    pub job: usize,
    /// Map-phase duration (barrier: slowest worker), seconds.
    pub map_secs: f64,
    /// Shuffle duration of this job's ledger slice, seconds.
    pub shuffle_secs: f64,
    /// Bytes this job put on the link.
    pub bytes: usize,
    /// Transmissions in this job's ledger slice.
    pub transmissions: usize,
}

/// Result of replaying a multi-job aggregate ledger (see
/// [`simulate_batch`]).
#[derive(Debug, Clone)]
pub struct BatchSimOutcome {
    /// Per-job simulated times, in job order.
    pub jobs: Vec<BatchJobTime>,
    /// Barriered makespan: every job fully finishes (map + shuffle)
    /// before the next one starts — `Σ (mapᵢ + shuffleᵢ)`.
    pub serial_secs: f64,
    /// Pipelined makespan: job `i+1` maps (compute resource) while job
    /// `i` shuffles (link resource). Two-stage pipeline recurrence:
    /// `map_endᵢ = map_endᵢ₋₁ + mapᵢ`,
    /// `shuffle_endᵢ = max(map_endᵢ, shuffle_endᵢ₋₁) + shuffleᵢ`.
    pub pipelined_secs: f64,
    /// Total map time across jobs (the compute chain's length).
    pub map_secs_total: f64,
    /// Total shuffle time across jobs (the link chain's length).
    pub shuffle_secs_total: f64,
    /// Total bytes across all jobs.
    pub bytes_total: usize,
}

impl BatchSimOutcome {
    /// Wall-clock saved by pipelining over the barriered schedule.
    pub fn saved_secs(&self) -> f64 {
        self.serial_secs - self.pipelined_secs
    }

    /// Stable JSON rendering (keys sorted; bit-deterministic for a
    /// given config + seed).
    pub fn to_json(&self) -> Json {
        let jobs: Vec<Json> = self
            .jobs
            .iter()
            .map(|j| {
                Json::obj(vec![
                    ("job", Json::UInt(j.job as u128)),
                    ("map_secs", Json::Num(j.map_secs)),
                    ("shuffle_secs", Json::Num(j.shuffle_secs)),
                    ("bytes", Json::UInt(j.bytes as u128)),
                    ("transmissions", Json::UInt(j.transmissions as u128)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("serial_secs", Json::Num(self.serial_secs)),
            ("pipelined_secs", Json::Num(self.pipelined_secs)),
            ("saved_secs", Json::Num(self.saved_secs())),
            ("map_secs_total", Json::Num(self.map_secs_total)),
            ("shuffle_secs_total", Json::Num(self.shuffle_secs_total)),
            ("bytes_total", Json::UInt(self.bytes_total as u128)),
            ("jobs", Json::Arr(jobs)),
        ])
    }
}

/// Replay a job-tagged aggregate ledger (see [`crate::net::Bus::append_ledger`])
/// as a batch of `maps.len()` jobs, where `maps[j]` holds job `j`'s
/// per-worker map-task counts, and report both the barriered and the
/// pipelined makespan.
///
/// Job `j`'s transmissions are the ledger entries tagged `job == j`
/// (they must be contiguous and in job order; a job may have none, e.g.
/// a failed round contributes only its tag gap). Each job's straggler
/// draws use a per-job seed derived from `sc.seed` via
/// [`crate::util::rng::mix_key`], so repeated jobs of one batch see
/// fresh (but fully deterministic) randomness.
pub fn simulate_batch(
    sc: &SimConfig,
    maps: &[Vec<usize>],
    ledger: &[Transmission],
) -> Result<BatchSimOutcome> {
    if maps.is_empty() {
        return Err(CamrError::InvalidConfig("simulate_batch needs at least one job".into()));
    }
    // Split the ledger into per-job contiguous slices.
    let mut slices: Vec<std::ops::Range<usize>> = vec![0..0; maps.len()];
    let mut seen: Vec<bool> = vec![false; maps.len()];
    let mut i = 0usize;
    while i < ledger.len() {
        let job = ledger[i].job;
        if job >= maps.len() {
            return Err(CamrError::InvalidConfig(format!(
                "ledger job tag {job} out of range for a {}-job batch",
                maps.len()
            )));
        }
        if seen[job] {
            return Err(CamrError::InvalidConfig(format!(
                "ledger entries for job {job} are not contiguous"
            )));
        }
        seen[job] = true;
        let start = i;
        while i < ledger.len() && ledger[i].job == job {
            i += 1;
        }
        slices[job] = start..i;
    }

    let mut jobs: Vec<BatchJobTime> = Vec::with_capacity(maps.len());
    let mut serial = 0.0f64;
    let mut map_end = 0.0f64;
    let mut shuffle_end = 0.0f64;
    let mut map_total = 0.0f64;
    let mut shuffle_total = 0.0f64;
    let mut bytes_total = 0usize;
    for (j, jmaps) in maps.iter().enumerate() {
        let mut scj = sc.clone();
        scj.seed = crate::util::rng::mix_key(sc.seed, &[j as u64]);
        let slice = &ledger[slices[j].clone()];
        let out = simulate(&scj, jmaps, slice)?;
        serial += out.map_secs + out.shuffle_secs;
        map_end += out.map_secs;
        shuffle_end = map_end.max(shuffle_end) + out.shuffle_secs;
        map_total += out.map_secs;
        shuffle_total += out.shuffle_secs;
        bytes_total += out.shuffle_bytes;
        jobs.push(BatchJobTime {
            job: j,
            map_secs: out.map_secs,
            shuffle_secs: out.shuffle_secs,
            bytes: out.shuffle_bytes,
            transmissions: slice.len(),
        });
    }
    Ok(BatchSimOutcome {
        jobs,
        serial_secs: serial,
        // The batch ends when both chains drain: the link after the last
        // shuffle, the compute fabric after the last map (a trailing
        // shuffle-free job can leave map_end ahead of shuffle_end).
        pipelined_secs: shuffle_end.max(map_end),
        map_secs_total: map_total,
        shuffle_secs_total: shuffle_total,
        bytes_total,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tx(stage: Stage, sender: usize, bytes: usize) -> Transmission {
        Transmission { stage, sender, recipients: vec![], bytes, job: 0 }
    }

    fn degenerate(bw: f64, spm: f64) -> SimConfig {
        SimConfig {
            link: LinkKind::Shared,
            link_bytes_per_sec: bw,
            latency_secs: 0.0,
            secs_per_map: spm,
            speeds: Vec::new(),
            straggler: StragglerModel::Deterministic,
            seed: 0,
        }
    }

    #[test]
    fn degenerate_case_is_bit_exact_against_closed_form() {
        let sc = degenerate(125e6, 1e-3);
        let maps = [8usize, 8, 8, 8, 8, 8];
        let ledger: Vec<Transmission> =
            (0..36).map(|i| tx(Stage::Stage1, i % 6, 64)).collect();
        let out = simulate(&sc, &maps, &ledger).unwrap();
        let tm = sc.time_model();
        let (m, s) = tm.phase_times(6, 48, (36 * 64) as f64);
        assert_eq!(out.map_secs, m, "map time drifted from the closed form");
        assert_eq!(out.shuffle_secs, s, "shuffle time drifted from the closed form");
        assert_eq!(out.total_secs, m + s);
        assert_eq!(out.events, 48 + 36);
    }

    #[test]
    fn multicast_is_charged_once() {
        let sc = degenerate(1e3, 0.0);
        let wide = [Transmission {
            stage: Stage::Stage1,
            sender: 0,
            recipients: vec![1, 2, 3, 4, 5],
            bytes: 100,
            job: 0,
        }];
        let narrow = [tx(Stage::Stage1, 0, 100)];
        let a = simulate(&sc, &[0, 0, 0, 0, 0, 0], &wide).unwrap();
        let b = simulate(&sc, &[0, 0, 0, 0, 0, 0], &narrow).unwrap();
        assert_eq!(a.total_secs, b.total_secs);
        assert_eq!(a.shuffle_secs, 100.0 / 1e3);
    }

    #[test]
    fn bisection_parallelizes_across_senders_but_not_within() {
        let mut sc = degenerate(1e3, 0.0);
        // Two senders, 100 B each: shared serializes (0.2 s), bisection
        // overlaps (0.1 s).
        let ledger = [tx(Stage::Stage1, 0, 100), tx(Stage::Stage1, 1, 100)];
        let shared = simulate(&sc, &[0, 0], &ledger).unwrap();
        sc.link = LinkKind::Bisection;
        let bis = simulate(&sc, &[0, 0], &ledger).unwrap();
        assert_eq!(shared.shuffle_secs, 0.2);
        assert_eq!(bis.shuffle_secs, 0.1);
        // Same sender twice: no overlap on its NIC under either model.
        let ledger2 = [tx(Stage::Stage1, 0, 100), tx(Stage::Stage1, 0, 100)];
        let bis2 = simulate(&sc, &[0, 0], &ledger2).unwrap();
        assert_eq!(bis2.shuffle_secs, 0.2);
    }

    #[test]
    fn stage_barriers_hold_on_bisection() {
        let mut sc = degenerate(1e3, 0.0);
        sc.link = LinkKind::Bisection;
        // Different stages → a barrier between the phases even though
        // the senders differ; one stage → full overlap.
        let two_phases = [tx(Stage::Stage1, 0, 100), tx(Stage::Stage2, 1, 100)];
        let one_phase = [tx(Stage::Stage1, 0, 100), tx(Stage::Stage1, 1, 100)];
        let a = simulate(&sc, &[0, 0], &two_phases).unwrap();
        let b = simulate(&sc, &[0, 0], &one_phase).unwrap();
        assert_eq!(a.shuffle_secs, 0.2);
        assert_eq!(a.phases.len(), 2);
        assert_eq!(b.shuffle_secs, 0.1);
        assert_eq!(b.phases.len(), 1);
    }

    #[test]
    fn latency_charges_per_message() {
        let mut sc = degenerate(1e3, 0.0);
        sc.latency_secs = 0.5;
        let ledger = [tx(Stage::Stage1, 0, 100), tx(Stage::Stage1, 1, 100)];
        let out = simulate(&sc, &[0, 0], &ledger).unwrap();
        assert_eq!(out.shuffle_secs, 2.0 * 0.5 + 200.0 / 1e3);
    }

    #[test]
    fn stragglers_stretch_the_map_phase_deterministically() {
        let mut sc = degenerate(1e6, 1e-3);
        let maps = [8usize, 8, 8, 8];
        let base = simulate(&sc, &maps, &[]).unwrap();
        sc.straggler = StragglerModel::ShiftedExp { rate: 2.0 };
        let a = simulate(&sc, &maps, &[]).unwrap();
        let b = simulate(&sc, &maps, &[]).unwrap();
        assert!(a.map_secs > base.map_secs, "stragglers must slow the map barrier");
        assert_eq!(a.map_secs.to_bits(), b.map_secs.to_bits(), "same seed must be bit-equal");
        sc.seed = 99;
        let c = simulate(&sc, &maps, &[]).unwrap();
        assert_ne!(a.map_secs, c.map_secs, "different seed must perturb times");
    }

    #[test]
    fn heterogeneous_speeds_divide_task_time() {
        let mut sc = degenerate(1e6, 1.0);
        sc.speeds = vec![1.0, 2.0];
        let out = simulate(&sc, &[4, 4], &[]).unwrap();
        // Worker 0: 4 tasks at 1 s; worker 1: 4 tasks at 0.5 s.
        assert_eq!(out.map_secs, 4.0);
    }

    #[test]
    fn empty_inputs_are_zero_time() {
        let sc = degenerate(1e6, 1e-3);
        let out = simulate(&sc, &[0, 0], &[]).unwrap();
        assert_eq!(out.total_secs, 0.0);
        assert_eq!(out.events, 0);
        assert!(out.phases.is_empty());
    }

    #[test]
    fn rejects_bad_inputs() {
        let sc = degenerate(1e6, 1e-3);
        assert!(simulate(&sc, &[], &[]).is_err(), "no workers");
        let mut bad = sc.clone();
        bad.speeds = vec![1.0];
        assert!(simulate(&bad, &[1, 1], &[]).is_err(), "speeds arity");
        let ledger = [tx(Stage::Stage1, 9, 10)];
        assert!(simulate(&sc, &[1, 1], &ledger).is_err(), "sender out of range");
        let mut bad = sc.clone();
        bad.link_bytes_per_sec = 0.0;
        assert!(simulate(&bad, &[1], &[]).is_err(), "zero bandwidth");
    }

    #[test]
    fn json_report_is_deterministic() {
        let mut sc = degenerate(1e6, 1e-3);
        sc.straggler = StragglerModel::Tail { prob: 0.2, factor: 4.0 };
        let maps = [5usize, 5, 5];
        let ledger = [tx(Stage::Stage1, 0, 64), tx(Stage::Stage3, 1, 128)];
        let a = simulate(&sc, &maps, &ledger).unwrap().to_json().render();
        let b = simulate(&sc, &maps, &ledger).unwrap().to_json().render();
        assert_eq!(a, b);
        assert!(a.contains("\"stage\":\"stage1\""));
        assert!(a.contains("\"shuffle_bytes\":192"));
    }

    #[test]
    fn config_parsing_round_trip() {
        let text = r#"
            [sim]
            link = "bisection"
            link_bytes_per_sec = 1.25e7
            latency_secs = 0.0001
            secs_per_map = 0.002
            straggler = "shifted_exp"
            straggler_rate = 4.0
            seed = 9
            speeds = "1.0, 2.0, 1.5"
        "#;
        let c = CfgText::parse(text).unwrap();
        let sc = SimConfig::from_cfg(&c).unwrap().unwrap();
        assert_eq!(sc.link, LinkKind::Bisection);
        assert_eq!(sc.link_bytes_per_sec, 1.25e7);
        assert_eq!(sc.straggler, StragglerModel::ShiftedExp { rate: 4.0 });
        assert_eq!(sc.speeds, vec![1.0, 2.0, 1.5]);
        assert_eq!(sc.seed, 9);
        // Absent section → None; unknown key → error.
        assert!(SimConfig::from_cfg(&CfgText::parse("[system]\nk = 3").unwrap())
            .unwrap()
            .is_none());
        assert!(SimConfig::from_cfg(&CfgText::parse("[sim]\nbogus = 1").unwrap()).is_err());
        assert!(
            SimConfig::from_cfg(&CfgText::parse("[sim]\nstraggler = \"warp\"").unwrap()).is_err()
        );
        // Straggler parameters without a model that uses them are
        // rejected, not silently dropped.
        assert!(
            SimConfig::from_cfg(&CfgText::parse("[sim]\nstraggler_rate = 10.0").unwrap()).is_err()
        );
        let tail_on_exp = "[sim]\nstraggler = \"shifted_exp\"\ntail_prob = 0.1";
        assert!(SimConfig::from_cfg(&CfgText::parse(tail_on_exp).unwrap()).is_err());
        let rate_on_tail = "[sim]\nstraggler = \"tail\"\nstraggler_rate = 2.0";
        assert!(SimConfig::from_cfg(&CfgText::parse(rate_on_tail).unwrap()).is_err());
    }

    fn jtx(stage: Stage, sender: usize, bytes: usize, job: usize) -> Transmission {
        Transmission { stage, sender, recipients: vec![], bytes, job }
    }

    #[test]
    fn batch_pipeline_overlaps_map_with_previous_shuffle() {
        // Two identical jobs: 1 s map, 1 s shuffle each. Barriered: 4 s.
        // Pipelined: job 1 maps during job 0's shuffle → 3 s.
        let sc = degenerate(1e3, 1.0);
        let maps = vec![vec![1usize], vec![1usize]];
        let ledger =
            [jtx(Stage::Stage1, 0, 1000, 0), jtx(Stage::Stage1, 0, 1000, 1)];
        let out = simulate_batch(&sc, &maps, &ledger).unwrap();
        assert_eq!(out.serial_secs, 4.0);
        assert_eq!(out.pipelined_secs, 3.0);
        assert_eq!(out.saved_secs(), 1.0);
        assert_eq!(out.jobs.len(), 2);
        assert_eq!(out.bytes_total, 2000);
        assert_eq!(out.map_secs_total, 2.0);
        assert_eq!(out.shuffle_secs_total, 2.0);
    }

    #[test]
    fn batch_pipelined_never_beats_resource_chains_and_never_loses_to_serial() {
        let mut sc = degenerate(1e4, 2e-3);
        sc.straggler = StragglerModel::ShiftedExp { rate: 3.0 };
        let maps: Vec<Vec<usize>> = (0..5).map(|_| vec![4usize, 4, 4]).collect();
        let ledger: Vec<Transmission> = (0..5)
            .flat_map(|j| {
                (0..6).map(move |i| jtx(Stage::Stage1, i % 3, 128 * (j + 1), j))
            })
            .collect();
        let out = simulate_batch(&sc, &maps, &ledger).unwrap();
        assert!(out.pipelined_secs <= out.serial_secs + 1e-12);
        assert!(out.pipelined_secs + 1e-12 >= out.map_secs_total);
        assert!(out.pipelined_secs + 1e-12 >= out.shuffle_secs_total);
        // Per-job seeds differ, so equal map layouts still draw fresh
        // straggler factors per job.
        assert_ne!(out.jobs[0].map_secs, out.jobs[1].map_secs);
        // Deterministic: same inputs, byte-identical JSON.
        let again = simulate_batch(&sc, &maps, &ledger).unwrap();
        assert_eq!(out.to_json().render(), again.to_json().render());
    }

    #[test]
    fn batch_tolerates_traffic_free_jobs_and_rejects_bad_tags() {
        let sc = degenerate(1e3, 1.0);
        // Job 0 failed before its shuffle: no tagged entries for it.
        let maps = vec![vec![1usize], vec![1usize]];
        let ledger = [jtx(Stage::Stage1, 0, 500, 1)];
        let out = simulate_batch(&sc, &maps, &ledger).unwrap();
        assert_eq!(out.jobs[0].bytes, 0);
        assert_eq!(out.jobs[0].transmissions, 0);
        assert_eq!(out.jobs[1].bytes, 500);
        // A trailing map-only job keeps the compute chain in the makespan.
        let tail = [jtx(Stage::Stage1, 0, 500, 0)];
        let t = simulate_batch(&sc, &maps, &tail).unwrap();
        assert_eq!(t.pipelined_secs, 2.0); // two 1 s maps back to back
        // Out-of-range and non-contiguous tags are rejected.
        let bad = [jtx(Stage::Stage1, 0, 1, 9)];
        assert!(simulate_batch(&sc, &maps, &bad).is_err());
        let split = [
            jtx(Stage::Stage1, 0, 1, 0),
            jtx(Stage::Stage1, 0, 1, 1),
            jtx(Stage::Stage1, 0, 1, 0),
        ];
        assert!(simulate_batch(&sc, &maps, &split).is_err());
        assert!(simulate_batch(&sc, &[], &[]).is_err());
    }

    #[test]
    fn ccdc_map_counts_match_combinatorics() {
        // K=6, k=3, γ=2: each server owns C(5,2)=10 jobs × 2 batches ×
        // 2 subfiles = 40 maps.
        assert_eq!(ccdc_per_worker_maps(6, 3, 2), vec![40; 6]);
    }
}
