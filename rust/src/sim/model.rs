//! Closed-form job-completion-time model — connects communication load
//! back to the paper's motivation ("on average, 33% of the overall job
//! execution time is spent on data shuffling", §I).
//!
//! Given a link bandwidth and a per-map-invocation compute cost, the
//! model converts measured byte counts and map counts into phase times
//! and end-to-end speedups of CAMR over the uncoded baselines. Map work
//! runs K-way parallel; the shared link serializes the shuffle (the
//! paper's single-shared-link model).
//!
//! This is the **degenerate case** of the discrete-event simulator in
//! [`crate::sim`]: zero per-message latency, homogeneous workers, no
//! stragglers, shared link. The identity is *exact* — the simulator
//! reproduces [`TimeModel::phase_times`] to the bit in that regime
//! (asserted by `rust/tests/sim_times.rs`) — so the closed form and the
//! simulator can never silently diverge. Historically this lived in
//! `analysis::time_model`, which now re-exports it.

use crate::analysis::load;

/// Cluster timing parameters.
#[derive(Debug, Clone, Copy)]
pub struct TimeModel {
    /// Shared-link bandwidth in bytes/second.
    pub link_bytes_per_sec: f64,
    /// Compute cost of mapping one subfile for all Q functions, seconds.
    pub secs_per_map: f64,
}

impl TimeModel {
    /// A 1 Gb/s Ethernet-class link (the paper's commodity-cluster
    /// setting) with a 1 ms map task.
    pub fn commodity() -> Self {
        TimeModel { link_bytes_per_sec: 125e6, secs_per_map: 1e-3 }
    }

    /// Simulated phase times for a run: `(map_secs, shuffle_secs)`.
    ///
    /// `map_invocations` spread over `servers` parallel workers;
    /// `shuffle_bytes` serialized on the shared link.
    pub fn phase_times(
        &self,
        servers: usize,
        map_invocations: usize,
        shuffle_bytes: f64,
    ) -> (f64, f64) {
        let map = map_invocations as f64 / servers as f64 * self.secs_per_map;
        let shuffle = shuffle_bytes / self.link_bytes_per_sec;
        (map, shuffle)
    }

    /// Simulated job time = parallel map + serialized shuffle.
    pub fn job_time(&self, servers: usize, map_invocations: usize, shuffle_bytes: f64) -> f64 {
        let (m, s) = self.phase_times(servers, map_invocations, shuffle_bytes);
        m + s
    }

    /// Analytic job-time comparison of CAMR vs the uncoded-aggregated
    /// baseline at the same placement (identical map work — both schemes
    /// map each subfile k-1 times — so the entire difference is the
    /// shuffle). Returns `(t_camr, t_uncoded, speedup)` for a job set
    /// with the given per-value size.
    pub fn camr_vs_uncoded(
        &self,
        k: usize,
        q: usize,
        gamma: usize,
        value_bytes: usize,
    ) -> (f64, f64, f64) {
        let servers = k * q;
        let jobs = q.pow(k as u32 - 1);
        let subfiles = k * gamma;
        let normalizer = (jobs * servers * value_bytes) as f64; // J·Q·B, Q = K
        let maps = (k - 1) * jobs * subfiles;
        let camr_bytes = load::camr_total(k, q) * normalizer;
        let unc_bytes = load::uncoded_aggregated_total(k, q) * normalizer;
        let t_camr = self.job_time(servers, maps, camr_bytes);
        let t_unc = self.job_time(servers, maps, unc_bytes);
        (t_camr, t_unc, t_unc / t_camr)
    }

    /// The shuffle's share of total job time (the paper's "33%"-style
    /// statistic) for a given scheme load.
    pub fn shuffle_fraction(
        &self,
        k: usize,
        q: usize,
        gamma: usize,
        value_bytes: usize,
        scheme_load: f64,
    ) -> f64 {
        let servers = k * q;
        let jobs = q.pow(k as u32 - 1);
        let subfiles = k * gamma;
        let normalizer = (jobs * servers * value_bytes) as f64;
        let maps = (k - 1) * jobs * subfiles;
        let bytes = scheme_load * normalizer;
        let (m, s) = self.phase_times(servers, maps, bytes);
        s / (m + s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_times_scale_linearly() {
        let tm = TimeModel { link_bytes_per_sec: 1e6, secs_per_map: 1e-3 };
        let (m, s) = tm.phase_times(10, 100, 2e6);
        assert!((m - 0.01).abs() < 1e-12); // 100 maps / 10 workers × 1ms
        assert!((s - 2.0).abs() < 1e-12); // 2 MB / 1 MB/s
    }

    #[test]
    fn camr_speedup_over_uncoded_matches_load_ratio_when_shuffle_bound() {
        // With a slow link (shuffle-dominated), the job-time speedup
        // approaches the load ratio (2 - k/K) / L_CAMR.
        let tm = TimeModel { link_bytes_per_sec: 1e3, secs_per_map: 1e-9 };
        let (tc, tu, speedup) = tm.camr_vs_uncoded(3, 3, 2, 1 << 20);
        assert!(tc < tu);
        let load_ratio = load::uncoded_aggregated_total(3, 3) / load::camr_total(3, 3);
        assert!((speedup - load_ratio).abs() < 1e-6, "{speedup} vs {load_ratio}");
    }

    #[test]
    fn compute_bound_cluster_sees_no_speedup() {
        // A very fast link makes both schemes map-bound: speedup → 1.
        let tm = TimeModel { link_bytes_per_sec: 1e15, secs_per_map: 1e-3 };
        let (_, _, speedup) = tm.camr_vs_uncoded(3, 3, 2, 64);
        assert!((speedup - 1.0).abs() < 1e-6);
    }

    #[test]
    fn shuffle_fraction_is_a_fraction() {
        let tm = TimeModel::commodity();
        let f = tm.shuffle_fraction(3, 2, 2, 1 << 16, load::camr_total(3, 2));
        assert!(f > 0.0 && f < 1.0);
        // Coding must lower the shuffle share relative to uncoded.
        let fu = tm.shuffle_fraction(3, 2, 2, 1 << 16, load::uncoded_aggregated_total(3, 2));
        assert!(f < fu);
    }
}
