//! Discrete-event queue: a binary heap of scheduled events plus a
//! virtual clock.
//!
//! Determinism is load-bearing here (the determinism tests diff whole
//! JSON reports byte-for-byte), so ties are broken by insertion
//! sequence number: two events at the same virtual time pop in the
//! order they were scheduled, on every platform, every run. Times are
//! ordered with [`f64::total_cmp`]; the queue never stores NaN (guarded
//! by a debug assertion at schedule time).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Something that happens at a point in virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// One map task finished on `worker` (the worker's next task, if
    /// any, starts immediately).
    MapTaskDone {
        /// Which worker finished a task.
        worker: usize,
    },
    /// Transmission `index` (its position in the replayed ledger) left
    /// the link; the next transmission in its chain may start.
    TxDone {
        /// Ledger position of the completed transmission.
        index: usize,
    },
}

/// Heap entry. `Ord` is *reversed* on time so that
/// [`BinaryHeap`] (a max-heap) pops the earliest event first, with FIFO
/// order on exact ties via `seq`.
#[derive(Debug, Clone, Copy)]
struct Scheduled {
    at: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: smaller time (then smaller seq) compares greater.
        other.at.total_cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

/// The event queue + virtual clock of one simulation.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
    now: f64,
    processed: u64,
}

impl EventQueue {
    /// Empty queue at virtual time 0.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0, processed: 0 }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of events still pending.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at virtual time `at` (must be finite and not in
    /// the past).
    pub fn schedule(&mut self, at: f64, event: Event) {
        debug_assert!(at.is_finite(), "non-finite event time {at}");
        debug_assert!(at >= self.now, "event at {at} scheduled before now = {}", self.now);
        self.heap.push(Scheduled { at, seq: self.seq, event });
        self.seq += 1;
    }

    /// Pop the earliest pending event, advancing the clock to its time.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        let s = self.heap.pop()?;
        debug_assert!(s.at >= self.now, "clock would run backwards");
        self.now = s.at;
        self.processed += 1;
        Some((s.at, s.event))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, Event::MapTaskDone { worker: 3 });
        q.schedule(1.0, Event::MapTaskDone { worker: 1 });
        q.schedule(2.0, Event::MapTaskDone { worker: 2 });
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| match e {
                Event::MapTaskDone { worker } => worker,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 3]);
        assert_eq!(q.processed(), 3);
        assert!((q.now() - 3.0).abs() < 1e-15);
    }

    #[test]
    fn ties_break_by_schedule_order() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(1.0, Event::TxDone { index: i });
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| match e {
                Event::TxDone { index } => index,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(0.5, Event::TxDone { index: 0 });
        q.schedule(0.5, Event::TxDone { index: 1 });
        q.schedule(0.75, Event::TxDone { index: 2 });
        let mut last = 0.0;
        while let Some((at, _)) = q.pop() {
            assert!(at >= last);
            last = at;
            // Events may schedule follow-ups at the current time.
            if q.len() == 1 {
                q.schedule(last, Event::TxDone { index: 9 });
            }
        }
        assert!(q.is_empty());
        assert_eq!(q.processed(), 4);
    }
}
