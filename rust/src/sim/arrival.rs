//! Seeded **open-arrival traffic**: Poisson arrival traces and an
//! FCFS multi-engine replay model.
//!
//! The continuous job service ([`crate::service`]) executes an open
//! stream of jobs against wall clocks; this module produces the same
//! stream for the simulator. [`poisson_trace`] draws a deterministic
//! arrival trace — exponential interarrivals at a configured rate, a
//! uniformly-mixed tenant tag per arrival — from the crate's counter
//! RNG, so the *same seed yields bit-identical arrivals* in the driver
//! (which paces real submissions by it) and in
//! [`simulate_open_arrivals`] (which replays it against a c-server FCFS
//! model). That shared trace is what makes the `camr serve` sim-vs-real
//! throughput/latency comparison apples-to-apples: both sides see the
//! exact same offered load, and only the service-time model differs.

use crate::error::{CamrError, Result};
use crate::util::rng::mix_key;

/// One arrival of the trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Arrival time, seconds since the trace epoch.
    pub at_secs: f64,
    /// Tenant the job bills to.
    pub tenant: usize,
}

/// Parameters of a Poisson arrival trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalConfig {
    /// Mean arrival rate λ, jobs per second.
    pub rate_per_sec: f64,
    /// Number of arrivals to draw.
    pub jobs: usize,
    /// Tenant tags are drawn uniformly from `0..tenants`.
    pub tenants: usize,
    /// Seed addressing every draw (same seed ⇒ identical trace).
    pub seed: u64,
}

impl ArrivalConfig {
    /// Reject degenerate parameters.
    pub fn validate(&self) -> Result<()> {
        if !(self.rate_per_sec.is_finite() && self.rate_per_sec > 0.0) {
            return Err(CamrError::InvalidConfig("arrival rate must be > 0".into()));
        }
        if self.jobs == 0 {
            return Err(CamrError::InvalidConfig("arrival trace needs >= 1 job".into()));
        }
        if self.tenants == 0 {
            return Err(CamrError::InvalidConfig("arrival trace needs >= 1 tenant".into()));
        }
        Ok(())
    }
}

/// A uniform draw in the open interval (0, 1) addressed by
/// `(seed, parts)` — the straggler module's ln-safe idiom.
fn uniform_open(seed: u64, parts: &[u64]) -> f64 {
    let r = mix_key(seed, parts);
    ((r >> 11) as f64 + 0.5) / (1u64 << 53) as f64
}

/// Draw a deterministic Poisson arrival trace: interarrival `i` is
/// `-ln(u_i)/λ` with `u_i` addressed by `(seed, i)`, and the tenant tag
/// by an independent draw at the same index. Arrival times are strictly
/// increasing (the open-interval uniform never yields a zero gap).
pub fn poisson_trace(cfg: &ArrivalConfig) -> Result<Vec<Arrival>> {
    cfg.validate()?;
    let mut at = 0.0f64;
    Ok((0..cfg.jobs)
        .map(|i| {
            at += -uniform_open(cfg.seed, &[i as u64, 0]).ln() / cfg.rate_per_sec;
            let tenant = (mix_key(cfg.seed, &[i as u64, 1]) % cfg.tenants as u64) as usize;
            Arrival { at_secs: at, tenant }
        })
        .collect())
}

/// What the FCFS replay of a trace predicts.
#[derive(Debug, Clone, PartialEq)]
pub struct OpenArrivalOutcome {
    /// Jobs completed (always the full trace — the model never drops).
    pub completed: usize,
    /// First arrival to last completion, seconds.
    pub makespan_secs: f64,
    /// `completed / makespan`, jobs per second.
    pub throughput: f64,
    /// Median sojourn (arrival → completion), seconds.
    pub sojourn_p50_secs: f64,
    /// 99th-percentile sojourn, seconds.
    pub sojourn_p99_secs: f64,
    /// Mean sojourn, seconds.
    pub sojourn_mean_secs: f64,
    /// Completed jobs per tenant tag.
    pub per_tenant_completed: Vec<u64>,
}

/// Replay `trace` against `engines` identical servers under FCFS in
/// arrival order, each job costing `secs_per_job`: a job starts at
/// `max(arrival, earliest engine free time)`. This is the simulated
/// counterpart of the service's dispatcher pool — feed it the measured
/// mean round time and compare throughput and sojourn against the real
/// run on the *same* trace.
pub fn simulate_open_arrivals(
    trace: &[Arrival],
    secs_per_job: f64,
    engines: usize,
    tenants: usize,
) -> Result<OpenArrivalOutcome> {
    if trace.is_empty() {
        return Err(CamrError::InvalidConfig("open-arrival replay needs >= 1 job".into()));
    }
    if !(secs_per_job.is_finite() && secs_per_job >= 0.0) {
        return Err(CamrError::InvalidConfig("secs per job must be >= 0".into()));
    }
    if engines == 0 {
        return Err(CamrError::InvalidConfig("open-arrival replay needs >= 1 engine".into()));
    }
    let mut free = vec![0.0f64; engines];
    let mut per_tenant = vec![0u64; tenants];
    let mut sojourns: Vec<f64> = Vec::with_capacity(trace.len());
    let mut last_done = 0.0f64;
    for a in trace {
        // Earliest-free engine; ties go to the lowest index, so the
        // replay is deterministic regardless of float equality quirks.
        let (idx, _) = free
            .iter()
            .enumerate()
            .min_by(|(_, x), (_, y)| x.partial_cmp(y).expect("finite free times"))
            .expect("engines >= 1");
        let start = free[idx].max(a.at_secs);
        let done = start + secs_per_job;
        free[idx] = done;
        last_done = last_done.max(done);
        sojourns.push(done - a.at_secs);
        if let Some(n) = per_tenant.get_mut(a.tenant) {
            *n += 1;
        }
    }
    sojourns.sort_by(|x, y| x.partial_cmp(y).expect("finite sojourns"));
    let pct = |q: f64| -> f64 {
        let idx = ((sojourns.len() - 1) as f64 * q).round() as usize;
        sojourns[idx.min(sojourns.len() - 1)]
    };
    let makespan = last_done - trace[0].at_secs;
    Ok(OpenArrivalOutcome {
        completed: trace.len(),
        makespan_secs: makespan,
        throughput: trace.len() as f64 / makespan.max(1e-12),
        sojourn_p50_secs: pct(0.50),
        sojourn_p99_secs: pct(0.99),
        sojourn_mean_secs: sojourns.iter().sum::<f64>() / sojourns.len() as f64,
        per_tenant_completed: per_tenant,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(seed: u64) -> ArrivalConfig {
        ArrivalConfig { rate_per_sec: 100.0, jobs: 2000, tenants: 4, seed }
    }

    #[test]
    fn degenerate_parameters_are_rejected() {
        assert!(ArrivalConfig { rate_per_sec: 0.0, ..cfg(1) }.validate().is_err());
        assert!(ArrivalConfig { jobs: 0, ..cfg(1) }.validate().is_err());
        assert!(ArrivalConfig { tenants: 0, ..cfg(1) }.validate().is_err());
        assert!(simulate_open_arrivals(&[], 1.0, 1, 1).is_err());
        let t = [Arrival { at_secs: 0.0, tenant: 0 }];
        assert!(simulate_open_arrivals(&t, 1.0, 0, 1).is_err());
        assert!(simulate_open_arrivals(&t, f64::NAN, 1, 1).is_err());
    }

    #[test]
    fn same_seed_reproduces_the_trace_bit_exactly() {
        let a = poisson_trace(&cfg(42)).unwrap();
        let b = poisson_trace(&cfg(42)).unwrap();
        assert_eq!(a, b);
        let c = poisson_trace(&cfg(43)).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn trace_is_strictly_increasing_and_mixes_tenants() {
        let t = poisson_trace(&cfg(7)).unwrap();
        assert!(t.windows(2).all(|w| w[1].at_secs > w[0].at_secs));
        let mut seen = vec![false; 4];
        for a in &t {
            assert!(a.tenant < 4);
            seen[a.tenant] = true;
        }
        assert!(seen.iter().all(|&s| s), "2000 draws must hit all 4 tenants");
    }

    #[test]
    fn mean_interarrival_tracks_the_rate() {
        let t = poisson_trace(&cfg(11)).unwrap();
        let mean_gap = t.last().unwrap().at_secs / t.len() as f64;
        let expect = 1.0 / 100.0;
        assert!(
            (mean_gap - expect).abs() < 0.1 * expect,
            "mean gap {mean_gap} vs expected {expect}"
        );
    }

    #[test]
    fn fcfs_replay_matches_hand_computation() {
        // Two engines, unit service: arrivals at 0.0, 0.1, 0.2.
        let t = [
            Arrival { at_secs: 0.0, tenant: 0 },
            Arrival { at_secs: 0.1, tenant: 1 },
            Arrival { at_secs: 0.2, tenant: 0 },
        ];
        let out = simulate_open_arrivals(&t, 1.0, 2, 2).unwrap();
        // Job 2 waits for engine 0 (free at 1.0): done 2.0, sojourn 1.8.
        assert_eq!(out.completed, 3);
        assert!((out.makespan_secs - 2.0).abs() < 1e-12);
        assert!((out.sojourn_p99_secs - 1.8).abs() < 1e-12);
        assert_eq!(out.per_tenant_completed, vec![2, 1]);
        // More engines can only shorten sojourns.
        let wide = simulate_open_arrivals(&t, 1.0, 3, 2).unwrap();
        assert!(wide.sojourn_p99_secs <= out.sojourn_p99_secs);
    }
}
