//! Link models: how replayed transmissions contend for network
//! resources.
//!
//! Both models charge a multicast **once** — one link occupancy
//! regardless of recipient count — matching [`crate::net::Bus`]
//! semantics (Definition 3 counts bytes on the link, and multicast is
//! exactly where coded shuffling wins).
//!
//! - [`LinkKind::Shared`] — the paper's single shared multicast link:
//!   every transmission in the ledger serializes on one resource, in
//!   ledger (= schedule) order.
//! - [`LinkKind::Bisection`] — full-bisection fabric: each *sender's*
//!   NIC is the bottleneck. Transmissions from different senders
//!   proceed in parallel; each sender's transmissions serialize in
//!   ledger order on its own NIC at the same per-link bandwidth.
//!
//! A transmission occupies its resource for `latency + bytes/bandwidth`
//! seconds (fixed per-message overhead plus serialization time).
//!
//! Completion times are computed from *integer* accumulators
//! (`Acc`: message and byte counts) rather than by summing per-message
//! float durations — so a phase's duration is exactly
//! `msgs·latency + bytes/bandwidth` with one rounding, which is what
//! makes the zero-latency degenerate case bit-equal to the closed-form
//! [`crate::sim::model::TimeModel`].

use crate::error::{CamrError, Result};
use crate::net::Transmission;

/// Which contention model the simulated network uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// One shared multicast link; all transmissions serialize.
    Shared,
    /// Full-bisection fabric; transmissions serialize per sender NIC.
    Bisection,
}

impl LinkKind {
    /// Parse a link-model name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "shared" => Ok(LinkKind::Shared),
            "bisection" => Ok(LinkKind::Bisection),
            other => Err(CamrError::InvalidConfig(format!(
                "unknown link model {other} (shared | bisection)"
            ))),
        }
    }

    /// Short label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            LinkKind::Shared => "shared",
            LinkKind::Bisection => "bisection",
        }
    }
}

/// Integer message/byte accumulator for one serialized resource.
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct Acc {
    /// Messages charged so far.
    pub msgs: u64,
    /// Bytes charged so far.
    pub bytes: u64,
}

impl Acc {
    /// Charge one message of `bytes` bytes.
    pub fn add(&mut self, bytes: usize) {
        self.msgs += 1;
        self.bytes += bytes as u64;
    }

    /// Busy time accumulated so far: `msgs·latency + bytes/bandwidth`.
    pub fn secs(&self, bytes_per_sec: f64, latency_secs: f64) -> f64 {
        self.msgs as f64 * latency_secs + self.bytes as f64 / bytes_per_sec
    }
}

/// The serialization chains of one shuffle phase: each inner `Vec` holds
/// positions (into the phase's ledger slice) that contend for one
/// resource, in order; distinct chains run in parallel.
#[derive(Debug)]
pub(crate) struct PhaseChains {
    /// Transmission positions per chain.
    pub chains: Vec<Vec<usize>>,
}

impl PhaseChains {
    /// Group a phase's transmissions into chains for `kind`. Bisection
    /// chains are keyed by sender in order of first appearance (stable
    /// and platform-independent).
    pub fn build(kind: LinkKind, phase: &[Transmission], senders: usize) -> Result<Self> {
        for t in phase {
            if t.sender >= senders {
                return Err(CamrError::InvalidConfig(format!(
                    "ledger sender {} out of range for a {senders}-worker cluster",
                    t.sender
                )));
            }
        }
        let chains = match kind {
            LinkKind::Shared => vec![(0..phase.len()).collect()],
            LinkKind::Bisection => {
                let mut chain_of: Vec<Option<usize>> = vec![None; senders];
                let mut chains: Vec<Vec<usize>> = Vec::new();
                for (i, t) in phase.iter().enumerate() {
                    let c = *chain_of[t.sender].get_or_insert_with(|| {
                        chains.push(Vec::new());
                        chains.len() - 1
                    });
                    chains[c].push(i);
                }
                chains
            }
        };
        Ok(PhaseChains { chains })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Stage;

    fn tx(sender: usize, bytes: usize) -> Transmission {
        Transmission { stage: Stage::Stage1, sender, recipients: vec![], bytes, job: 0 }
    }

    #[test]
    fn parse_and_label() {
        assert_eq!(LinkKind::parse("shared").unwrap(), LinkKind::Shared);
        assert_eq!(LinkKind::parse("bisection").unwrap(), LinkKind::Bisection);
        assert!(LinkKind::parse("token-ring").is_err());
        assert_eq!(LinkKind::Bisection.label(), "bisection");
    }

    #[test]
    fn acc_uses_one_rounding_per_readout() {
        let mut a = Acc::default();
        for _ in 0..3 {
            a.add(100);
        }
        // Exactly 300/bw + 3·lat — not a sum of three rounded terms.
        assert_eq!(a.secs(1e3, 0.0), 300.0 / 1e3);
        assert_eq!(a.secs(1e3, 0.5), 3.0 * 0.5 + 300.0 / 1e3);
    }

    #[test]
    fn shared_is_one_chain_in_ledger_order() {
        let phase = [tx(0, 1), tx(2, 2), tx(1, 3)];
        let c = PhaseChains::build(LinkKind::Shared, &phase, 4).unwrap();
        assert_eq!(c.chains, vec![vec![0, 1, 2]]);
    }

    #[test]
    fn bisection_chains_by_sender_first_appearance() {
        let phase = [tx(2, 1), tx(0, 2), tx(2, 3), tx(1, 4), tx(0, 5)];
        let c = PhaseChains::build(LinkKind::Bisection, &phase, 3).unwrap();
        // Sender 2 appears first, then 0, then 1; per-sender order kept.
        assert_eq!(c.chains, vec![vec![0, 2], vec![1, 4], vec![3]]);
    }

    #[test]
    fn rejects_out_of_range_sender() {
        let phase = [tx(7, 1)];
        assert!(PhaseChains::build(LinkKind::Shared, &phase, 4).is_err());
    }
}
