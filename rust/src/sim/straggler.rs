//! Pluggable straggler distributions for map-task durations.
//!
//! Stragglers are the empirical motivation for coded computing (Li et
//! al., "Coded MapReduce"): a few slow tasks dominate a phase that ends
//! at a barrier. The simulator models them as a per-task *slowdown
//! factor* `>= 1` multiplying the nominal task duration.
//!
//! Draws are **addressable**: the factor for `(worker, task)` is a pure
//! function of `(seed, worker, task)` via [`mix_key`], independent of
//! sampling order. Two schemes with the same map layout therefore see
//! *identical* map-phase randomness, so a completion-time difference
//! between them is attributable to the shuffle — never to RNG luck.

use crate::error::{CamrError, Result};
use crate::util::rng::mix_key;

/// A straggler distribution over per-task slowdown factors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StragglerModel {
    /// No stragglers: every task takes exactly its nominal duration.
    Deterministic,
    /// Shifted exponential: factor `= 1 + Exp(rate)`, the classic
    /// straggler model (mean slowdown `1 + 1/rate`).
    ShiftedExp {
        /// Rate `λ` of the exponential tail (larger = milder).
        rate: f64,
    },
    /// Percentile tail: with probability `prob` a task is `factor`×
    /// slower (e.g. "5% of tasks run 10× slower"), otherwise nominal.
    Tail {
        /// Probability of a task being a straggler.
        prob: f64,
        /// Slowdown factor applied to straggler tasks.
        factor: f64,
    },
}

impl StragglerModel {
    /// Parse a distribution by name with its parameters.
    pub fn parse(name: &str, rate: f64, prob: f64, factor: f64) -> Result<Self> {
        let model = match name {
            "none" | "deterministic" => StragglerModel::Deterministic,
            "shifted_exp" => StragglerModel::ShiftedExp { rate },
            "tail" | "percentile_tail" => StragglerModel::Tail { prob, factor },
            other => {
                return Err(CamrError::InvalidConfig(format!(
                    "unknown straggler model {other} (none | shifted_exp | tail)"
                )))
            }
        };
        model.validate()?;
        Ok(model)
    }

    /// Validate parameter ranges.
    pub fn validate(&self) -> Result<()> {
        match *self {
            StragglerModel::Deterministic => Ok(()),
            StragglerModel::ShiftedExp { rate } => {
                if !(rate.is_finite() && rate > 0.0) {
                    return Err(CamrError::InvalidConfig(format!(
                        "straggler_rate must be finite and > 0 (got {rate})"
                    )));
                }
                Ok(())
            }
            StragglerModel::Tail { prob, factor } => {
                if !(0.0..=1.0).contains(&prob) || !prob.is_finite() {
                    return Err(CamrError::InvalidConfig(format!(
                        "tail_prob must be in [0, 1] (got {prob})"
                    )));
                }
                if !(factor.is_finite() && factor >= 1.0) {
                    return Err(CamrError::InvalidConfig(format!(
                        "tail_factor must be >= 1 (got {factor})"
                    )));
                }
                Ok(())
            }
        }
    }

    /// Short label for reports.
    pub fn label(&self) -> String {
        match self {
            StragglerModel::Deterministic => "none".to_string(),
            StragglerModel::ShiftedExp { rate } => format!("shifted_exp(rate={rate})"),
            StragglerModel::Tail { prob, factor } => format!("tail(p={prob},x{factor})"),
        }
    }

    /// Deterministic slowdown factor (`>= 1`) for the `task`-th map task
    /// of `worker`, addressable by `(seed, worker, task)`.
    pub fn factor(&self, seed: u64, worker: usize, task: usize) -> f64 {
        if let StragglerModel::Deterministic = self {
            return 1.0;
        }
        let r = mix_key(seed, &[worker as u64, task as u64]);
        // Uniform in the open interval (0, 1): 53 mantissa bits, offset
        // by half an ulp so neither endpoint is reachable (ln(0) guard).
        let u = ((r >> 11) as f64 + 0.5) / (1u64 << 53) as f64;
        match *self {
            StragglerModel::Deterministic => 1.0,
            StragglerModel::ShiftedExp { rate } => 1.0 + (-u.ln()) / rate,
            StragglerModel::Tail { prob, factor } => {
                if u < prob {
                    factor
                } else {
                    1.0
                }
            }
        }
    }

    /// Expected slowdown factor (used by reports to contextualize
    /// simulated times; the simulator itself only uses [`Self::factor`]).
    pub fn mean_factor(&self) -> f64 {
        match *self {
            StragglerModel::Deterministic => 1.0,
            StragglerModel::ShiftedExp { rate } => 1.0 + 1.0 / rate,
            StragglerModel::Tail { prob, factor } => 1.0 + prob * (factor - 1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_is_always_one() {
        let m = StragglerModel::Deterministic;
        for w in 0..4 {
            for t in 0..16 {
                assert_eq!(m.factor(7, w, t), 1.0);
            }
        }
    }

    #[test]
    fn factors_are_addressable_and_seed_dependent() {
        let m = StragglerModel::ShiftedExp { rate: 5.0 };
        // Same (seed, worker, task) → bit-identical factor.
        assert_eq!(m.factor(42, 3, 9).to_bits(), m.factor(42, 3, 9).to_bits());
        // Different seed, worker, or task all perturb the draw.
        assert_ne!(m.factor(42, 3, 9), m.factor(43, 3, 9));
        assert_ne!(m.factor(42, 2, 9), m.factor(42, 3, 9));
        assert_ne!(m.factor(42, 3, 8), m.factor(42, 3, 9));
    }

    #[test]
    fn shifted_exp_mean_is_one_plus_inverse_rate() {
        let m = StragglerModel::ShiftedExp { rate: 2.0 };
        let n = 20_000;
        let sum: f64 = (0..n).map(|t| m.factor(1, 0, t)).sum();
        let mean = sum / n as f64;
        assert!((mean - m.mean_factor()).abs() < 0.02, "mean = {mean}");
        // Every factor is strictly > 1 under the shifted exponential.
        assert!((0..1000).all(|t| m.factor(1, 0, t) > 1.0));
    }

    #[test]
    fn tail_hits_roughly_prob_fraction() {
        let m = StragglerModel::Tail { prob: 0.1, factor: 8.0 };
        let hits = (0..20_000).filter(|&t| m.factor(3, 1, t) > 1.0).count();
        assert!((1600..2400).contains(&hits), "hits = {hits}");
        // Straggler tasks are exactly `factor`× slower, others nominal.
        assert!((0..1000).all(|t| {
            let f = m.factor(3, 1, t);
            f == 1.0 || f == 8.0
        }));
    }

    #[test]
    fn parse_and_validate() {
        assert_eq!(
            StragglerModel::parse("none", 0.0, 0.0, 0.0).unwrap(),
            StragglerModel::Deterministic
        );
        assert_eq!(
            StragglerModel::parse("shifted_exp", 5.0, 0.0, 0.0).unwrap(),
            StragglerModel::ShiftedExp { rate: 5.0 }
        );
        assert_eq!(
            StragglerModel::parse("tail", 0.0, 0.05, 10.0).unwrap(),
            StragglerModel::Tail { prob: 0.05, factor: 10.0 }
        );
        assert!(StragglerModel::parse("bogus", 1.0, 0.0, 0.0).is_err());
        assert!(StragglerModel::parse("shifted_exp", 0.0, 0.0, 0.0).is_err());
        assert!(StragglerModel::parse("tail", 0.0, 1.5, 10.0).is_err());
        assert!(StragglerModel::parse("tail", 0.0, 0.5, 0.5).is_err());
    }
}
