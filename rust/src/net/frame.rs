//! Length-prefixed wire format for the socket transport.
//!
//! Every message between a worker and the coordinator hub is one
//! *frame*: a fixed 40-byte little-endian header, a recipient list, and
//! a raw payload. The header carries everything the ledger needs
//! ([`crate::net::Transmission`]: stage, sender, recipients, byte count,
//! schedule sequence number), so the hub can charge the shared link
//! without inspecting payloads:
//!
//! ```text
//!  offset  size  field
//!  ------  ----  -----------------------------------------------
//!       0     4  magic        0xCA3AF00D
//!       4     1  kind         FrameKind (Hello, Delta, …)
//!       5     1  stage        0=stage1 1=stage2 2=stage3 3=baseline
//!       6     2  reserved     must be 0
//!       8     8  seq          u64 schedule sequence number
//!      16     4  job          u32 job tag (kind-specific flags for
//!                             handshake frames)
//!      20     4  sender       u32 sending worker id
//!      24     4  tag          u32 kind-specific (group id, spec id,
//!                             barrier phase, error code, …)
//!      28     4  extra        u32 kind-specific (member position,
//!                             receiver id, die-after hook, …)
//!      32     4  nrecip       u32 number of recipients
//!      36     4  payload_len  u32 payload bytes
//!      40  4·nrecip  recipients, u32 each
//!       …  payload_len  payload bytes
//! ```
//!
//! Decoding is incremental ([`FrameDecoder`]) so the transport can feed
//! whatever the socket returns — down to one byte at a time — and
//! strict: a wrong magic, unknown kind/stage code, nonzero reserved
//! bytes or an absurd length is a typed [`CamrError::Wire`] error,
//! never a panic (the property suite in `rust/tests/wire_format.rs`
//! exercises exactly this).

use crate::error::{CamrError, Result};
use crate::net::Stage;
use crate::ServerId;
use std::io::Write;

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: u32 = 0xCA3A_F00D;
/// Wire protocol version, exchanged in the Hello/Welcome handshake.
pub const WIRE_VERSION: u32 = 1;
/// Fixed header length in bytes (before recipients and payload).
pub const HEADER_LEN: usize = 40;
/// Upper bound on the recipient list (a sanity cap, far above any `K`).
pub const MAX_RECIPIENTS: u32 = 1 << 16;
/// Upper bound on a single payload (sanity cap against corrupt lengths).
pub const MAX_PAYLOAD: u32 = 1 << 30;

/// What a frame means. The comments note the kind-specific use of the
/// `tag` / `extra` / `job` / `seq` header fields.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Worker → hub, first frame after connecting. `tag` = wire version.
    Hello,
    /// Hub → worker handshake reply. `tag` = assigned worker id, `job` =
    /// flags (bit 0: pooling), `extra` = die-after-barrier test hook
    /// (0 = none, n+1 = crash after barrier n), payload = run config
    /// TOML text.
    Welcome,
    /// Coded broadcast Δ. `seq` = schedule sequence, `tag` = flattened
    /// group index, `extra` = sender's member position, recipients =
    /// the other group members, payload = the encoded Δ.
    Delta,
    /// Stage-3 fused unicast. `seq` = schedule sequence, `tag` =
    /// stage-3 spec index, `extra` = receiver id, payload = the value.
    Fused,
    /// Worker → hub: reached phase barrier `tag` (0 = map … 3 = stage 3).
    Barrier,
    /// Hub → worker: every worker reached barrier `tag`; proceed.
    BarrierGo,
    /// Worker → hub: reduced outputs. Payload = `u32` entry count, then
    /// per entry `u32 job`, `u32 func`, `u32 len`, value bytes.
    Outputs,
    /// Worker → hub: run finished. `seq` = map invocations.
    Done,
    /// Worker → hub: run failed. `tag` = [`CamrError::wire_code`],
    /// payload = error message (UTF-8).
    Failed,
    /// Hub → worker: a peer failed; stop work and exit.
    Abort,
    /// Worker → hub: the round's trace spans, sent between `Outputs`
    /// and `Done` when the Welcome enabled tracing (job flags bit 1).
    /// Payload = [`crate::obs::encode_spans`].
    Spans,
}

/// The declared discriminant table — one entry per frame kind, no
/// collisions. This is the source of truth the `L204` lint and the
/// uniqueness guard test check the `match` arms below against; add a
/// kind here when extending [`FrameKind`].
pub const FRAME_KIND_CODES: &[(u8, &str)] = &[
    (0, "Hello"),
    (1, "Welcome"),
    (2, "Delta"),
    (3, "Fused"),
    (4, "Barrier"),
    (5, "BarrierGo"),
    (6, "Outputs"),
    (7, "Done"),
    (8, "Failed"),
    (9, "Abort"),
    (10, "Spans"),
];

impl FrameKind {
    fn code(self) -> u8 {
        match self {
            FrameKind::Hello => 0,
            FrameKind::Welcome => 1,
            FrameKind::Delta => 2,
            FrameKind::Fused => 3,
            FrameKind::Barrier => 4,
            FrameKind::BarrierGo => 5,
            FrameKind::Outputs => 6,
            FrameKind::Done => 7,
            FrameKind::Failed => 8,
            FrameKind::Abort => 9,
            FrameKind::Spans => 10,
        }
    }

    fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => FrameKind::Hello,
            1 => FrameKind::Welcome,
            2 => FrameKind::Delta,
            3 => FrameKind::Fused,
            4 => FrameKind::Barrier,
            5 => FrameKind::BarrierGo,
            6 => FrameKind::Outputs,
            7 => FrameKind::Done,
            8 => FrameKind::Failed,
            9 => FrameKind::Abort,
            10 => FrameKind::Spans,
            other => return Err(CamrError::Wire(format!("unknown frame kind {other}"))),
        })
    }
}

fn stage_code(s: Stage) -> u8 {
    match s {
        Stage::Stage1 => 0,
        Stage::Stage2 => 1,
        Stage::Stage3 => 2,
        Stage::Baseline => 3,
    }
}

fn stage_from_code(c: u8) -> Result<Stage> {
    Ok(match c {
        0 => Stage::Stage1,
        1 => Stage::Stage2,
        2 => Stage::Stage3,
        3 => Stage::Baseline,
        other => return Err(CamrError::Wire(format!("unknown stage code {other}"))),
    })
}

/// One decoded wire frame. Field meanings are kind-specific — see
/// [`FrameKind`].
#[derive(Debug, Clone)]
pub struct Frame {
    /// What the frame means.
    pub kind: FrameKind,
    /// Protocol stage (ledger tag for Delta/Fused; `Baseline` otherwise).
    pub stage: Stage,
    /// Schedule sequence number (Delta/Fused) or kind-specific u64.
    pub seq: u64,
    /// Job tag / kind-specific flags.
    pub job: u32,
    /// Sending worker id.
    pub sender: u32,
    /// Kind-specific (group id, spec id, barrier phase, error code…).
    pub tag: u32,
    /// Kind-specific (member position, receiver id, die-after hook…).
    pub extra: u32,
    /// Intended recipients (ledger recipients for Delta).
    pub recipients: Vec<ServerId>,
    /// Raw payload bytes.
    pub payload: Vec<u8>,
}

impl Frame {
    /// A frame of `kind` with every other field zeroed/empty.
    pub fn new(kind: FrameKind) -> Self {
        Frame {
            kind,
            stage: Stage::Baseline,
            seq: 0,
            job: 0,
            sender: 0,
            tag: 0,
            extra: 0,
            recipients: Vec::new(),
            payload: Vec::new(),
        }
    }

    /// Serialize into a fresh byte vector (header, recipients, payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(HEADER_LEN + 4 * self.recipients.len() + self.payload.len());
        encode_header(&mut out, self, self.payload.len());
        out.extend_from_slice(&self.payload);
        out
    }

    /// Strict one-shot decode of exactly one frame from the front of
    /// `bytes`; returns the frame and its encoded length. Truncated
    /// input is a typed [`CamrError::Wire`] error (unlike
    /// [`FrameDecoder::next_frame`], which waits for more bytes).
    pub fn decode(bytes: &[u8]) -> Result<(Frame, usize)> {
        let mut d = FrameDecoder::new();
        d.feed(bytes);
        match d.next_frame()? {
            Some(f) => {
                let used = bytes.len() - d.buffered();
                Ok((f, used))
            }
            None => Err(CamrError::Wire(format!(
                "truncated frame: {} bytes is not a whole frame",
                bytes.len()
            ))),
        }
    }
}

/// Serialize a frame's header + recipient list into `out`, with
/// `payload_len` as the advertised payload length. Splitting the header
/// from the payload lets the transport write a pooled
/// [`crate::shuffle::buf::SharedBuf`] payload straight from its backing
/// buffer — see [`write_frame`].
pub fn encode_header(out: &mut Vec<u8>, f: &Frame, payload_len: usize) {
    if crate::obs::metrics_enabled() {
        crate::obs::metrics().frames_encoded.inc();
    }
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(f.kind.code());
    out.push(stage_code(f.stage));
    out.extend_from_slice(&[0u8; 2]); // reserved
    out.extend_from_slice(&f.seq.to_le_bytes());
    out.extend_from_slice(&f.job.to_le_bytes());
    out.extend_from_slice(&f.sender.to_le_bytes());
    out.extend_from_slice(&f.tag.to_le_bytes());
    out.extend_from_slice(&f.extra.to_le_bytes());
    out.extend_from_slice(&(f.recipients.len() as u32).to_le_bytes());
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    for &r in &f.recipients {
        out.extend_from_slice(&(r as u32).to_le_bytes());
    }
}

/// Write `f`'s header followed by `payload` — which *replaces*
/// `f.payload` (normally empty here). This is the zero-copy send path:
/// an encoded Δ living in a pooled buffer is written to the socket
/// directly from the pool's backing store, never copied into a frame.
pub fn write_frame<W: Write>(w: &mut W, f: &Frame, payload: &[u8]) -> std::io::Result<()> {
    let mut head = Vec::with_capacity(HEADER_LEN + 4 * f.recipients.len());
    encode_header(&mut head, f, payload.len());
    w.write_all(&head)?;
    w.write_all(payload)
}

fn rd_u32(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

fn rd_u64(b: &[u8], off: usize) -> u64 {
    let mut x = [0u8; 8];
    x.copy_from_slice(&b[off..off + 8]);
    u64::from_le_bytes(x)
}

/// Incremental frame decoder: feed arbitrary byte chunks as the socket
/// yields them, take whole frames out. Corruption surfaces as a typed
/// [`CamrError::Wire`] error the moment the header is readable.
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    pos: usize,
}

impl FrameDecoder {
    /// New empty decoder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append raw bytes from the transport.
    pub fn feed(&mut self, bytes: &[u8]) {
        // Drop consumed prefix before growing (bounded memory under
        // long-lived connections).
        if self.pos > 0 && (self.pos >= self.buf.len() || self.pos > 64 * 1024) {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes fed but not yet consumed by a decoded frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Decode the next whole frame, `Ok(None)` if more bytes are needed.
    pub fn next_frame(&mut self) -> Result<Option<Frame>> {
        let b = &self.buf[self.pos..];
        if b.len() < HEADER_LEN {
            return Ok(None);
        }
        let magic = rd_u32(b, 0);
        if magic != MAGIC {
            return Err(CamrError::Wire(format!(
                "bad magic {magic:#010x} (want {MAGIC:#010x})"
            )));
        }
        let kind = FrameKind::from_code(b[4])?;
        let stage = stage_from_code(b[5])?;
        if b[6] != 0 || b[7] != 0 {
            return Err(CamrError::Wire("nonzero reserved header bytes".into()));
        }
        let nrecip = rd_u32(b, 32);
        if nrecip > MAX_RECIPIENTS {
            return Err(CamrError::Wire(format!(
                "recipient count {nrecip} exceeds cap {MAX_RECIPIENTS}"
            )));
        }
        let payload_len = rd_u32(b, 36);
        if payload_len > MAX_PAYLOAD {
            return Err(CamrError::Wire(format!(
                "payload length {payload_len} exceeds cap {MAX_PAYLOAD}"
            )));
        }
        let total = HEADER_LEN + 4 * nrecip as usize + payload_len as usize;
        if b.len() < total {
            return Ok(None);
        }
        let recipients: Vec<ServerId> = (0..nrecip as usize)
            .map(|i| rd_u32(b, HEADER_LEN + 4 * i) as ServerId)
            .collect();
        let pstart = HEADER_LEN + 4 * nrecip as usize;
        let frame = Frame {
            kind,
            stage,
            seq: rd_u64(b, 8),
            job: rd_u32(b, 16),
            sender: rd_u32(b, 20),
            tag: rd_u32(b, 24),
            extra: rd_u32(b, 28),
            recipients,
            payload: b[pstart..pstart + payload_len as usize].to_vec(),
        };
        self.pos += total;
        if crate::obs::metrics_enabled() {
            crate::obs::metrics().frames_decoded.inc();
        }
        Ok(Some(frame))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Frame {
        let mut f = Frame::new(FrameKind::Delta);
        f.stage = Stage::Stage2;
        f.seq = 0xDEAD_BEEF_0102_0304;
        f.job = 7;
        f.sender = 3;
        f.tag = 11;
        f.extra = 2;
        f.recipients = vec![0, 1, 4];
        f.payload = vec![0xAB; 37];
        f
    }

    #[test]
    fn frame_kind_table_is_collision_free_and_complete() {
        // The table is the linter's declared truth (L204): every
        // discriminant unique, every kind unique, and each listed
        // code round-trips through `from_code` back to itself.
        let mut codes: Vec<u8> = FRAME_KIND_CODES.iter().map(|(c, _)| *c).collect();
        codes.sort_unstable();
        codes.dedup();
        assert_eq!(codes.len(), FRAME_KIND_CODES.len(), "duplicate frame-kind code");
        let mut names: Vec<&str> = FRAME_KIND_CODES.iter().map(|(_, n)| *n).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), FRAME_KIND_CODES.len(), "duplicate frame-kind name");
        for (code, name) in FRAME_KIND_CODES {
            let kind = FrameKind::from_code(*code).unwrap();
            assert_eq!(kind.code(), *code, "{name}");
            assert_eq!(format!("{kind:?}"), *name, "code {code} decodes to {kind:?}");
        }
        // And the table covers the whole codomain: the next code up
        // must be unknown to the decoder.
        let max = *codes.last().unwrap();
        assert!(FrameKind::from_code(max + 1).is_err(), "table is missing a frame kind");
    }

    #[test]
    fn roundtrip_via_incremental_decoder() {
        let f = sample();
        let bytes = f.encode();
        assert_eq!(bytes.len(), HEADER_LEN + 4 * 3 + 37);
        let (g, used) = Frame::decode(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(g.kind, FrameKind::Delta);
        assert_eq!(g.stage, Stage::Stage2);
        assert_eq!(g.seq, f.seq);
        assert_eq!(g.job, 7);
        assert_eq!(g.sender, 3);
        assert_eq!(g.tag, 11);
        assert_eq!(g.extra, 2);
        assert_eq!(g.recipients, vec![0, 1, 4]);
        assert_eq!(g.payload, f.payload);
    }

    #[test]
    fn write_frame_matches_encode() {
        let mut f = sample();
        let owned = f.encode();
        let payload = std::mem::take(&mut f.payload);
        let mut wired = Vec::new();
        write_frame(&mut wired, &f, &payload).unwrap();
        assert_eq!(wired, owned, "zero-copy path must serialize identically");
    }

    #[test]
    fn truncated_one_shot_decode_is_typed_error() {
        let bytes = sample().encode();
        for cut in [0, 1, HEADER_LEN - 1, HEADER_LEN, bytes.len() - 1] {
            let err = Frame::decode(&bytes[..cut]).unwrap_err();
            assert!(matches!(err, CamrError::Wire(_)), "cut {cut}: {err}");
        }
    }

    #[test]
    fn corrupt_magic_rejected() {
        let mut bytes = sample().encode();
        bytes[0] ^= 0xFF;
        let mut d = FrameDecoder::new();
        d.feed(&bytes);
        assert!(matches!(d.next_frame(), Err(CamrError::Wire(_))));
    }
}
