//! Shared-link network simulator with byte-exact accounting.
//!
//! The paper's model (§II): servers exchange data over a *shared
//! multicast-capable link*; the communication load `L` (Definition 3) is
//! the total bytes put on the link normalized by `J·Q·B`. A multicast is
//! therefore charged **once**, regardless of how many servers decode it —
//! this is exactly where coded shuffling wins.
//!
//! [`Bus`] records every transmission with its phase/stage tag so the
//! per-stage loads of §IV can be measured rather than merely computed.

use crate::ServerId;
use std::fmt;

/// Which protocol phase a transmission belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Stage {
    /// CAMR stage 1: coded multicast among the owners of each job.
    Stage1,
    /// CAMR stage 2: coded multicast within transversal groups.
    Stage2,
    /// CAMR stage 3: unicasts within parallel classes.
    Stage3,
    /// Baseline traffic (uncoded / CCDC), tagged with a label instead.
    Baseline,
}

impl fmt::Display for Stage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Stage::Stage1 => write!(f, "stage1"),
            Stage::Stage2 => write!(f, "stage2"),
            Stage::Stage3 => write!(f, "stage3"),
            Stage::Baseline => write!(f, "baseline"),
        }
    }
}

/// A single transmission on the shared link.
#[derive(Debug, Clone)]
pub struct Transmission {
    /// Protocol stage.
    pub stage: Stage,
    /// Transmitting server.
    pub sender: ServerId,
    /// Intended recipients (decoders). Empty = broadcast to all.
    pub recipients: Vec<ServerId>,
    /// Payload size in bytes — counted once on the shared link.
    pub bytes: usize,
}

/// The shared link: a ledger of every transmission.
///
/// The bus itself performs no routing — the engine hands decoded payloads
/// to workers directly; the bus exists to make the *cost* auditable and
/// the schedule inspectable (used to print the paper's Tables I/II).
#[derive(Debug, Default, Clone)]
pub struct Bus {
    ledger: Vec<Transmission>,
}

impl Bus {
    /// New empty bus.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a multicast from `sender` to `recipients` of `bytes` bytes.
    pub fn multicast(
        &mut self,
        stage: Stage,
        sender: ServerId,
        recipients: Vec<ServerId>,
        bytes: usize,
    ) {
        self.ledger.push(Transmission { stage, sender, recipients, bytes });
    }

    /// Record a unicast.
    pub fn unicast(&mut self, stage: Stage, sender: ServerId, to: ServerId, bytes: usize) {
        self.multicast(stage, sender, vec![to], bytes);
    }

    /// Total bytes on the link (all stages).
    pub fn total_bytes(&self) -> usize {
        self.ledger.iter().map(|t| t.bytes).sum()
    }

    /// Total bytes for one stage.
    pub fn stage_bytes(&self, stage: Stage) -> usize {
        self.ledger.iter().filter(|t| t.stage == stage).map(|t| t.bytes).sum()
    }

    /// Number of transmissions in one stage.
    pub fn stage_count(&self, stage: Stage) -> usize {
        self.ledger.iter().filter(|t| t.stage == stage).count()
    }

    /// All transmissions (for schedule inspection / table printing).
    pub fn ledger(&self) -> &[Transmission] {
        &self.ledger
    }

    /// Communication load: total bytes / normalizer (Definition 3).
    pub fn load(&self, normalizer: f64) -> f64 {
        self.total_bytes() as f64 / normalizer
    }

    /// Per-stage load.
    pub fn stage_load(&self, stage: Stage, normalizer: f64) -> f64 {
        self.stage_bytes(stage) as f64 / normalizer
    }

    /// Clear the ledger (reused between runs).
    pub fn reset(&mut self) {
        self.ledger.clear();
    }

    /// Bytes transmitted per server (length `servers`). The SPC design
    /// is symmetric, so a correct CAMR run loads every server equally —
    /// asserted by the traffic-balance tests.
    pub fn per_server_tx(&self, servers: usize) -> Vec<usize> {
        let mut tx = vec![0usize; servers];
        for t in &self.ledger {
            tx[t.sender] += t.bytes;
        }
        tx
    }

    /// Bytes addressed to each server (multicasts count once per
    /// recipient — this is *decode* work, not link load).
    pub fn per_server_rx(&self, servers: usize) -> Vec<usize> {
        let mut rx = vec![0usize; servers];
        for t in &self.ledger {
            for &r in &t.recipients {
                rx[r] += t.bytes;
            }
        }
        rx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multicast_counted_once() {
        let mut bus = Bus::new();
        bus.multicast(Stage::Stage1, 0, vec![1, 2, 3], 100);
        // 100 bytes on the shared link, not 300.
        assert_eq!(bus.total_bytes(), 100);
        assert_eq!(bus.stage_count(Stage::Stage1), 1);
    }

    #[test]
    fn per_stage_accounting() {
        let mut bus = Bus::new();
        bus.multicast(Stage::Stage1, 0, vec![1], 10);
        bus.multicast(Stage::Stage2, 1, vec![0, 2], 20);
        bus.unicast(Stage::Stage3, 2, 0, 30);
        assert_eq!(bus.stage_bytes(Stage::Stage1), 10);
        assert_eq!(bus.stage_bytes(Stage::Stage2), 20);
        assert_eq!(bus.stage_bytes(Stage::Stage3), 30);
        assert_eq!(bus.total_bytes(), 60);
        assert!((bus.load(120.0) - 0.5).abs() < 1e-12);
        assert!((bus.stage_load(Stage::Stage3, 60.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn reset_clears_ledger() {
        let mut bus = Bus::new();
        bus.unicast(Stage::Baseline, 0, 1, 5);
        bus.reset();
        assert_eq!(bus.total_bytes(), 0);
        assert!(bus.ledger().is_empty());
    }
}
