//! Shared-link network simulator with byte-exact accounting.
//!
//! The paper's model (§II): servers exchange data over a *shared
//! multicast-capable link*; the communication load `L` (Definition 3) is
//! the total bytes put on the link normalized by `J·Q·B`. A multicast is
//! therefore charged **once**, regardless of how many servers decode it —
//! this is exactly where coded shuffling wins.
//!
//! [`Bus`] records every transmission with its phase/stage tag so the
//! per-stage loads of §IV can be measured rather than merely computed.
//!
//! ## Concurrency
//!
//! [`Bus`] itself is single-threaded (the serial engine owns it). The
//! thread-per-worker engine instead hands each worker a cloned
//! [`BusRecorder`]: a channel-backed handle that serializes every
//! transmission onto one [`SharedBus`] collector, each tagged with its
//! deterministic *schedule sequence number*. [`SharedBus::collect`]
//! sorts by that sequence, so the resulting ledger is byte-for-byte
//! identical to the one the serial engine would have produced — a
//! multicast is still charged exactly once, and the nondeterministic
//! arrival order of concurrent sends never leaks into the accounting.
//!
//! ## Data planes
//!
//! How the packets physically move is pluggable behind the
//! [`transport::Transport`] trait, and **the ledger cannot tell the
//! difference** (the golden-fixture tests enforce it):
//!
//! - [`transport::InProcTransport`] — the channel plane above: one OS
//!   thread per worker, `mpsc` channels, `std` barriers.
//! - [`socket`] — workers as separate processes (or threads) speaking
//!   the length-prefixed wire format of [`frame`] over TCP or
//!   Unix-domain sockets, with the coordinator hub fanning multicasts
//!   out and charging this recorder once per multicast.

pub mod frame;
pub mod socket;
pub mod transport;

use crate::ServerId;
use std::fmt;
use std::sync::mpsc;

/// Which protocol phase a transmission belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Stage {
    /// CAMR stage 1: coded multicast among the owners of each job.
    Stage1,
    /// CAMR stage 2: coded multicast within transversal groups.
    Stage2,
    /// CAMR stage 3: unicasts within parallel classes.
    Stage3,
    /// Baseline traffic (uncoded / CCDC), tagged with a label instead.
    Baseline,
}

impl fmt::Display for Stage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Stage::Stage1 => write!(f, "stage1"),
            Stage::Stage2 => write!(f, "stage2"),
            Stage::Stage3 => write!(f, "stage3"),
            Stage::Baseline => write!(f, "baseline"),
        }
    }
}

impl Stage {
    /// Parse the [`fmt::Display`] rendering back into a stage (used to
    /// replay checked-in ledger fixtures through the simulator).
    pub fn parse(s: &str) -> Option<Stage> {
        match s {
            "stage1" => Some(Stage::Stage1),
            "stage2" => Some(Stage::Stage2),
            "stage3" => Some(Stage::Stage3),
            "baseline" => Some(Stage::Baseline),
            _ => None,
        }
    }
}

/// Contiguous same-(job, stage) runs of a ledger, in order: the
/// barrier-separated *phases* of the recorded protocol (a CAMR ledger
/// yields `[stage1, stage2, stage3]`; a baseline ledger one `baseline`
/// run). A change of **job tag** is a barrier too, so the aggregate
/// ledger of a multi-job batch splits into per-job phase sequences even
/// where consecutive jobs share a stage tag (e.g. back-to-back
/// `baseline` runs). The simulator replays each run behind a barrier.
pub fn stage_runs(ledger: &[Transmission]) -> Vec<(Stage, std::ops::Range<usize>)> {
    let mut runs = Vec::new();
    let mut start = 0usize;
    for i in 1..=ledger.len() {
        if i == ledger.len()
            || ledger[i].stage != ledger[start].stage
            || ledger[i].job != ledger[start].job
        {
            runs.push((ledger[start].stage, start..i));
            start = i;
        }
    }
    runs
}

/// A single transmission on the shared link.
#[derive(Debug, Clone)]
pub struct Transmission {
    /// Protocol stage.
    pub stage: Stage,
    /// Transmitting server.
    pub sender: ServerId,
    /// Intended recipients (decoders). Empty = broadcast to all.
    pub recipients: Vec<ServerId>,
    /// Payload size in bytes — counted once on the shared link.
    pub bytes: usize,
    /// Batch job index this transmission belongs to (`0` for plain
    /// single-job runs). The batch runtime tags each job's ledger via
    /// [`Bus::append_ledger`] / [`Bus::set_job`]; [`stage_runs`] treats
    /// a job change as a phase barrier.
    pub job: usize,
}

/// The shared link: a ledger of every transmission.
///
/// The bus itself performs no routing — the engine hands decoded payloads
/// to workers directly; the bus exists to make the *cost* auditable and
/// the schedule inspectable (used to print the paper's Tables I/II).
#[derive(Debug, Default, Clone)]
pub struct Bus {
    ledger: Vec<Transmission>,
    /// Job tag applied to subsequently recorded transmissions.
    job: usize,
}

impl Bus {
    /// New empty bus.
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the job tag applied to transmissions recorded from now on
    /// (reset to `0` by [`Bus::reset`]). Engines leave this at `0`; the
    /// CCDC baseline tags each job of its family as it executes.
    pub fn set_job(&mut self, job: usize) {
        self.job = job;
    }

    /// Record a multicast from `sender` to `recipients` of `bytes` bytes.
    pub fn multicast(
        &mut self,
        stage: Stage,
        sender: ServerId,
        recipients: Vec<ServerId>,
        bytes: usize,
    ) {
        if crate::obs::metrics_enabled() {
            crate::obs::metrics().multicast_bytes.observe(bytes as u64);
        }
        self.ledger.push(Transmission { stage, sender, recipients, bytes, job: self.job });
    }

    /// Record a unicast.
    pub fn unicast(&mut self, stage: Stage, sender: ServerId, to: ServerId, bytes: usize) {
        self.multicast(stage, sender, vec![to], bytes);
    }

    /// Total bytes on the link (all stages).
    pub fn total_bytes(&self) -> usize {
        self.ledger.iter().map(|t| t.bytes).sum()
    }

    /// Total bytes for one stage.
    pub fn stage_bytes(&self, stage: Stage) -> usize {
        self.ledger.iter().filter(|t| t.stage == stage).map(|t| t.bytes).sum()
    }

    /// Number of transmissions in one stage.
    pub fn stage_count(&self, stage: Stage) -> usize {
        self.ledger.iter().filter(|t| t.stage == stage).count()
    }

    /// All transmissions (for schedule inspection / table printing).
    pub fn ledger(&self) -> &[Transmission] {
        &self.ledger
    }

    /// Communication load: total bytes / normalizer (Definition 3).
    pub fn load(&self, normalizer: f64) -> f64 {
        self.total_bytes() as f64 / normalizer
    }

    /// Per-stage load.
    pub fn stage_load(&self, stage: Stage, normalizer: f64) -> f64 {
        self.stage_bytes(stage) as f64 / normalizer
    }

    /// The ledger's barrier-separated phases: contiguous same-stage
    /// runs, as `(stage, transmissions)` slices (see [`stage_runs`]).
    pub fn phases(&self) -> Vec<(Stage, &[Transmission])> {
        stage_runs(&self.ledger).into_iter().map(|(s, r)| (s, &self.ledger[r])).collect()
    }

    /// Append another ledger's transmissions re-tagged with `job` — the
    /// batch runtime folds each executed job's per-run ledger into one
    /// aggregate, job-tagged transcript this way. Bytes, order, senders
    /// and recipients are preserved exactly; only the job tag changes.
    pub fn append_ledger(&mut self, ledger: &[Transmission], job: usize) {
        self.ledger.extend(ledger.iter().map(|t| Transmission { job, ..t.clone() }));
    }

    /// Number of distinct job tags (`max + 1`; `0` for an empty ledger).
    pub fn job_count(&self) -> usize {
        self.ledger.iter().map(|t| t.job + 1).max().unwrap_or(0)
    }

    /// Total bytes carrying one job tag.
    pub fn job_bytes(&self, job: usize) -> usize {
        self.ledger.iter().filter(|t| t.job == job).map(|t| t.bytes).sum()
    }

    /// Clear the ledger (reused between runs).
    pub fn reset(&mut self) {
        self.ledger.clear();
        self.job = 0;
    }

    /// Bytes transmitted per server (length `servers`). The SPC design
    /// is symmetric, so a correct CAMR run loads every server equally —
    /// asserted by the traffic-balance tests.
    pub fn per_server_tx(&self, servers: usize) -> Vec<usize> {
        let mut tx = vec![0usize; servers];
        for t in &self.ledger {
            tx[t.sender] += t.bytes;
        }
        tx
    }

    /// Bytes addressed to each server (multicasts count once per
    /// recipient — this is *decode* work, not link load).
    pub fn per_server_rx(&self, servers: usize) -> Vec<usize> {
        let mut rx = vec![0usize; servers];
        for t in &self.ledger {
            for &r in &t.recipients {
                rx[r] += t.bytes;
            }
        }
        rx
    }
}

/// A thread-safe handle workers use to charge the shared link from their
/// own threads. Clones share one [`SharedBus`] collector.
///
/// Every record carries a schedule sequence number assigned by the
/// engine (the position the transmission would occupy in a serial
/// execution of the same schedule); the collector orders by it, making
/// the ledger independent of thread interleaving.
#[derive(Clone)]
pub struct BusRecorder {
    tx: mpsc::Sender<(u64, Transmission)>,
}

impl BusRecorder {
    /// Record a multicast (charged once on the shared link).
    pub fn multicast(
        &self,
        seq: u64,
        stage: Stage,
        sender: ServerId,
        recipients: Vec<ServerId>,
        bytes: usize,
    ) {
        if crate::obs::metrics_enabled() {
            crate::obs::metrics().multicast_bytes.observe(bytes as u64);
        }
        let _ = self.tx.send((seq, Transmission { stage, sender, recipients, bytes, job: 0 }));
    }

    /// Record a unicast.
    pub fn unicast(&self, seq: u64, stage: Stage, sender: ServerId, to: ServerId, bytes: usize) {
        self.multicast(seq, stage, sender, vec![to], bytes);
    }
}

/// Collector side of the channel-backed shared link.
pub struct SharedBus {
    tx: mpsc::Sender<(u64, Transmission)>,
    rx: mpsc::Receiver<(u64, Transmission)>,
}

impl Default for SharedBus {
    fn default() -> Self {
        Self::new()
    }
}

impl SharedBus {
    /// New collector with no recorders yet.
    pub fn new() -> Self {
        let (tx, rx) = mpsc::channel();
        SharedBus { tx, rx }
    }

    /// A new recorder handle for one worker thread.
    pub fn recorder(&self) -> BusRecorder {
        BusRecorder { tx: self.tx.clone() }
    }

    /// Drain every record and fold them, ordered by sequence number, into
    /// a plain [`Bus`]. Call only after all [`BusRecorder`] clones have
    /// been dropped (i.e. the worker threads have exited) — otherwise
    /// this would block waiting for more records.
    pub fn collect(self) -> Bus {
        drop(self.tx);
        let mut records: Vec<(u64, Transmission)> = self.rx.iter().collect();
        records.sort_by_key(|(seq, _)| *seq);
        let mut bus = Bus::new();
        for (_, t) in records {
            bus.multicast(t.stage, t.sender, t.recipients, t.bytes);
        }
        bus
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multicast_counted_once() {
        let mut bus = Bus::new();
        bus.multicast(Stage::Stage1, 0, vec![1, 2, 3], 100);
        // 100 bytes on the shared link, not 300.
        assert_eq!(bus.total_bytes(), 100);
        assert_eq!(bus.stage_count(Stage::Stage1), 1);
    }

    #[test]
    fn per_stage_accounting() {
        let mut bus = Bus::new();
        bus.multicast(Stage::Stage1, 0, vec![1], 10);
        bus.multicast(Stage::Stage2, 1, vec![0, 2], 20);
        bus.unicast(Stage::Stage3, 2, 0, 30);
        assert_eq!(bus.stage_bytes(Stage::Stage1), 10);
        assert_eq!(bus.stage_bytes(Stage::Stage2), 20);
        assert_eq!(bus.stage_bytes(Stage::Stage3), 30);
        assert_eq!(bus.total_bytes(), 60);
        assert!((bus.load(120.0) - 0.5).abs() < 1e-12);
        assert!((bus.stage_load(Stage::Stage3, 60.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn stage_parse_inverts_display() {
        for s in [Stage::Stage1, Stage::Stage2, Stage::Stage3, Stage::Baseline] {
            assert_eq!(Stage::parse(&s.to_string()), Some(s));
        }
        assert_eq!(Stage::parse("stage9"), None);
    }

    #[test]
    fn stage_runs_split_at_stage_changes() {
        let mut bus = Bus::new();
        bus.multicast(Stage::Stage1, 0, vec![1], 10);
        bus.multicast(Stage::Stage1, 1, vec![0], 11);
        bus.multicast(Stage::Stage2, 0, vec![1], 12);
        bus.unicast(Stage::Stage3, 1, 0, 13);
        bus.unicast(Stage::Stage3, 0, 1, 14);
        let runs = stage_runs(bus.ledger());
        assert_eq!(runs.len(), 3);
        assert_eq!(runs[0], (Stage::Stage1, 0..2));
        assert_eq!(runs[1], (Stage::Stage2, 2..3));
        assert_eq!(runs[2], (Stage::Stage3, 3..5));
        let phases = bus.phases();
        assert_eq!(phases[2].1.iter().map(|t| t.bytes).sum::<usize>(), 27);
        assert!(stage_runs(&[]).is_empty());
    }

    #[test]
    fn job_tagging_and_append() {
        let mut single = Bus::new();
        single.multicast(Stage::Stage1, 0, vec![1], 10);
        single.unicast(Stage::Stage3, 1, 0, 20);
        assert!(single.ledger().iter().all(|t| t.job == 0));
        assert_eq!(single.job_count(), 1);

        // Fold the same per-run ledger in twice, tagged as jobs 0 and 1.
        let mut batch = Bus::new();
        batch.append_ledger(single.ledger(), 0);
        batch.append_ledger(single.ledger(), 1);
        assert_eq!(batch.job_count(), 2);
        assert_eq!(batch.total_bytes(), 2 * single.total_bytes());
        assert_eq!(batch.job_bytes(0), single.total_bytes());
        assert_eq!(batch.job_bytes(1), single.total_bytes());
        // Everything but the job tag is preserved exactly.
        for (a, b) in batch.ledger()[2..].iter().zip(single.ledger()) {
            assert_eq!(a.stage, b.stage);
            assert_eq!(a.sender, b.sender);
            assert_eq!(a.recipients, b.recipients);
            assert_eq!(a.bytes, b.bytes);
            assert_eq!(a.job, 1);
        }
        // A job change is a phase barrier even within one stage tag.
        let runs = stage_runs(batch.ledger());
        assert_eq!(runs.len(), 4);
        assert_eq!(runs[1], (Stage::Stage3, 1..2));
        assert_eq!(runs[2], (Stage::Stage1, 2..3));

        // set_job tags subsequent recordings; reset clears it.
        let mut tagged = Bus::new();
        tagged.set_job(7);
        tagged.unicast(Stage::Baseline, 0, 1, 5);
        assert_eq!(tagged.ledger()[0].job, 7);
        assert_eq!(tagged.job_count(), 8);
        tagged.reset();
        tagged.unicast(Stage::Baseline, 0, 1, 5);
        assert_eq!(tagged.ledger()[0].job, 0);
    }

    #[test]
    fn reset_clears_ledger() {
        let mut bus = Bus::new();
        bus.unicast(Stage::Baseline, 0, 1, 5);
        bus.reset();
        assert_eq!(bus.total_bytes(), 0);
        assert!(bus.ledger().is_empty());
    }

    #[test]
    fn shared_bus_orders_by_sequence_across_threads() {
        // 8 threads record in scrambled wall-clock order; the collected
        // ledger must come out in schedule order with exact bytes.
        let shared = SharedBus::new();
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let rec = shared.recorder();
                s.spawn(move || {
                    // Higher thread ids record *earlier* sequence numbers.
                    let seq = 7 - t;
                    rec.multicast(seq, Stage::Stage1, t as usize, vec![0, 1], (seq + 1) as usize);
                });
            }
        });
        let bus = shared.collect();
        assert_eq!(bus.ledger().len(), 8);
        for (i, tr) in bus.ledger().iter().enumerate() {
            assert_eq!(tr.bytes, i + 1, "ledger not in sequence order");
            assert_eq!(tr.sender, 7 - i);
        }
        assert_eq!(bus.total_bytes(), (1..=8).sum::<usize>());
    }

    #[test]
    fn shared_bus_unicast_records_single_recipient() {
        let shared = SharedBus::new();
        let rec = shared.recorder();
        rec.unicast(0, Stage::Stage3, 2, 5, 64);
        drop(rec);
        let bus = shared.collect();
        assert_eq!(bus.ledger().len(), 1);
        assert_eq!(bus.ledger()[0].recipients, vec![5]);
        assert_eq!(bus.stage_bytes(Stage::Stage3), 64);
    }
}
