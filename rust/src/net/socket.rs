//! Socket plumbing for the [`super::transport::Transport`] data plane:
//! TCP / Unix-domain streams, the listener/dialer pair, and the
//! worker-side [`SocketTransport`].
//!
//! ## Topology and handshake
//!
//! The socket plane is hub-and-spoke: the coordinator binds a listener
//! and every worker dials in (`camr worker --connect <url>`, or an
//! in-process thread for tests). Worker ids are assigned by the hub in
//! accept order — safe because a worker's entire behavior is a pure
//! function of its assigned id and the (deterministic) schedule, and
//! the ledger is ordered by schedule sequence numbers, not arrival:
//!
//! ```text
//!  worker                hub
//!    | --- Hello(version) -->|   first frame after connect
//!    |<-- Welcome(id, flags, |   id = accept order; payload = run
//!    |    config TOML) ------|   config text; extra = test hooks
//!    |                       |
//!    | --- Barrier(0) ------>|   …map phase done
//!    |<-- BarrierGo(0) ------|   …all K workers arrived
//!    | --- Delta(seq, …) --->|   hub charges the ledger ONCE and
//!    |<-- Delta(seq, …) -----|   fans out to the recipient list
//! ```
//!
//! A multicast is **one** frame worker→hub; the hub records it through
//! the same [`crate::net::BusRecorder`] the channel plane uses and
//! forwards copies to the recipients. That keeps Definition 3's
//! "charged once on the shared link" semantics — and the ledger
//! byte-identical to the in-process planes.

use crate::error::{CamrError, Result};
use crate::net::frame::{encode_header, write_frame, Frame, FrameDecoder, FrameKind, HEADER_LEN};
use crate::net::transport::{Packet, Transport};
use crate::net::Stage;
use crate::obs::{self, Span, SpanKind, SpanSink};
use crate::shuffle::buf::SharedBuf;
use crate::{FuncId, JobId, ServerId};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// A connected stream of either flavor.
pub enum SockStream {
    /// TCP (loopback or real network).
    Tcp(TcpStream),
    /// Unix-domain.
    Unix(UnixStream),
}

impl SockStream {
    /// Clone the OS handle (reader threads get the clone).
    pub fn try_clone(&self) -> std::io::Result<SockStream> {
        Ok(match self {
            SockStream::Tcp(s) => SockStream::Tcp(s.try_clone()?),
            SockStream::Unix(s) => SockStream::Unix(s.try_clone()?),
        })
    }

    /// Set the read timeout (None = block forever).
    pub fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            SockStream::Tcp(s) => s.set_read_timeout(d),
            SockStream::Unix(s) => s.set_read_timeout(d),
        }
    }

    /// Set the write timeout (a stalled peer surfaces as an io error
    /// instead of wedging the hub).
    pub fn set_write_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            SockStream::Tcp(s) => s.set_write_timeout(d),
            SockStream::Unix(s) => s.set_write_timeout(d),
        }
    }

    /// Shut down both directions (ignore "already closed").
    pub fn shutdown(&self) {
        let _ = match self {
            SockStream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            SockStream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl Read for SockStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            SockStream::Tcp(s) => s.read(buf),
            SockStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for SockStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            SockStream::Tcp(s) => s.write(buf),
            SockStream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            SockStream::Tcp(s) => s.flush(),
            SockStream::Unix(s) => s.flush(),
        }
    }
}

/// Which socket flavor a listener/dialer uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SocketKind {
    /// TCP (default listen address `127.0.0.1:0`).
    Tcp,
    /// Unix-domain (default path under the system temp dir).
    Unix,
}

static UNIX_PATH_COUNTER: AtomicUsize = AtomicUsize::new(0);

/// The hub's listening socket, with its dialable URL.
pub enum SockListener {
    /// Bound TCP listener + `tcp://addr:port` URL.
    Tcp(TcpListener, String),
    /// Bound Unix listener + owned socket path + `unix://path` URL.
    Unix(UnixListener, PathBuf, String),
}

impl SockListener {
    /// Bind a listener. `listen` overrides the default address
    /// (`127.0.0.1:0` for TCP; a fresh temp-dir path for Unix).
    pub fn bind(kind: SocketKind, listen: Option<&str>) -> Result<SockListener> {
        match kind {
            SocketKind::Tcp => {
                let addr = listen.unwrap_or("127.0.0.1:0");
                let l = TcpListener::bind(addr)?;
                l.set_nonblocking(true)?;
                let url = format!("tcp://{}", l.local_addr()?);
                Ok(SockListener::Tcp(l, url))
            }
            SocketKind::Unix => {
                let path = match listen {
                    Some(p) => PathBuf::from(p),
                    None => std::env::temp_dir().join(format!(
                        "camr-{}-{}.sock",
                        std::process::id(),
                        UNIX_PATH_COUNTER.fetch_add(1, Ordering::Relaxed)
                    )),
                };
                // A stale socket file from a killed run blocks bind.
                let _ = std::fs::remove_file(&path);
                let l = UnixListener::bind(&path)?;
                l.set_nonblocking(true)?;
                let url = format!("unix://{}", path.display());
                Ok(SockListener::Unix(l, path, url))
            }
        }
    }

    /// The URL workers dial (`tcp://…` / `unix://…`).
    pub fn url(&self) -> &str {
        match self {
            SockListener::Tcp(_, u) => u,
            SockListener::Unix(_, _, u) => u,
        }
    }

    /// Accept one connection before `deadline`, or a typed
    /// [`CamrError::Disconnected`].
    pub fn accept_within(&self, deadline: Instant) -> Result<SockStream> {
        loop {
            let res = match self {
                SockListener::Tcp(l, _) => l.accept().map(|(s, _)| SockStream::Tcp(s)),
                SockListener::Unix(l, _, _) => l.accept().map(|(s, _)| SockStream::Unix(s)),
            };
            match res {
                Ok(s) => {
                    if let SockStream::Tcp(t) = &s {
                        let _ = t.set_nodelay(true);
                    }
                    return Ok(s);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(CamrError::Disconnected(
                            "no worker connected within the handshake timeout".into(),
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

impl Drop for SockListener {
    fn drop(&mut self) {
        if let SockListener::Unix(_, path, _) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Dial a hub URL (`tcp://host:port` or `unix:///path`), with a short
/// retry loop to ride out spawn/bind races.
pub fn dial(url: &str) -> Result<SockStream> {
    let connect = || -> std::io::Result<SockStream> {
        if let Some(addr) = url.strip_prefix("tcp://") {
            let s = TcpStream::connect(addr)?;
            let _ = s.set_nodelay(true);
            Ok(SockStream::Tcp(s))
        } else if let Some(path) = url.strip_prefix("unix://") {
            Ok(SockStream::Unix(UnixStream::connect(path)?))
        } else {
            Err(std::io::Error::other(format!(
                "bad transport url {url} (want tcp://host:port or unix:///path)"
            )))
        }
    };
    let mut last = None;
    for _ in 0..50 {
        match connect() {
            Ok(s) => return Ok(s),
            Err(e) if e.kind() == std::io::ErrorKind::Other => {
                return Err(CamrError::InvalidConfig(e.to_string()))
            }
            Err(e) => {
                if obs::metrics_enabled() {
                    obs::metrics().dial_retries.inc();
                }
                last = Some(e);
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    Err(CamrError::Disconnected(format!(
        "could not dial {url}: {}",
        last.map(|e| e.to_string()).unwrap_or_default()
    )))
}

/// Read whole frames off a stream: feed the decoder until one frame is
/// complete. `Ok(None)` = clean EOF. Read timeouts just keep polling;
/// corrupt bytes surface as typed [`CamrError::Wire`] errors.
pub fn read_frame_blocking(
    stream: &mut SockStream,
    decoder: &mut FrameDecoder,
) -> Result<Option<Frame>> {
    let mut chunk = [0u8; 16 * 1024];
    loop {
        if let Some(f) = decoder.next_frame()? {
            return Ok(Some(f));
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                if decoder.buffered() > 0 {
                    return Err(CamrError::Wire(format!(
                        "connection closed mid-frame ({} bytes buffered)",
                        decoder.buffered()
                    )));
                }
                return Ok(None);
            }
            Ok(n) => decoder.feed(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Serialize reduced outputs into an `Outputs` frame payload:
/// `u32 count`, then per entry `u32 job`, `u32 func`, `u32 len`, bytes.
pub fn encode_outputs(entries: &[((JobId, FuncId), Vec<u8>)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + entries.iter().map(|(_, v)| 16 + v.len()).sum::<usize>());
    out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for ((job, func), v) in entries {
        out.extend_from_slice(&(*job as u32).to_le_bytes());
        out.extend_from_slice(&(*func as u32).to_le_bytes());
        out.extend_from_slice(&(v.len() as u32).to_le_bytes());
        out.extend_from_slice(v);
    }
    out
}

/// Inverse of [`encode_outputs`]; typed error on truncation.
pub fn decode_outputs(payload: &[u8]) -> Result<Vec<((JobId, FuncId), Vec<u8>)>> {
    let err = || CamrError::Wire("truncated Outputs payload".into());
    let rd = |b: &[u8], off: usize| -> Result<u32> {
        if off + 4 > b.len() {
            return Err(err());
        }
        Ok(u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]]))
    };
    let count = rd(payload, 0)? as usize;
    let mut off = 4usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let job = rd(payload, off)? as JobId;
        let func = rd(payload, off + 4)? as FuncId;
        let len = rd(payload, off + 8)? as usize;
        off += 12;
        if off + len > payload.len() {
            return Err(err());
        }
        out.push(((job, func), payload[off..off + len].to_vec()));
        off += len;
    }
    if off != payload.len() {
        return Err(CamrError::Wire("trailing bytes after Outputs entries".into()));
    }
    Ok(out)
}

/// Worker-side socket endpoint: one stream to the coordinator hub.
///
/// `send_delta` ships **one** frame regardless of the recipient count —
/// the hub charges the ledger once and fans out — so the shared-link
/// accounting matches the channel plane exactly. Encoded Δ payloads are
/// written straight from their (pooled) backing buffers via
/// [`write_frame`]: the zero-copy serialize path.
pub struct SocketTransport {
    id: ServerId,
    stream: SockStream,
    decoder: FrameDecoder,
    /// Barriers crossed so far (= the next barrier's phase index).
    barriers: usize,
    /// Test hook: crash after crossing barrier `n` (see
    /// [`FrameKind::Welcome`]).
    die_after: Option<usize>,
    /// Whether the die-after hook kills the whole process (subprocess
    /// workers) or just drops the connection (in-thread workers).
    hard_exit: bool,
    crashed: bool,
    aborted: bool,
    /// Frame-I/O span buffer (no-op unless [`SocketTransport::set_span_sink`]
    /// installed a live sink).
    sink: SpanSink,
}

impl SocketTransport {
    /// Wrap a handshaken stream as worker `id`'s transport. The
    /// `decoder` carries over any bytes buffered during the handshake.
    pub fn new(
        stream: SockStream,
        decoder: FrameDecoder,
        id: ServerId,
        die_after: Option<usize>,
        hard_exit: bool,
    ) -> Self {
        SocketTransport {
            id,
            stream,
            decoder,
            barriers: 0,
            die_after,
            hard_exit,
            crashed: false,
            aborted: false,
            sink: SpanSink::disabled(),
        }
    }

    /// Install a span buffer so outbound data frames record `frame_io`
    /// spans (the wire-serialization cost, tagged with payload bytes).
    pub fn set_span_sink(&mut self, sink: SpanSink) {
        self.sink = sink;
    }

    /// Drain buffered spans into their tracer (so a subsequent
    /// [`Tracer::take_spans`](crate::obs::Tracer::take_spans) sees them).
    pub fn flush_spans(&mut self) {
        self.sink.flush();
    }

    /// Whether the die-after test hook fired (thread mode only; the
    /// caller should drop the connection without sending results).
    pub fn crashed(&self) -> bool {
        self.crashed
    }

    fn frame(&self, kind: FrameKind) -> Frame {
        let mut f = Frame::new(kind);
        f.sender = self.id as u32;
        f
    }

    /// Ship the reduced outputs to the hub.
    pub fn send_outputs(&mut self, entries: &[((JobId, FuncId), Vec<u8>)]) -> Result<()> {
        let f = self.frame(FrameKind::Outputs);
        let payload = encode_outputs(entries);
        write_frame(&mut self.stream, &f, &payload)?;
        Ok(())
    }

    /// Tell the hub this worker finished cleanly.
    pub fn send_done(&mut self, map_invocations: usize) -> Result<()> {
        let mut f = self.frame(FrameKind::Done);
        f.seq = map_invocations as u64;
        write_frame(&mut self.stream, &f, &[])?;
        Ok(())
    }

    /// Ship this round's trace spans to the hub (between `Outputs` and
    /// `Done`; only sent when the `Welcome` enabled tracing).
    pub fn send_spans(&mut self, spans: &[Span]) -> Result<()> {
        let f = self.frame(FrameKind::Spans);
        let payload = obs::encode_spans(spans);
        write_frame(&mut self.stream, &f, &payload)?;
        Ok(())
    }
}

impl Transport for SocketTransport {
    fn send_delta(
        &mut self,
        seq: u64,
        stage: Stage,
        group: usize,
        from: usize,
        recipients: &[ServerId],
        delta: &SharedBuf,
    ) -> Result<()> {
        let mut f = self.frame(FrameKind::Delta);
        f.stage = stage;
        f.seq = seq;
        f.tag = group as u32;
        f.extra = from as u32;
        f.recipients = recipients.to_vec();
        // One frame to the hub; the payload streams straight from the
        // (pooled) encode buffer — no intermediate copy.
        let t = self.sink.begin();
        let mut hdr = Vec::with_capacity(HEADER_LEN + 4 * f.recipients.len());
        encode_header(&mut hdr, &f, delta.len());
        self.stream.write_all(&hdr)?;
        delta.write_to(&mut self.stream)?;
        self.sink.record(t, SpanKind::FrameIo, self.id, 0, Some(stage), seq, delta.len() as u64);
        Ok(())
    }

    fn send_fused(
        &mut self,
        seq: u64,
        spec: usize,
        receiver: ServerId,
        value: Vec<u8>,
    ) -> Result<()> {
        let mut f = self.frame(FrameKind::Fused);
        f.stage = Stage::Stage3;
        f.seq = seq;
        f.tag = spec as u32;
        f.extra = receiver as u32;
        let t = self.sink.begin();
        let bytes = value.len() as u64;
        write_frame(&mut self.stream, &f, &value)?;
        self.sink.record(t, SpanKind::FrameIo, self.id, 0, Some(Stage::Stage3), seq, bytes);
        Ok(())
    }

    fn recv(&mut self) -> Option<Packet> {
        loop {
            match read_frame_blocking(&mut self.stream, &mut self.decoder) {
                Ok(Some(f)) => match f.kind {
                    FrameKind::Delta => {
                        return Some(Packet::Delta {
                            group: f.tag as usize,
                            from: f.extra as usize,
                            delta: SharedBuf::from(f.payload),
                        })
                    }
                    FrameKind::Fused => {
                        return Some(Packet::Fused { spec: f.tag as usize, value: f.payload })
                    }
                    FrameKind::Abort => {
                        self.aborted = true;
                        return None;
                    }
                    // Anything else mid-phase means the run is broken;
                    // surface it as an abort.
                    _ => {
                        self.aborted = true;
                        return None;
                    }
                },
                // EOF or a read/decode error: the hub is gone.
                Ok(None) | Err(_) => {
                    self.aborted = true;
                    return None;
                }
            }
        }
    }

    fn barrier(&mut self) -> Result<()> {
        let phase = self.barriers;
        let mut f = self.frame(FrameKind::Barrier);
        f.tag = phase as u32;
        write_frame(&mut self.stream, &f, &[])
            .map_err(|e| CamrError::Disconnected(format!("barrier {phase} send: {e}")))?;
        loop {
            match read_frame_blocking(&mut self.stream, &mut self.decoder) {
                Ok(Some(g)) if g.kind == FrameKind::BarrierGo && g.tag == phase as u32 => break,
                Ok(Some(g)) if g.kind == FrameKind::Abort => {
                    self.aborted = true;
                    return Err(CamrError::Runtime(format!(
                        "worker {}: run aborted at barrier {phase}",
                        self.id
                    )));
                }
                Ok(Some(g)) => {
                    // Data frames cannot be in flight while the hub holds
                    // us at a barrier (the hub writes per-connection in
                    // order and releases after all data is forwarded).
                    self.aborted = true;
                    return Err(CamrError::Wire(format!(
                        "worker {}: unexpected {:?} frame at barrier {phase}",
                        self.id, g.kind
                    )));
                }
                Ok(None) => {
                    self.aborted = true;
                    return Err(CamrError::Disconnected(format!(
                        "worker {}: hub closed the connection at barrier {phase}",
                        self.id
                    )));
                }
                Err(e) => {
                    self.aborted = true;
                    return Err(e);
                }
            }
        }
        self.barriers += 1;
        if self.die_after == Some(phase) {
            // Fault-injection hook: simulate a worker crash right after
            // this barrier releases — mid-next-stage from the peers'
            // point of view.
            if self.hard_exit {
                std::process::exit(101);
            }
            self.crashed = true;
            self.stream.shutdown();
            return Err(CamrError::Runtime(format!(
                "worker {}: die-after-barrier {phase} test hook",
                self.id
            )));
        }
        Ok(())
    }

    fn fail(&mut self, err: &CamrError) {
        self.aborted = true;
        let mut f = self.frame(FrameKind::Failed);
        f.tag = err.wire_code();
        let msg = err.to_string();
        // Best effort: the hub may already be gone.
        let _ = write_frame(&mut self.stream, &f, msg.as_bytes());
    }

    fn aborted(&self) -> bool {
        self.aborted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outputs_payload_roundtrip() {
        let entries =
            vec![((0usize, 3usize), vec![1u8, 2, 3]), ((7, 11), vec![]), ((2, 5), vec![9; 64])];
        let payload = encode_outputs(&entries);
        let back = decode_outputs(&payload).unwrap();
        assert_eq!(back, entries);
        // Truncations are typed errors, not panics.
        for cut in [1, 3, 5, payload.len() - 1] {
            assert!(matches!(decode_outputs(&payload[..cut]), Err(CamrError::Wire(_))));
        }
        assert_eq!(decode_outputs(&encode_outputs(&[])).unwrap(), vec![]);
    }
}
