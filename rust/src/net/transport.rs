//! The [`Transport`] trait: how a worker's packets move, factored out of
//! the engines.
//!
//! A transport is one worker's endpoint onto the packet plane. The
//! engine's protocol body (`coordinator::proto`) is generic over it, so
//! the *same* code drives mpsc channels ([`InProcTransport`], the
//! default) and sockets (`net::socket::SocketTransport`, over TCP or
//! Unix-domain with workers in separate processes).
//!
//! ## Why ledger recording is transport-invariant
//!
//! The shared-link ledger is written by [`crate::net::BusRecorder`] at
//! the moment a send is *initiated*, tagged with the deterministic
//! schedule sequence number — never by observing what arrives where.
//! In-process, the sender's own recorder charges the link and the
//! payload fans out as `SharedBuf` clones; over sockets, the worker
//! ships **one** frame to the coordinator hub, which charges the link
//! once via the identical `BusRecorder` path and fans the frame out to
//! the recipients. Either way a multicast is charged exactly once with
//! the same stage/sender/recipients/bytes at the same sequence number,
//! so [`crate::net::SharedBus::collect`] produces a byte-identical
//! ledger on every transport — the golden-fixture tests cannot tell
//! them apart.

use crate::error::{CamrError, Result};
use crate::net::{BusRecorder, Stage};
use crate::shuffle::buf::SharedBuf;
use crate::ServerId;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Barrier};
use std::time::Duration;

/// A packet exchanged worker-to-worker (through channels or frames).
pub enum Packet {
    /// Coded broadcast `Δ` from member position `from` of the flattened
    /// stage-1/2 group with global index `group`. The payload is a
    /// [`SharedBuf`]: in-process, one encoded buffer shared by every
    /// recipient; over sockets, the received frame payload.
    Delta {
        /// Flattened group index (stage-1 groups then stage-2 groups).
        group: usize,
        /// Sender's member position within the group.
        from: usize,
        /// The encoded broadcast.
        delta: SharedBuf,
    },
    /// Stage-3 fused unicast payload for `schedule.stage3[spec]`.
    Fused {
        /// Index into the schedule's stage-3 spec list.
        spec: usize,
        /// The fused aggregate.
        value: Vec<u8>,
    },
}

/// One worker's endpoint onto the packet plane.
///
/// Contract (what `coordinator::proto::run_round` relies on):
/// - `send_delta` charges the shared link exactly once (multicast
///   semantics) and delivers the payload to every listed recipient.
/// - `recv` returns packets addressed to this worker; `None` means the
///   run is aborting (peer failure / disconnect) and no further packet
///   will come.
/// - `barrier` blocks until every worker reached the same phase
///   boundary; `Err` means the coordinator is gone and the worker must
///   stop (in-process barriers never fail).
/// - `fail` publishes this worker's error to the rest of the run.
pub trait Transport {
    /// Broadcast an encoded Δ to the other members of a coded group,
    /// charging the shared link once at schedule position `seq`.
    fn send_delta(
        &mut self,
        seq: u64,
        stage: Stage,
        group: usize,
        from: usize,
        recipients: &[ServerId],
        delta: &SharedBuf,
    ) -> Result<()>;

    /// Send a stage-3 fused unicast, charging the link at `seq`.
    fn send_fused(
        &mut self,
        seq: u64,
        spec: usize,
        receiver: ServerId,
        value: Vec<u8>,
    ) -> Result<()>;

    /// Next packet addressed to this worker; `None` = run aborting.
    fn recv(&mut self) -> Option<Packet>;

    /// Meet the next phase barrier (map, stage 1, stage 2, stage 3).
    fn barrier(&mut self) -> Result<()>;

    /// Publish this worker's failure to the run.
    fn fail(&mut self, err: &CamrError);

    /// Whether a failure/abort has been observed (locally or from a peer).
    fn aborted(&self) -> bool;
}

/// The default transport: per-worker mpsc channels inside one process,
/// with [`std::sync::Barrier`] phase synchronization and a shared poison
/// flag for failure propagation. This is exactly the packet plane the
/// thread-per-worker engine always had, behind the trait.
pub struct InProcTransport<'a> {
    /// This worker's id.
    id: ServerId,
    inbox: mpsc::Receiver<Packet>,
    peers: Vec<mpsc::Sender<Packet>>,
    bus: BusRecorder,
    gate: &'a Barrier,
    failed: &'a AtomicBool,
}

impl<'a> InProcTransport<'a> {
    /// Assemble one worker's channel endpoint.
    pub fn new(
        id: ServerId,
        inbox: mpsc::Receiver<Packet>,
        peers: Vec<mpsc::Sender<Packet>>,
        bus: BusRecorder,
        gate: &'a Barrier,
        failed: &'a AtomicBool,
    ) -> Self {
        InProcTransport { id, inbox, peers, bus, gate, failed }
    }
}

impl Transport for InProcTransport<'_> {
    fn send_delta(
        &mut self,
        seq: u64,
        stage: Stage,
        group: usize,
        from: usize,
        recipients: &[ServerId],
        delta: &SharedBuf,
    ) -> Result<()> {
        // Charge the shared link once, then fan out cheap SharedBuf
        // clones (Arc bumps, not byte copies). A send to a worker that
        // already exited is ignored — the failure path handles it.
        self.bus.multicast(seq, stage, self.id, recipients.to_vec(), delta.len());
        for &m in recipients {
            let _ = self.peers[m].send(Packet::Delta { group, from, delta: delta.clone() });
        }
        Ok(())
    }

    fn send_fused(
        &mut self,
        seq: u64,
        spec: usize,
        receiver: ServerId,
        value: Vec<u8>,
    ) -> Result<()> {
        self.bus.unicast(seq, Stage::Stage3, self.id, receiver, value.len());
        let _ = self.peers[receiver].send(Packet::Fused { spec, value });
        Ok(())
    }

    fn recv(&mut self) -> Option<Packet> {
        // Bail out (instead of blocking forever) once the shared failure
        // flag is raised and the inbox has drained.
        loop {
            match self.inbox.recv_timeout(Duration::from_millis(10)) {
                Ok(p) => return Some(p),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if self.failed.load(Ordering::SeqCst) {
                        // Final non-blocking sweep: packets already in
                        // flight must not be mistaken for missing ones.
                        return self.inbox.try_recv().ok();
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => return None,
            }
        }
    }

    fn barrier(&mut self) -> Result<()> {
        self.gate.wait();
        Ok(())
    }

    fn fail(&mut self, _err: &CamrError) {
        self.failed.store(true, Ordering::SeqCst);
    }

    fn aborted(&self) -> bool {
        self.failed.load(Ordering::SeqCst)
    }
}
