//! Aggregation (combiner) functions — paper Definition 1.
//!
//! An *aggregate function* is associative and commutative, so any number
//! of intermediate values of the same `(job, function)` can be combined
//! into a single value of the same size `B`. This compression is what
//! CAMR's batch-level shuffle exploits.
//!
//! Values are opaque byte strings of a fixed length; each [`Aggregator`]
//! interprets the bytes (u64 lanes, f32 lanes, …) and must satisfy the
//! algebraic laws — enforced by tests and the proptest suite.

use crate::error::{CamrError, Result};

/// An intermediate value `ν` (or any aggregate of them): exactly
/// `value_bytes` bytes.
pub type Value = Vec<u8>;

/// An associative + commutative combiner over fixed-size byte values.
pub trait Aggregator: Send + Sync {
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;

    /// Combine two values of equal length into one of the same length.
    fn combine(&self, a: &[u8], b: &[u8]) -> Result<Value>;

    /// In-place combine: `acc ← acc ⊕ b`. The allocation-free hot path
    /// used by the map-phase accumulation and stage-3 fusion (§Perf);
    /// the default falls back to [`Aggregator::combine`].
    fn combine_into(&self, acc: &mut [u8], b: &[u8]) -> Result<()> {
        let out = self.combine(acc, b)?;
        acc.copy_from_slice(&out);
        Ok(())
    }

    /// The identity element of the monoid, for a given value size.
    fn identity(&self, len: usize) -> Value;

    /// Fold an iterator of values; returns the identity when empty.
    fn fold<'a, I: Iterator<Item = &'a [u8]>>(&self, len: usize, values: I) -> Result<Value>
    where
        Self: Sized,
    {
        let mut acc = self.identity(len);
        for v in values {
            acc = self.combine(&acc, v)?;
        }
        Ok(acc)
    }
}

fn check_lengths(name: &str, a: &[u8], b: &[u8]) -> Result<()> {
    if a.len() != b.len() {
        return Err(CamrError::Aggregation(format!(
            "{name}: length mismatch {} vs {}",
            a.len(),
            b.len()
        )));
    }
    Ok(())
}

/// Lane-wise wrapping sum of little-endian u64 lanes. The workhorse for
/// word counting and any integer linear aggregation. Value length must be
/// a multiple of 8.
#[derive(Debug, Clone, Copy, Default)]
pub struct SumU64;

impl Aggregator for SumU64 {
    fn name(&self) -> &'static str {
        "sum_u64"
    }

    fn combine(&self, a: &[u8], b: &[u8]) -> Result<Value> {
        let mut out = a.to_vec();
        self.combine_into(&mut out, b)?;
        Ok(out)
    }

    fn combine_into(&self, acc: &mut [u8], b: &[u8]) -> Result<()> {
        check_lengths("sum_u64", acc, b)?;
        if acc.len() % 8 != 0 {
            return Err(CamrError::Aggregation(format!(
                "sum_u64 requires 8-byte lanes, got length {}",
                acc.len()
            )));
        }
        for i in (0..acc.len()).step_by(8) {
            let x = u64::from_le_bytes(acc[i..i + 8].try_into().unwrap());
            let y = u64::from_le_bytes(b[i..i + 8].try_into().unwrap());
            acc[i..i + 8].copy_from_slice(&x.wrapping_add(y).to_le_bytes());
        }
        Ok(())
    }

    fn identity(&self, len: usize) -> Value {
        vec![0u8; len]
    }
}

/// Lane-wise IEEE-754 f32 sum (little-endian lanes). Used by the matvec
/// and gradient workloads. Value length must be a multiple of 4.
///
/// Note: f32 addition is not exactly associative; the engine's oracle
/// therefore verifies with a tolerance for this aggregator (integer
/// aggregators verify bit-exactly).
#[derive(Debug, Clone, Copy, Default)]
pub struct SumF32;

impl Aggregator for SumF32 {
    fn name(&self) -> &'static str {
        "sum_f32"
    }

    fn combine(&self, a: &[u8], b: &[u8]) -> Result<Value> {
        let mut out = a.to_vec();
        self.combine_into(&mut out, b)?;
        Ok(out)
    }

    fn combine_into(&self, acc: &mut [u8], b: &[u8]) -> Result<()> {
        check_lengths("sum_f32", acc, b)?;
        if acc.len() % 4 != 0 {
            return Err(CamrError::Aggregation(format!(
                "sum_f32 requires 4-byte lanes, got length {}",
                acc.len()
            )));
        }
        for i in (0..acc.len()).step_by(4) {
            let x = f32::from_le_bytes(acc[i..i + 4].try_into().unwrap());
            let y = f32::from_le_bytes(b[i..i + 4].try_into().unwrap());
            acc[i..i + 4].copy_from_slice(&(x + y).to_le_bytes());
        }
        Ok(())
    }

    fn identity(&self, len: usize) -> Value {
        // 0.0f32 lanes are all-zero bytes.
        vec![0u8; len]
    }
}

/// Lane-wise max of little-endian u64 lanes (e.g. distributed top-k /
/// max-pooling style reductions).
#[derive(Debug, Clone, Copy, Default)]
pub struct MaxU64;

impl Aggregator for MaxU64 {
    fn name(&self) -> &'static str {
        "max_u64"
    }

    fn combine(&self, a: &[u8], b: &[u8]) -> Result<Value> {
        let mut out = a.to_vec();
        self.combine_into(&mut out, b)?;
        Ok(out)
    }

    fn combine_into(&self, acc: &mut [u8], b: &[u8]) -> Result<()> {
        check_lengths("max_u64", acc, b)?;
        if acc.len() % 8 != 0 {
            return Err(CamrError::Aggregation(format!(
                "max_u64 requires 8-byte lanes, got length {}",
                acc.len()
            )));
        }
        for i in (0..acc.len()).step_by(8) {
            let x = u64::from_le_bytes(acc[i..i + 8].try_into().unwrap());
            let y = u64::from_le_bytes(b[i..i + 8].try_into().unwrap());
            acc[i..i + 8].copy_from_slice(&x.max(y).to_le_bytes());
        }
        Ok(())
    }

    fn identity(&self, len: usize) -> Value {
        vec![0u8; len] // u64::MIN lanes
    }
}

/// Lane-wise XOR — useful for testing (it is its own inverse) and for
/// parity-style reductions. Any value length.
#[derive(Debug, Clone, Copy, Default)]
pub struct XorBytes;

impl Aggregator for XorBytes {
    fn name(&self) -> &'static str {
        "xor_bytes"
    }

    fn combine(&self, a: &[u8], b: &[u8]) -> Result<Value> {
        check_lengths("xor_bytes", a, b)?;
        Ok(a.iter().zip(b).map(|(x, y)| x ^ y).collect())
    }

    fn combine_into(&self, acc: &mut [u8], b: &[u8]) -> Result<()> {
        check_lengths("xor_bytes", acc, b)?;
        for (x, y) in acc.iter_mut().zip(b) {
            *x ^= y;
        }
        Ok(())
    }

    fn identity(&self, len: usize) -> Value {
        vec![0u8; len]
    }
}

/// Type-erased aggregation helper used by the engine (object-safe fold).
pub fn fold_values(agg: &dyn Aggregator, len: usize, values: &[&[u8]]) -> Result<Value> {
    let mut acc = agg.identity(len);
    for v in values {
        acc = agg.combine(&acc, v)?;
    }
    Ok(acc)
}

/// Helpers to view values as typed lanes (used by workload oracles).
pub mod lanes {
    /// Interpret a value as little-endian u64 lanes.
    pub fn as_u64(v: &[u8]) -> Vec<u64> {
        v.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect()
    }

    /// Build a value from u64 lanes.
    pub fn from_u64(lanes: &[u64]) -> Vec<u8> {
        lanes.iter().flat_map(|x| x.to_le_bytes()).collect()
    }

    /// Interpret a value as little-endian f32 lanes.
    pub fn as_f32(v: &[u8]) -> Vec<f32> {
        v.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
    }

    /// Build a value from f32 lanes.
    pub fn from_f32(lanes: &[f32]) -> Vec<u8> {
        lanes.iter().flat_map(|x| x.to_le_bytes()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v64(xs: &[u64]) -> Value {
        lanes::from_u64(xs)
    }

    #[test]
    fn sum_u64_combines_lanes() {
        let a = v64(&[1, 2, 3]);
        let b = v64(&[10, 20, 30]);
        let c = SumU64.combine(&a, &b).unwrap();
        assert_eq!(lanes::as_u64(&c), vec![11, 22, 33]);
    }

    #[test]
    fn sum_u64_wraps() {
        let a = v64(&[u64::MAX]);
        let b = v64(&[2]);
        assert_eq!(lanes::as_u64(&SumU64.combine(&a, &b).unwrap()), vec![1]);
    }

    #[test]
    fn associativity_and_commutativity_u64() {
        let a = v64(&[5, 7]);
        let b = v64(&[11, 13]);
        let c = v64(&[17, 19]);
        let ab_c = SumU64.combine(&SumU64.combine(&a, &b).unwrap(), &c).unwrap();
        let a_bc = SumU64.combine(&a, &SumU64.combine(&b, &c).unwrap()).unwrap();
        assert_eq!(ab_c, a_bc);
        assert_eq!(SumU64.combine(&a, &b).unwrap(), SumU64.combine(&b, &a).unwrap());
    }

    #[test]
    fn identity_laws() {
        let a = v64(&[42, 43]);
        let id = SumU64.identity(16);
        assert_eq!(SumU64.combine(&a, &id).unwrap(), a);
        assert_eq!(SumU64.combine(&id, &a).unwrap(), a);
        let idx = XorBytes.identity(5);
        let x = vec![1u8, 2, 3, 4, 5];
        assert_eq!(XorBytes.combine(&x, &idx).unwrap(), x);
    }

    #[test]
    fn sum_f32_lanes() {
        let a = lanes::from_f32(&[1.5, -2.0]);
        let b = lanes::from_f32(&[0.25, 4.0]);
        let c = SumF32.combine(&a, &b).unwrap();
        assert_eq!(lanes::as_f32(&c), vec![1.75, 2.0]);
    }

    #[test]
    fn max_u64_lanes() {
        let a = v64(&[3, 100]);
        let b = v64(&[7, 50]);
        assert_eq!(lanes::as_u64(&MaxU64.combine(&a, &b).unwrap()), vec![7, 100]);
    }

    #[test]
    fn xor_is_self_inverse() {
        let a = vec![0xAAu8, 0x55, 0xFF];
        let b = vec![0x0Fu8, 0xF0, 0x3C];
        let x = XorBytes.combine(&a, &b).unwrap();
        assert_eq!(XorBytes.combine(&x, &b).unwrap(), a);
    }

    #[test]
    fn length_mismatch_is_error() {
        assert!(SumU64.combine(&[0u8; 8], &[0u8; 16]).is_err());
        assert!(XorBytes.combine(&[0u8; 3], &[0u8; 4]).is_err());
    }

    #[test]
    fn lane_misalignment_is_error() {
        assert!(SumU64.combine(&[0u8; 7], &[0u8; 7]).is_err());
        assert!(SumF32.combine(&[0u8; 6], &[0u8; 6]).is_err());
    }

    #[test]
    fn fold_empty_is_identity() {
        let out = SumU64.fold(8, std::iter::empty()).unwrap();
        assert_eq!(out, SumU64.identity(8));
    }

    #[test]
    fn fold_values_object_safe() {
        let a = v64(&[1]);
        let b = v64(&[2]);
        let agg: &dyn Aggregator = &SumU64;
        let out = fold_values(agg, 8, &[&a, &b]).unwrap();
        assert_eq!(lanes::as_u64(&out), vec![3]);
    }
}
