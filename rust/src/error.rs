//! Error types for the CAMR crate.

use std::fmt;

/// All errors surfaced by the CAMR library.
#[derive(Debug)]
pub enum CamrError {
    /// Invalid system parameters (e.g. `k < 2`, `q < 2`, `γ < 1`).
    InvalidConfig(String),
    /// A design-theory invariant was violated (block sizes, resolution…).
    DesignInvariant(String),
    /// Placement inconsistency (missing batch, wrong owner set…).
    Placement(String),
    /// Shuffle decode failure: a worker could not reconstruct a chunk.
    ShuffleDecode(String),
    /// A worker was asked for a value it does not store.
    MissingValue(String),
    /// Aggregation error (mismatched lengths / types).
    Aggregation(String),
    /// Reduce-phase verification against the oracle failed.
    Verification(String),
    /// PJRT runtime error (artifact load / compile / execute).
    Runtime(String),
    /// I/O error.
    Io(std::io::Error),
}

impl fmt::Display for CamrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CamrError::InvalidConfig(m) => write!(f, "invalid config: {m}"),
            CamrError::DesignInvariant(m) => write!(f, "design invariant violated: {m}"),
            CamrError::Placement(m) => write!(f, "placement error: {m}"),
            CamrError::ShuffleDecode(m) => write!(f, "shuffle decode error: {m}"),
            CamrError::MissingValue(m) => write!(f, "missing value: {m}"),
            CamrError::Aggregation(m) => write!(f, "aggregation error: {m}"),
            CamrError::Verification(m) => write!(f, "verification failed: {m}"),
            CamrError::Runtime(m) => write!(f, "runtime error: {m}"),
            CamrError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for CamrError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CamrError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CamrError {
    fn from(e: std::io::Error) -> Self {
        CamrError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, CamrError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_are_stable() {
        let e = CamrError::InvalidConfig("k must be >= 2".into());
        assert_eq!(e.to_string(), "invalid config: k must be >= 2");
        let e = CamrError::ShuffleDecode("chunk 3".into());
        assert!(e.to_string().contains("chunk 3"));
    }

    #[test]
    fn io_error_preserves_source() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = CamrError::from(io);
        assert!(std::error::Error::source(&e).is_some());
    }
}
