//! Error types for the CAMR crate.

use std::fmt;

/// All errors surfaced by the CAMR library.
#[derive(Debug)]
pub enum CamrError {
    /// Invalid system parameters (e.g. `k < 2`, `q < 2`, `γ < 1`).
    InvalidConfig(String),
    /// A design-theory invariant was violated (block sizes, resolution…).
    DesignInvariant(String),
    /// Placement inconsistency (missing batch, wrong owner set…).
    Placement(String),
    /// Shuffle decode failure: a worker could not reconstruct a chunk.
    ShuffleDecode(String),
    /// A worker was asked for a value it does not store.
    MissingValue(String),
    /// Aggregation error (mismatched lengths / types).
    Aggregation(String),
    /// Reduce-phase verification against the oracle failed.
    Verification(String),
    /// PJRT runtime error (artifact load / compile / execute).
    Runtime(String),
    /// I/O error.
    Io(std::io::Error),
    /// Wire-format violation on the socket transport (bad magic,
    /// unknown frame kind, oversized lengths, truncated one-shot decode).
    Wire(String),
    /// A worker's transport connection died mid-run (process killed,
    /// socket closed, or no progress within the disconnect timeout).
    Disconnected(String),
    /// Job-service admission queue at capacity: the typed backpressure
    /// rejection. Retry later or use the blocking submit.
    QueueFull(String),
}

impl fmt::Display for CamrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CamrError::InvalidConfig(m) => write!(f, "invalid config: {m}"),
            CamrError::DesignInvariant(m) => write!(f, "design invariant violated: {m}"),
            CamrError::Placement(m) => write!(f, "placement error: {m}"),
            CamrError::ShuffleDecode(m) => write!(f, "shuffle decode error: {m}"),
            CamrError::MissingValue(m) => write!(f, "missing value: {m}"),
            CamrError::Aggregation(m) => write!(f, "aggregation error: {m}"),
            CamrError::Verification(m) => write!(f, "verification failed: {m}"),
            CamrError::Runtime(m) => write!(f, "runtime error: {m}"),
            CamrError::Io(e) => write!(f, "io error: {e}"),
            CamrError::Wire(m) => write!(f, "wire protocol error: {m}"),
            CamrError::Disconnected(m) => write!(f, "worker disconnected: {m}"),
            CamrError::QueueFull(m) => write!(f, "queue full: {m}"),
        }
    }
}

impl CamrError {
    /// Stable numeric code for shipping the error *variant* across the
    /// socket transport (a `Failed` frame carries the code in its tag and
    /// the message in its payload). `0` is reserved for "no error".
    pub fn wire_code(&self) -> u32 {
        match self {
            CamrError::InvalidConfig(_) => 1,
            CamrError::DesignInvariant(_) => 2,
            CamrError::Placement(_) => 3,
            CamrError::ShuffleDecode(_) => 4,
            CamrError::MissingValue(_) => 5,
            CamrError::Aggregation(_) => 6,
            CamrError::Verification(_) => 7,
            CamrError::Runtime(_) => 8,
            CamrError::Io(_) => 9,
            CamrError::Wire(_) => 10,
            CamrError::Disconnected(_) => 11,
            CamrError::QueueFull(_) => 12,
        }
    }

    /// Reconstruct a typed error from a wire code + message — the inverse
    /// of [`CamrError::wire_code`] up to the `Io` payload (which becomes
    /// an `io::Error::other`). Unknown codes degrade to `Runtime`.
    pub fn from_wire(code: u32, msg: String) -> CamrError {
        match code {
            1 => CamrError::InvalidConfig(msg),
            2 => CamrError::DesignInvariant(msg),
            3 => CamrError::Placement(msg),
            4 => CamrError::ShuffleDecode(msg),
            5 => CamrError::MissingValue(msg),
            6 => CamrError::Aggregation(msg),
            7 => CamrError::Verification(msg),
            9 => CamrError::Io(std::io::Error::other(msg)),
            10 => CamrError::Wire(msg),
            11 => CamrError::Disconnected(msg),
            12 => CamrError::QueueFull(msg),
            _ => CamrError::Runtime(msg),
        }
    }
}

impl std::error::Error for CamrError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CamrError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CamrError {
    fn from(e: std::io::Error) -> Self {
        CamrError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, CamrError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_are_stable() {
        let e = CamrError::InvalidConfig("k must be >= 2".into());
        assert_eq!(e.to_string(), "invalid config: k must be >= 2");
        let e = CamrError::ShuffleDecode("chunk 3".into());
        assert!(e.to_string().contains("chunk 3"));
    }

    #[test]
    fn wire_code_roundtrips_every_variant() {
        let all = [
            CamrError::InvalidConfig("m".into()),
            CamrError::DesignInvariant("m".into()),
            CamrError::Placement("m".into()),
            CamrError::ShuffleDecode("m".into()),
            CamrError::MissingValue("m".into()),
            CamrError::Aggregation("m".into()),
            CamrError::Verification("m".into()),
            CamrError::Runtime("m".into()),
            CamrError::Io(std::io::Error::other("m")),
            CamrError::Wire("m".into()),
            CamrError::Disconnected("m".into()),
            CamrError::QueueFull("m".into()),
        ];
        for e in all {
            let code = e.wire_code();
            assert!(code != 0, "0 is reserved");
            let back = CamrError::from_wire(code, "m".into());
            assert_eq!(back.wire_code(), code, "{e}");
        }
        // Unknown codes degrade to Runtime instead of panicking.
        assert!(matches!(CamrError::from_wire(999, "m".into()), CamrError::Runtime(_)));
    }

    #[test]
    fn io_error_preserves_source() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = CamrError::from(io);
        assert!(std::error::Error::source(&e).is_some());
    }
}
