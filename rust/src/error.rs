//! Error types for the CAMR crate.

use std::fmt;

/// All errors surfaced by the CAMR library.
#[derive(Debug)]
pub enum CamrError {
    /// Invalid system parameters (e.g. `k < 2`, `q < 2`, `γ < 1`).
    InvalidConfig(String),
    /// A design-theory invariant was violated (block sizes, resolution…).
    DesignInvariant(String),
    /// Placement inconsistency (missing batch, wrong owner set…).
    Placement(String),
    /// Shuffle decode failure: a worker could not reconstruct a chunk.
    ShuffleDecode(String),
    /// A worker was asked for a value it does not store.
    MissingValue(String),
    /// Aggregation error (mismatched lengths / types).
    Aggregation(String),
    /// Reduce-phase verification against the oracle failed.
    Verification(String),
    /// PJRT runtime error (artifact load / compile / execute).
    Runtime(String),
    /// I/O error.
    Io(std::io::Error),
    /// Wire-format violation on the socket transport (bad magic,
    /// unknown frame kind, oversized lengths, truncated one-shot decode).
    Wire(String),
    /// A worker's transport connection died mid-run (process killed,
    /// socket closed, or no progress within the disconnect timeout).
    Disconnected(String),
    /// Job-service admission queue at capacity: the typed backpressure
    /// rejection. Retry later or use the blocking submit.
    QueueFull(String),
    /// Static verification rejected the plan or spec before execution
    /// ([`crate::check`]): the message carries the diagnostic code(s),
    /// e.g. `P105` for an undecodable XOR term. Raised by `camr
    /// check`, engine pre-flight, and job-service admission.
    Invalid(String),
}

impl fmt::Display for CamrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CamrError::InvalidConfig(m) => write!(f, "invalid config: {m}"),
            CamrError::DesignInvariant(m) => write!(f, "design invariant violated: {m}"),
            CamrError::Placement(m) => write!(f, "placement error: {m}"),
            CamrError::ShuffleDecode(m) => write!(f, "shuffle decode error: {m}"),
            CamrError::MissingValue(m) => write!(f, "missing value: {m}"),
            CamrError::Aggregation(m) => write!(f, "aggregation error: {m}"),
            CamrError::Verification(m) => write!(f, "verification failed: {m}"),
            CamrError::Runtime(m) => write!(f, "runtime error: {m}"),
            CamrError::Io(e) => write!(f, "io error: {e}"),
            CamrError::Wire(m) => write!(f, "wire protocol error: {m}"),
            CamrError::Disconnected(m) => write!(f, "worker disconnected: {m}"),
            CamrError::QueueFull(m) => write!(f, "queue full: {m}"),
            CamrError::Invalid(m) => write!(f, "static check failed: {m}"),
        }
    }
}

impl CamrError {
    /// Stable numeric code for shipping the error *variant* across the
    /// socket transport (a `Failed` frame carries the code in its tag and
    /// the message in its payload). `0` is reserved for "no error".
    pub fn wire_code(&self) -> u32 {
        match self {
            CamrError::InvalidConfig(_) => 1,
            CamrError::DesignInvariant(_) => 2,
            CamrError::Placement(_) => 3,
            CamrError::ShuffleDecode(_) => 4,
            CamrError::MissingValue(_) => 5,
            CamrError::Aggregation(_) => 6,
            CamrError::Verification(_) => 7,
            CamrError::Runtime(_) => 8,
            CamrError::Io(_) => 9,
            CamrError::Wire(_) => 10,
            CamrError::Disconnected(_) => 11,
            CamrError::QueueFull(_) => 12,
            CamrError::Invalid(_) => 13,
        }
    }

    /// Reconstruct a typed error from a wire code + message — the inverse
    /// of [`CamrError::wire_code`] up to the `Io` payload (which becomes
    /// an `io::Error::other`). Unknown codes degrade to `Runtime`.
    pub fn from_wire(code: u32, msg: String) -> CamrError {
        match code {
            1 => CamrError::InvalidConfig(msg),
            2 => CamrError::DesignInvariant(msg),
            3 => CamrError::Placement(msg),
            4 => CamrError::ShuffleDecode(msg),
            5 => CamrError::MissingValue(msg),
            6 => CamrError::Aggregation(msg),
            7 => CamrError::Verification(msg),
            9 => CamrError::Io(std::io::Error::other(msg)),
            10 => CamrError::Wire(msg),
            11 => CamrError::Disconnected(msg),
            12 => CamrError::QueueFull(msg),
            13 => CamrError::Invalid(msg),
            _ => CamrError::Runtime(msg),
        }
    }
}

/// The declared wire-code table — one entry per variant, no
/// collisions. This is the source of truth the `L205` lint and the
/// uniqueness guard test check the `match` arms above against; add a
/// variant here when adding it to [`CamrError`].
pub const WIRE_CODES: &[(u32, &str)] = &[
    (1, "InvalidConfig"),
    (2, "DesignInvariant"),
    (3, "Placement"),
    (4, "ShuffleDecode"),
    (5, "MissingValue"),
    (6, "Aggregation"),
    (7, "Verification"),
    (8, "Runtime"),
    (9, "Io"),
    (10, "Wire"),
    (11, "Disconnected"),
    (12, "QueueFull"),
    (13, "Invalid"),
];

impl std::error::Error for CamrError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CamrError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CamrError {
    fn from(e: std::io::Error) -> Self {
        CamrError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, CamrError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_are_stable() {
        let e = CamrError::InvalidConfig("k must be >= 2".into());
        assert_eq!(e.to_string(), "invalid config: k must be >= 2");
        let e = CamrError::ShuffleDecode("chunk 3".into());
        assert!(e.to_string().contains("chunk 3"));
    }

    #[test]
    fn wire_code_roundtrips_every_variant() {
        let all = [
            CamrError::InvalidConfig("m".into()),
            CamrError::DesignInvariant("m".into()),
            CamrError::Placement("m".into()),
            CamrError::ShuffleDecode("m".into()),
            CamrError::MissingValue("m".into()),
            CamrError::Aggregation("m".into()),
            CamrError::Verification("m".into()),
            CamrError::Runtime("m".into()),
            CamrError::Io(std::io::Error::other("m")),
            CamrError::Wire("m".into()),
            CamrError::Disconnected("m".into()),
            CamrError::QueueFull("m".into()),
            CamrError::Invalid("m".into()),
        ];
        for e in all {
            let code = e.wire_code();
            assert!(code != 0, "0 is reserved");
            let back = CamrError::from_wire(code, "m".into());
            assert_eq!(back.wire_code(), code, "{e}");
        }
        // Unknown codes degrade to Runtime instead of panicking.
        assert!(matches!(CamrError::from_wire(999, "m".into()), CamrError::Runtime(_)));
    }

    #[test]
    fn wire_code_table_is_collision_free_and_complete() {
        // The table is the linter's declared truth (L205): every code
        // unique, every variant unique, `0` absent (reserved), and
        // each listed code round-trips through `from_wire` to a
        // variant with that exact code.
        let mut codes: Vec<u32> = WIRE_CODES.iter().map(|(c, _)| *c).collect();
        codes.sort_unstable();
        codes.dedup();
        assert_eq!(codes.len(), WIRE_CODES.len(), "duplicate wire code in WIRE_CODES");
        assert!(!codes.contains(&0), "0 is reserved for 'no error'");
        let mut names: Vec<&str> = WIRE_CODES.iter().map(|(_, n)| *n).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), WIRE_CODES.len(), "duplicate variant in WIRE_CODES");
        for (code, name) in WIRE_CODES {
            let back = CamrError::from_wire(*code, "m".into());
            assert_eq!(back.wire_code(), *code, "{name}");
            assert!(
                format!("{back:?}").starts_with(name),
                "code {code} reconstructs {back:?}, table says {name}"
            );
        }
    }

    #[test]
    fn io_error_preserves_source() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = CamrError::from(io);
        assert!(std::error::Error::source(&e).is_some());
    }
}
