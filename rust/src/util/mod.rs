//! Self-contained utility substrates (this workspace builds offline, so
//! the usual ecosystem crates are implemented in-tree):
//!
//! - [`rng`] — SplitMix64 / XorShift64* deterministic RNGs.
//! - [`par`] — scoped-thread parallel map (rayon-shaped API surface).
//! - [`json`] — minimal JSON writer for reports.
//! - [`cfgtext`] — TOML-subset parser for run configs.

pub mod bench;
pub mod cfgtext;
pub mod json;
pub mod par;
pub mod rng;
