//! Minimal benchmarking harness (in-tree criterion substitute; this
//! workspace builds offline).
//!
//! Each `cargo bench` target is a plain `main()` that drives [`Bench`]:
//! warmup, N timed iterations, mean / min / stddev reporting, and a
//! machine-readable `BENCH <name> mean_ns=… min_ns=…` line that
//! EXPERIMENTS.md extracts. `--quick` (or `CAMR_BENCH_QUICK=1`) drops the
//! iteration count so CI stays fast.

use std::time::Instant;

/// Runs and reports micro/macro benchmarks.
pub struct Bench {
    iters: usize,
    warmup: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// Create with iteration counts honoring `--quick` / env override.
    pub fn new() -> Self {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("CAMR_BENCH_QUICK").is_ok();
        if quick {
            Bench { iters: 5, warmup: 1 }
        } else {
            Bench { iters: 20, warmup: 3 }
        }
    }

    /// Explicit iteration counts.
    pub fn with_iters(iters: usize, warmup: usize) -> Self {
        Bench { iters: iters.max(1), warmup }
    }

    /// Time `f` and report. Returns mean nanoseconds per iteration.
    ///
    /// `f` should return something observable (e.g. a byte count) to
    /// keep the optimizer honest; the value is black-boxed.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> f64 {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
            / samples.len() as f64;
        let sd = var.sqrt();
        println!(
            "BENCH {name} mean_ns={mean:.0} min_ns={min:.0} sd_ns={sd:.0} iters={}",
            self.iters
        );
        println!(
            "  {name:<46} {:>12}   (min {:>10}, ±{:.1}%)",
            fmt_ns(mean),
            fmt_ns(min),
            if mean > 0.0 { 100.0 * sd / mean } else { 0.0 }
        );
        mean
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench::with_iters(3, 0);
        let mean = b.run("noop_loop", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(mean > 0.0);
    }

    #[test]
    fn formats_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e10).contains("s"));
    }
}
