//! Scoped-thread parallel helpers (in-tree rayon substitute).
//!
//! The engine fans the map phase out across servers; these helpers give
//! it a minimal data-parallel API on top of `std::thread::scope` with a
//! thread count capped at the machine's parallelism.

/// Effective worker-thread count.
pub fn threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every element of `items` in parallel (mutably), chunking
/// the slice across up to [`threads`] scoped threads. `f` must be `Sync`
/// (it is shared), elements are visited exactly once.
pub fn for_each_mut<T: Send, F>(items: &mut [T], f: F)
where
    F: Fn(&mut T) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let workers = threads().min(n);
    if workers <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for part in items.chunks_mut(chunk) {
            s.spawn(|| {
                for it in part.iter_mut() {
                    f(it);
                }
            });
        }
    });
}

/// Parallel map over an index range, collecting results in order.
pub fn map_indexed<R: Send, F>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = threads().min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (c, part) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = c * chunk;
                for (i, slot) in part.iter_mut().enumerate() {
                    *slot = Some(f(base + i));
                }
            });
        }
    });
    out.into_iter().map(|x| x.expect("filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn for_each_mut_visits_all_once() {
        let mut v: Vec<usize> = (0..1000).collect();
        for_each_mut(&mut v, |x| *x += 1);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i + 1));
    }

    #[test]
    fn for_each_mut_empty_and_single() {
        let mut empty: Vec<usize> = vec![];
        for_each_mut(&mut empty, |_| panic!("must not run"));
        let mut one = vec![5usize];
        for_each_mut(&mut one, |x| *x *= 2);
        assert_eq!(one, vec![10]);
    }

    #[test]
    fn map_indexed_in_order() {
        let out = map_indexed(257, |i| i * i);
        assert_eq!(out.len(), 257);
        assert!(out.iter().enumerate().all(|(i, &x)| x == i * i));
    }

    #[test]
    fn runs_concurrently_when_possible() {
        // All threads increment; total must be exact regardless of split.
        let counter = AtomicUsize::new(0);
        let mut v = vec![0u8; 10_000];
        for_each_mut(&mut v, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 10_000);
    }
}
