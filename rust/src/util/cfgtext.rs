//! TOML-subset parser for run configs.
//!
//! Supports exactly what `camr run --config` needs: `[section]` headers,
//! `key = value` lines (integers, booleans, quoted strings), `#`
//! comments, and blank lines. Unknown keys are surfaced as errors so
//! typos never silently fall back to defaults.

use std::collections::BTreeMap;

/// Parsed config: section → key → raw value string.
#[derive(Debug, Default, Clone)]
pub struct CfgText {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl CfgText {
    /// Parse the TOML subset. Top-level keys land in section `""`.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut cfg = CfgText::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim().to_string();
            let mut value = value.trim().to_string();
            if value.starts_with('"') && value.ends_with('"') && value.len() >= 2 {
                value = value[1..value.len() - 1].to_string();
            }
            cfg.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(cfg)
    }

    /// Raw string value.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// Integer value.
    pub fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|e| format!("[{section}] {key} = {v}: {e}")),
        }
    }

    /// u64 value.
    pub fn get_u64(&self, section: &str, key: &str) -> Result<Option<u64>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .parse::<u64>()
                .map(Some)
                .map_err(|e| format!("[{section}] {key} = {v}: {e}")),
        }
    }

    /// f64 value (accepts `0.001`, `1.25e7`, …); must be finite.
    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => match v.parse::<f64>() {
                Ok(x) if x.is_finite() => Ok(Some(x)),
                Ok(x) => Err(format!("[{section}] {key} = {v}: {x} is not finite")),
                Err(e) => Err(format!("[{section}] {key} = {v}: {e}")),
            },
        }
    }

    /// Boolean value (`true`/`false`).
    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some("true") => Ok(Some(true)),
            Some("false") => Ok(Some(false)),
            Some(v) => Err(format!("[{section}] {key} = {v}: expected true/false")),
        }
    }

    /// All keys of a section (for unknown-key validation).
    pub fn keys(&self, section: &str) -> Vec<String> {
        self.sections
            .get(section)
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// All section names present.
    pub fn section_names(&self) -> Vec<String> {
        self.sections.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let text = r#"
            # run config
            workload = "word_count"
            seed = 7
            json = true

            [system]
            k = 3
            q = 2   # inline comment
            gamma = 2
        "#;
        let c = CfgText::parse(text).unwrap();
        assert_eq!(c.get("", "workload"), Some("word_count"));
        assert_eq!(c.get_u64("", "seed").unwrap(), Some(7));
        assert_eq!(c.get_bool("", "json").unwrap(), Some(true));
        assert_eq!(c.get_usize("system", "k").unwrap(), Some(3));
        assert_eq!(c.get_usize("system", "q").unwrap(), Some(2));
        assert_eq!(c.get("system", "missing"), None);
    }

    #[test]
    fn rejects_garbage_lines() {
        assert!(CfgText::parse("not a kv line").is_err());
    }

    #[test]
    fn rejects_bad_types() {
        let c = CfgText::parse("k = banana").unwrap();
        assert!(c.get_usize("", "k").is_err());
        let c = CfgText::parse("flag = yes").unwrap();
        assert!(c.get_bool("", "flag").is_err());
    }

    #[test]
    fn parses_floats_including_scientific_notation() {
        let c = CfgText::parse("a = 0.001\nb = 1.25e7\nc = nan\nd = x").unwrap();
        assert_eq!(c.get_f64("", "a").unwrap(), Some(0.001));
        assert_eq!(c.get_f64("", "b").unwrap(), Some(1.25e7));
        assert_eq!(c.get_f64("", "missing").unwrap(), None);
        assert!(c.get_f64("", "c").is_err(), "NaN must be rejected");
        assert!(c.get_f64("", "d").is_err());
    }

    #[test]
    fn lists_keys_for_validation() {
        let c = CfgText::parse("[system]\nk = 1\nq = 2\n").unwrap();
        let mut keys = c.keys("system");
        keys.sort();
        assert_eq!(keys, vec!["k".to_string(), "q".into()]);
        assert_eq!(c.section_names(), vec!["system".to_string()]);
    }
}
