//! Deterministic pseudo-random generators (no external crates).
//!
//! [`SplitMix64`] is the workhorse: tiny state, excellent avalanche,
//! reproducible across platforms — everything a simulation needs.

/// SplitMix64 (Steele, Lea, Flood 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` (n > 0); uses rejection-free modulo (bias is
    /// negligible for the small `n` used in simulations/tests).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 <= p
    }

    /// f32 uniform in [-1, 1).
    pub fn f32_signed(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
    }
}

/// Stateless mix of a compound key — handy for "random but addressable"
/// values (workload generators index by (job, subfile, func, lane)).
pub fn mix_key(seed: u64, parts: &[u64]) -> u64 {
    let mut acc = seed;
    for (i, &p) in parts.iter().enumerate() {
        acc ^= p.rotate_left((i as u32 * 17 + 11) % 64);
        // one splitmix round
        acc = acc.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = acc;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        acc = z ^ (z >> 31);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_sequence() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = r.range(3, 9);
            assert!((3..9).contains(&x));
        }
    }

    #[test]
    fn f32_signed_bounds() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let x = r.f32_signed();
            assert!((-1.0..1.0).contains(&x));
        }
    }

    #[test]
    fn chance_rough_frequency() {
        let mut r = SplitMix64::new(11);
        let hits = (0..10_000).filter(|_| r.chance(0.7)).count();
        assert!((6500..7500).contains(&hits), "hits = {hits}");
    }

    #[test]
    fn mix_key_distinguishes_parts() {
        let a = mix_key(0, &[1, 2, 3]);
        let b = mix_key(0, &[1, 2, 4]);
        let c = mix_key(0, &[1, 3, 2]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(mix_key(5, &[9, 9]), mix_key(5, &[9, 9]));
    }
}
