//! Minimal JSON writer (objects, arrays, numbers, strings, bools) for
//! report output, plus a small recursive-descent reader ([`Json::parse`])
//! used by the bench smoke tests to prove every emitted `BENCH_*.json`
//! is well-formed. Artifact metadata keeps its own tiny flat reader
//! ([`get_field`]).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// Any number (rendered with enough precision to round-trip f64).
    Num(f64),
    /// Unsigned integer rendered without decimal point.
    UInt(u128),
    /// String (escaped on render).
    Str(String),
    /// Ordered array.
    Arr(Vec<Json>),
    /// Object with stable (sorted) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Parse a JSON document. Covers everything this writer emits
    /// (objects, arrays, strings with the writer's escape set, numbers,
    /// booleans, null) plus insignificant whitespace; trailing garbage
    /// is an error. Non-negative integers without fraction or exponent
    /// come back as [`Json::UInt`], every other number as [`Json::Num`]
    /// — mirroring the writer, so `parse(render(x)).render()` equals
    /// `render(x)`.
    pub fn parse(text: &str) -> std::result::Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing input at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Index into an object field ([`Json::Obj`] only).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Render compactly.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::UInt(x) => {
                let _ = write!(out, "{x}");
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Recursive-descent state for [`Json::parse`].
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, expected: u8) -> std::result::Result<(), String> {
        if self.peek() == Some(expected) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {} (found {:?})",
                expected as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn eat_literal(&mut self, lit: &str) -> std::result::Result<(), String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(format!("expected `{lit}` at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> std::result::Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.eat_literal("true").map(|()| Json::Bool(true)),
            Some(b'f') => self.eat_literal("false").map(|()| Json::Bool(false)),
            Some(b'n') => self.eat_literal("null").map(|()| Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> std::result::Result<Json, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} (found {other:?})")),
            }
        }
    }

    fn array(&mut self) -> std::result::Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] (found {other:?})")),
            }
        }
    }

    fn string(&mut self) -> std::result::Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            while self.pos < self.bytes.len()
                && self.bytes[self.pos] != b'"'
                && self.bytes[self.pos] != b'\\'
            {
                self.pos += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|e| format!("invalid UTF-8 in string: {e}"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or("truncated \\u escape")?;
                            let hex = std::str::from_utf8(hex)
                                .map_err(|_| "non-ASCII \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| format!("bad \\u escape {hex}: {e}"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| format!("invalid codepoint {code:#x}"))?,
                            );
                        }
                        other => return Err(format!("unknown escape \\{}", other as char)),
                    }
                }
                _ => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> std::result::Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut integral = true;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    integral = false;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ASCII number");
        if integral && !text.starts_with('-') {
            if let Ok(u) = text.parse::<u128>() {
                return Ok(Json::UInt(u));
            }
        }
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number `{text}`: {e}"))
    }
}

/// Extract a flat field from a tiny JSON object like the artifact meta
/// (`{"m": 128, "cols": 32, "dtype": "f32", "kernel": "pallas_matvec"}`).
/// Supports string and unsigned-integer values; not a general parser.
pub fn get_field(text: &str, key: &str) -> Option<String> {
    let needle = format!("\"{key}\"");
    let pos = text.find(&needle)?;
    let rest = &text[pos + needle.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    if let Some(stripped) = rest.strip_prefix('"') {
        let end = stripped.find('"')?;
        Some(stripped[..end].to_string())
    } else {
        let end = rest
            .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == '+'))
            .unwrap_or(rest.len());
        if end == 0 {
            None
        } else {
            Some(rest[..end].to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_structures() {
        let j = Json::obj(vec![
            ("k", Json::UInt(3)),
            ("load", Json::Num(1.0)),
            ("name", Json::Str("camr".into())),
            ("stages", Json::Arr(vec![Json::Num(0.25), Json::Num(0.25), Json::Num(0.5)])),
            ("verified", Json::Bool(true)),
        ]);
        let s = j.render();
        assert_eq!(
            s,
            r#"{"k":3,"load":1,"name":"camr","stages":[0.25,0.25,0.5],"verified":true}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let s = Json::Str("a\"b\\c\nd".into()).render();
        assert_eq!(s, r#""a\"b\\c\nd""#);
    }

    #[test]
    fn get_field_reads_meta() {
        let meta = r#"{"m": 128, "cols": 32, "dtype": "f32", "kernel": "pallas_matvec"}"#;
        assert_eq!(get_field(meta, "m").unwrap(), "128");
        assert_eq!(get_field(meta, "dtype").unwrap(), "f32");
        assert_eq!(get_field(meta, "kernel").unwrap(), "pallas_matvec");
        assert!(get_field(meta, "missing").is_none());
    }

    #[test]
    fn get_field_handles_tight_spacing() {
        let meta = r#"{"m":7,"dtype":"f32"}"#;
        assert_eq!(get_field(meta, "m").unwrap(), "7");
        assert_eq!(get_field(meta, "dtype").unwrap(), "f32");
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let j = Json::obj(vec![
            ("bench", Json::Str("batch_jobs".into())),
            ("quick", Json::Bool(true)),
            ("nothing", Json::Null),
            ("count", Json::UInt(75_287_520)),
            ("load", Json::Num(1.0)),
            ("ratio", Json::Num(0.03125)),
            ("big", Json::Num(1.25e8)),
            (
                "rows",
                Json::Arr(vec![
                    Json::obj(vec![("secs", Json::Num(0.015625)), ("n", Json::UInt(0))]),
                    Json::Arr(vec![]),
                    Json::Obj(BTreeMap::new()),
                ]),
            ),
            ("text", Json::Str("a\"b\\c\nd\ttab".into())),
        ]);
        let rendered = j.render();
        let parsed = Json::parse(&rendered).unwrap();
        // String-stable round trip (Num(1.0) renders as `1`, re-parses
        // as UInt(1) — re-rendering restores the identical document).
        assert_eq!(parsed.render(), rendered);
        assert_eq!(parsed.get("count"), Some(&Json::UInt(75_287_520)));
        assert_eq!(parsed.get("text"), Some(&Json::Str("a\"b\\c\nd\ttab".into())));
        assert!(parsed.get("missing").is_none());
        assert!(Json::Num(2.0).get("x").is_none());
    }

    #[test]
    fn parse_accepts_whitespace_and_escapes() {
        let text = concat!(
            " {\n  \"a\" : [ 1 , -2.5 , true , false , null ] ,\n",
            " \"u\": \"\\u0041\\u00e9\" }  "
        );
        let j = Json::parse(text).unwrap();
        assert_eq!(
            j.get("a"),
            Some(&Json::Arr(vec![
                Json::UInt(1),
                Json::Num(-2.5),
                Json::Bool(true),
                Json::Bool(false),
                Json::Null,
            ]))
        );
        assert_eq!(j.get("u"), Some(&Json::Str("Aé".into())));
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\":}",
            "{\"a\" 1}",
            "tru",
            "\"unterminated",
            "{\"a\":1} trailing",
            "{\"a\":1e}",
            "nul",
            "\"bad \\x escape\"",
        ] {
            assert!(Json::parse(bad).is_err(), "parsed: {bad}");
        }
    }
}
