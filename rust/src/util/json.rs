//! Minimal JSON writer (objects, arrays, numbers, strings, bools) for
//! report output. Writing only — nothing in the system parses JSON at
//! runtime except artifact metadata, which has its own tiny reader here.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// Any number (rendered with enough precision to round-trip f64).
    Num(f64),
    /// Unsigned integer rendered without decimal point.
    UInt(u128),
    /// String (escaped on render).
    Str(String),
    /// Ordered array.
    Arr(Vec<Json>),
    /// Object with stable (sorted) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Render compactly.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::UInt(x) => {
                let _ = write!(out, "{x}");
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Extract a flat field from a tiny JSON object like the artifact meta
/// (`{"m": 128, "cols": 32, "dtype": "f32", "kernel": "pallas_matvec"}`).
/// Supports string and unsigned-integer values; not a general parser.
pub fn get_field(text: &str, key: &str) -> Option<String> {
    let needle = format!("\"{key}\"");
    let pos = text.find(&needle)?;
    let rest = &text[pos + needle.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    if let Some(stripped) = rest.strip_prefix('"') {
        let end = stripped.find('"')?;
        Some(stripped[..end].to_string())
    } else {
        let end = rest
            .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == '+'))
            .unwrap_or(rest.len());
        if end == 0 {
            None
        } else {
            Some(rest[..end].to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_structures() {
        let j = Json::obj(vec![
            ("k", Json::UInt(3)),
            ("load", Json::Num(1.0)),
            ("name", Json::Str("camr".into())),
            ("stages", Json::Arr(vec![Json::Num(0.25), Json::Num(0.25), Json::Num(0.5)])),
            ("verified", Json::Bool(true)),
        ]);
        let s = j.render();
        assert_eq!(
            s,
            r#"{"k":3,"load":1,"name":"camr","stages":[0.25,0.25,0.5],"verified":true}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let s = Json::Str("a\"b\\c\nd".into()).render();
        assert_eq!(s, r#""a\"b\\c\nd""#);
    }

    #[test]
    fn get_field_reads_meta() {
        let meta = r#"{"m": 128, "cols": 32, "dtype": "f32", "kernel": "pallas_matvec"}"#;
        assert_eq!(get_field(meta, "m").unwrap(), "128");
        assert_eq!(get_field(meta, "dtype").unwrap(), "f32");
        assert_eq!(get_field(meta, "kernel").unwrap(), "pallas_matvec");
        assert!(get_field(meta, "missing").is_none());
    }

    #[test]
    fn get_field_handles_tight_spacing() {
        let meta = r#"{"m":7,"dtype":"f32"}"#;
        assert_eq!(get_field(meta, "m").unwrap(), "7");
        assert_eq!(get_field(meta, "dtype").unwrap(), "f32");
    }
}
