//! Bounded per-tenant admission queues drained by deficit round-robin.
//!
//! Every tenant owns one FIFO lane with a hard capacity bound —
//! [`DrrQueue::try_push`] to a full lane is a typed
//! [`CamrError::QueueFull`] rejection, never a silent drop and never an
//! unbounded buffer. The dispatcher side pops through classic deficit
//! round-robin (Shreedhar–Varghese): visiting a backlogged lane grants
//! it `quantum × weight` job credits, each pop spends one credit, and a
//! lane that empties forfeits its residual credit. With every lane
//! backlogged the served shares converge to the weight vector exactly —
//! `rust/tests/service.rs` pins the resulting pop pattern.
//!
//! The queue is a plain data structure (no locks, no clocks): the
//! service wraps it in its own mutex, so the fairness policy stays
//! deterministic and unit-testable in isolation.

use crate::error::{CamrError, Result};
use std::collections::VecDeque;

/// One tenant's FIFO lane.
#[derive(Debug)]
struct Lane<T> {
    weight: u64,
    items: VecDeque<T>,
}

/// Bounded multi-tenant queue with deficit round-robin draining.
#[derive(Debug)]
pub struct DrrQueue<T> {
    lanes: Vec<Lane<T>>,
    capacity: usize,
    quantum: u64,
    /// Lane the scheduler is currently serving.
    cursor: usize,
    /// Unspent credits of the cursor lane.
    budget: u64,
    len: usize,
}

impl<T> DrrQueue<T> {
    /// A queue with one lane per weight entry, each bounded to
    /// `capacity` items. `quantum` scales every lane's per-visit grant
    /// (`quantum × weight` pops before the cursor moves on).
    pub fn new(weights: &[u64], capacity: usize, quantum: u64) -> Result<Self> {
        if weights.is_empty() {
            return Err(CamrError::InvalidConfig("service needs >= 1 tenant".into()));
        }
        if weights.contains(&0) {
            return Err(CamrError::InvalidConfig("tenant weights must be >= 1".into()));
        }
        if capacity == 0 {
            return Err(CamrError::InvalidConfig("queue capacity must be >= 1".into()));
        }
        if quantum == 0 {
            return Err(CamrError::InvalidConfig("drr quantum must be >= 1".into()));
        }
        let lanes = weights
            .iter()
            .map(|&weight| Lane { weight, items: VecDeque::new() })
            .collect::<Vec<_>>();
        let budget = quantum * lanes[0].weight;
        Ok(DrrQueue { lanes, capacity, quantum, cursor: 0, budget, len: 0 })
    }

    /// Number of tenant lanes.
    pub fn tenants(&self) -> usize {
        self.lanes.len()
    }

    /// Per-lane capacity bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Queued items across all lanes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no lane holds an item.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Items currently queued for `tenant`.
    pub fn lane_len(&self, tenant: usize) -> usize {
        self.lanes.get(tenant).map_or(0, |l| l.items.len())
    }

    /// Admit an item to `tenant`'s lane, or reject it with the typed
    /// backpressure error when the lane is at capacity.
    pub fn try_push(&mut self, tenant: usize, item: T) -> Result<()> {
        let lanes = self.lanes.len();
        let lane = self.lanes.get_mut(tenant).ok_or_else(|| {
            CamrError::InvalidConfig(format!("tenant {tenant} out of range (have {lanes})"))
        })?;
        if lane.items.len() >= self.capacity {
            return Err(CamrError::QueueFull(format!(
                "tenant {tenant} queue at capacity {}",
                self.capacity
            )));
        }
        lane.items.push_back(item);
        self.len += 1;
        Ok(())
    }

    /// Pop the next item under deficit round-robin, with the owning
    /// tenant. `None` when the queue is empty.
    pub fn pop(&mut self) -> Option<(usize, T)> {
        if self.len == 0 {
            return None;
        }
        loop {
            let lane = &mut self.lanes[self.cursor];
            if !lane.items.is_empty() && self.budget >= 1 {
                self.budget -= 1;
                self.len -= 1;
                let item = lane.items.pop_front().expect("non-empty lane");
                return Some((self.cursor, item));
            }
            // Lane exhausted (or out of credit): forfeit the residual
            // deficit and grant the next lane a fresh visit.
            self.cursor = (self.cursor + 1) % self.lanes.len();
            self.budget = self.quantum * self.lanes[self.cursor].weight;
        }
    }

    /// Drain every lane in round-robin order without spending credits
    /// (shutdown path: ordering fairness no longer matters, loss does).
    pub fn drain_all(&mut self) -> Vec<(usize, T)> {
        let mut out = Vec::with_capacity(self.len);
        while let Some(x) = self.pop() {
            out.push(x);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_degenerate_parameters() {
        assert!(DrrQueue::<u32>::new(&[], 4, 1).is_err());
        assert!(DrrQueue::<u32>::new(&[1, 0], 4, 1).is_err());
        assert!(DrrQueue::<u32>::new(&[1], 0, 1).is_err());
        assert!(DrrQueue::<u32>::new(&[1], 4, 0).is_err());
    }

    #[test]
    fn capacity_bound_is_typed_and_per_lane() {
        let mut q = DrrQueue::new(&[1, 1], 2, 1).unwrap();
        q.try_push(0, 'a').unwrap();
        q.try_push(0, 'b').unwrap();
        let err = q.try_push(0, 'c').unwrap_err();
        assert!(matches!(err, CamrError::QueueFull(_)), "{err}");
        // The other lane still has room, and popping frees space.
        q.try_push(1, 'x').unwrap();
        assert_eq!(q.len(), 3);
        let _ = q.pop().unwrap();
        q.try_push(0, 'c').unwrap();
        assert!(matches!(q.try_push(9, 'z').unwrap_err(), CamrError::InvalidConfig(_)));
    }

    #[test]
    fn backlogged_lanes_share_by_weight() {
        // Weights 1:2, both lanes saturated: the pop pattern must be
        // t0, t1, t1 repeating — shares exactly 1/3 vs 2/3.
        let mut q = DrrQueue::new(&[1, 2], 64, 1).unwrap();
        for i in 0..12u32 {
            q.try_push(0, i).unwrap();
            q.try_push(1, i).unwrap();
        }
        let order: Vec<usize> = (0..9).map(|_| q.pop().unwrap().0).collect();
        assert_eq!(order, vec![0, 1, 1, 0, 1, 1, 0, 1, 1]);
    }

    #[test]
    fn empty_lane_forfeits_deficit() {
        // Lane 1 has nothing queued: lane 0 must be served back to back
        // without accumulating credit for lane 1's later burst.
        let mut q = DrrQueue::new(&[1, 4], 64, 1).unwrap();
        for i in 0..3u32 {
            q.try_push(0, i).unwrap();
        }
        assert_eq!(q.pop().unwrap(), (0, 0));
        assert_eq!(q.pop().unwrap(), (0, 1));
        q.try_push(1, 10).unwrap();
        q.try_push(1, 11).unwrap();
        // Lane 1 gets its fresh grant (4), not 4 + hoarded visits.
        assert_eq!(q.pop().unwrap(), (1, 10));
        assert_eq!(q.pop().unwrap(), (1, 11));
        assert_eq!(q.pop().unwrap(), (0, 2));
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn drain_all_loses_nothing() {
        let mut q = DrrQueue::new(&[1, 1, 1], 8, 1).unwrap();
        for i in 0..8u32 {
            q.try_push((i % 3) as usize, i).unwrap();
        }
        let drained = q.drain_all();
        assert_eq!(drained.len(), 8);
        let mut vals: Vec<u32> = drained.iter().map(|(_, v)| *v).collect();
        vals.sort_unstable();
        assert_eq!(vals, (0..8).collect::<Vec<_>>());
    }
}
