//! Continuous **job service**: open-arrival admission, per-tenant
//! fairness, and multi-round concurrency on top of the batch runtime.
//!
//! [`crate::coordinator::batch`] executes one closed job set to
//! completion; production traffic is an open stream of heterogeneous
//! jobs from many tenants. This module turns the persistent-engine
//! machinery into a long-running service. A submitted [`JobSpec`] is
//! one **coded round**: the engine executes the full `J = q^(k-1)`
//! coupled paper jobs of the design over that spec's workload, so
//! service throughput in jobs/sec understates paper-job throughput by
//! exactly `J`.
//!
//! ## Lifecycle: admission → fairness → dispatch → completion
//!
//! 1. **Admission** — [`JobService::submit`] places the spec into its
//!    tenant's bounded FIFO lane ([`queue::DrrQueue`]). A full lane is
//!    backpressure: the submit fails with the *typed*
//!    [`CamrError::QueueFull`] rejection (counted per tenant), or the
//!    caller opts into [`JobService::submit_blocking`], which parks on
//!    a condvar until a dispatcher frees space.
//! 2. **Fairness** — dispatchers pop through deficit round-robin:
//!    a backlogged tenant is served `quantum × weight` jobs per visit,
//!    so long-run shares converge to the weight vector no matter how
//!    lopsided the offered load is (pinned by `rust/tests/service.rs`).
//! 3. **Dispatch** — a pool of dispatcher threads, each owning one
//!    persistent engine (serial [`Engine`] or thread-per-worker
//!    [`ParallelEngine`], chosen by [`ServiceOptions::parallel`]),
//!    drains the queue with multiple coded rounds in flight. Engines
//!    are built lazily on the first job and then reused via the batch
//!    runtime's [`RoundEngine`] face — only the workload is swapped per
//!    job, so pooled shuffle buffers recycle across the whole stream.
//! 4. **Completion** — every job's outputs are oracle-verified inside
//!    the engine round (unless [`ServiceOptions::verify`] is off), and
//!    a [`JobResult`] records the latency decomposition: `queue_ns`
//!    (submit → dequeue, also emitted as a [`SpanKind::Queue`] span on
//!    the service tracer) and `exec_ns` (dequeue → round complete, with
//!    per-phase roll-ups when tracing is on). [`JobService::drain`]
//!    closes admission, lets the dispatchers finish every queued job,
//!    and returns the [`ServiceOutcome`].
//!
//! ## Invariants under concurrency
//!
//! - A tenant lane never exceeds [`ServiceOptions::queue_capacity`]
//!   items; admission over the bound is always a typed rejection.
//! - Every admitted job is executed **exactly once**: job ids are
//!   assigned under the state lock at admission, dispatchers pop under
//!   the same lock, and `drain` joins every dispatcher only after the
//!   queue is empty — no lost and no double-run jobs (tested).
//! - Per-job failures (workload build, execution, verification) are
//!   recorded in that job's [`JobResult`] and never take the service
//!   down or skip other tenants' work.
//! - The byte-exact ledger of each round is identical to a standalone
//!   engine run — the golden-fixture test drives it through the
//!   service path ([`ServiceOptions::capture_ledger`]).

pub mod queue;

use crate::config::{SystemConfig, WorkloadKind};
use crate::coordinator::batch::RoundEngine;
use crate::coordinator::engine::Engine;
use crate::coordinator::parallel::ParallelEngine;
use crate::error::{CamrError, Result};
use crate::net::Transmission;
use crate::obs::{self, PhaseRollup, SpanKind, SpanStart, Tracer};
use crate::workload::build_native;
use queue::DrrQueue;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One submitted job: which tenant it bills to and what one coded round
/// of it computes. Workloads are built natively from `(kind, seed)`, so
/// a spec is a value, not a closure — it can cross threads and be
/// replayed deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobSpec {
    /// Owning tenant (index into [`ServiceOptions::weights`]).
    pub tenant: usize,
    /// Workload family for this round.
    pub kind: WorkloadKind,
    /// Seed the workload's data is derived from.
    pub seed: u64,
}

/// What happened to one job, with its sojourn decomposition.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Service-assigned id (admission order, 0-based).
    pub job: u64,
    /// Owning tenant.
    pub tenant: usize,
    /// Workload family executed.
    pub kind: WorkloadKind,
    /// Dispatcher (engine) index that ran the round.
    pub engine: usize,
    /// Outputs passed oracle verification. Always `false` when the
    /// service runs with [`ServiceOptions::verify`] off — unverified is
    /// not the same as verified.
    pub verified: bool,
    /// Failure, if any (workload build, execution, or verification).
    pub error: Option<String>,
    /// Bytes the round put on the shared link (0 on failure).
    pub bytes: usize,
    /// Nanoseconds from admission to dequeue (queue wait).
    pub queue_ns: u64,
    /// Nanoseconds from dequeue to round completion (execution).
    pub exec_ns: u64,
    /// Per-phase wall windows of the round (empty unless the service
    /// ran with [`ServiceOptions::tracer`] enabled).
    pub phases: Vec<PhaseRollup>,
    /// The round's byte-exact ledger (empty unless
    /// [`ServiceOptions::capture_ledger`] is set — it clones per job).
    pub ledger: Vec<Transmission>,
}

impl JobResult {
    /// Total sojourn: queue wait plus execution, nanoseconds.
    pub fn sojourn_ns(&self) -> u64 {
        self.queue_ns + self.exec_ns
    }
}

/// Knobs of a running service.
#[derive(Debug, Clone)]
pub struct ServiceOptions {
    /// Dispatcher pool size: engines (and coded rounds) in flight.
    pub engines: usize,
    /// Use the thread-per-worker [`ParallelEngine`] per dispatcher.
    pub parallel: bool,
    /// Route shuffle buffers through each engine's shared pool.
    pub pooling: bool,
    /// Oracle-verify every round's outputs.
    pub verify: bool,
    /// Per-tenant admission-queue bound (backpressure threshold).
    pub queue_capacity: usize,
    /// Deficit round-robin quantum (jobs per weight unit per visit).
    pub quantum: u64,
    /// Per-tenant scheduling weights; the length is the tenant count.
    pub weights: Vec<u64>,
    /// Clone each round's ledger into its [`JobResult`] (tests).
    pub capture_ledger: bool,
    /// Span collector: queue-wait spans land here directly, and each
    /// dispatcher's engine spans are re-ingested per job after their
    /// per-phase roll-up.
    pub tracer: Tracer,
}

impl Default for ServiceOptions {
    fn default() -> Self {
        ServiceOptions {
            engines: 1,
            parallel: false,
            pooling: true,
            verify: true,
            queue_capacity: 64,
            quantum: 1,
            weights: vec![1],
            capture_ledger: false,
            tracer: Tracer::Off,
        }
    }
}

/// One queued job awaiting dispatch.
struct Queued {
    job: u64,
    spec: JobSpec,
    at: Instant,
    qstart: SpanStart,
}

/// State behind the service lock.
struct State {
    queue: DrrQueue<Queued>,
    closed: bool,
    next_job: u64,
    submitted_per_tenant: Vec<u64>,
    rejected_per_tenant: Vec<u64>,
    results: Vec<JobResult>,
}

/// Shared between the handle and every dispatcher thread.
struct Shared {
    cfg: SystemConfig,
    opts: ServiceOptions,
    state: Mutex<State>,
    /// Dispatchers park here when the queue is empty.
    jobs_ready: Condvar,
    /// Blocking submitters park here when their lane is full.
    space_free: Condvar,
}

/// Per-tenant slice of a [`ServiceOutcome`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantStat {
    /// Tenant index.
    pub tenant: usize,
    /// Scheduling weight.
    pub weight: u64,
    /// Jobs admitted.
    pub submitted: u64,
    /// Jobs completed (including failed ones — they ran).
    pub completed: u64,
    /// Completed jobs that passed verification.
    pub verified: u64,
    /// Typed `QueueFull` rejections returned to this tenant.
    pub rejected: u64,
}

/// Everything a drained service measured.
#[derive(Debug, Clone)]
pub struct ServiceOutcome {
    /// Per-job results in completion order.
    pub results: Vec<JobResult>,
    /// Jobs admitted across all tenants.
    pub submitted: u64,
    /// Typed rejections across all tenants.
    pub rejected: u64,
    /// Wall clock from service start to drain completion.
    pub wall: Duration,
    /// The weight vector the service scheduled with.
    pub weights: Vec<u64>,
}

impl ServiceOutcome {
    /// Jobs that completed (ran to a result, successful or not).
    pub fn completed(&self) -> usize {
        self.results.len()
    }

    /// True when every completed job verified with no error.
    pub fn all_verified(&self) -> bool {
        self.results.iter().all(|r| r.verified && r.error.is_none())
    }

    /// Completed jobs per wall-clock second.
    pub fn jobs_per_sec(&self) -> f64 {
        self.results.len() as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// `(p50, p99, mean)` nanoseconds of `metric` over the results.
    pub fn latency_ns(&self, metric: impl Fn(&JobResult) -> u64) -> (u64, u64, f64) {
        let mut v: Vec<u64> = self.results.iter().map(metric).collect();
        if v.is_empty() {
            return (0, 0, 0.0);
        }
        v.sort_unstable();
        let mean = v.iter().map(|&n| n as f64).sum::<f64>() / v.len() as f64;
        (obs::percentile(&v, 0.50), obs::percentile(&v, 0.99), mean)
    }

    /// Per-tenant throughput/rejection accounting.
    pub fn per_tenant(&self) -> Vec<TenantStat> {
        let mut stats: Vec<TenantStat> = self
            .weights
            .iter()
            .enumerate()
            .map(|(tenant, &weight)| TenantStat {
                tenant,
                weight,
                submitted: 0,
                completed: 0,
                verified: 0,
                rejected: 0,
            })
            .collect();
        for r in &self.results {
            let s = &mut stats[r.tenant];
            s.completed += 1;
            if r.verified && r.error.is_none() {
                s.verified += 1;
            }
        }
        stats
    }
}

/// Handle to a running job service. Dropping it without
/// [`JobService::drain`] detaches the dispatchers mid-stream; drain for
/// a graceful shutdown.
pub struct JobService {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    t0: Instant,
}

impl JobService {
    /// Validate the options and start the dispatcher pool. Engines are
    /// constructed lazily inside each dispatcher on its first job.
    pub fn start(cfg: SystemConfig, opts: ServiceOptions) -> Result<JobService> {
        cfg.validate()?;
        // Admission-time pre-flight: prove the plan every admitted job
        // will execute (decodability, replication, schedule
        // invariants) once, up front. A malformed spec is rejected
        // here as the typed `CamrError::Invalid` instead of failing
        // mid-round inside a dispatcher.
        crate::check::preflight(&crate::coordinator::master::Master::new(cfg.clone())?)?;
        if opts.engines == 0 {
            return Err(CamrError::InvalidConfig("service needs >= 1 engine".into()));
        }
        let tenants = opts.weights.len();
        let queue = DrrQueue::new(&opts.weights, opts.queue_capacity, opts.quantum)?;
        let shared = Arc::new(Shared {
            cfg,
            opts,
            state: Mutex::new(State {
                queue,
                closed: false,
                next_job: 0,
                submitted_per_tenant: vec![0; tenants],
                rejected_per_tenant: vec![0; tenants],
                results: Vec::new(),
            }),
            jobs_ready: Condvar::new(),
            space_free: Condvar::new(),
        });
        let handles = (0..shared.opts.engines)
            .map(|engine_idx| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || dispatcher(&shared, engine_idx))
            })
            .collect();
        Ok(JobService { shared, handles, t0: Instant::now() })
    }

    /// Number of tenant lanes.
    pub fn tenants(&self) -> usize {
        self.shared.opts.weights.len()
    }

    /// Jobs currently queued (all lanes).
    pub fn queue_len(&self) -> usize {
        self.lock().queue.len()
    }

    /// Admit a job, or reject it with the typed [`CamrError::QueueFull`]
    /// backpressure error when its tenant lane is at capacity. Returns
    /// the admission-ordered job id.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        let mut st = self.lock();
        self.admit(&mut st, spec, true)
    }

    /// Admit a job, blocking while its tenant lane is full. The first
    /// full-lane encounter still counts as one rejection, so rejection
    /// counters measure backpressure even for patient submitters.
    pub fn submit_blocking(&self, spec: JobSpec) -> Result<u64> {
        let mut st = self.lock();
        let mut counted = false;
        loop {
            match self.admit(&mut st, spec, !counted) {
                Err(CamrError::QueueFull(_)) => {
                    counted = true;
                    st = self.shared.space_free.wait(st).expect("service state poisoned");
                }
                other => return other,
            }
        }
    }

    /// Close admission, let the dispatchers finish every queued job,
    /// and collect the outcome. Blocks until the queue is fully drained.
    pub fn drain(self) -> Result<ServiceOutcome> {
        {
            let mut st = self.lock();
            st.closed = true;
        }
        self.shared.jobs_ready.notify_all();
        self.shared.space_free.notify_all();
        for h in self.handles {
            h.join()
                .map_err(|_| CamrError::Runtime("service dispatcher panicked".into()))?;
        }
        let mut st = self.shared.state.lock().expect("service state poisoned");
        debug_assert!(st.queue.is_empty(), "drain left jobs behind");
        let results = std::mem::take(&mut st.results);
        Ok(ServiceOutcome {
            submitted: st.next_job,
            rejected: st.rejected_per_tenant.iter().sum(),
            wall: self.t0.elapsed(),
            weights: self.shared.opts.weights.clone(),
            results,
        })
    }

    fn lock(&self) -> MutexGuard<'_, State> {
        self.shared.state.lock().expect("service state poisoned")
    }

    /// Enqueue under the held lock; shared by both submit flavors.
    fn admit(
        &self,
        st: &mut MutexGuard<'_, State>,
        spec: JobSpec,
        count_reject: bool,
    ) -> Result<u64> {
        if st.closed {
            return Err(CamrError::Runtime("service closed to new submissions".into()));
        }
        let job = st.next_job;
        let queued = Queued {
            job,
            spec,
            at: Instant::now(),
            qstart: self.shared.opts.tracer.sink().begin(),
        };
        match st.queue.try_push(spec.tenant, queued) {
            Ok(()) => {}
            Err(e) => {
                if count_reject {
                    if let (CamrError::QueueFull(_), Some(n)) =
                        (&e, st.rejected_per_tenant.get_mut(spec.tenant))
                    {
                        *n += 1;
                        if obs::metrics_enabled() {
                            obs::metrics().jobs_rejected.inc();
                        }
                    }
                }
                return Err(e);
            }
        }
        st.next_job += 1;
        st.submitted_per_tenant[spec.tenant] += 1;
        if obs::metrics_enabled() {
            obs::metrics().jobs_submitted.inc();
        }
        self.shared.jobs_ready.notify_one();
        Ok(job)
    }
}

/// One dispatcher: pop under DRR, run the round on a lazily-built
/// persistent engine, record the result. Exits when the service is
/// closed *and* the queue is empty — never before, so a drain loses
/// nothing.
fn dispatcher(shared: &Shared, engine_idx: usize) {
    let mut service_sink = shared.opts.tracer.sink();
    // Engine spans go to a dispatcher-local tracer so each job's
    // roll-up sees only its own round; spans are re-ingested into the
    // service tracer afterwards (same dance as the batch runtime).
    let local_tracer =
        if shared.opts.tracer.enabled() { Tracer::on() } else { Tracer::Off };
    let mut engine: Option<Box<dyn RoundEngine>> = None;
    loop {
        let q = {
            let mut st = shared.state.lock().expect("service state poisoned");
            loop {
                if let Some((_, q)) = st.queue.pop() {
                    shared.space_free.notify_one();
                    break q;
                }
                if st.closed {
                    return;
                }
                st = shared.jobs_ready.wait(st).expect("service state poisoned");
            }
        };
        let queue_ns = q.at.elapsed().as_nanos() as u64;
        service_sink.record(q.qstart, SpanKind::Queue, obs::COORD, q.job as usize, None, 0, 0);
        service_sink.flush();

        let t1 = Instant::now();
        let (verified, error, bytes, ledger) = run_round(shared, &mut engine, &local_tracer, &q);
        let exec_ns = t1.elapsed().as_nanos() as u64;
        let phases = if local_tracer.enabled() {
            let spans = local_tracer.take_spans();
            let rollup = obs::phase_rollup(&spans);
            shared.opts.tracer.ingest(spans);
            rollup
        } else {
            Vec::new()
        };
        if obs::metrics_enabled() {
            obs::metrics().jobs_completed.inc();
        }
        let result = JobResult {
            job: q.job,
            tenant: q.spec.tenant,
            kind: q.spec.kind,
            engine: engine_idx,
            verified,
            error,
            bytes,
            queue_ns,
            exec_ns,
            phases,
            ledger,
        };
        shared.state.lock().expect("service state poisoned").results.push(result);
    }
}

/// Execute one coded round for `q` on this dispatcher's engine,
/// building the engine on the first job. Failures come back as the
/// result tuple — a bad job must not take the dispatcher down.
fn run_round(
    shared: &Shared,
    engine: &mut Option<Box<dyn RoundEngine>>,
    tracer: &Tracer,
    q: &Queued,
) -> (bool, Option<String>, usize, Vec<Transmission>) {
    let fail = |e: CamrError| (false, Some(e.to_string()), 0, Vec::new());
    let wl = match build_native(q.spec.kind, &shared.cfg, q.spec.seed) {
        Ok(wl) => wl,
        Err(e) => return fail(e),
    };
    if let Some(eng) = engine.as_mut() {
        drop(eng.swap_workload(wl));
    } else {
        let built: Result<Box<dyn RoundEngine>> = if shared.opts.parallel {
            ParallelEngine::new(shared.cfg.clone(), wl).map(|mut e| {
                e.pooling = shared.opts.pooling;
                e.verify = shared.opts.verify;
                e.tracer = tracer.clone();
                Box::new(e) as Box<dyn RoundEngine>
            })
        } else {
            Engine::new(shared.cfg.clone(), wl).map(|mut e| {
                e.pooling = shared.opts.pooling;
                e.verify = shared.opts.verify;
                e.tracer = tracer.clone();
                Box::new(e) as Box<dyn RoundEngine>
            })
        };
        match built {
            Ok(e) => *engine = Some(e),
            Err(e) => return fail(e),
        }
    }
    let eng = engine.as_mut().expect("engine installed above");
    match eng.run_once() {
        Ok(out) => {
            let ledger = if shared.opts.capture_ledger {
                eng.ledger_bus().ledger().to_vec()
            } else {
                Vec::new()
            };
            drop(eng.grab_outputs()); // keep resident memory flat
            // `run` returns Err on verification failure, so reaching
            // here with verify on means the oracle passed; with verify
            // off nothing was checked and the job is *not* verified.
            (shared.opts.verify && out.verified, None, out.stage_bytes.iter().sum(), ledger)
        }
        Err(e) => {
            drop(eng.grab_outputs());
            fail(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> SystemConfig {
        SystemConfig::with_options(2, 2, 1, 1, 16).unwrap()
    }

    #[test]
    fn start_rejects_degenerate_options() {
        let mut o = ServiceOptions { engines: 0, ..ServiceOptions::default() };
        assert!(JobService::start(tiny_cfg(), o.clone()).is_err());
        o.engines = 1;
        o.weights = Vec::new();
        assert!(JobService::start(tiny_cfg(), o).is_err());
    }

    #[test]
    fn submit_after_drain_window_is_rejected() {
        let svc = JobService::start(tiny_cfg(), ServiceOptions::default()).unwrap();
        let spec = JobSpec { tenant: 0, kind: WorkloadKind::Synthetic, seed: 7 };
        svc.submit(spec).unwrap();
        // Mark closed the way drain does, then check the typed error.
        svc.lock().closed = true;
        let err = svc.submit(spec).unwrap_err();
        assert!(matches!(err, CamrError::Runtime(_)), "{err}");
        svc.lock().closed = false;
        let out = svc.drain().unwrap();
        assert_eq!(out.completed(), 1);
        assert!(out.all_verified());
    }

    #[test]
    fn unknown_tenant_is_a_config_error_not_a_reject() {
        let svc = JobService::start(tiny_cfg(), ServiceOptions::default()).unwrap();
        let err = svc
            .submit(JobSpec { tenant: 5, kind: WorkloadKind::Synthetic, seed: 1 })
            .unwrap_err();
        assert!(matches!(err, CamrError::InvalidConfig(_)), "{err}");
        let out = svc.drain().unwrap();
        assert_eq!(out.submitted, 0);
        assert_eq!(out.rejected, 0);
    }

    #[test]
    fn outcome_latency_percentiles_are_exact() {
        let mk = |job: u64, queue_ns: u64, exec_ns: u64| JobResult {
            job,
            tenant: 0,
            kind: WorkloadKind::Synthetic,
            engine: 0,
            verified: true,
            error: None,
            bytes: 0,
            queue_ns,
            exec_ns,
            phases: Vec::new(),
            ledger: Vec::new(),
        };
        let out = ServiceOutcome {
            results: (0..100).map(|i| mk(i, i as u64, 10)).collect(),
            submitted: 100,
            rejected: 0,
            wall: Duration::from_secs(1),
            weights: vec![1],
        };
        let (p50, p99, mean) = out.latency_ns(|r| r.queue_ns);
        assert_eq!((p50, p99), (50, 98));
        assert!((mean - 49.5).abs() < 1e-9);
        let (p50, _, _) = out.latency_ns(|r| r.sojourn_ns());
        assert_eq!(p50, 60);
    }
}
