//! Distributed matrix–vector products — the paper's §I motivation
//! ("matrix-vector multiplications performed during the forward and
//! backward propagation in neural networks. Computing each of these
//! products constitutes a job").
//!
//! Job `j` computes `y^{(j)} = A^{(j)} x^{(j)}` for an `M × D` layer
//! weight matrix. Subfile `n` is a column shard `A_n` (M × D/N) with the
//! matching slice `x_n`; its partial product `A_n x_n` is an M-vector,
//! and `y = Σ_n A_n x_n` — linear aggregation, Definition 1. Output
//! function `f` owns the row slice `[f·M/Q, (f+1)·M/Q)`.
//!
//! The shard product is computed by a pluggable [`ShardCompute`]:
//! - [`NativeShardCompute`] — straightforward rust loops (reference);
//! - `runtime::PjrtShardCompute` — the AOT-compiled JAX/Pallas kernel
//!   executed through PJRT (the L1/L2 layers of this repo).

use super::Workload;
use crate::agg::{lanes, Aggregator, SumF32, Value};
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::{JobId, SubfileId};
use std::sync::Arc;

/// Computes one shard's partial product `A_n x_n` (length M).
pub trait ShardCompute: Send + Sync {
    /// `a_shard` is row-major `M × cols`, `x_shard` has length `cols`.
    fn partial_product(&self, a_shard: &[f32], x_shard: &[f32], m: usize) -> Result<Vec<f32>>;

    /// Name for reports ("native", "pjrt").
    fn name(&self) -> &'static str;
}

/// Reference implementation in plain rust.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeShardCompute;

impl ShardCompute for NativeShardCompute {
    fn partial_product(&self, a_shard: &[f32], x_shard: &[f32], m: usize) -> Result<Vec<f32>> {
        let cols = x_shard.len();
        if a_shard.len() != m * cols {
            return Err(CamrError::Aggregation(format!(
                "shard shape mismatch: {} != {m}×{cols}",
                a_shard.len()
            )));
        }
        let mut y = vec![0f32; m];
        for (r, yr) in y.iter_mut().enumerate() {
            let row = &a_shard[r * cols..(r + 1) * cols];
            let mut acc = 0f32;
            for (a, x) in row.iter().zip(x_shard) {
                acc += a * x;
            }
            *yr = acc;
        }
        Ok(y)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The distributed matvec workload.
pub struct MatVecWorkload {
    /// Row-major `M × D` matrices, one per job.
    matrices: Vec<Vec<f32>>,
    /// Input vectors, one per job (length D).
    vectors: Vec<Vec<f32>>,
    m: usize,
    d: usize,
    subfiles: usize,
    funcs: usize,
    rows_per_func: usize,
    compute: Arc<dyn ShardCompute>,
    agg: SumF32,
}

impl MatVecWorkload {
    /// Build with deterministic pseudo-random layer weights.
    ///
    /// `rows_per_func` sets `M = Q · rows_per_func`; the value size is
    /// `4 · rows_per_func` bytes and must equal `cfg.value_bytes`.
    /// `cols_per_subfile` sets `D = N · cols_per_subfile`.
    pub fn synthetic(
        cfg: &SystemConfig,
        seed: u64,
        rows_per_func: usize,
        cols_per_subfile: usize,
        compute: Arc<dyn ShardCompute>,
    ) -> Result<Self> {
        if cfg.value_bytes != 4 * rows_per_func {
            return Err(CamrError::InvalidConfig(format!(
                "matvec values are 4·rows_per_func = {} bytes but config B = {}",
                4 * rows_per_func,
                cfg.value_bytes
            )));
        }
        let m = cfg.functions() * rows_per_func;
        let d = cfg.subfiles() * cols_per_subfile;
        let mut state = seed | 1;
        let mut next = move || {
            // xorshift64* → f32 in [-1, 1).
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            let v = state.wrapping_mul(0x2545F4914F6CDD1D);
            ((v >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
        };
        let matrices: Vec<Vec<f32>> =
            (0..cfg.jobs()).map(|_| (0..m * d).map(|_| next() * 0.1).collect()).collect();
        let vectors: Vec<Vec<f32>> =
            (0..cfg.jobs()).map(|_| (0..d).map(|_| next()).collect()).collect();
        Ok(MatVecWorkload {
            matrices,
            vectors,
            m,
            d,
            subfiles: cfg.subfiles(),
            funcs: cfg.functions(),
            rows_per_func,
            compute,
            agg: SumF32,
        })
    }

    /// Column count per subfile shard.
    pub fn cols_per_subfile(&self) -> usize {
        self.d / self.subfiles
    }

    /// Extract the column shard `A_n` (row-major `M × cols`) and `x_n`.
    pub fn shard(&self, job: JobId, subfile: SubfileId) -> (Vec<f32>, Vec<f32>) {
        let cols = self.cols_per_subfile();
        let lo = subfile * cols;
        let a = &self.matrices[job];
        let mut a_shard = Vec::with_capacity(self.m * cols);
        for r in 0..self.m {
            a_shard.extend_from_slice(&a[r * self.d + lo..r * self.d + lo + cols]);
        }
        let x_shard = self.vectors[job][lo..lo + cols].to_vec();
        (a_shard, x_shard)
    }

    /// Single-node full product (test/verification helper).
    pub fn full_product(&self, job: JobId) -> Vec<f32> {
        let a = &self.matrices[job];
        let x = &self.vectors[job];
        (0..self.m)
            .map(|r| a[r * self.d..(r + 1) * self.d].iter().zip(x).map(|(p, q)| p * q).sum())
            .collect()
    }

    /// The backend used for shard products.
    pub fn compute_name(&self) -> &'static str {
        self.compute.name()
    }
}

impl Workload for MatVecWorkload {
    fn name(&self) -> &str {
        "matvec"
    }

    fn aggregator(&self) -> &dyn Aggregator {
        &self.agg
    }

    fn map_subfile(&self, job: JobId, subfile: SubfileId) -> Result<Vec<Value>> {
        let (a_shard, x_shard) = self.shard(job, subfile);
        let y = self.compute.partial_product(&a_shard, &x_shard, self.m)?;
        Ok((0..self.funcs)
            .map(|f| {
                lanes::from_f32(&y[f * self.rows_per_func..(f + 1) * self.rows_per_func])
            })
            .collect())
    }

    fn tolerance(&self) -> Option<f32> {
        Some(2e-4) // f32 sums are order-sensitive across batches
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;

    fn cfg_for(rows_per_func: usize) -> SystemConfig {
        SystemConfig::with_options(3, 2, 2, 1, 4 * rows_per_func).unwrap()
    }

    #[test]
    fn shards_partition_the_product() {
        let cfg = cfg_for(4);
        let wl =
            MatVecWorkload::synthetic(&cfg, 7, 4, 3, Arc::new(NativeShardCompute)).unwrap();
        // Sum of partial products over all subfiles == full product.
        for job in 0..cfg.jobs() {
            let mut acc = vec![0f32; wl.m];
            for n in 0..cfg.subfiles() {
                let (a, x) = wl.shard(job, n);
                let p = NativeShardCompute.partial_product(&a, &x, wl.m).unwrap();
                for (s, v) in acc.iter_mut().zip(&p) {
                    *s += v;
                }
            }
            let full = wl.full_product(job);
            for (a, b) in acc.iter().zip(&full) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn rejects_mismatched_value_bytes() {
        let cfg = SystemConfig::with_options(3, 2, 2, 1, 64).unwrap();
        assert!(
            MatVecWorkload::synthetic(&cfg, 7, 4, 3, Arc::new(NativeShardCompute)).is_err()
        );
    }

    #[test]
    fn native_rejects_bad_shapes() {
        let e = NativeShardCompute.partial_product(&[0.0; 10], &[0.0; 3], 4);
        assert!(e.is_err());
    }

    #[test]
    fn end_to_end_matvec_verifies() {
        // Full pipeline on NN-layer matvec jobs; reduce must reproduce
        // every y^{(j)} row slice within f32 tolerance.
        let cfg = cfg_for(4);
        let wl =
            MatVecWorkload::synthetic(&cfg, 42, 4, 5, Arc::new(NativeShardCompute)).unwrap();
        let full: Vec<Vec<f32>> = (0..cfg.jobs()).map(|j| wl.full_product(j)).collect();
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified);
        assert!((out.total_load() - 1.0).abs() < 1e-12);
        // Outputs really are the row slices of A x.
        for j in 0..cfg.jobs() {
            for f in 0..cfg.functions() {
                let got = lanes::as_f32(e.output(j, f).unwrap());
                let want = &full[j][f * 4..(f + 1) * 4];
                for (x, y) in got.iter().zip(want) {
                    assert!((x - y).abs() < 2e-4 * 1.0f32.max(y.abs()), "{x} vs {y}");
                }
            }
        }
    }
}
