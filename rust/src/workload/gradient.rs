//! Distributed gradient aggregation — the paper's SGD motivation (§I,
//! citing gradient coding [11]).
//!
//! Job `j` trains a linear model `w^{(j)}` on its own dataset; subfile
//! `n` is a minibatch shard. The map computes the shard's gradient of the
//! squared loss, `g_n = X_n^T (X_n w - y_n)`; the full gradient is the
//! sum over shards — linear aggregation again. Output function `f` owns
//! the slice `[f·P/Q, (f+1)·P/Q)` of the parameter vector.

use super::Workload;
use crate::agg::{lanes, Aggregator, SumF32, Value};
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::{JobId, SubfileId};

/// Linear-regression gradient workload.
#[derive(Clone)]
pub struct GradientWorkload {
    /// Per-job parameter vectors `w` (length P).
    weights: Vec<Vec<f32>>,
    /// `data[j][n]` = (X_n row-major `samples × P`, y_n length `samples`).
    data: Vec<Vec<(Vec<f32>, Vec<f32>)>>,
    params: usize,
    funcs: usize,
    params_per_func: usize,
    samples_per_shard: usize,
    agg: SumF32,
}

impl GradientWorkload {
    /// Deterministic synthetic regression problems.
    ///
    /// `params_per_func` sets `P = Q · params_per_func`; `value_bytes`
    /// must equal `4 · params_per_func`.
    pub fn synthetic(
        cfg: &SystemConfig,
        seed: u64,
        params_per_func: usize,
        samples_per_shard: usize,
    ) -> Result<Self> {
        if cfg.value_bytes != 4 * params_per_func {
            return Err(CamrError::InvalidConfig(format!(
                "gradient values are 4·params_per_func = {} bytes but config B = {}",
                4 * params_per_func,
                cfg.value_bytes
            )));
        }
        let p = cfg.functions() * params_per_func;
        let mut state = seed | 1;
        let mut next = move || {
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            let v = state.wrapping_mul(0x2545F4914F6CDD1D);
            ((v >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
        };
        let weights: Vec<Vec<f32>> =
            (0..cfg.jobs()).map(|_| (0..p).map(|_| next() * 0.5).collect()).collect();
        let data: Vec<Vec<(Vec<f32>, Vec<f32>)>> = (0..cfg.jobs())
            .map(|_| {
                (0..cfg.subfiles())
                    .map(|_| {
                        let x: Vec<f32> =
                            (0..samples_per_shard * p).map(|_| next() * 0.2).collect();
                        let y: Vec<f32> = (0..samples_per_shard).map(|_| next()).collect();
                        (x, y)
                    })
                    .collect()
            })
            .collect();
        Ok(GradientWorkload {
            weights,
            data,
            params: p,
            funcs: cfg.functions(),
            params_per_func,
            samples_per_shard,
            agg: SumF32,
        })
    }

    /// Shard gradient `g_n = X_n^T (X_n w - y_n)` (length P).
    pub fn shard_gradient(&self, job: JobId, subfile: SubfileId) -> Vec<f32> {
        let w = &self.weights[job];
        let (x, y) = &self.data[job][subfile];
        let s = self.samples_per_shard;
        let p = self.params;
        // residual r = X w - y
        let mut r = vec![0f32; s];
        for (i, ri) in r.iter_mut().enumerate() {
            let row = &x[i * p..(i + 1) * p];
            *ri = row.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() - y[i];
        }
        // g = X^T r
        let mut g = vec![0f32; p];
        for i in 0..s {
            let row = &x[i * p..(i + 1) * p];
            for (gj, a) in g.iter_mut().zip(row) {
                *gj += a * r[i];
            }
        }
        g
    }

    /// Total squared loss of one job's model over all shards.
    pub fn loss(&self, job: JobId) -> f32 {
        let w = &self.weights[job];
        let p = self.params;
        let mut total = 0f32;
        for (x, y) in &self.data[job] {
            for i in 0..self.samples_per_shard {
                let row = &x[i * p..(i + 1) * p];
                let pred: f32 = row.iter().zip(w).map(|(a, b)| a * b).sum();
                total += (pred - y[i]).powi(2);
            }
        }
        total * 0.5
    }

    /// A copy of this workload after one SGD step `w -= lr·g` per job.
    pub fn stepped(&self, grads: &[Vec<f32>], lr: f32) -> Self {
        let mut next = self.clone();
        for (w, g) in next.weights.iter_mut().zip(grads) {
            for (wi, gi) in w.iter_mut().zip(g) {
                *wi -= lr * gi;
            }
        }
        next
    }

    /// Full gradient over all shards (verification helper).
    pub fn full_gradient(&self, job: JobId) -> Vec<f32> {
        let mut acc = vec![0f32; self.params];
        for n in 0..self.data[job].len() {
            for (a, b) in acc.iter_mut().zip(self.shard_gradient(job, n)) {
                *a += b;
            }
        }
        acc
    }
}

impl Workload for GradientWorkload {
    fn name(&self) -> &str {
        "gradient"
    }

    fn aggregator(&self) -> &dyn Aggregator {
        &self.agg
    }

    fn map_subfile(&self, job: JobId, subfile: SubfileId) -> Result<Vec<Value>> {
        let g = self.shard_gradient(job, subfile);
        Ok((0..self.funcs)
            .map(|f| {
                lanes::from_f32(&g[f * self.params_per_func..(f + 1) * self.params_per_func])
            })
            .collect())
    }

    fn tolerance(&self) -> Option<f32> {
        Some(2e-4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;

    #[test]
    fn shard_gradients_sum_to_full() {
        let cfg = SystemConfig::with_options(3, 2, 1, 1, 8).unwrap();
        let wl = GradientWorkload::synthetic(&cfg, 11, 2, 4).unwrap();
        let full = wl.full_gradient(0);
        let mut acc = vec![0f32; full.len()];
        for n in 0..cfg.subfiles() {
            for (a, b) in acc.iter_mut().zip(wl.shard_gradient(0, n)) {
                *a += b;
            }
        }
        for (a, b) in acc.iter().zip(&full) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn gradient_descends_loss() {
        // Sanity: stepping against the aggregated gradient reduces the
        // squared loss — the values being shuffled are real gradients.
        let cfg = SystemConfig::with_options(3, 2, 1, 1, 8).unwrap();
        let wl = GradientWorkload::synthetic(&cfg, 5, 2, 4).unwrap();
        let job = 0;
        let loss = |w: &[f32]| -> f32 {
            let mut total = 0f32;
            for n in 0..cfg.subfiles() {
                let (x, y) = &wl.data[job][n];
                for i in 0..wl.samples_per_shard {
                    let row = &x[i * wl.params..(i + 1) * wl.params];
                    let pred: f32 = row.iter().zip(w).map(|(a, b)| a * b).sum();
                    total += (pred - y[i]).powi(2);
                }
            }
            total * 0.5
        };
        let w0 = wl.weights[job].clone();
        let g = wl.full_gradient(job);
        let w1: Vec<f32> = w0.iter().zip(&g).map(|(w, gi)| w - 0.05 * gi).collect();
        assert!(loss(&w1) < loss(&w0));
    }

    #[test]
    fn stepped_reduces_loss() {
        let cfg = SystemConfig::with_options(3, 2, 1, 1, 8).unwrap();
        let wl = GradientWorkload::synthetic(&cfg, 13, 2, 4).unwrap();
        let grads: Vec<Vec<f32>> = (0..cfg.jobs()).map(|j| wl.full_gradient(j)).collect();
        let next = wl.stepped(&grads, 0.05);
        for j in 0..cfg.jobs() {
            assert!(next.loss(j) < wl.loss(j), "job {j}");
        }
    }

    #[test]
    fn end_to_end_gradient_verifies() {
        let cfg = SystemConfig::with_options(3, 2, 2, 1, 8).unwrap();
        let wl = GradientWorkload::synthetic(&cfg, 77, 2, 3).unwrap();
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified);
        assert!((out.total_load() - 1.0).abs() < 1e-12);
    }
}
