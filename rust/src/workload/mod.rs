//! Workloads: the pluggable map computations CAMR coordinates.
//!
//! A [`Workload`] defines how a subfile of a job maps to `Q` intermediate
//! values (one per output function), which aggregator combines them, and
//! how reduce outputs are verified. The paper's motivating applications
//! are all here: word counting (Example 1), matrix–vector products for
//! neural-network layers (§I), and distributed gradient aggregation.

pub mod gradient;
pub mod matvec;
pub mod stream;
pub mod synth;
pub mod wordcount;

use crate::agg::{Aggregator, Value};
use crate::config::{SystemConfig, WorkloadKind};
use crate::error::Result;
use crate::{FuncId, JobId, SubfileId};

/// Build the native (non-PJRT) workload for a [`WorkloadKind`]. This is
/// the deterministic `(kind, cfg, seed) → workload` constructor both the
/// CLI and socket-transport worker processes use, so every process of a
/// distributed run reconstructs bit-identical data from the config text
/// alone.
pub fn build_native(
    kind: WorkloadKind,
    cfg: &SystemConfig,
    seed: u64,
) -> Result<Box<dyn Workload>> {
    Ok(match kind {
        WorkloadKind::WordCount => Box::new(wordcount::WordCountWorkload::synthetic(cfg, seed, 40)),
        WorkloadKind::Synthetic => Box::new(synth::SyntheticWorkload::new(cfg, seed)),
        WorkloadKind::Gradient => {
            let params_per_func = cfg.value_bytes / 4;
            Box::new(gradient::GradientWorkload::synthetic(cfg, seed, params_per_func, 4)?)
        }
        WorkloadKind::MatVec => {
            let rows_per_func = cfg.value_bytes / 4;
            let compute: std::sync::Arc<dyn matvec::ShardCompute> =
                std::sync::Arc::new(matvec::NativeShardCompute);
            Box::new(matvec::MatVecWorkload::synthetic(cfg, seed, rows_per_func, 8, compute)?)
        }
        // Stream geometry comes from CAMR_STREAM_* env vars; worker
        // subprocesses inherit the environment, so every process of a
        // socket-transport run reconstructs the identical stream.
        WorkloadKind::Streamed => Box::new(stream::StreamedWorkload::from_env(cfg, seed)?),
    })
}

/// A distributed computation with aggregatable intermediate values
/// (paper Definition 1).
pub trait Workload: Send + Sync {
    /// Human-readable name.
    fn name(&self) -> &str;

    /// The combiner for this workload's values.
    fn aggregator(&self) -> &dyn Aggregator;

    /// Map one subfile of one job to its `Q` intermediate values
    /// `ν^{(j)}_{q,n}` — each exactly `value_bytes` long.
    fn map_subfile(&self, job: JobId, subfile: SubfileId) -> Result<Vec<Value>>;

    /// Verification tolerance per f32 lane; `None` means bit-exact
    /// (integer aggregators). Floating-point sums are order-sensitive,
    /// so f32 workloads verify with a small tolerance.
    fn tolerance(&self) -> Option<f32> {
        None
    }

    /// Single-node oracle for `φ_f^{(j)}`: aggregate over all subfiles.
    /// The default maps every subfile; workloads with a closed form may
    /// override for speed.
    fn oracle(&self, cfg: &SystemConfig, job: JobId, func: FuncId) -> Result<Value> {
        let agg = self.aggregator();
        let mut acc = agg.identity(cfg.value_bytes);
        for n in 0..cfg.subfiles() {
            let vals = self.map_subfile(job, n)?;
            acc = agg.combine(&acc, &vals[func])?;
        }
        Ok(acc)
    }
}

/// Compare a reduced output against the oracle value under the
/// workload's tolerance. Returns Ok(()) or a descriptive error.
pub fn check_output(
    wl: &dyn Workload,
    job: JobId,
    func: FuncId,
    got: &[u8],
    want: &[u8],
) -> Result<()> {
    use crate::error::CamrError;
    match wl.tolerance() {
        None => {
            if got != want {
                return Err(CamrError::Verification(format!(
                    "{}: job {job} func {func}: bit-exact mismatch",
                    wl.name()
                )));
            }
        }
        Some(tol) => {
            let g = crate::agg::lanes::as_f32(got);
            let w = crate::agg::lanes::as_f32(want);
            if g.len() != w.len() {
                return Err(CamrError::Verification(format!(
                    "{}: job {job} func {func}: lane count mismatch",
                    wl.name()
                )));
            }
            for (i, (x, y)) in g.iter().zip(&w).enumerate() {
                let scale = 1.0f32.max(y.abs());
                if (x - y).abs() > tol * scale {
                    return Err(CamrError::Verification(format!(
                        "{}: job {job} func {func} lane {i}: {x} vs {y} (tol {tol})",
                        wl.name()
                    )));
                }
            }
        }
    }
    Ok(())
}
