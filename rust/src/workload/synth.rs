//! Synthetic workload: deterministic pseudo-random u64-lane values.
//!
//! Used for load/stress testing and property tests — the values carry no
//! meaning, but reduces are still verified bit-exactly against the
//! oracle, which exercises the full shuffle machinery on arbitrary data.

use super::Workload;
use crate::agg::{Aggregator, SumU64, Value};
use crate::config::SystemConfig;
use crate::error::Result;
use crate::{JobId, SubfileId};

/// Deterministic synthetic values derived from (seed, job, subfile, func).
pub struct SyntheticWorkload {
    seed: u64,
    funcs: usize,
    value_bytes: usize,
    agg: SumU64,
}

impl SyntheticWorkload {
    /// Build for a config; `value_bytes` must be a multiple of 8 — the
    /// config default (64) is.
    pub fn new(cfg: &SystemConfig, seed: u64) -> Self {
        assert!(cfg.value_bytes % 8 == 0, "synthetic values use u64 lanes");
        SyntheticWorkload {
            seed,
            funcs: cfg.functions(),
            value_bytes: cfg.value_bytes,
            agg: SumU64,
        }
    }

    /// splitmix64 — tiny, deterministic, good avalanche.
    fn mix(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = x;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

impl Workload for SyntheticWorkload {
    fn name(&self) -> &str {
        "synthetic"
    }

    fn aggregator(&self) -> &dyn Aggregator {
        &self.agg
    }

    fn map_subfile(&self, job: JobId, subfile: SubfileId) -> Result<Vec<Value>> {
        let lanes = self.value_bytes / 8;
        Ok((0..self.funcs)
            .map(|f| {
                let mut v = Vec::with_capacity(self.value_bytes);
                for lane in 0..lanes {
                    let x = Self::mix(
                        self.seed
                            ^ (job as u64) << 40
                            ^ (subfile as u64) << 24
                            ^ (f as u64) << 8
                            ^ lane as u64,
                    );
                    v.extend_from_slice(&x.to_le_bytes());
                }
                v
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_calls() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 7);
        assert_eq!(wl.map_subfile(1, 2).unwrap(), wl.map_subfile(1, 2).unwrap());
    }

    #[test]
    fn distinct_inputs_give_distinct_values() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 7);
        let a = wl.map_subfile(0, 0).unwrap();
        let b = wl.map_subfile(0, 1).unwrap();
        assert_ne!(a[0], b[0]);
        assert_ne!(a[0], a[1]); // different funcs differ too
    }

    #[test]
    fn seeds_change_values() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let w1 = SyntheticWorkload::new(&cfg, 1);
        let w2 = SyntheticWorkload::new(&cfg, 2);
        assert_ne!(w1.map_subfile(0, 0).unwrap(), w2.map_subfile(0, 0).unwrap());
    }

    #[test]
    fn value_sizes_match_config() {
        let cfg = SystemConfig::with_options(3, 2, 1, 1, 128).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 0);
        let vals = wl.map_subfile(0, 0).unwrap();
        assert_eq!(vals.len(), cfg.functions());
        assert!(vals.iter().all(|v| v.len() == 128));
    }
}
