//! Word counting over books — the paper's Example 1.
//!
//! Job `j` is a book of `N` chapters (subfiles); output function
//! `φ_q^{(j)}` counts occurrences of word `χ_q^{(j)}` across the book.
//! Counts are linearly aggregatable: the count over a batch of chapters
//! is the sum of per-chapter counts — exactly Definition 1.
//!
//! Values are u64 lanes; lane 0 carries the count (remaining lanes are
//! zero so any configured `B` works and the load accounting stays
//! faithful to "every value is B bytes").

use super::Workload;
use crate::agg::{lanes, Aggregator, SumU64, Value};
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::util::rng::SplitMix64;
use crate::{FuncId, JobId, SubfileId};

/// A corpus: `books[j][n]` = the word list of chapter `n` of book `j`.
pub struct WordCountWorkload {
    books: Vec<Vec<Vec<String>>>,
    /// `vocab[j][q]` = the word counted by `φ_q` for book `j` (the paper
    /// allows per-job function sets `A^{(j)}`).
    vocab: Vec<Vec<String>>,
    value_bytes: usize,
    agg: SumU64,
}

impl WordCountWorkload {
    /// Build from an explicit corpus and per-job vocabularies.
    pub fn from_corpus(
        cfg: &SystemConfig,
        books: Vec<Vec<Vec<String>>>,
        vocab: Vec<Vec<String>>,
    ) -> Result<Self> {
        if cfg.value_bytes % 8 != 0 {
            return Err(CamrError::InvalidConfig(
                "word count uses u64 lanes; value_bytes must be a multiple of 8".into(),
            ));
        }
        if books.len() != cfg.jobs() || vocab.len() != cfg.jobs() {
            return Err(CamrError::InvalidConfig(format!(
                "corpus has {} books / {} vocabs, config needs J = {}",
                books.len(),
                vocab.len(),
                cfg.jobs()
            )));
        }
        for (j, book) in books.iter().enumerate() {
            if book.len() != cfg.subfiles() {
                return Err(CamrError::InvalidConfig(format!(
                    "book {j} has {} chapters, config needs N = {}",
                    book.len(),
                    cfg.subfiles()
                )));
            }
            if vocab[j].len() != cfg.functions() {
                return Err(CamrError::InvalidConfig(format!(
                    "book {j} vocab has {} words, config needs Q = {}",
                    vocab[j].len(),
                    cfg.functions()
                )));
            }
        }
        Ok(WordCountWorkload { books, vocab, value_bytes: cfg.value_bytes, agg: SumU64 })
    }

    /// The paper's Example 1: J = 4 books, N = 6 chapters, Q = 6 words,
    /// deterministic tiny corpus.
    pub fn example1(cfg: &SystemConfig) -> Self {
        Self::synthetic(cfg, 0x1EE7, 40)
    }

    /// Deterministic synthetic corpus: each chapter is `words_per_chapter`
    /// draws from the job's Q-word vocabulary (plus filler words).
    pub fn synthetic(cfg: &SystemConfig, seed: u64, words_per_chapter: usize) -> Self {
        let base: Vec<&str> = vec![
            "coded", "shuffle", "aggregate", "mapreduce", "resolvable", "design", "parity",
            "batch", "owner", "class", "multicast", "packet", "load", "storage", "job",
            "server",
        ];
        let mut rng = SplitMix64::new(seed);
        let jobs = cfg.jobs();
        let vocab: Vec<Vec<String>> = (0..jobs)
            .map(|j| {
                (0..cfg.functions())
                    .map(|q| format!("{}_{}", base[q % base.len()], j))
                    .collect()
            })
            .collect();
        let books: Vec<Vec<Vec<String>>> = (0..jobs)
            .map(|j| {
                (0..cfg.subfiles())
                    .map(|_| {
                        (0..words_per_chapter)
                            .map(|_| {
                                // ~70% vocab words, 30% filler.
                                if rng.chance(0.7) {
                                    vocab[j][rng.range(0, vocab[j].len())].clone()
                                } else {
                                    format!("filler_{}", rng.range(0, 32))
                                }
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        Self::from_corpus(cfg, books, vocab).expect("synthetic corpus is well-formed")
    }

    /// Direct count of `vocab[j][q]` in chapter `n` (test helper).
    pub fn count(&self, job: JobId, subfile: SubfileId, func: FuncId) -> u64 {
        let word = &self.vocab[job][func];
        self.books[job][subfile].iter().filter(|w| *w == word).count() as u64
    }
}

impl Workload for WordCountWorkload {
    fn name(&self) -> &str {
        "wordcount"
    }

    fn aggregator(&self) -> &dyn Aggregator {
        &self.agg
    }

    fn map_subfile(&self, job: JobId, subfile: SubfileId) -> Result<Vec<Value>> {
        if job >= self.books.len() || subfile >= self.books[job].len() {
            return Err(CamrError::MissingValue(format!(
                "no chapter {subfile} in book {job}"
            )));
        }
        let lanes_n = self.value_bytes / 8;
        Ok((0..self.vocab[job].len())
            .map(|q| {
                let mut v = vec![0u64; lanes_n];
                v[0] = self.count(job, subfile, q);
                lanes::from_u64(&v)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;

    #[test]
    fn counts_are_exact() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let books = vec![
            vec![vec!["a".to_string(), "b".into(), "a".into()]; 6],
            vec![vec!["b".to_string(); 3]; 6],
            vec![vec!["a".to_string()]; 6],
            vec![vec!["c".to_string(), "c".into()]; 6],
        ];
        let vocab: Vec<Vec<String>> = (0..4)
            .map(|_| vec!["a".into(), "b".into(), "c".into(), "d".into(), "e".into(), "f".into()])
            .collect();
        let wl = WordCountWorkload::from_corpus(&cfg, books, vocab).unwrap();
        assert_eq!(wl.count(0, 0, 0), 2); // "a" twice in book 0 chapters
        assert_eq!(wl.count(1, 3, 1), 3); // "b" thrice in book 1
        assert_eq!(wl.count(2, 0, 1), 0);
        let vals = wl.map_subfile(0, 0).unwrap();
        assert_eq!(lanes::as_u64(&vals[0])[0], 2);
        assert_eq!(lanes::as_u64(&vals[1])[0], 1);
    }

    #[test]
    fn rejects_malformed_corpus() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let books = vec![vec![vec!["a".to_string()]; 5]; 4]; // 5 chapters != N=6
        let vocab = vec![vec!["a".to_string(); 6]; 4];
        assert!(WordCountWorkload::from_corpus(&cfg, books, vocab).is_err());
    }

    #[test]
    fn example1_end_to_end_counts_match_oracle() {
        // The full Example-1 pipeline: synthetic corpus, coded shuffle,
        // bit-exact verification, measured load = 1.
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = WordCountWorkload::example1(&cfg);
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified);
        assert!((out.total_load() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reduced_counts_equal_direct_totals() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = WordCountWorkload::synthetic(&cfg, 99, 25);
        // Direct totals computed before the engine consumes the workload.
        let mut totals = vec![vec![0u64; cfg.functions()]; cfg.jobs()];
        for j in 0..cfg.jobs() {
            for f in 0..cfg.functions() {
                for n in 0..cfg.subfiles() {
                    totals[j][f] += wl.count(j, n, f);
                }
            }
        }
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.run().unwrap();
        for j in 0..cfg.jobs() {
            for f in 0..cfg.functions() {
                let got = lanes::as_u64(e.output(j, f).unwrap())[0];
                assert_eq!(got, totals[j][f], "job {j} func {f}");
            }
        }
    }
}
