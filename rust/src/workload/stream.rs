//! Streamed huge-payload workload: map phases fold over pooled chunks
//! of a byte stream instead of materializing whole subfiles.
//!
//! The paper's regime of interest has subfiles in the hundreds of MB
//! (§V sizes shuffles by `B` per value, but the *inputs* each mapper
//! reads dwarf the intermediate values). Materializing a 256 MB subfile
//! per map call would make the runtime's memory high-water mark
//! `O(subfile)` per in-flight map and the allocator — not the shuffle —
//! the bottleneck. This module streams instead: a [`StreamSource`]
//! yields the subfile's byte range chunk by chunk through **one**
//! recycled [`BufferPool`] buffer (the pool's large size class, see
//! `shuffle::buf`), and the map folds each chunk into its `Q`
//! intermediate values as it goes. Peak memory is one chunk, not one
//! subfile, and the chunk buffer is shared across every map call on the
//! pool.
//!
//! The digest is **chunk-size independent**: values are a function of
//! the subfile's absolute word stream only, so any `chunk_bytes` (and
//! any mix of short reads from the source) reduces to bit-identical
//! outputs. Tests pin that invariant, and the socket plane relies on it
//! — worker processes inherit the stream geometry via environment
//! variables and must reconstruct the same values from config text
//! alone.

use super::Workload;
use crate::agg::{Aggregator, SumU64, Value};
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};
use crate::shuffle::buf::BufferPool;
use crate::{JobId, SubfileId};
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::ops::Range;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Default subfile extent for env-configured streamed runs (1 MiB —
/// small enough for tests, overridable up to the 256 MB regime).
pub const DEFAULT_SUBFILE_BYTES: u64 = 1 << 20;

/// Default chunk checkout size for env-configured streamed runs.
pub const DEFAULT_CHUNK_BYTES: usize = 256 << 10;

/// A random-access byte stream the streamed workload reads from.
///
/// `read_at` is positional (no cursor shared between callers), so one
/// source serves concurrent map calls from the parallel engine.
pub trait StreamSource: Send + Sync {
    /// Total stream length in bytes.
    fn len(&self) -> u64;

    /// True when the stream holds zero bytes.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read up to `buf.len()` bytes at absolute `offset`, returning the
    /// count read. Returns `Ok(0)` only at end of stream. Short reads
    /// mid-stream are allowed; callers loop.
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize>;
}

/// A real file as a [`StreamSource`] (set `CAMR_STREAM_FILE` to use one
/// as the streamed workload's input). Positional reads go through one
/// mutex-guarded seek+read handle — correctness over parallel read
/// throughput; swap in `pread` per-thread handles if a profile ever
/// says the lock is hot.
pub struct FileSource {
    file: Mutex<File>,
    len: u64,
}

impl FileSource {
    /// Open `path` and capture its current length.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        Ok(FileSource { file: Mutex::new(file), len })
    }
}

impl StreamSource for FileSource {
    fn len(&self) -> u64 {
        self.len
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        if offset >= self.len || buf.is_empty() {
            return Ok(0);
        }
        let mut f = self.file.lock().expect("file source poisoned");
        f.seek(SeekFrom::Start(offset))?;
        let n = f.read(buf)?;
        Ok(n)
    }
}

/// splitmix64 — the same tiny deterministic mixer the synthetic
/// workload uses.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A deterministic pseudo-random stream generated on the fly — no disk,
/// no materialization, any length. Byte `p` is byte `p % 8` of
/// `mix(seed ^ (p / 8))`, so reads are position-pure: every process
/// that knows `(seed, len)` sees the identical stream.
pub struct SyntheticSource {
    seed: u64,
    len: u64,
}

impl SyntheticSource {
    /// A stream of `len` bytes derived from `seed`.
    pub fn new(seed: u64, len: u64) -> Self {
        SyntheticSource { seed, len }
    }
}

impl StreamSource for SyntheticSource {
    fn len(&self) -> u64 {
        self.len
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        if offset >= self.len {
            return Ok(0);
        }
        let n = buf.len().min((self.len - offset) as usize);
        let out = &mut buf[..n];
        let mut pos = offset;
        let end = offset + n as u64;
        let mut i = 0usize;
        // Partial word at the head, whole words, partial word at the
        // tail — word-at-a-time in the middle keeps synthetic streaming
        // benches from being bound by the generator.
        while pos < end && pos % 8 != 0 {
            out[i] = mix(self.seed ^ (pos / 8)).to_le_bytes()[(pos % 8) as usize];
            pos += 1;
            i += 1;
        }
        while pos + 8 <= end {
            out[i..i + 8].copy_from_slice(&mix(self.seed ^ (pos / 8)).to_le_bytes());
            pos += 8;
            i += 8;
        }
        while pos < end {
            out[i] = mix(self.seed ^ (pos / 8)).to_le_bytes()[(pos % 8) as usize];
            pos += 1;
            i += 1;
        }
        Ok(n)
    }
}

/// Fold `f` over `range` of `src` in `chunk_bytes` pieces, reusing one
/// pooled buffer for every chunk. `f` receives the chunk's absolute
/// start offset and its (full-or-final-partial) bytes. The range is
/// clamped to the source length.
pub fn fold_chunks<T>(
    src: &dyn StreamSource,
    range: Range<u64>,
    chunk_bytes: usize,
    pool: &BufferPool,
    mut acc: T,
    mut f: impl FnMut(u64, &[u8], &mut T) -> Result<()>,
) -> Result<T> {
    if chunk_bytes == 0 {
        return Err(CamrError::InvalidConfig("stream chunk_bytes must be > 0".into()));
    }
    let end = range.end.min(src.len());
    let mut offset = range.start;
    // One checkout serves the whole fold; contents are fully
    // overwritten before each use, so the unzeroed acquire is safe.
    let mut chunk = pool.acquire_unzeroed(chunk_bytes);
    while offset < end {
        let want = chunk_bytes.min((end - offset) as usize);
        let buf = &mut chunk.as_mut_slice()[..want];
        // Loop short reads so `f` only ever sees full chunks (except
        // the final partial one) — chunk boundaries must be stable for
        // chunk-size-independent digests.
        let mut filled = 0usize;
        while filled < want {
            let n = src.read_at(offset + filled as u64, &mut buf[filled..])?;
            if n == 0 {
                return Err(CamrError::Runtime(format!(
                    "stream source ended early at byte {} (len {} claimed)",
                    offset + filled as u64,
                    src.len()
                )));
            }
            filled += n;
        }
        f(offset, &buf[..want], &mut acc)?;
        offset += want as u64;
    }
    Ok(acc)
}

/// Huge-payload workload: subfile `n` is the byte range
/// `[n·subfile_bytes, (n+1)·subfile_bytes)` of a [`StreamSource`],
/// digested chunk-at-a-time into `Q` u64-lane values.
///
/// For subfile word `w` at word-index `i` (absolute within the
/// subfile), lane `i % lanes` of function `f`'s value accumulates
/// `mix(w ^ salt(job)) ^ salt(job, f)` — one mix per word, one xor+add
/// per function. The digest never sees chunk boundaries, so it is
/// invariant to `chunk_bytes` (pinned by tests).
pub struct StreamedWorkload {
    source: Arc<dyn StreamSource>,
    subfile_bytes: u64,
    chunk_bytes: usize,
    funcs: usize,
    value_bytes: usize,
    seed: u64,
    agg: SumU64,
    pool: BufferPool,
}

impl StreamedWorkload {
    /// Build over an explicit source and geometry. `value_bytes`,
    /// `subfile_bytes`, and `chunk_bytes` must all be multiples of 8 so
    /// no u64 word straddles a chunk or subfile boundary.
    pub fn new(
        cfg: &SystemConfig,
        source: Arc<dyn StreamSource>,
        subfile_bytes: u64,
        chunk_bytes: usize,
        seed: u64,
    ) -> Result<Self> {
        if cfg.value_bytes % 8 != 0 {
            return Err(CamrError::InvalidConfig(
                "streamed workload needs value_bytes % 8 == 0 (u64 lanes)".into(),
            ));
        }
        if subfile_bytes == 0 || subfile_bytes % 8 != 0 {
            return Err(CamrError::InvalidConfig(format!(
                "stream subfile_bytes must be a positive multiple of 8, got {subfile_bytes}"
            )));
        }
        if chunk_bytes == 0 || chunk_bytes % 8 != 0 {
            return Err(CamrError::InvalidConfig(format!(
                "stream chunk_bytes must be a positive multiple of 8, got {chunk_bytes}"
            )));
        }
        Ok(StreamedWorkload {
            source,
            subfile_bytes,
            chunk_bytes,
            funcs: cfg.functions(),
            value_bytes: cfg.value_bytes,
            seed,
            agg: SumU64,
            pool: BufferPool::new(),
        })
    }

    /// Build from environment geometry — the constructor
    /// `workload::build_native` uses, so socket-transport worker
    /// processes (which inherit the coordinator's environment)
    /// reconstruct the identical stream from config text + env alone.
    ///
    /// * `CAMR_STREAM_SUBFILE_BYTES` — bytes per subfile (default 1 MiB;
    ///   set to the 256 MiB regime for huge-payload runs).
    /// * `CAMR_STREAM_CHUNK_BYTES` — checkout size (default 256 KiB).
    /// * `CAMR_STREAM_FILE` — optional real file input; without it a
    ///   [`SyntheticSource`] spanning every subfile is generated.
    pub fn from_env(cfg: &SystemConfig, seed: u64) -> Result<Self> {
        let subfile_bytes = env_bytes("CAMR_STREAM_SUBFILE_BYTES", DEFAULT_SUBFILE_BYTES)?;
        let chunk_bytes = env_bytes("CAMR_STREAM_CHUNK_BYTES", DEFAULT_CHUNK_BYTES as u64)?;
        let source: Arc<dyn StreamSource> = match std::env::var_os("CAMR_STREAM_FILE") {
            Some(path) => Arc::new(FileSource::open(path)?),
            None => {
                let total = subfile_bytes * cfg.subfiles() as u64;
                Arc::new(SyntheticSource::new(seed, total))
            }
        };
        Self::new(cfg, source, subfile_bytes, chunk_bytes as usize, seed)
    }

    /// The pool the chunk checkouts recycle through (stats inspection).
    pub fn pool(&self) -> &BufferPool {
        &self.pool
    }
}

fn env_bytes(key: &str, default: u64) -> Result<u64> {
    match std::env::var(key) {
        Ok(s) => s.trim().parse::<u64>().map_err(|_| {
            CamrError::InvalidConfig(format!("{key} must be an integer byte count, got {s:?}"))
        }),
        Err(_) => Ok(default),
    }
}

impl Workload for StreamedWorkload {
    fn name(&self) -> &str {
        "streamed"
    }

    fn aggregator(&self) -> &dyn Aggregator {
        &self.agg
    }

    fn map_subfile(&self, job: JobId, subfile: SubfileId) -> Result<Vec<Value>> {
        let lanes = self.value_bytes / 8;
        let job_salt = mix(self.seed ^ 0xCA3A_0001 ^ ((job as u64) << 32));
        let func_salts: Vec<u64> = (0..self.funcs).map(|f| mix(job_salt ^ f as u64)).collect();
        let start = subfile as u64 * self.subfile_bytes;
        let range = start..start + self.subfile_bytes;
        let acc = vec![vec![0u64; lanes]; self.funcs];
        let acc = fold_chunks(
            self.source.as_ref(),
            range,
            self.chunk_bytes,
            &self.pool,
            acc,
            |chunk_start, bytes, acc| {
                // Word index is absolute within the subfile, so the
                // digest cannot depend on where chunks were cut.
                let mut widx = ((chunk_start - start) / 8) as usize;
                for word in bytes.chunks(8) {
                    let mut w = [0u8; 8];
                    w[..word.len()].copy_from_slice(word);
                    let m = mix(u64::from_le_bytes(w) ^ job_salt);
                    let lane = widx % lanes;
                    for (a, salt) in acc.iter_mut().zip(&func_salts) {
                        a[lane] = a[lane].wrapping_add(m ^ salt);
                    }
                    widx += 1;
                }
                Ok(())
            },
        )?;
        Ok(acc
            .into_iter()
            .map(|words| {
                let mut v = Vec::with_capacity(self.value_bytes);
                for x in words {
                    v.extend_from_slice(&x.to_le_bytes());
                }
                v
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::with_options(3, 2, 1, 1, 64).unwrap()
    }

    fn streamed(subfile_bytes: u64, chunk_bytes: usize, seed: u64) -> StreamedWorkload {
        let c = cfg();
        let total = subfile_bytes * c.subfiles() as u64;
        let src = Arc::new(SyntheticSource::new(seed, total));
        StreamedWorkload::new(&c, src, subfile_bytes, chunk_bytes, seed).unwrap()
    }

    #[test]
    fn synthetic_source_reads_are_position_pure() {
        let src = SyntheticSource::new(9, 1024);
        let mut whole = vec![0u8; 1024];
        assert_eq!(src.read_at(0, &mut whole).unwrap(), 1024);
        // Any offset/length window sees the same bytes, including
        // misaligned windows that split words.
        for (off, len) in [(0usize, 64usize), (3, 61), (8, 8), (13, 100), (1000, 24)] {
            let mut win = vec![0u8; len];
            assert_eq!(src.read_at(off as u64, &mut win).unwrap(), len);
            assert_eq!(win, &whole[off..off + len], "off={off} len={len}");
        }
        // Reads past the end clamp; reads at the end return 0.
        let mut tail = vec![0u8; 64];
        assert_eq!(src.read_at(1000, &mut tail).unwrap(), 24);
        assert_eq!(src.read_at(1024, &mut tail).unwrap(), 0);
    }

    #[test]
    fn digest_is_chunk_size_independent() {
        let base = streamed(4096, 4096, 7);
        let want: Vec<_> = (0..3).map(|n| base.map_subfile(1, n).unwrap()).collect();
        for chunk in [8usize, 24, 256, 1000, 8192] {
            let wl = streamed(4096, chunk, 7);
            for (n, w) in want.iter().enumerate() {
                assert_eq!(&wl.map_subfile(1, n).unwrap(), w, "chunk={chunk} subfile={n}");
            }
        }
    }

    #[test]
    fn values_have_config_shape_and_vary_by_inputs() {
        let wl = streamed(1024, 256, 3);
        let c = cfg();
        let vals = wl.map_subfile(0, 0).unwrap();
        assert_eq!(vals.len(), c.functions());
        assert!(vals.iter().all(|v| v.len() == c.value_bytes));
        assert_ne!(vals[0], vals[1], "funcs must differ");
        assert_ne!(vals[0], wl.map_subfile(0, 1).unwrap()[0], "subfiles must differ");
        assert_ne!(vals[0], wl.map_subfile(1, 0).unwrap()[0], "jobs must differ");
        assert_eq!(vals, wl.map_subfile(0, 0).unwrap(), "maps are deterministic");
    }

    #[test]
    fn file_source_matches_synthetic_bytes() {
        let seed = 11;
        let total = 4096u64 * 6;
        let synth = SyntheticSource::new(seed, total);
        let mut bytes = vec![0u8; total as usize];
        assert_eq!(synth.read_at(0, &mut bytes).unwrap(), total as usize);
        let dir = std::env::temp_dir();
        let path = dir.join(format!("camr_stream_test_{seed}_{total}.bin"));
        std::fs::write(&path, &bytes).unwrap();
        let file = FileSource::open(&path).unwrap();
        assert_eq!(file.len(), total);
        let c = cfg();
        let from_file = StreamedWorkload::new(&c, Arc::new(file), 4096, 512, seed).unwrap();
        let from_synth = streamed(4096, 512, seed);
        for n in 0..3 {
            assert_eq!(from_file.map_subfile(0, n).unwrap(), from_synth.map_subfile(0, n).unwrap());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fold_reuses_one_pooled_chunk_buffer() {
        let wl = streamed(8192, 512, 5);
        wl.map_subfile(0, 0).unwrap();
        let stats = wl.pool().stats();
        // 8192 / 512 = 16 chunks, one checkout.
        assert_eq!(stats.acquired, 1);
        assert_eq!(stats.outstanding(), 0);
        wl.map_subfile(0, 1).unwrap();
        let stats = wl.pool().stats();
        assert_eq!(stats.acquired, 2);
        assert_eq!(stats.recycled, 1, "second map must recycle the first map's chunk");
    }

    #[test]
    fn truncated_source_errors_instead_of_digesting_garbage() {
        let c = cfg();
        // Source claims less than the subfile range needs.
        let src = Arc::new(SyntheticSource::new(1, 1024));
        let wl = StreamedWorkload::new(&c, src, 4096, 256, 1).unwrap();
        // Subfile 0 wants [0, 4096) but the source ends at 1024: the
        // range clamps, digesting only what exists (no error) —
        let v = wl.map_subfile(0, 0);
        assert!(v.is_ok());
        // — while a source that lies about its length errors.
        struct Liar;
        impl StreamSource for Liar {
            fn len(&self) -> u64 {
                4096
            }
            fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
                if offset >= 100 {
                    return Ok(0);
                }
                let n = buf.len().min((100 - offset) as usize);
                buf[..n].fill(7);
                Ok(n)
            }
        }
        let wl = StreamedWorkload::new(&c, Arc::new(Liar), 4096, 256, 1).unwrap();
        assert!(wl.map_subfile(0, 0).is_err());
    }

    #[test]
    fn geometry_is_validated() {
        let c = cfg();
        let src: Arc<dyn StreamSource> = Arc::new(SyntheticSource::new(0, 1024));
        assert!(StreamedWorkload::new(&c, Arc::clone(&src), 0, 256, 0).is_err());
        assert!(StreamedWorkload::new(&c, Arc::clone(&src), 100, 256, 0).is_err());
        assert!(StreamedWorkload::new(&c, Arc::clone(&src), 1024, 0, 0).is_err());
        assert!(StreamedWorkload::new(&c, Arc::clone(&src), 1024, 12, 0).is_err());
        assert!(StreamedWorkload::new(&c, src, 1024, 256, 0).is_ok());
    }
}
