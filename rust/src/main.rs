//! `camr` — CLI launcher for the CAMR coded-shuffle runtime.
//!
//! ```text
//! camr run      [--k 3] [--q 2] [--gamma 2] [--workload word_count]
//!               [--artifact artifacts/map_kernel.hlo.txt] [--seed N]
//!               [--json] [--parallel] [--config run.toml]
//! camr check    [CONFIG.toml] [--json]
//! camr lint     [--root DIR] [--json]
//! camr sweep    [--max-k 4] [--max-q 4]
//! camr table3
//! camr example1
//! camr serve    [--bench] [--engines 2] [--tenants 4] [--weights 1,2,4]
//! camr cluster  [--k 3] [--q 2] [--gamma 2]
//! camr speedup  [--k 4] [--q 2] [--gamma 8] [--value-bytes 256]
//! ```
//!
//! The argument parser is in-tree (this workspace builds offline); it
//! supports `--flag value`, `--flag=value` and boolean `--flag`.

use anyhow::{anyhow, bail, Context, Result};
use camr::analysis::{jobs, load, TimeModel};
use camr::baseline::{run_ablation, CcdcEngine, CodingChoice, UncodedEngine, UncodedMode};
use camr::config::{
    RunConfig, SystemConfig, TransportChoice, TransportConfig, WorkerModeChoice, WorkloadKind,
};
use camr::coordinator::batch::{self, BatchOptions, BatchScheme};
use camr::coordinator::cluster;
use camr::coordinator::engine::{Engine, RunOutcome};
use camr::coordinator::parallel::{ParallelEngine, TransportKind};
use camr::coordinator::remote::{self, SocketOptions, WorkerMode, WorkerSpec};
use camr::metrics::{BatchReport, LoadReport, SchemeBatch, ServeReport, SimTimes, TenantServe};
use camr::net::socket::SocketKind;
use camr::net::{Bus, Stage};
use camr::obs::{self, Tracer};
use camr::report::Table;
use camr::service::{JobService, JobSpec, ServiceOptions};
use camr::sim::{
    self, poisson_trace, simulate_open_arrivals, ArrivalConfig, LinkKind, SimConfig, SimOutcome,
    StragglerModel,
};
use camr::util::json::Json;
use camr::util::rng::mix_key;
use camr::workload::matvec::MatVecWorkload;
use camr::workload::synth::SyntheticWorkload;
use camr::workload::wordcount::WordCountWorkload;
use camr::workload::Workload;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Minimal flag parser: `--key value`, `--key=value`, boolean `--key`.
struct Args {
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Self> {
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            let key = arg
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("unexpected argument {arg} (flags start with --)"))?;
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if bool_flags.contains(&key) {
                flags.insert(key.to_string(), "true".to_string());
            } else {
                i += 1;
                let v = argv
                    .get(i)
                    .ok_or_else(|| anyhow!("flag --{key} expects a value"))?;
                flags.insert(key.to_string(), v.clone());
            }
            i += 1;
        }
        Ok(Args { flags })
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_opt(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    fn get_bool(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

const USAGE: &str = "camr — Coded Aggregated MapReduce (ISIT 2019 reproduction)

USAGE:
  camr run      [CONFIG.toml] [--k N] [--q N] [--gamma N] [--workload KIND]
                [--seed N] [--artifact PATH] [--json] [--parallel]
                [--config FILE] [--transport serial|chan|tcp|unix]
                [--trace OUT.json]
  camr worker   --connect URL        (spawned by the socket-transport hub)
  camr trace    [CONFIG.toml] [--config FILE] [--k N] [--q N] [--gamma N]
                [--workload KIND] [--seed N] [--json] [--parallel]
                [--transport serial|chan|tcp|unix] [--out TRACE.json]
  camr simulate [CONFIG.toml] [--config FILE] [--k N] [--q N] [--gamma N]
                [--workload KIND] [--seed N] [--json] [--parallel]
                [--link shared|bisection] [--bandwidth BYTES/S]
                [--latency SECS] [--secs-per-map SECS]
                [--straggler none|shifted_exp|tail] [--straggler-rate R]
                [--tail-prob P] [--tail-factor F] [--sim-seed N]
  camr batch    [CONFIG.toml] [--config FILE] [--k N] [--q N] [--gamma N]
                [--workload KIND] [--scheme camr|ccdc|uncoded|all]
                [--jobs all|N] [--ccdc-cap N] [--parallel] [--json]
  camr check    [CONFIG.toml] [--config FILE] [--k N] [--q N] [--gamma N]
                [--json]
  camr lint     [--root DIR] [--json]
  camr sweep    [--max-k N] [--max-q N]
  camr table3
  camr example1
  camr serve    [CONFIG.toml] [--config FILE] [--k N] [--q N] [--gamma N]
                [--value-bytes N] [--seed N] [--engines N] [--queue-cap N]
                [--tenants N] [--quantum N] [--weights 1,2,4] [--parallel]
                [--bench [--quick] [--jobs N] [--out FILE] [--json]]
                [--rate JOBS/S] [--arrivals N]
  camr cluster  [--k N] [--q N] [--gamma N]
  camr speedup  [--k N] [--q N] [--gamma N] [--value-bytes N]
  camr ablation [--k N] [--q N]
  camr ccdc     [--servers N] [--k N]
  camr timemodel [--k N] [--q N] [--gamma N] [--value-bytes N]

KIND: word_count | mat_vec | gradient | synthetic | streamed
      (streamed reads CAMR_STREAM_SUBFILE_BYTES / CAMR_STREAM_CHUNK_BYTES
       / CAMR_STREAM_FILE for its huge-payload geometry)

batch executes each scheme's *entire* job set end to end through the
multi-job batch runtime (persistent engine, pooled buffers, pipelined
verification): all q^(k-1) CAMR jobs vs CCDC's C(K, μK+1) family
(capped by --ccdc-cap; the count is exponential) vs uncoded, then
replays the aggregate job-tagged ledger through the cluster simulator
([sim] section, or the commodity preset) for barriered-vs-pipelined
batch makespans. --jobs N executes at least N jobs (CAMR rounds up to
whole coded rounds of J).

--transport picks the data plane: serial (the reference engine), chan
(thread-per-worker over in-process channels; same as --parallel), or
tcp / unix (workers as separate `camr worker` processes speaking the
length-prefixed wire format over loopback sockets, multicasts fanned
out by the coordinator hub and charged once). All four produce
byte-identical load ledgers — the golden-fixture tests enforce it.
The flag beats --parallel beats the config's [transport] section.

simulate replays the byte-exact ledgers of a CAMR run and the
CCDC/uncoded baselines through the discrete-event cluster simulator
([sim] section of CONFIG.toml, flags override) and prints per-stage
simulated times, then lines them up against the traced phase windows
of the real run (sim_vs_real).

trace runs one round with the observability layer forced on and
prints per-worker × per-phase span percentiles, per-phase wall
windows, and the metric counters the run moved; --out writes the
Chrome trace_event JSON (open in Perfetto or chrome://tracing).
`camr run --trace OUT.json` exports the same trace without the
tables. Tracing is otherwise off: a disabled tracer never reads the
clock and adds no work to the data path.

check statically proves a config's full placement + schedule before
any worker starts: every coded packet decodable by each recipient,
map replication exactly (k-1)x, job counts matching the paper's
closed forms, sequence numbers gap-free per stage, and the stage
barriers partitioning the schedule. The same prover runs as engine
pre-flight on all four planes and at job-service admission; a
malformed plan is the typed Invalid rejection (wire code 13), never
a mid-round failure. --json emits the diagnostic report as JSON.

lint walks the source tree and enforces the repo invariants that have
actually shipped broken before: test registration in Cargo.toml,
bench-name/schema agreement with bench_json, line width, unique
FrameKind discriminants and CamrError wire codes, and sim/
determinism purity. Exit status is nonzero on any error finding —
CI runs it as a blocking step.

serve runs the continuous job service: mixed-workload jobs stream
into bounded per-tenant queues (deficit round-robin fairness, typed
QueueFull backpressure) drained by a pool of persistent engines with
multiple coded rounds in flight. --bench is the closed-loop traffic
driver — 10^5 jobs quick / 10^6 full, every round oracle-verified,
jobs/sec + p50/p99 sojourn + per-tenant counts into BENCH_serve.json.
Without --bench, submissions are paced by a seeded Poisson arrival
trace and the run is compared against the simulator's FCFS replay of
the identical trace (sim-vs-real on the same offered load). The old
one-shot Arc-shared round lives on as `camr cluster`.
";

fn build_workload(
    kind: WorkloadKind,
    cfg: &SystemConfig,
    seed: u64,
    artifact: Option<&PathBuf>,
) -> Result<Box<dyn Workload>> {
    // Only the PJRT-backed mapper differs from the deterministic native
    // constructor (which socket worker processes also use, so a run is
    // identical data whichever process builds it).
    if let (WorkloadKind::MatVec, Some(path)) = (kind, artifact) {
        let rows_per_func = cfg.value_bytes / 4;
        let compute: Arc<dyn camr::workload::matvec::ShardCompute> =
            Arc::new(camr::runtime::PjrtShardCompute::new(path)?);
        return Ok(Box::new(MatVecWorkload::synthetic(cfg, seed, rows_per_func, 8, compute)?));
    }
    Ok(camr::workload::build_native(kind, cfg, seed)?)
}

/// Replay a CAMR run's ledger through the simulator (when the config
/// carries a `[sim]` section) and package the report times.
fn attach_sim_times(
    cfg: &SystemConfig,
    simcfg: Option<&SimConfig>,
    placement: &camr::placement::Placement,
    bus: &Bus,
) -> Result<Option<SimTimes>> {
    let Some(sc) = simcfg else {
        return Ok(None);
    };
    let maps = sim::camr_per_worker_maps(cfg, placement);
    let out = sim::simulate(sc, &maps, bus.ledger())?;
    Ok(Some(SimTimes::from_outcome(&out)))
}

/// Build the [`SocketOptions`] for a tcp/unix run from the config's
/// `[transport]` section (defaults when absent).
fn socket_options(sock_kind: SocketKind, tcfg: Option<&TransportConfig>) -> Result<SocketOptions> {
    let t = tcfg.cloned().unwrap_or_default();
    let mode = match t.workers {
        WorkerModeChoice::Process => WorkerMode::Process { exe: std::env::current_exe()? },
        WorkerModeChoice::Thread => WorkerMode::Thread,
    };
    let mut opts = SocketOptions::new(sock_kind, mode);
    opts.listen = t.listen;
    opts.disconnect_timeout = Duration::from_secs_f64(t.disconnect_timeout_secs);
    Ok(opts)
}

fn cmd_run(argv: &[String]) -> Result<()> {
    let (path, rest) = split_positional_config(argv);
    let args = Args::parse(rest, &["json", "parallel"])?;
    let (cfg, kind, seed, artifact, json, simcfg, tcfg, ocfg) =
        match path.or_else(|| args.get_opt("config")) {
            Some(path) => {
                let rc = RunConfig::from_path(std::path::Path::new(&path))?;
                (
                    rc.system,
                    rc.workload,
                    rc.seed,
                    rc.artifact.map(PathBuf::from),
                    rc.json,
                    rc.sim,
                    rc.transport,
                    rc.obs,
                )
            }
            None => (
                SystemConfig::new(
                    args.get_usize("k", 3)?,
                    args.get_usize("q", 2)?,
                    args.get_usize("gamma", 2)?,
                )?,
                WorkloadKind::parse(&args.get_str("workload", "word_count"))?,
                args.get_u64("seed", 0xCA3A)?,
                args.get_opt("artifact").map(PathBuf::from),
                args.get_bool("json"),
                None,
                None,
                None,
            ),
        };
    let json = json || args.get_bool("json");
    // Trace destination: --trace OUT.json beats the config's [obs]
    // section beats the CAMR_TRACE env convention. Absent all three the
    // tracer stays on its no-op branch.
    let trace_dest = args
        .get_opt("trace")
        .or_else(|| ocfg.as_ref().and_then(|o| o.destination()))
        .or_else(obs::env_trace_destination);
    let tracer = if trace_dest.is_some() {
        obs::set_metrics_enabled(true);
        Tracer::on()
    } else {
        Tracer::Off
    };
    // Data-plane resolution: --transport beats --parallel beats the
    // config's [transport] section beats the serial default.
    let choice = match args.get_opt("transport") {
        Some(v) => TransportChoice::parse(&v)?,
        None if args.get_bool("parallel") => TransportChoice::Chan,
        None => tcfg.as_ref().map(|t| t.kind).unwrap_or_default(),
    };
    let wl = build_workload(kind, &cfg, seed, artifact.as_ref())?;
    let name = wl.name().to_string();
    // Keep the engine around: the `[sim]` section replays its ledger.
    let (out, sim_times, engine_label): (RunOutcome, _, String) = match choice {
        TransportChoice::Serial => {
            let mut e = Engine::new(cfg.clone(), wl)?;
            e.tracer = tracer.clone();
            let out = e.run()?;
            let st = attach_sim_times(&cfg, simcfg.as_ref(), &e.master.placement, &e.bus)?;
            (out, st, "serial".into())
        }
        TransportChoice::Chan => {
            let mut e = ParallelEngine::new(cfg.clone(), wl)?;
            e.tracer = tracer.clone();
            let out = e.run()?;
            let st = attach_sim_times(&cfg, simcfg.as_ref(), &e.master.placement, &e.bus)?;
            (out, st, "parallel (thread-per-worker, channels)".into())
        }
        TransportChoice::Tcp | TransportChoice::Unix => {
            anyhow::ensure!(
                artifact.is_none(),
                "--artifact is not supported over socket transports (worker processes \
                 rebuild the workload from the shipped config text)"
            );
            let sock_kind = if choice == TransportChoice::Tcp {
                SocketKind::Tcp
            } else {
                SocketKind::Unix
            };
            let opts = socket_options(sock_kind, tcfg.as_ref())?;
            let label = format!(
                "{} sockets ({})",
                if sock_kind == SocketKind::Tcp { "tcp" } else { "unix" },
                match &opts.mode {
                    WorkerMode::Process { .. } => "process-per-worker",
                    WorkerMode::Thread => "thread-per-worker",
                }
            );
            let mut e = ParallelEngine::new(cfg.clone(), wl)?;
            e.tracer = tracer.clone();
            e.transport = TransportKind::Socket(opts);
            e.remote_spec = Some(WorkerSpec { kind, seed });
            let out = e.run()?;
            let st = attach_sim_times(&cfg, simcfg.as_ref(), &e.master.placement, &e.bus)?;
            (out, st, label)
        }
    };
    if let Some(dest) = &trace_dest {
        let spans = tracer.take_spans();
        obs::write_chrome_trace(std::path::Path::new(dest), &spans)?;
        // stderr so --json stdout stays machine-parseable.
        eprintln!("trace: {} spans -> {dest}", spans.len());
    }
    let mut report = LoadReport::from_outcome(&cfg, &out);
    if let Some(st) = sim_times {
        report.attach_sim(st);
    }
    if json {
        println!("{}", report.to_json());
    } else {
        println!("workload: {name}   engine: {engine_label}");
        print!("{report}");
        if !report.matches_analysis() {
            bail!("measured load deviates from §IV closed form");
        }
    }
    Ok(())
}

/// `camr worker --connect URL`: the subprocess entrypoint spawned by the
/// socket-transport hub. Never invoked by hand.
fn cmd_worker(args: &Args) -> Result<()> {
    let url = args
        .get_opt("connect")
        .ok_or_else(|| anyhow!("camr worker requires --connect URL (spawned by the hub)"))?;
    remote::run_worker(&url)?;
    Ok(())
}

/// `camr trace`: run the configured round with the tracer forced on and
/// print per-worker × per-phase span statistics plus the metric
/// counters the run incremented. `--out PATH` additionally writes the
/// Chrome `trace_event` JSON (load it in Perfetto / chrome://tracing).
fn cmd_trace(argv: &[String]) -> Result<()> {
    let (path, rest) = split_positional_config(argv);
    let args = Args::parse(rest, &["json", "parallel"])?;
    let (cfg, kind, seed, artifact, json, tcfg) = match path.or_else(|| args.get_opt("config")) {
        Some(p) => {
            let rc = RunConfig::from_path(std::path::Path::new(&p))?;
            (
                rc.system,
                rc.workload,
                rc.seed,
                rc.artifact.map(PathBuf::from),
                rc.json,
                rc.transport,
            )
        }
        None => (
            SystemConfig::new(
                args.get_usize("k", 3)?,
                args.get_usize("q", 2)?,
                args.get_usize("gamma", 2)?,
            )?,
            WorkloadKind::parse(&args.get_str("workload", "word_count"))?,
            args.get_u64("seed", 0xCA3A)?,
            args.get_opt("artifact").map(PathBuf::from),
            args.get_bool("json"),
            None,
        ),
    };
    let json = json || args.get_bool("json");
    obs::set_metrics_enabled(true);
    let tracer = Tracer::on();
    let choice = match args.get_opt("transport") {
        Some(v) => TransportChoice::parse(&v)?,
        None if args.get_bool("parallel") => TransportChoice::Chan,
        None => tcfg.as_ref().map(|t| t.kind).unwrap_or_default(),
    };
    let wl = build_workload(kind, &cfg, seed, artifact.as_ref())?;
    let (out, engine_label): (RunOutcome, &str) = match choice {
        TransportChoice::Serial => {
            let mut e = Engine::new(cfg.clone(), wl)?;
            e.tracer = tracer.clone();
            (e.run()?, "serial")
        }
        TransportChoice::Chan => {
            let mut e = ParallelEngine::new(cfg.clone(), wl)?;
            e.tracer = tracer.clone();
            (e.run()?, "chan")
        }
        TransportChoice::Tcp | TransportChoice::Unix => {
            anyhow::ensure!(
                artifact.is_none(),
                "--artifact is not supported over socket transports"
            );
            let sock_kind = if choice == TransportChoice::Tcp {
                SocketKind::Tcp
            } else {
                SocketKind::Unix
            };
            let opts = socket_options(sock_kind, tcfg.as_ref())?;
            let mut e = ParallelEngine::new(cfg.clone(), wl)?;
            e.tracer = tracer.clone();
            e.transport = TransportKind::Socket(opts);
            e.remote_spec = Some(WorkerSpec { kind, seed });
            (e.run()?, if sock_kind == SocketKind::Tcp { "tcp" } else { "unix" })
        }
    };
    anyhow::ensure!(out.verified, "traced run failed verification");
    let spans = tracer.take_spans();
    anyhow::ensure!(!spans.is_empty(), "tracer captured no spans");

    // Sanity: each protocol phase's measured *window* (earliest span
    // start to latest span end across all workers) must stay inside the
    // engine's own stage wall time plus slack. The slack absorbs
    // scheduling jitter and — on socket planes — the handshake-level
    // epoch skew between worker-process clocks (see `obs` docs). Summed
    // span durations are deliberately NOT compared against wall time:
    // concurrent workers make sums exceed it by design.
    let rollup = obs::phase_rollup(&spans);
    let walls = [
        ("map", out.map_time.as_secs_f64()),
        ("stage1", out.stage_times[0].as_secs_f64()),
        ("stage2", out.stage_times[1].as_secs_f64()),
        ("stage3", out.stage_times[2].as_secs_f64()),
    ];
    for (phase, wall) in walls {
        if let Some(r) = rollup.iter().find(|r| r.phase == phase) {
            let allowed = wall * 1.5 + 0.25;
            anyhow::ensure!(
                r.secs <= allowed,
                "phase {phase}: traced window {:.6}s exceeds engine wall {wall:.6}s + slack",
                r.secs,
            );
        }
    }

    if let Some(dest) = args.get_opt("out") {
        obs::write_chrome_trace(std::path::Path::new(&dest), &spans)?;
        eprintln!("trace: {} spans -> {dest}", spans.len());
    }

    let stats = obs::summarize(&spans);
    let counters = obs::metrics().snapshot();
    let wname = |w: usize| if w == obs::COORD { "coord".to_string() } else { w.to_string() };

    if json {
        let stat_rows: Vec<Json> = stats
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("worker", Json::Str(wname(s.worker))),
                    ("phase", Json::Str(s.phase.to_string())),
                    ("count", Json::UInt(s.count as u128)),
                    ("total_ns", Json::UInt(s.total_ns as u128)),
                    ("p50_ns", Json::UInt(s.p50_ns as u128)),
                    ("p99_ns", Json::UInt(s.p99_ns as u128)),
                    ("max_ns", Json::UInt(s.max_ns as u128)),
                    ("bytes", Json::UInt(s.bytes as u128)),
                ])
            })
            .collect();
        let phase_rows: Vec<Json> = rollup
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("phase", Json::Str(r.phase.to_string())),
                    ("secs", Json::Num(r.secs)),
                    ("spans", Json::UInt(r.spans as u128)),
                    ("bytes", Json::UInt(r.bytes as u128)),
                ])
            })
            .collect();
        let metric_rows: Vec<Json> = counters
            .iter()
            .map(|(name, v)| {
                Json::obj(vec![
                    ("name", Json::Str(name.clone())),
                    ("value", Json::UInt(*v as u128)),
                ])
            })
            .collect();
        let obj = Json::obj(vec![
            ("engine", Json::Str(engine_label.to_string())),
            ("spans", Json::UInt(spans.len() as u128)),
            ("stats", Json::Arr(stat_rows)),
            ("phases", Json::Arr(phase_rows)),
            ("metrics", Json::Arr(metric_rows)),
        ]);
        println!("{}", obj.render());
        return Ok(());
    }

    println!(
        "traced round — K={} (k={} q={}) γ={} engine={engine_label} spans={}",
        cfg.servers(),
        cfg.k,
        cfg.q,
        cfg.gamma,
        spans.len()
    );
    println!();
    let us = |ns: u64| format!("{:.1}", ns as f64 / 1e3);
    let mut t = Table::new(vec![
        "worker", "phase", "count", "total_us", "p50_us", "p99_us", "max_us", "bytes",
    ]);
    for s in &stats {
        t.row(vec![
            wname(s.worker),
            s.phase.to_string(),
            s.count.to_string(),
            us(s.total_ns),
            us(s.p50_ns),
            us(s.p99_ns),
            us(s.max_ns),
            s.bytes.to_string(),
        ]);
    }
    print!("{}", t.render());

    println!();
    let mut p = Table::new(vec!["phase", "window_s", "spans", "bytes"]);
    for r in &rollup {
        p.row(vec![
            r.phase.to_string(),
            format!("{:.6}", r.secs),
            r.spans.to_string(),
            r.bytes.to_string(),
        ]);
    }
    print!("{}", p.render());

    // Counters stay zero for code paths the run never touched — only
    // print the ones that moved.
    let moved: Vec<_> = counters.iter().filter(|(_, v)| *v != 0).collect();
    if !moved.is_empty() {
        println!();
        let mut m = Table::new(vec!["metric", "value"]);
        for (name, v) in moved {
            m.row(vec![name.clone(), v.to_string()]);
        }
        print!("{}", m.render());
    }
    Ok(())
}

/// One scheme's simulated run for `camr simulate`.
struct SchemeSim {
    label: &'static str,
    jobs: usize,
    map_tasks: usize,
    sim: SimOutcome,
}

/// Split an optional leading positional CONFIG path off an argv slice
/// (`camr simulate configs/x.toml …` / `camr batch configs/x.toml …`).
fn split_positional_config(argv: &[String]) -> (Option<String>, &[String]) {
    match argv.first() {
        Some(a) if !a.starts_with("--") => (Some(a.clone()), &argv[1..]),
        _ => (None, argv),
    }
}

/// Shared resolution for `camr simulate` / `camr batch`: the system,
/// workload, seed, artifact, cluster model and JSON preference from a
/// positional or `--config` file, falling back to `--k/--q/--gamma`
/// flags with the commodity sim preset.
fn resolve_sim_setup(
    args: &Args,
    path: Option<String>,
) -> Result<(SystemConfig, WorkloadKind, u64, Option<PathBuf>, SimConfig, bool)> {
    Ok(match path.or_else(|| args.get_opt("config")) {
        Some(p) => {
            let rc = RunConfig::from_path(std::path::Path::new(&p))?;
            let sc = rc.sim.unwrap_or_else(SimConfig::commodity);
            (rc.system, rc.workload, rc.seed, rc.artifact.map(PathBuf::from), sc, rc.json)
        }
        None => (
            SystemConfig::new(
                args.get_usize("k", 3)?,
                args.get_usize("q", 2)?,
                args.get_usize("gamma", 2)?,
            )?,
            WorkloadKind::parse(&args.get_str("workload", "word_count"))?,
            args.get_u64("seed", 0xCA3A)?,
            None,
            SimConfig::commodity(),
            false,
        ),
    })
}

fn cmd_simulate(argv: &[String]) -> Result<()> {
    let (path, rest) = split_positional_config(argv);
    let args = Args::parse(rest, &["json", "parallel"])?;
    let (cfg, kind, wseed, artifact, mut sc, cfg_json) = resolve_sim_setup(&args, path)?;
    let json = cfg_json || args.get_bool("json");
    // Flag overrides on top of the `[sim]` section (or the commodity
    // preset when the config has none).
    if let Some(v) = args.get_opt("link") {
        sc.link = LinkKind::parse(&v)?;
    }
    sc.link_bytes_per_sec = args.get_f64("bandwidth", sc.link_bytes_per_sec)?;
    sc.latency_secs = args.get_f64("latency", sc.latency_secs)?;
    sc.secs_per_map = args.get_f64("secs-per-map", sc.secs_per_map)?;
    // Straggler overrides layer on top of the config's model: absent
    // flags keep the config's parameters, and parameter flags without a
    // matching model are an error rather than silently dropped.
    let any_straggler_flag = ["straggler", "straggler-rate", "tail-prob", "tail-factor"]
        .iter()
        .any(|f| args.get_opt(f).is_some());
    if any_straggler_flag {
        let (cur_name, cur_rate, cur_prob, cur_factor) = match sc.straggler {
            StragglerModel::Deterministic => ("none", 5.0, 0.05, 10.0),
            StragglerModel::ShiftedExp { rate } => ("shifted_exp", rate, 0.05, 10.0),
            StragglerModel::Tail { prob, factor } => ("tail", 5.0, prob, factor),
        };
        let name = args.get_str("straggler", cur_name);
        match name.as_str() {
            "none" | "deterministic"
                if args.get_opt("straggler-rate").is_some()
                    || args.get_opt("tail-prob").is_some()
                    || args.get_opt("tail-factor").is_some() =>
            {
                bail!(
                    "--straggler-rate/--tail-prob/--tail-factor need --straggler \
                     shifted_exp or tail (current model is none)"
                )
            }
            "shifted_exp"
                if args.get_opt("tail-prob").is_some()
                    || args.get_opt("tail-factor").is_some() =>
            {
                bail!("--tail-prob/--tail-factor only apply with --straggler tail")
            }
            "tail" if args.get_opt("straggler-rate").is_some() => {
                bail!("--straggler-rate only applies with --straggler shifted_exp")
            }
            _ => {}
        }
        sc.straggler = StragglerModel::parse(
            &name,
            args.get_f64("straggler-rate", cur_rate)?,
            args.get_f64("tail-prob", cur_prob)?,
            args.get_f64("tail-factor", cur_factor)?,
        )?;
    }
    sc.seed = args.get_u64("sim-seed", sc.seed)?;
    sc.validate()?;

    // CAMR: a real engine run produces the byte-exact ledger to replay,
    // traced so the sim-vs-real table compares the simulator's phases
    // against measured phase *windows* with the same boundaries
    // (`net::stage_runs` barriers), not whole-engine wall times.
    let tracer = Tracer::on();
    let wl = build_workload(kind, &cfg, wseed, artifact.as_ref())?;
    let (camr_bus, camr_maps, _camr_out) = if args.get_bool("parallel") {
        let mut e = ParallelEngine::new(cfg.clone(), wl)?;
        e.tracer = tracer.clone();
        let out = e.run()?;
        anyhow::ensure!(out.verified, "CAMR run failed verification");
        (e.bus.clone(), sim::camr_per_worker_maps(&cfg, &e.master.placement), out)
    } else {
        let mut e = Engine::new(cfg.clone(), wl)?;
        e.tracer = tracer.clone();
        let out = e.run()?;
        anyhow::ensure!(out.verified, "CAMR run failed verification");
        (e.bus.clone(), sim::camr_per_worker_maps(&cfg, &e.master.placement), out)
    };
    let measured_rollup = obs::phase_rollup(&tracer.take_spans());
    let camr_tasks: usize = camr_maps.iter().sum();
    let mut rows = vec![SchemeSim {
        label: "camr",
        jobs: cfg.jobs(),
        map_tasks: camr_tasks,
        sim: sim::simulate(&sc, &camr_maps, camr_bus.ledger())?,
    }];

    // CCDC at matched μ: C(K, k) jobs, measured (2B-delivery) ledger.
    match CcdcEngine::new(cfg.servers(), cfg.k, cfg.gamma, cfg.value_bytes, wseed) {
        Ok(mut e) => {
            let out = e.run()?;
            let maps = sim::ccdc_per_worker_maps(cfg.servers(), cfg.k, cfg.gamma);
            rows.push(SchemeSim {
                label: "ccdc",
                jobs: out.jobs,
                map_tasks: maps.iter().sum(),
                sim: sim::simulate(&sc, &maps, e.bus.ledger())?,
            });
        }
        Err(e) => eprintln!("note: CCDC baseline skipped: {e}"),
    }

    // Uncoded-aggregated baseline: identical placement and map work —
    // the completion-time gap to CAMR is purely the shuffle.
    let wl2 = build_workload(kind, &cfg, wseed, artifact.as_ref())?;
    let mut ue = UncodedEngine::new(cfg.clone(), wl2, UncodedMode::Aggregated)?;
    let uout = ue.run()?;
    anyhow::ensure!(uout.verified, "uncoded run failed verification");
    rows.push(SchemeSim {
        label: "uncoded",
        jobs: cfg.jobs(),
        map_tasks: camr_tasks,
        sim: sim::simulate(&sc, &camr_maps, ue.bus.ledger())?,
    });

    // Measured-vs-simulated CAMR phases, paired on the same stage
    // boundaries the engines barrier on (`net::stage_runs`).
    let sim_cmp = obs::compare_with_sim(&measured_rollup, &rows[0].sim);

    if json {
        let schemes: Vec<Json> = rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("scheme", Json::Str(r.label.to_string())),
                    ("jobs", Json::UInt(r.jobs as u128)),
                    ("map_tasks", Json::UInt(r.map_tasks as u128)),
                    ("sim", r.sim.to_json()),
                ])
            })
            .collect();
        let sim_vs_real: Vec<Json> = sim_cmp
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("phase", Json::Str(c.phase.to_string())),
                    ("sim_secs", Json::Num(c.sim_secs)),
                    ("measured_secs", Json::Num(c.measured_secs)),
                    ("rel_err", Json::Num(c.rel_err)),
                ])
            })
            .collect();
        let obj = Json::obj(vec![
            ("k", Json::UInt(cfg.k as u128)),
            ("q", Json::UInt(cfg.q as u128)),
            ("gamma", Json::UInt(cfg.gamma as u128)),
            ("value_bytes", Json::UInt(cfg.value_bytes as u128)),
            ("servers", Json::UInt(cfg.servers() as u128)),
            ("sim_config", Json::Str(sc.describe())),
            ("schemes", Json::Arr(schemes)),
            ("sim_vs_real", Json::Arr(sim_vs_real)),
        ]);
        println!("{}", obj.render());
        return Ok(());
    }

    println!(
        "discrete-event cluster simulation — K={} (k={} q={}) γ={} B={}",
        cfg.servers(),
        cfg.k,
        cfg.q,
        cfg.gamma,
        cfg.value_bytes
    );
    println!("  {}\n", sc.describe());
    let mut t = Table::new(vec!["scheme", "jobs", "phase", "tx", "bytes", "secs"]);
    for r in &rows {
        t.row(vec![
            r.label.to_string(),
            r.jobs.to_string(),
            "map".to_string(),
            format!("{} tasks", r.map_tasks),
            "-".to_string(),
            format!("{:.6}", r.sim.map_secs),
        ]);
        // The CCDC ledger is per-job tagged (one barrier-separated phase
        // per job of the family) — collapse long phase lists into one
        // aggregate row so the table stays readable.
        if r.sim.phases.len() > 8 {
            let tx: usize = r.sim.phases.iter().map(|p| p.transmissions).sum();
            let bytes: usize = r.sim.phases.iter().map(|p| p.bytes).sum();
            let secs: f64 = r.sim.phases.iter().map(|p| p.secs).sum();
            t.row(vec![
                r.label.to_string(),
                r.jobs.to_string(),
                format!("{}×{}", r.sim.phases[0].stage, r.sim.phases.len()),
                tx.to_string(),
                bytes.to_string(),
                format!("{secs:.6}"),
            ]);
        } else {
            for p in &r.sim.phases {
                t.row(vec![
                    r.label.to_string(),
                    r.jobs.to_string(),
                    p.stage.to_string(),
                    p.transmissions.to_string(),
                    p.bytes.to_string(),
                    format!("{:.6}", p.secs),
                ]);
            }
        }
        t.row(vec![
            r.label.to_string(),
            r.jobs.to_string(),
            "total".to_string(),
            r.sim.transmissions.to_string(),
            r.sim.shuffle_bytes.to_string(),
            format!("{:.6}", r.sim.total_secs),
        ]);
    }
    print!("{}", t.render());

    println!();
    let mut s = Table::new(vec!["scheme", "jobs", "t_total", "t_per_job", "vs_camr"]);
    let camr_per_job = rows[0].sim.total_secs / rows[0].jobs as f64;
    for r in &rows {
        let per_job = r.sim.total_secs / r.jobs as f64;
        s.row(vec![
            r.label.to_string(),
            r.jobs.to_string(),
            format!("{:.6}", r.sim.total_secs),
            format!("{:.6}", per_job),
            format!("{:.2}x", per_job / camr_per_job),
        ]);
    }
    print!("{}", s.render());

    // Sim-vs-real: the simulator's CAMR phase times next to the
    // *measured phase windows* the traced engine run just recorded —
    // the same stage boundaries the sim models, not whole-engine wall
    // times. Absolute values differ wildly (the sim models a 1 Gb/s
    // cluster, the real run is memcpy over channels) — the column
    // worth reading is each phase's share, and rel_err tracks how the
    // shares drift.
    println!();
    let mut vr = Table::new(vec!["phase", "sim_s", "real_s", "rel_err"]);
    for c in &sim_cmp {
        vr.row(vec![
            c.phase.to_string(),
            format!("{:.6}", c.sim_secs),
            format!("{:.6}", c.measured_secs),
            format!("{:+.2}", c.rel_err),
        ]);
    }
    print!("{}", vr.render());
    println!("(camr only; real_s is the traced phase window of this machine's run)");

    if let Some(u) = rows.iter().find(|r| r.label == "uncoded") {
        println!(
            "\nCAMR end-to-end speedup over uncoded (same map work): {:.2}x",
            u.sim.total_secs / rows[0].sim.total_secs
        );
    }
    println!(
        "note: CCDC runs its own C(K,k)-job workload at matched μ — compare t_per_job;\n\
         its ledger is this implementation's measured (2B) delivery, ≥ the Eq.-(6) bound."
    );
    Ok(())
}

fn cmd_batch(argv: &[String]) -> Result<()> {
    let (path, rest) = split_positional_config(argv);
    let args = Args::parse(rest, &["json", "parallel", "no-pipeline", "no-verify"])?;
    let (cfg, kind, wseed, artifact, sc, cfg_json) = resolve_sim_setup(&args, path)?;
    let json = cfg_json || args.get_bool("json");
    let jobs = match args.get_str("jobs", "all").as_str() {
        "all" => None,
        n => Some(n.parse::<usize>().with_context(|| format!("--jobs {n}"))?),
    };
    let schemes: Vec<BatchScheme> = match args.get_str("scheme", "all").as_str() {
        "all" => vec![BatchScheme::Camr, BatchScheme::Ccdc, BatchScheme::Uncoded],
        s => vec![BatchScheme::parse(s)?],
    };
    let opts = BatchOptions {
        jobs,
        parallel: args.get_bool("parallel"),
        verify: !args.get_bool("no-verify"),
        pipeline_verify: !args.get_bool("no-pipeline"),
        ccdc_cap: Some(args.get_usize("ccdc-cap", batch::DEFAULT_CCDC_CAP)?),
        seed: wseed,
        ..BatchOptions::default()
    };
    let factory = |_unit: usize, seed: u64| {
        build_workload(kind, &cfg, seed, artifact.as_ref())
            .map_err(|e| camr::CamrError::Runtime(format!("workload: {e:#}")))
    };

    let mut rows: Vec<SchemeBatch> = Vec::new();
    for scheme in schemes {
        let out = batch::run_batch(&cfg, scheme, &opts, &factory)?;
        let sim = out.simulate(&sc)?;
        rows.push(SchemeBatch::from_outcome(&out, &sim));
    }
    let report = BatchReport {
        k: cfg.k,
        q: cfg.q,
        gamma: cfg.gamma,
        value_bytes: cfg.value_bytes,
        servers: cfg.servers(),
        sim_config: sc.describe(),
        schemes: rows,
    };

    // Invariants the batch must demonstrate (CI runs this command as a
    // smoke test): every scheme verified end to end with a nonzero
    // simulated makespan, and CAMR's job requirement is strictly below
    // CCDC's when both ran.
    for s in &report.schemes {
        anyhow::ensure!(s.verified, "{}: batch had failed units", s.scheme);
        anyhow::ensure!(
            s.pipelined_secs > 0.0 && s.pipelined_secs <= s.serial_secs + 1e-12,
            "{}: degenerate simulated makespan",
            s.scheme
        );
    }
    if let (Some(c), Some(d)) = (report.scheme("camr"), report.scheme("ccdc")) {
        anyhow::ensure!(
            c.jobs_required < d.jobs_required,
            "CAMR must require fewer jobs than CCDC"
        );
    }

    if json {
        println!("{}", report.to_json());
        return Ok(());
    }
    print!("{report}");
    if let (Some(c), Some(d)) = (report.scheme("camr"), report.scheme("ccdc")) {
        println!(
            "\nCAMR executed its full {}-job set; CCDC requires C({},{}) = {} jobs \
             ({} executed{}) — {:.1}x more.",
            c.jobs_executed,
            cfg.servers(),
            cfg.k,
            d.jobs_required,
            d.jobs_executed,
            if (d.jobs_executed as u128) < d.jobs_required { ", capped" } else { "" },
            d.jobs_required as f64 / c.jobs_required as f64
        );
        println!(
            "per-job completion (pipelined): camr {:.6}s vs ccdc {:.6}s",
            c.secs_per_job(),
            d.secs_per_job()
        );
    }
    if let Some(c) = report.scheme("camr") {
        if c.units > 1 {
            println!(
                "pipelining saved {:.6}s over barriered rounds ({:.1}%)",
                c.serial_secs - c.pipelined_secs,
                100.0 * (c.serial_secs - c.pipelined_secs) / c.serial_secs.max(1e-12)
            );
        }
    }
    Ok(())
}

fn cmd_speedup(args: &Args) -> Result<()> {
    let k = args.get_usize("k", 4)?;
    let q = args.get_usize("q", 2)?;
    let gamma = args.get_usize("gamma", 8)?;
    let bytes = args.get_usize("value-bytes", 256)?;
    let cfg = SystemConfig::with_options(k, q, gamma, 1, bytes)?;
    println!(
        "serial vs thread-per-worker — K={} servers, J={} jobs, γ={gamma}, B={bytes}\n",
        cfg.servers(),
        cfg.jobs()
    );
    let serial = {
        let wl = SyntheticWorkload::new(&cfg, 7);
        let mut e = Engine::new(cfg.clone(), Box::new(wl))?;
        e.verify = false;
        e.run()?
    };
    let par = {
        let wl = SyntheticWorkload::new(&cfg, 7);
        let mut e = ParallelEngine::new(cfg.clone(), Box::new(wl))?;
        e.verify = false;
        e.run()?
    };
    if serial.stage_bytes != par.stage_bytes {
        bail!(
            "ledgers diverged: serial {:?} vs parallel {:?}",
            serial.stage_bytes,
            par.stage_bytes
        );
    }
    let speedup = |s: std::time::Duration, p: std::time::Duration| {
        s.as_secs_f64() / p.as_secs_f64().max(1e-12)
    };
    println!("  {:<10} {:>12} {:>12} {:>9}", "phase", "serial", "parallel", "speedup");
    for (phase, s, p) in [
        ("map", serial.map_time, par.map_time),
        ("shuffle", serial.shuffle_time, par.shuffle_time),
    ] {
        println!("  {:<10} {:>12?} {:>12?} {:>8.2}x", phase, s, p, speedup(s, p));
    }
    println!(
        "\nstage bytes identical: {:?} (load {:.4} both engines)",
        par.stage_bytes,
        par.total_load()
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let max_k = args.get_usize("max-k", 4)?;
    let max_q = args.get_usize("max-q", 4)?;
    let mut t = Table::new(vec![
        "k", "q", "K", "J", "mu", "L_camr(meas)", "L_camr(form)", "L_ccdc", "L_unc_agg",
        "J_ccdc",
    ]);
    for k in 2..=max_k {
        for q in 2..=max_q {
            let cfg = SystemConfig::new(k, q, 2)?;
            let wl = SyntheticWorkload::new(&cfg, 7);
            let mut e = Engine::new(cfg.clone(), Box::new(wl))?;
            e.verify = false;
            let out = e.run()?;
            t.row(vec![
                k.to_string(),
                q.to_string(),
                cfg.servers().to_string(),
                cfg.jobs().to_string(),
                format!("{:.4}", cfg.storage_fraction()),
                format!("{:.4}", out.total_load()),
                format!("{:.4}", load::camr_total(k, q)),
                format!("{:.4}", load::ccdc_total(k - 1, cfg.servers())),
                format!("{:.4}", load::uncoded_aggregated_total(k, q)),
                jobs::JobRequirement::for_params(k, q).ccdc.to_string(),
            ]);
        }
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_table3() -> Result<()> {
    println!("Table III — minimum number of jobs, K = 100:\n");
    let mut t = Table::new(vec!["k", "CAMR", "CCDC", "ratio"]);
    for row in jobs::table3() {
        t.row(vec![
            row.k.to_string(),
            row.camr.to_string(),
            row.ccdc.to_string(),
            format!("{:.1}x", row.ratio()),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_example1() -> Result<()> {
    let cfg = SystemConfig::new(3, 2, 2)?;
    let wl = WordCountWorkload::example1(&cfg);
    let mut engine = Engine::new(cfg.clone(), Box::new(wl))?;

    println!("== Paper Example 1: K = 6, q = 2, k = 3, J = 4, N = 6, γ = 2 ==\n");
    println!("Ownership (Eq. 2) and placement (Fig. 1):");
    let mut t = Table::new(vec!["server", "class", "owned jobs", "stored (job:batch)"]);
    {
        let m = &engine.master;
        for s in 0..cfg.servers() {
            let inv = m.placement.inventory(s);
            let stored: Vec<String> =
                inv.iter().map(|(j, b)| format!("J{}:B{}", j + 1, b + 1)).collect();
            let owned: Vec<String> =
                m.design.block(s).points.iter().map(|j| format!("J{}", j + 1)).collect();
            t.row(vec![
                format!("U{}", s + 1),
                format!("P{}", m.design.class_of(s) + 1),
                owned.join(","),
                stored.join(" "),
            ]);
        }
    }
    print!("{}", t.render());

    let out = engine.run()?;
    println!("\nShuffle ledger:");
    for stage in [Stage::Stage1, Stage::Stage2, Stage::Stage3] {
        let count = engine.bus.stage_count(stage);
        let bytes = engine.bus.stage_bytes(stage);
        println!(
            "  {stage}: {count} transmissions, {bytes} bytes, load {:.4}",
            engine.bus.stage_load(stage, cfg.load_normalizer())
        );
    }
    let report = LoadReport::from_outcome(&cfg, &out);
    println!();
    print!("{report}");
    println!(
        "\nPaper §III-C: L1 = 1/4, L2 = 1/4, L3 = 1/2, total = 1. \
         CCDC would need C(6,3) = 20 jobs; CAMR used 4."
    );
    Ok(())
}

/// `camr cluster`: the legacy one-shot Arc-shared cluster round (what
/// `camr serve` meant before the continuous job service existed).
fn cmd_cluster(args: &Args) -> Result<()> {
    let k = args.get_usize("k", 3)?;
    let q = args.get_usize("q", 2)?;
    let gamma = args.get_usize("gamma", 2)?;
    let cfg = SystemConfig::new(k, q, gamma)?;
    let wl = Arc::new(SyntheticWorkload::new(&cfg, 1));
    let out = cluster::run_cluster(cfg.clone(), wl)?;
    println!(
        "cluster: K={} J={} load={:.4} (expected {:.4}), {} outputs, {} map calls",
        cfg.servers(),
        cfg.jobs(),
        out.total_load(),
        load::camr_total(k, q),
        out.outputs,
        out.map_invocations
    );
    Ok(())
}

/// Tenant → workload family for `camr serve` traffic: the mixed-load
/// rotation the bench submits.
const SERVE_KINDS: [WorkloadKind; 4] = [
    WorkloadKind::WordCount,
    WorkloadKind::MatVec,
    WorkloadKind::Gradient,
    WorkloadKind::Synthetic,
];

/// Resolve `camr serve`'s system + service knobs: positional/`--config`
/// file first (its `[service]` section), then flag overrides.
fn resolve_serve_setup(
    args: &Args,
    path: Option<String>,
) -> Result<(SystemConfig, u64, camr::config::ServiceConfig)> {
    let (cfg, seed, svc) = match path.or_else(|| args.get_opt("config")) {
        Some(p) => {
            let rc = RunConfig::from_path(std::path::Path::new(&p))?;
            (rc.system, rc.seed, rc.service.unwrap_or_default())
        }
        None => (
            // Small rounds by default: serve throughput comes from many
            // coded rounds in flight, not from one big round.
            SystemConfig::with_options(
                args.get_usize("k", 2)?,
                args.get_usize("q", 2)?,
                args.get_usize("gamma", 1)?,
                1,
                args.get_usize("value-bytes", 16)?,
            )?,
            args.get_u64("seed", 0xCA3A)?,
            camr::config::ServiceConfig::default(),
        ),
    };
    let svc = camr::config::ServiceConfig {
        engines: args.get_usize("engines", svc.engines)?,
        queue_capacity: args.get_usize("queue-cap", svc.queue_capacity)?,
        tenants: args.get_usize("tenants", svc.tenants)?,
        quantum: args.get_u64("quantum", svc.quantum)?,
        weights: match args.get_opt("weights") {
            Some(s) => Some(
                s.split(',')
                    .map(|w| w.trim().parse::<u64>().with_context(|| format!("--weights {s}")))
                    .collect::<Result<Vec<u64>>>()?,
            ),
            None => svc.weights,
        },
    };
    svc.validate()?;
    Ok((cfg, seed, svc))
}

/// Start a [`JobService`] from resolved knobs.
fn start_service(
    cfg: &SystemConfig,
    svc: &camr::config::ServiceConfig,
    parallel: bool,
) -> Result<JobService> {
    let service = JobService::start(
        cfg.clone(),
        ServiceOptions {
            engines: svc.engines,
            parallel,
            weights: svc.weight_vector(),
            queue_capacity: svc.queue_capacity,
            quantum: svc.quantum,
            ..ServiceOptions::default()
        },
    )?;
    Ok(service)
}

/// Package a drained service into the `BENCH_serve.json` report.
fn serve_report(
    cfg: &SystemConfig,
    svc: &camr::config::ServiceConfig,
    parallel: bool,
    quick: bool,
    out: &camr::service::ServiceOutcome,
) -> ServeReport {
    let ns_to_us = |ns: u64| ns / 1_000;
    let sojourn = out.latency_ns(|r| r.sojourn_ns());
    let queue = out.latency_ns(|r| r.queue_ns);
    let exec = out.latency_ns(|r| r.exec_ns);
    let mut tenants: Vec<TenantServe> = out
        .per_tenant()
        .into_iter()
        .map(|t| TenantServe {
            tenant: t.tenant,
            weight: t.weight,
            submitted: 0,
            completed: t.completed,
            rejected: t.rejected,
        })
        .collect();
    for r in &out.results {
        tenants[r.tenant].submitted += 1; // closed-loop: all admitted jobs complete
    }
    ServeReport {
        k: cfg.k,
        q: cfg.q,
        gamma: cfg.gamma,
        value_bytes: cfg.value_bytes,
        servers: cfg.servers(),
        engines: svc.engines,
        parallel,
        quick,
        queue_capacity: svc.queue_capacity,
        jobs_submitted: out.submitted,
        jobs_completed: out.completed() as u64,
        jobs_rejected: out.rejected,
        paper_jobs: out.completed() as u128 * cfg.jobs() as u128,
        verified: out.all_verified(),
        wall_secs: out.wall.as_secs_f64(),
        jobs_per_sec: out.jobs_per_sec(),
        sojourn_us: [ns_to_us(sojourn.0), ns_to_us(sojourn.1)],
        sojourn_mean_us: sojourn.2 / 1e3,
        queue_us: [ns_to_us(queue.0), ns_to_us(queue.1)],
        exec_us: [ns_to_us(exec.0), ns_to_us(exec.1)],
        tenants,
    }
}

/// `camr serve`: the continuous job service. `--bench` runs the
/// closed-loop traffic driver (10^5–10^6 mixed-workload jobs, report
/// into `BENCH_serve.json`); without it, a seeded Poisson open-arrival
/// run is paced in real time and compared against the simulator's
/// replay of the *same* arrival trace.
fn cmd_serve(argv: &[String]) -> Result<()> {
    let (path, rest) = split_positional_config(argv);
    let args = Args::parse(rest, &["json", "parallel", "bench", "quick"])?;
    let (cfg, seed, svc) = resolve_serve_setup(&args, path)?;
    let parallel = args.get_bool("parallel");
    if args.get_bool("bench") {
        return serve_bench(&args, &cfg, seed, &svc, parallel);
    }
    serve_open_arrivals(&args, &cfg, seed, &svc, parallel)
}

/// The closed-loop traffic driver behind `camr serve --bench`.
fn serve_bench(
    args: &Args,
    cfg: &SystemConfig,
    seed: u64,
    svc: &camr::config::ServiceConfig,
    parallel: bool,
) -> Result<()> {
    let quick = args.get_bool("quick")
        || std::env::var("CAMR_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let jobs = args.get_u64("jobs", if quick { 100_000 } else { 1_000_000 })?;
    let tenants = svc.weight_vector().len() as u64;
    let service = start_service(cfg, svc, parallel)?;
    for j in 0..jobs {
        let tenant = (mix_key(seed, &[j, 1]) % tenants) as usize;
        let spec = JobSpec {
            tenant,
            kind: SERVE_KINDS[tenant % SERVE_KINDS.len()],
            seed: mix_key(seed, &[j, 0]),
        };
        // Blocking submit: the closed loop applies backpressure instead
        // of dropping — first full-lane encounter still counts as a
        // rejection, so the report shows how often the queue pushed back.
        service.submit_blocking(spec)?;
    }
    let out = service.drain()?;
    anyhow::ensure!(
        out.completed() as u64 == jobs,
        "service completed {} of {jobs} submitted jobs",
        out.completed()
    );
    anyhow::ensure!(out.all_verified(), "a served job failed oracle verification");
    let report = serve_report(cfg, svc, parallel, quick, &out);
    let rendered = report.to_json();
    if args.get_bool("json") {
        println!("{rendered}");
    } else {
        print!("{report}");
    }
    let dest = args.get_str("out", "BENCH_serve.json");
    std::fs::write(&dest, format!("{rendered}\n"))?;
    eprintln!("report -> {dest}");
    Ok(())
}

/// The open-arrival mode: pace real submissions by a seeded Poisson
/// trace, then replay the identical trace through the FCFS simulator
/// with the measured mean round time and line the two up.
fn serve_open_arrivals(
    args: &Args,
    cfg: &SystemConfig,
    seed: u64,
    svc: &camr::config::ServiceConfig,
    parallel: bool,
) -> Result<()> {
    let trace_cfg = ArrivalConfig {
        rate_per_sec: args.get_f64("rate", 500.0)?,
        jobs: args.get_usize("arrivals", 200)?,
        tenants: svc.weight_vector().len(),
        seed,
    };
    let trace = poisson_trace(&trace_cfg)?;
    let service = start_service(cfg, svc, parallel)?;
    let t0 = Instant::now();
    for (j, a) in trace.iter().enumerate() {
        if let Some(wait) = Duration::from_secs_f64(a.at_secs).checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        service.submit_blocking(JobSpec {
            tenant: a.tenant,
            kind: SERVE_KINDS[a.tenant % SERVE_KINDS.len()],
            seed: mix_key(seed, &[j as u64, 0]),
        })?;
    }
    let out = service.drain()?;
    anyhow::ensure!(out.all_verified(), "a served job failed oracle verification");
    let (_, _, exec_mean_ns) = out.latency_ns(|r| r.exec_ns);
    let sim = simulate_open_arrivals(&trace, exec_mean_ns / 1e9, svc.engines, trace_cfg.tenants)?;
    let (p50, p99, _) = out.latency_ns(|r| r.sojourn_ns());
    println!(
        "open arrivals: {} jobs @ {:.0}/s over {} tenant(s), {} engine(s)  (seed {seed})",
        trace.len(),
        trace_cfg.rate_per_sec,
        trace_cfg.tenants,
        svc.engines
    );
    println!("  {:<12} {:>12} {:>14} {:>14}", "", "jobs/s", "sojourn_p50_s", "sojourn_p99_s");
    println!(
        "  {:<12} {:>12.1} {:>14.6} {:>14.6}",
        "real",
        out.jobs_per_sec(),
        p50 as f64 / 1e9,
        p99 as f64 / 1e9
    );
    println!(
        "  {:<12} {:>12.1} {:>14.6} {:>14.6}",
        "sim",
        sim.throughput,
        sim.sojourn_p50_secs,
        sim.sojourn_p99_secs
    );
    println!(
        "  (sim replays the identical seeded trace against {} FCFS engine(s) at the \
         measured {:.1} µs mean round time)",
        svc.engines,
        exec_mean_ns / 1e3
    );
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let k = args.get_usize("k", 3)?;
    let q = args.get_usize("q", 2)?;
    let cfg = SystemConfig::with_options(k, q, 2, 1, 120)?;
    println!(
        "stage-coding ablation — K={} J={} (all variants oracle-verified):\n",
        cfg.servers(),
        cfg.jobs()
    );
    let mut t = Table::new(vec!["variant", "L1", "L2", "L3", "total", "expected"]);
    for choice in CodingChoice::all() {
        let wl = SyntheticWorkload::new(&cfg, 1);
        let out = run_ablation(cfg.clone(), Box::new(wl), choice)?;
        let n = out.normalizer;
        t.row(vec![
            choice.label(),
            format!("{:.4}", out.stage_bytes[0] as f64 / n),
            format!("{:.4}", out.stage_bytes[1] as f64 / n),
            format!("{:.4}", out.stage_bytes[2] as f64 / n),
            format!("{:.4}", out.total_load()),
            format!("{:.4}", choice.expected_load(k, q)),
        ]);
    }
    print!("{}", t.render());
    println!("\ncoding each stage saves a factor k-1 = {} on that stage's bytes", k - 1);
    Ok(())
}

fn cmd_ccdc(args: &Args) -> Result<()> {
    let servers = args.get_usize("servers", 6)?;
    let k = args.get_usize("k", 3)?;
    let mut e = CcdcEngine::new(servers, k, 2, 64, 7)?;
    let out = e.run()?;
    println!(
        "CCDC baseline: K={servers} k={k} → {} jobs (C({servers},{k}))\n  \
         Eq.(6) load {:.4}   measured (this impl) {:.4}   encode ops {}   verified {}",
        out.jobs,
        out.paper_load(),
        out.measured_load(),
        out.encode_ops,
        out.verified
    );
    println!(
        "CAMR at the same μ would need q^(k-1) jobs with K = k·q (e.g. q = {}: {} jobs).",
        servers / k,
        (servers / k).pow(k as u32 - 1)
    );
    Ok(())
}

fn cmd_timemodel(args: &Args) -> Result<()> {
    let k = args.get_usize("k", 3)?;
    let q = args.get_usize("q", 2)?;
    let gamma = args.get_usize("gamma", 2)?;
    let bytes = args.get_usize("value-bytes", 1 << 20)?;
    let tm = TimeModel::commodity();
    let (tc, tu, speedup) = tm.camr_vs_uncoded(k, q, gamma, bytes);
    let fc = tm.shuffle_fraction(k, q, gamma, bytes, load::camr_total(k, q));
    let fu = tm.shuffle_fraction(k, q, gamma, bytes, load::uncoded_aggregated_total(k, q));
    println!(
        "job-time model (1 Gb/s link, 1 ms map): K={} J={} B={bytes}",
        k * q,
        q.pow(k as u32 - 1)
    );
    println!("  uncoded aggregated: {tu:.4}s  (shuffle share {:.0}%)", fu * 100.0);
    println!("  CAMR coded:         {tc:.4}s  (shuffle share {:.0}%)", fc * 100.0);
    println!("  end-to-end speedup: {speedup:.2}x");
    Ok(())
}

fn cmd_check(argv: &[String]) -> Result<()> {
    let (path, rest) = split_positional_config(argv);
    let args = Args::parse(rest, &["json"])?;
    let (cfg, label) = match path.or_else(|| args.get_opt("config")) {
        Some(p) => (RunConfig::from_path(std::path::Path::new(&p))?.system, p),
        None => (
            SystemConfig::new(
                args.get_usize("k", 3)?,
                args.get_usize("q", 2)?,
                args.get_usize("gamma", 2)?,
            )?,
            "(flags)".to_string(),
        ),
    };
    let facts = camr::check::PlanFacts::from_config(&cfg)?;
    let report = camr::check::prove(&facts);
    if args.get_bool("json") {
        println!("{}", report.to_json().render());
    } else {
        let ops = facts.stage1.len() + facts.stage2.len() + facts.stage3.len();
        println!(
            "camr check {label}: k={} q={} gamma={} -> K={} J={} rounds={} ({ops} scheduled ops)",
            cfg.k,
            cfg.q,
            cfg.gamma,
            cfg.servers(),
            cfg.jobs(),
            cfg.rounds,
        );
        for d in &report.diagnostics {
            println!("  {d}");
        }
        if report.is_clean() {
            println!(
                "  plan proven: decodability, (k-1)x replication, closed-form job \
                 counts, gap-free per-stage sequences, stage-barrier partition"
            );
        }
    }
    if !report.is_clean() {
        bail!("camr check: {} error(s) in {label}", report.errors().len());
    }
    Ok(())
}

fn cmd_lint(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["json"])?;
    let root = PathBuf::from(args.get_str("root", "."));
    let report = camr::check::lint::lint_repo(&root)?;
    if args.get_bool("json") {
        println!("{}", report.to_json().render());
    } else {
        for d in &report.diagnostics {
            println!("{d}");
        }
        println!(
            "camr lint ({}): {} finding(s), {} error(s)",
            root.display(),
            report.diagnostics.len(),
            report.errors().len()
        );
    }
    if !report.is_clean() {
        bail!("camr lint: repo invariants violated");
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    let bool_flags = ["json", "parallel"];
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "worker" => cmd_worker(&Args::parse(rest, &bool_flags)?),
        "simulate" => cmd_simulate(rest),
        "trace" => cmd_trace(rest),
        "batch" => cmd_batch(rest),
        "check" => cmd_check(rest),
        "lint" => cmd_lint(rest),
        "sweep" => cmd_sweep(&Args::parse(rest, &bool_flags)?),
        "table3" => cmd_table3(),
        "example1" => cmd_example1(),
        "serve" => cmd_serve(rest),
        "cluster" => cmd_cluster(&Args::parse(rest, &bool_flags)?),
        "speedup" => cmd_speedup(&Args::parse(rest, &bool_flags)?),
        "ablation" => cmd_ablation(&Args::parse(rest, &bool_flags)?),
        "ccdc" => cmd_ccdc(&Args::parse(rest, &bool_flags)?),
        "timemodel" => cmd_timemodel(&Args::parse(rest, &bool_flags)?),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other}\n{USAGE}"),
    }
}
