//! Job assignment and file placement (paper §III-A, Algorithm 1).
//!
//! Each job's `N = k·γ` subfiles are partitioned into `k` batches of `γ`
//! subfiles. Each batch is labeled with one of the job's `k` owners; an
//! owner stores **all batches except the one labeled with itself**. The
//! resulting storage fraction is `μ = (k-1)/K`.

pub mod batches;
pub mod storage;

pub use batches::Placement;
pub use storage::StorageReport;
