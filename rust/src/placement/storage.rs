//! Storage accounting: verifies the `μ = (k-1)/K` requirement of §III-A.

use super::batches::Placement;
use crate::config::SystemConfig;
use crate::error::{CamrError, Result};

/// Per-cluster storage accounting report.
#[derive(Debug, Clone, PartialEq)]
pub struct StorageReport {
    /// Subfiles stored per server (identical across servers by symmetry).
    pub subfiles_per_server: usize,
    /// Total subfiles across all jobs (`J·N`).
    pub total_subfiles: usize,
    /// Measured storage fraction per server.
    pub measured_mu: f64,
    /// The paper's closed form `(k-1)/K`.
    pub expected_mu: f64,
}

/// Audit the storage of every server against `μ = (k-1)/K`.
///
/// Errors if any server's stored fraction deviates from the closed form
/// (they must be *exactly* equal — the counts are integers).
pub fn audit_storage(p: &Placement, cfg: &SystemConfig) -> Result<StorageReport> {
    let total = cfg.jobs() * cfg.subfiles();
    let expected_mu = cfg.storage_fraction();
    // Each server owns q^{k-2} jobs (= J/q) and stores k-1 batches of γ
    // subfiles for each (§III-A).
    let expected_count = (cfg.jobs() / cfg.q) * (cfg.k - 1) * cfg.gamma;
    let mut first: Option<usize> = None;
    for s in 0..cfg.servers() {
        let count: usize = p.inventory(s).len() * cfg.gamma;
        if count != expected_count {
            return Err(CamrError::Placement(format!(
                "server {s} stores {count} subfiles, expected {expected_count}"
            )));
        }
        let mu = count as f64 / total as f64;
        if (mu - expected_mu).abs() > 1e-12 {
            return Err(CamrError::Placement(format!(
                "server {s} storage fraction {mu} != (k-1)/K = {expected_mu}"
            )));
        }
        first.get_or_insert(count);
    }
    Ok(StorageReport {
        subfiles_per_server: first.unwrap_or(0),
        total_subfiles: total,
        measured_mu: first.unwrap_or(0) as f64 / total as f64,
        expected_mu,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::ResolvableDesign;

    #[test]
    fn example2_mu_is_one_third() {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let d = ResolvableDesign::new(3, 2).unwrap();
        let p = Placement::new(&d, &cfg).unwrap();
        let rep = audit_storage(&p, &cfg).unwrap();
        assert!((rep.measured_mu - 1.0 / 3.0).abs() < 1e-12);
        // 4 batches × γ=2 subfiles per server (Fig. 1).
        assert_eq!(rep.subfiles_per_server, 8);
        assert_eq!(rep.total_subfiles, 24);
    }

    #[test]
    fn mu_matches_closed_form_across_sweep() {
        for (k, q, g) in [(2, 3, 1), (3, 2, 1), (3, 4, 2), (4, 2, 2), (4, 3, 1), (5, 2, 1)] {
            let cfg = SystemConfig::new(k, q, g).unwrap();
            let d = ResolvableDesign::new(k, q).unwrap();
            let p = Placement::new(&d, &cfg).unwrap();
            let rep = audit_storage(&p, &cfg).unwrap();
            assert!(
                (rep.measured_mu - rep.expected_mu).abs() < 1e-12,
                "k={k} q={q} γ={g}"
            );
        }
    }
}
