//! Algorithm 1: batch labeling and per-server storage maps.
//!
//! ## Labeling convention
//!
//! Algorithm 1 says "label each batch with a distinct index of an owner"
//! but leaves the bijection free. We fix the convention that reproduces
//! the paper's Example 2 exactly: with the owners of job `j` sorted
//! ascending as `owners[0..k]`, batch `b` (covering subfiles
//! `[bγ, (b+1)γ)`) is labeled with `owners[(b+1) mod k]`.
//!
//! Check against Example 2 (job `J_1`, owners `{U_1, U_3, U_5}`):
//! batch 0 = {1,2} → label `U_3`, batch 1 = {3,4} → label `U_5`,
//! batch 2 = {5,6} → label `U_1` — precisely the paper's
//! `B^{(1)}_{[i_3]}, B^{(1)}_{[i_5]}, B^{(1)}_{[i_1]}`.

use crate::config::SystemConfig;
use crate::design::ResolvableDesign;
use crate::error::{CamrError, Result};
use crate::{BatchId, JobId, ServerId, SubfileId};

/// The complete file placement for a CAMR deployment.
#[derive(Debug, Clone)]
pub struct Placement {
    k: usize,
    gamma: usize,
    jobs: usize,
    servers: usize,
    /// `label[j][b]` = owner server that batch `b` of job `j` is labeled
    /// with (the unique owner *not* storing that batch).
    label: Vec<Vec<ServerId>>,
    /// `owner_pos[j]` maps each owner of `j` to its position in the
    /// sorted owner list (parallel-class index).
    owners: Vec<Vec<ServerId>>,
}

impl Placement {
    /// Build the Algorithm-1 placement from a design and config.
    pub fn new(design: &ResolvableDesign, cfg: &SystemConfig) -> Result<Self> {
        if design.code.k != cfg.k || design.code.q != cfg.q {
            return Err(CamrError::Placement(
                "design parameters do not match the system config".into(),
            ));
        }
        let jobs = design.jobs();
        let k = cfg.k;
        let mut label = Vec::with_capacity(jobs);
        let mut owners = Vec::with_capacity(jobs);
        for j in 0..jobs {
            let own = design.owners(j).to_vec();
            // Batch b is labeled with owners[(b+1) mod k] (see module doc).
            let lab: Vec<ServerId> = (0..k).map(|b| own[(b + 1) % k]).collect();
            label.push(lab);
            owners.push(own);
        }
        Ok(Placement { k, gamma: cfg.gamma, jobs, servers: cfg.servers(), label, owners })
    }

    /// Number of batches per job (= `k`).
    pub fn batches_per_job(&self) -> usize {
        self.k
    }

    /// Subfiles per batch (`γ`).
    pub fn gamma(&self) -> usize {
        self.gamma
    }

    /// The subfiles in batch `b`: `[bγ, (b+1)γ)`.
    pub fn batch_subfiles(&self, b: BatchId) -> std::ops::Range<SubfileId> {
        b * self.gamma..(b + 1) * self.gamma
    }

    /// The batch containing subfile `n`.
    pub fn batch_of_subfile(&self, n: SubfileId) -> BatchId {
        n / self.gamma
    }

    /// The owner that batch `b` of job `j` is labeled with — the unique
    /// owner **not** storing that batch.
    pub fn batch_label(&self, j: JobId, b: BatchId) -> ServerId {
        self.label[j][b]
    }

    /// The unique batch of job `j` labeled with owner `s` — the one batch
    /// of its job that `s` is missing. Errors if `s` is not an owner.
    pub fn missing_batch(&self, j: JobId, s: ServerId) -> Result<BatchId> {
        self.label[j]
            .iter()
            .position(|&o| o == s)
            .ok_or_else(|| CamrError::Placement(format!("server {s} does not own job {j}")))
    }

    /// The owners of job `j`, sorted ascending (one per parallel class).
    pub fn owners(&self, j: JobId) -> &[ServerId] {
        &self.owners[j]
    }

    /// Whether server `s` owns job `j`.
    pub fn owns(&self, s: ServerId, j: JobId) -> bool {
        self.owners[j].binary_search(&s).is_ok()
    }

    /// Whether server `s` stores batch `b` of job `j`: true iff `s` owns
    /// `j` and the batch is not labeled with `s`.
    pub fn stores_batch(&self, s: ServerId, j: JobId, b: BatchId) -> bool {
        self.owns(s, j) && self.label[j][b] != s
    }

    /// Whether server `s` stores subfile `n` of job `j`.
    pub fn stores_subfile(&self, s: ServerId, j: JobId, n: SubfileId) -> bool {
        self.stores_batch(s, j, self.batch_of_subfile(n))
    }

    /// All batches of job `j` stored by server `s` (empty if non-owner).
    pub fn stored_batches(&self, s: ServerId, j: JobId) -> Vec<BatchId> {
        if !self.owns(s, j) {
            return Vec::new();
        }
        (0..self.k).filter(|&b| self.label[j][b] != s).collect()
    }

    /// All `(job, batch)` pairs stored by server `s` — its local cache
    /// inventory.
    pub fn inventory(&self, s: ServerId) -> Vec<(JobId, BatchId)> {
        let mut inv = Vec::new();
        for j in 0..self.jobs {
            for b in self.stored_batches(s, j) {
                inv.push((j, b));
            }
        }
        inv
    }

    /// Number of jobs in the placement.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Number of servers in the placement.
    pub fn servers(&self) -> usize {
        self.servers
    }

    /// Validate placement invariants (each batch stored by exactly `k-1`
    /// owners; each owner misses exactly one batch per owned job).
    pub fn validate(&self) -> Result<()> {
        for j in 0..self.jobs {
            // Labels must be a permutation of the owners.
            let mut lab = self.label[j].clone();
            lab.sort_unstable();
            if lab != self.owners[j] {
                return Err(CamrError::Placement(format!(
                    "job {j}: batch labels are not a permutation of owners"
                )));
            }
            for b in 0..self.k {
                let holders: Vec<ServerId> = (0..self.servers)
                    .filter(|&s| self.stores_batch(s, j, b))
                    .collect();
                if holders.len() != self.k - 1 {
                    return Err(CamrError::Placement(format!(
                        "job {j} batch {b}: stored by {} servers, expected k-1 = {}",
                        holders.len(),
                        self.k - 1
                    )));
                }
                if holders.contains(&self.label[j][b]) {
                    return Err(CamrError::Placement(format!(
                        "job {j} batch {b}: stored by its own label"
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::design::ResolvableDesign;

    fn example() -> (ResolvableDesign, SystemConfig, Placement) {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let d = ResolvableDesign::new(3, 2).unwrap();
        let p = Placement::new(&d, &cfg).unwrap();
        (d, cfg, p)
    }

    #[test]
    fn example2_batch_labels() {
        // Job J_1 (0-based 0), owners {U1,U3,U5} = {0,2,4}:
        // batch {1,2} → U3, batch {3,4} → U5, batch {5,6} → U1.
        let (_, _, p) = example();
        assert_eq!(p.batch_label(0, 0), 2);
        assert_eq!(p.batch_label(0, 1), 4);
        assert_eq!(p.batch_label(0, 2), 0);
    }

    #[test]
    fn example2_storage_sets() {
        // Fig. 1 + Example 2: U1 stores {1,2},{3,4} of J1; U3 stores
        // {3,4},{5,6}; U5 stores {1,2},{5,6}.
        let (_, _, p) = example();
        assert_eq!(p.stored_batches(0, 0), vec![0, 1]); // U1
        assert_eq!(p.stored_batches(2, 0), vec![1, 2]); // U3
        assert_eq!(p.stored_batches(4, 0), vec![0, 2]); // U5
        assert_eq!(p.stored_batches(1, 0), Vec::<usize>::new()); // U2 non-owner
    }

    #[test]
    fn missing_batch_is_label_inverse() {
        let (_, _, p) = example();
        for j in 0..p.jobs() {
            for &s in &p.owners(j).to_vec() {
                let b = p.missing_batch(j, s).unwrap();
                assert_eq!(p.batch_label(j, b), s);
                assert!(!p.stores_batch(s, j, b));
            }
        }
    }

    #[test]
    fn missing_batch_rejects_non_owner() {
        let (_, _, p) = example();
        assert!(p.missing_batch(0, 1).is_err()); // U2 does not own J1
    }

    #[test]
    fn validate_passes_for_sweep() {
        for (k, q, g) in [(2, 2, 1), (3, 2, 2), (3, 3, 1), (4, 2, 3), (2, 5, 2)] {
            let cfg = SystemConfig::new(k, q, g).unwrap();
            let d = ResolvableDesign::new(k, q).unwrap();
            let p = Placement::new(&d, &cfg).unwrap();
            p.validate().unwrap_or_else(|e| panic!("k={k} q={q}: {e}"));
        }
    }

    #[test]
    fn subfile_batch_mapping() {
        let (_, _, p) = example();
        assert_eq!(p.batch_subfiles(0), 0..2);
        assert_eq!(p.batch_subfiles(2), 4..6);
        assert_eq!(p.batch_of_subfile(5), 2);
        assert!(p.stores_subfile(0, 0, 0)); // U1 stores subfile 1 of J1
        assert!(!p.stores_subfile(0, 0, 5)); // but not subfile 6
    }

    #[test]
    fn inventory_counts_match_mu() {
        // Each server stores q^{k-2} jobs × (k-1) batches.
        for (k, q) in [(3, 2), (3, 3), (4, 2)] {
            let cfg = SystemConfig::new(k, q, 2).unwrap();
            let d = ResolvableDesign::new(k, q).unwrap();
            let p = Placement::new(&d, &cfg).unwrap();
            for s in 0..cfg.servers() {
                let inv = p.inventory(s);
                assert_eq!(inv.len(), q.pow(k as u32 - 2) * (k - 1));
            }
        }
    }
}
