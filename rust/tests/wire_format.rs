//! Wire-format property suite for the socket transport's frame codec
//! (`camr::net::frame`).
//!
//! The contract under test: encoding any frame and feeding the bytes to
//! the incremental decoder — in chunks of any size, down to one byte at
//! a time — reproduces the frame exactly; truncated or corrupt input is
//! a typed [`CamrError::Wire`] error (or a clean "need more bytes"),
//! **never** a panic and never a silently wrong frame.

use camr::error::CamrError;
use camr::net::frame::{
    write_frame, Frame, FrameDecoder, FrameKind, HEADER_LEN, MAX_PAYLOAD, MAX_RECIPIENTS,
};
use camr::net::socket::{decode_outputs, encode_outputs};
use camr::net::Stage;

/// Deterministic pseudo-random byte (no RNG dependency needed).
fn byte(i: usize, salt: u64) -> u8 {
    let x = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(salt);
    (x >> 32) as u8
}

fn frame_with(payload_len: usize, recipients: usize, salt: u64) -> Frame {
    let mut f = Frame::new(FrameKind::Delta);
    f.stage = match salt % 4 {
        0 => Stage::Stage1,
        1 => Stage::Stage2,
        2 => Stage::Stage3,
        _ => Stage::Baseline,
    };
    f.seq = salt.wrapping_mul(0x0101_0101_0101_0101);
    f.job = (salt as u32).wrapping_mul(3);
    f.sender = (salt as u32) % 64;
    f.tag = salt as u32 ^ 0xA5A5;
    f.extra = (salt as u32) % 7;
    f.recipients = (0..recipients).map(|r| (r * 3 + salt as usize) % 4096).collect();
    f.payload = (0..payload_len).map(|i| byte(i, salt)).collect();
    f
}

fn assert_same(a: &Frame, b: &Frame) {
    assert_eq!(a.kind, b.kind);
    assert_eq!(a.stage, b.stage);
    assert_eq!(a.seq, b.seq);
    assert_eq!(a.job, b.job);
    assert_eq!(a.sender, b.sender);
    assert_eq!(a.tag, b.tag);
    assert_eq!(a.extra, b.extra);
    assert_eq!(a.recipients, b.recipients);
    assert_eq!(a.payload, b.payload);
}

/// Payload sizes the transport actually produces: empty control frames,
/// tiny and word-multiple Δs, page-sized values, and non-word-multiple
/// odd sizes that catch alignment assumptions.
const SIZES: [usize; 8] = [0, 1, 7, 8, 63, 1023, 4096, 4097];

#[test]
fn roundtrip_across_payload_sizes_and_recipient_counts() {
    for (i, &len) in SIZES.iter().enumerate() {
        for &nrecip in &[0usize, 1, 5, 17] {
            let f = frame_with(len, nrecip, (i * 31 + nrecip) as u64 + 1);
            let bytes = f.encode();
            assert_eq!(bytes.len(), HEADER_LEN + 4 * nrecip + len);
            let (g, used) = Frame::decode(&bytes).unwrap();
            assert_eq!(used, bytes.len());
            assert_same(&f, &g);
        }
    }
}

#[test]
fn one_byte_at_a_time_feeding_decodes_identically() {
    for (i, &len) in SIZES.iter().enumerate() {
        let f = frame_with(len, 3, i as u64 + 101);
        let bytes = f.encode();
        let mut d = FrameDecoder::new();
        for (fed, b) in bytes.iter().enumerate() {
            // Before the last byte arrives the decoder must keep waiting,
            // never guess.
            if fed + 1 < bytes.len() {
                assert!(d.next_frame().unwrap().is_none(), "frame produced early at {fed}");
            }
            d.feed(std::slice::from_ref(b));
        }
        let g = d.next_frame().unwrap().expect("whole frame fed");
        assert_same(&f, &g);
        assert_eq!(d.buffered(), 0);
    }
}

#[test]
fn arbitrary_chunk_boundaries_decode_identically() {
    let f = frame_with(1023, 5, 7);
    let bytes = f.encode();
    for chunk in [2usize, 3, 13, 39, 40, 41, 1000] {
        let mut d = FrameDecoder::new();
        for c in bytes.chunks(chunk) {
            d.feed(c);
        }
        let g = d.next_frame().unwrap().expect("whole frame fed");
        assert_same(&f, &g);
    }
}

#[test]
fn back_to_back_frames_stream_through_one_decoder() {
    // A worker connection carries many frames; splice several encodings
    // together, feed them across an awkward boundary, and drain.
    let frames: Vec<Frame> =
        (0..5).map(|i| frame_with(SIZES[i % SIZES.len()], i % 4, i as u64 + 55)).collect();
    let mut stream = Vec::new();
    for f in &frames {
        stream.extend_from_slice(&f.encode());
    }
    let mut d = FrameDecoder::new();
    let (a, b) = stream.split_at(stream.len() / 2 + 1);
    d.feed(a);
    let mut got = Vec::new();
    while let Some(f) = d.next_frame().unwrap() {
        got.push(f);
    }
    d.feed(b);
    while let Some(f) = d.next_frame().unwrap() {
        got.push(f);
    }
    assert_eq!(got.len(), frames.len());
    for (f, g) in frames.iter().zip(&got) {
        assert_same(f, g);
    }
    assert_eq!(d.buffered(), 0);
}

#[test]
fn truncation_is_wait_for_incremental_and_typed_error_for_one_shot() {
    let f = frame_with(64, 3, 9);
    let bytes = f.encode();
    for cut in 0..bytes.len() {
        // Incremental: a prefix is "not yet", never an error or a frame.
        let mut d = FrameDecoder::new();
        d.feed(&bytes[..cut]);
        assert!(d.next_frame().unwrap().is_none(), "cut {cut}: produced a frame early");
        // One-shot: the same prefix is a typed Wire error.
        let err = Frame::decode(&bytes[..cut]).unwrap_err();
        assert!(matches!(err, CamrError::Wire(_)), "cut {cut}: {err}");
    }
}

#[test]
fn corrupt_magic_is_a_typed_error_at_every_flip() {
    let bytes = frame_with(16, 2, 3).encode();
    for i in 0..4 {
        let mut bad = bytes.clone();
        bad[i] ^= 0x40;
        let mut d = FrameDecoder::new();
        d.feed(&bad);
        assert!(
            matches!(d.next_frame(), Err(CamrError::Wire(_))),
            "magic byte {i} corruption not caught"
        );
    }
}

#[test]
fn unknown_kind_stage_and_reserved_bytes_are_typed_errors() {
    let bytes = frame_with(16, 2, 4).encode();
    // Unknown frame kind (offset 4; 10 is Spans, the highest assigned).
    for bad_kind in [11u8, 12, 200, 255] {
        let mut bad = bytes.clone();
        bad[4] = bad_kind;
        let mut d = FrameDecoder::new();
        d.feed(&bad);
        assert!(matches!(d.next_frame(), Err(CamrError::Wire(_))), "kind {bad_kind}");
    }
    // Unknown stage code (offset 5).
    for bad_stage in [4u8, 9, 255] {
        let mut bad = bytes.clone();
        bad[5] = bad_stage;
        let mut d = FrameDecoder::new();
        d.feed(&bad);
        assert!(matches!(d.next_frame(), Err(CamrError::Wire(_))), "stage {bad_stage}");
    }
    // Nonzero reserved bytes (offsets 6, 7).
    for off in [6usize, 7] {
        let mut bad = bytes.clone();
        bad[off] = 1;
        let mut d = FrameDecoder::new();
        d.feed(&bad);
        assert!(matches!(d.next_frame(), Err(CamrError::Wire(_))), "reserved {off}");
    }
}

#[test]
fn absurd_lengths_are_rejected_without_allocation() {
    // A corrupt length field must be rejected from the header alone —
    // decoding must not wait for (or try to allocate) gigabytes.
    let bytes = frame_with(8, 1, 5).encode();
    // Recipient count over the cap (offset 32).
    let mut bad = bytes.clone();
    bad[32..36].copy_from_slice(&(MAX_RECIPIENTS + 1).to_le_bytes());
    let mut d = FrameDecoder::new();
    d.feed(&bad);
    assert!(matches!(d.next_frame(), Err(CamrError::Wire(_))));
    // Payload length over the cap (offset 36).
    let mut bad = bytes.clone();
    bad[36..40].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
    let mut d = FrameDecoder::new();
    d.feed(&bad);
    assert!(matches!(d.next_frame(), Err(CamrError::Wire(_))));
    // u32::MAX in both: still a clean typed error.
    let mut bad = bytes;
    bad[32..36].copy_from_slice(&u32::MAX.to_le_bytes());
    bad[36..40].copy_from_slice(&u32::MAX.to_le_bytes());
    let mut d = FrameDecoder::new();
    d.feed(&bad);
    assert!(matches!(d.next_frame(), Err(CamrError::Wire(_))));
}

#[test]
fn corruption_after_a_good_frame_still_surfaces() {
    // The decoder must stay strict mid-stream, not just on frame one.
    let good = frame_with(32, 2, 6).encode();
    let mut bad = frame_with(32, 2, 7).encode();
    bad[0] ^= 0xFF;
    let mut d = FrameDecoder::new();
    d.feed(&good);
    d.feed(&bad);
    assert!(d.next_frame().unwrap().is_some(), "first frame is intact");
    assert!(matches!(d.next_frame(), Err(CamrError::Wire(_))));
}

#[test]
fn zero_copy_write_path_is_byte_identical_to_encode() {
    // write_frame(header, payload) is the transport's streaming path for
    // pooled buffers; it must serialize exactly like Frame::encode.
    for &len in &SIZES {
        let mut f = frame_with(len, 4, len as u64 + 13);
        let owned = f.encode();
        let payload = std::mem::take(&mut f.payload);
        let mut wired = Vec::new();
        write_frame(&mut wired, &f, &payload).unwrap();
        assert_eq!(wired, owned, "payload len {len}");
    }
}

#[test]
fn outputs_payload_roundtrips_and_rejects_corruption() {
    let entries: Vec<((usize, usize), Vec<u8>)> = vec![
        ((0, 0), vec![]),
        ((1, 5), vec![9u8; 64]),
        ((3, 2), (0..63u8).collect()),
    ];
    let payload = encode_outputs(&entries);
    assert_eq!(decode_outputs(&payload).unwrap(), entries);
    // Truncation anywhere is a typed Wire error, not a panic.
    for cut in 0..payload.len() {
        assert!(
            matches!(decode_outputs(&payload[..cut]), Err(CamrError::Wire(_))),
            "cut {cut} accepted"
        );
    }
    // Trailing garbage is rejected too.
    let mut long = payload.clone();
    long.push(0);
    assert!(matches!(decode_outputs(&long), Err(CamrError::Wire(_))));
    // An inflated entry count over-reads into a typed error.
    let mut inflated = payload;
    inflated[0..4].copy_from_slice(&4u32.to_le_bytes());
    assert!(matches!(decode_outputs(&inflated), Err(CamrError::Wire(_))));
}
