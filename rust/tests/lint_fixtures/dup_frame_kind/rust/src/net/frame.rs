// Seeded defect: two frame kinds share wire discriminant 3 — the
// decoder silently misroutes Fused frames as Barrier frames.
impl FrameKind {
    fn code(self) -> u8 {
        match self {
            FrameKind::Hello => 0,
            FrameKind::Delta => 2,
            FrameKind::Fused => 3,
            FrameKind::Barrier => 3,
        }
    }
}
