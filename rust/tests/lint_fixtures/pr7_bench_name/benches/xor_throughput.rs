// The PR 7 defect class, reproduced: the bench identifies its report
// as "shuffle_data_plane", but the assertion suite checks for
// "xor_throughput" — green `cargo test`, guaranteed failure on any
// executed bench run.
fn main() {
    let report = vec![("bench", Json::Str("shuffle_data_plane".into()))];
    let _ = report;
}
