// Fixture assertion suite: only ever checks for "xor_throughput".
#[test]
fn report_names() {
    let expected = "xor_throughput";
    let _ = expected;
}
