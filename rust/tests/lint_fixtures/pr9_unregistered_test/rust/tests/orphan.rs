// The PR 9 defect class: a test file Cargo.toml never mentions.
// With autotests = false, `cargo test` silently skips it.
#[test]
fn never_runs() {
    panic!("this suite is not part of cargo test");
}
