// This file IS registered in the fixture manifest: no finding.
#[test]
fn registered() {}
