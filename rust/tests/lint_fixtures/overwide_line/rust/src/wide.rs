// The PR 7 fmt defect class: one line below is 120 characters wide.
pub fn narrow() {}
pub fn wide() { let message = "a string literal long enough that rustfmt cannot wrap the line back under the width limit"; let _ = message; }
