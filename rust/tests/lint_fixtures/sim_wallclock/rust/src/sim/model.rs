// Seeded defect: the simulator reads the wall clock, so two replays
// of the same ledger can disagree — determinism contract broken.
pub fn jitter_seed() -> u64 {
    let t = std::time::Instant::now();
    let _ = t;
    0
}
