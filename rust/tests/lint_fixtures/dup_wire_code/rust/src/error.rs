// Seeded defect: two error variants share wire code 4 — a Failed
// frame carrying a Placement error reconstructs as ShuffleDecode.
impl CamrError {
    pub fn wire_code(&self) -> u32 {
        match self {
            CamrError::InvalidConfig(_) => 1,
            CamrError::ShuffleDecode(_) => 4,
            CamrError::Placement(_) => 4,
        }
    }
}
