//! Golden ledger compatibility: the pooled shuffle data plane must put
//! *exactly* the same transmissions on the shared link as the legacy
//! allocate-per-packet plane — same order, same senders, same
//! recipients, same byte counts — on both engines.
//!
//! The fixture `rust/tests/golden/example1_ledger.txt` pins the
//! pre-refactor ledger of `configs/example1.toml` (paper Example 1);
//! any accounting drift in a future refactor fails this test. The
//! ledger is payload-independent (it records only sizes and routing),
//! so the fixture is stable across workloads of the same shape.
//!
//! These runs go through whatever XOR kernel tier `shuffle::buf`
//! dispatched (AVX2/NEON/portable), so passing here proves the ledger
//! is byte-identical under the SIMD kernel stack too; CI re-runs the
//! suite with `CAMR_FORCE_PORTABLE=1` to pin the portable tier as well
//! (socket_transport.rs extends the same equality to the tcp and unix
//! planes).
//!
//! Re-bless after an *intentional* schedule change with:
//! `CAMR_BLESS=1 cargo test --test golden_ledger`.

use camr::config::RunConfig;
use camr::coordinator::engine::Engine;
use camr::coordinator::parallel::ParallelEngine;
use camr::net::Bus;
use camr::workload::wordcount::WordCountWorkload;
use std::path::PathBuf;

fn example1_config() -> RunConfig {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/example1.toml");
    RunConfig::from_path(&path).expect("configs/example1.toml parses")
}

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/example1_ledger.txt")
}

/// Render a ledger in the fixture's line format:
/// `<stage> <sender> <bytes> <recipient,...>`.
fn render(bus: &Bus) -> String {
    let mut out = String::new();
    for t in bus.ledger() {
        let recipients: Vec<String> = t.recipients.iter().map(|r| r.to_string()).collect();
        out.push_str(&format!(
            "{} {} {} {}\n",
            t.stage,
            t.sender,
            t.bytes,
            recipients.join(",")
        ));
    }
    out
}

/// The fixture's data lines (comments stripped), newline-terminated.
fn fixture_contents() -> String {
    let text = std::fs::read_to_string(fixture_path()).expect(
        "golden fixture missing — run `CAMR_BLESS=1 cargo test --test golden_ledger` \
         to create it",
    );
    let mut out = String::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push_str(line);
        out.push('\n');
    }
    out
}

fn run_serial(pooling: bool) -> String {
    let rc = example1_config();
    let wl = WordCountWorkload::example1(&rc.system);
    let mut e = Engine::new(rc.system, Box::new(wl)).unwrap();
    e.pooling = pooling;
    let out = e.run().unwrap();
    assert!(out.verified, "serial(pooling={pooling}) failed verification");
    render(&e.bus)
}

fn run_parallel(pooling: bool) -> String {
    let rc = example1_config();
    let wl = WordCountWorkload::example1(&rc.system);
    let mut e = ParallelEngine::new(rc.system, Box::new(wl)).unwrap();
    e.pooling = pooling;
    let out = e.run().unwrap();
    assert!(out.verified, "parallel(pooling={pooling}) failed verification");
    render(&e.bus)
}

#[test]
fn ledger_byte_identical_across_engines_and_data_planes() {
    // The legacy (unpooled) serial ledger is the pre-refactor reference.
    let reference = run_serial(false);
    assert!(!reference.is_empty());
    assert_eq!(run_serial(true), reference, "pooled serial ledger drifted");
    assert_eq!(run_parallel(false), reference, "unpooled parallel ledger drifted");
    assert_eq!(run_parallel(true), reference, "pooled parallel ledger drifted");
}

#[test]
fn ledger_matches_checked_in_golden_fixture() {
    let reference = run_serial(false);
    if std::env::var("CAMR_BLESS").is_ok() {
        let header = "\
# Golden shared-link ledger for configs/example1.toml (paper Example 1:
# k=3, q=2, gamma=2, rounds=1, value_bytes=64 -> K=6 servers, J=4 jobs).
# One line per transmission, in canonical serial schedule order:
#   <stage> <sender> <bytes> <recipient,recipient,...>
# Captured from the pre-pooling data plane; the pooled refactor must
# reproduce it byte-for-byte on both engines (see rust/tests/golden_ledger.rs).
# Regenerate with: CAMR_BLESS=1 cargo test --test golden_ledger
";
        std::fs::write(fixture_path(), format!("{header}{reference}")).unwrap();
    }
    assert_eq!(
        fixture_contents(),
        reference,
        "ledger diverged from the golden fixture; if the schedule change is \
         intentional, re-bless with CAMR_BLESS=1"
    );
}

#[test]
fn golden_fixture_totals_match_paper_example1() {
    // Cross-check the fixture itself against the paper's closed forms:
    // stage 1 = 6B, stage 2 = 6B, stage 3 = 12B, total = 24B -> L = 1.
    let rc = example1_config();
    let b = rc.system.value_bytes;
    // Under CAMR_BLESS the sibling test may be rewriting the fixture
    // concurrently; audit the freshly rendered ledger instead of racing
    // the file write (they are asserted equal anyway).
    let text = if std::env::var("CAMR_BLESS").is_ok() {
        run_serial(false)
    } else {
        fixture_contents()
    };
    let mut per_stage = [0usize; 3];
    let mut count = 0usize;
    for line in text.lines() {
        let mut parts = line.split_whitespace();
        let stage = parts.next().unwrap();
        let _sender: usize = parts.next().unwrap().parse().unwrap();
        let bytes: usize = parts.next().unwrap().parse().unwrap();
        let idx = match stage {
            "stage1" => 0,
            "stage2" => 1,
            "stage3" => 2,
            other => panic!("unexpected stage {other}"),
        };
        per_stage[idx] += bytes;
        count += 1;
    }
    assert_eq!(count, 36, "Example 1 has 24 coded broadcasts + 12 unicasts");
    assert_eq!(per_stage, [6 * b, 6 * b, 12 * b]);
}
