//! Cross-module integration: full pipeline runs across the parameter
//! grid and every workload, all oracle-verified with byte-exact loads.

use camr::analysis::load;
use camr::baseline::{UncodedEngine, UncodedMode};
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::metrics::LoadReport;
use camr::workload::gradient::GradientWorkload;
use camr::workload::matvec::{MatVecWorkload, NativeShardCompute};
use camr::workload::synth::SyntheticWorkload;
use camr::workload::wordcount::WordCountWorkload;
use std::sync::Arc;

#[test]
fn parameter_grid_all_verified_exact_loads() {
    // B = 120 divides by k-1 for k ∈ {2..=5} → zero padding slack.
    for (k, q, gamma) in [
        (2usize, 2usize, 1usize),
        (2, 3, 2),
        (2, 5, 1),
        (3, 2, 1),
        (3, 2, 3),
        (3, 3, 2),
        (3, 4, 1),
        (4, 2, 2),
        (4, 3, 1),
        (5, 2, 1),
    ] {
        let cfg = SystemConfig::with_options(k, q, gamma, 1, 120).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 0xFEED ^ (k as u64) << 8 ^ q as u64);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified, "k={k} q={q} γ={gamma}");
        let expect = load::camr_total(k, q);
        assert!(
            (out.total_load() - expect).abs() < 1e-12,
            "k={k} q={q} γ={gamma}: {} vs {expect}",
            out.total_load()
        );
        let report = LoadReport::from_outcome(&cfg, &out);
        assert!(report.matches_analysis());
    }
}

#[test]
fn all_workloads_verify_on_example1_shape() {
    // wordcount (u64 exact)
    {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = WordCountWorkload::synthetic(&cfg, 5, 30);
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        assert!(e.run().unwrap().verified);
    }
    // matvec (f32 tolerance)
    {
        let cfg = SystemConfig::with_options(3, 2, 2, 1, 64).unwrap();
        let wl =
            MatVecWorkload::synthetic(&cfg, 5, 16, 8, Arc::new(NativeShardCompute)).unwrap();
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        assert!(e.run().unwrap().verified);
    }
    // gradient (f32 tolerance)
    {
        let cfg = SystemConfig::with_options(3, 2, 2, 1, 8).unwrap();
        let wl = GradientWorkload::synthetic(&cfg, 5, 2, 4).unwrap();
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        assert!(e.run().unwrap().verified);
    }
    // synthetic (u64 exact)
    {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 5);
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        assert!(e.run().unwrap().verified);
    }
}

#[test]
fn multi_round_q_equals_2k_and_3k() {
    for rounds in [2usize, 3] {
        let cfg = SystemConfig::with_options(3, 2, 2, rounds, 64).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 7);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified);
        // Load normalized by JQB is round-invariant (§II).
        assert!((out.total_load() - 1.0).abs() < 1e-12, "rounds={rounds}");
        assert_eq!(out.outputs, cfg.jobs() * cfg.functions());
    }
}

#[test]
fn odd_value_sizes_stay_within_padding_slack() {
    // B not divisible by k-1: measured load may exceed the closed form
    // by at most the padding bound (k-1 extra bytes per packet-split
    // value → handled by LoadReport::matches_analysis).
    for bytes in [8usize, 24, 40, 56, 104] {
        let cfg = SystemConfig::with_options(3, 2, 2, 1, bytes).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 1);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified, "B={bytes}");
        let report = LoadReport::from_outcome(&cfg, &out);
        assert!(report.matches_analysis(), "B={bytes}: load {}", out.total_load());
        assert!(out.total_load() >= load::camr_total(3, 2) - 1e-12);
    }
}

#[test]
fn uncoded_baselines_verify_and_order_correctly() {
    let cfg = SystemConfig::new(3, 3, 2).unwrap();
    let camr = {
        let wl = SyntheticWorkload::new(&cfg, 9);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.run().unwrap().total_load()
    };
    let agg = {
        let wl = SyntheticWorkload::new(&cfg, 9);
        let mut e =
            UncodedEngine::new(cfg.clone(), Box::new(wl), UncodedMode::Aggregated).unwrap();
        e.run().unwrap().load()
    };
    let raw = {
        let wl = SyntheticWorkload::new(&cfg, 9);
        let mut e = UncodedEngine::new(cfg.clone(), Box::new(wl), UncodedMode::Raw).unwrap();
        e.run().unwrap().load()
    };
    assert!(camr < agg, "coding must beat aggregated unicast for k=3");
    assert!(agg < raw, "aggregation must beat raw shuffle");
}

#[test]
fn deterministic_given_seed() {
    let run = |seed: u64| {
        let cfg = SystemConfig::new(3, 2, 2).unwrap();
        let wl = SyntheticWorkload::new(&cfg, seed);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.run().unwrap();
        (0..cfg.jobs())
            .flat_map(|j| (0..cfg.functions()).map(move |f| (j, f)))
            .map(|(j, f)| e.output(j, f).unwrap().clone())
            .collect::<Vec<_>>()
    };
    assert_eq!(run(42), run(42));
    assert_ne!(run(42), run(43));
}

#[test]
fn engine_reports_phase_times_and_outputs() {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let wl = SyntheticWorkload::new(&cfg, 3);
    let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
    let out = e.run().unwrap();
    assert_eq!(out.outputs, 24);
    assert_eq!(out.map_invocations, (cfg.k - 1) * cfg.jobs() * cfg.subfiles());
    // Phase durations are populated (non-zero map work happened).
    assert!(out.map_time.as_nanos() > 0);
}

#[test]
fn rerun_is_idempotent() {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let wl = SyntheticWorkload::new(&cfg, 4);
    let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
    let a = e.run().unwrap();
    let b = e.run().unwrap();
    assert_eq!(a.stage_bytes, b.stage_bytes);
    assert!(b.verified);
}

#[test]
fn run_config_fixtures_parse_and_run() {
    // The shipped config files must stay valid.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let rc = camr::config::RunConfig::from_path(&root.join("configs/example1.toml")).unwrap();
    assert_eq!(rc.system.jobs(), 4);
    let wl = WordCountWorkload::synthetic(&rc.system, rc.seed, 40);
    let mut e = Engine::new(rc.system.clone(), Box::new(wl)).unwrap();
    let out = e.run().unwrap();
    assert!(out.verified);

    let rc = camr::config::RunConfig::from_path(&root.join("configs/matvec_pjrt.toml")).unwrap();
    assert_eq!(rc.artifact.as_deref(), Some("artifacts/map_kernel.hlo.txt"));
}

#[test]
#[ignore = "stress: ~36 servers, 64 jobs — run with --ignored"]
fn stress_k3_q8() {
    let cfg = SystemConfig::with_options(3, 8, 2, 1, 256).unwrap();
    let wl = SyntheticWorkload::new(&cfg, 1);
    let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
    let out = e.run().unwrap();
    assert!(out.verified);
    assert!((out.total_load() - load::camr_total(3, 8)).abs() < 1e-12);
}
