//! Failure injection: the engine's strict local-state model means any
//! corruption or loss must surface as a typed error or an oracle
//! mismatch — never as a silently wrong answer. Exercised on **both**
//! engines (serial and thread-per-worker), including the buffer-pool
//! hygiene invariant: after any failed run, every pooled buffer has
//! been returned exactly once — never leaked, never double-released.

use camr::config::{SystemConfig, WorkloadKind};
use camr::coordinator::engine::Engine;
use camr::coordinator::master::Master;
use camr::coordinator::parallel::{ParallelEngine, TransportKind};
use camr::coordinator::remote::{SocketOptions, WorkerSpec};
use camr::coordinator::values::ValueKey;
use camr::coordinator::worker::Worker;
use camr::error::CamrError;
use camr::shuffle::multicast::GroupPlan;
use camr::shuffle::plan::ChunkSpec;
use camr::workload::synth::SyntheticWorkload;
use camr::workload::{build_native, Workload};
use std::time::{Duration, Instant};

/// A workload whose map fails for one (job, subfile) — models a dead
/// mapper kernel on one server.
struct FailingMapWorkload {
    inner: SyntheticWorkload,
    job: usize,
    subfile: usize,
}

impl Workload for FailingMapWorkload {
    fn name(&self) -> &str {
        "failing-map"
    }
    fn aggregator(&self) -> &dyn camr::agg::Aggregator {
        self.inner.aggregator()
    }
    fn map_subfile(&self, job: usize, subfile: usize) -> camr::error::Result<Vec<Vec<u8>>> {
        if job == self.job && subfile == self.subfile {
            return Err(CamrError::Runtime("injected map failure".into()));
        }
        self.inner.map_subfile(job, subfile)
    }
}

/// A workload wrapper that flips one bit in one intermediate value —
/// models a corrupted mapper (bad disk/memory on one server).
struct CorruptingWorkload {
    inner: SyntheticWorkload,
    job: usize,
    subfile: usize,
    func: usize,
}

impl Workload for CorruptingWorkload {
    fn name(&self) -> &str {
        "corrupting"
    }
    fn aggregator(&self) -> &dyn camr::agg::Aggregator {
        self.inner.aggregator()
    }
    fn map_subfile(&self, job: usize, subfile: usize) -> camr::error::Result<Vec<Vec<u8>>> {
        let mut vals = self.inner.map_subfile(job, subfile)?;
        if job == self.job && subfile == self.subfile {
            vals[self.func][0] ^= 0x01;
        }
        Ok(vals)
    }
    // The oracle uses the *uncorrupted* inner workload, so the mapper
    // corruption is detectable.
    fn oracle(
        &self,
        cfg: &SystemConfig,
        job: usize,
        func: usize,
    ) -> camr::error::Result<Vec<u8>> {
        self.inner.oracle(cfg, job, func)
    }
}

#[test]
fn corrupted_mapper_is_caught_by_verification() {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let wl = CorruptingWorkload {
        inner: SyntheticWorkload::new(&cfg, 5),
        job: 1,
        subfile: 3,
        func: 2,
    };
    let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
    match e.run() {
        Err(CamrError::Verification(msg)) => {
            assert!(msg.contains("mismatch"), "unexpected message: {msg}");
        }
        other => panic!("expected verification failure, got {other:?}"),
    }
}

#[test]
fn missing_map_phase_fails_encode() {
    // A worker that skipped its map phase cannot encode its broadcasts.
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let master = Master::new(cfg.clone()).unwrap();
    let schedule = master.schedule().unwrap();
    let w = Worker::new(0, &cfg); // empty store
    let plan = &schedule.stage1[0];
    assert!(matches!(
        w.encode_for_group(plan),
        Err(CamrError::MissingValue(_))
    ));
}

#[test]
fn corrupted_delta_is_caught_by_verification() {
    // Manually corrupt one coded broadcast: the receiver decodes garbage
    // and its reduce output must mismatch the oracle.
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let master = Master::new(cfg.clone()).unwrap();
    let schedule = master.schedule().unwrap();
    let wl = SyntheticWorkload::new(&cfg, 6);
    let mut workers: Vec<Worker> =
        (0..cfg.servers()).map(|s| Worker::new(s, &cfg)).collect();
    for w in workers.iter_mut() {
        w.run_map_phase(&cfg, &master.placement, &wl).unwrap();
    }
    let plan = &schedule.stage1[0];
    let mut deltas: Vec<Vec<u8>> = plan
        .members
        .iter()
        .map(|&m| workers[m].encode_for_group(plan).unwrap())
        .collect();
    deltas[0][0] ^= 0xFF; // corruption on the wire
    // Member at position 1 decodes using the corrupted delta from 0.
    let m = plan.members[1];
    workers[m].decode_from_group(plan, &deltas).unwrap();
    let c = plan.chunks[1];
    let got = workers[m]
        .store
        .get(ValueKey { job: c.job, func: c.func, batch: c.batch })
        .unwrap()
        .clone();
    // Compare against an honest re-encode.
    let honest = workers[plan.members[0]].encode_for_group(plan).unwrap();
    let mut honest_deltas = deltas.clone();
    honest_deltas[0] = honest;
    workers[m].decode_from_group(plan, &honest_deltas).unwrap();
    let want = workers[m]
        .store
        .get(ValueKey { job: c.job, func: c.func, batch: c.batch })
        .unwrap()
        .clone();
    assert_ne!(got, want, "corruption must change the decoded chunk");
}

#[test]
fn wrong_group_membership_is_rejected() {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let w = Worker::new(0, &cfg);
    let plan = GroupPlan {
        members: vec![1, 2, 3], // worker 0 not a member
        chunks: (0..3)
            .map(|p| ChunkSpec { receiver: p + 1, job: 0, func: p + 1, batch: p })
            .collect(),
    };
    assert!(matches!(w.encode_for_group(&plan), Err(CamrError::Placement(_))));
}

#[test]
fn truncated_delta_is_rejected() {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let master = Master::new(cfg.clone()).unwrap();
    let schedule = master.schedule().unwrap();
    let wl = SyntheticWorkload::new(&cfg, 6);
    let mut workers: Vec<Worker> =
        (0..cfg.servers()).map(|s| Worker::new(s, &cfg)).collect();
    for w in workers.iter_mut() {
        w.run_map_phase(&cfg, &master.placement, &wl).unwrap();
    }
    let plan = &schedule.stage1[0];
    let mut deltas: Vec<Vec<u8>> = plan
        .members
        .iter()
        .map(|&m| workers[m].encode_for_group(plan).unwrap())
        .collect();
    deltas[2].truncate(3); // short packet
    let m = plan.members[0];
    assert!(matches!(
        workers[m].decode_from_group(plan, &deltas),
        Err(CamrError::ShuffleDecode(_))
    ));
}

#[test]
fn traffic_is_perfectly_balanced_across_servers() {
    // The SPC design is symmetric: every server transmits the same
    // number of bytes in a full run (stages 1+2+3 combined).
    for (k, q) in [(3usize, 2usize), (3, 3), (4, 2)] {
        let cfg = SystemConfig::with_options(k, q, 2, 1, 120).unwrap();
        let wl = SyntheticWorkload::new(&cfg, 2);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.run().unwrap();
        let tx = e.bus.per_server_tx(cfg.servers());
        assert!(
            tx.iter().all(|&b| b == tx[0]),
            "k={k} q={q}: unbalanced tx {tx:?}"
        );
        let rx = e.bus.per_server_rx(cfg.servers());
        assert!(
            rx.iter().all(|&b| b == rx[0]),
            "k={k} q={q}: unbalanced rx {rx:?}"
        );
    }
}

#[test]
fn serial_engine_map_failure_surfaces_and_leaves_pool_clean() {
    // The serial engine hits the failing mapper mid map phase: the run
    // must error out before any shuffle traffic, and every buffer the
    // pool handed out must have come back exactly once (no buffer is
    // leaked, none is released twice).
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let wl = FailingMapWorkload { inner: SyntheticWorkload::new(&cfg, 3), job: 1, subfile: 2 };
    let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
    let err = e.run().expect_err("run must fail");
    assert!(err.to_string().contains("injected map failure"), "got: {err}");
    assert_eq!(e.bus.total_bytes(), 0, "no shuffle traffic after a map failure");
    let stats = e.pool_stats();
    assert_eq!(stats.outstanding(), 0, "pool leak after failure: {stats:?}");
    assert_eq!(stats.acquired, stats.released, "double release: {stats:?}");
}

#[test]
fn serial_engine_verification_failure_leaves_pool_clean() {
    // A corrupted mapper makes the run fail *after* the whole shuffle —
    // by then the pool has seen real traffic, and it must all be back.
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let wl = CorruptingWorkload {
        inner: SyntheticWorkload::new(&cfg, 5),
        job: 0,
        subfile: 1,
        func: 0,
    };
    let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
    assert!(matches!(e.run(), Err(CamrError::Verification(_))));
    let stats = e.pool_stats();
    assert!(stats.acquired > 0, "shuffle must have used the pool");
    assert_eq!(stats.outstanding(), 0, "pool leak after failure: {stats:?}");
    assert_eq!(stats.acquired, stats.released, "double release: {stats:?}");
}

#[test]
fn serial_engine_recovers_after_failed_run() {
    // The same engine object reruns cleanly after a failure: the pool
    // keeps recycling, and nothing from the failed run lingers.
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let wl = CorruptingWorkload {
        inner: SyntheticWorkload::new(&cfg, 9),
        job: 2,
        subfile: 0,
        func: 3,
    };
    let mut bad = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
    assert!(bad.run().is_err());
    let wl = SyntheticWorkload::new(&cfg, 9);
    let mut good = Engine::new(cfg, Box::new(wl)).unwrap();
    let out = good.run().unwrap();
    assert!(out.verified);
    assert_eq!(good.pool_stats().outstanding(), 0);
}

#[test]
fn parallel_engine_worker_failure_leaves_pool_clean() {
    // One worker's map fails; the poison-flag protocol aborts the run
    // without deadlock, all threads exit, and the shared pool gets every
    // buffer back exactly once — including Δs already in flight through
    // peer channels when the failure struck.
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let wl = FailingMapWorkload { inner: SyntheticWorkload::new(&cfg, 8), job: 1, subfile: 2 };
    let mut e = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
    let err = e.run().expect_err("run must fail");
    let msg = err.to_string();
    assert!(
        msg.contains("injected map failure") || msg.contains("aborted"),
        "unexpected error: {msg}"
    );
    let stats = e.pool_stats();
    assert_eq!(stats.outstanding(), 0, "pool leak after worker failure: {stats:?}");
    assert_eq!(stats.acquired, stats.released, "double release: {stats:?}");
}

#[test]
fn parallel_engine_pool_stays_clean_across_failure_then_success() {
    // Failure followed by a clean rerun on the same engine: pooled
    // buffers from the failed run must not corrupt the next one.
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    {
        let wl =
            FailingMapWorkload { inner: SyntheticWorkload::new(&cfg, 8), job: 0, subfile: 0 };
        let mut e = ParallelEngine::new(cfg.clone(), Box::new(wl)).unwrap();
        assert!(e.run().is_err());
        assert_eq!(e.pool_stats().outstanding(), 0);
    }
    let wl = SyntheticWorkload::new(&cfg, 8);
    let mut e = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
    let first = e.run().unwrap();
    assert!(first.verified);
    let second = e.run().unwrap();
    assert!(second.verified);
    let stats = e.pool_stats();
    assert_eq!(stats.outstanding(), 0);
    assert!(stats.recycled > 0, "second run should reuse first-run buffers: {stats:?}");
}

#[test]
fn reduce_before_shuffle_fails_cleanly() {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let master = Master::new(cfg.clone()).unwrap();
    let wl = SyntheticWorkload::new(&cfg, 1);
    let mut w = Worker::new(0, &cfg);
    w.run_map_phase(&cfg, &master.placement, &wl).unwrap();
    // Owned job without stage-1 value: missing the last batch aggregate.
    assert!(matches!(
        w.reduce(&cfg, &master.placement, wl.aggregator(), 0, 0),
        Err(CamrError::MissingValue(_))
    ));
    // Non-owned job without stage-2/3 values.
    assert!(matches!(
        w.reduce(&cfg, &master.placement, wl.aggregator(), 2, 0),
        Err(CamrError::MissingValue(_))
    ));
}

/// A socket-plane engine on Example 1's shape, wired for fault
/// injection: worker 0 crashes right after crossing `die_after` (so
/// mid-next-stage from its peers' point of view).
fn socket_engine(opts_base: SocketOptions, die_after: usize, seed: u64) -> ParallelEngine {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let wl = build_native(WorkloadKind::Synthetic, &cfg, seed).unwrap();
    let mut e = ParallelEngine::new(cfg, wl).unwrap();
    let mut opts = opts_base;
    opts.die_after_barrier = Some(die_after);
    opts.disconnect_timeout = Duration::from_secs(5);
    e.remote_spec = Some(WorkerSpec { kind: WorkloadKind::Synthetic, seed });
    e.transport = TransportKind::Socket(opts);
    e
}

#[test]
fn socket_worker_vanishing_mid_stage_is_a_typed_disconnect() {
    // Thread-mode workers over a Unix socket; worker 0 drops its
    // connection right after the stage-1 barrier. The hub must surface
    // a typed Disconnected — promptly, never a hang — and every pooled
    // buffer must be back home when run() returns.
    let mut e = socket_engine(SocketOptions::unix_threads(), 1, 0xBAD);
    let t0 = Instant::now();
    let err = e.run().unwrap_err();
    let elapsed = t0.elapsed();
    assert!(
        matches!(err, CamrError::Disconnected(_)),
        "expected Disconnected, got {err:?}"
    );
    // EOF detection is immediate; allow slack far below anything that
    // would count as a hang but well above CI scheduling jitter.
    assert!(elapsed < Duration::from_secs(30), "took {elapsed:?}");
    let stats = e.pool_stats();
    assert_eq!(stats.outstanding(), 0, "pooled buffers leaked: {stats:?}");
    assert_eq!(stats.acquired, stats.released);
}

#[test]
fn killed_worker_process_surfaces_within_timeout_not_a_hang() {
    // Real subprocess workers over TCP; worker 0's process exits
    // mid-run (after the map barrier). The peers are blocked waiting on
    // its coded packets — the hub must still unblock everyone and
    // return a typed Disconnected within the configured timeout.
    let exe = std::path::PathBuf::from(env!("CARGO_BIN_EXE_camr"));
    let mut e = socket_engine(SocketOptions::tcp_processes(exe), 0, 0xDEAD);
    let t0 = Instant::now();
    let err = e.run().unwrap_err();
    let elapsed = t0.elapsed();
    assert!(
        matches!(err, CamrError::Disconnected(_)),
        "expected Disconnected, got {err:?}"
    );
    assert!(elapsed < Duration::from_secs(60), "took {elapsed:?}");
    assert_eq!(e.pool_stats().outstanding(), 0);
}

#[test]
fn socket_engine_recovers_after_worker_crash() {
    // A crashed run must not poison the engine: clearing the fault hook
    // and rerunning on the same engine verifies cleanly, and the pool
    // balance still holds across the failure/success pair.
    let mut e = socket_engine(SocketOptions::unix_threads(), 1, 42);
    assert!(e.run().is_err());
    assert_eq!(e.pool_stats().outstanding(), 0);
    let mut opts = SocketOptions::unix_threads();
    opts.disconnect_timeout = Duration::from_secs(30);
    e.transport = TransportKind::Socket(opts);
    let out = e.run().expect("clean rerun after a crashed run");
    assert!(out.verified);
    let stats = e.pool_stats();
    assert_eq!(stats.outstanding(), 0);
    assert_eq!(stats.acquired, stats.released);
}
